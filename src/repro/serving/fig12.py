"""Campaign-engine entry point for the fig12 serving-SLO experiment.

One point = one fully deterministic open-loop serving run: a CRN
workload (``traffic.build_workload``) driven through the virtual-clock
front end (``frontend.run_virtual_serving``) under one scheduling
policy, summarized to one tidy SLO row (``slo.slo_summary``).  Same
``(seed0, set_index)`` and traffic knobs across policies -> identical
arrival/service realizations, so the MESC-vs-non-preemptive delta in
any row pair is a pure policy effect (common random numbers).

``serving_v`` is the cache-key salt: bump
:data:`SERVING_SEMANTICS_VERSION` whenever the serving stack's
semantics change and every cached fig12 row is invalidated without
touching other campaigns' namespaces.

The offered-load axis is ``lo_load``: the LO arrival rate as a
multiple of pool capacity (``lanes x ServiceModelSpec.
lane_capacity_rps``) — ``lo_load >= 1`` saturates the pool, which is
where the paper's 250x inversion-resolution claim becomes a tail-
latency SLO statement (docs/serving.md explains the fig12 reading).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.core.scheduler import Policy
from repro.core.taskgen import point_seed
from repro.serving.frontend import ServiceModelSpec, run_virtual_serving
from repro.serving.slo import slo_summary
from repro.serving.traffic import Poisson, build_workload, make_process

SERVING_SEMANTICS_VERSION = 1

POLICIES = {
    "mesc": Policy.mesc,
    "np": Policy.non_preemptive,
    "lp": Policy.limited,
    "amc": Policy.amc,
}


def simulate_fig12_point(*, policy: str, arrivals: str, lanes: int,
                         set_index: int, seed0: int = 0,
                         n_lo: int = 64, n_hi: int = 24,
                         lo_load: float = 1.2, hi_rate_rps: float = 0.25,
                         lo_tokens: int = 96, hi_tokens: int = 8,
                         hi_deadline_s: float = 0.5,
                         lo_deadline_s: Optional[float] = None,
                         decode_mean_ms: float = 10.0,
                         prefill_mean_ms: float = 20.0,
                         jitter: float = 0.25,
                         cs_ms: float = 4.0,
                         max_live_lo: Optional[int] = None,
                         trace_path: Optional[str] = None,
                         serving_v: Any = None) -> Dict[str, Any]:
    """One serving run -> one SLO row.

    ``policy`` names a :data:`POLICIES` entry; ``arrivals`` names the
    LO arrival process (``traffic.PROCESS_KINDS``) — the HI stream is
    always Poisson at ``hi_rate_rps`` per lane (sparse, latency-
    critical).  ``lo_load`` scales the LO rate against pool capacity.
    Every kwarg is JSON-able, so the row is campaign-cacheable and
    byte-identical on replay (the serving-smoke CI gate).
    """
    del serving_v                   # cache-key salt only
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; "
                         f"want one of {sorted(POLICIES)}")
    seed = point_seed(seed0, set_index)
    svc = ServiceModelSpec(decode_mean_s=decode_mean_ms * 1e-3,
                           prefill_mean_s=prefill_mean_ms * 1e-3,
                           jitter=jitter,
                           cs_save_s=cs_ms * 1e-3,
                           cs_restore_s=cs_ms * 1e-3)
    # mean LO tokens is the midpoint of traffic._token_budget's
    # uniform [tokens/2, 3*tokens/2] draw = lo_tokens
    capacity = lanes * svc.lane_capacity_rps(float(lo_tokens))
    lo_rate = lo_load * capacity
    lo_process = make_process(arrivals, lo_rate, trace_path=trace_path)
    hi_process = Poisson(hi_rate_rps * lanes)
    workload = build_workload(seed=seed, lo_process=lo_process,
                              hi_process=hi_process,
                              n_lo=n_lo, n_hi=n_hi,
                              lo_tokens=lo_tokens, hi_tokens=hi_tokens)
    requests = run_virtual_serving(
        workload, lanes=lanes, policy=POLICIES[policy](), seed=seed,
        decode_mean_s=svc.decode_mean_s,
        prefill_mean_s=svc.prefill_mean_s, jitter=svc.jitter,
        cs_save_s=svc.cs_save_s, cs_restore_s=svc.cs_restore_s,
        max_live_lo=max_live_lo)
    row = slo_summary(requests.values(), hi_deadline_s=hi_deadline_s,
                      lo_deadline_s=lo_deadline_s)
    row["offered_lo_rps"] = float(lo_rate)
    row["capacity_rps"] = float(capacity)
    row["seed"] = seed
    # raw HI latencies ride along (sorted; a few dozen floats) so the
    # figure can pool a true p999 across set_index replications
    # instead of averaging per-point p99s
    row["hi_latencies_s"] = sorted(
        r.finished_at - r.submitted_at
        for r in requests.values()
        if r.crit.value == "HI" and r.done and r.finished_at is not None)
    return row
