"""Injectable clocks for the serving stack (the clock-injection
contract, see ``docs/serving.md``).

A *clock* is any zero-arg callable returning seconds as a float.
``core.serving.MESCServer`` reads every timestamp (``submitted_at``,
``started_at``, ``exec_s`` accumulation, LO-budget mode-switch checks)
through its injected clock, so the same scheduling code runs in two
regimes:

  * **wall clock** (:func:`wall_clock`, the default) — real serving:
    timestamps are ``time.monotonic()`` and service time is whatever
    the jitted dispatch actually costs;
  * **virtual clock** (:class:`VirtualClock`) — deterministic replay:
    time only moves when a model (``frontend.VirtualModel``) or the
    context-switch cost hooks explicitly :meth:`~VirtualClock.advance`
    it, so LO-budget timers, mode switches and every SLO metric are
    exact functions of ``(workload, seed, policy)`` — byte-identical
    across runs, machines and CI invocations.

Clocks are per dispatch lane: each lane of a
``core.serving.MultiLaneServer`` is an independent virtual accelerator
whose local time advances with its own dispatches (the open-loop driver
in ``frontend`` keeps idle lanes' clocks rode forward so admission
stays causal).
"""
from __future__ import annotations

import time

#: The default clock: real (monotonic) time.
wall_clock = time.monotonic


class VirtualClock:
    """Deterministic simulated time: moves only via :meth:`advance`.

    Calling the instance returns the current virtual time in seconds.
    ``advance`` adds a non-negative service duration; ``advance_to``
    clamps forward to an absolute time (used by the open-loop driver to
    ride idle lanes forward to the global frontier / next arrival).
    """

    __slots__ = ("_now",)

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def __call__(self) -> float:
        return self._now

    @property
    def now(self) -> float:
        return self._now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"VirtualClock.advance(dt={dt}): dt must "
                             "be >= 0 (virtual time is monotone)")
        self._now += dt
        return self._now

    def advance_to(self, t: float) -> float:
        """Move forward to absolute time ``t`` (no-op if already past)."""
        if t > self._now:
            self._now = t
        return self._now

    def __repr__(self) -> str:                      # pragma: no cover
        return f"VirtualClock({self._now!r})"
