"""Trace-driven serving front-end: open-loop traffic, admission
control, and SLO accounting over the MESC serving stack (fig12).

The package restates the paper's inversion-resolution claim as what it
is in production terms — a tail-latency SLO result under load:

  * :mod:`repro.serving.traffic` — arrival-process generators
    (Poisson, diurnal, bursty/heavy-tail, trace replay) built on the
    repo's counter-based splitmix64 CRN idiom keyed
    ``(seed, stream, arrival_index)`` — no host RNG, so traffic is
    byte-reproducible and comparable across policies under common
    random numbers;
  * :mod:`repro.serving.frontend` — the admission-control front door
    (HI queue drains before LO, optional LO live-cap) feeding
    ``core.serving.MultiLaneServer``, plus the virtual-clock /
    virtual-service-time harness that makes serving behaviour
    deterministic and CI-gateable;
  * :mod:`repro.serving.slo` — per-request SLO metrics (p50/p99/p999
    latency and TTFT, deadline-miss rate under overload, goodput at
    saturation);
  * :mod:`repro.serving.fig12` — the campaign-engine point function
    behind ``benchmarks/fig12_serving_slo.py``.

See ``docs/serving.md`` for the layer contract and fig12 reading.
"""
from repro.serving.clock import VirtualClock, wall_clock
from repro.serving.traffic import (PROCESS_KINDS, ArrivalSpec, Diurnal,
                                   HeavyTail, Poisson, Trace,
                                   arrival_times, build_workload,
                                   crn_u01, load_trace, make_process,
                                   save_trace)
from repro.serving.slo import nearest_rank, slo_summary
from repro.serving.frontend import (FrontDoor, VirtualModel,
                                    make_request, run_virtual_serving)

__all__ = [
    "VirtualClock", "wall_clock",
    "PROCESS_KINDS", "ArrivalSpec", "Poisson", "Diurnal", "HeavyTail",
    "Trace",
    "arrival_times", "build_workload", "crn_u01", "make_process",
    "save_trace", "load_trace",
    "nearest_rank", "slo_summary",
    "FrontDoor", "VirtualModel", "make_request", "run_virtual_serving",
]
