"""Per-request SLO metrics for the serving front-end (fig12's y-axes).

Definitions (``docs/serving.md`` has the full contract):

  * **latency** — ``finished_at - submitted_at``: arrival at the front
    door (not admission into the server) to last token, so queueing
    under overload is *in* the number;
  * **TTFT** — ``first_token_at - submitted_at``: time to first token,
    the paper's inversion-resolution headline restated per request;
  * **deadline-miss rate** — fraction of finished requests of a class
    whose latency exceeds that class's deadline (requests never
    finished within the horizon count as misses too);
  * **goodput** — finished-within-deadline requests per second of
    makespan (the saturation metric: offered load beyond capacity
    stops converting into goodput).

Tail percentiles use the deterministic nearest-rank definition
(:func:`nearest_rank`) — no interpolation, so a summary is a pure,
byte-stable function of the request set, which is what lets CI gate
serving runs byte-identically under the virtual clock.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Iterable, List, Optional, Sequence

from repro.core.task import Crit

#: The quantiles every class reports, as (field tag, q) pairs.
QUANTILES = (("p50", 0.50), ("p99", 0.99), ("p999", 0.999))


def nearest_rank(values: Sequence[float], q: float) -> Optional[float]:
    """Nearest-rank quantile: the ceil(q*n)-th smallest value.

    Deterministic and exact (returns one of the inputs, never an
    interpolation); ``None`` on an empty sample — the JSON-safe
    spelling the campaign cache round-trips."""
    if not 0.0 < q <= 1.0:
        raise ValueError(f"quantile q={q} must be in (0, 1]")
    xs = sorted(values)
    if not xs:
        return None
    return float(xs[max(0, math.ceil(q * len(xs)) - 1)])


def _class_block(tag: str, reqs: List[Any],
                 deadline_s: Optional[float]) -> Dict[str, Any]:
    """SLO block for one criticality class (``tag`` in {'hi', 'lo'})."""
    fin = [r for r in reqs if r.done and r.finished_at is not None]
    lat = sorted(r.finished_at - r.submitted_at for r in fin)
    ttft = sorted(r.first_token_at - r.submitted_at for r in fin
                  if r.first_token_at is not None)
    out: Dict[str, Any] = {
        f"{tag}_n": len(reqs),
        f"{tag}_finished": len(fin),
        f"{tag}_mean_latency_s":
            (sum(lat) / len(lat)) if lat else None,
    }
    for name, q in QUANTILES:
        out[f"{tag}_{name}_latency_s"] = nearest_rank(lat, q)
    for name, q in QUANTILES[:2]:                 # TTFT tail: p50/p99
        out[f"{tag}_{name}_ttft_s"] = nearest_rank(ttft, q)
    if deadline_s is not None:
        # unfinished requests are misses by definition (overload never
        # launders a dropped-on-the-floor request out of the rate)
        missed = sum(1 for v in lat if v > deadline_s) \
            + (len(reqs) - len(fin))
        out[f"{tag}_deadline_s"] = float(deadline_s)
        out[f"{tag}_miss_rate"] = missed / len(reqs) if reqs else None
        out[f"{tag}_in_deadline"] = len(reqs) - missed
    else:
        out[f"{tag}_deadline_s"] = None
        out[f"{tag}_miss_rate"] = None
        out[f"{tag}_in_deadline"] = len(fin)
    out[f"{tag}_preemptions"] = sum(r.preemptions for r in reqs)
    out[f"{tag}_saves"] = sum(r.saves for r in reqs)
    return out


def slo_summary(requests: Iterable[Any], *,
                hi_deadline_s: Optional[float] = None,
                lo_deadline_s: Optional[float] = None) -> Dict[str, Any]:
    """Flatten a finished (or partially finished) request set into one
    tidy SLO row: per-class latency/TTFT tails, deadline-miss rates,
    and goodput over the serving makespan.

    ``requests`` is any iterable of ``core.serving.Request`` (the
    values of ``MESCServer.requests`` / ``MultiLaneServer.requests``).
    """
    reqs = list(requests)
    row: Dict[str, Any] = {}
    by_crit = {"hi": [r for r in reqs if r.crit == Crit.HI],
               "lo": [r for r in reqs if r.crit == Crit.LO]}
    row.update(_class_block("hi", by_crit["hi"], hi_deadline_s))
    row.update(_class_block("lo", by_crit["lo"], lo_deadline_s))

    fin = [r for r in reqs if r.done and r.finished_at is not None]
    sub = [r.submitted_at for r in reqs if r.submitted_at is not None]
    makespan = (max(r.finished_at for r in fin) - min(sub)) \
        if fin and sub else 0.0
    row["makespan_s"] = float(makespan)
    row["tokens_generated"] = sum(len(r.generated) for r in fin)
    in_deadline = row["hi_in_deadline"] + row["lo_in_deadline"]
    row["goodput_rps"] = in_deadline / makespan if makespan > 0 else None
    row["hi_goodput_rps"] = (row["hi_in_deadline"] / makespan
                             if makespan > 0 else None)
    row["throughput_rps"] = len(fin) / makespan if makespan > 0 else None
    return row
