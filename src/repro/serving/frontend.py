"""Admission-control front door + open-loop driver for MESC serving.

This is the layer between an arrival realization (``traffic``) and the
serving stack (``core.serving``):

  * :class:`FrontDoor` — the admission queue.  HI requests always
    drain before LO requests (a HI request is never behind a LO
    request in the admission order — property-tested in
    tests/test_admission.py), and an optional ``max_live_lo`` cap
    bounds concurrent LO admissions so overload queues at the door
    instead of thrashing the KV arena.  Conservation invariant:
    ``finished + live + queued == submitted`` at every instant.
  * :class:`VirtualModel` — the deterministic stand-in for the jitted
    (decode, prefill) dispatch pair: instead of running a model it
    advances its lane's ``VirtualClock`` by a CRN-drawn service time
    keyed ``(seed, stream, rid, step)``, so two policies serve the
    same workload with the *same* per-token service realization
    (common random numbers end-to-end).
  * :func:`run_virtual_serving` — the open-loop driver: admits
    arrivals against the global virtual-time frontier (the minimum
    over busy lanes' clocks, idle lanes ridden forward so admission
    stays causal), steps the earliest busy lane, and returns the
    finished request set for ``slo.slo_summary``.

Open-loop means arrivals never wait for the system: under overload the
front-door queue grows, latency includes the queueing, and the SLO
metrics show it — which is the point of fig12.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.core.scheduler import Policy
from repro.core.serving import MultiLaneServer, Request
from repro.core.task import Crit
from repro.scenarios import get_scenario, lane_lost, next_loss_boundary
from repro.serving.clock import VirtualClock
from repro.serving.traffic import ArrivalSpec, crn_u01

#: Per-request decode-step key stride: step k of request rid draws at
#: counter index rid * _RID_STRIDE + k (bounds max_new_tokens).
_RID_STRIDE = 1 << 20


def make_request(spec: ArrivalSpec, *, vocab: int = 256) -> Request:
    """Instantiate one :class:`~repro.core.serving.Request` from a
    traffic spec.  The one-token prompt carries the rid so the
    :class:`VirtualModel` can key its CRN service draws per request;
    ``submitted_at`` is pre-stamped with the true arrival time (the
    server's ``submit`` respects it), so queueing at the front door is
    part of measured latency."""
    del vocab                               # shape knob reserved for real
    return Request(rid=spec.rid,            # prompts; rid prompt is exact
                   prompt=np.asarray([spec.rid], np.int32),
                   max_new_tokens=spec.max_new_tokens,
                   priority=spec.priority, crit=spec.crit,
                   lo_budget_s=spec.lo_budget_s,
                   submitted_at=spec.t)


class VirtualModel:
    """Deterministic (decode, prefill) pair for one dispatch lane.

    Each call advances the lane's :class:`VirtualClock` by a service
    time drawn from the counter-based CRN — decode step ``k`` of
    request ``rid`` costs ``decode_mean_s * (1 +- jitter)`` with the
    uniform jitter keyed ``(seed, 'svc_decode', rid * stride + k)``,
    prefill ``prefill_mean_s`` likewise.  The "KV cache" is a plain
    dict carrying (rid, pos, k); generated tokens are CRN draws too,
    so the full request transcript is byte-reproducible."""

    def __init__(self, clock: VirtualClock, *, seed: int,
                 decode_mean_s: float = 0.010,
                 prefill_mean_s: float = 0.020,
                 jitter: float = 0.25, vocab: int = 256):
        if not 0.0 <= jitter < 1.0:
            raise ValueError(f"jitter must be in [0, 1), got {jitter}")
        if min(decode_mean_s, prefill_mean_s) <= 0:
            raise ValueError("service means must be > 0")
        self.clock = clock
        self.seed = seed
        self.decode_mean_s = decode_mean_s
        self.prefill_mean_s = prefill_mean_s
        self.jitter = jitter
        self.vocab = vocab

    def _service(self, stream: str, idx: int, mean: float) -> float:
        u = float(crn_u01(self.seed, stream, idx))
        return mean * (1.0 + self.jitter * (2.0 * u - 1.0))

    def prefill(self, params, batch):
        del params
        tokens = np.asarray(batch["tokens"])
        rid = int(tokens[0, 0])
        self.clock.advance(self._service("svc_prefill", rid,
                                         self.prefill_mean_s))
        return None, {"rid": rid, "pos": int(tokens.shape[1]), "k": 0}

    def decode(self, params, tok, cache):
        del params, tok                     # service keyed by (rid, k)
        rid, k = int(cache["rid"]), int(cache["k"])
        idx = rid * _RID_STRIDE + k
        self.clock.advance(self._service("svc_decode", idx,
                                         self.decode_mean_s))
        tok_out = int(crn_u01(self.seed, "tok", idx) * self.vocab)
        logits = np.zeros((1, self.vocab), np.float32)
        logits[0, tok_out] = 1.0
        return logits, {"rid": rid, "pos": int(cache["pos"]) + 1,
                        "k": k + 1}

    @property
    def jit_fns(self):
        """(decode, prefill) in ``MESCServer``'s expected order."""
        return (self.decode, self.prefill)


# ----------------------------------------------------------------------
class FrontDoor:
    """Admission control between the arrival stream and the server.

    ``arrive`` enqueues (HI and LO queues, each FIFO); ``pump`` admits
    while capacity allows — HI first, always, then LO up to
    ``max_live_lo`` concurrently live LO requests (``None`` = open
    throttle).  HI requests are never capped: protecting the
    HI-criticality SLO is the door's whole job."""

    def __init__(self, server, *, max_live_lo: Optional[int] = None,
                 make_request_fn: Callable[[ArrivalSpec], Request]
                 = make_request):
        if max_live_lo is not None and max_live_lo < 1:
            raise ValueError(f"max_live_lo must be >= 1 or None, "
                             f"got {max_live_lo}")
        self.server = server
        self.max_live_lo = max_live_lo
        self._make = make_request_fn
        self.hi_q: Deque[ArrivalSpec] = deque()
        self.lo_q: Deque[ArrivalSpec] = deque()
        self.submitted = 0                 # arrived at the door, ever

    # -- conservation accounting (finished + live + queued == submitted)
    @property
    def queued(self) -> int:
        return len(self.hi_q) + len(self.lo_q)

    def live(self) -> int:
        return sum(1 for r in self.server.requests.values() if not r.done)

    def finished(self) -> int:
        return sum(1 for r in self.server.requests.values() if r.done)

    def check_conservation(self) -> None:
        total = self.finished() + self.live() + self.queued
        if total != self.submitted:
            raise AssertionError(
                f"request conservation violated: finished "
                f"{self.finished()} + live {self.live()} + queued "
                f"{self.queued} != submitted {self.submitted}")

    def _live_lo(self) -> int:
        return sum(1 for r in self.server.requests.values()
                   if not r.done and r.crit == Crit.LO)

    def arrive(self, spec: ArrivalSpec) -> None:
        self.submitted += 1
        (self.hi_q if spec.crit == Crit.HI else self.lo_q).append(spec)

    def pump(self) -> List[int]:
        """Admit everything currently admissible; returns the admitted
        rids (HI strictly before LO — the admission-order invariant)."""
        admitted: List[int] = []
        while self.hi_q:                   # HI is never throttled
            spec = self.hi_q.popleft()
            self.server.submit(self._make(spec))
            admitted.append(spec.rid)
        while self.lo_q:
            if (self.max_live_lo is not None
                    and self._live_lo() >= self.max_live_lo):
                break
            spec = self.lo_q.popleft()
            self.server.submit(self._make(spec))
            admitted.append(spec.rid)
        return admitted


# ----------------------------------------------------------------------
# The open-loop virtual-time driver
# ----------------------------------------------------------------------

def _lane_live(lane) -> bool:
    return any(not r.done for r in lane.requests.values())


def drive_open_loop(server: MultiLaneServer,
                    clocks: Sequence[VirtualClock],
                    workload: Sequence[ArrivalSpec],
                    front: FrontDoor, *,
                    max_steps: int = 5_000_000,
                    scenario=None, seed: int = 0,
                    on_step: Optional[Callable[[FrontDoor, Any], None]]
                    = None) -> Dict[int, Request]:
    """Serve an open-loop workload to completion on the virtual clock.

    The loop's one rule keeps multi-lane virtual time causal: arrivals
    are admitted only up to the *frontier* — the clock of the earliest
    busy lane — and idle lanes are ridden forward to the frontier
    before admission, so no lane can ever serve a request dated after
    its own local time.  The earliest busy lane then takes one
    instruction (= decode step); on an empty system all clocks jump to
    the next arrival.  ``on_step`` (tests) observes the front door
    after every iteration.

    A ``scenario`` with the instance-loss component shrinks the live
    lane set: a lane inside a keyed outage window (``lane_lost``, drawn
    per (seed, lane, window) — the realization is policy-independent)
    neither starts new work (``server.blocked_lanes`` steers the
    partitioner away) nor steps, so its in-flight requests stall and
    its clock rides forward with the pool.  When *no* lane is
    steppable, all clocks jump to the next instant anything can change
    — the next arrival or the next outage-window boundary — and
    admission is held while every lane is lost (requests conserve at
    the front door).  With ``scenario=None`` (or a scenario without the
    loss component) the loop is byte-identical to the scenario-free
    driver.
    """
    scen = get_scenario(scenario)
    if scen is not None and not scen.has_loss:
        scen = None        # only instance loss acts at the serving layer
    pending = deque(sorted(workload, key=lambda s: (s.t, s.rid)))
    lanes = server.lanes
    for _ in range(max_steps):
        busy = [i for i, ln in enumerate(lanes) if _lane_live(ln)]
        if not busy and not pending and not front.queued:
            break
        if scen is not None:
            lost = {j for j in range(len(lanes))
                    if lane_lost(scen, seed, j, clocks[j]())}
            server.blocked_lanes = lost
            steppable = [j for j in busy if j not in lost]
        else:
            lost = set()
            steppable = busy
        if steppable:
            i = min(steppable, key=lambda j: (clocks[j](), j))
            now = clocks[i]()
            for j, ln in enumerate(lanes):      # idle and lost lanes
                if j not in steppable:          # ride along
                    clocks[j].advance_to(now)
            while pending and pending[0].t <= now:
                front.arrive(pending.popleft())
            front.pump()
            lanes[i].step()
            front.pump()                        # a finish frees capacity
        else:
            # nothing steppable: jump to the next instant anything can
            # change — the next arrival, or (with work stalled behind
            # an outage) the next loss-window boundary
            t = pending[0].t if pending else np.inf
            if scen is not None and (busy or front.queued):
                t = min(t, next_loss_boundary(
                    scen, min(c() for c in clocks)))
            for c in clocks:
                c.advance_to(t)
            while pending and pending[0].t <= t:
                front.arrive(pending.popleft())
            if scen is not None:
                lost = {j for j in range(len(lanes))
                        if lane_lost(scen, seed, j, clocks[j]())}
                server.blocked_lanes = lost
            if len(lost) < len(lanes):          # hold admission while
                front.pump()                    # every lane is lost
        if on_step is not None:
            on_step(front, server)
    else:
        raise RuntimeError(
            f"open-loop drive exceeded max_steps={max_steps} with "
            f"{front.queued} queued / {front.live()} live requests — "
            "raise max_steps or shrink the workload")
    front.check_conservation()
    return server.requests


def run_virtual_serving(workload: Sequence[ArrivalSpec], *,
                        lanes: int = 1, policy: Optional[Policy] = None,
                        seed: int = 0,
                        decode_mean_s: float = 0.010,
                        prefill_mean_s: float = 0.020,
                        jitter: float = 0.25,
                        cs_save_s: float = 0.004,
                        cs_restore_s: float = 0.004,
                        heuristic: str = "crit_aware",
                        slots_per_lane: int = 2,
                        max_live_lo: Optional[int] = None,
                        max_steps: int = 5_000_000,
                        scenario=None,
                        on_step: Optional[Callable] = None,
                        ) -> Dict[int, Request]:
    """One fully deterministic serving run: workload in, finished
    :class:`Request` set out (feed it to ``slo.slo_summary``).

    Builds one :class:`VirtualClock` + :class:`VirtualModel` per lane,
    a shared-arena :class:`~repro.core.serving.MultiLaneServer`, and an
    admission :class:`FrontDoor`, then drives the open loop.  Every
    random quantity is CRN-keyed off ``seed``: same (workload, seed,
    policy knobs) -> byte-identical request timelines.
    """
    vclocks = [VirtualClock() for _ in range(lanes)]
    models = [VirtualModel(c, seed=seed, decode_mean_s=decode_mean_s,
                           prefill_mean_s=prefill_mean_s, jitter=jitter)
              for c in vclocks]
    max_tokens = max((s.max_new_tokens for s in workload), default=1)
    server = MultiLaneServer(
        None, None, n_lanes=lanes, policy=policy,
        max_len=max_tokens + 8,
        total_slots=slots_per_lane * lanes, heuristic=heuristic,
        jit_fns=[m.jit_fns for m in models], clocks=vclocks,
        cs_costs=(cs_save_s, cs_restore_s))
    front = FrontDoor(server, max_live_lo=max_live_lo)
    return drive_open_loop(server, vclocks, workload, front,
                           max_steps=max_steps, scenario=scenario,
                           seed=seed, on_step=on_step)


@dataclasses.dataclass(frozen=True)
class ServiceModelSpec:
    """The virtual service-time knobs as one JSON-able bundle (the
    fig12 sweep passes these through the campaign cache key)."""
    decode_mean_s: float = 0.010
    prefill_mean_s: float = 0.020
    jitter: float = 0.25
    cs_save_s: float = 0.004
    cs_restore_s: float = 0.004

    def lane_capacity_rps(self, mean_tokens: float) -> float:
        """Requests/s one lane sustains at ``mean_tokens`` per request
        (the saturation anchor fig12's offered-load axis scales on)."""
        return 1.0 / (self.prefill_mean_s
                      + mean_tokens * self.decode_mean_s)
