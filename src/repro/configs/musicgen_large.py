"""MusicGen-large (decoder-only over EnCodec tokens; 4 codebooks).

[arXiv:2306.05284; hf] — 48L, d_model=2048, 32 heads (kv=32), d_ff=8192,
vocab=2048 per codebook; delay-pattern / text conditioning are frontend stubs.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    norm="layernorm",
    n_codebooks=4,
    source="arXiv:2306.05284; hf",
)
