"""RecurrentGemma-2B (hybrid: RG-LRU + local attention, 2:1).

[arXiv:2402.19427; hf] — 26L, d_model=2560, 10 heads (MQA kv=1), d_ff=7680,
vocab=256000, lru_width=2560, window=2048, pattern (rglru, rglru, attn).
"""
from repro.configs.base import ArchConfig, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    rglru=RGLRUConfig(d_rnn=2560, conv_width=4, window=2048,
                      block_pattern=("rglru", "rglru", "attn")),
    source="arXiv:2402.19427; hf",
)
