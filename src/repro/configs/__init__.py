"""Config registry: one module per assigned architecture (plus smoke variants)."""
from __future__ import annotations

from repro.configs.base import (ArchConfig, MLAConfig, MoEConfig, RGLRUConfig,
                                ShapeConfig, XLSTMConfig, SHAPES,
                                SHAPES_BY_NAME, TRAIN_4K, PREFILL_32K,
                                DECODE_32K, LONG_500K, supports_shape)

from repro.configs.llama4_maverick_400b_a17b import CONFIG as LLAMA4_MAVERICK
from repro.configs.deepseek_v2_lite_16b import CONFIG as DEEPSEEK_V2_LITE
from repro.configs.olmo_1b import CONFIG as OLMO_1B
from repro.configs.phi4_mini_3_8b import CONFIG as PHI4_MINI
from repro.configs.tinyllama_1_1b import CONFIG as TINYLLAMA
from repro.configs.qwen1_5_110b import CONFIG as QWEN15_110B
from repro.configs.recurrentgemma_2b import CONFIG as RECURRENTGEMMA_2B
from repro.configs.llava_next_34b import CONFIG as LLAVA_NEXT_34B
from repro.configs.xlstm_125m import CONFIG as XLSTM_125M
from repro.configs.musicgen_large import CONFIG as MUSICGEN_LARGE

ARCHS = {
    c.name: c for c in (
        LLAMA4_MAVERICK, DEEPSEEK_V2_LITE, OLMO_1B, PHI4_MINI, TINYLLAMA,
        QWEN15_110B, RECURRENTGEMMA_2B, LLAVA_NEXT_34B, XLSTM_125M,
        MUSICGEN_LARGE,
    )
}


def get_config(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return ARCHS[name[:-len("-smoke")]].reduced()
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)


__all__ = [
    "ArchConfig", "MLAConfig", "MoEConfig", "RGLRUConfig", "XLSTMConfig",
    "ShapeConfig", "SHAPES", "SHAPES_BY_NAME", "TRAIN_4K", "PREFILL_32K",
    "DECODE_32K", "LONG_500K", "supports_shape", "ARCHS", "get_config",
    "list_archs",
]
