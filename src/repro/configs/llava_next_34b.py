"""LLaVA-NeXT 34B (VLM backbone; anyres tiling frontend is a stub).

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] — 60L, d_model=7168,
56 heads (kv=8), d_ff=20480, vocab=64000.  `input_specs` provides precomputed
patch embeddings (B, n_frontend_tokens, d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    rope_theta=5000000.0,
    n_frontend_tokens=576,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
