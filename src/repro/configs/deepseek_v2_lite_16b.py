"""DeepSeek-V2-Lite 16B (MLA + MoE).

[arXiv:2405.04434; hf] — 27L, d_model=2048, 16 heads, MLA kv_lora=512,
2 shared + 64 routed experts top-6, expert FFN 1408, vocab 102400.
(The pool line's "160 routed" is full-V2; Lite is 64 routed.)
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="mla_moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    head_dim=128,
    rope_theta=10000.0,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(num_experts=64, num_shared=2, top_k=6, d_expert=1408),
    source="arXiv:2405.04434; hf",
)
