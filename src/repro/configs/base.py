"""Architecture / shape configuration system.

Every assigned architecture is an :class:`ArchConfig`; every workload shape is
a :class:`ShapeConfig`.  ``(arch, shape)`` pairs form the dry-run / roofline
cells.  Reduced (smoke) configs are derived mechanically so every family has a
CPU-runnable variant.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0           # routed experts
    num_shared: int = 0            # shared (always-on) experts
    top_k: int = 1
    d_expert: int = 0              # per-expert FFN hidden size
    moe_every: int = 1             # MoE FFN every k-th layer (others dense)
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_coef: float = 1e-2


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma recurrent block (RG-LRU + conv1d) settings."""
    d_rnn: int = 0                 # recurrence width (lru_width)
    conv_width: int = 4
    window: int = 2048             # local-attention window for hybrid layers
    block_pattern: Tuple[str, ...] = ("rglru", "rglru", "attn")


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 4           # one sLSTM block per this many layers
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    chunk: int = 256               # chunkwise-parallel mLSTM chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | mla_moe | hybrid | xlstm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default: d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"          # rmsnorm | layernorm | layernorm_nonparam
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rglru: Optional[RGLRUConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # modality frontends (stubs — precomputed embeddings via input_specs)
    n_frontend_tokens: int = 0     # vlm: image patch embeds prepended
    n_codebooks: int = 1           # audio: EnCodec codebooks (summed embeds)
    source: str = ""

    @property
    def dh(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports O(1)-state decode (may run long_500k)."""
        return self.family in ("hybrid", "xlstm")

    def param_count(self) -> int:
        """Approximate total parameter count (embeddings + blocks)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "xlstm":
            per = 6 * d * d  # rough: qkv/proj + gates
            return emb + L * per
        dh, hq, hkv = self.dh, self.n_heads, self.n_kv_heads
        attn = d * hq * dh + 2 * d * hkv * dh + hq * dh * d
        if self.mla is not None:
            m = self.mla
            attn = (d * (m.kv_lora_rank + m.qk_rope_dim)
                    + m.kv_lora_rank * hq * (m.qk_nope_dim + m.v_head_dim)
                    + d * hq * (m.qk_nope_dim + m.qk_rope_dim)
                    + hq * m.v_head_dim * d)
        if self.moe is not None:
            e = self.moe
            moe_frac = 1.0 / e.moe_every
            moe_ffn = (e.num_experts + e.num_shared) * 3 * d * e.d_expert + d * e.num_experts
            ffn = moe_frac * moe_ffn + (1 - moe_frac) * 3 * d * self.d_ff
        else:
            ffn = 3 * d * self.d_ff
        if self.rglru is not None:
            pat = self.rglru.block_pattern
            fr_attn = sum(1 for p in _pattern_for(self) if p == "attn") / L
            rec = 3 * d * self.rglru.d_rnn + 2 * self.rglru.d_rnn
            per = fr_attn * attn + (1 - fr_attn) * rec + 3 * d * self.d_ff
            return int(emb + L * per)
        return int(emb + L * (attn + ffn))

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        e = self.moe
        n_moe_layers = L // e.moe_every
        full = self.param_count()
        all_experts = n_moe_layers * (e.num_experts + e.num_shared) * 3 * d * e.d_expert
        active = n_moe_layers * (e.top_k + e.num_shared) * 3 * d * e.d_expert
        return int(full - all_experts + active)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke", family=self.family,
            n_layers=min(self.n_layers, 2), d_model=64,
            n_heads=4, n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=128 if self.d_ff else 0, vocab=256,
            head_dim=16, qkv_bias=self.qkv_bias, norm=self.norm,
            rope_theta=self.rope_theta, tie_embeddings=True,
            n_frontend_tokens=min(self.n_frontend_tokens, 8),
            n_codebooks=self.n_codebooks, source="smoke",
        )
        if self.moe is not None:
            # capacity_factor=8 -> drop-free routing, so prefill+decode is
            # bit-consistent with the full forward in tests
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, num_shared=min(self.moe.num_shared, 1),
                top_k=min(self.moe.top_k, 2), d_expert=32,
                capacity_factor=8.0)
        if self.mla is not None:
            kw["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                                  v_head_dim=16)
        if self.rglru is not None:
            kw["rglru"] = dataclasses.replace(self.rglru, d_rnn=64, window=32)
            kw["n_layers"] = 3  # one full (rglru, rglru, attn) pattern
        if self.xlstm is not None:
            kw["xlstm"] = dataclasses.replace(self.xlstm, slstm_every=2, chunk=16)
        return ArchConfig(**kw)


def _pattern_for(cfg: ArchConfig):
    """Per-layer block types for hybrid archs."""
    if cfg.rglru is None:
        return ["attn"] * cfg.n_layers
    pat = cfg.rglru.block_pattern
    return [pat[i % len(pat)] for i in range(cfg.n_layers)]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def supports_shape(arch: ArchConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k":
        return arch.sub_quadratic
    return True
