"""Llama-4 Maverick 400B-A17B (MoE, early fusion).

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] — 48L, d_model=5120, 40 heads
(GQA kv=8), expert FFN 8192, vocab 202048, 128 routed experts top-1 + 1 shared, MoE every other layer (interleaved,
as in the released Maverick checkpoints — yields ~400B total / ~17B active).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    rope_theta=500000.0,
    moe=MoEConfig(num_experts=128, num_shared=1, top_k=1, d_expert=8192,
                  moe_every=2),
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
