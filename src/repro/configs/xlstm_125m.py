"""xLSTM-125M (sLSTM + mLSTM blocks).

[arXiv:2405.04517; unverified] — 12L, d_model=768, 4 heads, d_ff=0 (blocks
carry their own projections), vocab=50304; 1 sLSTM per 4 layers.
"""
from repro.configs.base import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="xlstm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=192,
    tie_embeddings=True,
    xlstm=XLSTMConfig(slstm_every=4, mlstm_proj_factor=2.0,
                      slstm_proj_factor=4.0 / 3.0, chunk=256),
    source="arXiv:2405.04517; unverified",
)
