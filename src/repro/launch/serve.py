"""Mixed-criticality serving driver (the paper's system end-to-end).

Serves a small model with batched requests of mixed priority/criticality
under the MESC scheduler (instruction-level = decode-step preemption,
bank-pool cache residency, LO-budget mode switching), and compares
against a non-preemptive (FIFO/run-to-completion) baseline.  With
``--lanes N`` the requests are partitioned across N virtual accelerator
dispatch lanes sharing one KV-slot arena (``core.serving.MultiLaneServer``,
see docs/scheduling.md).

``--arrivals`` switches from the legacy batch drive to the open-loop
traffic layer (``repro.serving``): requests arrive per a CRN arrival
process (poisson / heavy_tail / diurnal / a replayed ``--trace`` file)
through the admission front door, and the run is summarized as SLO
metrics (``docs/serving.md``).  Add ``--virtual`` to run the whole
thing on the deterministic virtual clock + service model (no model
weights, byte-reproducible — the fig12 path); without it the real
model serves the trace in wall-clock time.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b-smoke
  PYTHONPATH=src python -m repro.launch.serve --lanes 2 --heuristic crit_aware
  PYTHONPATH=src python -m repro.launch.serve --arrivals poisson --virtual
  PYTHONPATH=src python -m repro.launch.serve --arrivals trace --trace t.json
"""
from __future__ import annotations

import argparse
import time
from collections import deque

import numpy as np

from repro.core.scheduler import Policy
from repro.core.serving import MESCServer, MultiLaneServer, Request
from repro.core.task import Crit
from repro.serving import (FrontDoor, PROCESS_KINDS, build_workload,
                           make_process, run_virtual_serving, slo_summary)


def _load_model(arch: str):
    """Real-model setup, imported lazily so ``--virtual`` runs stay
    free of jax/model-weight start-up cost."""
    import jax
    from repro.configs import get_config
    from repro.models import lm
    from repro.models.common import CPU_RC
    cfg = get_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0), CPU_RC)
    return cfg, params


def make_requests(cfg, rng, n_lo: int = 4, n_hi: int = 2,
                  lo_len: int = 24, hi_len: int = 6):
    reqs = []
    rid = 0
    for _ in range(n_lo):
        reqs.append(Request(rid=rid, priority=10 + rid,
                            prompt=rng.integers(0, cfg.vocab, 8,
                                                dtype=np.int32),
                            max_new_tokens=lo_len, crit=Crit.LO))
        rid += 1
    for _ in range(n_hi):
        reqs.append(Request(rid=rid, priority=rid - n_lo,
                            prompt=rng.integers(0, cfg.vocab, 8,
                                                dtype=np.int32),
                            max_new_tokens=hi_len, crit=Crit.HI))
        rid += 1
    return reqs


def run(cfg, params, policy, reqs, hi_delay_steps: int = 3,
        lanes: int = 1, heuristic: str = "crit_aware"):
    """LO requests submitted first; HI requests arrive mid-flight."""
    if lanes > 1:
        srv = MultiLaneServer(cfg, params, policy=policy, max_len=64,
                              n_lanes=lanes, heuristic=heuristic)
    else:
        srv = MESCServer(cfg, params, policy=policy, max_len=64)
    # warmup: compile prefill+decode outside the measured window
    warm = Request(rid=-1, priority=99,
                   prompt=np.zeros(8, np.int32), max_new_tokens=2,
                   crit=Crit.LO)
    srv.submit(warm)
    srv.run()
    for ln in getattr(srv, "lanes", [srv]):
        ln.requests.clear()
    lo = [r for r in reqs if r.crit == Crit.LO]
    hi = [r for r in reqs if r.crit == Crit.HI]
    for r in lo:
        srv.submit(r)
    for _ in range(hi_delay_steps):
        srv.step()
    for r in hi:
        srv.submit(r)
    srv.run()
    return srv.requests


def summarize(name, reqs):
    out = {}
    for crit in (Crit.HI, Crit.LO):
        rs = [r for r in reqs.values() if r.crit == crit and r.finished_at]
        if not rs:
            continue
        ttft = [r.first_token_at - r.submitted_at for r in rs]
        lat = [r.finished_at - r.submitted_at for r in rs]
        out[crit.value] = (np.mean(ttft), np.mean(lat))
        print(f"  {name:12s} {crit.value}: ttft={np.mean(ttft)*1e3:7.1f} ms "
              f"latency={np.mean(lat)*1e3:7.1f} ms  n={len(rs)} "
              f"saves={sum(r.saves for r in rs)}")
    return out


def run_traffic_real(cfg, params, policy, workload, *, lanes: int = 1,
                     heuristic: str = "crit_aware",
                     max_live_lo=None, prompt_len: int = 8):
    """Open-loop wall-clock drive: the real model serves a CRN arrival
    realization in real time through the admission front door."""
    if lanes > 1:
        srv = MultiLaneServer(cfg, params, policy=policy, max_len=64,
                              n_lanes=lanes, heuristic=heuristic)
    else:
        srv = MESCServer(cfg, params, policy=policy, max_len=64)
    warm = Request(rid=-1, priority=99, prompt=np.zeros(8, np.int32),
                   max_new_tokens=2, crit=Crit.LO)
    srv.submit(warm)
    srv.run()
    for ln in getattr(srv, "lanes", [srv]):
        ln.requests.clear()

    rng = np.random.default_rng(0)
    t0 = time.monotonic()

    def make_real(spec):
        # pre-stamp the true arrival instant so front-door queueing is
        # inside measured latency (same contract as the virtual path)
        return Request(rid=spec.rid, priority=spec.priority,
                       prompt=rng.integers(0, cfg.vocab, prompt_len,
                                           dtype=np.int32),
                       max_new_tokens=spec.max_new_tokens,
                       crit=spec.crit, lo_budget_s=spec.lo_budget_s,
                       submitted_at=t0 + spec.t)

    front = FrontDoor(srv, max_live_lo=max_live_lo,
                      make_request_fn=make_real)
    pending = deque(sorted(workload, key=lambda s: (s.t, s.rid)))
    while pending or front.queued or front.live():
        now = time.monotonic() - t0
        while pending and pending[0].t <= now:
            front.arrive(pending.popleft())
        front.pump()
        if front.live():
            srv.step()
        elif pending:                      # idle: sleep to next arrival
            time.sleep(max(0.0, min(pending[0].t - now, 0.05)))
    front.check_conservation()
    return srv.requests


def print_slo(name, row):
    def f(v, scale=1e3, unit="ms"):
        return "   n/a" if v is None else f"{v * scale:7.1f} {unit}"
    print(f"  {name:6s} HI: p50={f(row['hi_p50_latency_s'])} "
          f"p99={f(row['hi_p99_latency_s'])} "
          f"miss={row['hi_miss_rate'] if row['hi_miss_rate'] is not None else 'n/a'}  "
          f"LO: p50={f(row['lo_p50_latency_s'])}  "
          f"goodput={row['goodput_rps']:.2f} rps")


def main_traffic(args):
    """--arrivals != batch: the open-loop traffic front end."""
    lo_process = make_process(args.arrivals, args.rate,
                              trace_path=args.trace)
    hi_process = make_process("poisson", args.hi_rate)
    workload = build_workload(seed=args.seed, lo_process=lo_process,
                              hi_process=hi_process, n_lo=args.n_lo,
                              n_hi=args.n_hi, lo_tokens=args.lo_tokens,
                              hi_tokens=args.hi_tokens)
    mode = "virtual clock" if args.virtual else "wall clock"
    print(f"open-loop {args.arrivals} arrivals ({mode}, "
          f"lanes={args.lanes}, n_lo={args.n_lo}, n_hi={args.n_hi}, "
          f"lo_rate={args.rate}/s, hi_rate={args.hi_rate}/s)")
    if not args.virtual:
        cfg, params = _load_model(args.arch)
    rows = {}
    for name, policy in (("mesc", Policy.mesc()),
                         ("np", Policy.non_preemptive())):
        if args.virtual:
            reqs = run_virtual_serving(
                workload, lanes=args.lanes, policy=policy,
                seed=args.seed, heuristic=args.heuristic,
                max_live_lo=args.max_live_lo)
        else:
            reqs = run_traffic_real(
                cfg, params, policy, workload, lanes=args.lanes,
                heuristic=args.heuristic, max_live_lo=args.max_live_lo)
        rows[name] = slo_summary(reqs.values(),
                                 hi_deadline_s=args.hi_deadline)
        print_slo(name, rows[name])
    m, b = rows["mesc"], rows["np"]
    if m["hi_p99_latency_s"] and b["hi_p99_latency_s"]:
        print(f"HI p99 latency np/mesc: "
              f"{b['hi_p99_latency_s'] / m['hi_p99_latency_s']:.1f}x")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b-smoke")
    ap.add_argument("--lanes", type=int, default=1,
                    help="virtual accelerator dispatch lanes (partitioned "
                         "MESC when > 1)")
    ap.add_argument("--heuristic", default="crit_aware",
                    choices=("first_fit", "worst_fit", "crit_aware"),
                    help="request -> lane partition heuristic")
    ap.add_argument("--arrivals", default="batch",
                    choices=("batch",) + PROCESS_KINDS,
                    help="batch = legacy closed-batch drive; anything "
                         "else selects the open-loop traffic layer")
    ap.add_argument("--trace", default=None,
                    help="arrival-trace JSON for --arrivals trace "
                         "(see repro.serving.save_trace)")
    ap.add_argument("--rate", type=float, default=2.0,
                    help="LO arrival rate, requests/s")
    ap.add_argument("--hi-rate", type=float, default=0.5,
                    help="HI arrival rate, requests/s")
    ap.add_argument("--n-lo", type=int, default=16)
    ap.add_argument("--n-hi", type=int, default=6)
    ap.add_argument("--lo-tokens", type=int, default=24)
    ap.add_argument("--hi-tokens", type=int, default=6)
    ap.add_argument("--hi-deadline", type=float, default=0.5,
                    help="HI deadline for miss-rate accounting, seconds")
    ap.add_argument("--max-live-lo", type=int, default=None,
                    help="admission cap on concurrently-live LO "
                         "requests (None = open throttle)")
    ap.add_argument("--virtual", action="store_true",
                    help="serve on the deterministic virtual clock + "
                         "service model (no weights; byte-reproducible)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.arrivals == "trace" and not args.trace:
        ap.error("--arrivals trace requires --trace PATH")
    if args.arrivals != "batch":
        main_traffic(args)
        return

    cfg, params = _load_model(args.arch)
    rng = np.random.default_rng(0)
    lane_kw = dict(lanes=args.lanes, heuristic=args.heuristic)
    print(f"MESC (instruction-level preemption, lanes={args.lanes}):")
    mesc = summarize("mesc", run(cfg, params, Policy.mesc(),
                                 make_requests(cfg, rng), **lane_kw))
    print("non-preemptive baseline:")
    rng = np.random.default_rng(0)
    base = summarize("np", run(cfg, params, Policy.non_preemptive(),
                               make_requests(cfg, rng), **lane_kw))
    if "HI" in mesc and "HI" in base:
        sp = base["HI"][0] / max(mesc["HI"][0], 1e-9)
        print(f"HI time-to-first-token speedup: {sp:.1f}x")


if __name__ == "__main__":
    main()
