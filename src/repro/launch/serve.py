"""Mixed-criticality serving driver (the paper's system end-to-end).

Serves a small model with batched requests of mixed priority/criticality
under the MESC scheduler (instruction-level = decode-step preemption,
bank-pool cache residency, LO-budget mode switching), and compares
against a non-preemptive (FIFO/run-to-completion) baseline.  With
``--lanes N`` the requests are partitioned across N virtual accelerator
dispatch lanes sharing one KV-slot arena (``core.serving.MultiLaneServer``,
see docs/scheduling.md).

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b-smoke
  PYTHONPATH=src python -m repro.launch.serve --lanes 2 --heuristic crit_aware
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.scheduler import Policy
from repro.core.serving import MESCServer, MultiLaneServer, Request
from repro.core.task import Crit
from repro.models import lm
from repro.models.common import CPU_RC


def make_requests(cfg, rng, n_lo: int = 4, n_hi: int = 2,
                  lo_len: int = 24, hi_len: int = 6):
    reqs = []
    rid = 0
    for _ in range(n_lo):
        reqs.append(Request(rid=rid, priority=10 + rid,
                            prompt=rng.integers(0, cfg.vocab, 8,
                                                dtype=np.int32),
                            max_new_tokens=lo_len, crit=Crit.LO))
        rid += 1
    for _ in range(n_hi):
        reqs.append(Request(rid=rid, priority=rid - n_lo,
                            prompt=rng.integers(0, cfg.vocab, 8,
                                                dtype=np.int32),
                            max_new_tokens=hi_len, crit=Crit.HI))
        rid += 1
    return reqs


def run(cfg, params, policy, reqs, hi_delay_steps: int = 3,
        lanes: int = 1, heuristic: str = "crit_aware"):
    """LO requests submitted first; HI requests arrive mid-flight."""
    if lanes > 1:
        srv = MultiLaneServer(cfg, params, policy=policy, max_len=64,
                              n_lanes=lanes, heuristic=heuristic)
    else:
        srv = MESCServer(cfg, params, policy=policy, max_len=64)
    # warmup: compile prefill+decode outside the measured window
    warm = Request(rid=-1, priority=99,
                   prompt=np.zeros(8, np.int32), max_new_tokens=2,
                   crit=Crit.LO)
    srv.submit(warm)
    srv.run()
    for ln in getattr(srv, "lanes", [srv]):
        ln.requests.clear()
    lo = [r for r in reqs if r.crit == Crit.LO]
    hi = [r for r in reqs if r.crit == Crit.HI]
    for r in lo:
        srv.submit(r)
    for _ in range(hi_delay_steps):
        srv.step()
    for r in hi:
        srv.submit(r)
    srv.run()
    return srv.requests


def summarize(name, reqs):
    out = {}
    for crit in (Crit.HI, Crit.LO):
        rs = [r for r in reqs.values() if r.crit == crit and r.finished_at]
        if not rs:
            continue
        ttft = [r.first_token_at - r.submitted_at for r in rs]
        lat = [r.finished_at - r.submitted_at for r in rs]
        out[crit.value] = (np.mean(ttft), np.mean(lat))
        print(f"  {name:12s} {crit.value}: ttft={np.mean(ttft)*1e3:7.1f} ms "
              f"latency={np.mean(lat)*1e3:7.1f} ms  n={len(rs)} "
              f"saves={sum(r.saves for r in rs)}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b-smoke")
    ap.add_argument("--lanes", type=int, default=1,
                    help="virtual accelerator dispatch lanes (partitioned "
                         "MESC when > 1)")
    ap.add_argument("--heuristic", default="crit_aware",
                    choices=("first_fit", "worst_fit", "crit_aware"),
                    help="request -> lane partition heuristic")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0), CPU_RC)
    rng = np.random.default_rng(0)

    lane_kw = dict(lanes=args.lanes, heuristic=args.heuristic)
    print(f"MESC (instruction-level preemption, lanes={args.lanes}):")
    mesc = summarize("mesc", run(cfg, params, Policy.mesc(),
                                 make_requests(cfg, rng), **lane_kw))
    print("non-preemptive baseline:")
    rng = np.random.default_rng(0)
    base = summarize("np", run(cfg, params, Policy.non_preemptive(),
                               make_requests(cfg, rng), **lane_kw))
    if "HI" in mesc and "HI" in base:
        sp = base["HI"][0] / max(mesc["HI"][0], 1e-9)
        print(f"HI time-to-first-token speedup: {sp:.1f}x")


if __name__ == "__main__":
    main()
