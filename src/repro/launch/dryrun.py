"""Multi-pod dry-run: lower + compile every (architecture x shape x
mesh) cell, print memory/cost analysis, and dump roofline raw terms to
JSON.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \\
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Success criterion (deliverable e): ``.lower().compile()`` succeeds and
the per-device memory fits a v5e (16 GB) for every supported cell.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The XLA_FLAGS write above MUST run before any other import (jax locks
# the device count on first backend initialisation).

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES_BY_NAME, get_config, supports_shape
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as S
from repro.models.common import RuntimeConfig
from repro.optim import OptConfig, init_opt_state
from repro.runtime import sharding as shlib
from repro.runtime.hlo_analysis import analyze_hlo
from repro.runtime.trainer import (make_decode_step, make_prefill_step,
                                   make_train_step)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# Failures one analysis probe may survive (recorded per-cell, never
# fatal to the sweep): jax/XLA API drift or an unsupported query on
# this backend.  XlaRuntimeError subclasses RuntimeError.
PROBE_ERRORS = (AttributeError, KeyError, TypeError, ValueError,
                RuntimeError)
# Failures one *cell* may survive — lowering/compile blowups land in
# the cell's JSON record and the sweep moves on.  Genuine bugs
# (NameError, ImportError) and KeyboardInterrupt still propagate.
CELL_ERRORS = PROBE_ERRORS + (MemoryError, OSError)

# --------------------------------------------------------------------------
# Per-cell runtime policy (baseline; §Perf hillclimbs override these)
# --------------------------------------------------------------------------

BIG_TRAIN = {"qwen1.5-110b": 8, "llava-next-34b": 6, "llama4-maverick-400b-a17b": 6}
# grad-accumulation microbatches for train cells (activation-linear memory)
MICROBATCH = {"qwen1.5-110b": 4, "llava-next-34b": 4,
              "llama4-maverick-400b-a17b": 4, "phi4-mini-3.8b": 2,
              "recurrentgemma-2b": 2, "deepseek-v2-lite-16b": 2}


def cell_microbatches(arch_name: str, shape_kind: str) -> int:
    return MICROBATCH.get(arch_name, 1) if shape_kind == "train" else 1


INT8_MOMENTS = {"llama4-maverick-400b-a17b"}
BF16_ACCUM = {"llama4-maverick-400b-a17b"}


def cell_opt(arch_name: str) -> OptConfig:
    return OptConfig(moments_int8=arch_name in INT8_MOMENTS)


def cell_rc(arch_name: str, shape_kind: str) -> RuntimeConfig:
    if shape_kind == "train":
        return RuntimeConfig(
            compute_dtype=jnp.bfloat16,
            param_dtype=jnp.bfloat16 if arch_name == "llama4-maverick-400b-a17b"
            else jnp.float32,
            remat_policy="full",
            remat_groups=BIG_TRAIN.get(arch_name, 0),
            sequence_parallel=True,
            flash_block_q=512, flash_block_kv=1024)
    return RuntimeConfig(compute_dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
                         sequence_parallel=(shape_kind == "prefill"),
                         pad_attn_heads=16,   # TP-align odd head counts
                         flash_block_q=512, flash_block_kv=1024)


# --------------------------------------------------------------------------
# Cell lowering
# --------------------------------------------------------------------------

def lower_cell(arch_name: str, shape_name: str, mesh, rules,
               rc_override=None):
    cfg = get_config(arch_name)
    shape = SHAPES_BY_NAME[shape_name]
    rc = rc_override or cell_rc(arch_name, shape.kind)
    opt_cfg = cell_opt(arch_name)

    with shlib.axis_rules(rules):
        if shape.kind == "train":
            params_a = S.params_abstract(cfg, rc)
            opt_a = jax.eval_shape(lambda: init_opt_state(params_a, opt_cfg))
            batch_a = S.train_batch_specs(cfg, shape, rc)
            p_spec = shlib.param_specs(params_a, rules)
            o_spec = {}
            for key, sub in opt_a.items():
                if key in ("m", "v"):
                    o_spec[key] = shlib.param_specs(params_a, rules)
                else:  # scales / step: replicated scalars
                    o_spec[key] = shlib.replicated(sub, rules)
            b_spec = shlib.batch_specs(batch_a, rules)
            step = make_train_step(
                cfg, rc, opt_cfg,
                microbatches=cell_microbatches(arch_name, "train"),
                accum_dtype=jnp.bfloat16 if arch_name in BF16_ACCUM
                else jnp.float32)
            fn = jax.jit(step,
                         in_shardings=(p_spec, o_spec, b_spec),
                         out_shardings=(p_spec, o_spec, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params_a, opt_a, batch_a)
        elif shape.kind == "prefill":
            params_a = S.params_abstract(cfg, rc)
            batch_a = S.prefill_batch_specs(cfg, shape, rc)
            p_spec = shlib.param_specs(params_a, rules)
            b_spec = shlib.batch_specs(batch_a, rules)
            step = make_prefill_step(cfg, rc)
            cache_a = jax.eval_shape(lambda p, b: step(p, b)[1],
                                     params_a, batch_a)
            c_spec = shlib.cache_specs(cache_a, rules)
            fn = jax.jit(step, in_shardings=(p_spec, b_spec),
                         out_shardings=(None, c_spec))
            lowered = fn.lower(params_a, batch_a)
        else:  # decode
            params_a = S.params_abstract(cfg, rc)
            tok_a = S.decode_token_specs(cfg, shape)
            cache_a = S.cache_specs_abstract(cfg, shape, rc)
            p_spec = shlib.param_specs(params_a, rules)
            c_spec = shlib.cache_specs(cache_a, rules)
            t_spec = shlib.batch_specs(tok_a, rules)
            step = make_decode_step(cfg, rc)
            fn = jax.jit(step,
                         in_shardings=(p_spec, t_spec, c_spec),
                         out_shardings=(None, c_spec),
                         donate_argnums=(2,))
            lowered = fn.lower(params_a, tok_a, cache_a)
    return lowered


def analyze(lowered, mesh) -> dict:
    n_dev = mesh.devices.size
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    res = {"compile_seconds": round(compile_s, 1), "n_devices": int(n_dev)}

    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                res[k] = int(v)
        res["per_device_hbm_bytes"] = (
            res.get("argument_size_in_bytes", 0)
            + res.get("output_size_in_bytes", 0)
            + res.get("temp_size_in_bytes", 0)
            - res.get("alias_size_in_bytes", 0))
    except PROBE_ERRORS as e:  # pragma: no cover
        res["memory_analysis_error"] = str(e)
        print(f"dryrun: memory_analysis failed: {type(e).__name__}: {e}",
              file=sys.stderr)

    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        res["hlo_flops"] = float(ca.get("flops", 0.0))
        res["hlo_bytes"] = float(ca.get("bytes accessed", 0.0))
        res["hlo_transcendentals"] = float(ca.get("transcendentals", 0.0))
    except PROBE_ERRORS as e:  # pragma: no cover
        res["cost_analysis_error"] = str(e)
        print(f"dryrun: cost_analysis failed: {type(e).__name__}: {e}",
              file=sys.stderr)

    try:
        txt = compiled.as_text()
        h = analyze_hlo(txt, n_dev)
        res["hlo_text_flops_per_device"] = h["flops"]
        res["hlo_text_bytes_per_device"] = h["hbm_bytes"]
        res["hlo_text_bytes_no_copies"] = h["hbm_bytes_no_copies"]
        res["collectives"] = h["collectives"]
        res["collective_link_bytes"] = h["collective_link_bytes"]
    except PROBE_ERRORS as e:  # pragma: no cover
        res["collective_parse_error"] = str(e)
        print(f"dryrun: HLO text analysis failed: {type(e).__name__}: {e}",
              file=sys.stderr)
    return res


def cost_probe(arch_name: str, shape_name: str) -> dict:
    """Single-device, scan-unrolled lowering -> exact global HLO FLOPs.

    Uses lowered.cost_analysis() (no compile); flash attention runs
    single-block so no inner loops hide FLOPs.  Cross-check for the
    compiled-text analysis (roofline methodology: benchmarks/roofline.py).
    """
    import dataclasses
    cfg = get_config(arch_name)
    shape = SHAPES_BY_NAME[shape_name]
    base = cell_rc(arch_name, shape.kind)
    rc = dataclasses.replace(base, cost_probe=True,
                             flash_block_q=shape.seq_len,
                             flash_block_kv=shape.seq_len,
                             logical_axes=False)
    opt_cfg = cell_opt(arch_name)
    if shape.kind == "train":
        params_a = S.params_abstract(cfg, rc)
        opt_a = jax.eval_shape(lambda: init_opt_state(params_a, opt_cfg))
        batch_a = S.train_batch_specs(cfg, shape, rc)
        step = make_train_step(
            cfg, rc, opt_cfg,
            microbatches=cell_microbatches(arch_name, "train"),
            accum_dtype=jnp.bfloat16 if arch_name in BF16_ACCUM
            else jnp.float32)
        lowered = jax.jit(step).lower(params_a, opt_a, batch_a)
    elif shape.kind == "prefill":
        params_a = S.params_abstract(cfg, rc)
        batch_a = S.prefill_batch_specs(cfg, shape, rc)
        lowered = jax.jit(make_prefill_step(cfg, rc)).lower(params_a, batch_a)
    else:
        params_a = S.params_abstract(cfg, rc)
        tok_a = S.decode_token_specs(cfg, shape)
        cache_a = S.cache_specs_abstract(cfg, shape, rc)
        lowered = jax.jit(make_decode_step(cfg, rc)).lower(
            params_a, tok_a, cache_a)
    ca = lowered.cost_analysis()
    return {"probe_global_flops": float(ca.get("flops", 0.0)),
            "probe_global_bytes": float(ca.get("bytes accessed", 0.0))}


SMALL_2D = {"tinyllama-1.1b", "olmo-1b", "xlstm-125m", "musicgen-large",
            "phi4-mini-3.8b"}


def cell_mode(arch_name: str, shape_name: str) -> str:
    """2d (ZeRO-3 batch sharding) for small archs in training; sp+TP else."""
    if shape_name == "train_4k" and arch_name in SMALL_2D:
        return "2d"
    return "sp"


FSDP_OVER_POD = {"llama4-maverick-400b-a17b", "qwen1.5-110b"}


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             out_dir: Path = RESULTS_DIR) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = shlib.AxisRules(mesh, sequence_parallel=True,
                            mode=cell_mode(arch_name, shape_name),
                            fsdp_over_pod=(multi_pod and
                                           arch_name in FSDP_OVER_POD))
    tag = f"{arch_name}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{tag}.json"
    rec = {"arch": arch_name, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "status": "ok"}
    t0 = time.time()
    try:
        lowered = lower_cell(arch_name, shape_name, mesh, rules)
        rec["lower_seconds"] = round(time.time() - t0, 1)
        rec.update(analyze(lowered, mesh))
        try:
            rec.update(cost_probe(arch_name, shape_name))
        except PROBE_ERRORS as e:  # probe is best-effort
            rec["probe_error"] = f"{type(e).__name__}: {e}"
            print(f"dryrun: {tag}: cost probe failed: {rec['probe_error']}",
                  file=sys.stderr)
    except CELL_ERRORS as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"dryrun: {tag}: cell failed: {rec['error']}",
              file=sys.stderr)
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = (["train_4k", "prefill_32k", "decode_32k", "long_500k"]
              if (args.all or not args.shape) else [args.shape])
    for a in archs:
        for s in shapes:
            if supports_shape(ARCHS[a], SHAPES_BY_NAME[s]):
                cells.append((a, s))
            else:
                print(f"SKIP {a} x {s} (needs sub-quadratic attention)")

    for a, s in cells:
        tag = f"{a}__{s}__{'pod2' if args.multi_pod else 'pod1'}"
        if args.skip_existing and (RESULTS_DIR / f"{tag}.json").exists():
            prev = json.loads((RESULTS_DIR / f"{tag}.json").read_text())
            if prev.get("status") == "ok":
                print(f"CACHED {tag}")
                continue
        print(f"=== {tag} ===", flush=True)
        rec = run_cell(a, s, args.multi_pod)
        if rec["status"] == "ok":
            print(f"  ok: compile={rec.get('compile_seconds')}s "
                  f"hbm/device={rec.get('per_device_hbm_bytes', 0)/2**30:.2f}GiB "
                  f"flops={rec.get('hlo_flops', 0):.3e} "
                  f"coll={rec.get('collective_link_bytes', 0)/2**30:.3f}GiB",
                  flush=True)
        else:
            print(f"  ERROR: {rec['error']}", flush=True)


if __name__ == "__main__":
    main()
