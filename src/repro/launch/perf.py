"""Perf hillclimbing harness: lower/compile named VARIANTS of the three
chosen cells and record the roofline terms for the
hypothesis -> change -> measure -> validate loop (EXPERIMENTS.md SSPerf).

Usage::

    PYTHONPATH=src python -m repro.launch.perf --cell qwen_train --variant mb2
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The XLA_FLAGS write above MUST run before any other import (jax locks
# the device count on first backend initialisation).

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES_BY_NAME, get_config
from repro.launch import specs as S
from repro.launch.dryrun import analyze, cell_microbatches, cell_rc, cell_opt
from repro.launch.mesh import make_production_mesh
from repro.optim import OptConfig, init_opt_state
from repro.runtime import sharding as shlib
from repro.runtime.trainer import (make_decode_step, make_prefill_step,
                                   make_train_step)

OUT = Path(__file__).resolve().parents[3] / "results" / "perf"


def lower_variant(arch, shape_name, *, rc=None, microbatches=None,
                  mode="sp", opt_cfg=None, accum_dtype=jnp.float32):
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    rc = rc or cell_rc(arch, shape.kind)
    opt_cfg = opt_cfg or cell_opt(arch)
    mesh = make_production_mesh()
    rules = shlib.AxisRules(mesh, sequence_parallel=True, mode=mode)
    with shlib.axis_rules(rules):
        if shape.kind == "train":
            mb = microbatches if microbatches is not None \
                else cell_microbatches(arch, "train")
            params_a = S.params_abstract(cfg, rc)
            opt_a = jax.eval_shape(lambda: init_opt_state(params_a, opt_cfg))
            batch_a = S.train_batch_specs(cfg, shape, rc)
            p_spec = shlib.param_specs(params_a, rules)
            o_spec = {k: (shlib.param_specs(params_a, rules)
                          if k in ("m", "v") else shlib.replicated(v, rules))
                      for k, v in opt_a.items()}
            b_spec = shlib.batch_specs(batch_a, rules)
            fn = jax.jit(make_train_step(cfg, rc, opt_cfg, microbatches=mb,
                                         accum_dtype=accum_dtype),
                         in_shardings=(p_spec, o_spec, b_spec),
                         out_shardings=(p_spec, o_spec, None),
                         donate_argnums=(0, 1))
            return fn.lower(params_a, opt_a, batch_a), mesh
        if shape.kind == "prefill":
            params_a = S.params_abstract(cfg, rc)
            batch_a = S.prefill_batch_specs(cfg, shape, rc)
            p_spec = shlib.param_specs(params_a, rules)
            b_spec = shlib.batch_specs(batch_a, rules)
            step = make_prefill_step(cfg, rc)
            cache_a = jax.eval_shape(lambda p, b: step(p, b)[1],
                                     params_a, batch_a)
            c_spec = shlib.cache_specs(cache_a, rules)
            fn = jax.jit(step, in_shardings=(p_spec, b_spec),
                         out_shardings=(None, c_spec))
            return fn.lower(params_a, batch_a), mesh
        params_a = S.params_abstract(cfg, rc)
        tok_a = S.decode_token_specs(cfg, shape)
        cache_a = S.cache_specs_abstract(cfg, shape, rc)
        p_spec = shlib.param_specs(params_a, rules)
        c_spec = shlib.cache_specs(cache_a, rules)
        t_spec = shlib.batch_specs(tok_a, rules)
        fn = jax.jit(make_decode_step(cfg, rc),
                     in_shardings=(p_spec, t_spec, c_spec),
                     out_shardings=(None, c_spec), donate_argnums=(2,))
        return fn.lower(params_a, tok_a, cache_a), mesh


# ---------------------------------------------------------------------------
# Variant registry (hypotheses documented in EXPERIMENTS.md SSPerf)
# ---------------------------------------------------------------------------

def _qwen_rc(**kw):
    return dataclasses.replace(cell_rc("qwen1.5-110b", "train"), **kw)


def _xlstm_rc(**kw):
    return dataclasses.replace(cell_rc("xlstm-125m", "prefill"), **kw)


def _xlstm_cfg_chunk(chunk):
    # chunk is carried on the arch config; build an rc-compatible override
    import repro.configs as C
    cfg = C.ARCHS["xlstm-125m"]
    return dataclasses.replace(cfg, xlstm=dataclasses.replace(
        cfg.xlstm, chunk=chunk))


VARIANTS = {
    "qwen_train": {
        "arch": "qwen1.5-110b", "shape": "train_4k",
        "variants": {
            "baseline": {},
            "mb2": {"microbatches": 2},
            "mb1": {"microbatches": 1},
            "dots": {"rc": _qwen_rc(remat_policy="dots", remat_groups=0),
                     "microbatches": 4},
            "mb2_groups4": {"microbatches": 2,
                            "rc": _qwen_rc(remat_groups=4)},
            # round 2: dots needs less memory headroom via more microbatches
            "dots_mb8": {"rc": _qwen_rc(remat_policy="dots", remat_groups=0),
                         "microbatches": 8},
            # round 2: ZeRO-3 (2d batch sharding) vs Megatron-SP — weight
            # gathers (~220GB bf16/pass) vs activation AG/RS at 16 seq/shard
            "2d_dots_mb1": {"mode": "2d", "microbatches": 1,
                            "rc": _qwen_rc(remat_policy="dots",
                                           remat_groups=0)},
            "2d_full_mb2": {"mode": "2d", "microbatches": 2},
            # round 3: 2d needs mb=1 (B=256 = dp x tp exactly); full remat
            # trades one extra gather pass for activation memory
            "2d_full_mb1": {"mode": "2d", "microbatches": 1,
                            "rc": _qwen_rc(remat_groups=0)},
            "2d_groups8_mb1": {"mode": "2d", "microbatches": 1},
        },
    },
    "xlstm_prefill": {
        "arch": "xlstm-125m", "shape": "prefill_32k",
        "variants": {
            "baseline": {},
            "chunk128": {"cfg_override": 128},
            "chunk512": {"cfg_override": 512},
            "chunk1024": {"cfg_override": 1024},
        },
    },
    "qwen_decode": {
        "arch": "qwen1.5-110b", "shape": "decode_32k",
        "variants": {
            "baseline": {},
            # DUS write touches one slot (ideal bytes) IF GSPMD partitions
            # it on the sharded S dim; select touches the whole cache
            "dus_update": {"rc": dataclasses.replace(
                cell_rc("qwen1.5-110b", "decode"), dus_cache_update=True)},
        },
    },
}


def run(cell: str, variant: str):
    spec = VARIANTS[cell]
    kw = dict(spec["variants"][variant])
    cfg_override = kw.pop("cfg_override", None)
    if cfg_override is not None:
        import repro.configs as C
        C.ARCHS["xlstm-125m"] = _xlstm_cfg_chunk(cfg_override)
    t0 = time.time()
    lowered, mesh = lower_variant(spec["arch"], spec["shape"], **kw)
    rec = {"cell": cell, "variant": variant,
           "lower_s": round(time.time() - t0, 1)}
    rec.update(analyze(lowered, mesh))
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / f"{cell}__{variant}.json").write_text(json.dumps(rec, indent=2))
    print(f"{cell}/{variant}: hbm={rec.get('per_device_hbm_bytes',0)/2**30:.2f}GiB "
          f"flops/dev={rec.get('hlo_text_flops_per_device',0):.3e} "
          f"bytes/dev={rec.get('hlo_text_bytes_per_device',0):.3e} "
          f"coll={rec.get('collective_link_bytes',0)/2**30:.1f}GiB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--variant", default=None)
    args = ap.parse_args()
    variants = ([args.variant] if args.variant
                else list(VARIANTS[args.cell]["variants"]))
    for v in variants:
        run(args.cell, v)


if __name__ == "__main__":
    main()
