"""Training driver: data pipeline -> sharded train_step -> checkpoints.

Runs at smoke scale on CPU and is the same code path the production mesh
uses (pass --mesh prod inside a 256-device environment).

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b-smoke \
      --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpointing import CheckpointManager
from repro.configs import get_config
from repro.data import batch_for_arch
from repro.models import lm
from repro.models.common import RuntimeConfig, CPU_RC
from repro.optim import OptConfig, init_opt_state
from repro.runtime.trainer import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b-smoke")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    rc = CPU_RC if jax.default_backend() == "cpu" else RuntimeConfig()
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                        decay_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, rc, opt_cfg,
                                      microbatches=args.microbatches))

    def init():
        params = lm.init_params(cfg, jax.random.PRNGKey(args.seed), rc)
        return {"params": params, "opt": init_opt_state(params, opt_cfg)}

    start = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, interval=args.ckpt_every)
        state, start, _ = mgr.restore_or_init(
            jax.eval_shape(init), init)
        if start:
            print(f"resumed from step {start}")
    else:
        mgr = None
        state = init()

    params, opt = state["params"], state["opt"]
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n/1e6:.2f}M backend={jax.default_backend()}")
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 batch_for_arch(cfg, args.seq, args.batch, step,
                                seed=args.seed).items()}
        params, opt, m = step_fn(params, opt, batch)
        if mgr:
            mgr.maybe_save(step + 1, {"params": params, "opt": opt},
                           extra={"data_step": step + 1})
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = (time.time() - t0) / max(step - start + 1, 1)
            print(f"step {step:5d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"lr={float(m['lr']):.2e} {dt*1e3:.0f} ms/step",
                  flush=True)
    print("done")


if __name__ == "__main__":
    main()
