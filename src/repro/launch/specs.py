"""ShapeDtypeStruct stand-ins for every model input (dry-run inputs).

``input_specs`` mirrors the shannon/kernels pattern: weak-type-correct,
shardable, no device allocation.  For decode shapes the cache structure is
obtained with jax.eval_shape over init_cache.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import lm
from repro.models.common import RuntimeConfig, DEFAULT_RC

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig,
                      rc: RuntimeConfig = DEFAULT_RC) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        t = SDS((B, S, cfg.n_codebooks), jnp.int32)
        return {"tokens": t, "labels": t}
    if cfg.family == "vlm":
        nf = cfg.n_frontend_tokens
        return {
            "tokens": SDS((B, S - nf), jnp.int32),
            "labels": SDS((B, S - nf), jnp.int32),
            "vis_embeds": SDS((B, nf, cfg.d_model), rc.compute_dtype),
        }
    t = SDS((B, S), jnp.int32)
    return {"tokens": t, "labels": t}


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeConfig,
                        rc: RuntimeConfig = DEFAULT_RC) -> Dict[str, Any]:
    b = train_batch_specs(cfg, shape, rc)
    b.pop("labels")
    return b


def decode_token_specs(cfg: ArchConfig, shape: ShapeConfig) -> Any:
    B = shape.global_batch
    if cfg.family == "audio":
        return SDS((B, cfg.n_codebooks), jnp.int32)
    return SDS((B,), jnp.int32)


def cache_specs_abstract(cfg: ArchConfig, shape: ShapeConfig,
                         rc: RuntimeConfig = DEFAULT_RC):
    """Abstract cache pytree (ShapeDtypeStructs) for decode dry-runs."""
    return jax.eval_shape(
        lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len, rc))


def params_abstract(cfg: ArchConfig, rc: RuntimeConfig = DEFAULT_RC):
    return jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0), rc))


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                rc: RuntimeConfig = DEFAULT_RC) -> Dict[str, Any]:
    """All inputs for the step implied by shape.kind (excluding params/state)."""
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape, rc)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape, rc)}
    if shape.kind == "decode":
        return {"tokens": decode_token_specs(cfg, shape),
                "cache": cache_specs_abstract(cfg, shape, rc)}
    raise ValueError(shape.kind)
