"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (jax locks the device count on first backend init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e pod, 256 chips) or 2x16x16 (two pods, 512 chips).

    Axis roles: 'pod' = pure DP across pods (slow links, gradient all-reduce
    only), 'data' = DP + FSDP shard axis, 'model' = TP/EP/vocab/sequence.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small host-device mesh for tests (requires >= n_data*n_model devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
