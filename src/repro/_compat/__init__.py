"""Compatibility shims for optional third-party dependencies.

The repo's hard runtime dependencies are ``jax`` and ``numpy`` only
(see pyproject.toml).  Everything else is gated: when an optional
package is missing, a minimal fallback with the same surface is
installed instead, so the tier-1 test suite collects and runs on a
bare image.
"""
