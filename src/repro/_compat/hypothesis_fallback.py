"""Minimal stand-in for `hypothesis` when the real package is absent.

The test suite uses a small slice of the hypothesis API:

    from hypothesis import given, settings, strategies as st
    @settings(max_examples=N, deadline=None)
    @given(seed=st.integers(a, b), u=st.floats(a, b))
    def test_...(...)

This module reimplements exactly that slice as a deterministic
pseudo-random sampler (seeded per test from the test's qualified name),
so property tests still exercise a spread of inputs on images where
hypothesis cannot be installed.  It is NOT a shrinker and finds no
minimal counterexamples — install the real `hypothesis` (declared in
pyproject.toml's dev extra) for full power.  `install()` registers the
shim under ``sys.modules["hypothesis"]`` only when the real package is
missing; see tests/conftest.py.
"""
from __future__ import annotations

import functools
import hashlib
import inspect
import sys
import types

import numpy as np

FALLBACK = True
_DEFAULT_MAX_EXAMPLES = 20


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)

    def map(self, f) -> "Strategy":
        return Strategy(lambda rng: f(self._draw(rng)))

    def filter(self, pred, _tries: int = 1000) -> "Strategy":
        def draw(rng):
            for _ in range(_tries):
                x = self._draw(rng)
                if pred(x):
                    return x
            raise ValueError("filter predicate never satisfied")
        return Strategy(draw)


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float, **_kw) -> Strategy:
    return Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans() -> Strategy:
    return Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(seq) -> Strategy:
    seq = list(seq)
    return Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def lists(elements: Strategy, min_size: int = 0,
          max_size: int = 10, **_kw) -> Strategy:
    return Strategy(
        lambda rng: [elements.example(rng)
                     for _ in range(int(rng.integers(min_size,
                                                     max_size + 1)))])


def just(value) -> Strategy:
    return Strategy(lambda rng: value)


def tuples(*strategies: Strategy) -> Strategy:
    return Strategy(lambda rng: tuple(s.example(rng) for s in strategies))


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies: Strategy, **kw_strategies: Strategy):
    def deco(fn):
        # like real hypothesis: positional strategies bind to the
        # RIGHTMOST parameters (by keyword), so preceding pytest
        # fixture params keep working
        sig = inspect.signature(fn)
        free = [n for n in sig.parameters if n not in kw_strategies]
        pos_names = free[len(free) - len(arg_strategies):] \
            if arg_strategies else []
        strategies = {**dict(zip(pos_names, arg_strategies)),
                      **kw_strategies}

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            # stable per-test stream, independent of run order
            h = hashlib.sha256(fn.__qualname__.encode()).digest()
            rng = np.random.default_rng(int.from_bytes(h[:8], "little"))
            done = 0
            attempts = 0
            while done < n and attempts < n * 50:
                attempts += 1
                drawn = {k: s.example(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except _Unsatisfied:
                    continue             # assume() discarded the example
                done += 1
            if n > 0 and done == 0:
                raise RuntimeError(
                    f"{fn.__qualname__}: assume() discarded all "
                    f"{attempts} drawn examples — unsatisfiable predicate?")
        # hide strategy-filled parameters from pytest's fixture resolution
        wrapper.__signature__ = sig.replace(
            parameters=[sig.parameters[n] for n in sig.parameters
                        if n not in strategies])
        wrapper.hypothesis_fallback = True
        return wrapper
    return deco


def assume(condition: bool) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class _Unsatisfied(Exception):
    pass


def install() -> types.ModuleType:
    """Register this shim as ``hypothesis`` if the real one is missing."""
    if "hypothesis" in sys.modules:
        return sys.modules["hypothesis"]
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.FALLBACK = True
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from",
                 "lists", "just", "tuples"):
        setattr(st, name, globals()[name])
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
    return mod
