"""FFN variants: SwiGLU MLP and Mixture-of-Experts.

MoE uses sort-based capacity dispatch (no (N,E,C) one-hot tensor):
tokens are argsorted by expert id, packed into per-expert buffers of capacity
C = ceil(N*k*cf/E) via gathers, processed with batched expert einsums (expert
dim sharded over 'model' = EP), and combined with a batched scatter-add
(lowers to local scatter + all-reduce over the expert axis under GSPMD).

Routing rows: training/prefill routes per sequence (rows=B, tokens=S) so the
sort stays local to each data shard; decode routes over the batch (rows=1,
tokens=B) so capacity stays proportional to live tokens.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.runtime.sharding import shard_activation


def _glu(x, p, act):
    if x.ndim == 3:
        x = shard_activation(x, "ffn_in", None)
    h = jnp.einsum("...d,df->...f", x, p["w1"].astype(x.dtype))
    g = jnp.einsum("...d,df->...f", x, p["w3"].astype(x.dtype))
    h = act(h) * g
    if h.ndim == 3:
        h = shard_activation(h, "ffn_hidden", None)
    y = jnp.einsum("...f,fd->...d", h, p["w2"].astype(x.dtype))
    if y.ndim == 3:
        # partial sums over 'model' reduce-scatter straight into the
        # S-sharded residual layout (Megatron-SP exit boundary)
        y = shard_activation(y, "residual", None)
    return y


def swiglu(x, p):
    """x (..., D) with params w1,w3 (D,F), w2 (F,D)."""
    return _glu(x, p, jax.nn.silu)


def geglu(x, p):
    """Gated-GeLU MLP (RecurrentGemma/Gemma style)."""
    return _glu(x, p, jax.nn.gelu)


def moe_capacity(n_tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    c = int(math.ceil(n_tokens * top_k * cf / n_experts))
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def moe_apply(x, p, cfg):
    """Mixture-of-experts FFN.  x (R, N, D) -> (y (R, N, D), aux_metrics).

    R = routing rows (sorted independently), N = tokens per row.
    """
    e = cfg.moe
    R, N, D = x.shape
    E, K = e.num_experts, e.top_k
    C = moe_capacity(N, K, E, e.capacity_factor)

    x = shard_activation(x, "moe_tokens", None)
    router_logits = jnp.einsum("rnd,de->rne", x, p["router"].astype(x.dtype))
    router_logits = router_logits.astype(jnp.float32)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, K)                       # (R, N, K)
    if K > 1:
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # ---- dispatch bookkeeping (all (R, N*K) int32) ----
    e_flat = eidx.reshape(R, N * K)
    order = jnp.argsort(e_flat, axis=-1, stable=True)           # slots grouped by expert
    sorted_e = jnp.take_along_axis(e_flat, order, axis=-1)
    hist = jnp.sum(jax.nn.one_hot(e_flat, E, dtype=jnp.int32), axis=1)  # (R, E)
    starts = jnp.cumsum(hist, axis=-1) - hist                   # exclusive cumsum
    pos_in_e = jnp.arange(N * K)[None, :] - jnp.take_along_axis(
        starts, sorted_e, axis=-1)
    keep = pos_in_e < C
    tok_sorted = order // K                                      # token id per sorted slot

    # destination-major view: slot (e, c) <- sorted position starts[e] + c
    slot = starts[:, :, None] + jnp.arange(C)[None, None, :]     # (R, E, C)
    slot_valid = jnp.arange(C)[None, None, :] < jnp.minimum(hist, C)[:, :, None]
    slot_c = jnp.clip(slot, 0, N * K - 1)
    src_tok = jnp.take_along_axis(tok_sorted, slot_c.reshape(R, -1), axis=-1)
    src_tok = src_tok.reshape(R, E, C)
    gates_flat = jnp.take_along_axis(
        gates.reshape(R, N * K), order, axis=-1)
    slot_gate = jnp.take_along_axis(gates_flat, slot_c.reshape(R, -1), axis=-1)
    slot_gate = (slot_gate.reshape(R, E, C) * slot_valid).astype(x.dtype)

    # ---- gather -> expert compute -> gather-based combine ----
    # All data movement is take_along_axis over one collapsed dim (implicit
    # batch): these partition on the row dim under GSPMD, while scatter-add
    # or multi-dim advanced indexing would replicate the operands.
    x_e = jnp.take_along_axis(x, src_tok.reshape(R, E * C)[..., None],
                              axis=1).reshape(R, E, C, D)
    x_e = x_e * slot_valid[..., None].astype(x.dtype)
    x_e = shard_activation(x_e, "moe_buf", None)                 # EP layout
    h = jnp.einsum("recd,edf->recf", x_e, p["w1"].astype(x.dtype))
    g = jnp.einsum("recd,edf->recf", x_e, p["w3"].astype(x.dtype))
    h = shard_activation(h, "moe_buf", None)
    y_e = jnp.einsum("recf,efd->recd", jax.nn.silu(h) * g,
                     p["w2"].astype(x.dtype))
    y_e = y_e * slot_gate[..., None]

    # invert the sort: position of every (token, choice) inside its expert
    inv = jnp.argsort(order, axis=-1)
    pos_unsorted = jnp.take_along_axis(pos_in_e, inv, axis=-1)
    slot_c2 = pos_unsorted.reshape(R, N, K)
    valid_tok = (slot_c2 < C)
    y_e = shard_activation(y_e, "moe_gathered", None)  # AG experts locally
    flat_idx = (eidx * C + jnp.clip(slot_c2, 0, C - 1)).reshape(R, N * K)
    picked = jnp.take_along_axis(y_e.reshape(R, E * C, D),
                                 flat_idx[..., None], axis=1)
    picked = picked.reshape(R, N, K, D)                # gated expert outputs
    y = jnp.sum(picked * valid_tok[..., None].astype(x.dtype), axis=2)
    y = shard_activation(y, "moe_tokens", None)

    if e.num_shared > 0:
        y = y + swiglu(x, p["shared"])

    # ---- aux losses (Switch-style load balance + router z-loss) ----
    frac = jnp.mean(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=(1, 2))
    mean_p = jnp.mean(probs, axis=1)                             # (R, E)
    aux = E * jnp.mean(jnp.sum(frac * mean_p, axis=-1))
    z = jnp.mean(jnp.square(jax.nn.logsumexp(router_logits, axis=-1)))
    dropped = 1.0 - jnp.sum(slot_valid) / (R * N * K)
    metrics = {"moe_aux": aux * e.aux_coef, "moe_z": z * e.router_z_coef,
               "moe_dropped": dropped}
    return y, metrics
