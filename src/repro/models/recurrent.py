"""Recurrent temporal-mixing blocks: RG-LRU (RecurrentGemma/Griffin) and
xLSTM cells (mLSTM with parallel+recurrent forms, sLSTM sequential).

Parallel (training) and recurrent (decode) forms are numerically consistent —
property-tested in tests/test_models.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SQRT_EPS = 1e-8
RGLRU_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def block_diag_linear(x, w, b=None):
    """x (..., H, dh_in) @ w (H, dh_in, dh_out)."""
    y = jnp.einsum("...hi,hij->...hj", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def _rglru_coeffs(x, p, n_heads):
    """x (B,S,d_rnn) -> a (gate-modulated decay), b (gated input), fp32."""
    B, S, d = x.shape
    xh = x.reshape(B, S, n_heads, d // n_heads)
    r = jax.nn.sigmoid(block_diag_linear(xh, p["w_a"], p["b_a"])
                       .reshape(B, S, d).astype(jnp.float32))
    i = jax.nn.sigmoid(block_diag_linear(xh, p["w_x"], p["b_x"])
                       .reshape(B, S, d).astype(jnp.float32))
    log_a = -RGLRU_C * r * jax.nn.softplus(p["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    a2 = jnp.exp(2.0 * log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a2, SQRT_EPS)) * i * x.astype(jnp.float32)
    return a, b


def rglru_scan(x, p, n_heads, h0=None):
    """Parallel RG-LRU over a sequence via associative scan.

    x (B, S, d_rnn); h0 (B, d_rnn) optional initial state.
    Returns (y (B,S,d_rnn), h_last (B,d_rnn)).
    """
    a, b = _rglru_coeffs(x, p, n_heads)
    if h0 is not None:
        # fold h0 into the first step:  h_1 = a_1 h0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hh.astype(x.dtype), hh[:, -1]


def rglru_step(x, p, n_heads, h):
    """One decode step. x (B, d_rnn), h (B, d_rnn) -> (y, h_new)."""
    a, b = _rglru_coeffs(x[:, None], p, n_heads)
    h_new = a[:, 0] * h.astype(jnp.float32) + b[:, 0]
    return h_new.astype(x.dtype), h_new


def causal_conv1d(x, w, b, state=None):
    """Depthwise causal conv.  x (B,S,d), w (W,d).  state (B,W-1,d) for decode.

    Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    y = y + b.astype(x.dtype)
    return y, xp[:, -(W - 1):]


# ---------------------------------------------------------------------------
# mLSTM (matrix memory; parallel quadratic + recurrent forms)
# ---------------------------------------------------------------------------

def mlstm_parallel(q, k, v, log_i, log_f):
    """q,k,v (B,H,S,dh); log_i/log_f (B,H,S) fp32. Returns h (B,H,S,dh)."""
    S = q.shape[2]
    dh = q.shape[3]
    lf32 = log_f.astype(jnp.float32)
    li32 = log_i.astype(jnp.float32)
    F = jnp.cumsum(lf32, axis=-1)                       # inclusive
    D = F[..., :, None] - F[..., None, :] + li32[..., None, :]
    mask = jnp.tril(jnp.ones((S, S), bool))
    D = jnp.where(mask, D, -jnp.inf)
    m = jnp.max(D, axis=-1)                             # (B,H,S)
    Ds = jnp.exp(D - m[..., None])
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k,
                        preferred_element_type=jnp.float32) * (dh ** -0.5)
    Sm = scores * Ds
    norm = jnp.maximum(jnp.abs(jnp.sum(Sm, axis=-1)), jnp.exp(-m))
    h = jnp.einsum("bhst,bhtd->bhsd", Sm.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return (h / norm[..., None]).astype(q.dtype)


def mlstm_step(q, k, v, log_i, log_f, state):
    """Recurrent mLSTM step (stabilized).

    q,k,v (B,H,dh); log_i/log_f (B,H); state = (C (B,H,dh,dh), n (B,H,dh),
    m (B,H)).  Returns (h (B,H,dh), new_state).
    """
    C, n, m = state
    li = log_i.astype(jnp.float32)
    lf = log_f.astype(jnp.float32)
    m_new = jnp.maximum(lf + m, li)
    i_p = jnp.exp(li - m_new)
    f_p = jnp.exp(lf + m - m_new)
    k32, v32, q32 = (t.astype(jnp.float32) for t in (k, v, q))
    C_new = f_p[..., None, None] * C + i_p[..., None, None] * (
        k32[..., :, None] * v32[..., None, :])
    n_new = f_p[..., None] * n + i_p[..., None] * k32
    dh = q.shape[-1]
    qs = q32 * (dh ** -0.5)
    num = jnp.einsum("bhd,bhde->bhe", qs, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n_new)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).astype(q.dtype)
    return h, (C_new, n_new, m_new)


def _empty_mlstm_state(B, H, dh, dv):
    return (jnp.zeros((B, H, dh, dv), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32),
            jnp.full((B, H), -1e30, jnp.float32))


def _chunk_update(k, v, li, lf, F, state):
    """Chunk-end state update. k,v (B,H,W,dh); li/lf/F (B,H,W)."""
    C, n, m = state
    F_tot = F[..., -1]                                   # (B,H)
    decay_s = F_tot[..., None] - F + li                  # (B,H,W)
    m_new = jnp.maximum(m + F_tot, jnp.max(decay_s, axis=-1))
    carry_c = jnp.exp(m + F_tot - m_new)
    w_s = jnp.exp(decay_s - m_new[..., None])
    k32 = k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    C_new = carry_c[..., None, None] * C + jnp.einsum(
        "bhw,bhwd,bhwe->bhde", w_s, k32, v32)
    n_new = carry_c[..., None] * n + jnp.einsum("bhw,bhwd->bhd", w_s, k32)
    return C_new, n_new, m_new


def mlstm_final_state(q, k, v, log_i, log_f, state=None):
    """State after consuming the whole sequence (for prefill caches)."""
    B, H, S, dh = k.shape
    if state is None:
        state = _empty_mlstm_state(B, H, dh, v.shape[-1])
    F = jnp.cumsum(log_f.astype(jnp.float32), axis=-1)
    return _chunk_update(k, v, log_i.astype(jnp.float32), log_f, F, state)


def mlstm_chunkwise(q, k, v, log_i, log_f, *, chunk: int, state=None,
                    unroll: bool = False):
    """Chunkwise-parallel mLSTM: O(S*chunk) intra + O(S/chunk) recurrence.

    q,k,v (B,H,S,dh); log_i/log_f (B,H,S).  Returns (h, final_state).
    Numerically consistent with mlstm_parallel / mlstm_step (stabilized).
    """
    B, H, S, dh = q.shape
    dv = v.shape[-1]
    assert S % chunk == 0
    Nc = S // chunk
    if state is None:
        state = _empty_mlstm_state(B, H, dh, dv)

    rs = lambda t: t.reshape(B, H, Nc, chunk, -1).transpose(2, 0, 1, 3, 4)
    rg = lambda t: t.astype(jnp.float32).reshape(B, H, Nc, chunk) \
        .transpose(2, 0, 1, 3)
    qs, ks, vs = rs(q), rs(k), rs(v)
    lis, lfs = rg(log_i), rg(log_f)
    scale = dh ** -0.5
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(carry, xs):
        C, n, m = carry
        qc, kc, vc, li, lf = xs
        F = jnp.cumsum(lf, axis=-1)
        D = F[..., :, None] - F[..., None, :] + li[..., None, :]
        D = jnp.where(tri, D, -jnp.inf)
        g = F + m[..., None]                             # inter exponent
        m_t = jnp.maximum(jnp.max(D, axis=-1), g)        # (B,H,W)
        Ds = jnp.exp(D - m_t[..., None])
        inter_w = jnp.exp(g - m_t)                       # (B,H,W)
        scores = jnp.einsum("bhsd,bhtd->bhst", qc, kc,
                            preferred_element_type=jnp.float32) * scale
        Sm = scores * Ds
        q32 = qc.astype(jnp.float32) * scale
        num = jnp.einsum("bhst,bhtd->bhsd", Sm.astype(vc.dtype), vc,
                         preferred_element_type=jnp.float32) \
            + inter_w[..., None] * jnp.einsum("bhsd,bhde->bhse", q32, C)
        den = jnp.abs(jnp.sum(Sm, axis=-1)
                      + inter_w * jnp.einsum("bhsd,bhd->bhs", q32, n))
        den = jnp.maximum(den, jnp.exp(-m_t))
        h = (num / den[..., None]).astype(qc.dtype)
        new_state = _chunk_update(kc, vc, li, lf, F, (C, n, m))
        return new_state, h

    final_state, hs = jax.lax.scan(jax.checkpoint(step), state,
                                   (qs, ks, vs, lis, lfs), unroll=unroll)
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, dv)
    return h, final_state


def groupnorm_heads(x, scale, n_heads, eps: float = 1e-5):
    """Per-head LayerNorm (GroupNorm with groups = heads). x (..., inner)."""
    shp = x.shape
    dh = shp[-1] // n_heads
    xh = x.reshape(shp[:-1] + (n_heads, dh)).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    y = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (y.reshape(shp) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, recurrent h->gates connections; sequential)
# ---------------------------------------------------------------------------

def slstm_seq(x, p, n_heads, state=None):
    """x (B,S,D). Block-diagonal recurrent weights per head.

    state: (c, n, h, m) each (B, D).  Returns (y (B,S,D), new_state).
    """
    B, S, D = x.shape
    dh = D // n_heads

    wx = p["w_in"].astype(jnp.float32)        # (D, 4D) -> z,i,f,o pre-acts
    r = p["r"].astype(jnp.float32)            # (H, dh, 4*dh) recurrent
    b = p["b"].astype(jnp.float32)            # (4D,)

    if state is None:
        zeros = jnp.zeros((B, D), jnp.float32)
        state = (zeros, zeros, zeros, zeros - 10.0)

    pre_x = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), wx) + b

    def step(carry, pre_t):
        c, n, h, m = carry
        hh = h.reshape(B, n_heads, dh)
        pre_h = jnp.einsum("bhi,hij->bhj", hh, r).reshape(B, 4 * D)
        z_p, i_p, f_p, o_p = jnp.split(pre_t + pre_h, 4, axis=-1)
        z = jnp.tanh(z_p)
        o = jax.nn.sigmoid(o_p)
        m_new = jnp.maximum(f_p + m, i_p)
        i_g = jnp.exp(i_p - m_new)
        f_g = jnp.exp(f_p + m - m_new)
        c_new = f_g * c + i_g * z
        n_new = f_g * n + i_g
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    new_state, ys = jax.lax.scan(step, state, jnp.moveaxis(pre_x, 1, 0))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), new_state
