from repro.models import lm, attention, ffn, recurrent, common  # noqa: F401
