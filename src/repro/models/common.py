"""Shared model components: norms, RoPE, initialisers, runtime config."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Numerics / memory policy knobs (perf levers for §Perf)."""
    compute_dtype: jnp.dtype = jnp.bfloat16
    param_dtype: jnp.dtype = jnp.float32
    remat_policy: str = "none"          # none | full | dots
    remat_groups: int = 0               # >0: nested-scan double remat, G groups
    sequence_parallel: bool = False     # shard residual-stream S over 'model'
    flash_block_q: int = 512
    flash_block_kv: int = 512
    z_loss: float = 1e-4
    logical_axes: bool = True           # emit sharding constraints
    cost_probe: bool = False            # unroll scans for exact HLO FLOP counts
    dus_cache_update: bool = False      # decode cache write via DUS (vs select)
    pad_attn_heads: int = 0             # pad Q heads to this multiple for TP


DEFAULT_RC = RuntimeConfig()
CPU_RC = RuntimeConfig(compute_dtype=jnp.float32)


def remat_wrap(fn, rc: RuntimeConfig):
    if rc.remat_policy == "none":
        return fn
    if rc.remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale=None, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(dtype)


def layernorm(x, scale=None, bias=None, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dtype)


def apply_norm(kind: str, x, params: Optional[dict]):
    if kind == "rmsnorm":
        return rmsnorm(x, params.get("scale") if params else None)
    if kind == "layernorm":
        return layernorm(x, params.get("scale") if params else None,
                         params.get("bias") if params else None)
    if kind == "layernorm_nonparam":
        return layernorm(x, None, None)
    raise ValueError(f"unknown norm {kind}")


def norm_params(kind: str, d: int, dtype) -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {}  # non-parametric


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_cos_sin(positions, dim: int, theta: float):
    """positions (...,) -> cos/sin (..., dim/2), fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, H, D); cos/sin broadcastable (..., S, 1, D/2)."""
    dtype = x.dtype
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.truncated_normal(
        key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def keygen(key):
    while True:
        key, sub = jax.random.split(key)
        yield sub


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_xent_sums(logits, labels, z_loss_coef: float = 1e-4):
    """Sum-reduced xent pieces for chunked accumulation.

    Returns (sum nll+z, sum nll, n_valid) as fp32 scalars."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = lse - ll
    z = z_loss_coef * jnp.square(lse)
    return (jnp.sum(jnp.where(valid, nll + z, 0.0)),
            jnp.sum(jnp.where(valid, nll, 0.0)),
            jnp.sum(valid))


def softmax_xent(logits, labels, z_loss_coef: float = 1e-4, mask=None):
    """Causal-LM cross-entropy with z-loss; labels<0 are ignored.

    logits (..., V) fp-any; labels (...,) int32.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    valid = labels >= 0
    if mask is not None:
        valid = jnp.logical_and(valid, mask.astype(bool))
    safe = jnp.where(valid, labels, 0)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = lse - ll
    z = z_loss_coef * jnp.square(lse)
    per_tok = jnp.where(valid, nll + z, 0.0)
    n = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(per_tok) / n, {"nll": jnp.sum(jnp.where(valid, nll, 0.0)) / n,
                                  "ntokens": n}
