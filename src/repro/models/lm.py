"""Composable decoder LM covering all assigned architecture families.

Families:
  dense / vlm / audio : uniform (attn + SwiGLU/GeGLU) blocks, scan over L
  moe (moe_every=2)   : scan over groups of (attn+dense, attn+MoE)
  mla_moe             : scan over L of (MLA attn + MoE)
  hybrid              : scan over groups (rglru, rglru, local-attn) + tail
  xlstm               : scan over groups of (mLSTM ... sLSTM)

All entry points are pure functions of (cfg, params, ...):
  init_params, forward (train/prefill), loss_fn, init_cache, prefill,
  decode_step.

Layer params are stacked along a leading scan dim; caches mirror that
stacking so decode scans layers with (params, cache) as xs/ys.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import ffn as ffn_lib
from repro.models import recurrent as rec_lib
from repro.models.common import (RuntimeConfig, DEFAULT_RC, apply_norm,
                                 dense_init, norm_params, softmax_xent)
from repro.runtime.sharding import shard_activation

Params = Dict[str, Any]


# ===========================================================================
# Parameter init (single layer; stacked via vmap over keys)
# ===========================================================================

def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


def _attn_params(cfg: ArchConfig, key, dtype):
    d, dh, hq, hkv = cfg.d_model, cfg.dh, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "ln": norm_params(cfg.norm, d, dtype),
        "wq": dense_init(ks[0], (d, hq * dh), dtype),
        "wk": dense_init(ks[1], (d, hkv * dh), dtype),
        "wv": dense_init(ks[2], (d, hkv * dh), dtype),
        "wo": dense_init(ks[3], (hq * dh, d), dtype,
                         scale=0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)),
    }
    if cfg.qkv_bias:
        p.update(bq=jnp.zeros((hq * dh,), dtype),
                 bk=jnp.zeros((hkv * dh,), dtype),
                 bv=jnp.zeros((hkv * dh,), dtype))
    return p


def _mla_params(cfg: ArchConfig, key, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 5)
    return {
        "ln": norm_params(cfg.norm, d, dtype),
        "w_q": dense_init(ks[0], (d, H * (m.qk_nope_dim + m.qk_rope_dim)), dtype),
        "w_dkv": dense_init(ks[1], (d, m.kv_lora_rank + m.qk_rope_dim), dtype),
        "c_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        "w_uk": dense_init(ks[2], (m.kv_lora_rank, H * m.qk_nope_dim), dtype),
        "w_uv": dense_init(ks[3], (m.kv_lora_rank, H * m.v_head_dim), dtype),
        "w_o": dense_init(ks[4], (H * m.v_head_dim, d), dtype,
                          scale=0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)),
    }


def _mlp_params(cfg: ArchConfig, key, dtype, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "ln": norm_params(cfg.norm, d, dtype),
        "w1": dense_init(ks[0], (d, f), dtype),
        "w3": dense_init(ks[1], (d, f), dtype),
        "w2": dense_init(ks[2], (f, d), dtype,
                         scale=0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)),
    }


def _moe_params(cfg: ArchConfig, key, dtype):
    e = cfg.moe
    d, E, f = cfg.d_model, e.num_experts, e.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "ln": norm_params(cfg.norm, d, dtype),
        "router": dense_init(ks[0], (d, E), dtype, scale=0.02),
        "w1": dense_init(ks[1], (E, d, f), dtype),
        "w3": dense_init(ks[2], (E, d, f), dtype),
        "w2": dense_init(ks[3], (E, f, d), dtype,
                         scale=0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)),
    }
    if e.num_shared > 0:
        sk = jax.random.split(ks[4], 3)
        sf = e.num_shared * f
        p["shared"] = {
            "w1": dense_init(sk[0], (d, sf), dtype),
            "w3": dense_init(sk[1], (d, sf), dtype),
            "w2": dense_init(sk[2], (sf, d), dtype),
        }
    return p


def _rglru_block_params(cfg: ArchConfig, key, dtype):
    r = cfg.rglru
    d, dr, H = cfg.d_model, r.d_rnn, cfg.n_heads
    dh = dr // H
    ks = jax.random.split(key, 8)
    lam = jax.random.uniform(ks[6], (dr,), jnp.float32, 0.65 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.exp(jnp.sqrt(lam) * 8.0) - 1.0) / 8.0  # inv softplus-ish
    return {
        "ln": norm_params(cfg.norm, d, dtype),
        "w_y": dense_init(ks[0], (d, dr), dtype),          # gated (GeLU) branch
        "w_xb": dense_init(ks[1], (d, dr), dtype),         # recurrence branch
        "conv_w": dense_init(ks[2], (r.conv_width, dr), dtype, scale=0.1),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_a": dense_init(ks[3], (H, dh, dh), dtype),
        "b_a": dense_init(ks[4], (H, dh), dtype),
        "w_x": dense_init(ks[5], (H, dh, dh), dtype),
        "b_x": jnp.zeros((H, dh), dtype),
        "lam": lam.astype(jnp.float32),
        "w_out": dense_init(ks[7], (dr, d), dtype,
                            scale=0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)),
    }


def _mlstm_params(cfg: ArchConfig, key, dtype):
    x = cfg.xlstm
    d, H = cfg.d_model, cfg.n_heads
    inner = int(x.mlstm_proj_factor * d)
    dh = inner // H
    ks = jax.random.split(key, 7)
    return {
        "ln": norm_params(cfg.norm, d, dtype),
        "w_up": dense_init(ks[0], (d, 2 * inner), dtype),   # u, gate z
        "conv_w": dense_init(ks[1], (4, inner), dtype, scale=0.1),
        "conv_b": jnp.zeros((inner,), dtype),
        "w_q": dense_init(ks[2], (inner, inner), dtype),
        "w_k": dense_init(ks[3], (inner, inner), dtype),
        "w_if": dense_init(ks[4], (inner, 2 * H), dtype, scale=0.01),
        "b_if": jnp.concatenate([jnp.zeros((H,), dtype),
                                 jnp.full((H,), 3.0, dtype)]),  # forget-bias
        "gn": jnp.ones((inner,), dtype),
        "w_down": dense_init(ks[6], (inner, d), dtype,
                             scale=0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)),
    }


def _slstm_params(cfg: ArchConfig, key, dtype):
    x = cfg.xlstm
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 5)
    f_inner = int(x.slstm_proj_factor * d)
    return {
        "ln": norm_params(cfg.norm, d, dtype),
        "ln_mlp": norm_params(cfg.norm, d, dtype),
        "w_in": dense_init(ks[0], (d, 4 * d), dtype),
        "r": dense_init(ks[1], (H, dh, 4 * dh), dtype, scale=0.01),
        "b": jnp.concatenate([jnp.zeros((2 * d,), dtype),
                              jnp.full((d,), 2.0, dtype),
                              jnp.zeros((d,), dtype)]),  # z,i,f(+bias),o
        "gn": jnp.ones((d,), dtype),
        "mlp": {"w1": dense_init(ks[2], (d, f_inner), dtype),
                "w3": dense_init(ks[3], (d, f_inner), dtype),
                "w2": dense_init(ks[4], (f_inner, d), dtype)},
    }


def _hybrid_group_counts(cfg: ArchConfig) -> Tuple[int, int]:
    """(n_groups of (rec,rec,attn), n_tail rec layers)."""
    pat = len(cfg.rglru.block_pattern)  # 3
    return cfg.n_layers // pat, cfg.n_layers % pat


def init_params(cfg: ArchConfig, key, rc: RuntimeConfig = DEFAULT_RC) -> Params:
    dtype = rc.param_dtype
    kg = jax.random.split(key, 8)
    d, V = cfg.d_model, cfg.vocab
    params: Params = {
        "embed": dense_init(kg[0], (cfg.n_codebooks * V if cfg.family == "audio"
                                    else V, d), dtype, scale=0.02),
        "out_norm": norm_params(cfg.norm, d, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            kg[1], (d, cfg.n_codebooks * V if cfg.family == "audio" else V),
            dtype)

    fam = cfg.family
    if fam in ("dense", "vlm", "audio"):
        params["blocks"] = _stack_init(
            lambda k: {"attn": _attn_params(cfg, k, dtype),
                       "mlp": _mlp_params(cfg, jax.random.fold_in(k, 1), dtype)},
            kg[2], cfg.n_layers)
    elif fam == "moe":
        every = cfg.moe.moe_every
        assert cfg.n_layers % every == 0
        params["blocks"] = _stack_init(
            lambda k: {
                "attn_a": _attn_params(cfg, k, dtype),
                "mlp": _mlp_params(cfg, jax.random.fold_in(k, 1), dtype),
                "attn_b": _attn_params(cfg, jax.random.fold_in(k, 2), dtype),
                "moe": _moe_params(cfg, jax.random.fold_in(k, 3), dtype),
            }, kg[2], cfg.n_layers // every)
    elif fam == "mla_moe":
        params["blocks"] = _stack_init(
            lambda k: {"attn": _mla_params(cfg, k, dtype),
                       "moe": _moe_params(cfg, jax.random.fold_in(k, 1), dtype)},
            kg[2], cfg.n_layers)
    elif fam == "hybrid":
        G, tail = _hybrid_group_counts(cfg)
        params["blocks"] = _stack_init(
            lambda k: {
                "rec0": _rglru_block_params(cfg, k, dtype),
                "mlp0": _mlp_params(cfg, jax.random.fold_in(k, 1), dtype),
                "rec1": _rglru_block_params(cfg, jax.random.fold_in(k, 2), dtype),
                "mlp1": _mlp_params(cfg, jax.random.fold_in(k, 3), dtype),
                "attn": _attn_params(cfg, jax.random.fold_in(k, 4), dtype),
                "mlp2": _mlp_params(cfg, jax.random.fold_in(k, 5), dtype),
            }, kg[2], G)
        params["tail"] = _stack_init(
            lambda k: {"rec": _rglru_block_params(cfg, k, dtype),
                       "mlp": _mlp_params(cfg, jax.random.fold_in(k, 1), dtype)},
            kg[3], tail) if tail else {}
    elif fam == "xlstm":
        every = cfg.xlstm.slstm_every
        assert cfg.n_layers % every == 0
        n_m = every - 1
        params["blocks"] = _stack_init(
            lambda k: {
                "m": _stack_init(lambda kk: _mlstm_params(cfg, kk, dtype),
                                 k, n_m),
                "s": _slstm_params(cfg, jax.random.fold_in(k, 1), dtype),
            }, kg[2], cfg.n_layers // every)
    else:
        raise ValueError(fam)
    return params


# ===========================================================================
# Embedding / heads (modality frontends are stubs per the assignment)
# ===========================================================================

def embed_inputs(cfg: ArchConfig, params: Params, batch: Dict[str, Any],
                 rc: RuntimeConfig):
    """Returns h (B, S, D)."""
    emb = params["embed"]
    if cfg.family == "audio":
        toks = batch["tokens"]                        # (B, S, K)
        K, V = cfg.n_codebooks, cfg.vocab
        offs = jnp.arange(K, dtype=toks.dtype) * V
        h = jnp.sum(jnp.take(emb, toks + offs, axis=0), axis=2)
    elif cfg.family == "vlm" and "vis_embeds" in batch:
        te = jnp.take(emb, batch["tokens"], axis=0)   # (B, S_text, D)
        h = jnp.concatenate([batch["vis_embeds"].astype(te.dtype), te], axis=1)
    else:
        h = jnp.take(emb, batch["tokens"], axis=0)
    h = h.astype(rc.compute_dtype)
    if cfg.family == "hybrid":                        # gemma-style scaling
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return h


def lm_logits(cfg: ArchConfig, params: Params, h, rc: RuntimeConfig):
    h = apply_norm(cfg.norm, h, params["out_norm"])
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("...d,dv->...v", h, w.astype(h.dtype))
    if cfg.family == "audio":
        logits = logits.reshape(logits.shape[:-1] + (cfg.n_codebooks, cfg.vocab))
    return logits


# ===========================================================================
# Block bodies (single layer, full-sequence mode)
# ===========================================================================

def _attn_full(cfg, rc, h, p, positions, *, window=None, make_cache=False):
    x = apply_norm(cfg.norm, h, p["ln"])
    q, k, v = attn_lib.gqa_project_qkv(x, p, cfg, positions)
    # head padding: archs whose Q-head count does not divide the TP axis
    # (40/56/24/10 on a 16-way axis) would otherwise replicate attention
    # across 'model'.  Padding is PER KV GROUP (so GQA head->kv alignment
    # is preserved) and exact: padded heads are sliced off before the
    # output projection, costing +pad/H extra FLOPs.
    g_orig = g_pad = 0
    if rc.pad_attn_heads > 1 and q.shape[2] % rc.pad_attn_heads != 0:
        B_, S_, Hq_, dh_ = q.shape
        Hkv_ = cfg.n_kv_heads
        g_orig = Hq_ // Hkv_
        g_pad = g_orig
        while (Hkv_ * g_pad) % rc.pad_attn_heads != 0:
            g_pad += 1
        qg = q.reshape(B_, S_, Hkv_, g_orig, dh_)
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, g_pad - g_orig),
                          (0, 0)))
        q = qg.reshape(B_, S_, Hkv_ * g_pad, dh_)
    q = shard_activation(q, "attn_in", rc)
    k = shard_activation(k, "attn_in", rc)
    v = shard_activation(v, "attn_in", rc)
    if window is not None:
        o = attn_lib.local_attention(q, k, v, window=window,
                                     block_q=rc.flash_block_q,
                                     unroll=rc.cost_probe)
    else:
        o = attn_lib.flash_attention(q, k, v, causal=True,
                                     block_q=rc.flash_block_q,
                                     block_kv=rc.flash_block_kv,
                                     unroll=rc.cost_probe)
    if g_pad and g_pad != g_orig:          # drop padded heads (exact)
        B_, S_ = o.shape[:2]
        o = o.reshape(B_, S_, cfg.n_kv_heads, g_pad, -1)[:, :, :, :g_orig]
        o = o.reshape(B_, S_, cfg.n_heads, -1)
    o = o.reshape(o.shape[:2] + (-1,))
    o = shard_activation(o, "attn_out", rc)
    delta = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(o.dtype))
    h = h + shard_activation(delta, "residual", rc)
    cache = None
    if make_cache:
        if window is not None:
            S = k.shape[1]
            W = window
            if S >= W:
                kc = jnp.roll(k[:, -W:], S % W, axis=1)
                vc = jnp.roll(v[:, -W:], S % W, axis=1)
            else:
                pad = ((0, 0), (0, W - S), (0, 0), (0, 0))
                kc, vc = jnp.pad(k, pad), jnp.pad(v, pad)
            cache = (kc, vc)
        else:
            cache = (k, v)
    return h, cache


def _mla_full(cfg, rc, h, p, positions, *, make_cache=False):
    x = apply_norm(cfg.norm, h, p["ln"])
    q, k, v, c, kr = attn_lib.mla_prefill_qkv(x, p, cfg, positions)
    q = shard_activation(q, "attn_in", rc)
    k = shard_activation(k, "attn_in", rc)
    v = shard_activation(v, "attn_in", rc)
    o = attn_lib.flash_attention(q, k, v, causal=True,
                                 block_q=rc.flash_block_q,
                                 block_kv=rc.flash_block_kv,
                                 unroll=rc.cost_probe)
    m = cfg.mla
    o = jnp.einsum("bshv,hvd->bsd", o,
                   p["w_o"].astype(o.dtype).reshape(
                       cfg.n_heads, m.v_head_dim, -1))
    h = h + shard_activation(o, "residual", rc)
    return h, ((c, kr) if make_cache else None)


def _mlp_full(cfg, rc, h, p, *, act="swiglu"):
    x = apply_norm(cfg.norm, h, p["ln"])
    y = ffn_lib.swiglu(x, p) if act == "swiglu" else ffn_lib.geglu(x, p)
    return h + y


MOE_METRIC_KEYS = ("moe_aux", "moe_z", "moe_dropped")


def _moe_nometrics(cfg, h, p):
    x = apply_norm(cfg.norm, h, p["ln"])
    y, _ = ffn_lib.moe_apply(x, p, cfg)
    return h + y


def _moe_full(cfg, rc, h, p, aux):
    """Returns (h, aux) with per-layer MoE metrics accumulated into ``aux``."""
    x = apply_norm(cfg.norm, h, p["ln"])
    y, metrics = ffn_lib.moe_apply(x, p, cfg)
    aux = {k: aux[k] + metrics[k] for k in MOE_METRIC_KEYS}
    return h + shard_activation(y, "residual", rc), aux


def _rglru_full(cfg, rc, h, p, *, h0=None, conv0=None, make_cache=False):
    r = cfg.rglru
    x = apply_norm(cfg.norm, h, p["ln"])
    y = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["w_y"].astype(x.dtype)))
    xb = jnp.einsum("bsd,dr->bsr", x, p["w_xb"].astype(x.dtype))
    xb, conv_state = rec_lib.causal_conv1d(xb, p["conv_w"], p["conv_b"],
                                           state=conv0)
    rec, h_last = rec_lib.rglru_scan(xb, p, cfg.n_heads, h0=h0)
    out = jnp.einsum("bsr,rd->bsd", rec * y, p["w_out"].astype(x.dtype))
    cache = (h_last, conv_state) if make_cache else None
    return h + shard_activation(out, "residual", rc), cache


def _mlstm_qkv(cfg, p, x):
    """x (B,S,D) -> q,k,v (B,H,S,dh), log_i/log_f (B,H,S), gate z, conv_state."""
    H = cfg.n_heads
    up = jnp.einsum("bsd,de->bse", x, p["w_up"].astype(x.dtype))
    u, z = jnp.split(up, 2, axis=-1)
    uc, conv_state = rec_lib.causal_conv1d(u, p["conv_w"], p["conv_b"])
    uc = jax.nn.silu(uc)
    inner = u.shape[-1]
    dh = inner // H
    q = jnp.einsum("bse,ef->bsf", uc, p["w_q"].astype(x.dtype))
    k = jnp.einsum("bse,ef->bsf", uc, p["w_k"].astype(x.dtype))
    gates = jnp.einsum("bse,eg->bsg", uc, p["w_if"].astype(x.dtype)) \
        + p["b_if"].astype(x.dtype)
    log_i, f_pre = jnp.split(gates.astype(jnp.float32), 2, axis=-1)
    log_f = jax.nn.log_sigmoid(f_pre)
    tr = lambda t: t.reshape(t.shape[0], t.shape[1], H, dh).transpose(0, 2, 1, 3)
    v = tr(u)
    return tr(q), tr(k), v, log_i.transpose(0, 2, 1), \
        log_f.transpose(0, 2, 1), z, conv_state


def _mlstm_full(cfg, rc, h, p, *, state=None, make_cache=False):
    x = apply_norm(cfg.norm, h, p["ln"])
    q, k, v, log_i, log_f, z, conv_state = _mlstm_qkv(cfg, p, x)
    S = q.shape[2]
    chunk = cfg.xlstm.chunk
    if S > chunk and S % chunk == 0:
        hh, new_state = rec_lib.mlstm_chunkwise(q, k, v, log_i, log_f,
                                                chunk=chunk, state=state,
                                                unroll=rc.cost_probe)
    else:
        hh = rec_lib.mlstm_parallel(q, k, v, log_i, log_f)
        new_state = rec_lib.mlstm_final_state(q, k, v, log_i, log_f, state) \
            if make_cache else None
    B, H, _, dh = hh.shape
    hh = hh.transpose(0, 2, 1, 3).reshape(B, S, H * dh)
    hh = rec_lib.groupnorm_heads(hh, p["gn"], H)
    out = jnp.einsum("bse,ed->bsd", hh * jax.nn.silu(z),
                     p["w_down"].astype(h.dtype))
    out = shard_activation(out, "residual", rc)
    return h + out, ((new_state, conv_state) if make_cache else None)


def _slstm_full(cfg, rc, h, p, *, state=None, make_cache=False):
    x = apply_norm(cfg.norm, h, p["ln"])
    y, new_state = rec_lib.slstm_seq(x, p, cfg.n_heads, state=state)
    y = rec_lib.groupnorm_heads(y, p["gn"], cfg.n_heads)
    h = h + y
    h = h + ffn_lib.geglu(apply_norm(cfg.norm, h, p["ln_mlp"]), p["mlp"])
    return h, (new_state if make_cache else None)


# ===========================================================================
# Full-sequence forward (train / prefill)
# ===========================================================================

def _scan_blocks(cfg, rc, carry, params, body):
    """scan over stacked blocks with optional double-remat grouping.

    ``body(carry, layer_params) -> carry``.  The first carry leaf is the
    residual stream and gets a sharding constraint between layers.
    """
    blocks = params["blocks"]
    L = jax.tree_util.tree_leaves(blocks)[0].shape[0]

    def layer(c, p):
        c = body(c, p)
        if isinstance(c, tuple):
            c = (shard_activation(c[0], "residual", rc),) + c[1:]
        else:
            c = shard_activation(c, "residual", rc)
        return c, None

    G = rc.remat_groups
    if rc.remat_policy == "dots":
        layer = jax.checkpoint(
            layer,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    elif rc.remat_policy != "none":
        layer = jax.checkpoint(layer)   # per-layer full remat
    if G > 1 and L % G == 0:            # + double remat over layer groups
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((G, L // G) + a.shape[1:]), blocks)

        def group(c, gp):
            c, _ = jax.lax.scan(layer, c, gp, unroll=rc.cost_probe)
            return c, None

        group = jax.checkpoint(group)
        carry, _ = jax.lax.scan(group, carry, grouped, unroll=rc.cost_probe)
    else:
        carry, _ = jax.lax.scan(layer, carry, blocks, unroll=rc.cost_probe)
    return carry


def forward(cfg: ArchConfig, params: Params, batch: Dict[str, Any],
            rc: RuntimeConfig = DEFAULT_RC, return_hidden: bool = False):
    """Full-sequence forward -> logits (or pre-norm hidden). """
    h = embed_inputs(cfg, params, batch, rc)
    B, S = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h = shard_activation(h, "residual", rc)
    metrics: Dict[str, Any] = {}
    fam = cfg.family

    if fam in ("dense", "vlm", "audio"):
        def body(h, p):
            h, _ = _attn_full(cfg, rc, h, p["attn"], positions)
            return _mlp_full(cfg, rc, h, p["mlp"])
        h = _scan_blocks(cfg, rc, h, params, body)
    elif fam == "moe":
        def body(carry, p):
            h, aux = carry
            h, _ = _attn_full(cfg, rc, h, p["attn_a"], positions)
            h = _mlp_full(cfg, rc, h, p["mlp"])
            h, _ = _attn_full(cfg, rc, h, p["attn_b"], positions)
            h, aux = _moe_full(cfg, rc, h, p["moe"], aux)
            return (h, aux)
        aux0 = {k: jnp.zeros((), jnp.float32) for k in MOE_METRIC_KEYS}
        h, aux = _scan_blocks(cfg, rc, (h, aux0), params, body)
        metrics.update(aux)
    elif fam == "mla_moe":
        def body(carry, p):
            h, aux = carry
            h, _ = _mla_full(cfg, rc, h, p["attn"], positions)
            h, aux = _moe_full(cfg, rc, h, p["moe"], aux)
            return (h, aux)
        aux0 = {k: jnp.zeros((), jnp.float32) for k in MOE_METRIC_KEYS}
        h, aux = _scan_blocks(cfg, rc, (h, aux0), params, body)
        metrics.update(aux)
    elif fam == "hybrid":
        w = cfg.rglru.window

        def body(h, p):
            h, _ = _rglru_full(cfg, rc, h, p["rec0"])
            h = _mlp_full(cfg, rc, h, p["mlp0"], act="gelu")
            h, _ = _rglru_full(cfg, rc, h, p["rec1"])
            h = _mlp_full(cfg, rc, h, p["mlp1"], act="gelu")
            h, _ = _attn_full(cfg, rc, h, p["attn"], positions, window=w)
            return _mlp_full(cfg, rc, h, p["mlp2"], act="gelu")
        h = _scan_blocks(cfg, rc, h, params, body)
        if params.get("tail"):
            tail = params["tail"]
            n_tail = jax.tree_util.tree_leaves(tail)[0].shape[0]
            for i in range(n_tail):
                tp = jax.tree_util.tree_map(lambda a: a[i], tail)
                h, _ = _rglru_full(cfg, rc, h, tp["rec"])
                h = _mlp_full(cfg, rc, h, tp["mlp"], act="gelu")
    elif fam == "xlstm":
        n_m = cfg.xlstm.slstm_every - 1

        def body(h, p):
            for i in range(n_m):
                mp = jax.tree_util.tree_map(lambda a: a[i], p["m"])
                h, _ = _mlstm_full(cfg, rc, h, mp)
            h, _ = _slstm_full(cfg, rc, h, p["s"])
            return h
        h = _scan_blocks(cfg, rc, h, params, body)
    else:
        raise ValueError(fam)

    if return_hidden:
        return h, metrics
    logits = lm_logits(cfg, params, h, rc)
    logits = shard_activation(logits, "logits", rc)
    return logits, metrics


# ===========================================================================
# Serving: cache init / prefill / decode
# ===========================================================================

def _kv_shape(cfg, B, S):
    return (B, S, cfg.n_kv_heads, cfg.dh)


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int,
               rc: RuntimeConfig = DEFAULT_RC) -> Dict[str, Any]:
    """Zero-initialised decode cache (pytree of arrays + 'pos' scalar)."""
    B, dt = batch_size, rc.compute_dtype
    fam = cfg.family
    L = cfg.n_layers
    z = jnp.zeros
    if fam in ("dense", "vlm", "audio"):
        cache = {"ck": z((L,) + _kv_shape(cfg, B, max_len), dt),
                 "cv": z((L,) + _kv_shape(cfg, B, max_len), dt)}
    elif fam == "moe":
        G = L // cfg.moe.moe_every
        kv = (G,) + _kv_shape(cfg, B, max_len)
        cache = {"cka": z(kv, dt), "cva": z(kv, dt),
                 "ckb": z(kv, dt), "cvb": z(kv, dt)}
    elif fam == "mla_moe":
        m = cfg.mla
        cache = {"cc": z((L, B, max_len, m.kv_lora_rank), dt),
                 "ckr": z((L, B, max_len, m.qk_rope_dim), dt)}
    elif fam == "hybrid":
        G, tail = _hybrid_group_counts(cfg)
        r = cfg.rglru
        W = min(r.window, max_len)
        cache = {
            "rh0": z((G, B, r.d_rnn), jnp.float32),
            "rconv0": z((G, B, r.conv_width - 1, r.d_rnn), dt),
            "rh1": z((G, B, r.d_rnn), jnp.float32),
            "rconv1": z((G, B, r.conv_width - 1, r.d_rnn), dt),
            "wk": z((G, B, W, cfg.n_kv_heads, cfg.dh), dt),
            "wv": z((G, B, W, cfg.n_kv_heads, cfg.dh), dt),
        }
        if tail:
            cache["tail"] = {
                "rh": z((tail, B, r.d_rnn), jnp.float32),
                "rconv": z((tail, B, r.conv_width - 1, r.d_rnn), dt),
            }
    elif fam == "xlstm":
        x = cfg.xlstm
        G = L // x.slstm_every
        n_m = x.slstm_every - 1
        inner = int(x.mlstm_proj_factor * cfg.d_model)
        dh = inner // cfg.n_heads
        H, D = cfg.n_heads, cfg.d_model
        cache = {
            "mC": z((G, n_m, B, H, dh, dh), jnp.float32),
            "mn": z((G, n_m, B, H, dh), jnp.float32),
            "mm": jnp.full((G, n_m, B, H), -1e30, jnp.float32),
            "mconv": z((G, n_m, B, 3, inner), dt),
            "sc": z((G, B, D), jnp.float32),
            "sn": z((G, B, D), jnp.float32),
            "sh": z((G, B, D), jnp.float32),
            "sm": jnp.full((G, B, D), -10.0, jnp.float32),
        }
    else:
        raise ValueError(fam)
    cache["pos"] = jnp.zeros((), jnp.int32)
    return cache


def prefill(cfg: ArchConfig, params: Params, batch: Dict[str, Any],
            rc: RuntimeConfig = DEFAULT_RC, max_len: Optional[int] = None):
    """Full-sequence pass that also builds the decode cache.

    Returns (last_logits, cache).  Caches are padded to ``max_len`` if given.
    """
    h = embed_inputs(cfg, params, batch, rc)
    B, S = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h = shard_activation(h, "residual", rc)
    fam = cfg.family
    blocks = params["blocks"]
    metrics: Dict[str, Any] = {}

    if fam in ("dense", "vlm", "audio"):
        def body(h, p):
            h, (k, v) = _attn_full(cfg, rc, h, p["attn"], positions,
                                   make_cache=True)
            h = _mlp_full(cfg, rc, h, p["mlp"])
            return shard_activation(h, "residual", rc), {"ck": k, "cv": v}
        h, cache = jax.lax.scan(body, h, blocks, unroll=rc.cost_probe)
    elif fam == "moe":
        def body(h, p):
            h, (ka, va) = _attn_full(cfg, rc, h, p["attn_a"], positions,
                                     make_cache=True)
            h = _mlp_full(cfg, rc, h, p["mlp"])
            h, (kb, vb) = _attn_full(cfg, rc, h, p["attn_b"], positions,
                                     make_cache=True)
            h = _moe_nometrics(cfg, h, p["moe"])
            return shard_activation(h, "residual", rc), \
                {"cka": ka, "cva": va, "ckb": kb, "cvb": vb}
        h, cache = jax.lax.scan(body, h, blocks, unroll=rc.cost_probe)
    elif fam == "mla_moe":
        def body(h, p):
            h, (c, kr) = _mla_full(cfg, rc, h, p["attn"], positions,
                                   make_cache=True)
            h = _moe_nometrics(cfg, h, p["moe"])
            return shard_activation(h, "residual", rc), {"cc": c, "ckr": kr}
        h, cache = jax.lax.scan(body, h, blocks, unroll=rc.cost_probe)
    elif fam == "hybrid":
        w = cfg.rglru.window

        def body(h, p):
            h, (h0, cv0) = _rglru_full(cfg, rc, h, p["rec0"], make_cache=True)
            h = _mlp_full(cfg, rc, h, p["mlp0"], act="gelu")
            h, (h1, cv1) = _rglru_full(cfg, rc, h, p["rec1"], make_cache=True)
            h = _mlp_full(cfg, rc, h, p["mlp1"], act="gelu")
            h, (kc, vc) = _attn_full(cfg, rc, h, p["attn"], positions,
                                     window=w, make_cache=True)
            h = _mlp_full(cfg, rc, h, p["mlp2"], act="gelu")
            return shard_activation(h, "residual", rc), \
                {"rh0": h0, "rconv0": cv0, "rh1": h1, "rconv1": cv1,
                 "wk": kc, "wv": vc}
        h, cache = jax.lax.scan(body, h, blocks, unroll=rc.cost_probe)
        if params.get("tail"):
            def tbody(h, p):
                h, (hs, cv) = _rglru_full(cfg, rc, h, p["rec"], make_cache=True)
                h = _mlp_full(cfg, rc, h, p["mlp"], act="gelu")
                return h, {"rh": hs, "rconv": cv}
            h, tcache = jax.lax.scan(tbody, h, params["tail"], unroll=rc.cost_probe)
            cache["tail"] = tcache
    elif fam == "xlstm":
        n_m = cfg.xlstm.slstm_every - 1

        def body(h, p):
            mC, mn, mm, mcv = [], [], [], []
            for i in range(n_m):
                mp = jax.tree_util.tree_map(lambda a: a[i], p["m"])
                h, st = _mlstm_full(cfg, rc, h, mp, make_cache=True)
                (C, n, m), conv = st
                mC.append(C); mn.append(n); mm.append(m); mcv.append(conv)
            h, s_st = _slstm_full(cfg, rc, h, p["s"], make_cache=True)
            sc, sn, sh, sm = s_st
            return h, {"mC": jnp.stack(mC), "mn": jnp.stack(mn),
                       "mm": jnp.stack(mm), "mconv": jnp.stack(mcv),
                       "sc": sc, "sn": sn, "sh": sh, "sm": sm}
        h, cache = jax.lax.scan(body, h, blocks, unroll=rc.cost_probe)
    else:
        raise ValueError(fam)

    if max_len is not None and max_len > S and fam in (
            "dense", "vlm", "audio", "moe", "mla_moe"):
        pad = max_len - S
        cache = jax.tree_util.tree_map(
            lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad))
                              + ((0, 0),) * (a.ndim - 3)), cache)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    logits = lm_logits(cfg, params, h[:, -1:], rc)[:, 0]
    return logits, cache


# --- per-family decode bodies ----------------------------------------------

def _attn_decode(cfg, rc, h, p, ck, cv, pos, positions, window=None):
    x = apply_norm(cfg.norm, h, p["ln"])
    q, k, v = attn_lib.gqa_project_qkv(x, p, cfg, positions)
    dus = rc.dus_cache_update
    if window is not None:
        W = ck.shape[1]
        slot = pos % W
        ck = attn_lib.cache_update(ck, k[:, 0], slot, use_dus=dus)
        cv = attn_lib.cache_update(cv, v[:, 0], slot, use_dus=dus)
        pos_eff = jnp.minimum(pos, W - 1)
    else:
        ck = attn_lib.cache_update(ck, k[:, 0], pos, use_dus=dus)
        cv = attn_lib.cache_update(cv, v[:, 0], pos, use_dus=dus)
        pos_eff = pos
    o = attn_lib.decode_attention(q[:, 0], ck, cv, pos_eff)
    o = o.reshape(o.shape[0], 1, -1)
    h = h + jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(o.dtype))
    return h, ck, cv


def _rglru_decode(cfg, rc, h, p, rh, rconv):
    x = apply_norm(cfg.norm, h, p["ln"])
    y = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["w_y"].astype(x.dtype)))
    xb = jnp.einsum("bsd,dr->bsr", x, p["w_xb"].astype(x.dtype))
    xb, rconv = rec_lib.causal_conv1d(xb, p["conv_w"], p["conv_b"], state=rconv)
    rec, rh = rec_lib.rglru_step(xb[:, 0], p, cfg.n_heads, rh)
    out = jnp.einsum("br,rd->bd", rec * y[:, 0], p["w_out"].astype(x.dtype))
    return h + out[:, None], rh, rconv


def _mlstm_decode(cfg, rc, h, p, state, conv):
    x = apply_norm(cfg.norm, h, p["ln"])
    H = cfg.n_heads
    up = jnp.einsum("bsd,de->bse", x, p["w_up"].astype(x.dtype))
    u, z = jnp.split(up, 2, axis=-1)
    uc, conv = rec_lib.causal_conv1d(u, p["conv_w"], p["conv_b"], state=conv)
    uc = jax.nn.silu(uc)
    inner = u.shape[-1]
    dh = inner // H
    q = jnp.einsum("bse,ef->bsf", uc, p["w_q"].astype(x.dtype))[:, 0]
    k = jnp.einsum("bse,ef->bsf", uc, p["w_k"].astype(x.dtype))[:, 0]
    gates = (jnp.einsum("bse,eg->bsg", uc, p["w_if"].astype(x.dtype))
             + p["b_if"].astype(x.dtype))[:, 0].astype(jnp.float32)
    log_i, f_pre = jnp.split(gates, 2, axis=-1)
    log_f = jax.nn.log_sigmoid(f_pre)
    rs = lambda t: t.reshape(-1, H, dh)
    hh, state = rec_lib.mlstm_step(rs(q), rs(k), rs(u[:, 0]), log_i, log_f,
                                   state)
    hh = rec_lib.groupnorm_heads(hh.reshape(-1, inner), p["gn"], H)
    out = jnp.einsum("be,ed->bd", hh * jax.nn.silu(z[:, 0]),
                     p["w_down"].astype(h.dtype))
    return h + out[:, None], state, conv


def _scan_layers_carry(body_kv, h, blocks, cache, keys, rc):
    """Layer scan with the decode cache as a *carry* (not xs/ys).

    xs/ys buffers cannot alias in XLA while-loops, which would double the
    multi-GB KV cache; carries alias in place, and the per-layer index /
    update on the (unsharded) leading layer dim partitions cleanly.
    body_kv(h, p, layer_cache) -> (h, new_layer_cache).
    """
    sub = {k: cache[k] for k in keys}

    def body(carry, p):
        h, caches, i = carry
        layer = {k: jax.lax.dynamic_index_in_dim(v, i, axis=0, keepdims=False)
                 for k, v in caches.items()}
        h, newl = body_kv(h, p, layer)
        caches = {k: jax.lax.dynamic_update_index_in_dim(
            caches[k], newl[k].astype(caches[k].dtype), i, axis=0)
            for k in caches}
        return (h, caches, i + 1), None

    (h, caches, _), _ = jax.lax.scan(
        body, (h, sub, jnp.zeros((), jnp.int32)), blocks,
        unroll=rc.cost_probe)
    return h, caches


def decode_step(cfg: ArchConfig, params: Params, tokens, cache,
                rc: RuntimeConfig = DEFAULT_RC):
    """One decode step.  tokens (B,) int32 (audio: (B, K)).

    Returns (logits (B, V) or (B, K, V), new_cache)."""
    pos = cache["pos"]
    B = tokens.shape[0]
    batch = {"tokens": tokens[:, None] if tokens.ndim == 1 else tokens[:, None]}
    h = embed_inputs(cfg, params, batch, rc)          # (B, 1, D)
    positions = jnp.full((B, 1), pos)
    fam = cfg.family
    blocks = params["blocks"]
    new_cache = {}

    if fam in ("dense", "vlm", "audio"):
        def body(h, p, c):
            h, ck, cv = _attn_decode(cfg, rc, h, p["attn"], c["ck"], c["cv"],
                                     pos, positions)
            h = _mlp_full(cfg, rc, h, p["mlp"])
            return h, {"ck": ck, "cv": cv}
        h, kv = _scan_layers_carry(body, h, blocks, cache, ("ck", "cv"), rc)
        new_cache.update(kv)
    elif fam == "moe":
        def body(h, p, c):
            h, cka, cva = _attn_decode(cfg, rc, h, p["attn_a"], c["cka"],
                                       c["cva"], pos, positions)
            h = _mlp_full(cfg, rc, h, p["mlp"])
            h, ckb, cvb = _attn_decode(cfg, rc, h, p["attn_b"], c["ckb"],
                                       c["cvb"], pos, positions)
            x = apply_norm(cfg.norm, h, p["moe"]["ln"])
            y, _ = ffn_lib.moe_apply(x.reshape(1, B, -1), p["moe"], cfg)
            h = h + y.reshape(B, 1, -1)
            return h, {"cka": cka, "cva": cva, "ckb": ckb, "cvb": cvb}
        h, kv = _scan_layers_carry(body, h, blocks, cache,
                                   ("cka", "cva", "ckb", "cvb"), rc)
        new_cache.update(kv)
    elif fam == "mla_moe":
        def body(h, p, c):
            x = apply_norm(cfg.norm, h, p["attn"]["ln"])
            out, cc, ckr = attn_lib.mla_decode(x[:, 0], p["attn"], cfg,
                                               c["cc"], c["ckr"], pos)
            h = h + out[:, None]
            x = apply_norm(cfg.norm, h, p["moe"]["ln"])
            y, _ = ffn_lib.moe_apply(x.reshape(1, B, -1), p["moe"], cfg)
            h = h + y.reshape(B, 1, -1)
            return h, {"cc": cc, "ckr": ckr}
        h, kv = _scan_layers_carry(body, h, blocks, cache, ("cc", "ckr"), rc)
        new_cache.update(kv)
    elif fam == "hybrid":
        w = cfg.rglru.window

        def body(h, p, c):
            h, rh0, rcv0 = _rglru_decode(cfg, rc, h, p["rec0"], c["rh0"],
                                         c["rconv0"])
            h = _mlp_full(cfg, rc, h, p["mlp0"], act="gelu")
            h, rh1, rcv1 = _rglru_decode(cfg, rc, h, p["rec1"], c["rh1"],
                                         c["rconv1"])
            h = _mlp_full(cfg, rc, h, p["mlp1"], act="gelu")
            h, wk, wv = _attn_decode(cfg, rc, h, p["attn"], c["wk"], c["wv"],
                                     pos, positions, window=w)
            h = _mlp_full(cfg, rc, h, p["mlp2"], act="gelu")
            return h, {"rh0": rh0, "rconv0": rcv0, "rh1": rh1, "rconv1": rcv1,
                       "wk": wk, "wv": wv}
        h, kv = _scan_layers_carry(body, h, blocks, cache,
                                   ("rh0", "rconv0", "rh1", "rconv1",
                                    "wk", "wv"), rc)
        new_cache.update(kv)
        if params.get("tail"):
            def tbody(h, p, c):
                h, rh, rcv = _rglru_decode(cfg, rc, h, p["rec"], c["rh"],
                                           c["rconv"])
                h = _mlp_full(cfg, rc, h, p["mlp"], act="gelu")
                return h, {"rh": rh, "rconv": rcv}
            h, tkv = _scan_layers_carry(tbody, h, params["tail"],
                                        cache["tail"], ("rh", "rconv"), rc)
            new_cache["tail"] = tkv
    elif fam == "xlstm":
        n_m = cfg.xlstm.slstm_every - 1

        def body(h, p, c):
            mC, mn, mm, mcv = [], [], [], []
            for i in range(n_m):
                mp = jax.tree_util.tree_map(lambda a: a[i], p["m"])
                st = (c["mC"][i], c["mn"][i], c["mm"][i])
                h, st, cv = _mlstm_decode(cfg, rc, h, mp, st, c["mconv"][i])
                mC.append(st[0]); mn.append(st[1]); mm.append(st[2])
                mcv.append(cv)
            x = apply_norm(cfg.norm, h, p["s"]["ln"])
            y, s_st = rec_lib.slstm_seq(x, p["s"], cfg.n_heads,
                                        state=(c["sc"], c["sn"], c["sh"],
                                               c["sm"]))
            y = rec_lib.groupnorm_heads(y, p["s"]["gn"], cfg.n_heads)
            h = h + y
            h = h + ffn_lib.geglu(
                apply_norm(cfg.norm, h, p["s"]["ln_mlp"]), p["s"]["mlp"])
            return h, {"mC": jnp.stack(mC), "mn": jnp.stack(mn),
                       "mm": jnp.stack(mm), "mconv": jnp.stack(mcv),
                       "sc": s_st[0], "sn": s_st[1], "sh": s_st[2],
                       "sm": s_st[3]}
        h, kv = _scan_layers_carry(body, h, blocks, cache,
                                   ("mC", "mn", "mm", "mconv",
                                    "sc", "sn", "sh", "sm"), rc)
        new_cache.update(kv)
    else:
        raise ValueError(fam)

    new_cache["pos"] = pos + 1
    logits = lm_logits(cfg, params, h, rc)[:, 0]
    return logits, new_cache


LOSS_CHUNK = 512


def chunked_xent(cfg: ArchConfig, params: Params, h, labels,
                 rc: RuntimeConfig):
    """Cross-entropy without materializing full-sequence fp32 logits.

    Scans S in chunks; each chunk projects h -> logits and reduces to sums;
    jax.checkpoint makes the backward recompute chunk logits instead of
    saving them (decisive at 150k-200k vocab: full fp32 logits are GBs).
    """
    from repro.models.common import softmax_xent_sums
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    B, S = h.shape[0], h.shape[1]
    chunk = LOSS_CHUNK if (S % LOSS_CHUNK == 0) else S
    nc = S // chunk

    def body(carry, xs):
        hc, lc = xs
        logits = jnp.einsum("bsd,dv->bsv", hc, w.astype(hc.dtype))
        if cfg.family == "audio":
            logits = logits.reshape(logits.shape[:-1]
                                    + (cfg.n_codebooks, cfg.vocab))
        logits = shard_activation(logits, "logits", rc)
        t, n_, nv = softmax_xent_sums(logits, lc, z_loss_coef=rc.z_loss)
        return (carry[0] + t, carry[1] + n_, carry[2] + nv), None

    hcs = jnp.moveaxis(h.reshape(B, nc, chunk, -1), 1, 0)
    lcs = jnp.moveaxis(labels.reshape((B, nc, chunk) + labels.shape[2:]), 1, 0)
    z = jnp.zeros((), jnp.float32)
    (tot, nll, n), _ = jax.lax.scan(jax.checkpoint(body), (z, z, z),
                                    (hcs, lcs), unroll=rc.cost_probe)
    n = jnp.maximum(n, 1.0)
    return tot / n, {"nll": nll / n, "ntokens": n}


def loss_fn(cfg: ArchConfig, params: Params, batch: Dict[str, Any],
            rc: RuntimeConfig = DEFAULT_RC):
    h, metrics = forward(cfg, params, batch, rc, return_hidden=True)
    labels = batch["labels"]
    if cfg.family == "vlm" and "vis_embeds" in batch:
        # patch positions carry no labels
        nf = batch["vis_embeds"].shape[1]
        pad = jnp.full(labels.shape[:1] + (nf,), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    h = apply_norm(cfg.norm, h, params["out_norm"])
    loss, lm_metrics = chunked_xent(cfg, params, h, labels, rc)
    metrics.update(lm_metrics)
    for k in ("moe_aux", "moe_z"):
        if k in metrics:
            loss = loss + metrics[k]
    metrics["loss"] = loss
    return loss, metrics
