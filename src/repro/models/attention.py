"""Attention variants: GQA (flash-style blocked), local/windowed, decode,
and MLA (DeepSeek-V2 multi-head latent attention, with weight absorption on
the decode path so the cache stays compressed).

All functions are pure jnp/lax — the Pallas kernels in ``repro.kernels`` are
drop-in replacements for the hot spots (see ops.py); these serve as oracles
and as the portable path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, rope_cos_sin

NEG_INF = -1e30


def _split_heads(x, n_heads, dh):
    return x.reshape(x.shape[:-1] + (n_heads, dh))


# ---------------------------------------------------------------------------
# Blocked causal attention (flash-style online softmax, pure lax.scan)
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True, q_offset=0,
                    block_q: int = 512, block_kv: int = 512, softcap=None,
                    unroll: bool = False):
    """q (B,Sq,Hq,Dh), k/v (B,Skv,Hkv,Dh) -> (B,Sq,Hq,Dh).

    Blocked online-softmax; GQA via head grouping.  KV blocks are scanned with
    masking (exact numerics; causal skipping is done in the Pallas kernel).
    ``q_offset`` is the absolute position of q[0] (for chunked prefill).
    """
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    scale = Dh ** -0.5

    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    nq, nkv = Sq // bq, Skv // bkv
    assert Sq % bq == 0 and Skv % bkv == 0, (Sq, bq, Skv, bkv)

    qb = q.reshape(B, nq, bq, Hq, Dh)
    kb = k.reshape(B, nkv, bkv, Hkv, Dh)
    vb = v.reshape(B, nkv, bkv, Hkv, Dv)

    def q_block(qi, i, kb, vb):
        # qi: (B, bq, Hq, Dh).  KV heads are repeated to Hq per block (tiny)
        # so GQA needs no (Hkv, G) reshape and head sharding stays clean.
        q_pos = q_offset + i * bq + jnp.arange(bq)

        def kv_step(carry, j_kj_vj):
            m, l, acc = carry
            j, kj, vj = j_kj_vj
            if G > 1:
                kj = jnp.repeat(kj, G, axis=2)
                vj = jnp.repeat(vj, G, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            if causal:
                k_pos = j * bkv + jnp.arange(bkv)
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # zero out masked entries: when an entire block is masked the
            # running max still sits at NEG_INF and exp(s - m) would be 1
            p = jnp.exp(s - m_new[..., None]) * (s > NEG_INF * 0.5)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vj.dtype), vj,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hq, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hq, bq), jnp.float32)
        a0 = jnp.zeros((B, Hq, bq, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nkv), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)),
            unroll=unroll)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (B, Hq, bq, Dv)

    # checkpoint each q block: its backward recomputes the KV scan instead of
    # saving O(S^2) score blocks (this is what keeps train-time attention
    # memory O(S * block) like a fused flash kernel)
    q_block_ckpt = jax.checkpoint(q_block)

    def outer(_, xs):
        i, qi = xs
        return None, q_block_ckpt(qi, i, kb, vb)

    _, outs = jax.lax.scan(outer, None,
                           (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)),
                           unroll=unroll)
    # (nq, B, Hq, bq, Dv) -> (B, nq, bq, Hq, Dv) -> (B, Sq, Hq, Dv)
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 3, 2, 4)
    return out.reshape(B, Sq, Hq, Dv).astype(q.dtype)


def local_attention(q, k, v, *, window: int, q_offset=0, block_q: int = 512,
                    unroll: bool = False):
    """Banded causal attention: each query attends the previous ``window``
    keys (inclusive of self).  Exact-FLOP banded gather — O(S * window).

    q (B,Sq,Hq,Dh), k/v (B,Skv,Hkv,Dh); requires Skv == q_offset + Sq
    (the usual prefill layout).
    """
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = Dh ** -0.5
    bq = min(block_q, Sq)
    nq = Sq // bq
    assert Sq % bq == 0
    span = window + bq  # kv span needed per q block
    # pad keys on the left so every block can take a fixed-size slice
    pad = window
    kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))

    qb = q.reshape(B, nq, bq, Hq, Dh)

    def q_block(qi, i, kp, vp):
        start = q_offset + i * bq  # absolute position of first query in block
        ks = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
        if G > 1:
            ks = jnp.repeat(ks, G, axis=2)
            vs = jnp.repeat(vs, G, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, ks,
                       preferred_element_type=jnp.float32) * scale
        # absolute positions: query t = start + qi_idx; key t' = start - window + k_idx
        qpos = jnp.arange(bq)[:, None]
        kpos = jnp.arange(span)[None, :] - window
        valid = (kpos <= qpos) & (kpos > qpos - window) \
            & (kpos + start >= 0)
        s = jnp.where(valid[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vs.dtype), vs,
                       preferred_element_type=jnp.float32)
        return o

    q_block_ckpt = jax.checkpoint(q_block)

    def outer(_, xs):
        i, qi = xs
        return None, q_block_ckpt(qi, i, kp, vp)

    _, outs = jax.lax.scan(outer, None,
                           (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)),
                           unroll=unroll)
    # (nq, B, Hq, bq, Dh) -> (B, Sq, Hq, Dh)
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 3, 2, 4)
    return out.reshape(B, Sq, Hq, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode (single new token against a KV cache)
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, pos):
    """q (B,Hq,Dh); k/v_cache (B,S,Hkv,Dh); pos () current position.

    Memory-bound; the softmax reductions partition over an S-sharded cache
    (flash-decoding emerges from GSPMD).  Positions > pos are masked.
    """
    B, Hq, Dh = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * (Dh ** -0.5)
    mask = jnp.arange(S)[None, None, None, :] <= pos
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Hq, Dh).astype(q.dtype)


def cache_update(cache, new, pos, use_dus: bool = False):
    """Write ``new`` (B, Hkv, Dh) into cache (B, S, Hkv, Dh) at ``pos``.

    Default: one-hot select — DUS on a sharded S dim makes GSPMD replicate
    the whole cache, while the select partitions cleanly (each shard
    touches only its S-slice) at the cost of a full cache read+write in
    the XLA byte model.  ``use_dus`` measures the alternative (SSPerf);
    the fused Pallas decode kernel removes the extra traffic on TPU.
    """
    if use_dus:
        return jax.lax.dynamic_update_slice_in_dim(
            cache, new[:, None].astype(cache.dtype), pos, axis=1)
    S = cache.shape[1]
    hit = (jnp.arange(S) == pos)[None, :, None, None]
    return jnp.where(hit, new[:, None].astype(cache.dtype), cache)


# ---------------------------------------------------------------------------
# GQA block-level ops
# ---------------------------------------------------------------------------

def gqa_project_qkv(x, p, cfg, positions):
    """x (B,S,D) -> q (B,S,Hq,Dh), k,v (B,S,Hkv,Dh), RoPE applied."""
    dh = cfg.dh
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = _split_heads(q, cfg.n_heads, dh)
    k = _split_heads(k, cfg.n_kv_heads, dh)
    v = _split_heads(v, cfg.n_kv_heads, dh)
    cos, sin = rope_cos_sin(positions, dh, cfg.rope_theta)
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_prefill_qkv(x, p, cfg, positions):
    """Returns q (B,S,H,dn+dr), decompressed k (B,S,H,dn+dr), v (B,S,H,dv),
    plus the compressed cache entries (c_kv, k_rope)."""
    m = cfg.mla
    H = cfg.n_heads
    ckv = jnp.einsum("bsd,dl->bsl", x, p["w_dkv"].astype(x.dtype))
    c, kr = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    from repro.models.common import rmsnorm
    c = rmsnorm(c, p["c_norm"])
    cos, sin = rope_cos_sin(positions, m.qk_rope_dim, cfg.rope_theta)
    kr = apply_rope(kr[:, :, None, :], cos[:, :, None, :], sin[:, :, None, :])[:, :, 0]
    q = jnp.einsum("bsd,dh->bsh", x, p["w_q"].astype(x.dtype))
    q = _split_heads(q, H, m.qk_nope_dim + m.qk_rope_dim)
    qn, qr = jnp.split(q, [m.qk_nope_dim], axis=-1)
    qr = apply_rope(qr, cos[:, :, None, :], sin[:, :, None, :])
    k_nope = jnp.einsum("bsl,lhn->bshn", c,
                        p["w_uk"].astype(x.dtype).reshape(
                            m.kv_lora_rank, H, m.qk_nope_dim))
    v = jnp.einsum("bsl,lhv->bshv", c,
                   p["w_uv"].astype(x.dtype).reshape(
                       m.kv_lora_rank, H, m.v_head_dim))
    q_full = jnp.concatenate([qn, qr], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None, :], qn.shape[:-1] + (m.qk_rope_dim,))],
        axis=-1)
    return q_full, k_full, v, c, kr


def mla_decode(x, p, cfg, c_cache, kr_cache, pos):
    """Weight-absorbed MLA decode: cache stays compressed.

    x (B,D); c_cache (B,S,lora); kr_cache (B,S,dr) -> out (B,D), new caches.
    """
    m = cfg.mla
    H = cfg.n_heads
    B = x.shape[0]
    ckv = jnp.einsum("bd,dl->bl", x, p["w_dkv"].astype(x.dtype))
    c, kr = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    from repro.models.common import rmsnorm
    c = rmsnorm(c, p["c_norm"])
    posv = jnp.full((B, 1), pos)
    cos, sin = rope_cos_sin(posv, m.qk_rope_dim, cfg.rope_theta)
    kr = apply_rope(kr[:, None, None, :], cos[:, :, None, :],
                    sin[:, :, None, :])[:, 0, 0]
    q = jnp.einsum("bd,dh->bh", x, p["w_q"].astype(x.dtype))
    q = q.reshape(B, H, m.qk_nope_dim + m.qk_rope_dim)
    qn, qr = jnp.split(q, [m.qk_nope_dim], axis=-1)
    qr = apply_rope(qr[:, None], cos[:, :, None, :], sin[:, :, None, :])[:, 0]
    # absorb W_uk into q:  scores_nope = (q_n W_uk^T) . c
    w_uk = p["w_uk"].astype(x.dtype).reshape(m.kv_lora_rank, H, m.qk_nope_dim)
    q_abs = jnp.einsum("bhn,lhn->bhl", qn, w_uk)
    S_c = c_cache.shape[1]
    hit = (jnp.arange(S_c) == pos)[None, :, None]
    c_cache = jnp.where(hit, c[:, None].astype(c_cache.dtype), c_cache)
    kr_cache = jnp.where(hit, kr[:, None].astype(kr_cache.dtype), kr_cache)
    S = c_cache.shape[1]
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    s = (jnp.einsum("bhl,bsl->bhs", q_abs, c_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhr,bsr->bhs", qr, kr_cache,
                      preferred_element_type=jnp.float32)) * scale
    mask = jnp.arange(S)[None, None, :] <= pos
    s = jnp.where(mask, s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhs,bsl->bhl", pr.astype(c_cache.dtype), c_cache,
                     preferred_element_type=jnp.float32)
    w_uv = p["w_uv"].astype(x.dtype).reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bhl,lhv->bhv", o_c.astype(x.dtype), w_uv)
    out = jnp.einsum("bhv,hvd->bd", o,
                     p["w_o"].astype(x.dtype).reshape(H, m.v_head_dim, -1))
    return out, c_cache, kr_cache
