"""Causal flash attention as a Pallas TPU kernel.

Unlike the portable jnp implementation (which must *mask* future KV blocks,
spending the full S^2 FLOPs), the kernel **skips** fully-masked blocks via
``pl.when`` on the grid coordinates — halving compute for causal prefill —
and keeps (m, l, acc) in VMEM scratch across the (sequential, innermost) KV
grid dimension, so nothing score-sized ever reaches HBM.

Layout: q (B, Hq, S, dh), k/v (B, Hkv, S, dh); GQA via index-map folding
(query head h reads kv head h // G).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, bq: int, bkv: int, nkv: int, scale: float,
                  causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = (ki * bkv < (qi + 1) * bq) if causal else True

    @pl.when(live)
    def _compute():
        q = q_ref[0]                       # (bq, dh)
        k = k_ref[0]                       # (bkv, dh)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new) * (s > NEG_INF * 0.5)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(ki == nkv - 1)
    def _out():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[:, :1], 1e-30)).astype(o_ref.dtype)


def flash_attention_tpu(q, k, v, *, causal: bool = True, block_q: int = 512,
                        block_kv: int = 512, interpret: bool = False):
    """q (B,Hq,S,dh), k/v (B,Hkv,S,dh) -> (B,Hq,S,dh)."""
    B, Hq, S, dh = q.shape
    _, Hkv, Skv, _ = k.shape
    G = Hq // Hkv
    bq, bkv = min(block_q, S), min(block_kv, Skv)
    assert S % bq == 0 and Skv % bkv == 0
    nq, nkv = S // bq, Skv // bkv
    qf = q.reshape(B * Hq, S, dh)
    kf = k.reshape(B * Hkv, Skv, dh)
    vf = v.reshape(B * Hkv, Skv, dh)

    def kv_index(bh, qi, ki):
        b, hq = bh // Hq, bh % Hq
        return (b * Hkv + hq // G, ki, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bkv=bkv, nkv=nkv,
                          scale=dh ** -0.5, causal=causal),
        grid=(B * Hq, nq, nkv),
        in_specs=[pl.BlockSpec((1, bq, dh), lambda bh, qi, ki: (bh, qi, 0)),
                  pl.BlockSpec((1, bkv, dh), kv_index),
                  pl.BlockSpec((1, bkv, dh), kv_index)],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 128), jnp.float32),
                        pltpu.VMEM((bq, 128), jnp.float32),
                        pltpu.VMEM((bq, dh), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, S, dh)
