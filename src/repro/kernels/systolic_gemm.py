"""Systolic-array GEMM as a Pallas TPU kernel — the Gemmini^RT analogue.

The paper's accelerator streams (mvin, preload, compute, mvout) tiles
through a 16x16 systolic array with an explicitly managed scratchpad.  On
TPU the same insight maps to: MXU-aligned (128-multiple) VMEM tiles via
BlockSpec, a fp32 accumulator living in VMEM scratch across the K grid
dimension, and — the MESC-specific part — a **checkpointable** variant
whose accumulator can be written out mid-K ("step_wise_mvout of the
accumulator") and resumed later, giving instruction-level preemption
granularity *inside* a single GEMM:

    acc   = gemm_partial(A, B, acc, k0, k1)   # preempt here, acc -> DRAM
    out   = gemm_partial(A, B, acc, k1, nK)   # resume

Grid (M/bm, N/bn, K/bk), K innermost (sequential on TPU) so the scratch
accumulator carries across K steps.  The M/N grid dimensions are
declared ``parallel`` and K ``arbitrary`` (``dimension_semantics``), so
the Mosaic pipeliner can overlap the K-loop's HBM->VMEM tile fetches
with the MXU work of the previous step instead of serialising the whole
grid; a ``CostEstimate`` (exact GEMM flops/bytes) feeds the scheduler's
overlap heuristics.  Both knobs are compile-time only — interpret-mode
CI and the equivalence tests vs ``kernels/ref.py`` are unaffected.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 256

# M and N tiles are independent outputs; only K (the accumulation dim)
# must run in order on the TPU's sequential grid.
_DIM_SEMANTICS = ("parallel", "parallel", "arbitrary")


def _gemm_cost(M: int, K: int, N: int, a_dtype, b_dtype,
               out_dtype) -> pl.CostEstimate:
    """Exact cost of C[M,N] = A[M,K] @ B[K,N] for the pipeliner."""
    return pl.CostEstimate(
        flops=2 * M * N * K,
        transcendentals=0,
        bytes_accessed=(M * K * a_dtype.itemsize
                        + K * N * b_dtype.itemsize
                        + M * N * out_dtype.itemsize),
    )


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _out():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def systolic_gemm(a, b, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                  bk: int = DEFAULT_BK, out_dtype=None,
                  interpret: bool = False):
    """C = A @ B with VMEM-tiled accumulation.  A (M,K), B (K,N)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    nk = K // bk
    out_dtype = out_dtype or a.dtype
    out_sds = jax.ShapeDtypeStruct((M, N), out_dtype)
    return pl.pallas_call(
        functools.partial(_gemm_kernel, nk=nk),
        grid=(M // bm, N // bn, nk),
        in_specs=[pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
                  pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni))],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=out_sds,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=_DIM_SEMANTICS),
        cost_estimate=_gemm_cost(M, K, N, a.dtype, b.dtype, out_sds.dtype),
        interpret=interpret,
    )(a, b)


def _gemm_partial_kernel(a_ref, b_ref, acc_in_ref, acc_out_ref, acc_ref,
                         *, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = acc_in_ref[...]        # restore saved accumulator

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _out():
        acc_out_ref[...] = acc_ref[...]       # step_wise_mvout


def gemm_partial(a, b, acc, k_begin: int, k_end: int, *,
                 bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                 bk: int = DEFAULT_BK, interpret: bool = False):
    """Process K-chunks [k_begin, k_end) of C += A@B, resuming from ``acc``.

    ``acc`` is the fp32 accumulator (M, N) saved at the previous preemption
    point; returns the updated accumulator.  ``k_begin``/``k_end`` are in
    units of bk blocks (static).  The full product is recovered by chaining
    calls until k_end == K // bk and casting.
    """
    M, K = a.shape
    _, N = b.shape
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert K % bk == 0
    nk_total = K // bk
    assert 0 <= k_begin < k_end <= nk_total
    nk = k_end - k_begin
    a_sl = jax.lax.slice_in_dim(a, k_begin * bk, k_end * bk, axis=1)
    b_sl = jax.lax.slice_in_dim(b, k_begin * bk, k_end * bk, axis=0)
    out_sds = jax.ShapeDtypeStruct((M, N), jnp.float32)
    cost = _gemm_cost(M, nk * bk, N, a.dtype, b.dtype, out_sds.dtype)
    cost = pl.CostEstimate(
        flops=cost.flops, transcendentals=0,
        # the saved accumulator is both read and written
        bytes_accessed=cost.bytes_accessed + M * N * 4)
    return pl.pallas_call(
        functools.partial(_gemm_partial_kernel, nk=nk),
        grid=(M // bm, N // bn, nk),
        in_specs=[pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
                  pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
                  pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni))],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=out_sds,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=_DIM_SEMANTICS),
        cost_estimate=cost,
        interpret=interpret,
    )(a_sl, b_sl, acc)
