"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute via ``interpret=True`` —
bit-exact kernel-body semantics in Python — and the jnp reference path is
used by the models by default.  On TPU backends the kernels compile natively
(interpret=False) and are the drop-in hot-spot replacements measured in
EXPERIMENTS.md SSPerf.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_tpu
from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.rglru_scan import rglru_scan_tpu
from repro.kernels.systolic_gemm import gemm_partial, systolic_gemm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm(a, b, *, bm=256, bn=256, bk=256):
    return systolic_gemm(a, b, bm=bm, bn=bn, bk=bk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("k_begin", "k_end", "bk"))
def gemm_resume(a, b, acc, k_begin, k_end, *, bk=256):
    """Preemptible GEMM step: process K blocks [k_begin, k_end)."""
    return gemm_partial(a, b, acc, k_begin, k_end, bk=bk,
                        interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv"))
def flash_attention(q, k, v, *, causal=True, block_q=512, block_kv=512):
    return flash_attention_tpu(q, k, v, causal=causal, block_q=block_q,
                               block_kv=block_kv, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_s",))
def decode_attention(q, k_cache, v_cache, pos, *, block_s=1024):
    return decode_attention_tpu(q, k_cache, v_cache, pos, block_s=block_s,
                                interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_s", "block_d"))
def rglru(a, b, h0, *, block_s=256, block_d=256):
    return rglru_scan_tpu(a, b, h0, block_s=block_s, block_d=block_d,
                          interpret=_interpret())
