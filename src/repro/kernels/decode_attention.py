"""Flash-decoding Pallas kernel: one new query token vs a long KV cache.

The decode hot spot is memory-bound (stream the whole cache once); the
kernel blocks over S with an online softmax in VMEM scratch and masks
positions > pos.  Fusing the mask+softmax+weighted-sum means the cache is
read exactly once from HBM and nothing S-sized is written back — the
pure-jnp path materializes (B,H,S) logits instead.

Layout: q (B,Hq,dh); cache (B,Hkv,S,dh); pos () int32 (scalar-prefetched).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, bs: int, ns: int, scale: float,
                   g: int):
    si = pl.program_id(1)
    pos = pos_ref[0]

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(si * bs <= pos)                   # skip fully-future blocks
    def _compute():
        q = q_ref[0]                           # (G, dh) query heads group
        k = k_ref[0]                           # (bs, dh)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = si * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos <= pos, s, NEG_INF)
        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new) * (s > NEG_INF * 0.5)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(si == ns - 1)
    def _out():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[:, :1], 1e-30)).astype(o_ref.dtype)


def decode_attention_tpu(q, k_cache, v_cache, pos, *, block_s: int = 1024,
                         interpret: bool = False):
    """q (B,Hq,dh), k/v_cache (B,Hkv,S,dh), pos () -> (B,Hq,dh)."""
    B, Hq, dh = q.shape
    _, Hkv, S, _ = k_cache.shape
    G = Hq // Hkv
    bs = min(block_s, S)
    assert S % bs == 0
    ns = S // bs
    qf = q.reshape(B * Hkv, G, dh)
    kf = k_cache.reshape(B * Hkv, S, dh)
    vf = v_cache.reshape(B * Hkv, S, dh)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * Hkv, ns),
        in_specs=[pl.BlockSpec((1, G, dh), lambda bh, si, pos: (bh, 0, 0)),
                  pl.BlockSpec((1, bs, dh), lambda bh, si, pos: (bh, si, 0)),
                  pl.BlockSpec((1, bs, dh), lambda bh, si, pos: (bh, si, 0))],
        out_specs=pl.BlockSpec((1, G, dh), lambda bh, si, pos: (bh, 0, 0)),
        scratch_shapes=[pltpu.VMEM((G, 128), jnp.float32),
                        pltpu.VMEM((G, 128), jnp.float32),
                        pltpu.VMEM((G, dh), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, bs=bs, ns=ns, scale=dh ** -0.5,
                          g=G),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hkv, G, dh), q.dtype),
        interpret=interpret,
    )(pos_arr, qf, kf, vf)
    return out.reshape(B, Hq, dh)
