"""Pallas TPU kernels for the compute hot spots.

systolic_gemm     — Gemmini^RT analogue: VMEM-tiled GEMM + checkpointable
                    accumulator (instruction-level preemption inside a GEMM)
flash_attention   — causal flash with true block skipping
decode_attention  — flash-decoding for long KV caches
rglru_scan        — RG-LRU linear recurrence

ops.py = jit'd wrappers (interpret=True on CPU); ref.py = jnp oracles.
EXAMPLE.md documents the per-kernel structure convention.
"""
from repro.kernels import ops, ref  # noqa: F401
