"""RG-LRU linear recurrence as a Pallas TPU kernel.

h_t = a_t * h_{t-1} + b_t over the sequence, with the channel dim blocked
into VMEM lanes and the hidden state carried in VMEM scratch across
(sequential) S blocks — one HBM read of (a, b) and one write of h, instead
of the log-depth associative-scan's repeated passes.

Inputs a, b fp32 (B, S, D) (precomputed gates; see models.recurrent);
h0 (B, D) initial state.  Returns h (B, S, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, state_ref, *, bs: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        state_ref[...] = h0_ref[...]

    a = a_ref[0]                       # (bs, bd)
    b = b_ref[0]
    h = state_ref[...]                 # (1, bd)

    def step(t, carry):
        h = carry
        h = a[t][None] * h + b[t][None]
        y_ref[0, t] = h[0]
        return h

    h = jax.lax.fori_loop(0, bs, step, h)
    state_ref[...] = h


def rglru_scan_tpu(a, b, h0, *, block_s: int = 256, block_d: int = 256,
                   interpret: bool = False):
    """a,b (B,S,D) fp32; h0 (B,D) -> h (B,S,D)."""
    B, S, D = a.shape
    bs, bd = min(block_s, S), min(block_d, D)
    assert S % bs == 0 and D % bd == 0
    return pl.pallas_call(
        functools.partial(_rglru_kernel, bs=bs),
        grid=(B * (D // bd), S // bs),
        in_specs=[
            pl.BlockSpec((1, bs, bd),
                         lambda bd_i, si: (bd_i // (D // bd), si,
                                           bd_i % (D // bd))),
            pl.BlockSpec((1, bs, bd),
                         lambda bd_i, si: (bd_i // (D // bd), si,
                                           bd_i % (D // bd))),
            pl.BlockSpec((1, bd),
                         lambda bd_i, si: (bd_i // (D // bd),
                                           bd_i % (D // bd))),
        ],
        out_specs=pl.BlockSpec((1, bs, bd),
                               lambda bd_i, si: (bd_i // (D // bd), si,
                                                 bd_i % (D // bd))),
        out_shape=jax.ShapeDtypeStruct((B, S, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
