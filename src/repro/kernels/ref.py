"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm_ref(a, b, out_dtype=None):
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(
        out_dtype or a.dtype)


def gemm_partial_ref(a, b, acc, k_begin: int, k_end: int, bk: int):
    a_sl = a[:, k_begin * bk: k_end * bk].astype(jnp.float32)
    b_sl = b[k_begin * bk: k_end * bk].astype(jnp.float32)
    return acc + a_sl @ b_sl


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q (B,Hq,S,dh), k/v (B,Hkv,S,dh)."""
    B, Hq, S, dh = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * (dh ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos):
    """q (B,Hq,dh), k/v (B,Hkv,S,dh), pos ()."""
    B, Hq, dh = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    k = jnp.repeat(k_cache, G, axis=1)
    v = jnp.repeat(v_cache, G, axis=1)
    s = jnp.einsum("bhd,bhsd->bhs", q, k,
                   preferred_element_type=jnp.float32) * (dh ** -0.5)
    s = jnp.where(jnp.arange(S)[None, None] <= pos, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def rglru_scan_ref(a, b, h0):
    """Sequential oracle for h_t = a_t h_{t-1} + b_t."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    _, ys = jax.lax.scan(step, h0, (jnp.moveaxis(a, 1, 0),
                                    jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(ys, 0, 1)
