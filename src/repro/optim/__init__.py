from repro.optim.adamw import (OptConfig, init_opt_state, adamw_update,
                               lr_schedule, global_norm)
from repro.optim.compression import compress_int8, decompress_int8
