"""Int8 error-feedback gradient compression for slow (cross-pod) links.

Per-tensor symmetric int8 quantization with an error-feedback residual so
compression noise does not bias convergence.  Intended to wrap the pod-axis
gradient all-reduce: grads are quantized before crossing the pod boundary,
summed, then dequantized; the residual stays local.

On TPU, applying this around a `psum` over the 'pod' axis reduces the
cross-pod collective payload 4x (fp32->int8) at the cost of two cheap
elementwise passes, moving the collective roofline term down accordingly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x):
    """x fp -> (q int8, scale fp32). Symmetric per-tensor."""
    x32 = x.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress(g, residual):
    """Error-feedback compression for one tensor.

    Returns ((q, scale), new_residual): the residual carries this round's
    quantization error into the next step, keeping the compressed optimizer
    unbiased in expectation.
    """
    x = g.astype(jnp.float32) + residual
    q, s = compress_int8(x)
    return (q, s), x - decompress_int8(q, s)


def psum_compressed(grads, axis_name):
    """All-reduce ``grads`` over ``axis_name`` with int8 payload.

    Quantize -> psum(int32 accumulate) -> dequantize with max-scale.  The
    scale is itself psum-maxed so all shards agree.
    """
    def one(g):
        q, s = compress_int8(g)
        s_max = jax.lax.pmax(s, axis_name)
        # requantize against the shared scale so sums are consistent
        q2 = jnp.clip(jnp.round(g.astype(jnp.float32) / s_max), -127, 127)
        total = jax.lax.psum(q2.astype(jnp.int32), axis_name)
        return (total.astype(jnp.float32) * s_max).astype(g.dtype)
    return jax.tree_util.tree_map(one, grads)
