"""AdamW with warmup+cosine schedule, global-norm clipping, and
memory-frugal (bf16) first/second moments — the ZeRO-style sharding comes
from the parameter PartitionSpecs (moments inherit them).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.bfloat16   # bf16 moments halve optimizer HBM
    # 8-bit moments (per-tensor scaled int8, Dettmers-style): 4 B/param
    # optimizer state total — what makes 400B-param AdamW fit one v5e pod
    moments_int8: bool = False


def lr_schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params, cfg: OptConfig) -> Dict[str, Any]:
    if cfg.moments_int8:
        zq = lambda p: jnp.zeros(p.shape, jnp.int8)
        sc = lambda p: jnp.ones((), jnp.float32)
        return {
            "m": jax.tree_util.tree_map(zq, params),
            "v": jax.tree_util.tree_map(zq, params),
            "m_scale": jax.tree_util.tree_map(sc, params),
            "v_scale": jax.tree_util.tree_map(sc, params),
            "step": jnp.zeros((), jnp.int32),
        }
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else 1.0
    lr = lr_schedule(cfg, step)
    c1 = 1.0 - cfg.b1 ** (step.astype(jnp.float32) + 1)
    c2 = 1.0 - cfg.b2 ** (step.astype(jnp.float32) + 1)

    def common(p, g, m32, v32):
        m32 = cfg.b1 * m32 + (1 - cfg.b1) * g
        v32 = cfg.b2 * v32 + (1 - cfg.b2) * jnp.square(g)
        delta = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32, v32

    if cfg.moments_int8:
        def upd8(p, g, mq, ms, vq, vs):
            g = g.astype(jnp.float32) * scale
            newp, m32, v32 = common(p, g, mq.astype(jnp.float32) * ms,
                                    vq.astype(jnp.float32) * vs)
            ms2 = jnp.maximum(jnp.max(jnp.abs(m32)), 1e-12) / 127.0
            vs2 = jnp.maximum(jnp.max(v32), 1e-12) / 127.0
            mq2 = jnp.clip(jnp.round(m32 / ms2), -127, 127).astype(jnp.int8)
            vq2 = jnp.clip(jnp.round(v32 / vs2), 0, 127).astype(jnp.int8)
            return newp, mq2, ms2, vq2, vs2

        out = jax.tree_util.tree_map(upd8, params, grads, state["m"],
                                     state["m_scale"], state["v"],
                                     state["v_scale"])
        flat, treedef = jax.tree_util.tree_flatten(
            out, is_leaf=lambda x: isinstance(x, tuple))
        pick = lambda i: treedef.unflatten([t[i] for t in flat])
        new_state = {"m": pick(1), "m_scale": pick(2), "v": pick(3),
                     "v_scale": pick(4), "step": step + 1}
        return pick(0), new_state, {"grad_norm": gnorm, "lr": lr}

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        newp, m32, v32 = common(p, g, m.astype(jnp.float32),
                                v.astype(jnp.float32))
        return (newp, m32.astype(cfg.moment_dtype),
                v32.astype(cfg.moment_dtype))

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    flat, treedef = jax.tree_util.tree_flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    newp = treedef.unflatten([t[0] for t in flat])
    newm = treedef.unflatten([t[1] for t in flat])
    newv = treedef.unflatten([t[2] for t in flat])
    new_state = {"m": newm, "v": newv, "step": step + 1}
    return newp, new_state, {"grad_norm": gnorm, "lr": lr}
