"""Post-SPMD HLO text analysis with while-loop trip-count correction.

``compiled.cost_analysis()`` counts each while-loop body ONCE, which
undercounts scanned programs (scan-over-layers, flash KV scans) by their trip
counts.  This module parses ``compiled.as_text()`` instead:

  * computations are parsed into (name -> op lines) with a per-computation
    symbol table (%var -> shape);
  * while ops are resolved to (condition, body); the trip count is read from
    the s32 constant in the canonicalized condition computation;
  * a call-graph walk assigns every computation a multiplier
    (entry = 1, while body = parent x trip, fusion-called = parent x 1);
  * dot FLOPs            = 2 * out_elems * contracted_elems, summed with
    multipliers over ALL computations (incl. fusion bodies);
  * HBM bytes            = sum of (operand + output) bytes of materializing
    top-level ops in CONTROL computations only (entry + while bodies) —
    fusion internals are on-chip and excluded;
  * collective payloads  = per-kind output bytes and ring-model link bytes,
    with multipliers.

This is the measured basis for the roofline terms in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\(")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|true_computation|false_computation|"
                      r"branch_computations)=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_GROUP_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUP_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")
_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "while", "conditional", "call"}


def _shape_elems_and_bytes(type_str: str) -> Tuple[int, int]:
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


def _first_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    kind: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symbols: Dict[str, str]          # var -> type str


def parse_computations(txt: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in txt.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and line.rstrip().endswith("{"):
            cur = Computation(hdr.group(2), [], {})
            comps[cur.name] = cur
            if hdr.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(2), m.group(3), line)
            cur.ops.append(op)
            cur.symbols[op.name] = op.type_str
    comps["__entry__"] = comps.get(entry) if entry else None
    return comps


def _while_edges(comps: Dict[str, Computation]):
    """[(parent, body, trip), ...] and [(parent, callee)] for plain calls."""
    whiles, calls = [], []
    for name, comp in comps.items():
        if name == "__entry__" or comp is None:
            continue
        for op in comp.ops:
            if op.kind == "while":
                m = _WHILE_RE.search(op.line)
                if not m:
                    continue
                cond, body = m.group(1), m.group(2)
                trip = 1
                ccomp = comps.get(cond)
                if ccomp is not None:
                    consts = [int(c) for o in ccomp.ops
                              for c in _CONST_RE.findall(o.line)]
                    if consts:
                        trip = max(max(consts), 1)
                whiles.append((name, body, trip))
            else:
                for m in _CALL_RE.finditer(op.line):
                    for callee in re.split(r",\s*", m.group(1)):
                        calls.append((name, callee.lstrip("%")))
    return whiles, calls


def computation_multipliers(comps: Dict[str, Computation]):
    """(multiplier per computation, set of 'control' computations)."""
    entry = comps.get("__entry__")
    if entry is None:
        return {}, set()
    whiles, calls = _while_edges(comps)
    wmap = defaultdict(list)
    cmap = defaultdict(list)
    for p, b, t in whiles:
        wmap[p].append((b, t))
    for p, c in calls:
        cmap[p].append(c)

    mult: Dict[str, float] = defaultdict(float)
    control = set()
    seen_stack = []

    def visit(name: str, m: float, is_control: bool):
        if name not in comps or comps[name] is None or name in seen_stack:
            return
        mult[name] += m
        if is_control:
            control.add(name)
        seen_stack.append(name)
        for body, trip in wmap.get(name, ()):  # while bodies: control
            visit(body, m * trip, True)
        for callee in cmap.get(name, ()):      # fused/applied: not control
            visit(callee, m, False)
        seen_stack.pop()

    visit(entry.name, 1.0, True)
    return dict(mult), control


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems, _ = _shape_elems_and_bytes(op.type_str)
    args = re.findall(r"\(\s*%([\w\.\-]+)", op.line)
    m = _CONTRACT_RE.search(op.line)
    if not args or m is None:
        return 2.0 * out_elems  # degenerate
    lhs_type = comp.symbols.get(args[0])
    if lhs_type is None:
        return 2.0 * out_elems
    dims = _first_dims(lhs_type) or []
    contracted = 1
    for i in m.group(1).split(","):
        if i and int(i) < len(dims):
            contracted *= dims[int(i)]
    return 2.0 * out_elems * contracted


def _op_bytes(op: Op, comp: Computation) -> int:
    """Approximate HBM traffic of one materializing op.

    Slice-aware: dynamic-slice/gather read only the slice (2x output);
    dynamic-update-slice/scatter touch only the update region (2x update).
    Everything else: operands + output (XLA 'bytes accessed' convention;
    an upper bound at CPU-fusion granularity).
    """
    _, out_b = _shape_elems_and_bytes(op.type_str)
    tag = op.kind + " " + op.name
    if "dynamic-update-slice" in tag or "scatter" in tag:
        ops_b = []
        for arg in re.findall(r"%([\w\.\-]+)", op.line.split("(", 1)[1]):
            t = comp.symbols.get(arg)
            if t is not None:
                ops_b.append(_shape_elems_and_bytes(t)[1])
        small = sum(ops_b) - (max(ops_b) if ops_b else 0)
        return 2 * small
    if "slice" in tag or "gather" in tag:
        # slice-semantics op (incl. fusions like add_slice_fusion reading a
        # loop-iteration slice of a big buffer): traffic is output-sized plus
        # operands no larger than the output
        total = 2 * out_b
        for arg in re.findall(r"%([\w\.\-]+)", op.line.split("(", 1)[1]):
            t = comp.symbols.get(arg)
            if t is not None:
                b = _shape_elems_and_bytes(t)[1]
                if b <= out_b:
                    total += b
        return total
    total = out_b
    for arg in re.findall(r"%([\w\.\-]+)", op.line.split("(", 1)[1]):
        t = comp.symbols.get(arg)
        if t is not None:
            total += _shape_elems_and_bytes(t)[1]
    return total


def _group_size(line: str, default: int) -> int:
    gm = _GROUP_RE.search(line)
    if gm:
        return len(gm.group(1).split(","))
    gm2 = _GROUP_V2_RE.search(line)
    if gm2:
        return int(gm2.group(2))
    return default


def _link_bytes(kind: str, out_bytes: float, gsize: int) -> float:
    g = max(gsize, 1)
    if kind == "all-reduce":
        return 2 * (g - 1) / g * out_bytes
    if kind == "all-gather":
        return (g - 1) / g * out_bytes
    if kind == "reduce-scatter":
        return (g - 1) * out_bytes          # input = out * g
    if kind == "all-to-all":
        return (g - 1) / g * out_bytes
    return out_bytes                        # collective-permute


def analyze_hlo(txt: str, default_group: int) -> dict:
    comps = parse_computations(txt)
    mult, control = computation_multipliers(comps)

    flops = 0.0
    bytes_hbm = 0.0
    bytes_no_copies = 0.0   # optimistic: loop-carry copies alias on TPU
    coll = {}
    for name, m in mult.items():
        comp = comps[name]
        is_ctrl = name in control
        for op in comp.ops:
            base = op.kind.replace("-start", "")
            if base == "dot":
                flops += m * _dot_flops(op, comp)
            if is_ctrl and op.kind not in _SKIP_BYTES_OPS:
                b = m * _op_bytes(op, comp)
                bytes_hbm += b
                if op.kind != "copy" and "copy" not in op.name:
                    bytes_no_copies += b
            if base in COLLECTIVE_KINDS and is_ctrl:
                _, ob = _shape_elems_and_bytes(op.type_str)
                g = _group_size(op.line, default_group)
                rec = coll.setdefault(base, {"count": 0.0, "out_bytes": 0.0,
                                             "link_bytes": 0.0})
                rec["count"] += m
                rec["out_bytes"] += m * ob
                rec["link_bytes"] += m * _link_bytes(base, ob, g)
    link_total = sum(v["link_bytes"] for v in coll.values())
    return {"flops": flops, "hbm_bytes": bytes_hbm,
            "hbm_bytes_no_copies": bytes_no_copies,
            "collectives": coll, "collective_link_bytes": link_total}
