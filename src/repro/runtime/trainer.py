"""train_step / serve_step factories with DP(+pod) x FSDP x TP sharding.

``make_train_step`` builds the jit-able step:
  grads = grad(loss);  optional microbatch accumulation (lax.scan);
  optional int8 cross-pod gradient compression; AdamW update.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.models.common import RuntimeConfig, DEFAULT_RC
from repro.optim import OptConfig, adamw_update, init_opt_state


def make_train_step(cfg: ArchConfig, rc: RuntimeConfig = DEFAULT_RC,
                    opt_cfg: OptConfig = OptConfig(), *,
                    microbatches: int = 1, accum_dtype=jnp.float32):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``accum_dtype``: microbatch gradient-accumulator dtype; bf16 halves the
    accumulator HBM for very large models (documented precision trade)."""

    def grad_fn(params, batch):
        return jax.value_and_grad(
            lambda p: lm.loss_fn(cfg, p, batch, rc), has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            k = microbatches

            def resh(a):
                assert a.shape[0] % k == 0, (a.shape, k)
                return a.reshape((k, a.shape[0] // k) + a.shape[1:])

            mbatches = jax.tree_util.tree_map(resh, batch)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)

            def mb_step(carry, b):
                g_acc, loss_acc = carry
                (loss, m), g = grad_fn(params, b)
                g_acc = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(accum_dtype), g_acc, g)
                return (g_acc, loss_acc + loss), m

            (grads, loss), ms = jax.lax.scan(mb_step, (g0, 0.0), mbatches)
            grads = jax.tree_util.tree_map(lambda g: g / k, grads)
            loss = loss / k
            metrics = jax.tree_util.tree_map(lambda a: a[-1], ms)

        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, rc: RuntimeConfig = DEFAULT_RC,
                      max_len: Optional[int] = None):
    def prefill_step(params, batch):
        return lm.prefill(cfg, params, batch, rc, max_len=max_len)
    return prefill_step


def make_decode_step(cfg: ArchConfig, rc: RuntimeConfig = DEFAULT_RC):
    def serve_step(params, tokens, cache):
        return lm.decode_step(cfg, params, tokens, cache, rc)
    return serve_step


def init_train_state(cfg: ArchConfig, key, rc: RuntimeConfig = DEFAULT_RC,
                     opt_cfg: OptConfig = OptConfig()):
    params = lm.init_params(cfg, key, rc)
    return params, init_opt_state(params, opt_cfg)
