"""Host/device environment configuration for the sharded jit engine.

XLA exposes one CPU device per process by default; the sharded jit
dispatcher (``core.simulator_jit``) spreads independent simulation
points over *logical* host devices carved out of the same CPU via
``--xla_force_host_platform_device_count`` — each logical device runs
its own copy of the compiled lockstep ``while_loop`` on a shard of the
point axis, so XLA:CPU's per-kernel dispatch queues proceed in
parallel instead of serializing behind one device queue.

Everything here is env/flag plumbing and therefore importable without
JAX (JAX is only touched lazily, to ask whether its backends are
already initialized): the experiments/spec layer uses the validation
helpers without dragging in a backend.

Ordering contract: XLA reads ``XLA_FLAGS`` **once**, when the first
backend initializes (the first ``jax.devices()``/array op).  Both
:func:`configure_host_devices` and :func:`set_platform` therefore warn
loudly — and change nothing about the running process — when called
after that point.  Call them first thing in ``main()``, or set
``REPRO_DEVICES`` in the environment and let the engine do it.
"""
from __future__ import annotations

import os
import re
import sys
import warnings
from typing import Optional

# logical host devices are threads multiplexed onto the same silicon:
# past any plausible host core count the forced device pool only adds
# scheduler pressure, so treat absurd requests as misconfiguration
# rather than oversubscribing quietly
MAX_LOGICAL_DEVICES = 256

_GPU_XLA_FLAGS = (
    "--xla_gpu_enable_triton_softmax_fusion=true "
    "--xla_gpu_triton_gemm_any=True "
    "--xla_gpu_enable_async_collectives=true "
    "--xla_gpu_enable_latency_hiding_scheduler=true "
    "--xla_gpu_enable_highest_priority_async_stream=true")

_DEVICE_COUNT_FLAG = "--xla_force_host_platform_device_count"


def _env_int(name: str, default: int, minimum: int = 1,
             maximum: Optional[int] = None) -> int:
    """Read an integer env override, rejecting junk loudly.

    Misconfigured performance knobs must fail at startup with the
    variable named, never silently fall back to a default (a campaign
    quietly running unsharded is the worst failure mode).
    """
    raw = os.environ.get(name)
    if raw is None or not raw.strip():
        return default
    try:
        val = int(raw)
    except ValueError:
        raise ValueError(
            f"{name}={raw!r} is not an integer; set {name} to an "
            f"integer >= {minimum} or unset it") from None
    if val < minimum:
        raise ValueError(
            f"{name}={raw!r} must be >= {minimum}; fix or unset {name}")
    if maximum is not None and val > maximum:
        raise ValueError(
            f"{name}={raw!r} exceeds the maximum of {maximum} logical "
            f"devices; fix or unset {name}")
    return val


def default_device_count() -> int:
    """Logical device count requested via ``REPRO_DEVICES`` (default 1).

    Junk, zero/negative, and oversubscribed (> ``MAX_LOGICAL_DEVICES``)
    values raise ``ValueError`` naming the variable.
    """
    return _env_int("REPRO_DEVICES", 1, minimum=1,
                    maximum=MAX_LOGICAL_DEVICES)


def jax_initialized() -> bool:
    """True once any XLA backend is live (XLA_FLAGS no longer read)."""
    mod = sys.modules.get("jax")
    if mod is None:
        return False
    try:
        from jax._src import xla_bridge
        return xla_bridge.backends_are_initialized()
    except (ImportError, AttributeError) as e:  # pragma: no cover
        # jax-internal API drift: assume live so callers warn rather
        # than claim a reconfiguration that cannot take effect
        warnings.warn(
            f"cannot query JAX backend state ({type(e).__name__}: {e}); "
            "assuming a backend is already initialized — device/platform "
            "reconfiguration is skipped for this process",
            RuntimeWarning, stacklevel=2)
        return True


def _warn_if_initialized(what: str) -> bool:
    if jax_initialized():
        warnings.warn(
            f"{what} called after JAX backend initialization — XLA has "
            "already read XLA_FLAGS and the device pool/platform cannot "
            "change for this process.  Call it before the first jax "
            "operation (or set REPRO_DEVICES in the environment before "
            "launch).", RuntimeWarning, stacklevel=3)
        return True
    return False


def configure_host_devices(n: Optional[int] = None) -> int:
    """Force ``n`` logical host (CPU) devices via ``XLA_FLAGS``.

    ``n=None`` reads ``REPRO_DEVICES`` (validated).  Replaces any
    existing ``--xla_force_host_platform_device_count`` flag, preserving
    unrelated flags.  Must run before JAX backend initialization; after
    it, warns loudly and leaves the process untouched.  Returns the
    count requested.
    """
    if n is None:
        n = default_device_count()
    n = int(n)
    if n < 1 or n > MAX_LOGICAL_DEVICES:
        raise ValueError(
            f"device count {n} out of range [1, {MAX_LOGICAL_DEVICES}] "
            "(REPRO_DEVICES semantics)")
    if _warn_if_initialized("configure_host_devices"):
        return n
    flags = os.environ.get("XLA_FLAGS", "")
    flags = re.sub(rf"{_DEVICE_COUNT_FLAG}=\S+", "", flags).strip()
    os.environ["XLA_FLAGS"] = \
        (flags + f" {_DEVICE_COUNT_FLAG}={n}").strip()
    return n


def set_platform(platform: str = "cpu") -> None:
    """Select the JAX platform (``cpu`` / ``gpu`` / ``tpu``).

    The GPU path additionally sets the XLA flags that matter for
    latency-bound dispatch (async collectives, latency-hiding
    scheduler, triton fusion) — the single-flag route from a CPU
    campaign to a GPU one.  Only env/config state is written; no
    accelerator needs to be present at call time (JAX validates the
    platform at backend init).  After JAX initialization this warns
    loudly and changes nothing.
    """
    if platform not in ("cpu", "gpu", "tpu"):
        raise ValueError(
            f"platform {platform!r} not in ('cpu', 'gpu', 'tpu')")
    if platform == "gpu":
        flags = os.environ.get("XLA_FLAGS", "")
        missing = " ".join(f for f in _GPU_XLA_FLAGS.split()
                           if f not in flags)
        if missing:
            os.environ["XLA_FLAGS"] = (flags + " " + missing).strip()
    os.environ["JAX_PLATFORM_NAME"] = platform
    if _warn_if_initialized("set_platform"):
        return
    try:
        import jax
    except ImportError:  # env vars above still steer a later init
        return
    jax.config.update("jax_platform_name", platform)


def resolve_device_count(requested: Optional[int] = None) -> int:
    """Devices the sharded dispatcher may actually use, right now.

    ``requested=None`` means the ``REPRO_DEVICES`` default.  For counts
    above 1 this forces the logical-device flag when the backend is not
    yet live; if the backend already is (or the platform offers fewer
    devices), the count is clamped to what exists, with a loud warning —
    results are bit-identical at any device count, so clamping is a
    performance event, not a correctness one.
    """
    want = default_device_count() if requested is None else int(requested)
    if want < 1 or want > MAX_LOGICAL_DEVICES:
        raise ValueError(
            f"devices={want} out of range [1, {MAX_LOGICAL_DEVICES}]")
    if want == 1:
        return 1
    if not jax_initialized():
        # force at least the env default so an explicit small request
        # does not lock a later REPRO_DEVICES-sized one out of the pool
        configure_host_devices(max(want, default_device_count()))
    import jax
    have = jax.local_device_count()
    if have < want:
        warnings.warn(
            f"requested {want} logical devices but this process has "
            f"{have} (JAX initialized before the device pool was "
            f"forced?) — running on {have}.  Set REPRO_DEVICES or call "
            "configure_host_devices() before the first jax operation.",
            RuntimeWarning, stacklevel=2)
        return have
    return want
