"""Sharding rules: DP (+pod) x FSDP('data') x TP/EP('model').

A thread-local :class:`AxisRules` context maps logical roles to mesh axes.
Outside any context (unit tests on one device) every constraint is a no-op,
so model code is portable.

Conventions:
  * batch dims           -> ('pod','data') / ('data',)
  * up-proj weights      -> (in='data' [FSDP], out='model' [TP])
  * down-proj weights    -> (in='model', out='data')
  * MoE expert weights   -> (E='model' [EP], in='data', out=None)
  * vocab dim            -> 'model'
  * residual stream S    -> 'model' when sequence_parallel
  * KV-cache S dim       -> 'model' (flash-decoding via GSPMD reductions)

Every spec is *sanitized* against the actual shape: axes that do not divide
the dimension are dropped (replicated) — this is what makes odd head counts
(40, 56, 10 heads on a 16-way axis) compile cleanly; GSPMD then propagates a
legal layout from the surrounding annotated ops.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_TLS = threading.local()


def logical_device_mesh(n: int, axis_name: str = "dev") -> Mesh:
    """1-D mesh over the first ``n`` local devices.

    The sim dispatcher's shard axis (``core.simulator_jit``): simulation
    points are independent, so the mesh carries no collectives — it only
    names the axis ``shard_map`` splits the point dimension over.  The
    logical CPU devices themselves come from ``runtime.device_config``
    (``--xla_force_host_platform_device_count``).
    """
    devs = jax.devices()
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"logical_device_mesh: need 1 <= n <= {len(devs)} "
            f"available devices, got n={n} (configure the pool first — "
            "see repro.runtime.device_config)")
    return Mesh(np.asarray(devs[:n]), (axis_name,))


def current_rules() -> Optional["AxisRules"]:
    return getattr(_TLS, "rules", None)


class AxisRules:
    """mode='sp': Megatron-SP+TP (weights stay model-sharded; sequence is
    gathered at block entry and reduce-scattered at exit).  mode='2d':
    batch sharded over data x model (ZeRO-3-style full weight gathers) —
    right for small models where replicating a layer's weights is cheap."""

    def __init__(self, mesh: Mesh, *, sequence_parallel: bool = False,
                 mode: str = "sp", fsdp_over_pod: bool = False):
        self.mesh = mesh
        names = mesh.axis_names
        self.dp: Tuple[str, ...] = tuple(n for n in names if n in ("pod", "data"))
        self.tp: Optional[str] = "model" if "model" in names else None
        self.sp = sequence_parallel
        self.mode = mode
        # ZeRO across pods: shard params over ('pod','data') so 400B-class
        # state halves per added pod (gathers cross slow links -> pair with
        # int8 gather compression, see optim.compression)
        self.fsdp: Tuple[str, ...] = (
            tuple(n for n in names if n in ("pod", "data"))
            if fsdp_over_pod else ("data",) if "data" in names else ())

    def axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        return int(np.prod([self.mesh.shape[a] for a in axes]))

    def _resolve(self, ax):
        if ax == "data":                    # alias: the FSDP shard axes
            if len(self.fsdp) == 0:
                return None
            return self.fsdp if len(self.fsdp) > 1 else self.fsdp[0]
        return ax

    def sanitize(self, spec: Tuple, shape: Tuple[int, ...]) -> P:
        out = []
        for d, ax in enumerate(spec[:len(shape)]):
            ax = self._resolve(ax)
            if ax is None or shape[d] % self.axis_size(ax) != 0:
                out.append(None)
            else:
                out.append(ax)
        out += [None] * (len(shape) - len(out))
        return P(*out)

    def named(self, spec: Tuple, shape: Tuple[int, ...]) -> NamedSharding:
        return NamedSharding(self.mesh, self.sanitize(spec, shape))


@contextmanager
def axis_rules(rules: Optional[AxisRules]):
    prev = getattr(_TLS, "rules", None)
    _TLS.rules = rules
    try:
        yield rules
    finally:
        _TLS.rules = prev


# ---------------------------------------------------------------------------
# Activation constraints (called from model code)
# ---------------------------------------------------------------------------

def shard_activation(x, kind: str, rc=None):
    r = current_rules()
    if r is None or (rc is not None and not rc.logical_axes):
        return x
    dp = r.dp if len(r.dp) != 1 else r.dp[0]
    full = r.dp + ((r.tp,) if r.tp else ())
    is2d = r.mode == "2d" and x.shape[0] % r.axis_size(full) == 0
    if kind == "residual":
        if is2d:
            spec: Tuple = (full, None, None)
        else:
            seq = r.tp if (r.sp and (rc is None or rc.sequence_parallel)) \
                else None
            spec = (dp, seq, None)
    elif kind == "logits":
        spec = (dp,) + (None,) * (x.ndim - 2) + (r.tp,)
    elif kind == "batch":
        spec = (dp,) + (None,) * (x.ndim - 1)
    elif kind == "attn_in":
        # q/k/v (B, S, H, dh): keep the flash loops collective-free.
        # 2d: batch-local attention; sp: head-sharded TP when heads divide,
        # else replicated across 'model' (documented redundancy; §Perf lever).
        spec = _attn_spec(r, x.shape[0], x.shape[2])
    elif kind == "attn_out":
        # o (B, S, H*dh) before the output projection
        if is2d:
            spec = (full, None, None)
        else:
            spec = (dp, None, r.tp)
    elif kind == "ffn_in":
        # block input x (B, S, D): sequence gathered (Megatron-SP boundary)
        spec = (full, None, None) if is2d else (dp, None, None)
    elif kind == "ffn_hidden":
        # up-projection output (B, S, F): F model-sharded in sp mode so the
        # FFN weights are never replicated across 'model'
        spec = (full, None, None) if is2d else (dp, None, r.tp)
    elif kind == "moe_tokens":
        # (R, N, D) routing rows: train routes per sequence (R = batch),
        # decode routes over batch (R = 1, N = batch)
        spec = (dp, None, None) if x.shape[0] > 1 else (None, dp, None)
    elif kind == "moe_buf":
        # expert buffers (R, E, C, *): expert dim over 'model' (EP)
        spec = (dp if x.shape[0] > 1 else None, r.tp)             + (None,) * (x.ndim - 2)
    else:
        return x
    return jax.lax.with_sharding_constraint(
        x, r.named(spec, x.shape))


def _attn_spec(r: "AxisRules", B: int, H: int) -> Tuple:
    dp = r.dp if len(r.dp) != 1 else r.dp[0]
    full = r.dp + ((r.tp,) if r.tp else ())
    if r.mode == "2d" and B % r.axis_size(full) == 0:
        return (full, None, None, None)
    if r.tp and H % r.axis_size(r.tp) == 0:
        return (dp, None, r.tp, None)
    return (dp, None, None, None)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

_UP = {"wq", "wk", "wv", "w1", "w3", "w_q", "w_dkv", "w_uk", "w_uv", "w_in",
       "w_up", "w_y", "w_xb", "w_if", "w_k"}
_DOWN = {"wo", "w2", "w_o", "w_down", "w_out"}
_REPL3 = {"w_a", "w_x", "r"}          # small block-diagonal weights

# (core_rank, core_spec); leading stack dims are padded with None
_PARAM_RULES = {
    **{n: (2, ("data", "model")) for n in _UP},
    **{n: (2, ("model", "data")) for n in _DOWN},
    **{n: (3, (None, None, None)) for n in _REPL3},
    # embed: vocab replicated, D sharded over the whole mesh -> token
    # gathers are fully local (a vocab-sharded table makes GSPMD emit
    # per-shard masked gathers with replicated batch)
    "embed": (2, (None, ("data", "model"))),
    "lm_head": (2, ("data", "model")),
    "router": (2, ("data", None)),
    "conv_w": (2, (None, "model")),
    "lam": (1, ("model",)),
}
_MOE_RULES = {
    "w1": (3, ("model", "data", None)),
    "w3": (3, ("model", "data", None)),
    "w2": (3, ("model", None, "data")),
}


def _param_spec(path, arr, rules: AxisRules, tied: bool = False) -> NamedSharding:
    keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    name = keys[-1]
    in_moe = len(keys) >= 2 and keys[-2] == "moe"
    if name == "embed" and tied:
        # tied embeddings serve as lm_head too: keep vocab on 'model' so
        # the logits matmul stays vocab-parallel (the input-side gather
        # cost is acceptable at tied-arch vocab sizes)
        rule = (2, ("model", "data"))
    else:
        rule = (_MOE_RULES.get(name) if in_moe else None) \
            or _PARAM_RULES.get(name)
    if rule is None:
        return rules.named((None,) * arr.ndim, arr.shape)
    core_rank, core = rule
    lead = arr.ndim - core_rank
    if lead < 0:
        return rules.named((None,) * arr.ndim, arr.shape)
    return rules.named((None,) * lead + tuple(core), arr.shape)


def param_specs(params, rules: AxisRules):
    """PyTree of NamedSharding for a parameter tree."""
    tied = isinstance(params, dict) and "lm_head" not in params
    return jax.tree_util.tree_map_with_path(
        lambda p, a: _param_spec(p, a, rules, tied=tied), params)


# ---------------------------------------------------------------------------
# Cache / optimizer / batch specs
# ---------------------------------------------------------------------------

_CACHE_RULES = {
    # core spec counted from the END of the shape
    "ck": ("batch", "model", None, None), "cv": ("batch", "model", None, None),
    "cka": ("batch", "model", None, None), "cva": ("batch", "model", None, None),
    "ckb": ("batch", "model", None, None), "cvb": ("batch", "model", None, None),
    "cc": ("batch", "model", None), "ckr": ("batch", "model", None),
    "wk": ("batch", "model", None, None), "wv": ("batch", "model", None, None),
    "rh": ("batch", "model"), "rconv": ("batch", None, "model"),
    "mC": ("batch", None, None, None), "mn": ("batch", None, None),
    "mm": ("batch", None), "mconv": ("batch", None, "model"),
    "sc": ("batch", "model"), "sn": ("batch", "model"),
    "sh": ("batch", "model"), "sm": ("batch", "model"),
    "pos": (),
}


def _cache_spec(path, arr, rules: AxisRules) -> NamedSharding:
    keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    name = keys[-1]
    rule = _CACHE_RULES.get(name) or _CACHE_RULES.get(name.rstrip("0123456789"))
    if rule is None:
        return rules.named((None,) * arr.ndim, arr.shape)
    dp = rules.dp if len(rules.dp) != 1 else rules.dp[0]
    core = tuple(dp if ax == "batch" else ax for ax in rule)
    lead = arr.ndim - len(core)
    if lead < 0:
        return rules.named((None,) * arr.ndim, arr.shape)
    return rules.named((None,) * lead + core, arr.shape)


def cache_specs(cache, rules: AxisRules):
    return jax.tree_util.tree_map_with_path(
        lambda p, a: _cache_spec(p, a, rules), cache)


def batch_specs(batch, rules: AxisRules):
    dp = rules.dp if len(rules.dp) != 1 else rules.dp[0]
    return jax.tree_util.tree_map(
        lambda a: rules.named((dp,) + (None,) * (a.ndim - 1), a.shape), batch)


def replicated(tree, rules: AxisRules):
    return jax.tree_util.tree_map(
        lambda a: rules.named((None,) * getattr(a, "ndim", 0),
                              getattr(a, "shape", ())), tree)
