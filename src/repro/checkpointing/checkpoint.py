"""Fault-tolerant checkpointing: atomic, shard-aware, elastic.

Design (no external deps — pure numpy + json manifest):
  * a checkpoint is a directory ``step_<n>.tmp`` renamed atomically to
    ``step_<n>`` once fully written (crash mid-write never corrupts);
  * the pytree is flattened to path-keyed .npy entries inside one .npz per
    top-level group, plus a JSON manifest (paths, shapes, dtypes, step,
    data cursor, RNG, scheduler state);
  * **elastic restore**: arrays are loaded as full (host) values and
    ``jax.device_put`` with the *target* mesh's NamedShardings — the saved
    layout and the restore layout are independent, so a job can restart on
    a different number of pods / a degraded mesh after node failure;
  * retention: keep the last ``keep`` checkpoints.

On a real multi-host cluster each host writes only the shards it owns
(process-local addressable_shards) into per-host files; here (single
process) we write the full value — the manifest format already carries
per-array metadata so the multi-host writer is a drop-in extension.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np

# numpy's npz format cannot hold ml_dtypes natively; store raw bits + name
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8}


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        out.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, [x for x in out])


def save_checkpoint(directory, step: int, state: Dict[str, Any],
                    extra: Optional[dict] = None, keep: int = 3) -> Path:
    """state: dict of pytrees (e.g. {'params':…, 'opt':…}). Atomic."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"step_{step:08d}.tmp"
    final = directory / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    manifest = {"step": step, "time": time.time(), "groups": {},
                "extra": extra or {}}
    for group, tree in state.items():
        flat = _flatten(tree)
        arrays = {}
        meta = {}
        for k, v in flat.items():
            arr = np.asarray(jax.device_get(v))
            meta[k] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
            if str(arr.dtype) in _EXOTIC:
                arr = arr.view(_EXOTIC[str(arr.dtype)])
            arrays[k] = arr
        np.savez(tmp / f"{group}.npz", **arrays)
        manifest["groups"][group] = meta
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    # retention
    ckpts = sorted(p for p in directory.iterdir()
                   if p.name.startswith("step_") and not
                   p.name.endswith(".tmp"))
    for old in ckpts[:-keep]:
        shutil.rmtree(old)
    return final


def latest_step(directory) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.iterdir()
             if p.name.startswith("step_") and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(directory, templates: Dict[str, Any],
                    step: Optional[int] = None,
                    shardings: Optional[Dict[str, Any]] = None):
    """Restore onto the CURRENT mesh (elastic: shardings come from the
    caller's target mesh, not from the checkpoint)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    state = {}
    for group, template in templates.items():
        meta = manifest["groups"][group]
        with np.load(d / f"{group}.npz") as z:
            flat = {}
            for k in z.files:
                arr = z[k]
                want = meta[k]["dtype"]
                if want in _EXOTIC:
                    arr = arr.view(getattr(ml_dtypes, want))
                flat[k] = arr
        tree = _unflatten_into(template, flat)
        if shardings and group in shardings:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), tree, shardings[group])
        state[group] = tree
    return state, manifest


class CheckpointManager:
    """Train-loop helper: periodic save + crash-safe resume + retention."""

    def __init__(self, directory, interval: int = 100, keep: int = 3):
        self.directory = Path(directory)
        self.interval = interval
        self.keep = keep

    def maybe_save(self, step: int, state: Dict[str, Any],
                   extra: Optional[dict] = None) -> Optional[Path]:
        if step % self.interval == 0 and step > 0:
            return save_checkpoint(self.directory, step, state, extra,
                                   keep=self.keep)
        return None

    def restore_or_init(self, templates, init_fn, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return init_fn(), 0, {}
        state, manifest = load_checkpoint(self.directory, templates,
                                          step=step, shardings=shardings)
        return state, step, manifest.get("extra", {})
