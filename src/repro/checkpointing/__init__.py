from repro.checkpointing.checkpoint import (save_checkpoint, load_checkpoint,
                                            latest_step, CheckpointManager)
