"""On-disk result cache for campaign points (SS VIII runs, keyed by
content hash).

Layout (under the cache root, default ``results/campaigns``)::

    points/<k[:2]>/<key>.json     one JSON row per completed point,
                                  keyed by the point's content hash
    manifests/<spec_hash>.json    per-campaign manifest: sweep name,
                                  spec, point keys, hit/miss counts

Point entries are content-addressed, so any two sweeps that share a
point (same policy/params/seed) share its cached result, and re-running
a sweep after editing only one axis re-simulates only the new points.
Writes are atomic (tmp file + rename) so a killed campaign never leaves
a truncated entry behind.
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, List, Optional

DEFAULT_CACHE_DIR = "results/campaigns"


def _env_path(name: str, default: str) -> Path:
    """Read a directory-path env override, rejecting junk loudly.

    A blank-but-set variable almost always means a broken launch
    script; failing at startup beats silently caching into ``.``.
    """
    raw = os.environ.get(name)
    if raw is None:
        return Path(default)
    if not raw.strip():
        raise ValueError(
            f"{name} is set but blank; set a directory path or unset it")
    return Path(raw)


def default_cache_dir() -> Path:
    return _env_path("REPRO_CACHE_DIR", DEFAULT_CACHE_DIR)


class ResultCache:
    def __init__(self, root: Optional[Path] = None):
        self.root = Path(root) if root is not None else default_cache_dir()

    # -- point entries --------------------------------------------------
    def _path(self, key: str) -> Path:
        return self.root / "points" / key[:2] / f"{key}.json"

    def has(self, key: str) -> bool:
        return self._path(key).exists()

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        p = self._path(key)
        try:
            return json.loads(p.read_text())
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def put(self, key: str, row: Dict[str, Any]) -> None:
        p = self._path(key)
        p.parent.mkdir(parents=True, exist_ok=True)
        _atomic_write(p, json.dumps(row, sort_keys=True))

    # -- campaign manifests ---------------------------------------------
    def write_manifest(self, spec_hash: str, manifest: Dict[str, Any]):
        p = self.root / "manifests" / f"{spec_hash}.json"
        p.parent.mkdir(parents=True, exist_ok=True)
        _atomic_write(p, json.dumps(manifest, indent=1, sort_keys=True))

    def read_manifest(self, spec_hash: str) -> Optional[Dict[str, Any]]:
        p = self.root / "manifests" / f"{spec_hash}.json"
        try:
            return json.loads(p.read_text())
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def manifests(self) -> List[Dict[str, Any]]:
        d = self.root / "manifests"
        if not d.is_dir():
            return []
        out = []
        for p in sorted(d.glob("*.json")):
            try:
                out.append(json.loads(p.read_text()))
            except json.JSONDecodeError:
                continue
        return out


def _atomic_write(path: Path, text: str) -> None:
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
