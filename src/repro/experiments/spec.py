"""Declarative sweep specifications for the campaign engine (the
SS VIII experimental campaigns as data).

A *sweep* is the unit the engine plans: a grid of independent *points*,
each of which is one unit of work a worker process can execute on its
own.  Two sweep flavours cover the repo's experiments:

  * :class:`Sweep` — the paper's simulation campaigns: a cartesian grid
    of policies x utilisations x gammas x taskset sizes x set indices.
    Each point is one taskset generation + one DES run
    (``core.simulator.MCSSimulator``), seeded by the deterministic
    per-point contract ``core.taskgen.point_seed`` (seed0 + set_index),
    which makes every point reproducible in isolation and keeps the
    engine's output bit-identical to the legacy serial loops.
  * :class:`FuncSweep` — analysis fan-outs (per-workload instruction
    statistics, roofline cells, ...): each point calls a module-level
    function referenced as ``"package.module:function"`` with
    JSON-able kwargs.

Every point owns a stable content hash (:func:`canonical_hash` over its
canonical-JSON form) used as its result-cache key, and every sweep owns
a ``spec_hash`` over the full spec — the campaign manifest key.  Hashes
depend only on point *content*, so two sweeps that share points share
cache entries.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

from repro.core.scheduler import Policy
from repro.core.simulator import DEMAND_PROFILES, SIM_SEMANTICS_VERSION
# both engine salts live in (jax-free) simulator_vec: hashing a jit
# point must not import JAX into every campaign worker
from repro.core.simulator_vec import (JIT_SIM_SEMANTICS_VERSION,
                                      VEC_SIM_SEMANTICS_VERSION)
from repro.core.taskgen import point_seed
from repro.scenarios import get_scenario

SPEC_VERSION = 1

ENGINES = ("event", "vec", "jit")


def canonical_json(obj: Any) -> str:
    """Key-sorted, whitespace-free JSON — the hashing wire format."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def canonical_hash(obj: Any) -> str:
    return hashlib.sha256(canonical_json(obj).encode()).hexdigest()


def policy_to_dict(policy: Policy) -> Dict[str, Any]:
    return dataclasses.asdict(policy)


def policy_from_dict(d: Dict[str, Any]) -> Policy:
    return Policy(**d)


# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SimPoint:
    """One taskset + one simulator run; the engine's atomic sim unit."""
    policy: Tuple[Tuple[str, Any], ...]   # sorted Policy asdict items
    u: float
    gamma: float
    n_tasks: int
    set_index: int
    seed: int
    duration: float
    cf: float
    overrun_prob: float
    library: str = "sim"                  # 'sim' (no arch:*) | 'all'
    engine: str = "event"                 # 'event' | 'vec' | 'jit'
    devices: Optional[int] = None         # jit only: logical devices
    scenario: Optional[str] = None        # scenarios.get_scenario name
    demand_profile: str = "sampled"       # 'sampled' | 'nominal'

    kind = "sim"

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["policy"] = dict(self.policy)
        d["kind"] = self.kind
        d["v"] = SPEC_VERSION
        # ties cache entries to the simulator's semantics, not just the
        # spec format: bumping core.simulator.SIM_SEMANTICS_VERSION
        # invalidates every cached sim point
        d["sim_v"] = SIM_SEMANTICS_VERSION
        # Cache contract across engines: event-engine points serialize
        # exactly as before this field existed (their keys — and every
        # previously cached result — survive), while vec/jit points
        # carry the engine tag plus their own semantics salt, so no
        # two engines ever share or clobber cache entries.
        if self.engine == "event":
            d.pop("engine")
        elif self.engine == "jit":
            d["jit_sim_v"] = JIT_SIM_SEMANTICS_VERSION
        else:
            d["vec_sim_v"] = VEC_SIM_SEMANTICS_VERSION
        # devices rides in worker payloads but never in cache keys —
        # see key(); omitting the default keeps old payloads identical
        if self.devices is None:
            d.pop("devices")
        # scenario / demand_profile salt the key only when non-default,
        # so every pre-scenario point hash stays byte-stable
        if self.scenario is None:
            d.pop("scenario")
        if self.demand_profile == "sampled":
            d.pop("demand_profile")
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "SimPoint":
        return SimPoint(
            policy=tuple(sorted(d["policy"].items())),
            u=d["u"], gamma=d["gamma"], n_tasks=d["n_tasks"],
            set_index=d["set_index"], seed=d["seed"],
            duration=d["duration"], cf=d["cf"],
            overrun_prob=d["overrun_prob"],
            library=d.get("library", "sim"),
            engine=d.get("engine", "event"),
            devices=d.get("devices"),
            scenario=d.get("scenario"),
            demand_profile=d.get("demand_profile", "sampled"))

    def key(self) -> str:
        # the sharded jit engine is bit-identical at every device count
        # (per-point keyed RNG draws), so the device count is execution
        # placement, not semantics: points at different counts SHARE
        # cache entries (pinned by tests/test_campaign_cache.py)
        d = self.to_dict()
        d.pop("devices", None)
        return canonical_hash(d)

    def policy_obj(self) -> Policy:
        return policy_from_dict(dict(self.policy))


@dataclasses.dataclass(frozen=True)
class FuncPoint:
    """One call of an importable function with JSON-able kwargs."""
    fn: str                                # "package.module:function"
    kwargs: Tuple[Tuple[str, Any], ...]    # sorted items

    kind = "func"

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "v": SPEC_VERSION, "fn": self.fn,
                "kwargs": dict(self.kwargs)}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "FuncPoint":
        return FuncPoint(fn=d["fn"],
                         kwargs=tuple(sorted(d["kwargs"].items())))

    def key(self) -> str:
        return canonical_hash(self.to_dict())


def point_from_dict(d: Dict[str, Any]):
    if d.get("kind") == "func":
        return FuncPoint.from_dict(d)
    return SimPoint.from_dict(d)


# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Sweep:
    """Cartesian simulation grid: policies x utils x gammas x betas x sets.

    ``n_sets`` task sets are drawn per grid cell, set ``s`` seeded with
    ``point_seed(seed0, s)`` for both taskset generation and the
    simulator — identical to the legacy ``benchmarks.common.run_many``
    loop, so engine results match the pre-engine serial outputs exactly.
    """
    name: str
    policies: Tuple[Policy, ...]
    utils: Tuple[float, ...] = (0.8,)
    gammas: Tuple[float, ...] = (0.5,)
    n_tasks: Tuple[int, ...] = (10,)
    n_sets: int = 100
    seed0: int = 0
    duration: float = 2e8
    cf: float = 2.0
    overrun_prob: float = 0.3
    library: str = "sim"
    engine: str = "event"                 # 'event' | 'vec' | 'jit'
    devices: Optional[int] = None         # jit only: logical devices
    scenario: Optional[str] = None        # scenarios.get_scenario name
    demand_profile: str = "sampled"       # 'sampled' | 'nominal'

    def __post_init__(self):
        names = [p.name for p in self.policies]
        if len(set(names)) != len(names):
            raise ValueError(
                f"sweep {self.name!r}: policy names must be unique "
                f"(got {names}); use dataclasses.replace(p, name=...)")
        if self.engine not in ENGINES:
            raise ValueError(f"sweep {self.name!r}: unknown engine "
                             f"{self.engine!r}; want one of {ENGINES}")
        if self.demand_profile not in DEMAND_PROFILES:
            raise ValueError(
                f"sweep {self.name!r}: unknown demand_profile "
                f"{self.demand_profile!r}; want one of {DEMAND_PROFILES}")
        try:
            get_scenario(self.scenario)
        except ValueError as e:
            raise ValueError(f"sweep {self.name!r}: {e}") from None
        if self.devices is not None:
            if self.engine != "jit":
                raise ValueError(
                    f"sweep {self.name!r}: devices={self.devices} "
                    f"requires engine='jit' (the {self.engine!r} "
                    "engine runs on the host)")
            if self.devices < 1:
                raise ValueError(f"sweep {self.name!r}: devices="
                                 f"{self.devices} must be >= 1")

    def points(self) -> List[SimPoint]:
        out = []
        for pol in self.policies:
            pol_items = tuple(sorted(policy_to_dict(pol).items()))
            for u in self.utils:
                for g in self.gammas:
                    for b in self.n_tasks:
                        for s in range(self.n_sets):
                            out.append(SimPoint(
                                policy=pol_items, u=u, gamma=g,
                                n_tasks=b, set_index=s,
                                seed=point_seed(self.seed0, s),
                                duration=self.duration, cf=self.cf,
                                overrun_prob=self.overrun_prob,
                                library=self.library,
                                engine=self.engine,
                                devices=self.devices,
                                scenario=self.scenario,
                                demand_profile=self.demand_profile))
        return out

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["policies"] = [policy_to_dict(p) for p in self.policies]
        d["kind"] = "sweep"
        d["v"] = SPEC_VERSION
        if self.engine == "event":        # keep pre-engine spec hashes
            d.pop("engine")
        if self.devices is None:          # keep pre-sharding hashes
            d.pop("devices")
        if self.scenario is None:         # keep pre-scenario hashes
            d.pop("scenario")
        if self.demand_profile == "sampled":
            d.pop("demand_profile")
        return d

    def spec_hash(self) -> str:
        return canonical_hash(self.to_dict())


@dataclasses.dataclass(frozen=True)
class FuncSweep:
    """Fan-out of one importable function over a list of kwargs dicts.

    ``cache=False`` marks sweeps whose points read mutable filesystem
    state (e.g. roofline over dry-run artifacts) — they always re-run.
    """
    name: str
    fn: str
    items: Tuple[Tuple[Tuple[str, Any], ...], ...]
    cache: bool = True

    @staticmethod
    def over(name: str, fn: str, items: Sequence[Dict[str, Any]],
             cache: bool = True) -> "FuncSweep":
        return FuncSweep(
            name=name, fn=fn, cache=cache,
            items=tuple(tuple(sorted(it.items())) for it in items))

    def points(self) -> List[FuncPoint]:
        return [FuncPoint(fn=self.fn, kwargs=it) for it in self.items]

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": "func_sweep", "v": SPEC_VERSION, "name": self.name,
                "fn": self.fn, "cache": self.cache,
                "items": [dict(it) for it in self.items]}

    def spec_hash(self) -> str:
        return canonical_hash(self.to_dict())
