"""Campaign-engine entry points for multi-accelerator (platform) sweeps.

The partitioned multi-instance simulator
(``core.simulator.MultiAccelSimulator``) is, like the single-instance
one, embarrassingly parallel per (taskset, seed) point — so the fig11
sweep is declared as a :class:`~repro.experiments.spec.FuncSweep` over
:func:`simulate_multiacc_point`, giving it the engine's process fan-out
and content-addressed result cache for free.

Seeding follows the engine's per-point contract
(``core.taskgen.point_seed``): set ``s`` generates its taskset AND runs
its simulator with ``seed0 + s``, so every point is reproducible in
isolation.  ``sim_v`` is accepted (and baked into the point's cache key
by the sweep declaration) so bumping
``core.simulator.MULTI_SIM_SEMANTICS_VERSION`` invalidates stale cached
rows without touching the single-instance campaign cache.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.core.scheduler import Policy
from repro.core.simulator import MultiAccelSimulator
from repro.core.taskgen import generate_taskset, point_seed
from repro.experiments.metrics import metrics_row
from repro.experiments.runner import cached_library

POLICIES = {
    "mesc": Policy.mesc,
    "np": Policy.non_preemptive,
    "lp": Policy.limited,
    "amc": Policy.amc,
}


def simulate_multiacc_point(*, policy: str, u: float, n_instances: int,
                            heuristic: str, set_index: int, seed0: int = 0,
                            n_tasks: int = 12, gamma: float = 0.5,
                            cf: float = 2.0, duration: float = 2e8,
                            overrun_prob: float = 0.3,
                            dma_contention: bool = True,
                            migration: bool = True,
                            max_task_u: float = 0.5,
                            library: str = "sim",
                            sim_v: Any = None) -> Dict[str, Any]:
    """One partitioned multi-accelerator DES run -> one tidy row.

    ``u`` is the TOTAL task-set utilisation (spread over the instances
    by the partition heuristic); ``policy`` is a name from
    :data:`POLICIES`.  Task sets use UUnifast-discard with
    ``max_task_u=0.5`` so every HI-task stays individually feasible
    under a full CF=2 overrun (u_lo <= 1/CF) — plain UUnifast over a
    multi-instance total would emit tasks no instance can host.
    Returns the merged platform-wide metrics row plus the multi-only
    counters (migrations, DMA-contention cycles).
    """
    from repro.core.platform import MigrationPolicy
    del sim_v                       # cache-key salt only
    programs = cached_library(library)
    seed = point_seed(seed0, set_index)
    tasks = generate_taskset(u, gamma=gamma, n_tasks=n_tasks, cf=cf,
                             seed=seed, programs=programs,
                             max_task_u=max_task_u)
    sim = MultiAccelSimulator(
        tasks, programs, POLICIES[policy](), n_instances=n_instances,
        heuristic=heuristic, duration=duration, seed=seed,
        overrun_prob=overrun_prob, cf=cf, dma_contention=dma_contention,
        migration=MigrationPolicy(enabled=migration))
    multi = sim.run()
    merged = multi.merged()
    row = metrics_row(merged, policy=policy, u=u,
                      n_instances=n_instances, heuristic=heuristic,
                      set_index=set_index, seed=seed)
    blocks = merged.pi_blocking + merged.ci_blocking
    row.update(
        migrations=multi.migrations,
        migration_cycles=float(multi.migration_cycles),
        dma_contention_cycles=float(multi.dma_contention_cycles),
        block_max=float(max(blocks)) if blocks else 0.0,
    )
    return row
