"""Unified experiment-campaign engine (see docs/experiments.md).

Declare a sweep, run it as a campaign, collect tidy rows:

    from repro.core import Policy
    from repro.experiments import Campaign, Sweep, group_rows, frac

    sweep = Sweep(name="demo", policies=(Policy.mesc(),),
                  utils=(0.7, 0.9), n_sets=50)
    rows = Campaign(sweep).collect()          # parallel + cached
    for (u,), cell in group_rows(rows, "u").items():
        print(u, frac(cell, "success_all"))

Points are content-hashed and cached on disk (``results/campaigns`` by
default), so repeated or overlapping sweeps only simulate what is new.
"""
from repro.experiments.spec import (FuncPoint, FuncSweep, SimPoint, Sweep,
                                    canonical_hash, canonical_json)
from repro.experiments.cache import ResultCache, default_cache_dir
from repro.experiments.runner import Campaign, default_workers, run_sweep
from repro.experiments.metrics import (frac, group_rows, metrics_row,
                                       pooled_mean, ratio_of_sums)

__all__ = [
    "Sweep", "FuncSweep", "SimPoint", "FuncPoint",
    "canonical_hash", "canonical_json",
    "ResultCache", "default_cache_dir",
    "Campaign", "run_sweep", "default_workers",
    "metrics_row", "group_rows", "pooled_mean", "frac", "ratio_of_sums",
]
