"""Campaign runner: fan sweep points (SS VIII experiment units) out
across worker processes.

Each point of a :class:`~repro.experiments.spec.Sweep` is one
independent DES run (the simulator is embarrassingly parallel per
point), so the runner simply:

  1. expands the sweep into points and looks each point's content hash
     up in the :class:`~repro.experiments.cache.ResultCache`;
  2. executes only the misses — serially for tiny batches, otherwise on
     a ``ProcessPoolExecutor`` (workers default to the CPU count, or
     the ``REPRO_WORKERS`` env var);
  3. writes each fresh row back to the cache and a campaign manifest
     under the sweep's spec hash.

``Campaign.collect()`` returns the tidy per-point rows in point order,
cache hits and fresh runs interleaved transparently — re-running an
identical sweep touches no simulator at all.
"""
from __future__ import annotations

import functools
import importlib
import os
from concurrent.futures import ProcessPoolExecutor
from pathlib import Path
from typing import Any, Dict, List, Optional, Union

from repro.core.simulator import simulate
from repro.core.taskgen import generate_taskset
from repro.experiments.cache import ResultCache
from repro.experiments.metrics import metrics_row
from repro.experiments.spec import (FuncPoint, FuncSweep, SimPoint, Sweep,
                                    point_from_dict)


def default_workers() -> int:
    env = os.environ.get("REPRO_WORKERS")
    if env:
        return max(int(env), 1)
    return max(os.cpu_count() or 1, 1)


@functools.lru_cache(maxsize=None)
def cached_library(which: str) -> Dict[str, Any]:
    """Per-process workload library ('sim' excludes the arch:* models).
    'sim' is derived from the cached 'all' build, so a process touching
    both pays the program-construction cost once."""
    if which == "sim":
        return {k: v for k, v in cached_library("all").items()
                if not k.startswith("arch:")}
    from repro.core.program import workload_library
    return workload_library(include_archs=True)


def _resolve(fn_ref: str):
    mod_name, _, fn_name = fn_ref.partition(":")
    if not fn_name:
        raise ValueError(f"bad function ref {fn_ref!r}; want 'module:fn'")
    return getattr(importlib.import_module(mod_name), fn_name)


def _run_sim(point: SimPoint) -> Dict[str, Any]:
    programs = cached_library(point.library)
    policy = point.policy_obj()
    tasks = generate_taskset(point.u, gamma=point.gamma,
                             n_tasks=point.n_tasks, cf=point.cf,
                             seed=point.seed, programs=programs)
    m = simulate(tasks, programs, policy, duration=point.duration,
                 seed=point.seed, overrun_prob=point.overrun_prob,
                 cf=point.cf)
    return metrics_row(m, policy=policy.name, u=point.u, gamma=point.gamma,
                       n_tasks=point.n_tasks, set_index=point.set_index,
                       seed=point.seed)


def _run_func(point: FuncPoint) -> Dict[str, Any]:
    kwargs = dict(point.kwargs)
    result = _resolve(point.fn)(**kwargs)
    if not isinstance(result, dict):
        result = {"result": result}
    for k, v in kwargs.items():      # make rows self-describing
        result.setdefault(k, v)
    return result


def _execute(payload: Dict[str, Any]) -> Dict[str, Any]:
    """Top-level worker entry point (must be picklable)."""
    point = point_from_dict(payload)
    if isinstance(point, FuncPoint):
        return _run_func(point)
    return _run_sim(point)


def _echo_point(**kwargs) -> Dict[str, Any]:
    """Trivial FuncSweep target used by the engine's own tests."""
    return {"echo": True, "pid": os.getpid(), **kwargs}


# ----------------------------------------------------------------------
class Campaign:
    """Plan, execute (in parallel, cached) and collect one sweep."""

    def __init__(self, sweep: Union[Sweep, FuncSweep], *,
                 cache_dir: Optional[Union[str, Path]] = None,
                 workers: Optional[int] = None,
                 use_cache: bool = True):
        self.sweep = sweep
        self.workers = default_workers() if workers is None else max(workers, 1)
        self.use_cache = use_cache and getattr(sweep, "cache", True)
        self.cache = ResultCache(cache_dir) if self.use_cache else None
        self.stats = {"hits": 0, "misses": 0}
        self._rows: Optional[List[Dict[str, Any]]] = None

    def run(self) -> "Campaign":
        points = self.sweep.points()
        keys = [p.key() for p in points]
        rows: List[Optional[Dict[str, Any]]] = [None] * len(points)
        todo: List[int] = []
        for i, k in enumerate(keys):
            cached = self.cache.get(k) if self.use_cache else None
            if cached is not None:
                rows[i] = cached
            else:
                todo.append(i)
        self.stats = {"hits": len(points) - len(todo), "misses": len(todo)}

        payloads = [points[i].to_dict() for i in todo]
        if len(payloads) <= 1 or self.workers <= 1:
            fresh = (_execute(p) for p in payloads)
            self._drain(todo, keys, rows, fresh)
        else:
            chunk = max(1, len(payloads) // (self.workers * 8))
            with ProcessPoolExecutor(max_workers=self.workers) as ex:
                self._drain(todo, keys, rows,
                            ex.map(_execute, payloads, chunksize=chunk))

        if self.use_cache:
            self.cache.write_manifest(self.sweep.spec_hash(), {
                "name": self.sweep.name,
                "spec_hash": self.sweep.spec_hash(),
                "spec": self.sweep.to_dict(),
                "n_points": len(points),
                "last_run": dict(self.stats),
                "point_keys": keys,
            })
        self._rows = rows  # type: ignore[assignment]
        return self

    def _drain(self, todo, keys, rows, fresh) -> None:
        """Store rows as they stream in, so a killed campaign keeps
        every completed point and the next run resumes from there."""
        for i, row in zip(todo, fresh):
            rows[i] = row
            if self.use_cache:
                self.cache.put(keys[i], row)

    def collect(self) -> List[Dict[str, Any]]:
        """Tidy per-point rows, in point order (runs the sweep if needed)."""
        if self._rows is None:
            self.run()
        return list(self._rows)  # type: ignore[arg-type]


def run_sweep(sweep: Union[Sweep, FuncSweep],
              **campaign_kw) -> List[Dict[str, Any]]:
    """One-shot convenience: ``Campaign(sweep, **kw).collect()``."""
    return Campaign(sweep, **campaign_kw).collect()
