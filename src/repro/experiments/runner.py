"""Campaign runner: fan sweep points (SS VIII experiment units) out
across worker processes.

Each point of a :class:`~repro.experiments.spec.Sweep` is one
independent DES run (the simulator is embarrassingly parallel per
point), so the runner simply:

  1. expands the sweep into points and looks each point's content hash
     up in the :class:`~repro.experiments.cache.ResultCache`;
  2. executes only the misses — serially for tiny batches, otherwise on
     a ``ProcessPoolExecutor`` (workers default to the CPU count, or
     the ``REPRO_WORKERS`` env var);
  3. writes each fresh row back to the cache and a campaign manifest
     under the sweep's spec hash.

Execution has two shapes:

  * ``engine="event"`` points run one DES per point (``_run_sim``),
    mapped over the pool with taskset construction memoized per worker
    (``_memo_taskset``) — a sweep that revisits the same
    ``(u, gamma, n_tasks, cf, seed)`` cell under several policies
    builds each task set once per worker instead of once per point;
  * ``engine="vec"`` / ``engine="jit"`` points are grouped into whole
    cache-miss *chunks* and handed to the vectorized SoA backend
    (``core.simulator_vec.simulate_vbatch``, which routes ``jit`` on
    to the fully-compiled ``core.simulator_jit`` loop), advancing
    hundreds of points per lockstep step.  The content-addressed cache
    contract is unchanged: every point is still keyed and stored
    individually (vec keys carry ``VEC_SIM_SEMANTICS_VERSION``, jit
    keys ``JIT_SIM_SEMANTICS_VERSION``).

``Campaign.collect()`` returns the tidy per-point rows in point order,
cache hits and fresh runs interleaved transparently — re-running an
identical sweep touches no simulator at all.
"""
from __future__ import annotations

import functools
import importlib
import os
from concurrent.futures import ProcessPoolExecutor, as_completed
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple, Union

from repro.core.simulator import simulate
from repro.core.simulator_vec import simulate_vbatch
from repro.core.taskgen import generate_taskset
from repro.experiments.cache import ResultCache
from repro.experiments.metrics import ensure_row_means, metrics_row
from repro.experiments.spec import (FuncPoint, FuncSweep, SimPoint, Sweep,
                                    point_from_dict, policy_from_dict)
from repro.runtime.device_config import _env_int

# max points per vectorized chunk: wide batches amortize the lockstep
# overhead (hundreds of points per argmin), and one chunk is one unit
# of worker-pool scheduling
VEC_CHUNK = 512


def default_workers() -> int:
    """Worker-pool width: ``REPRO_WORKERS`` (validated — junk or
    non-positive values raise naming the variable) or the CPU count."""
    return _env_int("REPRO_WORKERS", max(os.cpu_count() or 1, 1))


@functools.lru_cache(maxsize=None)
def cached_library(which: str) -> Dict[str, Any]:
    """Per-process workload library ('sim' excludes the arch:* models).
    'sim' is derived from the cached 'all' build, so a process touching
    both pays the program-construction cost once."""
    if which == "sim":
        return {k: v for k, v in cached_library("all").items()
                if not k.startswith("arch:")}
    from repro.core.program import workload_library
    return workload_library(include_archs=True)


def _resolve(fn_ref: str):
    mod_name, _, fn_name = fn_ref.partition(":")
    if not fn_name:
        raise ValueError(f"bad function ref {fn_ref!r}; want 'module:fn'")
    return getattr(importlib.import_module(mod_name), fn_name)


@functools.lru_cache(maxsize=4096)
def _memo_taskset(u: float, gamma: float, n_tasks: int, cf: float,
                  seed: int, library: str):
    """Per-worker taskset memo: sweeps revisit the same generation cell
    under several policies, so build each task set once per process.
    The returned list is shared — callers must not mutate it."""
    return generate_taskset(u, gamma=gamma, n_tasks=n_tasks, cf=cf,
                            seed=seed, programs=cached_library(library))


def _run_sim(point: SimPoint) -> Dict[str, Any]:
    programs = cached_library(point.library)
    policy = point.policy_obj()
    tasks = _memo_taskset(point.u, point.gamma, point.n_tasks, point.cf,
                          point.seed, point.library)
    if point.engine in ("vec", "jit"):
        m = simulate_vbatch([tasks], programs, policy, seeds=[point.seed],
                            duration=point.duration,
                            overrun_prob=point.overrun_prob,
                            cf=point.cf,
                            select_backend="numpy" if point.engine == "vec"
                            else "jit",
                            devices=point.devices,
                            demand_profile=point.demand_profile,
                            scenario=point.scenario)[0]
    else:
        m = simulate(tasks, programs, policy, duration=point.duration,
                     seed=point.seed, overrun_prob=point.overrun_prob,
                     cf=point.cf, demand_profile=point.demand_profile,
                     scenario=point.scenario)
    return metrics_row(m, policy=policy.name, u=point.u, gamma=point.gamma,
                       n_tasks=point.n_tasks, set_index=point.set_index,
                       seed=point.seed)


def _run_func(point: FuncPoint) -> Dict[str, Any]:
    kwargs = dict(point.kwargs)
    result = _resolve(point.fn)(**kwargs)
    if not isinstance(result, dict):
        result = {"result": result}
    for k, v in kwargs.items():      # make rows self-describing
        result.setdefault(k, v)
    return result


def _execute(payload: Dict[str, Any]) -> Dict[str, Any]:
    """Top-level worker entry point (must be picklable)."""
    point = point_from_dict(payload)
    if isinstance(point, FuncPoint):
        return _run_func(point)
    return _run_sim(point)


def _execute_chunk(payloads: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Worker entry point for a whole chunk of points.

    Vec- and jit-engine sim points are grouped by engine plus their
    shared scalar parameters (policy / duration / cf / overrun_prob /
    library) and executed in one ``simulate_vbatch`` call per group —
    the batch-execution fast path.  Anything else in the chunk falls
    back to the per-point runners.  Row order matches the input
    payload order.
    """
    rows: List[Optional[Dict[str, Any]]] = [None] * len(payloads)
    groups: Dict[Tuple, List[Tuple[int, SimPoint]]] = {}
    for i, d in enumerate(payloads):
        point = point_from_dict(d)
        if isinstance(point, SimPoint) and point.engine in ("vec", "jit"):
            key = (point.engine, point.policy, point.duration, point.cf,
                   point.overrun_prob, point.library, point.devices,
                   point.scenario, point.demand_profile)
            groups.setdefault(key, []).append((i, point))
        elif isinstance(point, FuncPoint):
            rows[i] = _run_func(point)
        else:
            rows[i] = _run_sim(point)
    for (engine, pol_items, duration, cf, op, library, devices,
         scenario, demand_profile), items in groups.items():
        programs = cached_library(library)
        policy = policy_from_dict(dict(pol_items))
        tasksets = [_memo_taskset(pt.u, pt.gamma, pt.n_tasks, pt.cf,
                                  pt.seed, library) for _, pt in items]
        seeds = [pt.seed for _, pt in items]
        ms = simulate_vbatch(tasksets, programs, policy, seeds=seeds,
                             duration=duration, overrun_prob=op, cf=cf,
                             batch_size=VEC_CHUNK,
                             select_backend="numpy" if engine == "vec"
                             else "jit",
                             devices=devices,
                             demand_profile=demand_profile,
                             scenario=scenario)
        for (i, pt), m in zip(items, ms):
            rows[i] = metrics_row(
                m, policy=policy.name, u=pt.u, gamma=pt.gamma,
                n_tasks=pt.n_tasks, set_index=pt.set_index, seed=pt.seed)
    return rows  # type: ignore[return-value]


def _echo_point(**kwargs) -> Dict[str, Any]:
    """Trivial FuncSweep target used by the engine's own tests."""
    return {"echo": True, "pid": os.getpid(), **kwargs}


# ----------------------------------------------------------------------
class Campaign:
    """Plan, execute (in parallel, cached) and collect one sweep."""

    def __init__(self, sweep: Union[Sweep, FuncSweep], *,
                 cache_dir: Optional[Union[str, Path]] = None,
                 workers: Optional[int] = None,
                 use_cache: bool = True):
        self.sweep = sweep
        self.workers = default_workers() if workers is None else max(workers, 1)
        self.use_cache = use_cache and getattr(sweep, "cache", True)
        self.cache = ResultCache(cache_dir) if self.use_cache else None
        self.stats = {"hits": 0, "misses": 0}
        self._rows: Optional[List[Dict[str, Any]]] = None

    def run(self) -> "Campaign":
        points = self.sweep.points()
        keys = [p.key() for p in points]
        rows: List[Optional[Dict[str, Any]]] = [None] * len(points)
        todo: List[int] = []
        for i, k in enumerate(keys):
            cached = self.cache.get(k) if self.use_cache else None
            if cached is not None:
                # rows cached before the {name}_mean columns existed
                # are upgraded in place (the mean is derivable from
                # the stored sum/count — no cache invalidation needed)
                rows[i] = ensure_row_means(cached)
            else:
                todo.append(i)
        self.stats = {"hits": len(points) - len(todo), "misses": len(todo)}

        payloads = [points[i].to_dict() for i in todo]
        # vec/jit-engine sim points take the chunked batch-execution
        # path: whole cache-miss chunks go to simulate_vbatch instead
        # of one point per task (each point still cached individually)
        vec_sel = [k for k, i in enumerate(todo)
                   if isinstance(points[i], SimPoint)
                   and points[i].engine in ("vec", "jit")]
        vec_set = set(vec_sel)
        other_sel = [k for k in range(len(todo)) if k not in vec_set]
        if len(payloads) <= 1 or self.workers <= 1:
            if vec_sel:
                out = _execute_chunk([payloads[k] for k in vec_sel])
                self._drain([todo[k] for k in vec_sel], keys, rows, out)
            fresh = (_execute(payloads[k]) for k in other_sel)
            self._drain([todo[k] for k in other_sel], keys, rows, fresh)
        else:
            with ProcessPoolExecutor(max_workers=self.workers) as ex:
                futures = {}
                if vec_sel:
                    per = max(1, min(VEC_CHUNK,
                                     -(-len(vec_sel) // self.workers)))
                    for lo in range(0, len(vec_sel), per):
                        sel = vec_sel[lo:lo + per]
                        fut = ex.submit(_execute_chunk,
                                        [payloads[k] for k in sel])
                        futures[fut] = sel
                if other_sel:
                    chunk = max(1, len(other_sel) // (self.workers * 8))
                    self._drain(
                        [todo[k] for k in other_sel], keys, rows,
                        ex.map(_execute, [payloads[k] for k in other_sel],
                               chunksize=chunk))
                # drain chunks as they finish, so a killed campaign
                # keeps every completed chunk (the per-point streaming
                # guarantee, at chunk granularity)
                for fut in as_completed(futures):
                    sel = futures[fut]
                    self._drain([todo[k] for k in sel], keys, rows,
                                fut.result())

        if self.use_cache:
            self.cache.write_manifest(self.sweep.spec_hash(), {
                "name": self.sweep.name,
                "spec_hash": self.sweep.spec_hash(),
                "spec": self.sweep.to_dict(),
                "n_points": len(points),
                "last_run": dict(self.stats),
                "point_keys": keys,
            })
        self._rows = rows  # type: ignore[assignment]
        return self

    def _drain(self, todo, keys, rows, fresh) -> None:
        """Store rows as they stream in, so a killed campaign keeps
        every completed point and the next run resumes from there."""
        for i, row in zip(todo, fresh):
            rows[i] = row
            if self.use_cache:
                self.cache.put(keys[i], row)

    def collect(self) -> List[Dict[str, Any]]:
        """Tidy per-point rows, in point order (runs the sweep if needed)."""
        if self._rows is None:
            self.run()
        return list(self._rows)  # type: ignore[arg-type]


def run_sweep(sweep: Union[Sweep, FuncSweep],
              **campaign_kw) -> List[Dict[str, Any]]:
    """One-shot convenience: ``Campaign(sweep, **kw).collect()``."""
    return Campaign(sweep, **campaign_kw).collect()
