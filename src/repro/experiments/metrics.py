"""Tidy per-point metric rows and cross-point aggregation helpers (the
SS VIII figures' statistics, exact under re-grouping).

The simulator returns a :class:`repro.core.simulator.RunMetrics` full of
per-event lists; the cache and the figure reports want flat, JSON-able
rows.  ``metrics_row`` flattens one run into sums/counts (not means), so
any grouping of rows can be re-aggregated exactly: a pooled mean over a
cell equals the mean over the concatenated per-event lists the legacy
serial scripts computed.
"""
from __future__ import annotations

import math
from collections import defaultdict
from typing import Any, Dict, Iterable, List, Tuple

from repro.core.simulator import AggSamples, RunMetrics

# the sample families metrics_row flattens (also the upgrade list for
# rows cached before the {name}_mean columns existed)
SAMPLE_FAMILIES = ("pi", "ci", "save", "restore")


def ensure_row_means(row: Dict[str, Any]) -> Dict[str, Any]:
    """Backfill the ``{name}_mean`` columns on a row cached before
    they existed (event/vec cache namespaces were deliberately NOT
    invalidated for a derivable column — the mean is a pure function
    of the stored sum/count).  Fresh rows and non-sim rows (no
    ``{name}_n`` keys) pass through untouched."""
    for name in SAMPLE_FAMILIES:
        n_key, mean_key = f"{name}_n", f"{name}_mean"
        if n_key in row and mean_key not in row:
            n = row[n_key]
            row[mean_key] = row[f"{name}_sum"] / n if n else None
    return row


def metrics_row(m: RunMetrics, **point_fields: Any) -> Dict[str, Any]:
    """Flatten one run's metrics into a JSON-able row.

    ``point_fields`` (policy name, u, gamma, ...) are merged in so rows
    are self-describing and groupable without the originating spec.
    Per-event lists may arrive pre-aggregated as
    :class:`~repro.core.simulator.AggSamples` (the jit backend carries
    sums/counts on-device instead of sample lists).  Each sample
    family also yields a per-run ``{name}_mean``; a zero-count
    aggregate means ``None`` (NaN's JSON-/equality-safe spelling —
    see the inline note) rather than raising ``ZeroDivisionError``.
    """
    row: Dict[str, Any] = dict(point_fields)
    for name, xs in (("pi", m.pi_blocking), ("ci", m.ci_blocking),
                     ("save", m.save_cycles), ("restore", m.restore_cycles)):
        if not isinstance(xs, AggSamples):
            xs = AggSamples(float(sum(xs)), len(xs))
        row[f"{name}_sum"] = xs.total
        row[f"{name}_n"] = xs.n
        # per-run mean via the one canonical definition
        # (AggSamples.mean: NaN when empty — zero blocking/save events
        # is normal), with NaN encoded as None in the row: the JSON-
        # safe spelling that also keeps row equality usable — NaN !=
        # NaN would break the cross-engine row-comparison gates and
        # the cache round-trip, None == None does not
        mean = xs.mean
        row[f"{name}_mean"] = None if math.isnan(mean) else mean
    row.update(
        jobs_lo=m.jobs["LO"], jobs_hi=m.jobs["HI"],
        done_lo=m.done["LO"], done_hi=m.done["HI"],
        misses_lo=m.misses["LO"], misses_hi=m.misses["HI"],
        misses_by_mode=dict(m.misses_by_mode),
        lo_released_in_hi=m.lo_released_in_hi,
        lo_done_in_hi=m.lo_done_in_hi,
        mode_cycles=dict(m.mode_cycles),
        cs_count=m.cs_count,
        exec_cycles=float(m.exec_cycles),
        overhead_cycles=float(m.overhead_cycles),
        success_all=int(m.success()),
        success_hi=int(m.success("HI")),
        survivability=float(m.survivability()),
    )
    return row


# ----------------------------------------------------------------------
def group_rows(rows: Iterable[Dict[str, Any]],
               *keys: str) -> Dict[Tuple, List[Dict[str, Any]]]:
    """Group rows by the given field names (insertion-ordered)."""
    out: Dict[Tuple, List[Dict[str, Any]]] = defaultdict(list)
    for r in rows:
        out[tuple(r[k] for k in keys)].append(r)
    return dict(out)


def pooled_mean(rows: Iterable[Dict[str, Any]], name: str) -> float:
    """Mean of the concatenated per-event list ``name`` across rows
    (rows carry ``{name}_sum`` / ``{name}_n``).  A cell with zero
    events pools to NaN — "no samples" must read as *no data*, not as
    a blocking time of 0.0 — and never raises ``ZeroDivisionError``."""
    rows = list(rows)
    n = sum(r[f"{name}_n"] for r in rows)
    if n == 0:
        return float("nan")
    return sum(r[f"{name}_sum"] for r in rows) / n


def frac(rows: Iterable[Dict[str, Any]], field: str) -> float:
    """Mean of a per-row scalar (e.g. ``success_all`` -> success ratio)."""
    rows = list(rows)
    if not rows:
        return 0.0
    return sum(r[field] for r in rows) / len(rows)


def ratio_of_sums(rows: Iterable[Dict[str, Any]], num: str,
                  den: str) -> float:
    rows = list(rows)
    d = sum(r[den] for r in rows)
    return sum(r[num] for r in rows) / d if d else float("nan")
