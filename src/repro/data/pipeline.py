"""Deterministic, resumable, shardable synthetic token pipeline.

Batches are a pure function of (seed, step, shard) — there is no iterator
state, so checkpoint/restart and elastic re-sharding are trivial: restore
``step`` and the pipeline continues bit-identically on any mesh layout.

The token distribution is a learnable mixture (so training-loss curves are
meaningful, not flat):
  * a dataset-global affine map  t_{i+1} = (a * t_i + c) mod V  (the model
    can memorize it as a next-token lookup -> loss drops toward the noise
    floor)
  * copy spans (induction heads)
  * uniform noise tokens
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise_frac: float = 0.1

    def _rng(self, step: int, shard: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))

    def batch(self, step: int, *, shard: int = 0, n_shards: int = 1
              ) -> Dict[str, np.ndarray]:
        """Tokens+labels for this step; ``shard`` of ``n_shards`` slices the
        global batch (data parallelism)."""
        assert self.global_batch % n_shards == 0
        b = self.global_batch // n_shards
        rng = self._rng(step, shard)
        V, S = self.vocab, self.seq_len
        g = np.random.default_rng(self.seed)          # dataset-global map
        a = np.full((b, 1), int(g.integers(2, min(V - 1, 97))))
        c = np.full((b, 1), int(g.integers(0, V)))
        t0 = rng.integers(0, V, size=(b, 1))
        toks = np.empty((b, S + 1), np.int64)
        toks[:, :1] = t0
        for i in range(S):
            toks[:, i + 1] = (a[:, 0] * toks[:, i] + c[:, 0]) % V
        # splice copy spans
        span = max(4, S // 8)
        starts = rng.integers(0, max(S - 2 * span, 1), size=b)
        for j in range(b):
            s0 = starts[j]
            toks[j, s0 + span: s0 + 2 * span] = toks[j, s0: s0 + span]
        noise = rng.random((b, S + 1)) < self.noise_frac
        toks = np.where(noise, rng.integers(0, V, size=(b, S + 1)), toks)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def batch_for_arch(cfg: ArchConfig, seq_len: int, global_batch: int,
                   step: int, *, seed: int = 0, shard: int = 0,
                   n_shards: int = 1) -> Dict[str, Any]:
    """Family-aware batch (audio codebooks / VLM patch-embedding stubs)."""
    if cfg.family == "audio":
        ds = SyntheticLM(cfg.vocab, seq_len * cfg.n_codebooks, global_batch,
                         seed=seed)
        b = ds.batch(step, shard=shard, n_shards=n_shards)
        K = cfg.n_codebooks
        return {k: v.reshape(v.shape[0], seq_len, K) for k, v in b.items()}
    ds = SyntheticLM(cfg.vocab, seq_len, global_batch, seed=seed)
    b = ds.batch(step, shard=shard, n_shards=n_shards)
    if cfg.family == "vlm" and cfg.n_frontend_tokens > 0:
        nf = min(cfg.n_frontend_tokens, seq_len // 2)
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, step, shard, 7]))
        bsz = b["tokens"].shape[0]
        b = {k: v[:, :seq_len - nf] for k, v in b.items()}
        b["vis_embeds"] = rng.standard_normal(
            (bsz, nf, cfg.d_model)).astype(np.float32) * 0.02
    return b
