"""Counter-based common-random-number primitives for the scenario layer.

Every scenario draw in the repository is a pure function of a 64-bit
key — no host RNG state, no draw-order dependence — built from the same
splitmix64 finalizer the jit engine's demand draws use
(``core.simulator_jit``):

    stream seed   s0  = point_seed64 ^ stream_salt(scenario, component)
    counter       ctr = (entity << 33) + (index << 1)
    draw          u   = u01(mix64(s0 + ctr * GOLD))

``stream_salt`` derives a fixed 64-bit constant per (scenario,
component) name via sha256 — the same idiom as
``repro.serving.traffic.stream_key`` — so scenario streams are
decorrelated from the engines' demand streams (which use the unsalted
point seed) and from each other, while staying comparable under common
random numbers: the draw for (seed, scenario, entity, index) is
byte-identical across engines, policies, batch compositions and device
counts.

All helpers are ``xp``-generic: pass ``numpy`` for the host engines
(event/vec) or ``jax.numpy`` for the compiled lockstep — the integer
ops are plain operators and the float ops are IEEE-754 double
multiplies/divides, so both backends produce bit-identical doubles.
"""
from __future__ import annotations

import hashlib

import numpy as np

#: splitmix64 golden-ratio increment (same constant as the jit engine).
GOLD = np.uint64(0x9E3779B97F4A7C15)

_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)


def mix64(x):
    """splitmix64 finalizer — identical to the jit engine's ``_mix64``.

    Works on numpy and jax uint64 arrays alike (plain operators only;
    uint64 wraparound is the point, so numpy's scalar overflow warning
    is suppressed).
    """
    with np.errstate(over="ignore"):
        x = (x ^ (x >> np.uint64(30))) * _M1
        x = (x ^ (x >> np.uint64(27))) * _M2
        return x ^ (x >> np.uint64(31))


def u01(bits):
    """Top 53 bits of a uint64 -> uniform double in [0, 1) (identical
    to the jit engine's ``_u01``)."""
    return (bits >> np.uint64(11)).astype(np.float64) * (1.0 / (1 << 53))


def stream_salt(name: str) -> np.uint64:
    """Fixed 64-bit salt for a named scenario stream.

    sha256-derived (first 8 bytes, little-endian), so salts are stable
    across runs/platforms and adding a stream never perturbs existing
    ones."""
    digest = hashlib.sha256(f"repro.scenario:{name}".encode()).digest()
    return np.uint64(int.from_bytes(digest[:8], "little"))


def counter(entity, index):
    """Pack (entity, index) into the draw counter: entity in the high
    bits (task/lane/window id), index shifted left once so the low bit
    stays free for sub-draws — the same layout as the jit demand draw's
    ``(task << 33) + (release_n << 1)``."""
    return (entity.astype(np.uint64) << np.uint64(33)) \
        + (index.astype(np.uint64) << np.uint64(1))


def keyed_u01(seed64, salt: np.uint64, entity, index, sub: int = 0):
    """One CRN draw: uniform double keyed (seed, stream, entity, index).

    ``sub`` selects independent sub-draws at the same counter (the
    ``+ k * GOLD`` trick the jit demand draw uses for its second
    uniform)."""
    with np.errstate(over="ignore"):
        s = (seed64 ^ salt) + counter(entity, index) * GOLD
        if sub:
            s = s + np.uint64(sub) * GOLD
    return u01(mix64(s))
