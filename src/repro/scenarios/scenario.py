"""Declarative fault/demand scenarios that compile into every engine.

A :class:`Scenario` is a frozen bundle of *components*, each either off
(its knob at the neutral value) or on:

demand-profile components (shape the per-release demand draw)
  * ``heavy_tail`` — with probability ``heavy_tail_prob`` a release's
    demand is stretched by the bounded rational tail
    ``1 + scale * u / (1 - q * u)`` (u uniform on the 2**-26 grid;
    max ``1 + scale/(1-q)``) — heavy-tailed-looking outliers from
    FMA-contraction-immune arithmetic, so the host (numpy) and
    compiled (XLA) engines agree bit for bit;
  * ``burst`` (correlated) — virtual time is cut into
    ``burst_window``-cycle windows; one keyed draw *per window* decides
    whether every release inside it is stretched by ``burst_factor``
    (all tasks of a point see the same burst — correlated demand);
  * ``phase_shift`` — each task's initial release phase is shifted by
    ``phase_shift * u`` periods (keyed per task, applied host-side at
    batch init, so all three engines see identical phases).

fault components (environmental stretch on top of any demand profile)
  * ``dma`` contention storm — per-release keyed coin: demand runs
    ``dma_factor`` slower with probability ``dma_prob``;
  * ``thermal`` throttle — deterministic duty-cycle slowdown: releases
    inside the first ``thermal_duty`` fraction of each
    ``thermal_period`` window run ``thermal_factor`` slower;
  * ``instance loss`` (serving only) — a lane inside a keyed
    ``loss_window_s`` outage window cannot start new work until the
    window passes (in-flight requests finish; the open-loop driver
    shrinks the live lane set — see ``serving.frontend``).

Compilation contract: :func:`demand_multiplier` is the single
implementation of the release-time fault arithmetic, parameterized by
the array namespace ``xp`` (``numpy`` for the event/vec engines,
``jax.numpy`` for the jit lockstep).  All draws are counter-based CRN
streams (``scenarios.crn``) keyed ``(seed ^ salt(component), task,
release_index)`` — policy-free, order-free, engine-free — so the same
scenario realization is applied under every policy and engine, and
``scenario=None`` leaves every engine byte-identical to the scenario-
free code path.  docs/scenarios.md walks through the model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Union

import numpy as np

from repro.scenarios.crn import keyed_u01, stream_salt

_SALT_HEAVY_TAIL = stream_salt("heavy_tail")
_SALT_BURST = stream_salt("burst")
_SALT_PHASE = stream_salt("phase_shift")
_SALT_DMA = stream_salt("dma")
_SALT_THERMAL = stream_salt("thermal")
_SALT_LOSS = stream_salt("instance_loss")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One declarative scenario: a named, hashable component bundle.

    Frozen and hashable on purpose — the jit engine keys its compiled-
    runner memo on the scenario, so each scenario compiles exactly once
    per policy class and ``scenario=None`` shares the scenario-free
    graph.  Neutral values (probability 0, window/duty 0, factor 1)
    switch a component off *statically*: disabled components add zero
    operations to any engine."""
    name: str
    # demand-profile components
    heavy_tail_prob: float = 0.0
    heavy_tail_scale: float = 0.0
    heavy_tail_q: float = 0.85
    burst_window: float = 0.0
    burst_prob: float = 0.0
    burst_factor: float = 1.0
    phase_shift: float = 0.0
    # fault components
    dma_prob: float = 0.0
    dma_factor: float = 1.0
    thermal_period: float = 0.0
    thermal_duty: float = 0.0
    thermal_factor: float = 1.0
    # serving-only component
    loss_prob: float = 0.0
    loss_window_s: float = 0.0

    # -- static component gates (Python-level: compiled out when off) --
    @property
    def has_heavy_tail(self) -> bool:
        return self.heavy_tail_prob > 0.0 and self.heavy_tail_scale > 0.0

    @property
    def has_burst(self) -> bool:
        return self.burst_window > 0.0 and self.burst_prob > 0.0 \
            and self.burst_factor != 1.0

    @property
    def has_phase_shift(self) -> bool:
        return self.phase_shift > 0.0

    @property
    def has_dma(self) -> bool:
        return self.dma_prob > 0.0 and self.dma_factor != 1.0

    @property
    def has_thermal(self) -> bool:
        return self.thermal_period > 0.0 and self.thermal_duty > 0.0 \
            and self.thermal_factor != 1.0

    @property
    def has_loss(self) -> bool:
        return self.loss_prob > 0.0 and self.loss_window_s > 0.0

    @property
    def affects_demand(self) -> bool:
        return (self.has_heavy_tail or self.has_burst or self.has_dma
                or self.has_thermal)


def faults(intensity: float) -> Scenario:
    """The parameterized ``faults@<intensity>`` family fig13 sweeps.

    ``intensity`` in [0, 1] scales a combined environmental-fault
    scenario — correlated contention bursts + DMA stretch + thermal
    duty-cycle — from "off" (an intensity-0 scenario is the neutral
    multiplier: bit-identical results to ``scenario=None``) to a
    heavily degraded MPSoC."""
    x = float(intensity)
    if not 0.0 <= x <= 1.0:
        raise ValueError(
            f"scenario 'faults@<intensity>' needs intensity in [0, 1], "
            f"got {intensity!r}")
    return Scenario(
        name=f"faults@{x:g}",
        burst_window=2e5, burst_prob=0.3 * x, burst_factor=1.0 + 0.4 * x,
        dma_prob=0.35 * x, dma_factor=1.0 + 0.3 * x,
        thermal_period=1e6, thermal_duty=0.4 * x,
        thermal_factor=1.0 + 0.5 * x,
        loss_prob=0.5 * x, loss_window_s=0.25)


#: Named scenario registry (the ``faults@<intensity>`` family rides
#: along via :func:`get_scenario`'s name parser).
SCENARIOS = {
    "heavy_tail": Scenario(name="heavy_tail", heavy_tail_prob=0.2,
                           heavy_tail_scale=0.6, heavy_tail_q=0.85),
    "burst": Scenario(name="burst", burst_window=1e5, burst_prob=0.25,
                      burst_factor=1.3),
    "phase_shift": Scenario(name="phase_shift", phase_shift=1.0),
    "dma_storm": Scenario(name="dma_storm", dma_prob=0.3,
                          dma_factor=1.25),
    "thermal_throttle": Scenario(name="thermal_throttle",
                                 thermal_period=1e6, thermal_duty=0.3,
                                 thermal_factor=1.4),
    "instance_loss": Scenario(name="instance_loss", loss_prob=0.35,
                              loss_window_s=0.25),
}


def get_scenario(scenario: Union[None, str, Scenario]) -> \
        Optional[Scenario]:
    """Resolve a scenario spec (None | name | ``faults@x`` | Scenario).

    The single loud-validation choke point: every layer (Sweep, the
    engines, the serving driver) resolves through here, so an unknown
    name raises the same ``ValueError`` naming the ``scenario``
    argument everywhere."""
    if scenario is None or isinstance(scenario, Scenario):
        return scenario
    if scenario in SCENARIOS:
        return SCENARIOS[scenario]
    if isinstance(scenario, str) and scenario.startswith("faults@"):
        try:
            x = float(scenario[len("faults@"):])
        except ValueError:
            raise ValueError(
                f"unknown scenario {scenario!r}: the faults family is "
                f"'faults@<intensity>' with a float intensity in [0, 1]"
            ) from None
        return faults(x)
    raise ValueError(
        f"unknown scenario {scenario!r}; want None, one of "
        f"{sorted(SCENARIOS)}, or 'faults@<intensity>'")


# ----------------------------------------------------------------------
# The release-time compilation target (shared by all three engines)
# ----------------------------------------------------------------------

#: Scenario draws that feed a ``c - a*b`` pattern live on this grid —
#: see :func:`_nofuse` for why.
_GRID = 2.0 ** 26


def _snap(x: float) -> float:
    """Snap a scenario parameter onto the 2**-26 grid (host-side, at
    trace/definition time — the snapped value is what both engines
    compile against)."""
    return round(x * _GRID) / _GRID


def _nofuse(xp, x):
    """Materialize a product before it meets a subtract — best effort.

    XLA's LLVM backend contracts ``c - a*b`` into an FMA, which rounds
    once instead of twice — a 1-ulp divergence from numpy that breaks
    the vec<->jit bit-exactness gate.  ``lax.optimization_barrier``
    does not help (the contraction happens below HLO), but routing the
    product through ``abs`` usually does: LLVM will not fuse through
    ``fabs``, and for the non-negative products used here ``abs`` is
    the bitwise identity.

    Caveat: when LLVM can *prove* the product non-negative (e.g. a
    u01 draw times a positive constant), it eliminates the ``fabs``
    and contracts anyway.  Such sites must instead make the product
    *exact* so fused and unfused subtracts round identically: quantize
    both factors to the 2**-26 grid (26+26 mantissa bits fit f64's
    53), as the heavy-tail component does with :data:`_GRID` /
    :func:`_snap`."""
    return xp.abs(x)


def burst_multiplier(scen: Scenario, xp, seed64, window):
    """Per-window correlated-burst multiplier (one draw per window,
    keyed (seed, 'burst', window) — every release in an active window
    sees the same stretch).  ``window`` is the integer window index;
    the jit engine caches the draw in its ``sw``/``sm`` carry tensors,
    which is sound exactly because this is a pure function of
    (seed, window)."""
    u = keyed_u01(seed64, _SALT_BURST, window, np.uint64(0))
    return xp.where(u < scen.burst_prob, scen.burst_factor, 1.0)


def demand_multiplier(scen: Scenario, xp, seed64, task_col, rel_n,
                      t_rel, burst_m=None):
    """The scenario's demand stretch for one release, as an array op.

    Pure function of ``(seed64, task_col, rel_n, t_rel)`` — the point
    seed, the task column, the task's absolute release index (counted
    over *all* release events, accepted or missed, so it is identical
    across policies), and the release time.  Component order is fixed
    (heavy_tail, burst, dma, thermal) so the float product associates
    identically in every engine.  Returns ``None`` when no demand
    component is active (callers skip the multiply — the neutral
    scenario costs nothing), else a float64 array to multiply into the
    base demand.  ``burst_m`` lets the jit engine supply its carry-
    cached per-window draw."""
    m = None

    def _mul(m, f):
        return f if m is None else m * f

    if scen.has_heavy_tail:
        ua = keyed_u01(seed64, _SALT_HEAVY_TAIL, task_col, rel_n)
        # ub and q live on the 2**-26 grid so q*ub is exact in f64 and
        # FMA contraction of 1 - q*ub is harmless (see _nofuse caveat —
        # abs cannot protect a provably-non-negative product).
        ub = xp.floor(
            keyed_u01(seed64, _SALT_HEAVY_TAIL, task_col, rel_n, sub=1)
            * _GRID) / _GRID
        q = _snap(scen.heavy_tail_q)
        tail = 1.0 + scen.heavy_tail_scale * ub / (1.0 - q * ub)
        m = _mul(m, xp.where(ua < scen.heavy_tail_prob, tail, 1.0))
    if scen.has_burst:
        if burst_m is None:
            burst_m = burst_multiplier(
                scen, xp, seed64, burst_window_index(scen, xp, t_rel))
        m = _mul(m, burst_m)
    if scen.has_dma:
        ud = keyed_u01(seed64, _SALT_DMA, task_col, rel_n)
        m = _mul(m, xp.where(ud < scen.dma_prob, scen.dma_factor, 1.0))
    if scen.has_thermal:
        k = xp.floor(t_rel / scen.thermal_period)
        pos = t_rel - _nofuse(xp, k * scen.thermal_period)
        on = scen.thermal_duty * scen.thermal_period
        m = _mul(m, xp.where(pos < on, scen.thermal_factor, 1.0))
    return m


def burst_window_index(scen: Scenario, xp, t_rel):
    """Integer burst-window index of a release time (int32: the dtype
    of the jit carry's ``sw`` cache tensor)."""
    return xp.floor(t_rel / scen.burst_window).astype(np.int32)


def shifted_phases(scen: Scenario, seed64, task_col, phase, period):
    """Apply the phase-shift component to host-drawn release phases.

    ``phase`` is the engine's own ``rng.uniform(0, period)`` draw; the
    shift fraction is a keyed CRN draw per (seed, task), so every
    engine lands on identical shifted phases.  Wraps back into
    [0, period) with one exact subtract (the shift is < one period)."""
    if not scen.has_phase_shift:
        return phase
    frac = scen.phase_shift * keyed_u01(seed64, _SALT_PHASE, task_col,
                                        np.uint64(0))
    shifted = phase + frac * period
    return np.where(shifted >= period, shifted - period, shifted)


def lane_lost(scen: Optional[Scenario], seed: int, lane: int,
              t: float) -> bool:
    """Serving instance loss: is ``lane`` inside a keyed outage window
    at virtual time ``t``?  One draw per (seed, lane, window) — lost
    lanes recover when their window passes, and the realization is
    identical across policies (common random numbers)."""
    if scen is None or not scen.has_loss:
        return False
    w = np.uint64(int(t // scen.loss_window_s))
    u = keyed_u01(np.int64(seed).astype(np.uint64), _SALT_LOSS,
                  np.uint64(lane), w)
    return bool(u < scen.loss_prob)


def next_loss_boundary(scen: Scenario, t: float) -> float:
    """First instant after ``t`` at which a lost lane's outage window
    can end (the open-loop driver jumps here when every live lane is
    lost).

    Guarantees strict progress: the returned instant maps to a window
    index greater than ``t``'s.  Plain ``(w + 1) * window`` does not —
    e.g. ``0.9 // 0.05 == 17.0`` while ``18 * 0.05 == 0.9``, so a clock
    sitting on that boundary would jump to itself and the driver would
    spin forever."""
    win = scen.loss_window_s
    w = int(t // win)
    b = (w + 1) * win
    while int(b // win) <= w:      # float rounding kept the old window
        b = math.nextafter(b, math.inf)
    return b
