"""Declarative fault-injection / demand-profile scenario layer.

Public surface:

  * :class:`~repro.scenarios.scenario.Scenario` — frozen component
    bundle (demand profiles: heavy-tail, correlated burst, phase
    shift; faults: DMA stretch, thermal throttle, serving instance
    loss);
  * :data:`~repro.scenarios.scenario.SCENARIOS` /
    :func:`~repro.scenarios.scenario.get_scenario` — the named
    registry plus the parameterized ``faults@<intensity>`` family
    (fig13's sweep axis), with loud validation;
  * :func:`~repro.scenarios.scenario.demand_multiplier` and friends —
    the xp-generic (numpy / jax.numpy) release-time arithmetic each
    engine compiles in;
  * :mod:`~repro.scenarios.crn` — the counter-based splitmix64 CRN
    primitives scenario streams draw from.

See docs/scenarios.md for the component model and the per-engine
compilation story.
"""
from repro.scenarios.crn import (GOLD, counter, keyed_u01, mix64,
                                 stream_salt, u01)
from repro.scenarios.scenario import (SCENARIOS, Scenario,
                                      burst_multiplier,
                                      burst_window_index,
                                      demand_multiplier, faults,
                                      get_scenario, lane_lost,
                                      next_loss_boundary,
                                      shifted_phases)

__all__ = [
    "GOLD", "SCENARIOS", "Scenario", "burst_multiplier",
    "burst_window_index", "counter", "demand_multiplier", "faults",
    "get_scenario", "keyed_u01", "lane_lost", "mix64",
    "next_loss_boundary", "shifted_phases", "stream_salt", "u01",
]
