"""Task model and Task Control Block (paper SS VI.A).

Each sporadic task tau_i = (P_i, T_i, D_i, C_i^LO, C_i^HI, L_i, eta_i).
The TCB extends it with runtime state: program counter into the
instruction stream, data locations (accelerator banks vs DRAM addresses),
timers and status — exactly the fields the paper's monitor (SS VI.B)
tracks.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional


class Crit(enum.Enum):
    LO = "LO"
    HI = "HI"


class Status(enum.Enum):
    READY = "ready"
    RUNNING = "running"
    PENDING = "pending"        # not released / finished current job
    INTERRUPTED = "interrupted"


@dataclasses.dataclass
class TaskParams:
    tid: int
    priority: int              # smaller = higher priority
    period: float              # T_i (cycles)
    deadline: float            # D_i (cycles)
    c_lo: float                # LO-WCET (cycles)
    c_hi: float                # HI-WCET (cycles)
    crit: Crit
    eta: int                   # scratchpad banks needed at full speed
    uses_accelerator: bool = True
    workload: Optional[str] = None   # program library key


@dataclasses.dataclass
class TCB:
    params: TaskParams
    status: Status = Status.PENDING
    pc: int = 0                          # next instruction index
    job_release: float = 0.0
    job_deadline: float = 0.0
    exec_cycles: float = 0.0             # consumed in current job
    budget_overrun: bool = False         # exceeded C_LO (HI-task)
    data_in_accel: bool = False
    banks_held: List[int] = dataclasses.field(default_factory=list)
    dram_addresses: Dict[str, int] = dataclasses.field(default_factory=dict)
    config_snapshot: Optional[tuple] = None
    remap_snapshot: Optional[dict] = None
    pending_resend: List[int] = dataclasses.field(default_factory=list)
    jobs_released: int = 0
    jobs_done: int = 0
    deadline_misses: int = 0
    released_in_hi: bool = False         # LO job released outside LO-mode
    # paper metrics
    blocked_since: Optional[float] = None
    blocking_cause: Optional[str] = None  # 'pi' | 'ci'

    @property
    def tid(self) -> int:
        return self.params.tid

    def release(self, now: float):
        self.status = Status.READY
        self.pc = 0
        self.exec_cycles = 0.0
        self.budget_overrun = False
        self.job_release = now
        self.job_deadline = now + self.params.deadline
        self.jobs_released += 1

    def remaining_budget(self, hi_mode: bool) -> float:
        c = self.params.c_hi if hi_mode else self.params.c_lo
        return max(c - self.exec_cycles, 0.0)
