"""Task monitor (paper SS VI.B): TCB registry + per-task LO-WCET timers.

In the discrete-event simulator the timer interrupt is the 'overrun' event;
this module provides the standalone monitor used by the real executor path
(examples/mcs_serve.py) where wall-clock budgets are tracked per task.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

from repro.core.task import Crit, Status, TCB, TaskParams


class TaskMonitor:
    def __init__(self, on_overrun: Optional[Callable[[TCB], None]] = None):
        self.tcbs: Dict[int, TCB] = {}
        self.on_overrun = on_overrun
        self._started_at: Dict[int, float] = {}
        self._accumulated: Dict[int, float] = {}

    def register(self, params: TaskParams) -> TCB:
        tcb = TCB(params=params)
        self.tcbs[params.tid] = tcb
        self._accumulated[params.tid] = 0.0
        return tcb

    # --- timers (Monitor.Timer.* in Alg. 1) -------------------------------
    def timer_set(self, tid: int):
        self._accumulated[tid] = 0.0

    def timer_activate(self, tid: int, now: Optional[float] = None):
        # wall-clock fallback is this monitor's documented contract: the
        # real-executor path tracks budgets in wall time; simulator
        # callers always inject `now`
        self._started_at[tid] = now if now is not None else time.monotonic()  # repro-lint: disable=no-wall-clock

    def timer_pause(self, tid: int, now: Optional[float] = None):
        t0 = self._started_at.pop(tid, None)
        if t0 is not None:
            t1 = now if now is not None else time.monotonic()  # repro-lint: disable=no-wall-clock
            self._accumulated[tid] += t1 - t0
            tcb = self.tcbs[tid]
            tcb.exec_cycles = self._accumulated[tid]
            if (tcb.params.crit == Crit.HI
                    and self._accumulated[tid] > tcb.params.c_lo
                    and not tcb.budget_overrun):
                tcb.budget_overrun = True
                if self.on_overrun:
                    self.on_overrun(tcb)

    def timer_is_zero(self, tid: int) -> bool:
        return self._accumulated.get(tid, 0.0) == 0.0

    def elapsed(self, tid: int) -> float:
        return self._accumulated.get(tid, 0.0)

    # --- status ------------------------------------------------------------
    def update_status(self, tid: int, status: Status):
        self.tcbs[tid].status = status
