"""Gemmini^RT instruction set (paper SS V.A, Tbl. I + base Gemmini ops).

The accelerator executes a *stream* of instructions.  Base ops mirror
Gemmini (CONFIG_*, MVIN/MVOUT, PRELOAD, COMPUTE); the RT extensions are the
paper's contribution: freeze, step-wise moves over the *default
configuration channel* (state moves that do not disturb the live config),
config-copy-buffer moves, reconfig, remapping-block moves and flush_x.

Costs are in accelerator cycles (100 MHz reference clock, as the paper's
FPGA).  The cost model mirrors Gemmini's micro-architecture: DMA moves
bounded by bus width (128 bit = 16 B/cycle), 16x16 systolic tile computes
bounded by K (+ pipeline latency), 2-cycle config writes.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple


class Op(enum.Enum):
    # --- base Gemmini ---
    CONFIG_LD = "config_ld"
    CONFIG_ST = "config_st"
    CONFIG_EX = "config_ex"
    CONFIG_NORM = "config_norm"
    MVIN = "mvin"
    MVOUT = "mvout"
    PRELOAD = "preload"
    COMPUTE = "compute"
    FENCE = "fence"
    # --- Gemmini^RT extensions (Tbl. I) ---
    INSTRUCTION_FREEZE = "instruction_freeze"
    STEP_WISE_MVIN = "step_wise_mvin"
    STEP_WISE_MVOUT = "step_wise_mvout"
    MVIN_CONFIG_BUFFER = "mvin_config_buffer"
    MVOUT_CONFIG_BUFFER = "mvout_config_buffer"
    RECONFIG = "reconfig"
    MVIN_REMAPPING_BLOCK = "mvin_remapping_block"
    MVOUT_REMAPPING_BLOCK = "mvout_remapping_block"
    FLUSH = "flush"          # flush_x: x in operand.meta['what']


CONFIG_OPS = (Op.CONFIG_LD, Op.CONFIG_ST, Op.CONFIG_EX, Op.CONFIG_NORM)
MOVE_OPS = (Op.MVIN, Op.MVOUT, Op.STEP_WISE_MVIN, Op.STEP_WISE_MVOUT)

# hardware constants (paper SS VIII experimental platform)
DMA_BYTES_PER_CYCLE = 16          # 128-bit bus
DMA_SETUP_CYCLES = 20             # request setup / TLB hit
TILE_DIM = 16                     # 16x16 systolic tile (256 PEs)
CONFIG_CYCLES = 2                 # executed in the reservation station
SCRATCHPAD_BANKS = 8
BANK_BYTES = 32 * 1024
ACCUM_BYTES = 64 * 1024
REMAP_BLOCK_BYTES = 4 * 1024
FREEZE_CYCLES = 2
FLUSH_CYCLES = 10


@dataclasses.dataclass(frozen=True)
class Instruction:
    op: Op
    bytes: int = 0                 # data moved (move ops)
    k: int = 0                     # contraction depth (compute ops)
    operator: int = 0              # operator id (algorithm-boundary marker)
    last_in_operator: bool = False
    meta: Optional[Tuple] = None

    @property
    def cost(self) -> int:
        """Execution cycles once issued (the paper's Fig. 2(c) quantity)."""
        return instruction_cost(self)


def instruction_cost(ins: Instruction) -> int:
    if ins.op in CONFIG_OPS or ins.op == Op.RECONFIG:
        return CONFIG_CYCLES if ins.op != Op.RECONFIG else 4 * CONFIG_CYCLES
    if ins.op in MOVE_OPS:
        return DMA_SETUP_CYCLES + -(-ins.bytes // DMA_BYTES_PER_CYCLE)
    if ins.op == Op.MVOUT_CONFIG_BUFFER or ins.op == Op.MVIN_CONFIG_BUFFER:
        return DMA_SETUP_CYCLES + 4  # 4 stored config words
    if ins.op in (Op.MVIN_REMAPPING_BLOCK, Op.MVOUT_REMAPPING_BLOCK):
        return DMA_SETUP_CYCLES + REMAP_BLOCK_BYTES // DMA_BYTES_PER_CYCLE
    if ins.op == Op.PRELOAD:
        return TILE_DIM  # stream a tile into the array
    if ins.op == Op.COMPUTE:
        return max(ins.k, 1) + 2 * TILE_DIM  # systolic fill + drain
    if ins.op == Op.INSTRUCTION_FREEZE:
        return FREEZE_CYCLES
    if ins.op == Op.FLUSH:
        return FLUSH_CYCLES
    if ins.op == Op.FENCE:
        return 1
    raise ValueError(ins.op)
