"""Gemmini^RT virtual accelerator (paper SS V).

Models the micro-architecture pieces the context-switch mechanism needs:

  * 4-class config registers + the **config-copy buffer** holding the most
    recent config instruction of each class (SS V.B);
  * scratchpad banks behind the **address remapper** (SS V.C) and the
    accumulator (no allocation restriction, SS V.C end);
  * a reservation station whose queue can be **frozen** (only flush-class
    instructions proceed) and **flushed**;
  * `step_wise_mvin/mvout` over the default configuration channel, moving
    computation data without touching the live configuration (SS V.A);
  * context save / restore cycle costs derived from the actual resident
    bytes — the quantities the scheduler charges as Upsilon^S/ Upsilon^R.

Cycle accounting is exact w.r.t. the ISA cost model; an optional numpy
backend executes tile GEMMs for the end-to-end demos and tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.isa import (ACCUM_BYTES, CONFIG_CYCLES, DMA_BYTES_PER_CYCLE,
                            DMA_SETUP_CYCLES, FLUSH_CYCLES, FREEZE_CYCLES,
                            REMAP_BLOCK_BYTES, CONFIG_OPS, Instruction, Op)
from repro.core.remapper import AddressRemapper


@dataclasses.dataclass
class ConfigState:
    ld: Optional[tuple] = None
    st: Optional[tuple] = None
    ex: Optional[tuple] = None
    norm: Optional[tuple] = None

    def as_tuple(self):
        return (self.ld, self.st, self.ex, self.norm)


class ConfigCopyBuffer:
    """Most recent configuration instruction of each of the 4 classes."""

    def __init__(self):
        self.slots: Dict[Op, Optional[tuple]] = {op: None for op in CONFIG_OPS}

    def record(self, ins: Instruction):
        self.slots[ins.op] = (ins.op, ins.meta)

    def snapshot(self) -> tuple:
        return tuple(self.slots[op] for op in CONFIG_OPS)

    def load(self, snap: tuple):
        for op, val in zip(CONFIG_OPS, snap):
            self.slots[op] = val

    def clear(self):
        for op in CONFIG_OPS:
            self.slots[op] = None


@dataclasses.dataclass
class CSBreakdown:
    """Cycle breakdown of one context save or restore."""
    drain: int = 0
    freeze_flush: int = 0
    accumulator: int = 0
    config_buffer: int = 0
    remap_block: int = 0
    scratchpad: int = 0
    reconfig: int = 0
    resend: int = 0

    @property
    def total(self) -> int:
        return (self.drain + self.freeze_flush + self.accumulator
                + self.config_buffer + self.remap_block + self.scratchpad
                + self.reconfig + self.resend)


def _dma_cycles(nbytes: int) -> int:
    if nbytes <= 0:
        return 0
    return DMA_SETUP_CYCLES + -(-nbytes // DMA_BYTES_PER_CYCLE)


class GemminiRT:
    """Cycle-accounting virtual accelerator with RT context switching."""

    def __init__(self, n_banks: int = 8, use_remapper: bool = True):
        self.remapper = AddressRemapper(n_banks=n_banks)
        self.config = ConfigState()
        self.config_buffer = ConfigCopyBuffer()
        self.use_remapper = use_remapper
        self.frozen = False
        self.accum_bytes_used: Dict[int, int] = {}   # per task
        self.spad_bytes: Dict[int, int] = {}         # residency w/o remapper
        self.queue_depth = 8                         # reservation station
        # DRAM context store: tid -> dict of saved regions
        self.dram: Dict[int, dict] = {}
        # per-task eta-bank cache (a task's program never changes mid-run)
        self._eta_banks: Dict[int, int] = {}
        self._bb = self.remapper.bank_bytes
        self._cap = self._bb * len(self.remapper.banks)

    # ------------------------------------------------------------------
    # streaming-mode bookkeeping (the scheduler charges cycles; we track
    # the state the context switch must preserve)
    # ------------------------------------------------------------------

    def note_execution(self, tid: int, cycles: float, program) -> None:
        """Approximate residency growth while a task streams instructions:
        its working set (bounded by eta banks) and accumulator fill.  When
        the scratchpad is contended, residency saturates at what the
        remapper can actually lock (no eviction of other tasks' banks)."""
        bb = self._bb
        cap = self._cap
        eta_banks = self._eta_banks.get(tid)
        if eta_banks is None:
            eta_banks = max(1, -(-min(program.working_set_bytes, cap) // bb))
            self._eta_banks[tid] = eta_banks
        if self.use_remapper:
            rm = self.remapper
            have = rm.resident_bytes(tid)
            avail = have + rm.free_banks() * bb
            want = min(eta_banks * bb, avail,
                       have + int(cycles * DMA_BYTES_PER_CYCLE))
            if want > have:
                rm.write(tid, have, want - have)
        else:
            # no bank model: explicit addressing, residency tracked only in
            # aggregate; every context switch must evacuate it all
            have = self.spad_bytes.get(tid, 0)
            others = sum(v for k, v in self.spad_bytes.items() if k != tid)
            want = min(eta_banks * bb, max(cap - others, 0),
                       have + int(cycles * DMA_BYTES_PER_CYCLE))
            self.spad_bytes[tid] = max(have, want)
        acc = self.accum_bytes_used.get(tid, 0)
        if acc < ACCUM_BYTES:
            self.accum_bytes_used[tid] = min(
                ACCUM_BYTES, acc + int(cycles * DMA_BYTES_PER_CYCLE // 4))

    # ------------------------------------------------------------------
    # Context switch (paper Alg. 1 + SS IV 'Context switch')
    # ------------------------------------------------------------------

    def instruction_freeze(self) -> int:
        self.frozen = True
        return FREEZE_CYCLES

    def flush(self) -> int:
        self.frozen = False
        return FLUSH_CYCLES

    def context_save(self, tcb, drain_cycles: int,
                     next_eta: Optional[int] = None) -> CSBreakdown:
        """Alg. 1 Context_save.  ``drain_cycles`` = remaining cycles of the
        in-flight instruction (instruction-level preemption bound)."""
        tid = tcb.tid
        br = CSBreakdown(drain=int(drain_cycles),
                         freeze_flush=FREEZE_CYCLES + FLUSH_CYCLES)
        # accumulator is always evacuated (step_wise_mvout, default channel)
        acc = self.accum_bytes_used.get(tid, 0)
        br.accumulator = _dma_cycles(acc)
        # config-copy buffer -> DRAM
        br.config_buffer = DMA_SETUP_CYCLES + 4 * CONFIG_CYCLES
        # remapping block -> DRAM
        br.remap_block = _dma_cycles(REMAP_BLOCK_BYTES) if self.use_remapper \
            else 0
        # scratchpad: only if the NEXT task does not fit alongside (line 35)
        if self.use_remapper:
            resident = self.remapper.resident_bytes(tid)
            need_spad = True
            if next_eta is not None:
                need_spad = not self.remapper.fits(next_eta, exclude_tid=None)
        else:
            resident = self.spad_bytes.get(tid, 0)
            need_spad = True    # explicit addressing: always evacuate
        if need_spad and resident > 0:
            br.scratchpad = _dma_cycles(resident)
            saved_spad = resident
            self.remapper.release(tid)
            self.spad_bytes.pop(tid, None)
            kept = False
        else:
            saved_spad = 0
            kept = True
        self.dram[tid] = {
            "accumulator": acc,
            "scratchpad": saved_spad,
            "kept_resident": kept,
            "config": self.config_buffer.snapshot(),
            "remap": self.remapper.snapshot(tid),
        }
        self.accum_bytes_used[tid] = 0
        tcb.data_in_accel = kept
        tcb.config_snapshot = self.dram[tid]["config"]
        tcb.dram_addresses = {"ctx": tid}
        return br

    def context_restore(self, tcb, n_resend: int = 2) -> CSBreakdown:
        """Alg. 1 Context_restore (mirrors save): reload data, update the
        remapping block, reconfig, re-dispatch unanswered instructions."""
        tid = tcb.tid
        ctx = self.dram.get(tid)
        br = CSBreakdown()
        if ctx is None:
            return br
        br.accumulator = _dma_cycles(ctx["accumulator"])
        self.accum_bytes_used[tid] = ctx["accumulator"]
        if not ctx["kept_resident"] and ctx["scratchpad"] > 0:
            br.scratchpad = _dma_cycles(ctx["scratchpad"])
            br.remap_block = _dma_cycles(REMAP_BLOCK_BYTES) \
                if self.use_remapper else 0
            if self.use_remapper:
                self.remapper.restore(tid, ctx["remap"], ctx["scratchpad"])
            else:
                self.spad_bytes[tid] = ctx["scratchpad"]
        br.config_buffer = DMA_SETUP_CYCLES + 4 * CONFIG_CYCLES
        br.reconfig = 4 * CONFIG_CYCLES
        self.config_buffer.load(ctx["config"])
        br.resend = n_resend * 2   # CPU re-dispatch of unanswered insts
        tcb.data_in_accel = True
        return br

    def evict(self, tid: int) -> int:
        """Flush a finished/terminated task's banks (banklock deactivate)."""
        self.remapper.release(tid)
        self.accum_bytes_used.pop(tid, None)
        self.spad_bytes.pop(tid, None)
        self.dram.pop(tid, None)
        return FLUSH_CYCLES

    # -- instruction-accurate execution (demos/tests) -------------------
    def execute(self, ins: Instruction, tid: int) -> int:
        if self.frozen and ins.op not in (Op.FLUSH,):
            raise RuntimeError("accelerator frozen; only flush may proceed")
        if ins.op in CONFIG_OPS:
            self.config_buffer.record(ins)
            setattr(self.config, ins.op.value.split("_")[1],
                    (ins.op, ins.meta))
        elif ins.op in (Op.MVIN, Op.STEP_WISE_MVIN) and self.use_remapper:
            self.remapper.write(tid, 0, ins.bytes)
        elif ins.op == Op.COMPUTE:
            self.accum_bytes_used[tid] = min(
                ACCUM_BYTES, self.accum_bytes_used.get(tid, 0) + 1024)
        return ins.cost
