"""Worst-Case Response Time analysis for MESC (paper SS VII, Eqs. 1-11).

Notation (all cycles):
  I(G)            longest single accelerator-instruction time in task set G
  T_sr            scheduler period
  Y_S / Y_R       max context save / restore durations (accelerator + CPU)
  Y_C             max CPU check time per scheduler invocation
  Y_CC            max CPU-only-task context switch time

Three schedulability cases: LO-mode (Eq. 3), HI-mode (Eq. 7), and mode
transition (Eq. 11), each a fixed-point recurrence solved iteratively.
A task set is schedulable iff every task passes its applicable cases.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.core.program import Program
from repro.core.task import Crit, TaskParams


@dataclasses.dataclass(frozen=True)
class AnalysisConstants:
    t_sr: float = 5000.0
    y_save: float = 12000.0       # Upsilon^S_Asr (measured; fig7 benchmark)
    y_restore: float = 12000.0    # Upsilon^R_Asr
    y_check: float = 200.0        # Upsilon_Csr
    y_cpu_cs: float = 500.0       # Upsilon^C_Csr


def longest_instruction(tasks: List[TaskParams],
                        programs: Dict[str, Program]) -> float:
    """I(F(G)): max instruction execution time among accelerator tasks."""
    accel = [t for t in tasks if t.uses_accelerator and t.workload]
    if not accel:
        return 0.0
    return max(programs[t.workload].max_instruction_cycles for t in accel)


def _partitions(tasks: List[TaskParams], ti: TaskParams):
    hpH = [t for t in tasks if t.priority < ti.priority and t.crit == Crit.HI]
    hpL = [t for t in tasks if t.priority < ti.priority and t.crit == Crit.LO]
    lpH = [t for t in tasks if t.priority > ti.priority and t.crit == Crit.HI]
    lpL = [t for t in tasks if t.priority > ti.priority and t.crit == Crit.LO]
    return hpH, hpL, lpH, lpL


def _F(ts):          # accelerator-using subset
    return [t for t in ts if t.uses_accelerator]


def _Fbar(ts):       # CPU-only subset
    return [t for t in ts if not t.uses_accelerator]


def _I(ts, programs) -> float:
    return longest_instruction(ts, programs)


def _solve(rhs, r0: float, bound: float) -> Optional[float]:
    """Fixed-point iteration R = rhs(R); None if it exceeds ``bound``."""
    r = r0
    for _ in range(500):
        nxt = rhs(r)
        if nxt <= r + 1e-6:
            return nxt
        if nxt > bound:
            return None
        r = nxt
    return None


def response_time_lo(ti: TaskParams, tasks, programs,
                     k: AnalysisConstants) -> Optional[float]:
    """Eq. 3 with blocking from Eqs. 1-2."""
    hpH, hpL, lpH, lpL = _partitions(tasks, ti)
    pb = _I(_F(lpH + lpL), programs) + k.t_sr          # Eq. 1
    b = pb                                             # Eq. 2
    cpu_hp = _Fbar(hpH + hpL)
    acc_hp = _F(hpH + hpL)

    def rhs(r):
        val = b + ti.c_lo + k.y_save + k.y_restore
        val += math.ceil(r / k.t_sr) * k.y_check
        for tj in cpu_hp:
            val += math.ceil(r / tj.period) * (2 * k.y_cpu_cs + tj.c_lo)
        for tk_ in acc_hp:
            val += math.ceil(r / tk_.period) * (k.y_save + k.y_restore
                                                + tk_.c_lo)
        return val

    return _solve(rhs, ti.c_lo, ti.deadline)


def response_time_hi(ti: TaskParams, tasks, programs,
                     k: AnalysisConstants) -> Optional[float]:
    """Eq. 7 with blocking from Eqs. 4-6 (HI-tasks only)."""
    assert ti.crit == Crit.HI
    hpH, hpL, lpH, lpL = _partitions(tasks, ti)
    b = _I(_F(lpL + hpL + lpH), programs) + k.t_sr     # Eq. 6
    cpu_hp = _Fbar(hpH)
    acc_hp = _F(hpH)

    def rhs(r):
        val = b + ti.c_hi + k.y_save + k.y_restore
        val += math.ceil(r / k.t_sr) * k.y_check
        for tj in cpu_hp:
            val += math.ceil(r / tj.period) * (2 * k.y_cpu_cs + tj.c_hi)
        for tk_ in acc_hp:
            val += math.ceil(r / tk_.period) * (k.y_save + k.y_restore
                                                + tk_.c_hi)
        return val

    return _solve(rhs, ti.c_hi, ti.deadline)


def response_time_trans(ti: TaskParams, tasks, programs,
                        k: AnalysisConstants) -> Optional[float]:
    """Eq. 11: released in LO/transition, finishes in transition/HI.

    LO-task preemptions of tau_i can only have happened while still in
    LO-mode, so their interference is windowed by R_i^LO (per the paper we
    upper-bound it with the LO response time; if tau_i is unschedulable in
    LO-mode the transition case fails too)."""
    assert ti.crit == Crit.HI
    hpH, hpL, lpH, lpL = _partitions(tasks, ti)
    b = _I(_F(lpL + hpL + lpH), programs) + k.t_sr     # Eqs. 8-10
    r_lo = response_time_lo(ti, tasks, programs, k)
    if r_lo is None:
        return None
    cpu_hpL, acc_hpL = _Fbar(hpL), _F(hpL)
    cpu_hpH, acc_hpH = _Fbar(hpH), _F(hpH)

    def rhs(r):
        val = b + ti.c_hi + k.y_save + k.y_restore
        val += math.ceil(r / k.t_sr) * k.y_check
        for tj in cpu_hpL:
            val += math.ceil(r_lo / tj.period) * (2 * k.y_cpu_cs + tj.c_lo)
        for tj in cpu_hpH:
            val += math.ceil(r / tj.period) * (2 * k.y_cpu_cs + tj.c_hi)
        for tm in acc_hpL:
            val += math.ceil(r_lo / tm.period) * (k.y_save + k.y_restore
                                                  + tm.c_lo)
        for tn in acc_hpH:
            val += math.ceil(r / tn.period) * (k.y_save + k.y_restore
                                               + tn.c_hi)
        return val

    return _solve(rhs, ti.c_hi, ti.deadline)


@dataclasses.dataclass
class SchedulabilityResult:
    schedulable: bool
    lo: Dict[int, Optional[float]]
    hi: Dict[int, Optional[float]]
    trans: Dict[int, Optional[float]]


@dataclasses.dataclass
class PartitionedSchedulability:
    """Partitioned analysis verdict: per-instance results + platform OK."""
    schedulable: bool
    per_instance: Dict[int, SchedulabilityResult]
    assignment: "object"                 # core.platform.Assignment


def analyze_partitioned(tasks: List[TaskParams],
                        programs: Dict[str, Program], *,
                        n_instances: int,
                        heuristic: str = "crit_aware",
                        k: AnalysisConstants = AnalysisConstants(),
                        dma_contention: bool = True,
                        assignment=None) -> PartitionedSchedulability:
    """Partitioned response-time analysis over N accelerator instances.

    Each instance is analysed as its own single-accelerator system
    (Eqs. 1-11) over *its partition only* — assignment-aware blocking:
    the I(G) term and the hp/lp interference sets shrink to the tasks
    actually co-located with tau_i, which is exactly why partitioning
    helps.  The shared-DMA path couples the instances through the
    context-switch terms: in the worst case every other instance is
    mid-save/restore concurrently, so with ``dma_contention`` the
    per-instance Upsilon^S/Upsilon^R constants are stretched by
    ``n_instances`` (equal-share bandwidth model, matching
    ``simulator.MultiAccelSimulator``).

    A task set is platform-schedulable iff every instance's partition
    passes all of its applicable LO/HI/transition cases.
    """
    from repro.core.platform import partition
    if assignment is None:
        assignment = partition(tasks, n_instances, heuristic)
    stretch = float(n_instances) if dma_contention else 1.0
    k_inst = dataclasses.replace(k, y_save=k.y_save * stretch,
                                 y_restore=k.y_restore * stretch)
    per: Dict[int, SchedulabilityResult] = {}
    ok = True
    for inst in range(n_instances):
        subset = assignment.tasks_on(inst, tasks)
        if not subset:
            per[inst] = SchedulabilityResult(True, {}, {}, {})
            continue
        res = analyze(subset, programs, k_inst)
        per[inst] = res
        ok = ok and res.schedulable
    return PartitionedSchedulability(ok, per, assignment)


def analyze(tasks: List[TaskParams], programs: Dict[str, Program],
            k: AnalysisConstants = AnalysisConstants()) -> SchedulabilityResult:
    lo, hi, tr = {}, {}, {}
    ok = True
    for t in tasks:
        r = response_time_lo(t, tasks, programs, k)
        lo[t.tid] = r
        if r is None or r > t.deadline:
            ok = False
        if t.crit == Crit.HI:
            r2 = response_time_hi(t, tasks, programs, k)
            hi[t.tid] = r2
            r3 = response_time_trans(t, tasks, programs, k)
            tr[t.tid] = r3
            if r2 is None or r2 > t.deadline:
                ok = False
            if r3 is None or r3 > t.deadline:
                ok = False
    return SchedulabilityResult(ok, lo, hi, tr)
