"""Multi-accelerator platform: partitioned MCS scheduling across N
virtual Gemmini^RT instances (scale-out of the paper's SS IV/V mechanism).

The paper makes ONE streaming accelerator preemptible at instruction
granularity; real MCS platforms (heterogeneous MPSoCs, serving fleets)
schedule criticality-mixed task sets across *pools* of such co-processors.
This module supplies the static half of that generalisation:

  * :class:`AcceleratorPool` — N instances, each with its own bank
    remapper/mode state, sharing one DMA path to DRAM (the contention
    the multi-instance simulator and the partitioned analysis charge);
  * task -> instance *partitioning* (:func:`partition`) with three
    heuristics: ``first_fit`` (decreasing-utilisation bin packing),
    ``worst_fit`` (load balancing), and ``crit_aware`` (spread HI-tasks
    evenly, then steer LO-tasks toward HI-light instances so a mode
    switch on one instance degrades as few LO-tasks as possible);
  * LO-task **migration-on-idle** (:class:`MigrationPolicy`): a LO-task
    waiting behind work on its home instance may move to an instance
    that has gone idle in LO-mode, paying the DMA cost of shipping its
    saved context.

The dynamic halves live next door: per-instance mode machines plus the
global coordinator in ``core.scheduler``, the multi-instance event loop
in ``core.simulator.MultiAccelSimulator``, and the partitioned
response-time analysis in ``core.wcrt.analyze_partitioned``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.executor import GemminiRT
from repro.core.task import Crit, TaskParams

HEURISTICS = ("first_fit", "worst_fit", "crit_aware")


def utilization(tasks: Sequence[TaskParams], *, hi: bool = False) -> float:
    """Sum of C/T over the tasks (C_HI for ``hi=True``)."""
    return sum((t.c_hi if hi else t.c_lo) / t.period for t in tasks)


@dataclasses.dataclass
class Assignment:
    """A static task -> instance partition plus derived views.

    ``task_to_instance`` is the *current* placement (a migrated job
    runs away from home); ``home`` is the heuristic's static partition
    a task returns to when its migrated job completes — migration is
    job-scoped, so the partition (and its analysis) never erodes.
    """
    n_instances: int
    heuristic: str
    task_to_instance: Dict[int, int]
    home: Dict[int, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.home:
            self.home = dict(self.task_to_instance)

    def instance_of(self, tid: int) -> int:
        return self.task_to_instance[tid]

    def home_of(self, tid: int) -> int:
        return self.home[tid]

    def tasks_on(self, inst: int,
                 tasks: Sequence[TaskParams]) -> List[TaskParams]:
        return [t for t in tasks if self.task_to_instance[t.tid] == inst]

    def migrate(self, tid: int, inst: int) -> None:
        self.task_to_instance[tid] = inst

    def return_home(self, tid: int) -> None:
        self.task_to_instance[tid] = self.home[tid]


def _first_fit(tasks: List[TaskParams], n: int) -> Dict[int, int]:
    """Decreasing-utilisation first-fit; a task that fits nowhere under
    the U<=1 capacity test goes to the least-loaded instance."""
    load = [0.0] * n
    out: Dict[int, int] = {}
    for t in sorted(tasks, key=lambda t: -(t.c_lo / t.period)):
        u = t.c_lo / t.period
        inst = next((i for i in range(n) if load[i] + u <= 1.0), None)
        if inst is None:
            inst = min(range(n), key=load.__getitem__)
        load[inst] += u
        out[t.tid] = inst
    return out


def _worst_fit(tasks: List[TaskParams], n: int) -> Dict[int, int]:
    """Decreasing-utilisation worst-fit: always the least-loaded
    instance — balances load, minimising per-instance peak demand."""
    load = [0.0] * n
    out: Dict[int, int] = {}
    for t in sorted(tasks, key=lambda t: -(t.c_lo / t.period)):
        inst = min(range(n), key=load.__getitem__)
        load[inst] += t.c_lo / t.period
        out[t.tid] = inst
    return out


def _crit_aware(tasks: List[TaskParams], n: int) -> Dict[int, int]:
    """Criticality-aware partition: HI-tasks worst-fit over HI-load
    first (spreads the overrun/mode-switch blast radius), then LO-tasks
    placed by combined load with HI-load weighted double — LO-tasks
    gravitate to HI-light instances, so fewer of them sit on an
    instance that leaves LO-mode."""
    hi_load = [0.0] * n
    lo_load = [0.0] * n
    out: Dict[int, int] = {}
    his = [t for t in tasks if t.crit == Crit.HI]
    los = [t for t in tasks if t.crit == Crit.LO]
    for t in sorted(his, key=lambda t: -(t.c_hi / t.period)):
        inst = min(range(n), key=hi_load.__getitem__)
        hi_load[inst] += t.c_hi / t.period
        out[t.tid] = inst
    for t in sorted(los, key=lambda t: -(t.c_lo / t.period)):
        inst = min(range(n),
                   key=lambda i: lo_load[i] + 2.0 * hi_load[i])
        lo_load[inst] += t.c_lo / t.period
        out[t.tid] = inst
    return out


_HEURISTIC_FNS = {"first_fit": _first_fit, "worst_fit": _worst_fit,
                  "crit_aware": _crit_aware}


def partition(tasks: Sequence[TaskParams], n_instances: int,
              heuristic: str = "crit_aware") -> Assignment:
    """Statically partition ``tasks`` over ``n_instances`` accelerators."""
    if n_instances < 1:
        raise ValueError(f"n_instances must be >= 1, got {n_instances}")
    if heuristic not in _HEURISTIC_FNS:
        raise ValueError(f"unknown heuristic {heuristic!r}; "
                         f"choose from {HEURISTICS}")
    mapping = _HEURISTIC_FNS[heuristic](list(tasks), n_instances)
    return Assignment(n_instances=n_instances, heuristic=heuristic,
                      task_to_instance=mapping)


# ----------------------------------------------------------------------
@dataclasses.dataclass
class MigrationPolicy:
    """LO-task migration-on-idle knobs.

    ``enabled``        master switch;
    ``cost_per_byte``  extra DMA cycles charged per byte of saved
                       context shipped between instances (the shared
                       DRAM path makes this a copy, not a remap);
    ``lo_mode_only``   only migrate onto instances still in LO-mode
                       (never feed LO work to a degraded instance);
    ``min_wait``       a task must have been waiting this many cycles
                       since release before it may migrate — an idle
                       home instance will usually pick it up sooner,
                       so eager migration just burns shared DMA;
    ``cooldown``       cycles between migrations of the same task
                       (ping-pong damping; ~one migration per job);
    ``hi_slack_guard`` criticality-aware admission test: refuse a
                       migrant whose worst-case preemption cost (its
                       longest instruction + a fully DMA-contended
                       save/restore), scaled by ``slack_margin``,
                       exceeds the static slack D - C_HI of any
                       HI-task on the target — a migrant LO-task must
                       never be able to turn a schedulable HI-task
                       into a missing one;
    ``slack_margin``   safety factor on that cost bound (the static
                       slack ignores tick quantisation and chained
                       migrant restores, so demand margin).
    """
    enabled: bool = True
    cost_per_byte: float = 1.0 / 16.0     # one shared 128-bit DMA bus
    lo_mode_only: bool = True
    min_wait: float = 20_000.0            # 4 scheduler periods
    cooldown: float = 1e6
    hi_slack_guard: bool = True
    slack_margin: float = 2.0


class AcceleratorPool:
    """N virtual Gemmini^RT instances behind one shared DMA path.

    Owns per-instance accelerator models and the mutable task->instance
    assignment; the simulator drives it, the coordinator reads it.
    """

    def __init__(self, n_instances: int, *, use_remapper: bool = True,
                 heuristic: str = "crit_aware",
                 migration: Optional[MigrationPolicy] = None):
        if n_instances < 1:
            raise ValueError("need at least one accelerator instance")
        self.n_instances = n_instances
        self.heuristic = heuristic
        self.migration = migration or MigrationPolicy()
        self.instances: List[GemminiRT] = [
            GemminiRT(use_remapper=use_remapper) for _ in range(n_instances)]
        self.assignment: Optional[Assignment] = None
        self.migrations = 0

    def assign(self, tasks: Sequence[TaskParams]) -> Assignment:
        self.assignment = partition(tasks, self.n_instances,
                                    self.heuristic)
        return self.assignment

    def accel_of(self, tid: int) -> GemminiRT:
        assert self.assignment is not None, "assign() first"
        return self.instances[self.assignment.instance_of(tid)]

    def migrate(self, tid: int, dst: int) -> float:
        """Move ``tid``'s saved context to instance ``dst``; returns the
        DMA cycles charged for shipping it over the shared path."""
        assert self.assignment is not None, "assign() first"
        src = self.assignment.instance_of(tid)
        if src == dst:
            return 0.0
        src_acc, dst_acc = self.instances[src], self.instances[dst]
        ctx = src_acc.dram.pop(tid, None)
        cycles = 0.0
        if ctx is not None:
            moved = ctx.get("accumulator", 0) + ctx.get("scratchpad", 0)
            # context saved "kept_resident" on the source must be
            # evacuated there before it can move
            if ctx.get("kept_resident"):
                moved += src_acc.remapper.resident_bytes(tid)
                ctx["scratchpad"] += src_acc.remapper.resident_bytes(tid)
                ctx["kept_resident"] = False
            dst_acc.dram[tid] = ctx
            cycles = moved * self.migration.cost_per_byte
        src_acc.remapper.release(tid)
        src_acc.accum_bytes_used.pop(tid, None)
        src_acc.spad_bytes.pop(tid, None)
        self.assignment.migrate(tid, dst)
        self.migrations += 1
        return cycles
