"""Task-set generation (paper SS VIII 'Task set setup').

* utilisations via UUnifast (unbiased);
* C_LO drawn from the workload library's measured total cycles;
* C_HI = CF * C_LO (default CF = 2.0);
* T_i = C_LO / U_i, implicit deadlines D_i = T_i;
* fixed priorities in ascending order of T_i (rate monotonic);
* HI-task share gamma (default 0.5); beta tasks per set (default 10).

Seeding contract (relied on by the campaign engine,
``repro.experiments``): set ``s`` of a batch anchored at ``seed0`` is
generated from ``point_seed(seed0, s) == seed0 + s``, and the simulator
run over that set uses the *same* seed.  Every (seed0, s) point is
therefore reproducible in isolation — independent of worker count,
execution order, or which other points run — and identical to the
legacy serial loops that iterated ``seed0 + s`` by hand.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.program import Program, workload_library
from repro.core.task import Crit, TaskParams
from repro.core.isa import BANK_BYTES, SCRATCHPAD_BANKS


def uunifast(n: int, total_u: float, rng: np.random.Generator) -> np.ndarray:
    u = np.empty(n)
    s = total_u
    for i in range(n - 1):
        nxt = s * rng.random() ** (1.0 / (n - 1 - i))
        u[i] = s - nxt
        s = nxt
    u[-1] = s
    return u


def uunifast_discard(n: int, total_u: float, rng: np.random.Generator,
                     max_u: float = 1.0, max_tries: int = 10_000
                     ) -> np.ndarray:
    """UUnifast-Discard (Davis & Burns): redraw until every per-task
    share is <= ``max_u``.  Required for multiprocessor/partitioned
    totals (total_u > 1), where plain UUnifast can emit a single task
    no instance could ever host — e.g. a HI-task with u_lo > 1/CF can
    miss its own implicit deadline on an idle accelerator."""
    for _ in range(max_tries):
        u = uunifast(n, total_u, rng)
        if u.max() <= max_u:
            return u
    raise ValueError(f"no {n}-task UUnifast draw with total {total_u} "
                     f"fits max_u={max_u} after {max_tries} tries")


def eta_for(program: Program) -> int:
    """Minimal banks preserving full speed (SS VII.C, Fig. 6 analogue):
    working set rounded up to banks, capped at the scratchpad."""
    eta = max(1, -(-program.working_set_bytes // BANK_BYTES))
    return min(eta, SCRATCHPAD_BANKS)


def point_seed(seed0: int, set_index: int) -> int:
    """Deterministic per-point seed: see the module seeding contract."""
    return int(seed0) + int(set_index)


def generate_taskset(total_u: float, *, n_tasks: int = 10,
                     gamma: float = 0.5, cf: float = 2.0,
                     seed: int = 0,
                     programs: Optional[Dict[str, Program]] = None,
                     workload_names: Optional[Sequence[str]] = None,
                     max_task_u: Optional[float] = None,
                     ) -> List[TaskParams]:
    """One UUnifast task set (``max_task_u`` switches to the discard
    variant — use it whenever ``total_u`` targets a multi-instance
    platform; ``None`` keeps the legacy single-accelerator draws and
    their campaign-cache results byte-identical)."""
    rng = np.random.default_rng(seed)
    programs = programs or workload_library()
    names = list(workload_names or
                 [n for n in programs
                  if programs[n].total_cycles < 2e7])  # keep periods tractable
    if max_task_u is None:
        u = uunifast(n_tasks, total_u, rng)
    else:
        u = uunifast_discard(n_tasks, total_u, rng, max_u=max_task_u)
    chosen = rng.choice(names, size=n_tasks)
    n_hi = int(round(gamma * n_tasks))
    crits = np.array([Crit.HI] * n_hi + [Crit.LO] * (n_tasks - n_hi))
    rng.shuffle(crits)
    tasks = []
    for i in range(n_tasks):
        prog = programs[chosen[i]]
        c_lo = float(prog.total_cycles)
        period = c_lo / max(u[i], 1e-6)
        tasks.append(TaskParams(
            tid=i, priority=0, period=period, deadline=period,
            c_lo=c_lo, c_hi=cf * c_lo, crit=crits[i],
            eta=eta_for(prog), workload=chosen[i]))
    # rate-monotonic: shorter period -> higher priority (smaller number)
    for prio, t in enumerate(sorted(tasks, key=lambda t: t.period)):
        t.priority = prio
    return tasks


def generate_taskset_batch(total_u: float, n_sets: int, *, seed0: int = 0,
                           **kw) -> List[List[TaskParams]]:
    """Batch entry point: ``n_sets`` independent task sets following the
    per-point seeding contract (set ``s`` uses ``point_seed(seed0, s)``)."""
    return [generate_taskset(total_u, seed=point_seed(seed0, s), **kw)
            for s in range(n_sets)]
