"""MESC-scheduled model serving: the paper's mechanism driving real JAX
model execution.

Mapping (the TPU adaptation of SS IV/V, see docs/design.md):
  * accelerator instruction  = one bounded-latency jitted dispatch
                               (one decode step / one prefill chunk)
  * scratchpad banks         = a bounded pool of device-resident KV-cache
                               slots (HBM arena); the bank allocator decides
                               which requests stay resident
  * context save / restore   = moving a request's cache pytree to/from host
                               DRAM (step_wise_mvout/mvin analogue)
  * config-copy buffer       = the request's generation config + position
  * task monitor             = LO-budget timers -> mode switch

Every timestamp (``submitted_at``, ``started_at``, ``exec_s``
accumulation, LO-budget checks) is read through an injected *clock* — a
zero-arg callable returning seconds.  The default is the wall clock
(``time.monotonic``); under test and in the fig12 traffic harness a
``repro.serving.clock.VirtualClock`` makes LO-budget overruns, mode
switches and all SLO metrics deterministic (see docs/serving.md for
the clock-injection contract).

Scheduling follows scheduler.Policy + mode rules: HI requests preempt LO
requests at instruction (= decode-step) boundaries; LO requests are never
dropped (imprecise-MCS stance), they run when no HI request is active.

Multi-accelerator scale-out (docs/scheduling.md): :class:`MultiLaneServer`
runs one :class:`MESCServer` dispatch lane per virtual accelerator, all
lanes drawing KV-cache residency from one shared :class:`KVSlotArena`
carved into per-lane quotas; requests are partitioned onto lanes with the
same first-fit / worst-fit / criticality-aware heuristics as
``core.platform.partition``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.scheduler import Mode, Policy
from repro.core.task import Crit
from repro.models import lm
from repro.models.common import RuntimeConfig, CPU_RC


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int
    priority: int
    crit: Crit
    lo_budget_s: float = 1e9        # LO-WCET analogue (wall clock)
    # runtime state
    generated: List[int] = dataclasses.field(default_factory=list)
    cache: Optional[dict] = None    # device (resident) or host (saved)
    resident: bool = False
    done: bool = False
    started_at: Optional[float] = None
    exec_s: float = 0.0
    first_token_at: Optional[float] = None
    # stamped by submit() from the server clock unless the caller (the
    # admission front door) already set the true arrival time
    submitted_at: Optional[float] = None
    finished_at: Optional[float] = None
    preemptions: int = 0
    saves: int = 0


class KVSlotArena:
    """Shared pool of device-resident KV-cache slots, carved into
    per-lane quotas (the multi-lane analogue of the scratchpad banks).

    Quotas statically partition the arena — ``sum(quotas) == total`` —
    so each lane's admission check is local (no cross-lane eviction),
    exactly like banklocked scratchpad banks partitioned across
    accelerator instances.
    """

    def __init__(self, total_slots: int, n_lanes: int = 1,
                 quotas: Optional[List[int]] = None):
        if quotas is None:
            base, rem = divmod(total_slots, n_lanes)
            quotas = [base + (1 if i < rem else 0) for i in range(n_lanes)]
        if len(quotas) != n_lanes or sum(quotas) != total_slots:
            raise ValueError(f"quotas {quotas} must partition "
                             f"{total_slots} slots over {n_lanes} lanes")
        if min(quotas) < 1:
            raise ValueError(f"every lane needs >= 1 slot, got {quotas}")
        self.total_slots = total_slots
        self.quotas = list(quotas)
        self._held: List[set] = [set() for _ in range(n_lanes)]

    def held(self, lane: int) -> int:
        return len(self._held[lane])

    def can_admit(self, lane: int) -> bool:
        return self.held(lane) < self.quotas[lane]

    def acquire(self, lane: int, rid: int) -> None:
        if rid not in self._held[lane] and not self.can_admit(lane):
            raise RuntimeError(f"lane {lane} over quota "
                               f"({self.quotas[lane]} slots)")
        self._held[lane].add(rid)

    def release(self, lane: int, rid: int) -> None:
        self._held[lane].discard(rid)


class MESCServer:
    """Single-model mixed-criticality serving loop (batch size 1 per
    request; the accelerator — one dispatch lane — is the shared
    resource).  Standalone it owns a private one-lane arena sized
    ``resident_slots``; under :class:`MultiLaneServer` it is one lane of
    a shared arena."""

    def __init__(self, cfg: ArchConfig, params, *, policy: Policy = None,
                 rc: RuntimeConfig = CPU_RC, max_len: int = 64,
                 resident_slots: int = 2,
                 arena: Optional[KVSlotArena] = None, lane: int = 0,
                 jit_fns=None,
                 clock: Callable[[], float] = time.monotonic,
                 cs_costs: Optional[Tuple[float, float]] = None):
        self.cfg = cfg
        self.params = params
        self.rc = rc
        self.policy = policy or Policy.mesc()
        self.max_len = max_len
        self.arena = arena or KVSlotArena(resident_slots, 1)
        self.lane = lane
        self.mode = Mode.LO
        self.requests: Dict[int, Request] = {}
        self.current: Optional[int] = None
        # the clock-injection contract (docs/serving.md): EVERY
        # timestamp below reads self.clock(), never time.monotonic()
        self.clock = clock
        self._cs_save_s, self._cs_restore_s = cs_costs or (0.0, 0.0)
        if jit_fns is not None:            # shared across lanes
            self._decode, self._prefill = jit_fns
        else:
            self._decode = jax.jit(
                lambda p, t, c: lm.decode_step(cfg, p, t, c, rc))
            self._prefill = jax.jit(
                lambda p, b: lm.prefill(cfg, p, b, rc, max_len=max_len))

    def _charge(self, dt: float) -> None:
        """Charge a modeled context-switch cost to an advanceable
        (virtual) clock; a wall clock pays real save/restore latency
        through the jax transfers themselves, so this is a no-op."""
        adv = getattr(self.clock, "advance", None)
        if adv is not None and dt:
            adv(dt)

    # -- bank pool ----------------------------------------------------------
    def _resident(self) -> List[Request]:
        return [r for r in self.requests.values()
                if r.resident and not r.done]

    def _evict(self, victim: Request):
        victim.cache = jax.device_get(victim.cache)       # step_wise_mvout
        victim.resident = False
        victim.saves += 1
        self._charge(self._cs_save_s)
        self.arena.release(self.lane, victim.rid)

    def _make_room(self, incoming: Request):
        """Evict (context-save) lowest-priority resident request if the
        lane's quota is full — zero work when a slot is free (Obs. 1)."""
        res = [r for r in self._resident() if r.rid != incoming.rid]
        while res and not self.arena.can_admit(self.lane):
            victim = max(res, key=lambda r: r.priority)
            self._evict(victim)
            res.remove(victim)

    def _restore(self, r: Request):
        self.arena.acquire(self.lane, r.rid)
        if r.cache is None:
            _, r.cache = self._prefill(
                self.params, {"tokens": jnp.asarray(r.prompt[None])})
        elif not r.resident:
            r.cache = jax.device_put(r.cache)             # step_wise_mvin
            self._charge(self._cs_restore_s)
        r.resident = True

    # -- scheduling ---------------------------------------------------------
    def submit(self, r: Request):
        if r.submitted_at is None:         # front door may pre-stamp the
            r.submitted_at = self.clock()  # true arrival time
        self.requests[r.rid] = r

    def _eligible(self) -> List[Request]:
        live = [r for r in self.requests.values() if not r.done]
        his = [r for r in live if r.crit == Crit.HI]
        out = []
        for r in live:
            if r.crit == Crit.HI or self.mode == Mode.LO:
                out.append(r)
            elif self.policy.drop_lo_in_hi:
                continue
            elif his:
                continue                   # LO only when no HI active
            else:
                out.append(r)
        return out

    def _pick(self) -> Optional[Request]:
        el = self._eligible()
        if not el:
            live = [r for r in self.requests.values() if not r.done]
            return min(live, key=lambda r: r.priority) if live else None
        return min(el, key=lambda r: r.priority)

    def eligible_order(self) -> List[Request]:
        """The lane's service order right now: eligible requests sorted
        the way successive ``_pick`` calls would drain them (priority,
        rid tiebreak), with a non-preemptive owner pinned first.  Used
        by the admission-invariant property tests — with the workload
        convention HI priorities < LO priorities, no LO request may
        ever precede a HI request here."""
        el = sorted(self._eligible(), key=lambda r: (r.priority, r.rid))
        if self.policy.preemption == "none" and self.current is not None:
            cur = self.requests.get(self.current)
            if cur is not None and not cur.done:
                el = [cur] + [r for r in el if r.rid != cur.rid]
        return el

    def _mode_tick(self):
        live = [r for r in self.requests.values() if not r.done]
        if not live:
            self.mode = Mode.LO            # idle -> revert
            return
        for r in live:                     # monitor: LO-budget timers
            # ANY request overrunning its LO-criticality budget trips
            # the switch: an overrunning HI request needs its HI budget
            # (the paper's rule), and an overrunning LO request is
            # demoted to run only when no HI request is active
            # (imprecise-MCS stance; regression-tested at a
            # deterministic virtual time in tests/test_serving.py)
            if r.exec_s > r.lo_budget_s and self.mode == Mode.LO:
                self.mode = Mode.HI        # (transition is instantaneous
                                           #  here: saves are synchronous)

    # -- the serve loop -----------------------------------------------------
    def step(self) -> Optional[int]:
        """One scheduler invocation + one instruction (decode step).
        Returns the rid that ran, or None if idle."""
        self._mode_tick()
        r = self._pick()
        # non-preemptive baseline: a started request owns the accelerator
        if (self.policy.preemption == "none" and self.current is not None):
            cur = self.requests.get(self.current)
            if cur is not None and not cur.done:
                r = cur
        if r is None:
            return None
        if r.rid != self.current and self.current is not None:
            prev = self.requests.get(self.current)
            if prev is not None and not prev.done:
                prev.preemptions += 1
        self.current = r.rid
        if not r.resident:
            self._make_room(r)
            self._restore(r)
        if r.started_at is None:
            r.started_at = self.clock()
        t0 = self.clock()
        last = (r.generated[-1] if r.generated else int(r.prompt[-1]))
        logits, r.cache = self._decode(self.params,
                                       jnp.asarray([last], jnp.int32),
                                       r.cache)
        tok = int(jnp.argmax(logits[0]))
        r.generated.append(tok)
        r.exec_s += self.clock() - t0
        if r.first_token_at is None:
            r.first_token_at = self.clock()
        if len(r.generated) >= r.max_new_tokens \
                or int(r.cache["pos"]) >= self.max_len - 1:
            r.done = True
            r.finished_at = self.clock()
            r.resident = False
            r.cache = None                 # flush banks
            self.arena.release(self.lane, r.rid)
            self.current = None
        return r.rid

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        for _ in range(max_steps):
            if self.step() is None:
                break
        return self.requests


# ----------------------------------------------------------------------
# Multi-accelerator serving: one dispatch lane per virtual accelerator
# ----------------------------------------------------------------------

class MultiLaneServer:
    """Partitioned MESC serving over N virtual accelerator lanes.

    Each lane is a full :class:`MESCServer` — its own SS IV mode
    machine, preemption policy, and slice of the shared
    :class:`KVSlotArena` — and all lanes share one pair of jitted
    prefill/decode dispatch functions (compiled once).  Requests are
    statically partitioned onto lanes at submit time with the platform
    heuristics (``core.platform``): ``crit_aware`` spreads HI requests
    round-robin and steers LO requests toward HI-light lanes,
    ``worst_fit`` balances live-request counts, ``first_fit`` packs.
    ``step()`` advances every lane by one instruction (= decode step),
    so lanes progress in lockstep rounds; a HI request only ever
    contends with its own lane's requests — the partitioned-blocking
    win the multi-accelerator analysis (``wcrt.analyze_partitioned``)
    quantifies.
    """

    def __init__(self, cfg: ArchConfig, params, *, n_lanes: int = 2,
                 policy: Policy = None, rc: RuntimeConfig = CPU_RC,
                 max_len: int = 64, total_slots: Optional[int] = None,
                 heuristic: str = "crit_aware", jit_fns=None,
                 clocks: Optional[Sequence[Callable[[], float]]] = None,
                 cs_costs: Optional[Tuple[float, float]] = None):
        from repro.core.platform import HEURISTICS
        if heuristic not in HEURISTICS:
            raise ValueError(f"unknown heuristic {heuristic!r}")
        total_slots = total_slots if total_slots is not None else 2 * n_lanes
        self.arena = KVSlotArena(total_slots, n_lanes)
        self.heuristic = heuristic
        # dispatch functions: one shared jitted pair by default; the
        # virtual-clock harness injects per-lane (decode, prefill)
        # pairs instead (each bound to its own lane clock)
        if jit_fns is None:
            decode = jax.jit(
                lambda p, t, c: lm.decode_step(cfg, p, t, c, rc))
            prefill = jax.jit(
                lambda p, b: lm.prefill(cfg, p, b, rc, max_len=max_len))
            per_lane_fns = [(decode, prefill)] * n_lanes
        elif callable(jit_fns[0]):                     # one shared pair
            per_lane_fns = [tuple(jit_fns)] * n_lanes
        else:                                          # per-lane pairs
            if len(jit_fns) != n_lanes:
                raise ValueError(f"got {len(jit_fns)} jit_fns pairs "
                                 f"for {n_lanes} lanes")
            per_lane_fns = [tuple(fns) for fns in jit_fns]
        if clocks is None:
            per_lane_clocks: List[Callable[[], float]] = \
                [time.monotonic] * n_lanes
        elif callable(clocks):                         # one shared clock
            per_lane_clocks = [clocks] * n_lanes
        else:
            if len(clocks) != n_lanes:
                raise ValueError(f"got {len(clocks)} clocks for "
                                 f"{n_lanes} lanes")
            per_lane_clocks = list(clocks)
        self.lanes: List[MESCServer] = [
            MESCServer(cfg, params, policy=policy, rc=rc, max_len=max_len,
                       arena=self.arena, lane=i, jit_fns=per_lane_fns[i],
                       clock=per_lane_clocks[i], cs_costs=cs_costs)
            for i in range(n_lanes)]
        self.lane_of: Dict[int, int] = {}
        # lanes currently inside a fault-scenario outage window: the
        # partitioner never places new requests on them (in-flight work
        # stays put and resumes when the driver unblocks the lane)
        self.blocked_lanes: set = set()

    # -- request -> lane partitioning ---------------------------------------
    def _live(self, lane: MESCServer, crit: Optional[Crit] = None) -> int:
        return sum(1 for r in lane.requests.values() if not r.done
                   and (crit is None or r.crit == crit))

    def _assign(self, r: Request) -> int:
        n = len(self.lanes)
        # blocked (outage-window) lanes are excluded while any healthy
        # lane exists; with every lane blocked fall back to all lanes
        # so a direct submit still lands somewhere deterministic
        cand = [i for i in range(n) if i not in self.blocked_lanes] \
            or list(range(n))
        if self.heuristic == "first_fit":
            return next((i for i in cand
                         if self._live(self.lanes[i]) < self.arena.quotas[i]),
                        min(cand,
                            key=lambda i: self._live(self.lanes[i])))
        if self.heuristic == "worst_fit":
            return min(cand, key=lambda i: self._live(self.lanes[i]))
        # crit_aware: spread HI (tiebreak on total load so a HI request
        # lands on an idle lane, not behind running LO work); LO avoids
        # HI-loaded lanes (x2 weight)
        if r.crit == Crit.HI:
            return min(cand,
                       key=lambda i: (self._live(self.lanes[i], Crit.HI),
                                      self._live(self.lanes[i])))
        return min(cand,
                   key=lambda i: self._live(self.lanes[i], Crit.LO)
                   + 2 * self._live(self.lanes[i], Crit.HI))

    def submit(self, r: Request) -> int:
        lane = self._assign(r)
        self.lane_of[r.rid] = lane
        self.lanes[lane].submit(r)
        return lane

    # -- the serve loop -----------------------------------------------------
    def step(self) -> List[Optional[int]]:
        """One lockstep round: each lane runs one scheduler invocation
        + one instruction.  Returns the rid that ran per lane."""
        return [lane.step() for lane in self.lanes]

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        for _ in range(max_steps):
            if all(r is None for r in self.step()):
                break
        return self.requests

    @property
    def requests(self) -> Dict[int, Request]:
        out: Dict[int, Request] = {}
        for lane in self.lanes:
            out.update(lane.requests)
        return out

    def platform_mode(self) -> Mode:
        from repro.core.scheduler import MODE_SEVERITY
        return max((lane.mode for lane in self.lanes),
                   key=MODE_SEVERITY.__getitem__)
