"""MESC-scheduled model serving: the paper's mechanism driving real JAX
model execution.

Mapping (the TPU adaptation of SS IV/V, see DESIGN.md):
  * accelerator instruction  = one bounded-latency jitted dispatch
                               (one decode step / one prefill chunk)
  * scratchpad banks         = a bounded pool of device-resident KV-cache
                               slots (HBM arena); the bank allocator decides
                               which requests stay resident
  * context save / restore   = moving a request's cache pytree to/from host
                               DRAM (step_wise_mvout/mvin analogue)
  * config-copy buffer       = the request's generation config + position
  * task monitor             = wall-clock LO-budget timers -> mode switch

Scheduling follows scheduler.Policy + mode rules: HI requests preempt LO
requests at instruction (= decode-step) boundaries; LO requests are never
dropped (imprecise-MCS stance), they run when no HI request is active.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.scheduler import Mode, Policy
from repro.core.task import Crit
from repro.models import lm
from repro.models.common import RuntimeConfig, CPU_RC


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int
    priority: int
    crit: Crit
    lo_budget_s: float = 1e9        # LO-WCET analogue (wall clock)
    # runtime state
    generated: List[int] = dataclasses.field(default_factory=list)
    cache: Optional[dict] = None    # device (resident) or host (saved)
    resident: bool = False
    done: bool = False
    started_at: Optional[float] = None
    exec_s: float = 0.0
    first_token_at: Optional[float] = None
    submitted_at: float = 0.0
    finished_at: Optional[float] = None
    preemptions: int = 0
    saves: int = 0


class MESCServer:
    """Single-model mixed-criticality serving loop (batch size 1 per
    request; the accelerator is the shared resource)."""

    def __init__(self, cfg: ArchConfig, params, *, policy: Policy = None,
                 rc: RuntimeConfig = CPU_RC, max_len: int = 64,
                 resident_slots: int = 2):
        self.cfg = cfg
        self.params = params
        self.rc = rc
        self.policy = policy or Policy.mesc()
        self.max_len = max_len
        self.resident_slots = resident_slots   # "banks"
        self.mode = Mode.LO
        self.requests: Dict[int, Request] = {}
        self.current: Optional[int] = None
        self._decode = jax.jit(
            lambda p, t, c: lm.decode_step(cfg, p, t, c, rc))
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(cfg, p, b, rc, max_len=max_len))

    # -- bank pool ----------------------------------------------------------
    def _resident(self) -> List[Request]:
        return [r for r in self.requests.values()
                if r.resident and not r.done]

    def _make_room(self, incoming: Request):
        """Evict (context-save) lowest-priority resident request if the
        bank pool is full — zero work when a slot is free (Obs. 1)."""
        res = [r for r in self._resident() if r.rid != incoming.rid]
        while len(res) >= self.resident_slots:
            victim = max(res, key=lambda r: r.priority)
            victim.cache = jax.device_get(victim.cache)   # step_wise_mvout
            victim.resident = False
            victim.saves += 1
            res.remove(victim)

    def _restore(self, r: Request):
        if r.cache is None:
            _, r.cache = self._prefill(
                self.params, {"tokens": jnp.asarray(r.prompt[None])})
        elif not r.resident:
            r.cache = jax.device_put(r.cache)             # step_wise_mvin
        r.resident = True

    # -- scheduling ---------------------------------------------------------
    def submit(self, r: Request):
        r.submitted_at = time.monotonic()
        self.requests[r.rid] = r

    def _eligible(self) -> List[Request]:
        live = [r for r in self.requests.values() if not r.done]
        his = [r for r in live if r.crit == Crit.HI]
        out = []
        for r in live:
            if r.crit == Crit.HI or self.mode == Mode.LO:
                out.append(r)
            elif self.policy.drop_lo_in_hi:
                continue
            elif his:
                continue                   # LO only when no HI active
            else:
                out.append(r)
        return out

    def _pick(self) -> Optional[Request]:
        el = self._eligible()
        if not el:
            live = [r for r in self.requests.values() if not r.done]
            return min(live, key=lambda r: r.priority) if live else None
        return min(el, key=lambda r: r.priority)

    def _mode_tick(self):
        live = [r for r in self.requests.values() if not r.done]
        if not live:
            self.mode = Mode.LO            # idle -> revert
            return
        for r in live:                     # monitor: LO-budget timers
            if (r.crit == Crit.HI and r.exec_s > r.lo_budget_s
                    and self.mode == Mode.LO):
                self.mode = Mode.HI        # (transition is instantaneous
                                           #  here: saves are synchronous)

    # -- the serve loop -----------------------------------------------------
    def step(self) -> Optional[int]:
        """One scheduler invocation + one instruction (decode step).
        Returns the rid that ran, or None if idle."""
        self._mode_tick()
        r = self._pick()
        # non-preemptive baseline: a started request owns the accelerator
        if (self.policy.preemption == "none" and self.current is not None):
            cur = self.requests.get(self.current)
            if cur is not None and not cur.done:
                r = cur
        if r is None:
            return None
        if r.rid != self.current and self.current is not None:
            prev = self.requests.get(self.current)
            if prev is not None and not prev.done:
                prev.preemptions += 1
        self.current = r.rid
        if not r.resident:
            self._make_room(r)
            self._restore(r)
        if r.started_at is None:
            r.started_at = time.monotonic()
        t0 = time.monotonic()
        last = (r.generated[-1] if r.generated else int(r.prompt[-1]))
        logits, r.cache = self._decode(self.params,
                                       jnp.asarray([last], jnp.int32),
                                       r.cache)
        tok = int(jnp.argmax(logits[0]))
        r.generated.append(tok)
        r.exec_s += time.monotonic() - t0
        if r.first_token_at is None:
            r.first_token_at = time.monotonic()
        if len(r.generated) >= r.max_new_tokens \
                or int(r.cache["pos"]) >= self.max_len - 1:
            r.done = True
            r.finished_at = time.monotonic()
            r.resident = False
            r.cache = None                 # flush banks
            self.current = None
        return r.rid

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        for _ in range(max_steps):
            if self.step() is None:
                break
        return self.requests
