"""Fully-compiled lockstep simulation backend (``select_backend="jit"``).

This module compiles the *entire* lockstep step of the vectorized
engine — candidate min/argmin, every masked event handler (release,
scheduler tick, pending finish/overrun interrupt), the scheduler pass
(mode progression, pick_next, blocking bookkeeping) and the full
context-switch cost model — into one pure ``(carry) -> (carry)``
function under ``jax.jit`` + ``jax.lax.while_loop``.  The host submits
one XLA computation per batch and only observes the final state: the
"streaming accelerator executes the schedule, host only observes"
structure MESC itself argues for.  This removes the NumPy engine's
fixed per-step host-call budget (~300 NumPy calls per lockstep
iteration) that capped campaign throughput regardless of batch width.

RNG-equivalence contract
------------------------
The event/NumPy engines draw demands from sequential per-point
``np.random.Generator`` streams whose call count is data-dependent —
host RNG inside the loop, the exact structure a compiled loop cannot
replicate.  The jit backend replaces those with *counter-based* draws:
a splitmix64 hash of ``(seed, task, release_index)`` yields the two
uniforms of each accepted release (``jax.random.fold_in``'s threefry
would be semantically equivalent but costs ~50 extra kernels per
lockstep step on CPU).  Consequences:

  * **statistical equivalence** under demand jitter: same release
    phases (still drawn host-side from the point's ``default_rng(seed)``
    in the NumPy engine's order), identical demand *distributions*, but
    different demand *realizations* — per-point trajectories diverge
    while every corpus-level statistic (success rates, blocking, mode
    residency) agrees within sampling error.  Pinned by
    ``tests/test_simulator_jit.py`` and gated in CI;
  * **exact equivalence** on the degenerate zero-jitter profile
    (``demand_profile="nominal"``: demand == C_LO, no in-loop draws
    exist): metrics match the NumPy vec engine bit-for-bit, pinned per
    run and gated in CI.

``JIT_SIM_SEMANTICS_VERSION`` salts campaign cache keys for jit points
(``repro.experiments.spec``), so jit results never collide with event-
or vec-engine cache entries.

Implementation notes
--------------------
  * All per-point state lives in a flat dict-of-``jnp``-array carry;
    static per-batch tables (priorities, periods, program boundary
    tables) are traced arguments, so one compilation serves every batch
    of the same shape/policy class.
  * The pending finish/overrun interrupt table is fixed-width (XLA
    needs static shapes).  A push into a full table sets a per-point
    overflow flag; the affected points are re-run in small padded
    sub-batches at doubled widths (``_run_chunk``) — counter-based RNG
    makes every retry bit-deterministic and results independent of
    batch composition.
  * Scheduler aggregates (active/HI counts, locked banks, resident-LO
    counts) ride in the carry and are updated incrementally at the
    NumPy engine's sites; pick_next keys are rank-compressed int32.
  * Chunks are streamed from a small host thread pool
    (``default_streams``, ``REPRO_JIT_STREAMS``): the compiled loop
    releases the GIL, so independent chunks overlap on separate cores
    — something the host-call-bound Python engines cannot do.
  * Everything runs in float64/int64 under ``jax.experimental
    .enable_x64`` (scoped, not process-global): event times must not
    round-trip through float32.

JAX is an optional dependency of this module: importing it (and
``core.simulator_vec``) works without JAX installed; selecting the
backend then raises a ``RuntimeError`` naming the fix.
"""
from __future__ import annotations

import functools
import os
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence

import numpy as np

try:  # optional dependency — guarded so module import never fails
    import jax
    import jax.numpy as jnp
except ImportError:  # pragma: no cover - exercised via monkeypatch test
    jax = None
    jnp = None

from repro.core.isa import (ACCUM_BYTES, DMA_BYTES_PER_CYCLE,
                            DMA_SETUP_CYCLES, FLUSH_CYCLES)
from repro.core.program import Program
from repro.core.scheduler import Policy
from repro.core.simulator import AggSamples, RunMetrics
from repro.core.simulator_vec import (_BB, _C_CI, _C_CIQ, _C_NONE, _C_PI,
                                      _CAP, _CFG_CY, _FF, _HI, _INT,
                                      _LO, _MODE_KEYS, _NBANKS, _PEND,
                                      _PID_KEY, _READY, _REMAP_CY,
                                      _RESTORE_FIXED, _RUN, _TRANS,
                                      _VecBatch)
# the jit cache salt lives in (jax-free) simulator_vec so the
# experiments/spec layer can hash points without importing JAX;
# re-exported here as the canonical name
from repro.core.simulator_vec import JIT_SIM_SEMANTICS_VERSION  # noqa: F401
from repro.core.task import TaskParams

# pending-interrupt table: primary width, the give-up bound for the
# host-side double-on-overflow retry ladder, and the padded sub-batch
# size retries are grouped into (bounds compilation variants).  The
# NumPy engine's on-demand table settles at 32-64 on the reference
# corpora, so starting at 64 makes the retry the rare path.
_K0 = 64
_K_MAX = 1024
_RETRY_BUCKET = 64

# lockstep width per compiled chunk: small enough to stay
# cache-resident and to give the stream threads work to overlap,
# large enough to amortize per-step fixed cost (measured optimum on
# the 512-point BENCH corpus)
_STREAM_CHUNK = 64

# "no eligible task" sentinel for the rank-compressed int32 pick_next
# keys (every real key is rank * (T+1) + column << 2**30)
_EMPTY32 = 2 ** 30

# Packed per-point metric layouts: one int32 counter array ``mi`` and
# one float64 accumulator array ``mf`` in the carry, each updated by a
# single fused add-chain per step (one XLA kernel instead of ~15).
# int counters: [jobs_lo, jobs_hi, done_lo, done_hi, miss_lo, miss_hi,
#                mbm_lo, mbm_tr, mbm_hi, lo_rel_hi, lo_done_hi,
#                cs_count, pi_n, ci_n, save_n, restore_n]
_MI_JOBS, _MI_DONE, _MI_MISS, _MI_MBM = 0, 2, 4, 6
_MI_LO_REL, _MI_LO_DONE, _MI_CS = 9, 10, 11
_MI_PI_N, _MI_CI_N, _MI_SAVE_N, _MI_RESTORE_N = 12, 13, 14, 15
_MI_W = 16
# float accumulators: [exec_sum, overhead, pi_sum, ci_sum, save_sum,
#                      restore_sum, mode_cycles_lo/tr/hi]
_MF_EXEC, _MF_OVERHEAD, _MF_PI, _MF_CI = 0, 1, 2, 3
_MF_SAVE, _MF_RESTORE, _MF_MC = 4, 5, 6
_MF_W = 9


def require_jax(backend: str = "jit") -> None:
    """Fail fast with an actionable message when JAX is unavailable."""
    if jax is None:
        raise RuntimeError(
            f"select_backend={backend!r} needs JAX, which is not "
            "importable in this environment; install jax (CPU wheels: "
            "`pip install jax`) or use select_backend='numpy'")


# ----------------------------------------------------------------------
# Compiled step (built once per static policy/profile class)
# ----------------------------------------------------------------------

def _build_run(use_banks: bool, drop_lo: bool, preempt: str,
               nominal: bool):
    """Compile the whole-simulation while_loop for one static config.

    Everything dynamic (per-batch tables, scalars, carry) is a traced
    argument; jax re-specializes per array shape, so batches sharing
    (n_points, n_tasks, K, table sizes) share one compilation.

    XLA:CPU pays a ~flat dispatch cost per emitted kernel inside a
    while_loop, so the body is shaped to minimize *kernel count*, not
    flops:

      * per-point single-column reads are gathers (cheap); every
        (P, T) state array receives exactly ONE fused where-chain
        write per step (XLA CPU scatters are pathologically slow, and
        one chain beats four separate masked writes);
      * deferring all writes to the end of the step is sound because
        the four event classes are disjoint per point and handlers
        only touch their own point's row — the few same-row
        read-after-write hazards (advance -> dispatch, finish ->
        scheduler) are resolved by deriving the post-write values as
        (P,)-scalars instead of re-reading the array;
      * metric counters live in two packed arrays (``mi`` int32,
        ``mf`` float64) updated by one fused add-chain each;
      * the demand draw is a branch-free splitmix64 hash (a handful of
        fused u64 ops; ``jax.random``'s threefry costs ~50 kernels per
        step on CPU).
    """

    GOLD = np.uint64(0x9E3779B97F4A7C15)

    def _mix64(x):
        """splitmix64 finalizer — the counter-based RNG's mixer."""
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return x ^ (x >> np.uint64(31))

    def _u01(bits):
        """Top 53 bits -> uniform double in [0, 1)."""
        return (bits >> np.uint64(11)).astype(jnp.float64) \
            * (1.0 / (1 << 53))

    def _oh(col, width):
        return col[:, None] == jnp.arange(width)[None, :]

    def _get(arr, col):
        """arr[p, col[p]] (clamped columns; callers mask the result)."""
        return jnp.take_along_axis(arr, col[:, None], axis=1)[:, 0]

    def _chain(arr, *writes):
        """One fused masked-write pass: ``writes`` are (oh, mask, val)
        triples applied lowest-precedence-first (later entries win on
        overlap, matching the sequential write order they replace)."""
        out = arr
        for oh, mask, val in writes:
            val = jnp.asarray(val, arr.dtype)
            if val.ndim:
                val = val[:, None]
            out = jnp.where(oh & mask[:, None], val, out)
        return out

    def _apply_inc(M, incs):
        """One fused add-chain over a packed metric array; ``incs`` are
        (column, mask, value) with scalar or per-point columns."""
        cols = jnp.arange(M.shape[1])
        out = M
        for idx, mask, val in incs:
            idx = jnp.asarray(idx)
            if idx.ndim:
                ohm = (idx[:, None] == cols[None, :]) & mask[:, None]
            else:
                ohm = (cols == idx)[None, :] & mask[:, None]
            val = jnp.asarray(val, M.dtype)
            if val.ndim:
                val = val[:, None]
            out = out + jnp.where(ohm, val, jnp.zeros((), M.dtype))
        return out

    def _dma(nbytes):
        cy = DMA_SETUP_CYCLES + (nbytes + DMA_BYTES_PER_CYCLE - 1) \
            // DMA_BYTES_PER_CYCLE
        return jnp.where(nbytes <= 0, 0, cy)

    def _banks(nbytes):
        return (nbytes + _BB - 1) // _BB

    def _boundaries(tb, pids, off):
        """Vectorized Program.next_{instruction,operator}_boundary via
        one searchsorted over the globally keyed tables (identical
        float/int op order to the NumPy engine's ``_boundaries``)."""
        total = tb["prog_total"][pids]
        wrap = off >= total
        base = jnp.where(wrap,
                         jnp.floor_divide(off, total) * total, 0.0)
        off = off - base
        pk = pids.astype(jnp.float64) * float(_PID_KEY)
        # searchsorted as a broadcast compare+count: the tables are a
        # few hundred entries, and one dense pass beats the unrolled
        # binary search's serial gather chain on CPU
        if preempt == "instruction":
            off = jnp.minimum(jnp.maximum(off, 0.0), total - 1e-9)
            q = pk + off
            i = (tb["seg_key"][None, :] <= q[:, None]).sum(axis=1)
            seg_start = (tb["seg_key"][i] - pk) - tb["seg_cycles"][i]
            within = off - seg_start
            pat = tb["seg_pat"][i]
            rep = jnp.floor_divide(within, pat)
            rem = within - rep * pat
            cum = tb["pat_cumsum"][i]
            k = (cum <= rem[:, None]).sum(axis=1)
            acc = _get(cum, k)
            return jnp.trunc(base + seg_start + rep * pat + acc)
        q = pk + off
        i = (tb["op_key"][None, :] <= q[:, None]).sum(axis=1)
        i = jnp.minimum(i, tb["op_hi"][pids])
        return jnp.trunc(base + tb["op_end"][i])

    def _sample_demand(tb, sc, rcol, n, hi_r, c_lo_r):
        """Counter-based per-release demand draw: splitmix64 of
        (seed, task, release index) — identical distributions to the
        sequential-stream engines, but order-free so the compiled loop
        needs no host RNG state (see the module docstring)."""
        ctr = (rcol.astype(jnp.uint64) << np.uint64(33)) \
            + (n.astype(jnp.uint64) << np.uint64(1))
        s = tb["seed64"] + ctr * GOLD
        u0 = _u01(_mix64(s))
        u1 = _u01(_mix64(s + GOLD))
        over = hi_r & (u0 < sc["overrun_prob"])
        mag = jnp.where(over, 1.0 + (sc["cf"] - 1.0) * u1,
                        0.7 + 0.3 * u1)
        return c_lo_r * mag

    # ------------------------------------------------------------------
    def _step(tb, sc, c):
        """One lockstep iteration: pop each live point's next event and
        apply the handlers as masked updates — the jit counterpart of
        ``_VecBatch.run``'s loop body, one event class per point.  The
        scheduler aggregates (locked banks, resident-LO / active / HI
        counts) ride in the carry and are updated incrementally at the
        NumPy engine's sites; every (P, T) array is written once, at
        the end (see ``_build_run``)."""
        T = tb["valid"].shape[1]
        K = c["ev_time"].shape[1]
        next_tick = lambda t: (jnp.floor_divide(t, sc["t_sr"]) + 1) \
            * sc["t_sr"]
        mi_inc, mf_inc = [], []

        # ---- candidate argmin over the four event sources ------------
        rel_min = c["next_release"].min(axis=1)
        tickR_min = c["tick_release"].min(axis=1)
        ev_min = c["ev_time"].min(axis=1)
        cand = jnp.stack([rel_min, tickR_min, ev_min, c["tick_cs"]],
                         axis=1)
        j = jnp.argmin(cand, axis=1)
        tmin = cand.min(axis=1)
        fire = c["alive"] & (tmin <= sc["duration"])
        c["alive"] = fire            # non-firing points are done forever
        now = jnp.where(fire, tmin, c["now"])
        c["now"] = now
        is_rel = fire & (j == 0)
        is_tickR = fire & (j == 1)
        is_cs = fire & (j == 3)
        is_int = fire & (j == 2)

        # ---- release events (no scheduler pass of their own) ---------
        rcol = jnp.argmin(c["next_release"], axis=1)
        ohR = _oh(rcol, T)
        st_r = _get(c["status"], rcol)
        hi_r = _get(tb["is_hi"], rcol)
        crit_r = hi_r.astype(jnp.int32)
        # previous job still live: count one miss, skip this release
        fresh_miss = is_rel & (st_r != _PEND) \
            & (_get(c["job_deadline"], rcol) != jnp.inf)
        mi_inc.append((_MI_MISS + crit_r, fresh_miss, 1))
        mi_inc.append((_MI_MBM + c["mode"], fresh_miss, 1))
        accept = is_rel & (st_r == _PEND)
        if drop_lo:                   # AMC: LO not released off-LO
            accept = accept & (hi_r | (c["mode"] == _LO))
        c["act_cnt"] = c["act_cnt"] + accept
        c["hi_cnt"] = c["hi_cnt"] + (accept & hi_r)
        c_lo_r = _get(tb["c_lo"], rcol)
        if nominal:                   # zero-jitter profile: no draws
            dem = c_lo_r
        else:
            n_r = _get(c["rel_cnt"], rcol)
            dem = _sample_demand(tb, sc, rcol, n_r, hi_r, c_lo_r)
            c["rel_cnt"] = _chain(c["rel_cnt"], (ohR, accept, n_r + 1))
        mi_inc.append((_MI_JOBS + crit_r, accept, 1))
        rel_hi = accept & ~hi_r & (c["mode"] != _LO)
        mi_inc.append((_MI_LO_REL, rel_hi, 1))

        # ---- scheduler-tick pops (defer while a CS is in flight) -----
        ohT = _oh(jnp.argmin(c["tick_release"], axis=1), T)
        c["tick_cs"] = jnp.where(is_cs, jnp.inf, c["tick_cs"])
        tick_mask = is_tickR | is_cs
        busy_t = tick_mask & (now < c["accel_free_at"])
        c["tick_cs"] = jnp.where(
            busy_t, jnp.minimum(c["tick_cs"],
                                next_tick(c["accel_free_at"])),
            c["tick_cs"])
        tick_sched = tick_mask & ~busy_t

        # ---- pending finish/overrun interrupts: pop + guard ----------
        icol = jnp.argmin(c["ev_time"], axis=1)
        ohI = _oh(icol, K)
        itid = _get(c["ev_tid"], icol)
        ikind = _get(c["ev_kind"], icol)
        tidc = jnp.maximum(itid, 0)
        ohTid = _oh(tidc, T)
        guard = is_int & (c["running"] == itid) \
            & (_get(c["status"], tidc) == _RUN)

        # ---- one advance for every point that needs it this step -----
        # (the running column is shared by the advance, the interrupt
        # target and the dispatch drain, so the post-advance values are
        # carried forward as scalars instead of array re-reads)
        runc = jnp.maximum(c["running"], 0)
        ohRun = _oh(runc, T)
        elapsed = now - c["run_started"]
        do_adv = (guard | tick_sched) & (c["running"] >= 0) \
            & (elapsed > 0)
        exec_r0 = _get(c["exec_cy"], runc)
        exec_r1 = jnp.where(do_adv, exec_r0 + elapsed, exec_r0)
        mf_inc.append((_MF_EXEC, do_adv, elapsed))
        c["run_started"] = jnp.where(do_adv, now, c["run_started"])
        # GemminiRT.note_execution (exact integer growth model)
        etab_r = _get(tb["etab"], runc).astype(jnp.int64) * _BB
        grow = jnp.floor(elapsed * DMA_BYTES_PER_CYCLE).astype(jnp.int64)
        if use_banks:
            have = _get(c["r_bytes"], runc).astype(jnp.int64)
            free = (_NBANKS - c["locked"]).astype(jnp.int64)
            growing = do_adv & (have < etab_r) & (free > 0)
            want = jnp.minimum(jnp.minimum(etab_r, have + free * _BB),
                               have + grow)
            rb_grown = jnp.maximum(have, want)
            rb_1 = jnp.where(growing, rb_grown, have)
            c["locked"] = c["locked"] + jnp.where(
                growing, _banks(rb_grown) - _banks(have), 0).astype(
                    jnp.int32)
            went = growing & (have == 0) & (rb_grown > 0) \
                & ~_get(tb["is_hi"], runc)
            c["res_lo"] = c["res_lo"] + went
        else:
            have = _get(c["spad"], runc).astype(jnp.int64)
            growing = do_adv & (have < etab_r)
            others = c["spad"].sum(axis=1) - have
            want = jnp.minimum(
                jnp.minimum(etab_r, jnp.maximum(_CAP - others, 0)),
                have + grow)
            rb_1 = jnp.where(growing, jnp.maximum(have, want), have)
        acc_r0 = _get(c["acc_bytes"], runc).astype(jnp.int64)
        filling = do_adv & (acc_r0 < ACCUM_BYTES)
        grow_acc = jnp.floor_divide(
            elapsed * DMA_BYTES_PER_CYCLE, 4).astype(jnp.int64)
        acc_1 = jnp.where(filling,
                          jnp.minimum(ACCUM_BYTES, acc_r0 + grow_acc),
                          acc_r0)

        # ---- fire guard-passing finish/overrun events ----------------
        # (the interrupt target IS the running column for guard-passing
        # points, so exec_r1 / rb_1 are its post-advance values)
        done_m = guard & (ikind == 1) \
            & (exec_r1 >= _get(c["demand"], tidc) - 1e-6)
        hi_i = _get(tb["is_hi"], tidc)
        crit_i = hi_i.astype(jnp.int32)
        ddl_i = _get(c["job_deadline"], tidc)
        mi_inc.append((_MI_DONE + crit_i, done_m, 1))
        late = done_m & (now > ddl_i)
        mi_inc.append((_MI_MISS + crit_i, late, 1))
        mi_inc.append((_MI_MBM + c["mode"], late, 1))
        surv = done_m & _get(c["released_in_hi"], tidc) & (now <= ddl_i)
        mi_inc.append((_MI_LO_DONE, surv, 1))
        c["act_cnt"] = c["act_cnt"] - done_m
        c["hi_cnt"] = c["hi_cnt"] - (done_m & hi_i)
        # GemminiRT.evict
        mf_inc.append((_MF_OVERHEAD, done_m, float(FLUSH_CYCLES)))
        if use_banks:
            c["locked"] = c["locked"] - jnp.where(
                done_m, _banks(rb_1), 0).astype(jnp.int32)
            c["res_lo"] = c["res_lo"] - (done_m & (rb_1 > 0) & ~hi_i)
        c["running"] = jnp.where(done_m, -1, c["running"])
        # overrun: flag the budget excess, degrade LO -> transition
        fire_o = guard & (ikind == 2) \
            & (exec_r1 >= _get(tb["c_lo"], tidc) - 1e-6) \
            & ~_get(c["budget_overrun"], tidc)
        was_lo = fire_o & (c["mode"] == _LO)
        mf_inc.append((_MF_MC + c["mode"], was_lo,
                       now - c["last_mode_stamp"]))
        c["last_mode_stamp"] = jnp.where(was_lo, now,
                                         c["last_mode_stamp"])
        c["mode"] = jnp.where(was_lo, _TRANS, c["mode"])

        # ---- scheduler pass ------------------------------------------
        sched = tick_sched | done_m | fire_o
        # a stale event can land mid-switch: defer like a tick re-push
        busy_s = sched & (now < c["accel_free_at"])
        c["tick_cs"] = jnp.where(
            busy_s, jnp.minimum(c["tick_cs"],
                                next_tick(c["accel_free_at"])),
            c["tick_cs"])
        sched = sched & ~busy_s
        # mode progression (SS IV) off the carried aggregates
        mt = sched & (c["mode"] != _LO)
        to_hi = mt & (c["mode"] == _TRANS) & (c["res_lo"] <= 1)
        to_lo = mt & ~to_hi & (c["act_cnt"] == 0)
        new_mode = jnp.where(to_hi, _HI,
                             jnp.where(to_lo, _LO, c["mode"]))
        chg = new_mode != c["mode"]
        mf_inc.append((_MF_MC + c["mode"], chg,
                       now - c["last_mode_stamp"]))
        c["last_mode_stamp"] = jnp.where(chg, now,
                                         c["last_mode_stamp"])
        c["mode"] = new_mode
        # pick_next via masked min over the rank-compressed
        # (priority, column) keys; the finishing task left the active
        # set this step, which the deferred status write hasn't
        # recorded yet — mask its column out here
        active = (c["status"] != _PEND) & tb["valid"] \
            & ~(ohTid & done_m[:, None])
        act_key = jnp.where(active, tb["key32"], _EMPTY32).min(axis=1)
        hi_key = jnp.where(active & tb["is_hi"], tb["key32"],
                           _EMPTY32).min(axis=1)
        hi_active = c["hi_cnt"] > 0
        off_lo = c["mode"] != _LO
        if drop_lo:                   # AMC: LO never runs off-LO
            key = jnp.where(off_lo, hi_key, act_key)
        else:
            key = jnp.where(off_lo & hi_active, hi_key, act_key)
            # transition mode: a LO task may run only while its data
            # is still resident (rare — branch around the extra pass,
            # correcting for this step's deferred writes)
            need_tr = sched & off_lo & ~hi_active \
                & (c["mode"] == _TRANS)

            def _tr_keys(_):
                resid = c["data_in_accel"] | (c["r_bytes"] > 0)
                resid = resid & ~(ohTid & done_m[:, None])
                if use_banks:
                    resid = resid | (ohRun
                                     & (growing & (rb_grown > 0))[:, None])
                ok = active & (tb["is_hi"] | resid)
                return jnp.where(ok, tb["key32"], _EMPTY32).min(axis=1)

            key_tr = jax.lax.cond(
                need_tr.any(), _tr_keys,
                lambda _: jnp.full_like(key, _EMPTY32), None)
            key = jnp.where(need_tr, key_tr, key)
        nxt = (key % (T + 1)).astype(jnp.int32)
        nxt = jnp.where(key >= _EMPTY32, -1, nxt)
        # clear a stale running slot (event engine's defensive check)
        cur = c["running"]
        curc = jnp.maximum(cur, 0)
        ohC = _oh(curc, T)
        stale = sched & (cur >= 0) \
            & (_get(c["status"], curc) != _RUN)
        c["running"] = jnp.where(stale, -1, c["running"])
        # ohC / curc stay valid: stale points get cur < 0, for which
        # every consumer below is masked out — and whenever a dispatch
        # drains a current task, curc equals runc (the point advanced
        # the same column this step), so rb_1 / acc_1 / exec_r1 are its
        # post-advance values
        cur = c["running"]
        act_m = sched & (nxt >= 0) & (cur != nxt)
        # a displaced current task blocks the newcomer until the switch
        nxtc = jnp.maximum(nxt, 0)
        ohN = _oh(nxtc, T)
        hi_n = _get(tb["is_hi"], nxtc)
        hi_c = _get(tb["is_hi"], curc)
        blocked = act_m & (cur >= 0)
        bsince_0 = _get(c["blocked_since"], nxtc)
        fresh_b = blocked & jnp.isnan(bsince_0)
        bsince_1 = jnp.where(fresh_b, now, bsince_0)
        run_lo = (cur >= 0) & ~hi_c
        ci_shape = hi_n & run_lo
        cause_v = jnp.where(
            ci_shape, jnp.where(c["mode"] != _LO, _C_CI, _C_CIQ),
            _C_PI)
        cz_1 = jnp.where(fresh_b, cause_v,
                         _get(c["cause"], nxtc).astype(jnp.int32))
        if preempt == "none":         # cannot displace the running task
            act_m = act_m & (cur < 0)

        # ---- dispatch (context switch, Alg. 1) -----------------------
        has_cur = act_m & (cur >= 0)
        # drain to the preemption boundary
        boundary = _boundaries(tb, _get(tb["prog_id"], curc), exec_r1)
        drain = jnp.maximum(
            0.0, jnp.minimum(boundary, _get(c["demand"], curc))
            - exec_r1)
        exec_r2 = jnp.where(has_cur, exec_r1 + drain, exec_r1)
        drain_i = jnp.trunc(drain).astype(jnp.int64)
        # context_save cost model (GemminiRT)
        acc_cy = _dma(acc_1)
        if use_banks:
            need = _get(tb["eta"], nxtc) + c["locked"] > _NBANKS
            spadsave = need & (rb_1 > 0)
            remap_cy = _REMAP_CY
            resident = rb_1
        else:
            resident = _get(c["spad"], curc).astype(jnp.int64)
            resident = jnp.where(curc == runc, rb_1, resident)
            spadsave = resident > 0
            remap_cy = 0
        spad_cy = jnp.where(spadsave, _dma(resident), 0)
        br_save = drain_i + (_FF + _CFG_CY + remap_cy) + acc_cy + spad_cy
        kept = ~spadsave
        sv = has_cur & spadsave
        # HI-mode LO->LO preemption: full eviction of the old LO data
        lolo = has_cur & (c["mode"] == _HI) & ~hi_c & ~hi_n
        if use_banks:
            c["locked"] = c["locked"] - jnp.where(
                sv, _banks(resident), 0).astype(jnp.int32)
            c["res_lo"] = c["res_lo"] - (sv & ~hi_c)
            # the lolo eviction sees the residency left after the save
            rb_2 = jnp.where(sv, 0, rb_1)
            c["locked"] = c["locked"] - jnp.where(
                lolo, _banks(rb_2), 0).astype(jnp.int32)
            c["res_lo"] = c["res_lo"] - (lolo & (rb_2 > 0))
        mi_inc.append((_MI_CS, has_cur, 1))
        mf_inc.append((_MF_SAVE, has_cur, br_save.astype(jnp.float64)))
        mi_inc.append((_MI_SAVE_N, has_cur, 1))
        # context_restore for resumed tasks
        resume = act_m & ((_get(c["pc"], nxtc) > 0)
                          | (_get(c["status"], nxtc) == _INT))
        has_ctx = _get(c["ctx_valid"], nxtc)
        ctx_acc_n = _get(c["ctx_acc"], nxtc).astype(jnp.int64)
        ctx_spad_n = _get(c["ctx_spad"], nxtc).astype(jnp.int64)
        acc_cy_r = jnp.where(has_ctx, _dma(ctx_acc_n), 0)
        reload = resume & has_ctx & ~_get(c["ctx_kept"], nxtc) \
            & (ctx_spad_n > 0)
        spad_cy_r = jnp.where(reload, _dma(ctx_spad_n), 0)
        br_rest = jnp.where(has_ctx,
                            acc_cy_r + spad_cy_r + _RESTORE_FIXED, 0)
        if use_banks:
            br_rest = br_rest + jnp.where(reload, _REMAP_CY, 0)
            free_b = (_NBANKS - c["locked"]).astype(jnp.int64)
            new_res = jnp.minimum(ctx_spad_n, free_b * _BB)
            c["locked"] = c["locked"] + jnp.where(
                reload, _banks(new_res), 0).astype(jnp.int32)
            c["res_lo"] = c["res_lo"] + (reload & (new_res > 0) & ~hi_n)
        else:
            new_res = ctx_spad_n
        mf_inc.append((_MF_RESTORE, resume, br_rest.astype(jnp.float64)))
        mi_inc.append((_MI_RESTORE_N, resume, 1))
        # commit the switch
        switch = jnp.where(has_cur, br_save, 0).astype(jnp.float64) \
            + jnp.where(resume, br_rest, 0).astype(jnp.float64)
        mf_inc.append((_MF_OVERHEAD, act_m, switch))
        c["running"] = jnp.where(act_m, nxt, c["running"])
        # _record_unblock(nxt, at=now + switch)
        at = now + switch
        was_b = act_m & ~jnp.isnan(bsince_1)
        dt = at - bsince_1
        cz = jnp.where((cz_1 == _C_CIQ) & (c["mode"] != _LO), _C_CI,
                       cz_1)
        posd = was_b & (dt > 0)
        ci_m = posd & (cz == _C_CI)
        pi_m = posd & (cz != _C_CI)
        mf_inc.append((_MF_CI, ci_m, dt))
        mi_inc.append((_MI_CI_N, ci_m, 1))
        mf_inc.append((_MF_PI, pi_m, dt))
        mi_inc.append((_MI_PI_N, pi_m, 1))
        c["run_started"] = jnp.where(act_m, at, c["run_started"])
        c["accel_free_at"] = jnp.where(act_m, at, c["accel_free_at"])
        # future events for the new running task
        exec_n = _get(c["exec_cy"], nxtc)
        rem = _get(c["demand"], nxtc) - exec_n
        c_lo_n = _get(tb["c_lo"], nxtc)
        arm = act_m & hi_n & ~_get(c["budget_overrun"], nxtc) \
            & (exec_n < c_lo_n)
        t_fin = at + rem
        t_ovr = at + (c_lo_n - exec_n)
        # pending-interrupt slots: this step's pop frees a slot the
        # pushes may immediately reuse (the event engine's heap does)
        isfree = jnp.isinf(c["ev_time"]) | (ohI & is_int[:, None])
        n_free = isfree.sum(axis=1)
        oh1 = _oh(jnp.argmax(isfree, axis=1), K)
        oh2 = _oh(jnp.argmax(isfree & ~oh1, axis=1), K)
        do1 = act_m & (n_free >= 1)
        do2 = arm & (n_free >= 2)
        c["overflow"] = c["overflow"] | (act_m & (n_free < 1)) \
            | (arm & (n_free < 2))
        ddl_new = now + _get(tb["deadline_rel"], rcol)
        nrel_new = now + _get(tb["period"], rcol)
        tr_new = next_tick(now)

        # ---- barrier, then deferred writes: one fused pass per array -
        # XLA:CPU loop fusion re-evaluates a shared producer once per
        # fused consumer; the barrier materializes every (P,) scalar
        # and one-hot mask exactly once, so the ~20 write chains below
        # are each a cheap read-modify-select pass
        (ohR, ohT, ohI, ohTid, ohRun, ohC, ohN, oh1, oh2,
         is_rel, is_tickR, is_int, accept, fresh_miss, done_m, fire_o,
         act_m, has_cur, resume, has_ctx, reload, sv, lolo, was_b,
         fresh_b, do_adv, growing, filling, do1, do2, dem, exec_r2,
         rb_1, acc_1, new_res, ctx_acc_n, resident, kept, spadsave,
         t_fin, t_ovr, cause_v, nxtc, now, ddl_new, nrel_new, tr_new,
         rel_hi, mi_inc, mf_inc) = jax.lax.optimization_barrier(
            (ohR, ohT, ohI, ohTid, ohRun, ohC, ohN, oh1, oh2,
             is_rel, is_tickR, is_int, accept, fresh_miss, done_m,
             fire_o, act_m, has_cur, resume, has_ctx, reload, sv, lolo,
             was_b, fresh_b, do_adv, growing, filling, do1, do2, dem,
             exec_r2, rb_1, acc_1, new_res, ctx_acc_n, resident, kept,
             spadsave, t_fin, t_ovr, cause_v, nxtc, now, ddl_new,
             nrel_new, tr_new, rel_hi, mi_inc, mf_inc))
        c["ev_time"] = _chain(c["ev_time"], (ohI, is_int, jnp.inf),
                              (oh1, do1, t_fin), (oh2, do2, t_ovr))
        c["ev_tid"] = _chain(c["ev_tid"], (oh1, do1, nxtc),
                             (oh2, do2, nxtc))
        c["ev_kind"] = _chain(c["ev_kind"], (oh1, do1, 1), (oh2, do2, 2))
        # per-task state (precedence follows the sequential order the
        # chains replace; distinct-column conflicts were ruled out in
        # the dispatch analysis above)
        c["status"] = _chain(c["status"], (ohR, accept, _READY),
                             (ohTid, done_m, _PEND),
                             (ohC, has_cur, _INT), (ohN, act_m, _RUN))
        c["exec_cy"] = _chain(c["exec_cy"], (ohR, accept, 0.0),
                              (ohRun, do_adv | has_cur, exec_r2))
        c["demand"] = _chain(c["demand"], (ohTid, done_m, jnp.inf),
                             (ohR, accept, dem))
        c["job_deadline"] = _chain(
            c["job_deadline"], (ohR, fresh_miss, jnp.inf),
            (ohR, accept, ddl_new))
        c["next_release"] = _chain(
            c["next_release"], (ohR, is_rel, nrel_new))
        c["tick_release"] = _chain(c["tick_release"],
                                   (ohT, is_tickR, jnp.inf),
                                   (ohR, accept, tr_new))
        c["pc"] = _chain(c["pc"], (ohR, accept, 0), (ohN, act_m, 1))
        c["budget_overrun"] = _chain(c["budget_overrun"],
                                     (ohR, accept, False),
                                     (ohTid, fire_o, True))
        c["released_in_hi"] = _chain(c["released_in_hi"],
                                     (ohR, accept, rel_hi))
        c["blocked_since"] = _chain(c["blocked_since"],
                                    (ohN, fresh_b, now),
                                    (ohN, was_b, jnp.nan))
        c["cause"] = _chain(c["cause"], (ohN, fresh_b, cause_v),
                            (ohN, was_b, _C_NONE))
        if use_banks:
            c["r_bytes"] = _chain(
                c["r_bytes"],
                (ohRun, growing | done_m | sv | lolo,
                 jnp.where(done_m | sv | lolo, 0, rb_1)),
                (ohN, reload, new_res))
        else:
            c["spad"] = _chain(
                c["spad"],
                (ohRun, growing | done_m | sv,
                 jnp.where(done_m | sv, 0, rb_1)),
                (ohN, reload, new_res))
        c["acc_bytes"] = _chain(
            c["acc_bytes"],
            (ohRun, filling | done_m | has_cur,
             jnp.where(done_m | has_cur, 0, acc_1)),
            (ohN, resume & has_ctx, ctx_acc_n))
        c["data_in_accel"] = _chain(
            c["data_in_accel"], (ohTid, done_m, False),
            (ohC, has_cur, kept & ~lolo),
            (ohN, resume & has_ctx, True))
        c["ctx_valid"] = _chain(c["ctx_valid"], (ohTid, done_m, False),
                                (ohC, has_cur, True))
        c["ctx_acc"] = _chain(c["ctx_acc"], (ohC, has_cur, acc_1))
        c["ctx_spad"] = _chain(
            c["ctx_spad"],
            (ohC, has_cur, jnp.where(spadsave, resident, 0)))
        c["ctx_kept"] = _chain(c["ctx_kept"], (ohC, has_cur, kept))
        c["mi"] = _apply_inc(c["mi"], mi_inc)
        c["mf"] = _apply_inc(c["mf"], mf_inc)
        c["steps"] = c["steps"] + 1
        return c

    def _run(tb, sc, carry):
        def cond(c):
            # overflowing points keep stepping (their results are
            # discarded and selectively re-run at a wider table); the
            # healthy majority of the batch must run to completion
            return c["alive"].any() & (c["steps"] < sc["max_steps"])

        return jax.lax.while_loop(cond, functools.partial(_step, tb, sc),
                                  carry)

    return jax.jit(_run)


@functools.lru_cache(maxsize=None)
def _compiled_run(use_banks: bool, drop_lo: bool, preempt: str,
                  nominal: bool):
    """One jitted runner per static policy/profile class — the memo is
    what makes 'one compilation per shape/config' true: jax.jit caches
    specializations per *function object*, so handing back a fresh
    closure per call would retrace and recompile every chunk."""
    return _build_run(use_banks, drop_lo, preempt, nominal)


# ----------------------------------------------------------------------
# Host driver: state build, overflow retry, tail accounting, assembly
# ----------------------------------------------------------------------

def _rank_keys(b: _VecBatch) -> np.ndarray:
    """Rank-compress the NumPy engine's (priority, column) int64 keys
    into int32: pick_next only compares keys *within* a point, so a
    per-point dense rank of the priorities preserves the selection
    (ties still break on the lowest column) at a quarter of the
    memory traffic."""
    pr = np.minimum(b.prio, 2 ** 40)
    key = np.empty((b.P, b.T), np.int32)
    cols = np.arange(b.T, dtype=np.int32)
    for p in range(b.P):
        distinct = np.unique(pr[p])
        key[p] = np.searchsorted(distinct, pr[p]).astype(np.int32) \
            * (b.T + 1) + cols
    return key


def _tables(b: _VecBatch, seeds: Sequence[int]) -> Dict[str, "jnp.ndarray"]:
    return {
        "seed64": jnp.asarray(
            np.asarray(seeds, dtype=np.int64).astype(np.uint64)),
        "valid": jnp.asarray(b.valid),
        "key32": jnp.asarray(_rank_keys(b)),
        "period": jnp.asarray(b.period),
        "deadline_rel": jnp.asarray(b.deadline_rel),
        "c_lo": jnp.asarray(b.c_lo),
        "is_hi": jnp.asarray(b.is_hi),
        "eta": jnp.asarray(b.eta.astype(np.int32)),
        "etab": jnp.asarray(b.etab.astype(np.int32)),
        "prog_id": jnp.asarray(b.prog_id.astype(np.int32)),
        "prog_total": jnp.asarray(b._prog_total.astype(np.float64)),
        "seg_key": jnp.asarray(b._g_seg_key),
        "seg_cycles": jnp.asarray(b._g_seg_cycles),
        "seg_pat": jnp.asarray(b._g_seg_pat),
        "pat_cumsum": jnp.asarray(b._g_pat_cumsum),
        "op_key": jnp.asarray(b._g_op_key),
        "op_end": jnp.asarray(b._g_op_end),
        "op_hi": jnp.asarray(b._g_op_hi),
    }


def _carry0(b: _VecBatch, seeds: Sequence[int],
            K: int) -> Dict[str, "jnp.ndarray"]:
    """Initial carry: the freshly-initialized NumPy batch state (which
    already drew the release phases from each point's host RNG) plus
    empty metric/interrupt tables of width ``K``."""
    P, T = b.P, b.T
    f = lambda a: jnp.asarray(a)
    zP = jnp.zeros(P)
    zPi = jnp.zeros(P, jnp.int32)
    return {
        "status": jnp.zeros((P, T), jnp.int8),
        "exec_cy": jnp.zeros((P, T)),
        "demand": jnp.full((P, T), jnp.inf),
        "job_deadline": jnp.zeros((P, T)),
        "budget_overrun": jnp.zeros((P, T), bool),
        "data_in_accel": jnp.zeros((P, T), bool),
        "pc": jnp.zeros((P, T), jnp.int8),
        "blocked_since": jnp.full((P, T), jnp.nan),
        "cause": jnp.zeros((P, T), jnp.int8),
        "released_in_hi": jnp.zeros((P, T), bool),
        "r_bytes": jnp.zeros((P, T), jnp.int32),
        "spad": jnp.zeros((P, T), jnp.int32),
        "acc_bytes": jnp.zeros((P, T), jnp.int32),
        "ctx_valid": jnp.zeros((P, T), bool),
        "ctx_acc": jnp.zeros((P, T), jnp.int32),
        "ctx_spad": jnp.zeros((P, T), jnp.int32),
        "ctx_kept": jnp.zeros((P, T), bool),
        "next_release": f(b.next_release),
        "tick_release": jnp.full((P, T), jnp.inf),
        "rel_cnt": jnp.zeros((P, T), jnp.int32),
        "ev_time": jnp.full((P, K), jnp.inf),
        "ev_tid": jnp.full((P, K), -1, jnp.int32),
        "ev_kind": jnp.zeros((P, K), jnp.int8),
        "locked": zPi,
        "res_lo": zPi,
        "act_cnt": zPi,
        "hi_cnt": zPi,
        "now": zP,
        "mode": jnp.zeros(P, jnp.int32),
        "running": jnp.full(P, -1, jnp.int32),
        "accel_free_at": zP,
        "run_started": zP,
        "last_mode_stamp": zP,
        "tick_cs": jnp.full(P, jnp.inf),
        "alive": jnp.ones(P, bool),
        "overflow": jnp.zeros(P, bool),
        "steps": jnp.zeros((), jnp.int64),
        "mi": jnp.zeros((P, _MI_W), jnp.int32),
        "mf": jnp.zeros((P, _MF_W)),
    }


def _max_steps(b: _VecBatch, duration: float) -> int:
    """Loose per-point event-count bound — a diverging while_loop is an
    engine bug and must surface as an error, not a hang."""
    with np.errstate(divide="ignore"):
        rel = np.where(b.valid, duration / b.period + 2, 0.0).sum(axis=1)
    return int(64 * (rel.max() + 16) + 65536)


# (config, P, T, K) tuples whose XLA executable is already built in
# this process — lets simulate_jbatch skip the serial warm-up span and
# pool every chunk immediately on repeat runs
_WARM: set = set()


def _warm_key(policy: Policy, nominal: bool, P: int, T: int,
              K: int) -> tuple:
    return (policy.use_banks, policy.drop_lo_in_hi, policy.preemption,
            nominal, P, T, K)


def _run_once(b: _VecBatch, policy: Policy, seeds: Sequence[int],
              duration: float, overrun_prob: float, cf: float,
              nominal: bool, K: int) -> Dict[str, np.ndarray]:
    """One compiled run of a prepared batch at interrupt-table width
    ``K``; returns the final carry as NumPy arrays."""
    run = _compiled_run(policy.use_banks, policy.drop_lo_in_hi,
                        policy.preemption, nominal)
    from jax.experimental import enable_x64
    max_steps = _max_steps(b, duration)
    # event times are float64; everything (array upload included) must
    # happen under x64 or XLA would round-trip them through float32
    with enable_x64():
        tb = _tables(b, seeds)
        sc = {"t_sr": jnp.float64(policy.t_sr),
              "overrun_prob": jnp.float64(overrun_prob),
              "cf": jnp.float64(cf),
              "duration": jnp.float64(duration),
              "max_steps": jnp.int64(max_steps)}
        final = run(tb, sc, _carry0(b, seeds, K))
        final = {k: np.asarray(v) for k, v in final.items()}
    if final["steps"] >= max_steps and final["alive"].any():
        raise RuntimeError(
            f"jit engine: lockstep loop hit the {max_steps}-step "
            "safety bound with live points remaining")
    _WARM.add(_warm_key(policy, nominal, b.P, b.T, K))
    return final


def _run_chunk(tasksets, programs, policy, seeds, duration, overrun_prob,
               cf, demand_profile: str) -> List[RunMetrics]:
    """Simulate one chunk with the per-point overflow-retry ladder.

    The chunk first runs at the narrow ``_K0`` interrupt table (ample
    for typical points).  Points whose table overflowed — a per-point,
    batch-composition-independent event — are re-run in small padded
    sub-batches at doubled widths until they fit; the counter-based
    RNG makes every retry bit-deterministic, so a point's result never
    depends on which batch or table width executed it."""
    nominal = demand_profile == "nominal"
    out: List[Optional[RunMetrics]] = [None] * len(tasksets)
    idx = list(range(len(tasksets)))
    K = _K0
    while idx:
        ts = [tasksets[i] for i in idx]
        sd = [int(seeds[i]) for i in idx]
        # pad retry sub-batches up to the bucket size so the ladder
        # reuses one compilation per (bucket, K) instead of one per
        # subset shape (padded copies are simulated and discarded)
        if K > _K0 and len(ts) < _RETRY_BUCKET:
            pad = _RETRY_BUCKET - len(ts)
            ts = ts + [ts[-1]] * pad
            sd = sd + [sd[-1]] * pad
        b = _VecBatch(ts, programs, policy, seeds=sd, duration=duration,
                      overrun_prob=overrun_prob, cf=cf)
        final = _run_once(b, policy, sd, duration, overrun_prob, cf,
                          nominal, K)
        metrics = _assemble(b, final, duration)
        redo = []
        for pos, i in enumerate(idx):
            if final["overflow"][pos]:
                redo.append(i)
            else:
                out[i] = metrics[pos]
        idx = redo
        K *= 2
        if idx and K > _K_MAX:
            raise RuntimeError(
                "jit engine: pending-interrupt table exceeded "
                f"{_K_MAX} slots — simulation state diverged")
    return out  # type: ignore[return-value]


def _assemble(b: _VecBatch, s: Dict[str, np.ndarray],
              duration: float) -> List[RunMetrics]:
    """Tail accounting (the event engine's post-loop pass) + RunMetrics
    assembly from the final carry."""
    P = b.P
    out: List[RunMetrics] = []
    live = (s["status"] != _PEND) & b.valid \
        & (duration > s["job_deadline"])
    mi, mf = s["mi"], s["mf"]
    for p in range(P):
        mode_cycles = mf[p, _MF_MC:_MF_MC + 3].copy()
        mode_cycles[s["mode"][p]] += duration - s["last_mode_stamp"][p]
        misses = mi[p, _MI_MISS:_MI_MISS + 2].astype(np.int64).copy()
        for t in live[p].nonzero()[0]:
            misses[int(b.is_hi[p, t])] += 1
        out.append(RunMetrics(
            pi_blocking=AggSamples(mf[p, _MF_PI], mi[p, _MI_PI_N]),
            ci_blocking=AggSamples(mf[p, _MF_CI], mi[p, _MI_CI_N]),
            save_cycles=AggSamples(mf[p, _MF_SAVE], mi[p, _MI_SAVE_N]),
            restore_cycles=AggSamples(mf[p, _MF_RESTORE],
                                      mi[p, _MI_RESTORE_N]),
            jobs={"LO": int(mi[p, _MI_JOBS]),
                  "HI": int(mi[p, _MI_JOBS + 1])},
            done={"LO": int(mi[p, _MI_DONE]),
                  "HI": int(mi[p, _MI_DONE + 1])},
            misses={"LO": int(misses[0]), "HI": int(misses[1])},
            misses_by_mode={k: int(mi[p, _MI_MBM + i])
                            for i, k in enumerate(_MODE_KEYS)},
            lo_released_in_hi=int(mi[p, _MI_LO_REL]),
            lo_done_in_hi=int(mi[p, _MI_LO_DONE]),
            mode_cycles={k: float(mode_cycles[i])
                         for i, k in enumerate(_MODE_KEYS)},
            cs_count=int(mi[p, _MI_CS]),
            exec_cycles=float(mf[p, _MF_EXEC]),
            overhead_cycles=float(mf[p, _MF_OVERHEAD])))
    return out


# ----------------------------------------------------------------------
# Public entry point (called by simulator_vec.simulate_vbatch)
# ----------------------------------------------------------------------

def default_streams() -> int:
    """Concurrent host threads driving independent compiled chunks.

    The compiled engine releases the GIL for the whole while_loop, so
    independent chunks genuinely overlap on separate cores — an engine
    property the Python-loop backends cannot share (their lockstep is
    host-call bound).  Override with ``REPRO_JIT_STREAMS``."""
    env = os.environ.get("REPRO_JIT_STREAMS")
    if env:
        return max(int(env), 1)
    return max(min(2, os.cpu_count() or 1), 1)


def simulate_jbatch(tasksets: Sequence[List[TaskParams]],
                    programs: Dict[str, Program], policy: Policy, *,
                    seeds: Sequence[int], duration: float = 2e7,
                    overrun_prob: float = 0.3, cf: float = 2.0,
                    batch_size: int = 256,
                    demand_profile: str = "sampled",
                    streams: Optional[int] = None) -> List[RunMetrics]:
    """Fully-compiled batch simulation: one ``lax.while_loop`` per
    chunk of points, no host work inside the loop, chunks streamed
    concurrently from ``streams`` host threads.

    Prefer :func:`repro.core.simulator_vec.simulate_vbatch` with
    ``select_backend="jit"`` — it validates arguments and routes here.
    See the module docstring for the RNG-equivalence contract.
    """
    require_jax()
    n = len(tasksets)
    if n != len(seeds):
        raise ValueError(f"{n} tasksets vs {len(seeds)} seeds")
    streams = default_streams() if streams is None else max(streams, 1)
    # small chunks keep the lockstep state cache-resident and give the
    # thread pool work to overlap (64 measured fastest on the BENCH
    # corpus — see docs/performance.md); the ragged tail span is
    # padded to the common chunk shape so it reuses the same
    # compilation (padded copies are simulated and discarded)
    chunk = max(1, min(batch_size, _STREAM_CHUNK))
    spans = []
    for lo in range(0, n, chunk):
        idxs = list(range(lo, min(lo + chunk, n)))
        real = len(idxs)
        if lo and real < chunk:
            idxs = idxs + [idxs[-1]] * (chunk - real)
        spans.append((idxs, real))

    def go(span):
        idxs, real = span
        part = _run_chunk([tasksets[i] for i in idxs], programs, policy,
                          [int(seeds[i]) for i in idxs], duration,
                          overrun_prob, cf, demand_profile)
        return part[:real]

    def span_warm(span):
        idxs, _ = span
        T = max(len(tasksets[i]) for i in idxs)
        return _warm_key(policy, demand_profile == "nominal",
                         len(idxs), T, _K0) in _WARM

    if streams == 1 or len(spans) == 1:
        parts = [go(sp) for sp in spans]
    elif all(span_warm(sp) for sp in spans):
        # every span's executable is already built: pool everything
        with ThreadPoolExecutor(max_workers=streams) as ex:
            parts = list(ex.map(go, spans))
    else:
        # run the first span serially so the (chunk, _K0) compilation
        # is warm before the pool fans out over the rest
        parts = [go(spans[0])]
        with ThreadPoolExecutor(max_workers=streams) as ex:
            parts += list(ex.map(go, spans[1:]))
    out: List[RunMetrics] = []
    for part in parts:
        out.extend(part)
    return out
