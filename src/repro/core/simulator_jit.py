"""Fully-compiled lockstep simulation backend (``select_backend="jit"``).

This module compiles the *entire* lockstep step of the vectorized
engine — candidate min/argmin, every masked event handler (release,
scheduler tick, pending finish/overrun interrupt), the scheduler pass
(mode progression, pick_next, blocking bookkeeping) and the full
context-switch cost model — into one pure ``(carry) -> (carry)``
function under ``jax.jit`` + ``jax.lax.while_loop``.  The host submits
one XLA computation per batch and only observes the final state: the
"streaming accelerator executes the schedule, host only observes"
structure MESC itself argues for.  This removes the NumPy engine's
fixed per-step host-call budget (~300 NumPy calls per lockstep
iteration) that capped campaign throughput regardless of batch width.

RNG-equivalence contract
------------------------
The event/NumPy engines draw demands from sequential per-point
``np.random.Generator`` streams whose call count is data-dependent —
host RNG inside the loop, the exact structure a compiled loop cannot
replicate.  The jit backend replaces those with *counter-based* draws:
a splitmix64 hash of ``(seed, task, release_index)`` yields the two
uniforms of each accepted release (``jax.random.fold_in``'s threefry
would be semantically equivalent but costs ~50 extra kernels per
lockstep step on CPU).  Consequences:

  * **statistical equivalence** under demand jitter: same release
    phases (still drawn host-side from the point's ``default_rng(seed)``
    in the NumPy engine's order), identical demand *distributions*, but
    different demand *realizations* — per-point trajectories diverge
    while every corpus-level statistic (success rates, blocking, mode
    residency) agrees within sampling error.  Pinned by
    ``tests/test_simulator_jit.py`` and gated in CI;
  * **exact equivalence** on the degenerate zero-jitter profile
    (``demand_profile="nominal"``: demand == C_LO, no in-loop draws
    exist): metrics match the NumPy vec engine bit-for-bit, pinned per
    run and gated in CI.

``JIT_SIM_SEMANTICS_VERSION`` salts campaign cache keys for jit points
(``repro.experiments.spec``), so jit results never collide with event-
or vec-engine cache entries.

Grouped carry layout
--------------------
XLA:CPU pays a ~flat dispatch cost per emitted kernel inside a
``while_loop`` body, so the loop carry is grouped into a handful of
dtype-homogeneous tensors, each written by ONE fused pass per step,
instead of the ~38 individually-updated per-field arrays of the first
jit engine (measured kernel counts: ``lockstep_kernel_count``, logged
into ``BENCH_sim.json`` by ``benchmarks/perf_sim.py``):

  * ``flags`` — one ``(P, T)`` int32 *bitfield* holding all eight small
    per-task state fields (status, pc, cause, budget_overrun,
    data_in_accel, released_in_hi, ctx_valid, ctx_kept) plus the
    per-task release counter: one gather yields every field of a task,
    and one 5-write read-modify-write chain replaces eight separate
    masked-write kernels at *fewer* total element passes;
  * six ``(P, T)`` float64 event/time arrays (exec_cy, demand,
    job_deadline, blocked_since, next_release, tick_release) — kept
    separate on purpose: stacking them into one ``(P, 6, T)`` block
    measures *slower* on XLA:CPU (the concatenate defeats loop fusion);
  * four ``(P, T)`` int32 byte-count arrays (res_bytes, acc_bytes,
    ctx_acc, ctx_spad; residency cap 256 KiB and accumulator cap
    64 KiB do not fit one int32 together);
  * the pending-interrupt table as ``(P, K)`` float64 ``ev_time`` plus
    one ``(P, K)`` int32 ``ev_pay`` payload (``tid * 4 + kind``),
    merging the old tid/kind pair;
  * ``pi`` — one packed ``(P, 24)`` int32 per-point block: mode,
    running task, locked banks, resident-LO count, active/HI counts,
    alive + overflow bits, then the 16 int metric counters; written by
    ONE fused column-onehot where-chain + add-chain (assembling the
    same block via stack/concatenate measures ~2.7x slower per step —
    XLA:CPU materializes concat operands as separate thunks);
  * ``pf`` — one packed ``(P, 14)`` float64 per-point block: clock,
    accelerator-free time, run-started stamp, mode stamp, CS-tick
    time, then the 9 float metric accumulators; same single fused
    write.

Stale-interrupt pruning (the step's compaction pass)
----------------------------------------------------
A pending finish/overrun entry ``(tid, t_e)`` is *provably dead* — it
can never pass the firing guard ``running == tid and status[tid] ==
RUNNING`` in any future — when, at the end of a step, task ``tid`` has
no live job (status PENDING) and ``t_e < next_release[tid]``: a
PENDING task can only become RUNNING at/after its next release, so at
time ``t_e`` it is still PENDING and the guard fails.  In the event
engine a guard-failing pop is a pure heap pop — no advance, no metric,
no state change — so removing the entry early is unobservable
(bit-exactness vs the unpruned NumPy engine pinned by the nominal CI
gate and a hypothesis property test).  The common producer of such
entries is a HI job whose sampled demand stays below C_LO (probability
``1 - overrun_prob`` per HI job): its overrun timer at
``dispatch + (C_LO - exec)`` outlives the finish at
``dispatch + (demand - exec)``, and once the job completes the task is
PENDING with a next release typically far beyond the timer.  Every
*other* stale-entry class fires strictly before its superseding event
(within one job, ``at + rem`` and ``at + C_LO - exec`` are
nondecreasing across re-dispatches, because execution time gained
never exceeds wall time elapsed) while its task may be running again —
those entries MUST be replayed, because their guarded pop calls the
advance and checkpoints the integer-floored residency growth of
``note_execution``; the pruning pass keeps them, exactly as the NumPy
engines replay them.  Pruning both shrinks the fixed-width table's
common-case occupancy (making the double-on-overflow retry ladder
rarer) and removes the dead entries' no-op pops from the lockstep
(fewer ``while_loop`` iterations).

Implementation notes
--------------------
  * Static per-batch tables (priorities, periods, program boundary
    tables) are traced arguments, so one compilation serves every batch
    of the same shape/policy class.
  * The pending finish/overrun interrupt table is fixed-width (XLA
    needs static shapes).  A push into a full table sets a per-point
    overflow flag; the affected points are re-run in small padded
    sub-batches at doubled widths (``_run_chunk``) — counter-based RNG
    makes every retry bit-deterministic and results independent of
    batch composition.  A point still overflowing at the maximum width
    raises a point-identified error instead of returning metrics from
    a saturated table.  ``REPRO_JIT_TABLE_WIDTH`` /
    ``REPRO_JIT_TABLE_MAX`` override the ladder bounds (CI shrinks
    them to exercise the ladder and the error path every run).
  * Scheduler aggregates (active/HI counts, locked banks, resident-LO
    counts) ride in the packed ``pi`` block and are updated
    incrementally at the NumPy engine's sites; pick_next keys are
    rank-compressed int32.
  * Batches are dispatched as *device superchunks*: ``shard_map`` over
    a 1-D mesh of logical host devices (``REPRO_DEVICES``,
    ``runtime.device_config``) splits the point axis of one
    ``devices x 64`` superchunk so every logical device runs its own
    copy of the while_loop on its point-shard — simulation points are
    independent, so the mapped body has no collectives and each
    device's loop halts on its own shard's quiescence.  Per-point
    keyed RNG draws make the sharded output bit-identical to the
    single-device engine at any device count (gated in CI at
    ``REPRO_DEVICES`` 2 and 4).  The carry is donated to the runner,
    so the dominant buffers are reused in place.
  * Everything runs in float64/int64 under ``jax.experimental
    .enable_x64`` (scoped, not process-global): event times must not
    round-trip through float32.

JAX is an optional dependency of this module: importing it (and
``core.simulator_vec``) works without JAX installed; selecting the
backend then raises a ``RuntimeError`` naming the fix.
"""
from __future__ import annotations

import functools
import os
import re
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

try:  # optional dependency — guarded so module import never fails
    import jax
    import jax.numpy as jnp
except ImportError:  # pragma: no cover - exercised via monkeypatch test
    jax = None
    jnp = None

from repro.core.isa import (ACCUM_BYTES, DMA_BYTES_PER_CYCLE,
                            DMA_SETUP_CYCLES, FLUSH_CYCLES)
from repro.core.program import Program
from repro.core.scheduler import Policy
from repro.core.simulator import AggSamples, RunMetrics
from repro.core.simulator_vec import (_BB, _C_CI, _C_CIQ, _C_NONE, _C_PI,
                                      _CAP, _CFG_CY, _FF, _HI, _INT,
                                      _LO, _MODE_KEYS, _NBANKS, _PEND,
                                      _PID_KEY, _READY, _REMAP_CY,
                                      _RESTORE_FIXED, _RUN, _TRANS,
                                      _VecBatch)
# the jit cache salt lives in (jax-free) simulator_vec so the
# experiments/spec layer can hash points without importing JAX;
# re-exported here as the canonical name
from repro.core.simulator_vec import JIT_SIM_SEMANTICS_VERSION  # noqa: F401
from repro.core.task import TaskParams
from repro.scenarios import (burst_multiplier, burst_window_index,
                             demand_multiplier, get_scenario)
# env validation + logical-device plumbing live with the other runtime
# environment code; both are importable without JAX
from repro.runtime.device_config import (_env_int, configure_host_devices,
                                         default_device_count,
                                         jax_initialized,
                                         resolve_device_count)

# XLA reads --xla_force_host_platform_device_count exactly once, at
# first backend init — which in a campaign process is triggered by this
# engine's first computation.  Forcing the REPRO_DEVICES pool at import
# (env mutation only, no jax touched) guarantees the flag is in place
# even when the caller runs a single-device batch before a sharded one.
if default_device_count() > 1 and not jax_initialized():
    configure_host_devices()

# pending-interrupt table: primary width, the give-up bound for the
# host-side double-on-overflow retry ladder, and the padded sub-batch
# size retries are grouped into (bounds compilation variants).  The
# NumPy engine's on-demand table settles at 32-64 on the reference
# corpora; with the stale-interrupt pruning pass the common-case
# occupancy is lower still, so starting at 64 makes the retry the
# rare path.  REPRO_JIT_TABLE_WIDTH / REPRO_JIT_TABLE_MAX override
# both bounds (validated in _env_int).
_K0 = 64
_K_MAX = 1024
_RETRY_BUCKET = 64

# compile-time switch for the stale-interrupt pruning pass; part of
# the compilation cache key.  Only tests flip it (to prove pruning is
# semantics-free by diffing against the unpruned graph).
_PRUNE_STALE = True

# lockstep width per device: small enough to stay cache-resident,
# large enough to amortize per-step fixed cost (measured optimum on
# the 512-point BENCH corpus); a superchunk is devices * this
_STREAM_CHUNK = 64

# "no eligible task" sentinel for the rank-compressed int32 pick_next
# keys (every real key is rank * (T+1) + column << 2**30)
_EMPTY32 = 2 ** 30

# ---- flags: the (P, T) int32 per-task bitfield -----------------------
# [1:0] status (PEND/READY/RUN/INT)   [2] pc>0     [4:3] blocking cause
# [5] budget_overrun   [6] data_in_accel   [7] released_in_hi
# [8] ctx_valid        [9] ctx_kept        [30:10] release counter
_FL_ST_M = 3
_FL_PC_SH = 2
_FL_CZ_SH = 3
_FL_BO_SH = 5
_FL_DIA_SH = 6
_FL_RH_SH = 7
_FL_CV_SH = 8
_FL_CK_SH = 9
_FL_RC_SH = 10          # 21 bits: < 2**21 accepted releases per task
_FL_CZ_M = 3 << _FL_CZ_SH

# ---- pi: the packed (P, 24) int32 per-point block --------------------
# [0] mode  [1] running tid  [2] locked banks  [3] resident-LO count
# [4] active count  [5] active-HI count  [6] alive  [7] table overflow
# [8:24] int metric counters (_MI_* offsets are relative to _I_MI):
#   [jobs_lo, jobs_hi, done_lo, done_hi, miss_lo, miss_hi, mbm_lo,
#    mbm_tr, mbm_hi, lo_rel_hi, lo_done_hi, cs_count, pi_n, ci_n,
#    save_n, restore_n]
(_I_MODE, _I_RUN, _I_LOCKED, _I_RESLO, _I_ACT, _I_HI,
 _I_ALIVE, _I_OVF) = range(8)
_I_MI = 8
_MI_JOBS, _MI_DONE, _MI_MISS, _MI_MBM = 0, 2, 4, 6
_MI_LO_REL, _MI_LO_DONE, _MI_CS = 9, 10, 11
_MI_PI_N, _MI_CI_N, _MI_SAVE_N, _MI_RESTORE_N = 12, 13, 14, 15
_MI_W = 16
_PI_W = _I_MI + _MI_W

# ---- pf: the packed (P, 14) float64 per-point block ------------------
# [0] now  [1] accel_free_at  [2] run_started  [3] last_mode_stamp
# [4] tick_cs  [5:14] float metric accumulators (_MF_* offsets are
# relative to _F_MF): [exec_sum, overhead, pi_sum, ci_sum, save_sum,
# restore_sum, mode_cycles_lo/tr/hi]
_F_NOW, _F_FREE, _F_RSTART, _F_LMS, _F_TICKCS = range(5)
_F_MF = 5
_MF_EXEC, _MF_OVERHEAD, _MF_PI, _MF_CI = 0, 1, 2, 3
_MF_SAVE, _MF_RESTORE, _MF_MC = 4, 5, 6
_MF_W = 9
_PF_W = _F_MF + _MF_W


def require_jax(backend: str = "jit") -> None:
    """Fail fast with an actionable message when JAX is unavailable."""
    if jax is None:
        raise RuntimeError(
            f"select_backend={backend!r} needs JAX, which is not "
            "importable in this environment; install jax (CPU wheels: "
            "`pip install jax`) or use select_backend='numpy'")


def _table_width() -> int:
    return _env_int("REPRO_JIT_TABLE_WIDTH", _K0)


def _table_max(k0: int) -> int:
    return max(_env_int("REPRO_JIT_TABLE_MAX", _K_MAX), k0)


# ----------------------------------------------------------------------
# Compiled step (built once per static policy/profile class)
# ----------------------------------------------------------------------

def _build_run(use_banks: bool, drop_lo: bool, preempt: str,
               nominal: bool, prune: bool, scenario=None):
    """Compile the whole-simulation while_loop for one static config.

    Everything dynamic (per-batch tables, scalars, carry) is a traced
    argument; jax re-specializes per array shape, so batches sharing
    (n_points, n_tasks, K, table sizes) share one compilation.

    XLA:CPU pays a ~flat dispatch cost per emitted kernel inside a
    while_loop, so the body is shaped to minimize *kernel count*, not
    flops (see the module docstring's carry-layout notes):

      * per-point single-column reads are gathers (cheap), and the
        ``flags`` bitfield makes one gather serve every small per-task
        field of a column; every carried array receives exactly ONE
        fused write pass per step (XLA CPU scatters are pathologically
        slow, and one chain beats separate masked writes);
      * deferring all writes to the end of the step is sound because
        the four event classes are disjoint per point and handlers
        only touch their own point's row — the few same-row
        read-after-write hazards (advance -> dispatch, finish ->
        scheduler, overrun -> dispatch on the same column) are
        resolved by deriving the post-write values as (P,)-scalars
        instead of re-reading the array;
      * metric counters live in the packed ``pi``/``pf`` tails and are
        updated by one fused add-chain each;
      * the demand draw is a branch-free splitmix64 hash (a handful of
        fused u64 ops; ``jax.random``'s threefry costs ~50 kernels per
        step on CPU).
    """

    GOLD = np.uint64(0x9E3779B97F4A7C15)

    def _mix64(x):
        """splitmix64 finalizer — the counter-based RNG's mixer."""
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return x ^ (x >> np.uint64(31))

    def _u01(bits):
        """Top 53 bits -> uniform double in [0, 1)."""
        return (bits >> np.uint64(11)).astype(jnp.float64) \
            * (1.0 / (1 << 53))

    def _oh(col, width):
        return col[:, None] == jnp.arange(width)[None, :]

    def _get(arr, col):
        """arr[p, col[p]] (clamped columns; callers mask the result)."""
        return jnp.take_along_axis(arr, col[:, None], axis=1)[:, 0]

    def _chain(arr, *writes):
        """One fused masked-write pass: ``writes`` are (oh, mask, val)
        triples applied lowest-precedence-first (later entries win on
        overlap, matching the sequential write order they replace)."""
        out = arr
        for oh, mask, val in writes:
            val = jnp.asarray(val, arr.dtype)
            if val.ndim:
                val = val[:, None]
            out = jnp.where(oh & mask[:, None], val, out)
        return out

    def _apply_inc(M, incs):
        """One fused add-chain over a packed metric block; ``incs`` are
        (column, mask, value) with scalar or per-point columns."""
        cols = jnp.arange(M.shape[1])
        out = M
        for idx, mask, val in incs:
            idx = jnp.asarray(idx)
            if idx.ndim:
                ohm = (idx[:, None] == cols[None, :]) & mask[:, None]
            else:
                ohm = (cols == idx)[None, :] & mask[:, None]
            val = jnp.asarray(val, M.dtype)
            if val.ndim:
                val = val[:, None]
            out = out + jnp.where(ohm, val, jnp.zeros((), M.dtype))
        return out

    def _dma(nbytes):
        cy = DMA_SETUP_CYCLES + (nbytes + DMA_BYTES_PER_CYCLE - 1) \
            // DMA_BYTES_PER_CYCLE
        return jnp.where(nbytes <= 0, 0, cy)

    def _banks(nbytes):
        return (nbytes + _BB - 1) // _BB

    def _bit(fl, sh):
        return ((fl >> sh) & 1) != 0

    def _boundaries(tb, pids, off):
        """Vectorized Program.next_{instruction,operator}_boundary via
        one searchsorted over the globally keyed tables (identical
        float/int op order to the NumPy engine's ``_boundaries``)."""
        total = tb["prog_total"][pids]
        wrap = off >= total
        base = jnp.where(wrap,
                         jnp.floor_divide(off, total) * total, 0.0)
        off = off - base
        pk = pids.astype(jnp.float64) * float(_PID_KEY)
        # searchsorted as a broadcast compare+count: the tables are a
        # few hundred entries, and one dense pass beats the unrolled
        # binary search's serial gather chain on CPU
        if preempt == "instruction":
            off = jnp.minimum(jnp.maximum(off, 0.0), total - 1e-9)
            q = pk + off
            i = (tb["seg_key"][None, :] <= q[:, None]).sum(axis=1)
            seg_start = (tb["seg_key"][i] - pk) - tb["seg_cycles"][i]
            within = off - seg_start
            pat = tb["seg_pat"][i]
            rep = jnp.floor_divide(within, pat)
            rem = within - rep * pat
            cum = tb["pat_cumsum"][i]
            k = (cum <= rem[:, None]).sum(axis=1)
            acc = _get(cum, k)
            return jnp.trunc(base + seg_start + rep * pat + acc)
        q = pk + off
        i = (tb["op_key"][None, :] <= q[:, None]).sum(axis=1)
        i = jnp.minimum(i, tb["op_hi"][pids])
        return jnp.trunc(base + tb["op_end"][i])

    def _sample_demand(tb, sc, rcol, n, hi_r, c_lo_r):
        """Counter-based per-release demand draw: splitmix64 of
        (seed, task, release index) — identical distributions to the
        sequential-stream engines, but order-free so the compiled loop
        needs no host RNG state (see the module docstring)."""
        ctr = (rcol.astype(jnp.uint64) << np.uint64(33)) \
            + (n.astype(jnp.uint64) << np.uint64(1))
        s = tb["seed64"] + ctr * GOLD
        u0 = _u01(_mix64(s))
        u1 = _u01(_mix64(s + GOLD))
        over = hi_r & (u0 < sc["overrun_prob"])
        mag = jnp.where(over, 1.0 + (sc["cf"] - 1.0) * u1,
                        0.7 + 0.3 * u1)
        return c_lo_r * mag

    # ------------------------------------------------------------------
    def _step(tb, sc, c):
        """One lockstep iteration: pop each live point's next event and
        apply the handlers as masked updates — the jit counterpart of
        ``_VecBatch.run``'s loop body, one event class per point.  The
        scheduler aggregates (locked banks, resident-LO / active / HI
        counts) ride in the packed ``pi`` block and are updated
        incrementally at the NumPy engine's sites; every carried array
        is written once, at the end (see ``_build_run``)."""
        T = tb["valid"].shape[1]
        K = c["ev_time"].shape[1]
        next_tick = lambda t: (jnp.floor_divide(t, sc["t_sr"]) + 1) \
            * sc["t_sr"]
        mi_inc, mf_inc = [], []

        # ---- unpack the grouped carry (slices fuse into consumers) ---
        flags = c["flags"]
        status_a = flags & _FL_ST_M
        pi, pf = c["pi"], c["pf"]
        mode0 = pi[:, _I_MODE]
        run0 = pi[:, _I_RUN]
        locked0 = pi[:, _I_LOCKED]
        res_lo0 = pi[:, _I_RESLO]
        act0 = pi[:, _I_ACT]
        hic0 = pi[:, _I_HI]
        alive0 = pi[:, _I_ALIVE] != 0
        ovf0 = pi[:, _I_OVF] != 0
        now0 = pf[:, _F_NOW]
        free0 = pf[:, _F_FREE]
        rs0 = pf[:, _F_RSTART]
        lms0 = pf[:, _F_LMS]
        tcs0 = pf[:, _F_TICKCS]

        # ---- candidate argmin over the four event sources ------------
        # hierarchical: per-source row mins feed a tiny (P, 4) argmin
        # (a single concatenated (P, 2T+K+1) pop has fewer kernels but
        # measures slower on XLA:CPU — the concat defeats loop fusion)
        rel_min = c["next_release"].min(axis=1)
        tickR_min = c["tick_release"].min(axis=1)
        ev_min = c["ev_time"].min(axis=1)
        cand = jnp.stack([rel_min, tickR_min, ev_min, tcs0], axis=1)
        j = jnp.argmin(cand, axis=1)
        tmin = cand.min(axis=1)
        fire = alive0 & (tmin <= sc["duration"])
        now = jnp.where(fire, tmin, now0)
        is_rel = fire & (j == 0)
        is_tickR = fire & (j == 1)
        is_cs = fire & (j == 3)
        is_int = fire & (j == 2)

        # ---- release events (no scheduler pass of their own) ---------
        rcol = jnp.argmin(c["next_release"], axis=1)
        ohR = _oh(rcol, T)
        fl_r = _get(flags, rcol)
        st_r = fl_r & _FL_ST_M
        hi_r = _get(tb["is_hi"], rcol)
        crit_r = hi_r.astype(jnp.int32)
        # previous job still live: count one miss, skip this release
        fresh_miss = is_rel & (st_r != _PEND) \
            & (_get(c["job_deadline"], rcol) != jnp.inf)
        mi_inc.append((_MI_MISS + crit_r, fresh_miss, 1))
        mi_inc.append((_MI_MBM + mode0, fresh_miss, 1))
        accept = is_rel & (st_r == _PEND)
        if drop_lo:                   # AMC: LO not released off-LO
            accept = accept & (hi_r | (mode0 == _LO))
        act1 = act0 + accept
        hic1 = hic0 + (accept & hi_r)
        c_lo_r = _get(tb["c_lo"], rcol)
        n_r = fl_r >> _FL_RC_SH
        if nominal:                   # zero-jitter profile: no draws
            dem = c_lo_r
        else:
            dem = _sample_demand(tb, sc, rcol, n_r, hi_r, c_lo_r)
        if scenario is not None:
            # scenario CRN draws: keyed on the absolute release-event
            # counter ``sn`` (bumped for every release, accepted or
            # not — policy-independent), never on the accepted-release
            # counter n_r.  Same splitmix64 arithmetic as the host
            # engines (scenarios.crn), so nominal-profile runs stay
            # bit-exact vs the vec engine per scenario.  Carry writes
            # happen inline (sn/sw/sm are read only here, so deferring
            # them past the barrier buys nothing).
            sn_r = _get(c["sn"], rcol)
            if scenario.affects_demand:
                if scenario.has_burst:
                    wi = burst_window_index(scenario, jnp, now)
                    fresh_bm = burst_multiplier(scenario, jnp,
                                                tb["seed64"], wi)
                    # per-window draw cached in the carry: pure in
                    # (seed, window), so reuse is exact
                    bm = jnp.where(wi == c["sw"], c["sm"], fresh_bm)
                    c["sw"] = jnp.where(is_rel, wi, c["sw"])
                    c["sm"] = jnp.where(is_rel, bm, c["sm"])
                else:
                    bm = None
                # abs pins the (non-negative) product as a plain IEEE
                # multiply — LLVM would otherwise contract it with
                # downstream subtracts into an FMA and drift a ulp off
                # the host engines' demand values (see scenarios
                # ._nofuse)
                dem = jnp.abs(dem * demand_multiplier(
                    scenario, jnp, tb["seed64"],
                    rcol.astype(jnp.uint64),
                    sn_r.astype(jnp.uint64), now, burst_m=bm))
            c["sn"] = _chain(c["sn"], (ohR, is_rel, sn_r + 1))
        mi_inc.append((_MI_JOBS + crit_r, accept, 1))
        rel_hi = accept & ~hi_r & (mode0 != _LO)
        mi_inc.append((_MI_LO_REL, rel_hi, 1))

        # ---- scheduler-tick pops (defer while a CS is in flight) -----
        ohT = _oh(jnp.argmin(c["tick_release"], axis=1), T)
        tcs1 = jnp.where(is_cs, jnp.inf, tcs0)
        tick_mask = is_tickR | is_cs
        busy_t = tick_mask & (now < free0)
        tcs2 = jnp.where(busy_t,
                         jnp.minimum(tcs1, next_tick(free0)), tcs1)
        tick_sched = tick_mask & ~busy_t

        # ---- pending finish/overrun interrupts: pop + guard ----------
        icol = jnp.argmin(c["ev_time"], axis=1)
        ohI = _oh(icol, K)
        pay_i = _get(c["ev_pay"], icol)
        itid = pay_i >> 2
        ikind = pay_i & 3
        tidc = jnp.maximum(itid, 0)
        ohTid = _oh(tidc, T)
        fl_tid = _get(flags, tidc)
        guard = is_int & (run0 == itid) \
            & ((fl_tid & _FL_ST_M) == _RUN)

        # ---- one advance for every point that needs it this step -----
        # (the running column is shared by the advance, the interrupt
        # target and the dispatch drain, so the post-advance values are
        # carried forward as scalars instead of array re-reads)
        runc = jnp.maximum(run0, 0)
        ohRun = _oh(runc, T)
        elapsed = now - rs0
        do_adv = (guard | tick_sched) & (run0 >= 0) & (elapsed > 0)
        exec_r0 = _get(c["exec_cy"], runc)
        exec_r1 = jnp.where(do_adv, exec_r0 + elapsed, exec_r0)
        mf_inc.append((_MF_EXEC, do_adv, elapsed))
        rs1 = jnp.where(do_adv, now, rs0)
        # GemminiRT.note_execution (exact integer growth model)
        etab_r = _get(tb["etab"], runc).astype(jnp.int64) * _BB
        grow = jnp.floor(elapsed * DMA_BYTES_PER_CYCLE).astype(jnp.int64)
        if use_banks:
            have = _get(c["res_bytes"], runc).astype(jnp.int64)
            free = (_NBANKS - locked0).astype(jnp.int64)
            growing = do_adv & (have < etab_r) & (free > 0)
            want = jnp.minimum(jnp.minimum(etab_r, have + free * _BB),
                               have + grow)
            rb_grown = jnp.maximum(have, want)
            rb_1 = jnp.where(growing, rb_grown, have)
            locked1 = locked0 + jnp.where(
                growing, _banks(rb_grown) - _banks(have), 0).astype(
                    jnp.int32)
            went = growing & (have == 0) & (rb_grown > 0) \
                & ~_get(tb["is_hi"], runc)
            res_lo1 = res_lo0 + went
        else:
            have = _get(c["res_bytes"], runc).astype(jnp.int64)
            growing = do_adv & (have < etab_r)
            others = c["res_bytes"].sum(axis=1) - have
            want = jnp.minimum(
                jnp.minimum(etab_r, jnp.maximum(_CAP - others, 0)),
                have + grow)
            rb_1 = jnp.where(growing, jnp.maximum(have, want), have)
            locked1, res_lo1 = locked0, res_lo0
        acc_r0 = _get(c["acc_bytes"], runc).astype(jnp.int64)
        filling = do_adv & (acc_r0 < ACCUM_BYTES)
        grow_acc = jnp.floor_divide(
            elapsed * DMA_BYTES_PER_CYCLE, 4).astype(jnp.int64)
        acc_1 = jnp.where(filling,
                          jnp.minimum(ACCUM_BYTES, acc_r0 + grow_acc),
                          acc_r0)

        # ---- fire guard-passing finish/overrun events ----------------
        # (the interrupt target IS the running column for guard-passing
        # points, so exec_r1 / rb_1 are its post-advance values)
        done_m = guard & (ikind == 1) \
            & (exec_r1 >= _get(c["demand"], tidc) - 1e-6)
        hi_i = _get(tb["is_hi"], tidc)
        crit_i = hi_i.astype(jnp.int32)
        ddl_i = _get(c["job_deadline"], tidc)
        mi_inc.append((_MI_DONE + crit_i, done_m, 1))
        late = done_m & (now > ddl_i)
        mi_inc.append((_MI_MISS + crit_i, late, 1))
        mi_inc.append((_MI_MBM + mode0, late, 1))
        surv = done_m & _bit(fl_tid, _FL_RH_SH) & (now <= ddl_i)
        mi_inc.append((_MI_LO_DONE, surv, 1))
        act2 = act1 - done_m
        hic2 = hic1 - (done_m & hi_i)
        # GemminiRT.evict
        mf_inc.append((_MF_OVERHEAD, done_m, float(FLUSH_CYCLES)))
        if use_banks:
            locked2 = locked1 - jnp.where(
                done_m, _banks(rb_1), 0).astype(jnp.int32)
            res_lo2 = res_lo1 - (done_m & (rb_1 > 0) & ~hi_i)
        else:
            locked2, res_lo2 = locked1, res_lo1
        run1 = jnp.where(done_m, -1, run0)
        # overrun: flag the budget excess, degrade LO -> transition
        fire_o = guard & (ikind == 2) \
            & (exec_r1 >= _get(tb["c_lo"], tidc) - 1e-6) \
            & ~_bit(fl_tid, _FL_BO_SH)
        was_lo = fire_o & (mode0 == _LO)
        mf_inc.append((_MF_MC + mode0, was_lo, now - lms0))
        lms1 = jnp.where(was_lo, now, lms0)
        mode1 = jnp.where(was_lo, _TRANS, mode0)

        # ---- scheduler pass ------------------------------------------
        sched = tick_sched | done_m | fire_o
        # a stale event can land mid-switch: defer like a tick re-push
        busy_s = sched & (now < free0)
        tcs3 = jnp.where(busy_s,
                         jnp.minimum(tcs2, next_tick(free0)), tcs2)
        sched = sched & ~busy_s
        # mode progression (SS IV) off the carried aggregates
        mt = sched & (mode1 != _LO)
        to_hi = mt & (mode1 == _TRANS) & (res_lo2 <= 1)
        to_lo = mt & ~to_hi & (act2 == 0)
        mode2 = jnp.where(to_hi, _HI, jnp.where(to_lo, _LO, mode1))
        chg = mode2 != mode1
        mf_inc.append((_MF_MC + mode1, chg, now - lms1))
        lms2 = jnp.where(chg, now, lms1)
        # pick_next via masked min over the rank-compressed
        # (priority, column) keys; the finishing task left the active
        # set this step, which the deferred status write hasn't
        # recorded yet — mask its column out here
        active = (status_a != _PEND) & tb["valid"] \
            & ~(ohTid & done_m[:, None])
        act_key = jnp.where(active, tb["key32"], _EMPTY32).min(axis=1)
        hi_key = jnp.where(active & tb["is_hi"], tb["key32"],
                           _EMPTY32).min(axis=1)
        hi_active = hic2 > 0
        off_lo = mode2 != _LO
        if drop_lo:                   # AMC: LO never runs off-LO
            key = jnp.where(off_lo, hi_key, act_key)
        else:
            key = jnp.where(off_lo & hi_active, hi_key, act_key)
            # transition mode: a LO task may run only while its data
            # is still resident (rare — branch around the extra pass,
            # correcting for this step's deferred writes)
            need_tr = sched & off_lo & ~hi_active & (mode2 == _TRANS)

            def _tr_keys(_):
                resid = _bit(flags, _FL_DIA_SH)
                if use_banks:
                    resid = resid | (c["res_bytes"] > 0)
                resid = resid & ~(ohTid & done_m[:, None])
                if use_banks:
                    resid = resid | (ohRun
                                     & (growing & (rb_grown > 0))[:, None])
                ok = active & (tb["is_hi"] | resid)
                return jnp.where(ok, tb["key32"], _EMPTY32).min(axis=1)

            key_tr = jax.lax.cond(
                need_tr.any(), _tr_keys,
                lambda _: jnp.full_like(key, _EMPTY32), None)
            key = jnp.where(need_tr, key_tr, key)
        nxt = (key % (T + 1)).astype(jnp.int32)
        nxt = jnp.where(key >= _EMPTY32, -1, nxt)
        # clear a stale running slot (event engine's defensive check)
        curc = jnp.maximum(run1, 0)
        ohC = _oh(curc, T)
        fl_c = _get(flags, curc)
        stale = sched & (run1 >= 0) & ((fl_c & _FL_ST_M) != _RUN)
        run2 = jnp.where(stale, -1, run1)
        # ohC / curc stay valid: stale points get cur < 0, for which
        # every consumer below is masked out — and whenever a dispatch
        # drains a current task, curc equals runc (the point advanced
        # the same column this step), so rb_1 / acc_1 / exec_r1 are its
        # post-advance values
        cur = run2
        act_m = sched & (nxt >= 0) & (cur != nxt)
        # a displaced current task blocks the newcomer until the switch
        nxtc = jnp.maximum(nxt, 0)
        ohN = _oh(nxtc, T)
        fl_n = _get(flags, nxtc)
        hi_n = _get(tb["is_hi"], nxtc)
        hi_c = _get(tb["is_hi"], curc)
        blocked = act_m & (cur >= 0)
        bsince_0 = _get(c["blocked_since"], nxtc)
        fresh_b = blocked & jnp.isnan(bsince_0)
        bsince_1 = jnp.where(fresh_b, now, bsince_0)
        run_lo = (cur >= 0) & ~hi_c
        ci_shape = hi_n & run_lo
        cause_v = jnp.where(
            ci_shape, jnp.where(mode2 != _LO, _C_CI, _C_CIQ), _C_PI)
        cz_1 = jnp.where(fresh_b, cause_v, (fl_n >> _FL_CZ_SH) & 3)
        if preempt == "none":         # cannot displace the running task
            act_m = act_m & (cur < 0)

        # ---- dispatch (context switch, Alg. 1) -----------------------
        has_cur = act_m & (cur >= 0)
        # drain to the preemption boundary
        boundary = _boundaries(tb, _get(tb["prog_id"], curc), exec_r1)
        drain = jnp.maximum(
            0.0, jnp.minimum(boundary, _get(c["demand"], curc))
            - exec_r1)
        exec_r2 = jnp.where(has_cur, exec_r1 + drain, exec_r1)
        drain_i = jnp.trunc(drain).astype(jnp.int64)
        # context_save cost model (GemminiRT)
        acc_cy = _dma(acc_1)
        if use_banks:
            need = _get(tb["eta"], nxtc) + locked2 > _NBANKS
            spadsave = need & (rb_1 > 0)
            remap_cy = _REMAP_CY
            resident = rb_1
        else:
            resident = _get(c["res_bytes"], curc).astype(jnp.int64)
            resident = jnp.where(curc == runc, rb_1, resident)
            spadsave = resident > 0
            remap_cy = 0
        spad_cy = jnp.where(spadsave, _dma(resident), 0)
        br_save = drain_i + (_FF + _CFG_CY + remap_cy) + acc_cy + spad_cy
        kept = ~spadsave
        sv = has_cur & spadsave
        # HI-mode LO->LO preemption: full eviction of the old LO data
        lolo = has_cur & (mode2 == _HI) & ~hi_c & ~hi_n
        if use_banks:
            locked3 = locked2 - jnp.where(
                sv, _banks(resident), 0).astype(jnp.int32)
            res_lo3 = res_lo2 - (sv & ~hi_c)
            # the lolo eviction sees the residency left after the save
            rb_2 = jnp.where(sv, 0, rb_1)
            locked4 = locked3 - jnp.where(
                lolo, _banks(rb_2), 0).astype(jnp.int32)
            res_lo4 = res_lo3 - (lolo & (rb_2 > 0))
        else:
            locked4, res_lo4 = locked2, res_lo2
        mi_inc.append((_MI_CS, has_cur, 1))
        mf_inc.append((_MF_SAVE, has_cur, br_save.astype(jnp.float64)))
        mi_inc.append((_MI_SAVE_N, has_cur, 1))
        # context_restore for resumed tasks
        resume = act_m & (_bit(fl_n, _FL_PC_SH)
                          | ((fl_n & _FL_ST_M) == _INT))
        has_ctx = _bit(fl_n, _FL_CV_SH)
        ctx_acc_n = _get(c["ctx_acc"], nxtc).astype(jnp.int64)
        ctx_spad_n = _get(c["ctx_spad"], nxtc).astype(jnp.int64)
        acc_cy_r = jnp.where(has_ctx, _dma(ctx_acc_n), 0)
        reload = resume & has_ctx & ~_bit(fl_n, _FL_CK_SH) \
            & (ctx_spad_n > 0)
        spad_cy_r = jnp.where(reload, _dma(ctx_spad_n), 0)
        br_rest = jnp.where(has_ctx,
                            acc_cy_r + spad_cy_r + _RESTORE_FIXED, 0)
        if use_banks:
            br_rest = br_rest + jnp.where(reload, _REMAP_CY, 0)
            free_b = (_NBANKS - locked4).astype(jnp.int64)
            new_res = jnp.minimum(ctx_spad_n, free_b * _BB)
            locked5 = locked4 + jnp.where(
                reload, _banks(new_res), 0).astype(jnp.int32)
            res_lo5 = res_lo4 + (reload & (new_res > 0) & ~hi_n)
        else:
            new_res = ctx_spad_n
            locked5, res_lo5 = locked4, res_lo4
        mf_inc.append((_MF_RESTORE, resume, br_rest.astype(jnp.float64)))
        mi_inc.append((_MI_RESTORE_N, resume, 1))
        # commit the switch
        switch = jnp.where(has_cur, br_save, 0).astype(jnp.float64) \
            + jnp.where(resume, br_rest, 0).astype(jnp.float64)
        mf_inc.append((_MF_OVERHEAD, act_m, switch))
        run3 = jnp.where(act_m, nxt, run2)
        # _record_unblock(nxt, at=now + switch)
        at = now + switch
        was_b = act_m & ~jnp.isnan(bsince_1)
        dt = at - bsince_1
        cz = jnp.where((cz_1 == _C_CIQ) & (mode2 != _LO), _C_CI, cz_1)
        posd = was_b & (dt > 0)
        ci_m = posd & (cz == _C_CI)
        pi_m = posd & (cz != _C_CI)
        mf_inc.append((_MF_CI, ci_m, dt))
        mi_inc.append((_MI_CI_N, ci_m, 1))
        mf_inc.append((_MF_PI, pi_m, dt))
        mi_inc.append((_MI_PI_N, pi_m, 1))
        rs2 = jnp.where(act_m, at, rs1)
        free1 = jnp.where(act_m, at, free0)
        # future events for the new running task
        exec_n = _get(c["exec_cy"], nxtc)
        rem = _get(c["demand"], nxtc) - exec_n
        c_lo_n = _get(tb["c_lo"], nxtc)
        arm = act_m & hi_n & ~_bit(fl_n, _FL_BO_SH) & (exec_n < c_lo_n)
        t_fin = at + rem
        t_ovr = at + (c_lo_n - exec_n)
        ddl_new = now + _get(tb["deadline_rel"], rcol)
        nrel_new = now + _get(tb["period"], rcol)
        tr_new = next_tick(now)

        # ---- flag-write values (one RMW per write site; see the
        # conflict analysis in _build_run's docstring) ------------------
        # release: fresh job — set READY, clear pc/budget_overrun, set
        # released_in_hi, bump the release counter; keep cause/ctx bits
        keep_r = _FL_CZ_M | (1 << _FL_DIA_SH) | (1 << _FL_CV_SH) \
            | (1 << _FL_CK_SH)
        fl_release = (fl_r & keep_r) | _READY \
            | (rel_hi.astype(jnp.int32) << _FL_RH_SH) \
            | ((n_r + 1) << _FL_RC_SH)
        # finish: back to PENDING, data gone, context invalid
        fl_done = fl_tid & ~jnp.int32(_FL_ST_M | (1 << _FL_DIA_SH)
                                      | (1 << _FL_CV_SH))
        # overrun: set budget_overrun (kept for non-dispatching points;
        # folded into fl_cur below when the same column is displaced)
        fl_fireo = fl_tid | (1 << _FL_BO_SH)
        # displaced current task: INTERRUPTED + ctx snapshot bits.  An
        # overrun fired on this very column this step (fire_o implies
        # tidc == curc) — fold its budget_overrun bit in so the RMW
        # does not resurrect the pre-step value
        fl_c2 = fl_c | (fire_o.astype(jnp.int32) << _FL_BO_SH)
        fl_cur = (fl_c2 & ~jnp.int32(_FL_ST_M | (1 << _FL_DIA_SH)
                                     | (1 << _FL_CV_SH)
                                     | (1 << _FL_CK_SH))) \
            | _INT \
            | ((kept & ~lolo).astype(jnp.int32) << _FL_DIA_SH) \
            | (1 << _FL_CV_SH) \
            | (kept.astype(jnp.int32) << _FL_CK_SH)
        # dispatched task: RUNNING + pc, blocking cause resolved, data
        # present again when a context reload happened
        st_n = jnp.where(act_m, _RUN, fl_n & _FL_ST_M)
        pc_n = jnp.where(act_m, 1, (fl_n >> _FL_PC_SH) & 1)
        cz_n = jnp.where(was_b, _C_NONE,
                         jnp.where(fresh_b, cause_v,
                                   (fl_n >> _FL_CZ_SH) & 3))
        dia_n = jnp.where(resume & has_ctx, 1, (fl_n >> _FL_DIA_SH) & 1)
        keep_n = ~jnp.int32(_FL_ST_M | (1 << _FL_PC_SH) | _FL_CZ_M
                            | (1 << _FL_DIA_SH))
        fl_nxt = (fl_n & keep_n) | st_n | (pc_n << _FL_PC_SH) \
            | (cz_n << _FL_CZ_SH) | (dia_n << _FL_DIA_SH)

        # ---- barrier, then deferred writes: one fused pass per array -
        # XLA:CPU loop fusion re-evaluates a shared producer once per
        # fused consumer; the barrier materializes every (P,) scalar
        # and one-hot mask exactly once, so the write chains below are
        # each a cheap read-modify-select pass
        (ohR, ohT, ohI, ohTid, ohRun, ohC, ohN,
         is_rel, is_tickR, is_int, accept, fresh_miss, done_m, fire_o,
         act_m, has_cur, resume, has_ctx, reload, sv, lolo, was_b,
         fresh_b, do_adv, growing, filling, arm, dem, exec_r2,
         rb_1, acc_1, new_res, ctx_acc_n, resident, spadsave,
         t_fin, t_ovr, nxtc, now, ddl_new, nrel_new, tr_new,
         fl_release, fl_done, fl_fireo, fl_cur, fl_nxt,
         mode2, run3, locked5, res_lo5, act2, hic2, fire,
         free1, rs2, lms2, tcs3, mi_inc, mf_inc) = \
            jax.lax.optimization_barrier(
                (ohR, ohT, ohI, ohTid, ohRun, ohC, ohN,
                 is_rel, is_tickR, is_int, accept, fresh_miss, done_m,
                 fire_o, act_m, has_cur, resume, has_ctx, reload, sv,
                 lolo, was_b, fresh_b, do_adv, growing, filling, arm,
                 dem, exec_r2, rb_1, acc_1, new_res, ctx_acc_n,
                 resident, spadsave, t_fin, t_ovr, nxtc, now, ddl_new,
                 nrel_new, tr_new, fl_release, fl_done, fl_fireo,
                 fl_cur, fl_nxt, mode2, run3, locked5, res_lo5, act2,
                 hic2, fire, free1, rs2, lms2, tcs3, mi_inc, mf_inc))

        # per-task state (precedence follows the sequential order the
        # chains replace; distinct-column conflicts were ruled out in
        # the dispatch analysis above, and the one same-column overlap
        # — overrun + displacement — is folded into fl_cur)
        flags_new = _chain(flags, (ohR, accept, fl_release),
                           (ohTid, done_m, fl_done),
                           (ohTid, fire_o, fl_fireo),
                           (ohC, has_cur, fl_cur),
                           (ohN, act_m | fresh_b, fl_nxt))
        c["flags"] = flags_new
        c["exec_cy"] = _chain(c["exec_cy"], (ohR, accept, 0.0),
                              (ohRun, do_adv | has_cur, exec_r2))
        c["demand"] = _chain(c["demand"], (ohTid, done_m, jnp.inf),
                             (ohR, accept, dem))
        c["job_deadline"] = _chain(
            c["job_deadline"], (ohR, fresh_miss, jnp.inf),
            (ohR, accept, ddl_new))
        nrel_a = _chain(c["next_release"], (ohR, is_rel, nrel_new))
        c["next_release"] = nrel_a
        c["tick_release"] = _chain(c["tick_release"],
                                   (ohT, is_tickR, jnp.inf),
                                   (ohR, accept, tr_new))
        c["blocked_since"] = _chain(c["blocked_since"],
                                    (ohN, fresh_b, now),
                                    (ohN, was_b, jnp.nan))
        if use_banks:
            c["res_bytes"] = _chain(
                c["res_bytes"],
                (ohRun, growing | done_m | sv | lolo,
                 jnp.where(done_m | sv | lolo, 0, rb_1)),
                (ohN, reload, new_res))
        else:
            c["res_bytes"] = _chain(
                c["res_bytes"],
                (ohRun, growing | done_m | sv,
                 jnp.where(done_m | sv, 0, rb_1)),
                (ohN, reload, new_res))
        c["acc_bytes"] = _chain(
            c["acc_bytes"],
            (ohRun, filling | done_m | has_cur,
             jnp.where(done_m | has_cur, 0, acc_1)),
            (ohN, resume & has_ctx, ctx_acc_n))
        c["ctx_acc"] = _chain(c["ctx_acc"], (ohC, has_cur, acc_1))
        c["ctx_spad"] = _chain(
            c["ctx_spad"],
            (ohC, has_cur, jnp.where(spadsave, resident, 0)))

        # ---- pending-interrupt table: pop + prune + push -------------
        # stale-interrupt pruning (proof in the module docstring): an
        # entry whose task ends this step with no live job and whose
        # fire time precedes that task's next release can never pass
        # the firing guard again — drop it and free the slot now
        popped = ohI & is_int[:, None]
        if prune:
            tid_k = jnp.maximum(c["ev_pay"] >> 2, 0)
            st_k = jnp.take_along_axis(flags_new & _FL_ST_M, tid_k,
                                       axis=1)
            nrel_k = jnp.take_along_axis(nrel_a, tid_k, axis=1)
            dead = jnp.isfinite(c["ev_time"]) & (st_k == _PEND) \
                & (c["ev_time"] < nrel_k)
            clear = popped | dead
        else:
            clear = popped
        # this step's freed slots (pop + pruned) are immediately
        # reusable by the pushes, like the event engine's heap
        isfree = jnp.isinf(c["ev_time"]) | clear
        n_free = isfree.sum(axis=1)
        oh1 = _oh(jnp.argmax(isfree, axis=1), K)
        oh2 = _oh(jnp.argmax(isfree & ~oh1, axis=1), K)
        do1 = act_m & (n_free >= 1)
        do2 = arm & (n_free >= 2)
        ovf1 = ovf0 | (act_m & (n_free < 1)) | (arm & (n_free < 2))
        ev_t = jnp.where(clear, jnp.inf, c["ev_time"])
        c["ev_time"] = _chain(ev_t, (oh1, do1, t_fin),
                              (oh2, do2, t_ovr))
        c["ev_pay"] = _chain(c["ev_pay"], (oh1, do1, nxtc * 4 + 1),
                             (oh2, do2, nxtc * 4 + 2))

        # ---- packed per-point blocks: one fused write each -----------
        # column-onehot where-chain + add-chain over the whole block:
        # everything fuses into ONE kernel per block (a stack +
        # concatenate assembly of the same values measures ~2.7x
        # slower per step — XLA:CPU materializes concat operands as
        # separate thunks inside the loop)
        cols_i = jnp.arange(_PI_W)
        new_pi = pi
        for col, val in ((_I_MODE, mode2), (_I_RUN, run3),
                         (_I_LOCKED, locked5), (_I_RESLO, res_lo5),
                         (_I_ACT, act2), (_I_HI, hic2),
                         (_I_ALIVE, fire), (_I_OVF, ovf1)):
            new_pi = jnp.where((cols_i == col)[None, :],
                               jnp.asarray(val, jnp.int32)[:, None],
                               new_pi)
        c["pi"] = _apply_inc(new_pi,
                             [(_I_MI + i, m, v) for i, m, v in mi_inc])
        cols_f = jnp.arange(_PF_W)
        new_pf = pf
        for col, val in ((_F_NOW, now), (_F_FREE, free1),
                         (_F_RSTART, rs2), (_F_LMS, lms2),
                         (_F_TICKCS, tcs3)):
            new_pf = jnp.where((cols_f == col)[None, :],
                               val[:, None], new_pf)
        c["pf"] = _apply_inc(new_pf,
                             [(_F_MF + i, m, v) for i, m, v in mf_inc])
        c["steps"] = c["steps"] + 1
        return c

    def _run(tb, sc, carry):
        def cond(c):
            # overflowing points keep stepping (their results are
            # discarded and selectively re-run at a wider table); the
            # healthy majority of the batch must run to completion
            return (c["pi"][:, _I_ALIVE] != 0).any() \
                & (c["steps"] < sc["max_steps"])

        return jax.lax.while_loop(cond, functools.partial(_step, tb, sc),
                                  carry)

    return _run


# tb/sc/carry dict layouts, fixed by _tables/_run_once/_carry0: the
# shard_map partition specs below are derived from these key lists, so
# they live next to the functions that define the dicts
_TB_PER_POINT = frozenset({
    "seed64", "valid", "key32", "period", "deadline_rel", "c_lo",
    "is_hi", "eta", "etab", "prog_id"})
_TB_KEYS = tuple(sorted(_TB_PER_POINT) + [
    "prog_total", "seg_key", "seg_cycles", "seg_pat", "pat_cumsum",
    "op_key", "op_end", "op_hi"])
_SC_KEYS = ("t_sr", "overrun_prob", "cf", "duration", "max_steps")
_CARRY_KEYS = (
    "flags", "exec_cy", "demand", "job_deadline", "blocked_since",
    "next_release", "tick_release", "res_bytes", "acc_bytes",
    "ctx_acc", "ctx_spad", "ev_time", "ev_pay", "sn", "sw", "sm",
    "pi", "pf", "steps")


@functools.lru_cache(maxsize=None)
def _compiled_run(use_banks: bool, drop_lo: bool, preempt: str,
                  nominal: bool, prune: bool, scenario=None,
                  devices: int = 1):
    """One jitted runner per static (policy/profile, device count)
    class — the memo is what makes 'one compilation per shape/config'
    true: jax.jit caches specializations per *function object*, so
    handing back a fresh closure per call would retrace and recompile
    every chunk.

    ``devices > 1`` wraps the runner in ``shard_map`` over a 1-D
    logical-device mesh: per-point tables and the whole carry shard
    along the point axis, the global program tables and scalars
    replicate, and — because simulation points are independent — the
    mapped body needs no collectives (``check_rep=False``: there is no
    replicated output for shard_map to prove anything about).  Each
    device's while_loop halts when its own point-shard quiesces, so a
    fast shard does not wait for a slow one's extra steps.  The carry
    (the dominant allocation) is donated in both variants.
    """
    run = _build_run(use_banks, drop_lo, preempt, nominal, prune,
                     scenario)
    if devices == 1:
        return jax.jit(run, donate_argnums=(2,))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.runtime.sharding import logical_device_mesh

    mesh = logical_device_mesh(devices)
    tb_specs = {k: P("dev") if k in _TB_PER_POINT else P()
                for k in _TB_KEYS}
    sc_specs = {k: P() for k in _SC_KEYS}
    carry_specs = {k: P("dev") for k in _CARRY_KEYS}

    def _dev_body(tb, sc, c):
        # each device runs the scalar-step runner on its point-shard;
        # the (devices,) step counter contributes one lane per device
        c = dict(c)
        c["steps"] = c["steps"][0]
        out = run(tb, sc, c)
        out["steps"] = out["steps"][None]
        return out

    return jax.jit(
        shard_map(_dev_body, mesh=mesh,
                  in_specs=(tb_specs, sc_specs, carry_specs),
                  out_specs=carry_specs, check_rep=False),
        donate_argnums=(2,))


# ----------------------------------------------------------------------
# Host driver: state build, overflow retry, tail accounting, assembly
# ----------------------------------------------------------------------

def _rank_keys(b: _VecBatch) -> np.ndarray:
    """Rank-compress the NumPy engine's (priority, column) int64 keys
    into int32: pick_next only compares keys *within* a point, so a
    per-point dense rank of the priorities preserves the selection
    (ties still break on the lowest column) at a quarter of the
    memory traffic."""
    pr = np.minimum(b.prio, 2 ** 40)
    key = np.empty((b.P, b.T), np.int32)
    cols = np.arange(b.T, dtype=np.int32)
    for p in range(b.P):
        distinct = np.unique(pr[p])
        key[p] = np.searchsorted(distinct, pr[p]).astype(np.int32) \
            * (b.T + 1) + cols
    return key


def _tables(b: _VecBatch, seeds: Sequence[int]) -> Dict[str, "jnp.ndarray"]:
    return {
        "seed64": jnp.asarray(
            np.asarray(seeds, dtype=np.int64).astype(np.uint64)),
        "valid": jnp.asarray(b.valid),
        "key32": jnp.asarray(_rank_keys(b)),
        "period": jnp.asarray(b.period),
        "deadline_rel": jnp.asarray(b.deadline_rel),
        "c_lo": jnp.asarray(b.c_lo),
        "is_hi": jnp.asarray(b.is_hi),
        "eta": jnp.asarray(b.eta.astype(np.int32)),
        "etab": jnp.asarray(b.etab.astype(np.int32)),
        "prog_id": jnp.asarray(b.prog_id.astype(np.int32)),
        "prog_total": jnp.asarray(b._prog_total.astype(np.float64)),
        "seg_key": jnp.asarray(b._g_seg_key),
        "seg_cycles": jnp.asarray(b._g_seg_cycles),
        "seg_pat": jnp.asarray(b._g_seg_pat),
        "pat_cumsum": jnp.asarray(b._g_pat_cumsum),
        "op_key": jnp.asarray(b._g_op_key),
        "op_end": jnp.asarray(b._g_op_end),
        "op_hi": jnp.asarray(b._g_op_hi),
    }


def _carry0(b: _VecBatch, seeds: Sequence[int], K: int,
            devices: int = 1) -> Dict[str, "jnp.ndarray"]:
    """Initial carry: the freshly-initialized NumPy batch state (which
    already drew the release phases from each point's host RNG) as the
    grouped tensors of the module docstring, plus empty packed metric
    blocks and an interrupt table of width ``K``.  The step counter is
    scalar on one device and one lane per device when sharded."""
    P, T = b.P, b.T
    pi0 = np.zeros((P, _PI_W), np.int32)
    pi0[:, _I_RUN] = -1
    pi0[:, _I_ALIVE] = 1
    pf0 = np.zeros((P, _PF_W))
    pf0[:, _F_TICKCS] = np.inf
    return {
        "flags": jnp.zeros((P, T), jnp.int32),
        "exec_cy": jnp.zeros((P, T)),
        "demand": jnp.full((P, T), jnp.inf),
        "job_deadline": jnp.zeros((P, T)),
        "blocked_since": jnp.full((P, T), jnp.nan),
        "next_release": jnp.asarray(b.next_release),
        "tick_release": jnp.full((P, T), jnp.inf),
        "res_bytes": jnp.zeros((P, T), jnp.int32),
        "acc_bytes": jnp.zeros((P, T), jnp.int32),
        "ctx_acc": jnp.zeros((P, T), jnp.int32),
        "ctx_spad": jnp.zeros((P, T), jnp.int32),
        "ev_time": jnp.full((P, K), jnp.inf),
        "ev_pay": jnp.full((P, K), -1, jnp.int32),
        # scenario state: absolute release-event counter + the cached
        # per-window burst draw (window index, multiplier).  Carried
        # unconditionally so the carry pytree is scenario-independent;
        # with scenario=None they are loop-invariant pass-throughs.
        "sn": jnp.zeros((P, T), jnp.int32),
        "sw": jnp.full((P,), -1, jnp.int32),
        "sm": jnp.ones((P,)),
        "pi": jnp.asarray(pi0),
        "pf": jnp.asarray(pf0),
        "steps": jnp.zeros((), jnp.int64) if devices == 1
        else jnp.zeros((devices,), jnp.int64),
    }


def _max_steps(b: _VecBatch, duration: float) -> int:
    """Loose per-point event-count bound — a diverging while_loop is an
    engine bug and must surface as an error, not a hang."""
    with np.errstate(divide="ignore"):
        rel = np.where(b.valid, duration / b.period + 2, 0.0).sum(axis=1)
    return int(64 * (rel.max() + 16) + 65536)


def _run_once(b: _VecBatch, policy: Policy, seeds: Sequence[int],
              duration: float, overrun_prob: float, cf: float,
              nominal: bool, K: int,
              devices: int = 1, scenario=None) -> Dict[str, np.ndarray]:
    """One compiled run of a prepared batch at interrupt-table width
    ``K``, sharded over ``devices`` logical devices; returns the final
    carry as NumPy arrays."""
    if b.P % max(devices, 1):
        raise ValueError(
            f"sharded run needs the point count ({b.P}) divisible by "
            f"the device count ({devices}); the span planner pads to "
            "a devices x chunk rectangle")
    run = _compiled_run(policy.use_banks, policy.drop_lo_in_hi,
                        policy.preemption, nominal, _PRUNE_STALE,
                        scenario, devices)
    from jax.experimental import enable_x64
    max_steps = _max_steps(b, duration)
    # event times are float64; everything (array upload included) must
    # happen under x64 or XLA would round-trip them through float32
    with enable_x64():
        tb = _tables(b, seeds)
        sc = {"t_sr": jnp.float64(policy.t_sr),
              "overrun_prob": jnp.float64(overrun_prob),
              "cf": jnp.float64(cf),
              "duration": jnp.float64(duration),
              "max_steps": jnp.int64(max_steps)}
        final = run(tb, sc, _carry0(b, seeds, K, devices=devices))
        final = {k: np.asarray(v) for k, v in final.items()}
    # unpack the layout-dependent bits here so _run_chunk (and its
    # tests) stay independent of the packed-block column order
    final["overflow"] = final["pi"][:, _I_OVF] != 0
    if int(np.max(final["steps"])) >= max_steps \
            and final["pi"][:, _I_ALIVE].any():
        raise RuntimeError(
            f"jit engine: lockstep loop hit the {max_steps}-step "
            "safety bound with live points remaining")
    return final


def _run_chunk(tasksets, programs, policy, seeds, duration, overrun_prob,
               cf, demand_profile: str,
               point_ids: Optional[Sequence[int]] = None,
               devices: int = 1, scenario=None) -> List[RunMetrics]:
    """Simulate one (super)chunk with the per-point overflow-retry
    ladder.

    The chunk first runs at the narrow primary interrupt table (ample
    for typical points, rarer still with stale-interrupt pruning),
    sharded over ``devices`` logical devices when asked.  Points whose
    table overflowed — a per-point, batch-composition-independent
    event — are re-run in small padded single-device sub-batches at
    doubled widths until they fit; the counter-based RNG makes every
    retry bit-deterministic, so a point's result never depends on
    which batch, table width, or device count executed it.  A point
    that still overflows at the maximum width raises a loud,
    point-identified error: metrics computed from a saturated table
    would silently drop interrupts.
    """
    nominal = demand_profile == "nominal"
    scenario = get_scenario(scenario)
    # only demand-affecting components reach the compiled loop (phase
    # shift is applied host-side at batch init, instance loss is
    # serving-only): a scenario with every in-loop component off shares
    # the scenario-free graph — disabled scenarios stay compiled-out
    loop_scen = scenario if scenario is not None \
        and scenario.affects_demand else None
    out: List[Optional[RunMetrics]] = [None] * len(tasksets)
    idx = list(range(len(tasksets)))
    K = _table_width()
    k_max = _table_max(K)
    first = True
    while idx:
        ts = [tasksets[i] for i in idx]
        sd = [int(seeds[i]) for i in idx]
        # pad retry sub-batches up to the bucket size so the ladder
        # reuses one compilation per (bucket, K) instead of one per
        # subset shape (padded copies are simulated and discarded)
        if not first and len(ts) < _RETRY_BUCKET:
            pad = _RETRY_BUCKET - len(ts)
            ts = ts + [ts[-1]] * pad
            sd = sd + [sd[-1]] * pad
        b = _VecBatch(ts, programs, policy, seeds=sd, duration=duration,
                      overrun_prob=overrun_prob, cf=cf,
                      scenario=scenario)
        final = _run_once(b, policy, sd, duration, overrun_prob, cf,
                          nominal, K, devices=devices if first else 1,
                          scenario=loop_scen)
        metrics = _assemble(b, final, duration)
        overflow = final["overflow"]
        redo = []
        for pos, i in enumerate(idx):
            if overflow[pos]:
                redo.append(i)
            else:
                out[i] = metrics[pos]
        idx = redo
        K *= 2
        first = False
        if idx and K > k_max:
            pts = ", ".join(
                f"(taskset {point_ids[i] if point_ids is not None else i}"
                f", seed {int(seeds[i])})" for i in idx)
            raise RuntimeError(
                f"jit engine: pending-interrupt table for {len(idx)} "
                f"point(s) still overflowed at the maximum width "
                f"{k_max} — refusing to return metrics from a "
                f"saturated table.  Affected (taskset index, seed): "
                f"[{pts}].  Raise REPRO_JIT_TABLE_MAX (or unset "
                f"REPRO_JIT_TABLE_WIDTH) to widen the retry ladder.")
    return out  # type: ignore[return-value]


def _assemble(b: _VecBatch, s: Dict[str, np.ndarray],
              duration: float) -> List[RunMetrics]:
    """Tail accounting (the event engine's post-loop pass) + RunMetrics
    assembly from the final grouped carry."""
    P = b.P
    out: List[RunMetrics] = []
    status = s["flags"] & _FL_ST_M
    live = (status != _PEND) & b.valid \
        & (duration > s["job_deadline"])
    mi = s["pi"][:, _I_MI:]
    mf = s["pf"][:, _F_MF:]
    mode = s["pi"][:, _I_MODE]
    lms = s["pf"][:, _F_LMS]
    for p in range(P):
        mode_cycles = mf[p, _MF_MC:_MF_MC + 3].copy()
        mode_cycles[mode[p]] += duration - lms[p]
        misses = mi[p, _MI_MISS:_MI_MISS + 2].astype(np.int64).copy()
        for t in live[p].nonzero()[0]:
            misses[int(b.is_hi[p, t])] += 1
        out.append(RunMetrics(
            pi_blocking=AggSamples(mf[p, _MF_PI], mi[p, _MI_PI_N]),
            ci_blocking=AggSamples(mf[p, _MF_CI], mi[p, _MI_CI_N]),
            save_cycles=AggSamples(mf[p, _MF_SAVE], mi[p, _MI_SAVE_N]),
            restore_cycles=AggSamples(mf[p, _MF_RESTORE],
                                      mi[p, _MI_RESTORE_N]),
            jobs={"LO": int(mi[p, _MI_JOBS]),
                  "HI": int(mi[p, _MI_JOBS + 1])},
            done={"LO": int(mi[p, _MI_DONE]),
                  "HI": int(mi[p, _MI_DONE + 1])},
            misses={"LO": int(misses[0]), "HI": int(misses[1])},
            misses_by_mode={k: int(mi[p, _MI_MBM + i])
                            for i, k in enumerate(_MODE_KEYS)},
            lo_released_in_hi=int(mi[p, _MI_LO_REL]),
            lo_done_in_hi=int(mi[p, _MI_LO_DONE]),
            mode_cycles={k: float(mode_cycles[i])
                         for i, k in enumerate(_MODE_KEYS)},
            cs_count=int(mi[p, _MI_CS]),
            exec_cycles=float(mf[p, _MF_EXEC]),
            overhead_cycles=float(mf[p, _MF_OVERHEAD])))
    return out


# ----------------------------------------------------------------------
# Public entry point (called by simulator_vec.simulate_vbatch)
# ----------------------------------------------------------------------

def while_body_kernels(compiled_text: str) -> int:
    """Number of XLA kernels (fusion instructions) in the while-loop
    *body* of one optimized HLO module, excluding free instructions
    (tuple plumbing, constants) — i.e. the number of thunks XLA:CPU
    dispatches per lockstep step.

    The body is identified as the largest non-fused computation in the
    module (the step dominates cond/entry by far).  This walker is the
    single implementation behind :func:`lockstep_kernel_count` and the
    ``tools/graphlint`` budget manifests; keep them on one code path so
    the committed kernel budgets and the perf log never disagree about
    what "a kernel" is."""
    best: List[str] = []
    for m in re.finditer(r"(?m)^(\S[^{\n]*) \{$(.*?)^\}",
                         compiled_text, re.S):
        name, body = m.group(1).strip(), m.group(2)
        if "fused_computation" in name:
            continue
        ops = re.findall(r"(?m)=\s+\S+\s+([\w-]+)\(", body)
        if len(ops) > len(best):
            best = ops
    free = ("get-tuple-element", "constant", "tuple", "parameter",
            "bitcast")
    return sum(1 for op in best if op not in free)


def lockstep_kernel_count(tasksets: Sequence[List[TaskParams]],
                          programs: Dict[str, Program], policy: Policy,
                          *, seeds: Sequence[int], duration: float = 2e7,
                          overrun_prob: float = 0.3, cf: float = 2.0,
                          demand_profile: str = "sampled",
                          table_width: Optional[int] = None,
                          scenario=None) -> int:
    """:func:`while_body_kernels` of the compiled lockstep computation
    for this batch shape/config.

    The grouped-carry refactor's whole point is cutting this number —
    XLA:CPU pays a per-kernel dispatch cost inside ``while_loop``
    bodies.  The *pinned* per-engine budgets live in
    ``tools/graphlint/budgets.json`` (rule ``ir-budget-drift``), which
    is also where ``benchmarks/perf_sim.py`` sources the
    ``xla_kernels`` numbers it logs into ``BENCH_sim.json``; this
    function remains the thin measurement primitive behind both."""
    require_jax()
    nominal = demand_profile == "nominal"
    scenario = get_scenario(scenario)
    loop_scen = scenario if scenario is not None \
        and scenario.affects_demand else None   # as simulate_jbatch
    K = _table_width() if table_width is None else table_width
    b = _VecBatch(tasksets, programs, policy,
                  seeds=[int(s) for s in seeds], duration=duration,
                  overrun_prob=overrun_prob, cf=cf, scenario=scenario)
    run = _compiled_run(policy.use_banks, policy.drop_lo_in_hi,
                        policy.preemption, nominal, _PRUNE_STALE,
                        loop_scen)
    from jax.experimental import enable_x64
    max_steps = _max_steps(b, duration)
    with enable_x64():
        tb = _tables(b, seeds)
        sc = {"t_sr": jnp.float64(policy.t_sr),
              "overrun_prob": jnp.float64(overrun_prob),
              "cf": jnp.float64(cf),
              "duration": jnp.float64(duration),
              "max_steps": jnp.int64(max_steps)}
        txt = run.lower(tb, sc, _carry0(b, seeds, K)).compile().as_text()
    return while_body_kernels(txt)


def _plan_spans(n: int, chunk: int,
                devices: int) -> List[Tuple[List[int], int, int]]:
    """Split ``n`` points into ``(indices, real, devices)`` spans.

    A span is one dispatch: a ``d * c`` rectangle (``c`` points per
    logical device) padded — by duplicating its last point — so
    shard_map sees equal shards; padded copies are simulated and
    discarded by the caller.  The first (possibly only) span of a
    small batch shrinks ``d`` and ``c`` to the batch instead of
    simulating a mostly-padding superchunk; later ragged tails pad up
    to the full common shape so they reuse the superchunk's
    compilation — the same rule the single-device engine applied to
    its ragged tail (and ``devices=1`` reproduces the old plan
    exactly).
    """
    spans: List[Tuple[List[int], int, int]] = []
    lo = 0
    while lo < n:
        real = min(chunk * devices, n - lo)
        if lo == 0:
            d = min(devices, real)
            c = min(chunk, -(-real // d))
        else:
            d, c = devices, chunk
        idxs = list(range(lo, lo + real))
        idxs += [idxs[-1]] * (d * c - real)
        spans.append((idxs, real, d))
        lo += real
    return spans


def simulate_jbatch(tasksets: Sequence[List[TaskParams]],
                    programs: Dict[str, Program], policy: Policy, *,
                    seeds: Sequence[int], duration: float = 2e7,
                    overrun_prob: float = 0.3, cf: float = 2.0,
                    batch_size: int = 256,
                    demand_profile: str = "sampled",
                    devices: Optional[int] = None,
                    scenario=None) -> List[RunMetrics]:
    """Fully-compiled batch simulation: one ``lax.while_loop`` per
    superchunk of points, no host work inside the loop, the point axis
    sharded over ``devices`` logical devices (``None``: the
    ``REPRO_DEVICES`` default; see ``runtime.device_config``).

    Per-point keyed RNG draws make the result bit-identical at every
    device count — sharding is purely a throughput knob.

    Prefer :func:`repro.core.simulator_vec.simulate_vbatch` with
    ``select_backend="jit"`` — it validates arguments and routes here.
    See the module docstring for the RNG-equivalence contract.
    """
    require_jax()
    n = len(tasksets)
    if n != len(seeds):
        raise ValueError(f"{n} tasksets vs {len(seeds)} seeds")
    devices = resolve_device_count(devices)
    # small per-device chunks keep the lockstep state cache-resident
    # (64 measured fastest on the BENCH corpus — docs/performance.md)
    chunk = max(1, min(batch_size, _STREAM_CHUNK))
    out: List[RunMetrics] = []
    for idxs, real, d in _plan_spans(n, chunk, devices):
        part = _run_chunk([tasksets[i] for i in idxs], programs, policy,
                          [int(seeds[i]) for i in idxs], duration,
                          overrun_prob, cf, demand_profile,
                          point_ids=idxs, devices=d, scenario=scenario)
        out.extend(part[:real])
    return out
