"""MESC task scheduler: Alg. 1 (Context_switch / save / restore) and the
mode-switch rules of SS IV.

  * LO-mode:   highest priority ready task runs (HI and LO alike); bank
               allocation keeps every task at its minimal eta.
  * Transition: HI-tasks first; LO-tasks may run only if their computation
               data is still resident (not yet saved back), until at most
               one LO-task has data in the accelerator -> HI-mode.
  * HI-mode:   HI-tasks first; LO-tasks run only when no HI-task is active
               (imprecise-MCS stance: LO is never dropped).  A LO-task
               preempting another LO-task forces full eviction of the
               previous LO data (<=1 resident LO-task invariant).
  * Idle system -> revert to LO-mode.

Preemption granularity is a policy knob: 'instruction' (Gemmini^RT),
'operator' (limited preemption), 'none' (conventional NPU).  AMC baseline:
``drop_lo_in_hi`` cancels LO jobs in HI-mode (paper Fig. 8 comparison).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional

from repro.core.task import Crit, Status, TCB


class Mode(enum.Enum):
    LO = "LO"
    TRANS = "transition"
    HI = "HI"


@dataclasses.dataclass(frozen=True)
class Policy:
    preemption: str = "instruction"      # instruction | operator | none
    use_banks: bool = True               # address remapper / bank model
    drop_lo_in_hi: bool = False          # AMC
    t_sr: int = 5000                     # scheduler period (cycles)
    name: str = "mesc"

    @staticmethod
    def mesc(**kw) -> "Policy":
        return Policy(name="mesc", **kw)

    @staticmethod
    def non_preemptive() -> "Policy":
        return Policy(preemption="none", name="np")

    @staticmethod
    def limited() -> "Policy":
        return Policy(preemption="operator", name="lp")

    @staticmethod
    def amc(preemption: str = "instruction") -> "Policy":
        return Policy(preemption=preemption, drop_lo_in_hi=True,
                      name=f"amc-{preemption}")


ACTIVE = (Status.READY, Status.INTERRUPTED, Status.RUNNING)


def eligible_set(tcbs: Dict[int, TCB], mode: Mode, resident: List[int],
                 policy: Policy) -> List[TCB]:
    """Tasks schedulable under the current mode rules (SS IV)."""
    active = [t for t in tcbs.values() if t.status in ACTIVE]
    hi_active = any(t.params.crit == Crit.HI for t in active)
    out = []
    for t in active:
        if t.params.crit == Crit.HI or mode == Mode.LO:
            out.append(t)
            continue
        if policy.drop_lo_in_hi:          # AMC: LO dropped outside LO-mode
            continue
        if hi_active:                     # LO only when no HI-task is active
            continue
        if mode == Mode.TRANS and not (t.data_in_accel
                                       or t.tid in resident):
            continue                      # only not-yet-saved LO may run
        out.append(t)
    return out


def pick_next(tcbs: Dict[int, TCB], mode: Mode, resident: List[int],
              policy: Policy) -> Optional[TCB]:
    """Kernel.Scheduler.Find_next_task with MESC mode rules.

    Single fused pass over the TCBs (the simulator calls this once per
    scheduling event); equivalent to
    ``min(eligible_set(...), key=priority)`` with first-wins ties.
    """
    # ACTIVE == every status but PENDING, so one identity check suffices
    active = [t for t in tcbs.values() if t.status is not Status.PENDING]
    mode_lo = mode is Mode.LO
    hi_active = False
    if not mode_lo:
        for t in active:
            if t.params.crit is Crit.HI:
                hi_active = True
                break
    drop_lo = policy.drop_lo_in_hi
    trans = mode is Mode.TRANS
    best: Optional[TCB] = None
    best_prio = None
    for t in active:
        if t.params.crit is not Crit.HI and not mode_lo:
            if drop_lo or hi_active:
                continue
            if trans and not (t.data_in_accel or t.tid in resident):
                continue
        prio = t.params.priority
        if best is None or prio < best_prio:
            best = t
            best_prio = prio
    return best


def update_mode(mode: Mode, tcbs: Dict[int, TCB], resident_lo: List[int],
                any_active: bool) -> Mode:
    """Transition/HI/LO mode progression (SS IV 'Mode switch')."""
    if mode == Mode.TRANS and len(resident_lo) <= 1:
        return Mode.HI
    if mode != Mode.LO and not any_active:
        return Mode.LO            # system idle -> revert
    return mode


# ----------------------------------------------------------------------
# Multi-accelerator coordination (platform layer, see docs/scheduling.md)
# ----------------------------------------------------------------------

MODE_SEVERITY = {Mode.LO: 0, Mode.TRANS: 1, Mode.HI: 2}


class ModeCoordinator:
    """Per-instance mode machines + the platform-wide aggregate.

    Partitioned MESC runs one SS IV mode machine *per accelerator
    instance*: an overrun on instance ``i`` degrades only ``i``'s mode
    (its LO-tasks yield, its resident-LO countdown runs), while other
    instances keep serving their partitions in LO-mode.  The
    coordinator tracks every instance's mode and exposes the platform
    mode — the most severe per-instance mode — which gates global
    decisions: LO-task migration targets must be in LO-mode, and
    platform-level telemetry (mode residency, degraded-instance count)
    reads from here.
    """

    def __init__(self, n_instances: int):
        self.modes: List[Mode] = [Mode.LO] * n_instances

    def set_mode(self, inst: int, mode: Mode) -> None:
        self.modes[inst] = mode

    def mode_of(self, inst: int) -> Mode:
        return self.modes[inst]

    def update_instance(self, inst: int, tcbs: Dict[int, TCB],
                        resident_lo: List[int], any_active: bool) -> Mode:
        """Run one instance's SS IV progression and record the result."""
        self.modes[inst] = update_mode(self.modes[inst], tcbs,
                                       resident_lo, any_active)
        return self.modes[inst]

    def platform_mode(self) -> Mode:
        """Most severe mode across instances (LO < transition < HI)."""
        return max(self.modes, key=MODE_SEVERITY.__getitem__)

    def instances_in(self, mode: Mode) -> List[int]:
        return [i for i, m in enumerate(self.modes) if m == mode]

    def degraded(self) -> List[int]:
        """Instances that have left LO-mode."""
        return [i for i, m in enumerate(self.modes) if m != Mode.LO]
