"""Vectorized struct-of-arrays batch simulation backend.

``simulate_vbatch`` advances **hundreds of independent (taskset, seed)
points per vectorized step** instead of running one Python event loop
per point.  Every piece of per-point simulator state lives in a NumPy
array indexed ``[point]`` or ``[point, task]`` (remaining demand,
release phases, mode, resident bytes, save/restore context, ...), and
each lockstep iteration pops *each live point's next event* with one
``argmin`` over a candidate-time matrix, then applies the event
handlers as masked array updates.

Exactness contract
------------------
The engine is a *semantics-preserving* reimplementation of
:class:`repro.core.simulator.MCSSimulator`, not an approximation:

  * the event-queue is replaced by derived candidate times (per-task
    next release, pending scheduler ticks) plus a small per-point table
    of pending finish/overrun interrupts.  The table is a *multiset*,
    not one slot per task: the event engine's stale heap entries —
    finish/overrun events left behind by preemptions — are not pure
    no-ops, because their guarded handler still calls
    ``_advance_running`` when the event's task happens to be running
    again, checkpointing execution (and the integer-floored residency
    growth of ``note_execution``) at that timestamp.  The vectorized
    engine replays exactly those firings;
  * every float operation (demand sampling, drain/boundary arithmetic,
    blocking intervals, mode residency stamps) is performed in the same
    order with the same IEEE-754 double ops, and every cycle-cost
    quantity is the same integer arithmetic as ``GemminiRT``;
  * each point owns its own ``np.random.default_rng(seed)`` and draws
    are consumed in the same order (phases at init, demand per accepted
    release), so the two engines see identical randomness.

Result: per-run metrics (success/miss/blocking/survivability/overhead
aggregates) match the event-driven engine bit-for-bit on every point —
pinned by ``tests/test_simulator_vec.py``.  The only *permitted*
deviation class is sub-tick event interleaving at exactly-equal event
timestamps (probability ~0 under the continuous phase/demand draws;
grid-tick collisions are idempotent scheduler passes in both engines).

``VEC_SIM_SEMANTICS_VERSION`` salts campaign cache keys for points
executed by this backend (``repro.experiments.spec``), so vec results
never collide with — or invalidate — event-engine cache entries.

``select_backend="jit"`` routes the whole batch to the fully-compiled
``jax.lax.while_loop`` backend (``core.simulator_jit``): every lockstep
iteration — candidate argmin, masked handlers, scheduler pass — runs
on-device with no per-step host round-trip.  That backend trades the
NumPy path's bit-exactness for *statistical* equivalence (counter-based
RNG; exact on the zero-jitter ``demand_profile="nominal"``) and carries
its own cache salt; see docs/performance.md.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.isa import (ACCUM_BYTES, BANK_BYTES, CONFIG_CYCLES,
                            DMA_BYTES_PER_CYCLE, DMA_SETUP_CYCLES,
                            FLUSH_CYCLES, FREEZE_CYCLES, REMAP_BLOCK_BYTES,
                            SCRATCHPAD_BANKS)
from repro.core.program import Program
from repro.core.scheduler import Policy
from repro.core.simulator import DEMAND_PROFILES, RunMetrics
from repro.core.task import Crit, TaskParams
from repro.scenarios import demand_multiplier, get_scenario, shifted_phases

# Cache-key salt for campaign points executed by the vectorized backend.
# BUMP whenever a change to this module alters any simulated result.
# Event-engine points are salted by SIM_SEMANTICS_VERSION instead, so
# the two engines never share (or invalidate) cache entries.
VEC_SIM_SEMANTICS_VERSION = 1

# Cache-key salt for campaign points executed by the jit backend
# (core.simulator_jit re-exports it).  BUMP whenever a change to that
# module alters any simulated result.  Defined here — not in
# simulator_jit — so the experiments/spec layer can hash points
# without importing JAX (~1.5s per worker process).
# v2 = grouped-carry engine + stale-interrupt pruning (results are
# provably unchanged — the pruned entries are no-op pops — but the
# engine internals were rebuilt wholesale, so the cache namespace
# rolls over defensively rather than trusting the proof with stale
# campaign rows).
# v3 = scenario layer: new sn/sw/sm carry tensors.  scenario=None
# results are unchanged, but the carry pytree (and hence the compiled
# graph) changed shape, so the namespace rolls over.
JIT_SIM_SEMANTICS_VERSION = 3

# status codes (mirror task.Status)
_PEND, _READY, _RUN, _INT = 0, 1, 2, 3
# mode codes (column order of the mode-indexed metric arrays)
_LO, _TRANS, _HI = 0, 1, 2
_MODE_KEYS = ("LO", "transition", "HI")
# blocking causes
_C_NONE, _C_PI, _C_CIQ, _C_CI = 0, 1, 2, 3

_CRIT_KEYS = ("LO", "HI")
_PID_KEY = 2 ** 40          # per-program key offset for the global tables
_EMPTY = 2 ** 62            # "no eligible task" sentinel for min-keys
_BB = BANK_BYTES
_NBANKS = SCRATCHPAD_BANKS
_CAP = _BB * _NBANKS
_FF = FREEZE_CYCLES + FLUSH_CYCLES
_CFG_CY = DMA_SETUP_CYCLES + 4 * CONFIG_CYCLES
_REMAP_CY = DMA_SETUP_CYCLES + \
    -(-REMAP_BLOCK_BYTES // DMA_BYTES_PER_CYCLE)          # = _dma(4096)
_RESTORE_FIXED = _CFG_CY + 4 * CONFIG_CYCLES + 2 * 2      # config+reconfig+resend


def _dma_vec(nbytes: np.ndarray) -> np.ndarray:
    """Vectorized executor._dma_cycles (exact integer arithmetic;
    callers pass int64 arrays)."""
    cy = DMA_SETUP_CYCLES + (nbytes + DMA_BYTES_PER_CYCLE - 1) \
        // DMA_BYTES_PER_CYCLE
    return np.where(nbytes <= 0, 0, cy)


# ----------------------------------------------------------------------
# Program table: per-program constant arrays for the boundary queries
# ----------------------------------------------------------------------

class _VecProgram:
    """Per-program constant tables (segment ends/cycles, pattern
    cumsums, operator ends, eta banks) consumed by
    ``_VecBatch._build_boundary_tables`` for the vectorized
    next_{instruction,operator}_boundary queries."""

    def __init__(self, prog: Program):
        self.total = prog._total
        self.seg_ends = prog._seg_ends                     # int64, cumsum
        self.seg_cycles = np.asarray(prog._seg_cycles, dtype=np.int64)
        self.seg_pat = np.asarray(prog._seg_pattern_cycles, dtype=np.int64)
        self.op_ends = prog._operator_ends
        maxlen = max(len(s.pattern_costs) for s in prog.segments)
        pc = np.full((len(prog.segments), maxlen), np.iinfo(np.int64).max,
                     dtype=np.int64)
        for i, s in enumerate(prog.segments):
            pc[i, :len(s.pattern_costs)] = np.cumsum(s.pattern_costs)
        self.pat_cumsum = pc
        # executor.note_execution's eta-bank count for this program
        self.eta_banks = max(
            1, -(-min(prog.working_set_bytes, _CAP) // _BB))


# valid simulate_vbatch backends ("jax" is a deprecated alias of "jit";
# the old per-step jax candidate-select path it named was deleted — it
# paid a host<->device hop per lockstep iteration for no gain)
BACKENDS = ("numpy", "jit", "jax")
# DEMAND_PROFILES is canonically defined in core.simulator (the event
# engine validates it too) and re-exported here for callers.


# ----------------------------------------------------------------------
# The batch engine
# ----------------------------------------------------------------------

class _VecBatch:
    """SoA state + lockstep event loop for one batch of points that
    share (policy, duration, overrun_prob, cf)."""

    def __init__(self, tasksets: Sequence[List[TaskParams]],
                 programs: Dict[str, Program], policy: Policy, *,
                 seeds: Sequence[int], duration: float,
                 overrun_prob: float, cf: float,
                 demand_profile: str = "sampled", scenario=None):
        P = len(tasksets)
        T = max(len(ts) for ts in tasksets)
        self.P, self.T = P, T
        self.policy = policy
        self.duration = float(duration)
        self.overrun_prob = overrun_prob
        self.cf = cf
        self.t_sr = policy.t_sr
        self.use_banks = policy.use_banks
        self.drop_lo = policy.drop_lo_in_hi
        self.preempt = policy.preemption           # instruction|operator|none
        self.demand_profile = demand_profile
        self.scen = get_scenario(scenario)

        # ---- program table ------------------------------------------------
        prog_ids: Dict[int, int] = {}
        self.vprogs: List[_VecProgram] = []

        def pid_of(prog: Program) -> int:
            k = id(prog)
            if k not in prog_ids:
                prog_ids[k] = len(self.vprogs)
                self.vprogs.append(_VecProgram(prog))
            return prog_ids[k]

        # ---- static per-task arrays --------------------------------------
        self.valid = np.zeros((P, T), bool)
        self.prio = np.full((P, T), np.iinfo(np.int64).max, np.int64)
        self.period = np.full((P, T), np.inf)
        self.deadline_rel = np.full((P, T), np.inf)
        self.c_lo = np.full((P, T), np.inf)
        self.is_hi = np.zeros((P, T), bool)
        self.eta = np.zeros((P, T), np.int64)
        self.prog_id = np.zeros((P, T), np.int32)
        self.etab = np.ones((P, T), np.int64)      # note_execution eta banks
        for p, ts in enumerate(tasksets):
            for t, tp in enumerate(ts):
                prog = programs[tp.workload]
                self.valid[p, t] = True
                self.prio[p, t] = tp.priority
                self.period[p, t] = tp.period
                self.deadline_rel[p, t] = tp.deadline
                self.c_lo[p, t] = tp.c_lo
                self.is_hi[p, t] = tp.crit == Crit.HI
                self.eta[p, t] = tp.eta
                self.prog_id[p, t] = pid_of(prog)
                self.etab[p, t] = self.vprogs[self.prog_id[p, t]].eta_banks
        self._build_boundary_tables()

        # ---- dynamic per-task state --------------------------------------
        z = lambda dt: np.zeros((P, T), dt)
        self.status = z(np.int8)
        self.exec_cy = z(np.float64)
        self.demand = np.full((P, T), np.inf)
        self.job_release = z(np.float64)
        self.job_deadline = z(np.float64)
        self.budget_overrun = z(bool)
        self.data_in_accel = z(bool)
        self.pc = z(np.int8)
        self.blocked_since = np.full((P, T), np.nan)
        self.cause = z(np.int8)
        self.released_in_hi = z(bool)
        # scenario state: absolute release-event counter per (point,
        # task) — bumped on *every* release event (accepted, busy-
        # missed or AMC-dropped), so scenario CRN draws keyed on it are
        # identical across policies.  Unused (all-zero) with scen=None.
        self.scen_n = z(np.int64)
        # accelerator state
        self.r_bytes = z(np.int64)       # remapper residency (use_banks)
        self.spad = z(np.int64)          # explicit-addressing residency
        self.acc_bytes = z(np.int64)
        self.ctx_valid = z(bool)
        self.ctx_acc = z(np.int64)
        self.ctx_spad = z(np.int64)
        self.ctx_kept = z(bool)

        # ---- per-point state ---------------------------------------------
        self.now = np.zeros(P)
        self.mode = np.zeros(P, np.int8)
        self.running = np.full(P, -1, np.int32)
        self.accel_free_at = np.zeros(P)
        self.run_started = np.zeros(P)
        self.last_mode_stamp = np.zeros(P)
        self.tick_cs = np.full(P, np.inf)
        self.alive = np.ones(P, bool)
        self.next_release = np.full((P, T), np.inf)
        self.tick_release = np.full((P, T), np.inf)
        self.orig = np.arange(P)         # original point index (compaction)
        # pending finish/overrun interrupts: a per-point multiset (the
        # event engine's heap entries, stale ones included — see the
        # module docstring).  Grown on demand by _push_events.
        self.K = 8
        self.ev_time = np.full((P, self.K), np.inf)
        self.ev_tid = np.full((P, self.K), -1, np.int32)
        self.ev_kind = np.zeros((P, self.K), np.int8)   # 1=finish 2=overrun
        # hierarchical candidate minima: per-point row-min caches keep
        # the lockstep argmin at (P, 4) instead of (P, 2T+K+1)
        self.rel_min = np.full(P, np.inf)
        self.tickR_min = np.full(P, np.inf)
        self.ev_min = np.full(P, np.inf)

        # ---- metrics ------------------------------------------------------
        self.jobs = np.zeros((P, 2), np.int64)       # [:,0]=LO [:,1]=HI
        self.done = np.zeros((P, 2), np.int64)
        self.misses = np.zeros((P, 2), np.int64)
        self.misses_by_mode = np.zeros((P, 3), np.int64)
        self.mode_cycles = np.zeros((P, 3))
        self.lo_rel_hi = np.zeros(P, np.int64)
        self.lo_done_hi = np.zeros(P, np.int64)
        self.cs_count = np.zeros(P, np.int64)
        self.exec_sum = np.zeros(P)
        self.overhead = np.zeros(P)
        # event logs: (orig point idx array, value array) per metric list
        self.log_save: List = []
        self.log_restore: List = []
        self.log_pi: List = []
        self.log_ci: List = []

        # ---- rng + release phases (same draw order as the event engine) --
        self.rngs = [np.random.default_rng(int(s)) for s in seeds]
        self.rands = [r.random for r in self.rngs]
        self.seed64 = np.asarray(seeds, np.int64).astype(np.uint64)
        scen = self.scen
        shift = scen is not None and scen.has_phase_shift
        for p, ts in enumerate(tasksets):
            rng = self.rngs[p]
            for t, tp in enumerate(ts):
                ph = rng.uniform(0, tp.period)
                if shift:
                    # same scalar path as the event engine's sampler
                    ph = float(shifted_phases(scen, self.seed64[p],
                                              np.uint64(t), ph, tp.period))
                self.next_release[p, t] = ph
        self.rel_min = self.next_release.min(axis=1)
        # incremental total-locked-banks per point (sum of ceil(r/bb));
        # every r_bytes mutation below keeps it in sync
        self.locked = np.zeros(P, np.int64)
        self._ar = np.arange(P)
        # incremental pick_next aggregates.  The active set changes only
        # at releases and finishes, so each point carries the min
        # (priority, column) key over its active tasks — and over its
        # active HI tasks — plus active/HI counts and the count of LO
        # tasks with resident banks (mode progression).  prio_key
        # lexicographically encodes (priority, column) so ties break on
        # the lowest column, matching the event engine's dict order.
        self.keypad = T + 1
        self.prio_key = np.minimum(self.prio, 2 ** 40) * self.keypad \
            + np.arange(T)
        self.act_cnt = np.zeros(P, np.int32)
        self.hi_cnt = np.zeros(P, np.int32)
        self.act_key = np.full(P, _EMPTY, np.int64)
        self.hi_key = np.full(P, _EMPTY, np.int64)
        self.res_lo_cnt = np.zeros(P, np.int32)

    # ------------------------------------------------------------------
    _PT_ARRAYS = ("valid prio period deadline_rel c_lo is_hi eta prog_id "
                  "etab status exec_cy demand job_release job_deadline "
                  "budget_overrun data_in_accel pc blocked_since cause "
                  "released_in_hi r_bytes spad acc_bytes ctx_valid ctx_acc "
                  "ctx_spad ctx_kept next_release tick_release "
                  "ev_time ev_tid ev_kind prio_key scen_n").split()
    _P_ARRAYS = ("now mode running accel_free_at run_started "
                 "last_mode_stamp tick_cs alive orig seed64 "
                 "rel_min tickR_min ev_min locked "
                 "act_cnt hi_cnt act_key hi_key res_lo_cnt "
                 "jobs done misses misses_by_mode mode_cycles lo_rel_hi "
                 "lo_done_hi cs_count exec_sum overhead").split()

    def _compact(self):
        """Drop finished points from the lockstep arrays."""
        keep = self.alive
        for name in self._PT_ARRAYS + self._P_ARRAYS:
            setattr(self, name, getattr(self, name)[keep])
        self.rngs = [r for r, k in zip(self.rngs, keep) if k]
        self.rands = [r.random for r in self.rngs]
        self.P = int(keep.sum())
        self._ar = np.arange(self.P)

    # -- pending interrupt table ----------------------------------------
    def _push_events(self, ip: np.ndarray, tids: np.ndarray,
                     kind: int, times: np.ndarray):
        """Insert one pending finish/overrun event per point in ``ip``
        (the event engine's heappush), widening the table when full."""
        while True:
            isfree = np.isinf(self.ev_time[ip])
            if isfree.any(axis=1).all():
                break
            k = self.K
            self.ev_time = np.hstack(
                [self.ev_time, np.full((self.P, k), np.inf)])
            self.ev_tid = np.hstack(
                [self.ev_tid, np.full((self.P, k), -1, np.int32)])
            self.ev_kind = np.hstack(
                [self.ev_kind, np.zeros((self.P, k), np.int8)])
            self.K = 2 * k
            isfree = np.isinf(self.ev_time[ip])
        col = np.argmax(isfree, axis=1)
        self.ev_time[ip, col] = times
        self.ev_tid[ip, col] = tids
        self.ev_kind[ip, col] = kind
        self.ev_min[ip] = np.minimum(self.ev_min[ip], times)

    # -- helpers --------------------------------------------------------
    def _next_tick(self, t: np.ndarray) -> np.ndarray:
        return (np.floor_divide(t, self.t_sr) + 1) * self.t_sr

    def _set_mode(self, idx: np.ndarray, new_mode: np.ndarray):
        """Masked _set_mode: stamp residency of the outgoing mode."""
        old = self.mode[idx]
        chg = new_mode != old
        if not chg.any():
            return
        ic, oc, nc = idx[chg], old[chg], new_mode[chg]
        self.mode_cycles[ic, oc] += self.now[ic] - self.last_mode_stamp[ic]
        self.last_mode_stamp[ic] = self.now[ic]
        self.mode[ic] = nc

    # -- advance_running + note_execution -------------------------------
    def _advance(self, idx: np.ndarray):
        run = self.running[idx]
        sel = (run >= 0).nonzero()[0]
        if not len(sel):
            return
        ip, it = idx[sel], run[sel]
        elapsed = self.now[ip] - self.run_started[ip]
        pos = (elapsed > 0).nonzero()[0]
        if not len(pos):
            return
        ip, it, elapsed = ip[pos], it[pos], elapsed[pos]
        self.exec_cy[ip, it] += elapsed
        self.exec_sum[ip] += elapsed
        self.run_started[ip] = self.now[ip]
        # GemminiRT.note_execution (exact integer growth model).  Fast
        # paths: growth is a no-op once the task holds its eta banks or
        # the scratchpad has no free bank left, and once the accumulator
        # is full — the steady state for nearly every advance.
        etab = self.etab[ip, it] * _BB
        if self.use_banks:
            have = self.r_bytes[ip, it]
            free = _NBANKS - self.locked[ip]
            growing = ((have < etab) & (free > 0)).nonzero()[0]
            if len(growing):
                gp, gt = ip[growing], it[growing]
                grow = np.floor(elapsed[growing]
                                * DMA_BYTES_PER_CYCLE).astype(np.int64)
                hg = have[growing]
                avail = hg + free[growing] * _BB
                want = np.minimum(np.minimum(etab[growing], avail),
                                  hg + grow)
                new = np.maximum(hg, want)
                self.r_bytes[gp, gt] = new
                self.locked[gp] += (new + _BB - 1) // _BB \
                    - (hg + _BB - 1) // _BB
                went = ((hg == 0) & (new > 0)
                        & ~self.is_hi[gp, gt]).nonzero()[0]
                if len(went):
                    self.res_lo_cnt[gp[went]] += 1
        else:
            have = self.spad[ip, it]
            growing = have < etab
            if growing.any():
                gp, gt = ip[growing], it[growing]
                grow = np.floor(elapsed[growing]
                                * DMA_BYTES_PER_CYCLE).astype(np.int64)
                hg = have[growing]
                others = self.spad[gp].sum(axis=1) - hg
                want = np.minimum(
                    np.minimum(etab[growing], np.maximum(_CAP - others, 0)),
                    hg + grow)
                self.spad[gp, gt] = np.maximum(hg, want)
        acc = self.acc_bytes[ip, it]
        filling = (acc < ACCUM_BYTES).nonzero()[0]
        if len(filling):
            fp, ft = ip[filling], it[filling]
            grow_acc = np.floor_divide(
                elapsed[filling] * DMA_BYTES_PER_CYCLE, 4).astype(np.int64)
            self.acc_bytes[fp, ft] = np.minimum(
                ACCUM_BYTES, acc[filling] + grow_acc)

    # -- mode progression (SS IV) ---------------------------------------
    def _mode_tick(self, idx: np.ndarray, m: np.ndarray):
        nl = (m != _LO).nonzero()[0]
        if not len(nl):
            return
        ip = idx[nl]
        cur = self.mode[ip]
        new = cur.copy()
        to_hi = (cur == _TRANS) & (self.res_lo_cnt[ip] <= 1)
        new[to_hi] = _HI
        to_lo = ~to_hi & (self.act_cnt[ip] == 0)
        new[to_lo] = _LO
        self._set_mode(ip, new)

    # -- blocking bookkeeping -------------------------------------------
    def _mark_blocked(self, ip: np.ndarray, it: np.ndarray):
        fresh = (np.isnan(self.blocked_since[ip, it])).nonzero()[0]
        if not len(fresh):
            return
        ip, it = ip[fresh], it[fresh]
        self.blocked_since[ip, it] = self.now[ip]
        run = self.running[ip]
        has_run = run >= 0
        run_lo = np.zeros(len(ip), bool)
        run_lo[has_run] = ~self.is_hi[ip[has_run], run[has_run]]
        ci_shape = self.is_hi[ip, it] & has_run & run_lo
        cause = np.where(ci_shape,
                         np.where(self.mode[ip] != _LO, _C_CI, _C_CIQ),
                         _C_PI).astype(np.int8)
        self.cause[ip, it] = cause

    def _record_unblock(self, ip: np.ndarray, it: np.ndarray,
                        at: np.ndarray):
        was = (~np.isnan(self.blocked_since[ip, it])).nonzero()[0]
        if not len(was):
            return
        ip, it, at = ip[was], it[was], at[was]
        dt = at - self.blocked_since[ip, it]
        cause = self.cause[ip, it]
        cause = np.where((cause == _C_CIQ) & (self.mode[ip] != _LO),
                         _C_CI, cause)
        pos = dt > 0
        ci = (pos & (cause == _C_CI)).nonzero()[0]
        pi = (pos & (cause != _C_CI)).nonzero()[0]
        if len(ci):
            self.log_ci.append((self.orig[ip[ci]], dt[ci]))
        if len(pi):
            self.log_pi.append((self.orig[ip[pi]], dt[pi]))
        self.blocked_since[ip, it] = np.nan
        self.cause[ip, it] = _C_NONE

    # -- context switch (Alg. 1) ----------------------------------------
    def _build_boundary_tables(self):
        """Concatenate every program's segment/operator tables into one
        globally sorted keyed array (key = pid * 2**40 + cycle), so one
        ``searchsorted`` answers the preemption-boundary query for a
        mixed-program batch without a per-program loop.  All keyed
        values stay below 2**53, so float64 keys are exact."""
        KEY = float(_PID_KEY)
        seg_ends, seg_cycles, seg_pat, cums = [], [], [], []
        op_ends = []
        self._prog_total = np.array([vp.total for vp in self.vprogs],
                                    dtype=np.int64)
        maxlen = max(vp.pat_cumsum.shape[1] for vp in self.vprogs)
        self._g_op_lastkey = np.empty(len(self.vprogs))
        for pid, vp in enumerate(self.vprogs):
            seg_ends.append(vp.seg_ends + pid * KEY)
            seg_cycles.append(vp.seg_cycles)
            seg_pat.append(vp.seg_pat)
            pc = vp.pat_cumsum
            if pc.shape[1] < maxlen:
                pad = np.full((pc.shape[0], maxlen - pc.shape[1]),
                              np.iinfo(np.int64).max, np.int64)
                pc = np.hstack([pc, pad])
            cums.append(pc)
            op_ends.append(vp.op_ends + pid * KEY)
            self._g_op_lastkey[pid] = len(vp.op_ends)
        self._g_seg_key = np.concatenate(seg_ends).astype(float)
        self._g_seg_cycles = np.concatenate(seg_cycles)
        self._g_seg_pat = np.concatenate(seg_pat)
        self._g_pat_cumsum = np.vstack(cums)
        self._g_op_key = np.concatenate(op_ends).astype(float)
        self._g_op_end = np.concatenate(
            [vp.op_ends for vp in self.vprogs]).astype(np.int64)
        self._g_op_hi = np.cumsum(self._g_op_lastkey).astype(np.int64) - 1

    def _boundaries(self, ip: np.ndarray, it: np.ndarray) -> np.ndarray:
        """Preemption boundary per (point, running task), for the whole
        mixed-program batch in one vectorized pass."""
        pids = self.prog_id[ip, it].astype(np.int64)
        off = self.exec_cy[ip, it]
        total = self._prog_total[pids]
        base = np.zeros_like(off)
        wrap = off >= total
        if wrap.any():
            base[wrap] = np.floor_divide(off[wrap], total[wrap]) \
                * total[wrap]
            off = off - base
        pk = pids * float(_PID_KEY)
        if self.preempt == "instruction":
            off = np.minimum(np.maximum(off, 0.0), total - 1e-9)
            i = np.searchsorted(self._g_seg_key, pk + off, side="right")
            seg_start = (self._g_seg_key[i] - pk) - self._g_seg_cycles[i]
            within = off - seg_start
            pat = self._g_seg_pat[i]
            rep = np.floor_divide(within, pat)
            rem = within - rep * pat
            cum = self._g_pat_cumsum[i]
            k = (cum <= rem[:, None]).sum(axis=1)
            acc = cum[np.arange(len(off)), k]
            return np.trunc(base + seg_start + rep * pat + acc)
        i = np.searchsorted(self._g_op_key, pk + off, side="right")
        i = np.minimum(i, self._g_op_hi[pids])
        return np.trunc(base + self._g_op_end[i])

    def _dispatch(self, ip: np.ndarray, nxt: np.ndarray):
        n = len(ip)
        cur = self.running[ip]
        has_cur = (cur >= 0).nonzero()[0]
        switch = np.zeros(n)

        if len(has_cur):
            hp, hc = ip[has_cur], cur[has_cur]
            hn = nxt[has_cur]
            # drain to the preemption boundary
            boundary = self._boundaries(hp, hc)
            drain = np.maximum(
                0.0, np.minimum(boundary, self.demand[hp, hc])
                - self.exec_cy[hp, hc])
            self.exec_cy[hp, hc] += drain
            drain_i = np.trunc(drain).astype(np.int64)
            # context_save cost model (GemminiRT)
            acc = self.acc_bytes[hp, hc]
            acc_cy = _dma_vec(acc)
            if self.use_banks:
                resident = self.r_bytes[hp, hc]
                need = self.eta[hp, hn] + self.locked[hp] > _NBANKS
                spadsave = need & (resident > 0)
                remap_cy = _REMAP_CY
            else:
                resident = self.spad[hp, hc]
                spadsave = resident > 0
                remap_cy = 0
            spad_cy = np.where(spadsave, _dma_vec(resident), 0)
            br = drain_i + (_FF + _CFG_CY + remap_cy) + acc_cy + spad_cy
            # DRAM context + residency updates
            self.ctx_valid[hp, hc] = True
            self.ctx_acc[hp, hc] = acc
            self.ctx_spad[hp, hc] = np.where(spadsave, resident, 0)
            kept = ~spadsave
            self.ctx_kept[hp, hc] = kept
            sv_ = (spadsave).nonzero()[0]
            if len(sv_):
                if self.use_banks:
                    self.r_bytes[hp[sv_], hc[sv_]] = 0
                    self.locked[hp[sv_]] -= \
                        (resident[sv_] + _BB - 1) // _BB
                    lo_sel = (~self.is_hi[hp[sv_], hc[sv_]]).nonzero()[0]
                    if len(lo_sel):
                        self.res_lo_cnt[hp[sv_][lo_sel]] -= 1
                else:
                    self.spad[hp[sv_], hc[sv_]] = 0
            self.acc_bytes[hp, hc] = 0
            self.data_in_accel[hp, hc] = kept
            # HI-mode LO->LO preemption: full eviction of the old LO data
            lolo = ((self.mode[hp] == _HI)
                                  & ~self.is_hi[hp, hc]
                                  & ~self.is_hi[hp, hn]).nonzero()[0]
            if len(lolo):
                rb = self.r_bytes[hp[lolo], hc[lolo]]
                self.locked[hp[lolo]] -= (rb + _BB - 1) // _BB
                had = (rb > 0).nonzero()[0]
                if len(had):       # the preempted task is LO by definition
                    self.res_lo_cnt[hp[lolo][had]] -= 1
                self.r_bytes[hp[lolo], hc[lolo]] = 0
                self.data_in_accel[hp[lolo], hc[lolo]] = False
            self.status[hp, hc] = _INT
            self.cs_count[hp] += 1
            self.log_save.append((self.orig[hp], br))
            switch[has_cur] += br

        # context_restore for resumed tasks
        resume = ((self.pc[ip, nxt] > 0)
                                | (self.status[ip, nxt] == _INT)).nonzero()[0]
        if len(resume):
            rp, rt = ip[resume], nxt[resume]
            has_ctx = self.ctx_valid[rp, rt]
            acc_cy = np.where(has_ctx, _dma_vec(self.ctx_acc[rp, rt]), 0)
            reload = has_ctx & ~self.ctx_kept[rp, rt] \
                & (self.ctx_spad[rp, rt] > 0)
            spad_cy = np.where(reload, _dma_vec(self.ctx_spad[rp, rt]), 0)
            br = np.where(has_ctx, acc_cy + spad_cy + _RESTORE_FIXED, 0)
            rl = (reload).nonzero()[0]
            if len(rl):
                lp, lt = rp[rl], rt[rl]
                if self.use_banks:
                    br[rl] += _REMAP_CY
                    free = _NBANKS - self.locked[lp]
                    new = np.minimum(self.ctx_spad[lp, lt], free * _BB)
                    self.r_bytes[lp, lt] = new
                    self.locked[lp] += (new + _BB - 1) // _BB
                    came = ((new > 0)
                            & ~self.is_hi[lp, lt]).nonzero()[0]
                    if len(came):
                        self.res_lo_cnt[lp[came]] += 1
                else:
                    self.spad[lp, lt] = self.ctx_spad[lp, lt]
            hc2 = (has_ctx).nonzero()[0]
            if len(hc2):
                self.acc_bytes[rp[hc2], rt[hc2]] = \
                    self.ctx_acc[rp[hc2], rt[hc2]]
                self.data_in_accel[rp[hc2], rt[hc2]] = True
            self.log_restore.append((self.orig[rp], br))
            switch[resume] += br

        self.overhead[ip] += switch
        self.running[ip] = nxt
        self.status[ip, nxt] = _RUN
        self.pc[ip, nxt] = 1
        self._record_unblock(ip, nxt, self.now[ip] + switch)
        started = self.now[ip] + switch
        self.run_started[ip] = started
        self.accel_free_at[ip] = started
        rem = self.demand[ip, nxt] - self.exec_cy[ip, nxt]
        self._push_events(ip, nxt, 1, started + rem)
        arm = (self.is_hi[ip, nxt] & ~self.budget_overrun[ip, nxt]
               & (self.exec_cy[ip, nxt] < self.c_lo[ip, nxt]))
        if arm.any():
            ap, an = ip[arm], nxt[arm]
            self._push_events(
                ap, an, 2,
                started[arm] + (self.c_lo[ap, an] - self.exec_cy[ap, an]))

    # -- one scheduler invocation ---------------------------------------
    def _schedule(self, idx: np.ndarray):
        """One scheduler pass per point in ``idx``.  Callers have
        already advanced execution to ``now``; tick points were busy-
        filtered by the run loop, but a stale finish/overrun firing
        inside a context-switch window (its task was preempted with
        zero remaining drain) can still land here mid-switch — defer
        exactly like the event engine's tick re-push."""
        busy = (self.now[idx] < self.accel_free_at[idx]).nonzero()[0]
        if len(busy):
            b = idx[busy]
            self.tick_cs[b] = np.minimum(
                self.tick_cs[b], self._next_tick(self.accel_free_at[b]))
            idx = np.delete(idx, busy)
            if not len(idx):
                return
        m = self.mode[idx]
        self._mode_tick(idx, m)
        m = self.mode[idx]
        # pick_next via the maintained (priority, column) min-keys:
        #   LO-mode            -> min over active tasks
        #   off-LO, HI active  -> min over active HI tasks
        #   off-LO, no HI      -> AMC: none; HI-mode: min over active
        #                         (all LO); transition: resident-LO only
        key = self.act_key[idx]
        if m.any():
            hi_key = self.hi_key[idx]
            hi_active = self.hi_cnt[idx] > 0
            off_lo = m != _LO
            if self.drop_lo:                 # AMC: LO never runs off-LO
                key = np.where(off_lo, hi_key, key)
            else:
                key = np.where(off_lo & hi_active, hi_key, key)
                tr = (off_lo & ~hi_active & (m == _TRANS)).nonzero()[0]
                if len(tr):
                    # transition mode: a LO task may run only while its
                    # data is still resident (rare slow path)
                    rows = idx[tr]
                    ok = (self.status[rows] != _PEND) \
                        & (self.is_hi[rows] | self.data_in_accel[rows]
                           | (self.r_bytes[rows] > 0))
                    kk = np.where(ok, self.prio_key[rows], _EMPTY)
                    key[tr] = kk.min(axis=1)
        none = key >= _EMPTY
        nxt = (key % self.keypad).astype(np.int32)
        nxt[none] = -1
        # clear a stale running slot (event engine's defensive check)
        cur = self.running[idx]
        stale = (cur >= 0) & (self.status[idx, np.maximum(cur, 0)] != _RUN)
        if stale.any():
            self.running[idx[stale]] = -1
            cur = self.running[idx]
        act = ((nxt >= 0) & (cur != nxt)).nonzero()[0]
        if not len(act):
            return
        # a displaced current task blocks the newcomer until the switch
        blocked = act[cur[act] >= 0]
        if len(blocked):
            self._mark_blocked(idx[blocked], nxt[blocked])
        if self.preempt == "none":
            act = act[cur[act] < 0]        # cannot displace the running task
        if len(act):
            self._dispatch(idx[act], nxt[act])

    # -- event handlers --------------------------------------------------
    def _handle_release(self, idx: np.ndarray, tcol: np.ndarray):
        t = self.now[idx]
        self.next_release[idx, tcol] = t + self.period[idx, tcol]
        self.rel_min[idx] = self.next_release[idx].min(axis=1)
        if self.scen is not None:
            # absolute release-event counter (policy-independent CRN
            # key); draws below use the pre-bump value
            self.scen_n[idx, tcol] += 1
        st = self.status[idx, tcol]
        busy = (st != _PEND).nonzero()[0]
        if len(busy):
            # previous job still live: count one miss, skip this release
            bp, bt = idx[busy], tcol[busy]
            fresh = (self.job_deadline[bp, bt] != np.inf).nonzero()[0]
            if len(fresh):
                fp, ft = bp[fresh], bt[fresh]
                crit = self.is_hi[fp, ft].astype(np.int64)
                self.misses[fp, crit] += 1
                self.misses_by_mode[fp, self.mode[fp]] += 1
                self.job_deadline[fp, ft] = np.inf
        hi = self.is_hi[idx, tcol]
        free = st == _PEND
        if self.drop_lo:
            accept = (free & (hi | (self.mode[idx] == _LO))).nonzero()[0]
        else:
            accept = (free).nonzero()[0]
        if not len(accept):
            return
        ap, at_ = idx[accept], tcol[accept]
        ta = t[accept]
        self.status[ap, at_] = _READY
        # activate: bump counts, min-update the pick_next keys
        self.act_cnt[ap] += 1
        k = self.prio_key[ap, at_]
        self.act_key[ap] = np.minimum(self.act_key[ap], k)
        hi_sel = (self.is_hi[ap, at_]).nonzero()[0]
        if len(hi_sel):
            hp_ = ap[hi_sel]
            self.hi_cnt[hp_] += 1
            self.hi_key[hp_] = np.minimum(self.hi_key[hp_], k[hi_sel])
        self.pc[ap, at_] = 0
        self.exec_cy[ap, at_] = 0.0
        self.budget_overrun[ap, at_] = False
        self.job_release[ap, at_] = ta
        self.job_deadline[ap, at_] = ta + self.deadline_rel[ap, at_]
        # per-point rng draws, in the event engine's order.  Bound
        # ``Generator.random`` + the bit-exact identity
        # ``uniform(a, b) == a + (b - a) * random()`` (pinned by tests)
        # halve the per-draw cost of this Python loop.  The "nominal"
        # profile is the zero-jitter degenerate case (demand == C_LO,
        # no draws) shared with the jit backend's exactness gate.
        hi_a = hi[accept]
        c_a = self.c_lo[ap, at_]
        if self.demand_profile == "nominal":
            self.demand[ap, at_] = c_a
        else:
            op = self.overrun_prob
            w_hi = self.cf - 1.0
            w_lo = 1.0 - 0.7
            rands = self.rands
            demands = [0.0] * len(ap)
            for k, (p_, h, c) in enumerate(zip(ap.tolist(), hi_a.tolist(),
                                               c_a.tolist())):
                rnd = rands[p_]
                if h and rnd() < op:
                    demands[k] = c * (1.0 + w_hi * rnd())
                else:
                    demands[k] = c * (0.7 + w_lo * rnd())
            self.demand[ap, at_] = demands
        scen = self.scen
        if scen is not None and scen.affects_demand:
            n_pre = (self.scen_n[ap, at_] - 1).astype(np.uint64)
            m = demand_multiplier(scen, np, self.seed64[ap],
                                  at_.astype(np.uint64), n_pre, ta)
            self.demand[ap, at_] = self.demand[ap, at_] * m
        self.jobs[ap, hi_a.astype(np.int64)] += 1
        rel_hi_mask = ~hi_a & (self.mode[ap] != _LO)
        self.released_in_hi[ap, at_] = rel_hi_mask
        rel_hi = (rel_hi_mask).nonzero()[0]
        if len(rel_hi):
            self.lo_rel_hi[ap[rel_hi]] += 1
        tr = self._next_tick(ta)
        self.tick_release[ap, at_] = tr
        self.tickR_min[ap] = np.minimum(self.tickR_min[ap], tr)

    def _interrupt_guard(self, idx: np.ndarray, col: np.ndarray):
        """Pop one pending finish/overrun event per point; return the
        guard-passing subset (the event's task is the running task).
        Mirrors the event engine's ``running == tid and status ==
        RUNNING`` check; stale events fail it and are dropped."""
        tid = self.ev_tid[idx, col]
        kind = self.ev_kind[idx, col]
        self.ev_time[idx, col] = np.inf       # popped
        self.ev_min[idx] = self.ev_time[idx].min(axis=1)
        gsel = ((self.running[idx] == tid)
                & (self.status[idx, tid] == _RUN)).nonzero()[0]
        return idx[gsel], tid[gsel], kind[gsel]

    def _handle_interrupt(self, gi: np.ndarray, gt: np.ndarray,
                          kind: np.ndarray) -> np.ndarray:
        """Fire guard-passing finish/overrun events (points already
        advanced to the event time); returns points needing a scheduler
        pass.  A stale event whose task is running again reaches here
        too — its only effect is the advance the caller already did."""
        sched: List[np.ndarray] = []
        fin = (kind == 1).nonzero()[0]
        # finish: complete the job when the demand is met
        if len(fin):
            fp, ft = gi[fin], gt[fin]
            done = (self.exec_cy[fp, ft]
                    >= self.demand[fp, ft] - 1e-6).nonzero()[0]
            if len(done):
                dp, dt_ = fp[done], ft[done]
                self.status[dp, dt_] = _PEND
                crit = self.is_hi[dp, dt_].astype(np.int64)
                # deactivate: recompute the affected points' min-keys
                self.act_cnt[dp] -= 1
                hi_sel = (crit == 1).nonzero()[0]
                if len(hi_sel):
                    self.hi_cnt[dp[hi_sel]] -= 1
                pk = np.where(self.status[dp] != _PEND,
                              self.prio_key[dp], _EMPTY)
                self.act_key[dp] = pk.min(axis=1)
                self.hi_key[dp] = np.where(self.is_hi[dp], pk,
                                           _EMPTY).min(axis=1)
                self.done[dp, crit] += 1
                late = (self.now[dp] > self.job_deadline[dp, dt_]) \
                    .nonzero()[0]
                if len(late):
                    lp = dp[late]
                    self.misses[lp, crit[late]] += 1
                    self.misses_by_mode[lp, self.mode[lp]] += 1
                surv = (self.released_in_hi[dp, dt_]
                        & (self.now[dp]
                           <= self.job_deadline[dp, dt_])).nonzero()[0]
                if len(surv):
                    self.lo_done_hi[dp[surv]] += 1
                # GemminiRT.evict
                self.overhead[dp] += FLUSH_CYCLES
                rb = self.r_bytes[dp, dt_]
                self.locked[dp] -= (rb + _BB - 1) // _BB
                gone = ((rb > 0) & (crit == 0)).nonzero()[0]
                if len(gone):
                    self.res_lo_cnt[dp[gone]] -= 1
                self.r_bytes[dp, dt_] = 0
                self.spad[dp, dt_] = 0
                self.acc_bytes[dp, dt_] = 0
                self.ctx_valid[dp, dt_] = False
                self.data_in_accel[dp, dt_] = False
                self.demand[dp, dt_] = np.inf
                self.running[dp] = -1
                sched.append(dp)
        # overrun: flag the budget excess, degrade LO -> transition
        ovr = (kind == 2).nonzero()[0]
        if len(ovr):
            op_, ot = gi[ovr], gt[ovr]
            fire = ((self.exec_cy[op_, ot] >= self.c_lo[op_, ot] - 1e-6)
                    & ~self.budget_overrun[op_, ot]).nonzero()[0]
            if len(fire):
                fp, ft = op_[fire], ot[fire]
                self.budget_overrun[fp, ft] = True
                was_lo = (self.mode[fp] == _LO).nonzero()[0]
                if len(was_lo):
                    wp = fp[was_lo]
                    self._set_mode(wp, np.full(len(wp), _TRANS, np.int8))
                sched.append(fp)
        if not sched:
            return np.empty(0, np.int64)
        return np.concatenate(sched) if len(sched) > 1 else sched[0]

    # -- main loop --------------------------------------------------------
    def run(self) -> List[RunMetrics]:
        P0 = len(self.orig)
        T = self.T
        tail_state: Dict[int, tuple] = {}
        while True:
            P = self.P
            if P == 0:
                break
            cand = np.empty((P, 4))
            cand[:, 0] = self.rel_min
            cand[:, 1] = self.tickR_min
            cand[:, 2] = self.ev_min
            cand[:, 3] = self.tick_cs
            j = np.argmin(cand, axis=1)
            tmin = cand[self._ar, j]
            fire = self.alive & (tmin <= self.duration)
            expired = self.alive & ~fire
            if expired.any():
                # freeze tail state at expiry (the event engine's break)
                for p in (expired).nonzero()[0]:
                    tail_state[int(self.orig[p])] = self._tail_snapshot(p)
                self.alive[expired] = False
            if not fire.any():
                break
            self.now[fire] = tmin[fire]
            # release events (no scheduler pass of their own)
            ridx = (fire & (j == 0)).nonzero()[0]
            if len(ridx):
                tcol = np.argmin(self.next_release[ridx], axis=1)
                self._handle_release(ridx, tcol)
            # scheduler ticks: defer while a context switch is in flight
            tidx = (fire & (j == 1)).nonzero()[0]
            if len(tidx):
                tcol = np.argmin(self.tick_release[tidx], axis=1)
                self.tick_release[tidx, tcol] = np.inf
                self.tickR_min[tidx] = self.tick_release[tidx].min(axis=1)
            cidx = (fire & (j == 3)).nonzero()[0]
            if len(cidx):
                self.tick_cs[cidx] = np.inf
            ticks = np.concatenate([tidx, cidx]) \
                if len(cidx) else tidx
            # pending finish/overrun interrupts: pop + guard
            iidx = (fire & (j == 2)).nonzero()[0]
            if len(iidx):
                icol = np.argmin(self.ev_time[iidx], axis=1)
                gi, gt, gkind = self._interrupt_guard(iidx, icol)
            else:
                gi = gt = gkind = np.empty(0, np.int64)
            # one advance for every point that needs it this step
            # (interrupt targets + non-deferred tick points, disjoint)
            if len(ticks):
                busy = self.now[ticks] < self.accel_free_at[ticks]
                bsel = busy.nonzero()[0]
                if len(bsel):
                    b = ticks[bsel]
                    self.tick_cs[b] = np.minimum(
                        self.tick_cs[b],
                        self._next_tick(self.accel_free_at[b]))
                    ticks = ticks[(~busy).nonzero()[0]]
            adv = np.concatenate([gi, ticks]) if len(gi) else ticks
            if len(adv):
                self._advance(adv)
            if len(gi):
                extra = self._handle_interrupt(gi, gt, gkind)
                if len(extra):
                    ticks = np.concatenate([ticks, extra])
            if len(ticks):
                self._schedule(ticks)
            if self.P > 64 and self.alive.sum() < 0.5 * self.P:
                self._compact()
        # points that drained their event queues entirely (rare)
        for p in (self.alive).nonzero()[0]:
            tail_state[int(self.orig[p])] = self._tail_snapshot(p)
        return self._assemble(P0, tail_state)

    # -- tail accounting + RunMetrics assembly ---------------------------
    def _tail_snapshot(self, p: int) -> tuple:
        """Everything the tail accounting of run() needs, per point."""
        mode_cycles = self.mode_cycles[p].copy()
        mode_cycles[self.mode[p]] += self.duration - self.last_mode_stamp[p]
        live = (self.status[p] != _PEND) & self.valid[p] \
            & (self.duration > self.job_deadline[p])
        misses = self.misses[p].copy()
        for t in (live).nonzero()[0]:
            misses[int(self.is_hi[p, t])] += 1
        return (mode_cycles, misses, self.jobs[p].copy(),
                self.done[p].copy(), self.misses_by_mode[p].copy(),
                int(self.lo_rel_hi[p]), int(self.lo_done_hi[p]),
                int(self.cs_count[p]), float(self.exec_sum[p]),
                float(self.overhead[p]))

    def _assemble(self, P0: int, tail: Dict[int, tuple]) -> List[RunMetrics]:
        def per_point(log) -> List[List]:
            out: List[List] = [[] for _ in range(P0)]
            for ids, vals in log:
                for i, v in zip(ids.tolist(), vals.tolist()):
                    out[i].append(v)
            return out
        saves, restores = per_point(self.log_save), per_point(self.log_restore)
        pis, cis = per_point(self.log_pi), per_point(self.log_ci)
        out = []
        for p in range(P0):
            (mode_cycles, misses, jobs, done, mbm, lrh, ldh, csn,
             exs, ovh) = tail[p]
            out.append(RunMetrics(
                pi_blocking=pis[p], ci_blocking=cis[p],
                save_cycles=saves[p], restore_cycles=restores[p],
                jobs={"LO": int(jobs[0]), "HI": int(jobs[1])},
                done={"LO": int(done[0]), "HI": int(done[1])},
                misses={"LO": int(misses[0]), "HI": int(misses[1])},
                misses_by_mode={k: int(mbm[i])
                                for i, k in enumerate(_MODE_KEYS)},
                lo_released_in_hi=lrh, lo_done_in_hi=ldh,
                mode_cycles={k: float(mode_cycles[i])
                             for i, k in enumerate(_MODE_KEYS)},
                cs_count=csn, exec_cycles=exs, overhead_cycles=ovh))
        return out


# ----------------------------------------------------------------------
# Public entry point
# ----------------------------------------------------------------------

def simulate_vbatch(tasksets: Sequence[List[TaskParams]],
                    programs: Dict[str, Program], policy: Policy, *,
                    seeds: Sequence[int], duration: float = 2e7,
                    overrun_prob: float = 0.3, cf: float = 2.0,
                    batch_size: int = 256,
                    select_backend: str = "numpy",
                    demand_profile: str = "sampled",
                    devices: Optional[int] = None,
                    scenario=None) -> List[RunMetrics]:
    """Vectorized batch counterpart of :func:`repro.core.simulator
    .simulate_batch`: one independent simulated point per (taskset,
    seed) pair, all points advanced in lockstep SoA batches.

    ``select_backend`` picks the lockstep executor:

      * ``"numpy"`` (default) — bit-identical to the event-driven
        engine per point (see the module docstring);
      * ``"jit"`` — the fully-compiled ``jax.lax.while_loop`` backend
        (``core.simulator_jit``): statistically equivalent under demand
        jitter, exactly equivalent on ``demand_profile="nominal"``;
        raises ``RuntimeError`` when JAX is not installed.  ``"jax"``
        is accepted as a deprecated alias.

    ``demand_profile="nominal"`` replaces the per-release demand draws
    with the deterministic C_LO budget (the zero-jitter profile used by
    the cross-backend exact-equivalence gate).  ``batch_size`` bounds
    the lockstep width so a straggler point cannot serialize an
    arbitrarily large batch.  ``devices`` shards the jit backend's
    point axis over that many logical devices (``None``: the
    ``REPRO_DEVICES`` default; bit-identical results at any count —
    see ``repro.runtime.device_config``); the host backends are
    single-device, so an explicit count above 1 is rejected.
    """
    if select_backend not in BACKENDS:
        raise ValueError(
            f"unknown select_backend {select_backend!r}; "
            f"want one of {BACKENDS}")
    if demand_profile not in DEMAND_PROFILES:
        raise ValueError(
            f"unknown demand_profile {demand_profile!r}; "
            f"want one of {DEMAND_PROFILES}")
    scen = get_scenario(scenario)          # loud on unknown names
    if len(tasksets) != len(seeds):
        raise ValueError(f"{len(tasksets)} tasksets vs {len(seeds)} seeds")
    if select_backend in ("jit", "jax"):
        if select_backend == "jax":
            # the old per-step jax candidate-select path this named was
            # numerically identical to numpy; the jit backend it now
            # aliases is only *statistically* equivalent and returns
            # AggSamples aggregates instead of per-event metric lists
            warnings.warn(
                "select_backend='jax' is a deprecated alias for 'jit' "
                "(different RNG realizations, aggregate metrics); pass "
                "'jit' explicitly or 'numpy' for bit-exact results",
                DeprecationWarning, stacklevel=2)
        from repro.core import simulator_jit
        simulator_jit.require_jax(select_backend)
        return simulator_jit.simulate_jbatch(
            tasksets, programs, policy, seeds=seeds, duration=duration,
            overrun_prob=overrun_prob, cf=cf, batch_size=batch_size,
            demand_profile=demand_profile, devices=devices, scenario=scen)
    if devices is not None and devices != 1:
        raise ValueError(
            f"devices={devices} requires select_backend='jit' — the "
            f"{select_backend!r} backend runs on the host and cannot "
            "shard over logical devices")
    out: List[RunMetrics] = []
    for lo in range(0, len(tasksets), batch_size):
        chunk_ts = list(tasksets[lo:lo + batch_size])
        chunk_seeds = list(seeds[lo:lo + batch_size])
        batch = _VecBatch(chunk_ts, programs, policy, seeds=chunk_seeds,
                          duration=duration, overrun_prob=overrun_prob,
                          cf=cf, demand_profile=demand_profile,
                          scenario=scen)
        out.extend(batch.run())
    return out
