"""Cycle-level discrete-event simulation of MESC (and baselines).

Implements the paper's runtime semantics on a virtual 100 MHz clock:

  * the task scheduler runs every T_sr cycles (releases observed at ticks —
    the +T_sr term of Eq. 1);
  * job completion and LO-WCET overruns (the monitor's per-task timers)
    interrupt immediately;
  * a preemption drains the in-flight instruction (instruction policy), or
    runs to the operator boundary (limited preemption), or cannot happen
    at all (non-preemptive baseline);
  * context save/restore cycles come from the GemminiRT executor model —
    including the zero-scratchpad-copy fast path when the bank allocator
    finds room (Obs. 1);
  * mode transitions follow scheduler.update_mode; AMC drops LO jobs.

Metrics recorded per run: pi/ci blocking intervals, save/restore cycle
breakdowns, deadline misses per criticality, LO jobs released & completed
in HI-mode (survivability), mode residency.

Entry points: ``simulate`` runs one (taskset, seed) point;
``simulate_batch`` runs a list of such points serially in-process.  Runs
are fully independent — all randomness comes from the per-run
``np.random.default_rng(seed)`` — which is what lets the campaign
engine (``repro.experiments``) fan points out across worker processes
and cache each point by content hash without changing any result.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional

import numpy as np

from repro.core.executor import GemminiRT
from repro.core.program import Program
from repro.core.scheduler import Mode, Policy, pick_next
from repro.core.task import Crit, Status, TCB, TaskParams

# Fingerprint of the simulation semantics, baked into every campaign
# cache key (repro.experiments.spec).  BUMP THIS whenever a change to
# the simulator / scheduler / executor / taskgen alters any simulated
# result — otherwise previously-cached campaign points silently go
# stale and figures mix pre- and post-change rows.
SIM_SEMANTICS_VERSION = 1


@dataclasses.dataclass
class RunMetrics:
    pi_blocking: List[float] = dataclasses.field(default_factory=list)
    ci_blocking: List[float] = dataclasses.field(default_factory=list)
    save_cycles: List[float] = dataclasses.field(default_factory=list)
    restore_cycles: List[float] = dataclasses.field(default_factory=list)
    jobs: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"LO": 0, "HI": 0})
    done: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"LO": 0, "HI": 0})
    misses: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"LO": 0, "HI": 0})
    misses_by_mode: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"LO": 0, "transition": 0, "HI": 0})
    lo_released_in_hi: int = 0
    lo_done_in_hi: int = 0
    mode_cycles: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {"LO": 0.0, "transition": 0.0, "HI": 0.0})
    cs_count: int = 0
    exec_cycles: float = 0.0
    overhead_cycles: float = 0.0

    def success(self, scope: str = "all") -> bool:
        if scope == "HI":
            return self.misses["HI"] == 0
        return self.misses["HI"] == 0 and self.misses["LO"] == 0

    def survivability(self) -> float:
        if self.lo_released_in_hi == 0:
            return 1.0
        return self.lo_done_in_hi / self.lo_released_in_hi


class MCSSimulator:
    def __init__(self, tasks: List[TaskParams], programs: Dict[str, Program],
                 policy: Policy, *, duration: float = 2e7, seed: int = 0,
                 overrun_prob: float = 0.3, cf: float = 2.0):
        self.params = {t.tid: t for t in tasks}
        self.programs = programs
        self.policy = policy
        self.duration = duration
        self.rng = np.random.default_rng(seed)
        self.overrun_prob = overrun_prob
        self.cf = cf
        self.accel = GemminiRT(use_remapper=policy.use_banks)
        self.tcbs: Dict[int, TCB] = {t.tid: TCB(params=t) for t in tasks}
        self.metrics = RunMetrics()
        self.mode = Mode.LO
        self.now = 0.0
        self.running: Optional[int] = None
        self.accel_free_at = 0.0     # context switch in progress until here
        self.demand: Dict[int, float] = {}
        self._events: List = []      # (time, seq, kind, tid)
        self._seq = 0
        self._last_mode_stamp = 0.0

    # ------------------------------------------------------------------
    def _push(self, t: float, kind: str, tid: int = -1):
        self._seq += 1
        heapq.heappush(self._events, (t, self._seq, kind, tid))

    def _program(self, tid: int) -> Program:
        return self.programs[self.params[tid].workload]

    def _sample_demand(self, p: TaskParams) -> float:
        if p.crit == Crit.HI and self.rng.random() < self.overrun_prob:
            return p.c_lo * self.rng.uniform(1.0, self.cf)
        return p.c_lo * self.rng.uniform(0.7, 1.0)

    def _next_tick(self, t: float) -> float:
        k = int(t // self.policy.t_sr) + 1
        return k * self.policy.t_sr

    # ------------------------------------------------------------------
    def _advance_running(self):
        """Account progress of the running task up to self.now."""
        if self.running is None:
            return
        tcb = self.tcbs[self.running]
        elapsed = self.now - self._run_started
        if elapsed <= 0:
            return
        tcb.exec_cycles += elapsed
        self.metrics.exec_cycles += elapsed
        self.accel.note_execution(tcb.tid, elapsed, self._program(tcb.tid))
        self._run_started = self.now

    def _set_mode(self, mode: Mode):
        if mode is not self.mode:
            self.metrics.mode_cycles[self.mode.value] += \
                self.now - self._last_mode_stamp
            self._last_mode_stamp = self.now
            self.mode = mode

    def _mode_tick(self):
        """Mode progression per SS IV."""
        resident_lo = [t for t in self.accel.remapper.resident_tasks()
                       if self.params.get(t) is not None
                       and self.params[t].crit == Crit.LO]
        any_active = any(t.status in (Status.READY, Status.RUNNING,
                                      Status.INTERRUPTED)
                         for t in self.tcbs.values())
        if self.mode == Mode.TRANS and len(resident_lo) <= 1:
            self._set_mode(Mode.HI)
        elif self.mode != Mode.LO and not any_active:
            self._set_mode(Mode.LO)

    # ------------------------------------------------------------------
    def _finish_job(self, tcb: TCB):
        tcb.status = Status.PENDING
        crit = tcb.params.crit.value
        self.metrics.done[crit] += 1
        if tcb.job_release >= 0 and self.now > tcb.job_deadline:
            self.metrics.misses[crit] += 1
            self.metrics.misses_by_mode[self.mode.value] += 1
        if getattr(tcb, "released_in_hi", False) \
                and self.now <= tcb.job_deadline:
            self.metrics.lo_done_in_hi += 1
        self.metrics.overhead_cycles += self.accel.evict(tcb.tid)
        tcb.data_in_accel = False
        self.demand.pop(tcb.tid, None)

    def _record_unblock(self, tcb: TCB, at: Optional[float] = None):
        if tcb.blocked_since is not None:
            dt = (at if at is not None else self.now) - tcb.blocked_since
            # criticality inversion: a HI-task was kept waiting by a LO-task
            # while the system was (or entered) degraded mode
            cause = tcb.blocking_cause
            if (cause == "ci?" and self.mode != Mode.LO):
                cause = "ci"
            if dt > 0:
                (self.metrics.ci_blocking if cause == "ci"
                 else self.metrics.pi_blocking).append(dt)
            tcb.blocked_since = None
            tcb.blocking_cause = None

    def _mark_blocked(self, tcb: TCB):
        if tcb.blocked_since is None:
            tcb.blocked_since = self.now
            run = self.tcbs.get(self.running) if self.running is not None \
                else None
            if (tcb.params.crit == Crit.HI and run is not None
                    and run.params.crit == Crit.LO):
                tcb.blocking_cause = "ci" if self.mode != Mode.LO else "ci?"
            else:
                tcb.blocking_cause = "pi"

    # ------------------------------------------------------------------
    def _dispatch(self, nxt: TCB):
        """Context switch to ``nxt`` (Alg. 1)."""
        cur = self.tcbs.get(self.running) if self.running is not None else None
        switch_cost = 0.0
        if cur is not None and cur.tid != nxt.tid:
            prog = self._program(cur.tid)
            if self.policy.preemption == "instruction":
                boundary = prog.next_instruction_boundary(cur.exec_cycles)
            else:  # operator
                boundary = prog.next_operator_boundary(cur.exec_cycles)
            drain = max(0.0, min(boundary, self.demand[cur.tid])
                        - cur.exec_cycles)
            cur.exec_cycles += drain
            next_eta = nxt.params.eta if self.policy.use_banks else None
            br = self.accel.context_save(cur, int(drain), next_eta=next_eta)
            # HI-mode rule: <=1 resident LO-task -> evict on LO->LO preempt
            if (self.mode == Mode.HI and cur.params.crit == Crit.LO
                    and nxt.params.crit == Crit.LO):
                self.accel.remapper.release(cur.tid)
                cur.data_in_accel = False
            cur.status = Status.INTERRUPTED
            switch_cost += br.total
            self.metrics.save_cycles.append(br.total)
            self.metrics.cs_count += 1
        if nxt.pc > 0 or nxt.status == Status.INTERRUPTED:
            br = self.accel.context_restore(nxt)
            switch_cost += br.total
            self.metrics.restore_cycles.append(br.total)
        self.metrics.overhead_cycles += switch_cost
        self.running = nxt.tid
        nxt.status = Status.RUNNING
        nxt.pc = 1
        self._record_unblock(nxt, at=self.now + switch_cost)
        self._run_started = self.now + switch_cost
        self.accel_free_at = self.now + switch_cost
        # future events for the new running task
        rem = self.demand[nxt.tid] - nxt.exec_cycles
        self._push(self._run_started + rem, "finish", nxt.tid)
        p = nxt.params
        if (p.crit == Crit.HI and not nxt.budget_overrun
                and nxt.exec_cycles < p.c_lo):
            self._push(self._run_started + (p.c_lo - nxt.exec_cycles),
                       "overrun", nxt.tid)

    def _schedule(self):
        """One scheduler invocation (a T_sr tick or an interrupt)."""
        if self.now < self.accel_free_at:      # CS in progress
            self._push(self._next_tick(self.accel_free_at), "tick")
            return
        self._advance_running()
        self._mode_tick()
        resident = self.accel.remapper.resident_tasks()
        nxt = pick_next(self.tcbs, self.mode, resident, self.policy)
        cur = self.tcbs.get(self.running) if self.running is not None else None
        if cur is not None and cur.status != Status.RUNNING:
            cur = None
            self.running = None
        if nxt is None:
            return
        if cur is not None and nxt.tid == cur.tid:
            return
        if cur is not None and self.policy.preemption == "none":
            self._mark_blocked(nxt)            # must wait for completion
            return
        if cur is not None:
            self._mark_blocked(nxt)            # waits for drain + CS
        self._dispatch(nxt)

    # ------------------------------------------------------------------
    def run(self) -> RunMetrics:
        for tid, p in self.params.items():
            phase = self.rng.uniform(0, p.period)
            self._push(phase, "release", tid)
        self._run_started = 0.0
        while self._events:
            t, _, kind, tid = heapq.heappop(self._events)
            if t > self.duration:
                break
            self.now = t
            if kind == "release":
                tcb = self.tcbs[tid]
                p = tcb.params
                self._push(t + p.period, "release", tid)
                if tcb.status != Status.PENDING:
                    # previous job still live: count a miss once, skip release
                    if tcb.job_deadline != float("inf"):
                        self.metrics.misses[p.crit.value] += 1
                        self.metrics.misses_by_mode[self.mode.value] += 1
                        tcb.job_deadline = float("inf")
                    continue
                if self.policy.drop_lo_in_hi and p.crit == Crit.LO \
                        and self.mode != Mode.LO:
                    continue                    # AMC: LO not released
                tcb.release(t)
                self.demand[tid] = self._sample_demand(p)
                self.metrics.jobs[p.crit.value] += 1
                tcb.released_in_hi = (p.crit == Crit.LO
                                      and self.mode != Mode.LO)
                if tcb.released_in_hi:
                    self.metrics.lo_released_in_hi += 1
                self._push(self._next_tick(t), "tick")
            elif kind == "finish":
                tcb = self.tcbs[tid]
                if self.running == tid and tcb.status == Status.RUNNING:
                    self._advance_running()
                    if tcb.exec_cycles >= self.demand.get(
                            tid, float("inf")) - 1e-6:
                        self._finish_job(tcb)
                        self.running = None
                        self._schedule()
            elif kind == "overrun":
                tcb = self.tcbs[tid]
                if self.running == tid and tcb.status == Status.RUNNING:
                    self._advance_running()
                    if tcb.exec_cycles >= tcb.params.c_lo - 1e-6 \
                            and not tcb.budget_overrun:
                        tcb.budget_overrun = True
                        if self.mode == Mode.LO:
                            self._set_mode(Mode.TRANS)   # Mode_switch
                        self._schedule()
            elif kind == "tick":
                self._schedule()
        # tail accounting
        self.metrics.mode_cycles[self.mode.value] += \
            self.duration - self._last_mode_stamp
        for tcb in self.tcbs.values():
            if tcb.status != Status.PENDING \
                    and self.duration > tcb.job_deadline:
                self.metrics.misses[tcb.params.crit.value] += 1
        return self.metrics


def simulate(tasks, programs, policy, **kw) -> RunMetrics:
    return MCSSimulator(tasks, programs, policy, **kw).run()


def simulate_batch(tasksets, programs, policy, *, seeds,
                   **kw) -> List[RunMetrics]:
    """Batch entry point: one independent simulator per (taskset, seed).

    ``seeds`` must align with ``tasksets``; pair this with
    ``taskgen.generate_taskset_batch`` so taskset ``s`` and its run share
    ``point_seed(seed0, s)`` — the engine's per-point seeding contract.
    """
    if len(tasksets) != len(seeds):
        raise ValueError(f"{len(tasksets)} tasksets vs {len(seeds)} seeds")
    return [MCSSimulator(tasks, programs, policy, seed=s, **kw).run()
            for tasks, s in zip(tasksets, seeds)]
