"""Cycle-level discrete-event simulation of MESC (and baselines): the
runtime semantics of SS IV (scheduling/modes) + SS V (context-switch
costs) driving the SS VIII experiments.

Implements the paper's runtime semantics on a virtual 100 MHz clock:

  * the task scheduler runs every T_sr cycles (releases observed at ticks —
    the +T_sr term of Eq. 1);
  * job completion and LO-WCET overruns (the monitor's per-task timers)
    interrupt immediately;
  * a preemption drains the in-flight instruction (instruction policy), or
    runs to the operator boundary (limited preemption), or cannot happen
    at all (non-preemptive baseline);
  * context save/restore cycles come from the GemminiRT executor model —
    including the zero-scratchpad-copy fast path when the bank allocator
    finds room (Obs. 1);
  * mode transitions follow scheduler.update_mode; AMC drops LO jobs.

Metrics recorded per run: pi/ci blocking intervals, save/restore cycle
breakdowns, deadline misses per criticality, LO jobs released & completed
in HI-mode (survivability), mode residency.

Entry points: ``simulate`` runs one (taskset, seed) point;
``simulate_batch`` runs a list of such points serially in-process;
``simulate_multi`` runs the partitioned multi-accelerator variant
(``MultiAccelSimulator``, platform layer).  Runs
are fully independent — all randomness comes from the per-run
``np.random.default_rng(seed)`` — which is what lets the campaign
engine (``repro.experiments``) fan points out across worker processes
and cache each point by content hash without changing any result.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
from heapq import heappush
from typing import Dict, List, Optional, Union

import numpy as np

from repro.core.executor import GemminiRT
from repro.core.program import Program
from repro.scenarios import (demand_multiplier, get_scenario,
                             shifted_phases)
from repro.core.scheduler import (ACTIVE, Mode, Policy, pick_next,
                                  update_mode)
from repro.core.task import Crit, Status, TCB, TaskParams

# Fingerprint of the simulation semantics, baked into every campaign
# cache key (repro.experiments.spec).  BUMP THIS whenever a change to
# the simulator / scheduler / executor / taskgen alters any simulated
# result — otherwise previously-cached campaign points silently go
# stale and figures mix pre- and post-change rows.
SIM_SEMANTICS_VERSION = 1

# Same contract for the multi-accelerator path (MultiAccelSimulator /
# platform / migration): multi-instance sweeps salt their cache keys
# with this so multi semantics can evolve without invalidating the
# single-instance campaign cache.  v5 = job-scoped migration, HI-slack
# admission guard, migration retry + idle-wake ticks, un-double-counted
# overhead.
MULTI_SIM_SEMANTICS_VERSION = 5


class EventKind(enum.IntEnum):
    """Interned event kinds for the heap tuples (hot loop: comparing and
    hashing small ints beats per-event string handling)."""
    RELEASE = 0
    FINISH = 1
    OVERRUN = 2
    TICK = 3


# plain ints in the hot loop (IntEnum __eq__ costs a descriptor hop)
_RELEASE = int(EventKind.RELEASE)
_FINISH = int(EventKind.FINISH)
_OVERRUN = int(EventKind.OVERRUN)
_TICK = int(EventKind.TICK)

#: Demand profiles every engine understands.  "sampled" draws each
#: release's demand from the host rng stream (the engines' historical
#: behaviour); "nominal" pins demand at c_lo and consumes zero draws
#: (the vec<->jit bit-exactness corpus).  Canonical definition lives
#: here (the event engine is the semantic reference); simulator_vec
#: re-exports it.
DEMAND_PROFILES = ("sampled", "nominal")


class AggSamples:
    """Sum/count aggregate standing in for a per-event sample list.

    The jit lockstep backend (``core.simulator_jit``) accumulates
    blocking/save/restore statistics on-device as ``(total, n)`` pairs
    instead of materializing unbounded per-event lists; RunMetrics
    fields typed ``List[float]`` may hold one of these instead.
    ``metrics_row`` consumes either form — the totals are accumulated
    in event order, so on a trajectory identical to the NumPy engine's
    the flattened row is bit-identical too.
    """
    __slots__ = ("total", "n")

    def __init__(self, total: float, n: int):
        self.total = float(total)
        self.n = int(n)

    def __len__(self) -> int:
        return self.n

    @property
    def mean(self) -> float:
        """Mean of the aggregated samples; NaN for an empty aggregate
        (a run with zero blocking/save/restore events is normal — it
        must not raise ``ZeroDivisionError`` in a metrics pipeline)."""
        if self.n == 0:
            return float("nan")
        return self.total / self.n

    def __eq__(self, other) -> bool:
        return (isinstance(other, AggSamples)
                and self.total == other.total and self.n == other.n)

    def __iter__(self):
        raise TypeError(
            "AggSamples is a sum/count aggregate, not a sample list — "
            "read .total/.n (or go through metrics_row); the jit "
            "backend does not materialize per-event samples")

    def __repr__(self) -> str:
        return f"AggSamples(total={self.total!r}, n={self.n})"


# per-event sample lists, or AggSamples when the producing engine
# (core.simulator_jit) carries aggregates instead
Samples = Union[List[float], AggSamples]


@dataclasses.dataclass
class RunMetrics:
    pi_blocking: Samples = dataclasses.field(default_factory=list)
    ci_blocking: Samples = dataclasses.field(default_factory=list)
    save_cycles: Samples = dataclasses.field(default_factory=list)
    restore_cycles: Samples = dataclasses.field(default_factory=list)
    jobs: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"LO": 0, "HI": 0})
    done: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"LO": 0, "HI": 0})
    misses: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"LO": 0, "HI": 0})
    misses_by_mode: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"LO": 0, "transition": 0, "HI": 0})
    lo_released_in_hi: int = 0
    lo_done_in_hi: int = 0
    mode_cycles: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {"LO": 0.0, "transition": 0.0, "HI": 0.0})
    cs_count: int = 0
    exec_cycles: float = 0.0
    overhead_cycles: float = 0.0

    def success(self, scope: str = "all") -> bool:
        if scope == "HI":
            return self.misses["HI"] == 0
        return self.misses["HI"] == 0 and self.misses["LO"] == 0

    def survivability(self) -> float:
        if self.lo_released_in_hi == 0:
            return 1.0
        return self.lo_done_in_hi / self.lo_released_in_hi


class DemandSampler:
    """One scenario-aware demand/overrun sampler shared by the single-
    and multi-accelerator event engines (hoisted from their previously
    duplicated ``_sample_demand`` bodies, so the scenario hooks cannot
    drift between the two paths).

    Draw-order contract (bit-exactness vs the vec engine): the
    "sampled" profile consumes, per *accepted* release, exactly one
    ``rng.random()`` overrun coin for HI tasks plus one ``rng.uniform``
    magnitude; the "nominal" profile consumes no draws.  Scenario
    multipliers never touch the host stream: they are counter-based CRN
    draws keyed ``(seed, component, task_column, release_index)`` — the
    same keys the vec/jit lockstep uses — where ``release_index``
    counts *every* release event (accepted, busy-missed, or AMC-
    dropped), making the fault realization policy-independent.
    """

    def __init__(self, rng, tasks, *, seed, overrun_prob, cf,
                 demand_profile="sampled", scenario=None):
        if demand_profile not in DEMAND_PROFILES:
            raise ValueError(
                f"unknown demand_profile {demand_profile!r}; want one "
                f"of {DEMAND_PROFILES}")
        self.rng = rng
        self.overrun_prob = overrun_prob
        self.cf = cf
        self.nominal = demand_profile == "nominal"
        self.scenario = get_scenario(scenario)
        self.seed64 = np.uint64(np.int64(seed))
        self._col = {t.tid: np.uint64(i) for i, t in enumerate(tasks)}
        self._rel_n: Dict[int, int] = {t.tid: 0 for t in tasks}

    def count_release(self, tid: int) -> int:
        """Absolute release index of this release event — the host twin
        of the vec/jit engines' ``sn`` scenario counter.  Call once at
        release-handler entry (before any accept/drop gate); the draw
        for the release uses the returned pre-bump value."""
        n = self._rel_n[tid]
        self._rel_n[tid] = n + 1
        return n

    def shift_phase(self, tid: int, phase: float, period: float) -> float:
        """Apply the scenario's phase-shift component to one task's
        host-drawn initial release phase."""
        scen = self.scenario
        if scen is None or not scen.has_phase_shift:
            return phase
        return float(shifted_phases(scen, self.seed64, self._col[tid],
                                    phase, period))

    def sample(self, p: TaskParams, rel_n: int, t: float) -> float:
        """Demand for one accepted release of task ``p`` (release index
        ``rel_n``, release time ``t``)."""
        if self.nominal:
            d = p.c_lo
        elif p.crit == Crit.HI and self.rng.random() < self.overrun_prob:
            d = p.c_lo * self.rng.uniform(1.0, self.cf)
        else:
            d = p.c_lo * self.rng.uniform(0.7, 1.0)
        scen = self.scenario
        if scen is not None and scen.affects_demand:
            m = demand_multiplier(scen, np, self.seed64, self._col[p.tid],
                                  np.uint64(rel_n), np.float64(t))
            d = d * float(m)
        return d


class MCSSimulator:
    def __init__(self, tasks: List[TaskParams], programs: Dict[str, Program],
                 policy: Policy, *, duration: float = 2e7, seed: int = 0,
                 overrun_prob: float = 0.3, cf: float = 2.0,
                 demand_profile: str = "sampled", scenario=None):
        self.params = {t.tid: t for t in tasks}
        self.programs = programs
        self.policy = policy
        self.duration = duration
        self.rng = np.random.default_rng(seed)
        self.overrun_prob = overrun_prob
        self.cf = cf
        self.sampler = DemandSampler(
            self.rng, tasks, seed=seed, overrun_prob=overrun_prob, cf=cf,
            demand_profile=demand_profile, scenario=scenario)
        self.accel = GemminiRT(use_remapper=policy.use_banks)
        self.tcbs: Dict[int, TCB] = {t.tid: TCB(params=t) for t in tasks}
        self.metrics = RunMetrics()
        self.mode = Mode.LO
        self.now = 0.0
        self.running: Optional[int] = None
        self.accel_free_at = 0.0     # context switch in progress until here
        self.demand: Dict[int, float] = {}
        self._events: List = []      # (time, seq, kind, tid)
        self._seq = 0
        self._last_mode_stamp = 0.0
        # hot-loop caches: per-task program / LO-crit flag resolved once
        # instead of two dict hops per dispatch (+ per mode tick)
        self._progs: Dict[int, Program] = {
            t.tid: programs[t.workload] for t in tasks}
        self._is_lo: Dict[int, bool] = {
            t.tid: t.crit == Crit.LO for t in tasks}
        self._t_sr = policy.t_sr
        self._instr_preempt = policy.preemption == "instruction"
        self._use_banks = policy.use_banks
        self._note_execution = self.accel.note_execution

    # ------------------------------------------------------------------
    def _push(self, t: float, kind: int, tid: int = -1):
        self._seq += 1
        heappush(self._events, (t, self._seq, kind, tid))

    def _program(self, tid: int) -> Program:
        return self._progs[tid]

    def _next_tick(self, t: float) -> float:
        k = int(t // self._t_sr) + 1
        return k * self._t_sr

    # ------------------------------------------------------------------
    def _advance_running(self):
        """Account progress of the running task up to self.now."""
        if self.running is None:
            return
        tcb = self.tcbs[self.running]
        elapsed = self.now - self._run_started
        if elapsed <= 0:
            return
        tcb.exec_cycles += elapsed
        self.metrics.exec_cycles += elapsed
        self._note_execution(tcb.tid, elapsed, self._progs[tcb.tid])
        self._run_started = self.now

    def _set_mode(self, mode: Mode):
        if mode is not self.mode:
            self.metrics.mode_cycles[self.mode.value] += \
                self.now - self._last_mode_stamp
            self._last_mode_stamp = self.now
            self.mode = mode

    def _mode_tick(self):
        """Mode progression per SS IV."""
        if self.mode is Mode.LO:
            return                   # LO only leaves via an overrun event
        is_lo = self._is_lo
        resident_lo = [t for t in self.accel.remapper.resident_tasks()
                       if is_lo.get(t)]
        any_active = any(t.status is not Status.PENDING
                         for t in self.tcbs.values())
        if self.mode == Mode.TRANS and len(resident_lo) <= 1:
            self._set_mode(Mode.HI)
        elif self.mode != Mode.LO and not any_active:
            self._set_mode(Mode.LO)

    # ------------------------------------------------------------------
    def _finish_job(self, tcb: TCB):
        tcb.status = Status.PENDING
        crit = tcb.params.crit.value
        self.metrics.done[crit] += 1
        if tcb.job_release >= 0 and self.now > tcb.job_deadline:
            self.metrics.misses[crit] += 1
            self.metrics.misses_by_mode[self.mode.value] += 1
        if tcb.released_in_hi and self.now <= tcb.job_deadline:
            self.metrics.lo_done_in_hi += 1
        self.metrics.overhead_cycles += self.accel.evict(tcb.tid)
        tcb.data_in_accel = False
        self.demand.pop(tcb.tid, None)

    def _record_unblock(self, tcb: TCB, at: Optional[float] = None):
        if tcb.blocked_since is not None:
            dt = (at if at is not None else self.now) - tcb.blocked_since
            # criticality inversion: a HI-task was kept waiting by a LO-task
            # while the system was (or entered) degraded mode
            cause = tcb.blocking_cause
            if (cause == "ci?" and self.mode != Mode.LO):
                cause = "ci"
            if dt > 0:
                (self.metrics.ci_blocking if cause == "ci"
                 else self.metrics.pi_blocking).append(dt)
            tcb.blocked_since = None
            tcb.blocking_cause = None

    def _mark_blocked(self, tcb: TCB):
        if tcb.blocked_since is None:
            tcb.blocked_since = self.now
            run = self.tcbs.get(self.running) if self.running is not None \
                else None
            if (tcb.params.crit == Crit.HI and run is not None
                    and run.params.crit == Crit.LO):
                tcb.blocking_cause = "ci" if self.mode != Mode.LO else "ci?"
            else:
                tcb.blocking_cause = "pi"

    # ------------------------------------------------------------------
    def _dispatch(self, nxt: TCB):
        """Context switch to ``nxt`` (Alg. 1)."""
        cur = self.tcbs.get(self.running) if self.running is not None else None
        switch_cost = 0.0
        if cur is not None and cur.tid != nxt.tid:
            prog = self._progs[cur.tid]
            if self._instr_preempt:
                boundary = prog.next_instruction_boundary(cur.exec_cycles)
            else:  # operator
                boundary = prog.next_operator_boundary(cur.exec_cycles)
            drain = max(0.0, min(boundary, self.demand[cur.tid])
                        - cur.exec_cycles)
            cur.exec_cycles += drain
            next_eta = nxt.params.eta if self._use_banks else None
            br = self.accel.context_save(cur, int(drain), next_eta=next_eta)
            # HI-mode rule: <=1 resident LO-task -> evict on LO->LO preempt
            if (self.mode == Mode.HI and cur.params.crit == Crit.LO
                    and nxt.params.crit == Crit.LO):
                self.accel.remapper.release(cur.tid)
                cur.data_in_accel = False
            cur.status = Status.INTERRUPTED
            switch_cost += br.total
            self.metrics.save_cycles.append(br.total)
            self.metrics.cs_count += 1
        if nxt.pc > 0 or nxt.status == Status.INTERRUPTED:
            br = self.accel.context_restore(nxt)
            switch_cost += br.total
            self.metrics.restore_cycles.append(br.total)
        self.metrics.overhead_cycles += switch_cost
        self.running = nxt.tid
        nxt.status = Status.RUNNING
        nxt.pc = 1
        self._record_unblock(nxt, at=self.now + switch_cost)
        self._run_started = self.now + switch_cost
        self.accel_free_at = self.now + switch_cost
        # future events for the new running task
        rem = self.demand[nxt.tid] - nxt.exec_cycles
        self._push(self._run_started + rem, _FINISH, nxt.tid)
        p = nxt.params
        if (p.crit == Crit.HI and not nxt.budget_overrun
                and nxt.exec_cycles < p.c_lo):
            self._push(self._run_started + (p.c_lo - nxt.exec_cycles),
                       _OVERRUN, nxt.tid)

    def _schedule(self):
        """One scheduler invocation (a T_sr tick or an interrupt)."""
        if self.now < self.accel_free_at:      # CS in progress
            self._push(self._next_tick(self.accel_free_at), _TICK)
            return
        self._advance_running()
        self._mode_tick()
        # pick_next only consults residency in transition mode (the
        # "LO may run while not yet saved" rule) — skip the query otherwise
        resident = self.accel.remapper.resident_tasks() \
            if self.mode is Mode.TRANS else ()
        nxt = pick_next(self.tcbs, self.mode, resident, self.policy)
        cur = self.tcbs.get(self.running) if self.running is not None else None
        if cur is not None and cur.status != Status.RUNNING:
            cur = None
            self.running = None
        if nxt is None:
            return
        if cur is not None and nxt.tid == cur.tid:
            return
        if cur is not None and self.policy.preemption == "none":
            self._mark_blocked(nxt)            # must wait for completion
            return
        if cur is not None:
            self._mark_blocked(nxt)            # waits for drain + CS
        self._dispatch(nxt)

    # ------------------------------------------------------------------
    def run(self) -> RunMetrics:
        for tid, p in self.params.items():
            phase = self.rng.uniform(0, p.period)
            self._push(self.sampler.shift_phase(tid, phase, p.period),
                       _RELEASE, tid)
        self._run_started = 0.0
        events = self._events
        heappop = heapq.heappop
        tcbs = self.tcbs
        duration = self.duration
        while events:
            t, _, kind, tid = heappop(events)
            if t > duration:
                break
            self.now = t
            if kind == _TICK:
                self._schedule()
            elif kind == _FINISH:
                tcb = tcbs[tid]
                if self.running == tid and tcb.status == Status.RUNNING:
                    self._advance_running()
                    if tcb.exec_cycles >= self.demand.get(
                            tid, float("inf")) - 1e-6:
                        self._finish_job(tcb)
                        self.running = None
                        self._schedule()
            elif kind == _RELEASE:
                tcb = tcbs[tid]
                p = tcb.params
                rel_n = self.sampler.count_release(tid)
                self._seq += 1
                heappush(events, (t + p.period, self._seq, _RELEASE, tid))
                if tcb.status != Status.PENDING:
                    # previous job still live: count a miss once, skip release
                    if tcb.job_deadline != float("inf"):
                        self.metrics.misses[p.crit.value] += 1
                        self.metrics.misses_by_mode[self.mode.value] += 1
                        tcb.job_deadline = float("inf")
                    continue
                if self.policy.drop_lo_in_hi and p.crit == Crit.LO \
                        and self.mode != Mode.LO:
                    continue                    # AMC: LO not released
                tcb.release(t)
                self.demand[tid] = self.sampler.sample(p, rel_n, t)
                self.metrics.jobs[p.crit.value] += 1
                tcb.released_in_hi = (p.crit == Crit.LO
                                      and self.mode != Mode.LO)
                if tcb.released_in_hi:
                    self.metrics.lo_released_in_hi += 1
                self._seq += 1
                heappush(events,
                         (self._next_tick(t), self._seq, _TICK, -1))
            else:                               # _OVERRUN
                tcb = tcbs[tid]
                if self.running == tid and tcb.status == Status.RUNNING:
                    self._advance_running()
                    if tcb.exec_cycles >= tcb.params.c_lo - 1e-6 \
                            and not tcb.budget_overrun:
                        tcb.budget_overrun = True
                        if self.mode == Mode.LO:
                            self._set_mode(Mode.TRANS)   # Mode_switch
                        self._schedule()
        # tail accounting
        self.metrics.mode_cycles[self.mode.value] += \
            self.duration - self._last_mode_stamp
        for tcb in self.tcbs.values():
            if tcb.status != Status.PENDING \
                    and self.duration > tcb.job_deadline:
                self.metrics.misses[tcb.params.crit.value] += 1
        return self.metrics


def simulate(tasks, programs, policy, **kw) -> RunMetrics:
    return MCSSimulator(tasks, programs, policy, **kw).run()


# ======================================================================
# Multi-accelerator partitioned simulation (platform layer)
# ======================================================================

@dataclasses.dataclass
class MultiRunMetrics:
    """Per-instance RunMetrics plus the platform-global counters."""
    per_instance: List[RunMetrics]
    migrations: int = 0
    migration_cycles: float = 0.0
    dma_contention_cycles: float = 0.0

    @property
    def n_instances(self) -> int:
        return len(self.per_instance)

    def merged(self) -> RunMetrics:
        """Sum the per-instance metrics into one platform-wide view."""
        out = RunMetrics()
        for m in self.per_instance:
            out.pi_blocking += m.pi_blocking
            out.ci_blocking += m.ci_blocking
            out.save_cycles += m.save_cycles
            out.restore_cycles += m.restore_cycles
            for k in out.jobs:
                out.jobs[k] += m.jobs[k]
                out.done[k] += m.done[k]
                out.misses[k] += m.misses[k]
            for k in out.misses_by_mode:
                out.misses_by_mode[k] += m.misses_by_mode[k]
            for k in out.mode_cycles:
                out.mode_cycles[k] += m.mode_cycles[k]
            out.lo_released_in_hi += m.lo_released_in_hi
            out.lo_done_in_hi += m.lo_done_in_hi
            out.cs_count += m.cs_count
            out.exec_cycles += m.exec_cycles
            # migration + DMA-contention cycles are already part of the
            # per-instance overhead (charged at dispatch time); the
            # standalone counters below just break them out
            out.overhead_cycles += m.overhead_cycles
        return out

    def success(self, scope: str = "all") -> bool:
        return self.merged().success(scope)

    def survivability(self) -> float:
        return self.merged().survivability()


@dataclasses.dataclass
class _InstState:
    """Mutable per-instance runtime state of the multi-accel loop."""
    running: Optional[int] = None
    accel_free_at: float = 0.0
    run_started: float = 0.0
    last_mode_stamp: float = 0.0
    metrics: RunMetrics = dataclasses.field(default_factory=RunMetrics)


class MultiAccelSimulator:
    """Partitioned MESC over N virtual Gemmini^RT instances.

    Tasks are statically partitioned onto instances
    (``core.platform.partition``); each instance runs the single-
    accelerator MESC semantics — its own SS IV mode machine, bank
    remapper and preemption policy — under one global event clock.  Two
    cross-instance couplings make N instances more than N independent
    simulators:

      * **shared DMA**: all instances save/restore context over one
        DRAM path, so a context switch that overlaps ``k`` concurrent
        switches on other instances is stretched ``(1+k)x`` (equal
        bandwidth share), the extra cycles accounted in
        ``dma_contention_cycles``;
      * **LO migration-on-idle**: an instance that goes idle in LO-mode
        pulls the highest-priority waiting LO-task from a busy
        instance, paying the context-shipping DMA cost
        (``platform.MigrationPolicy``).

    ``n_instances=1`` degenerates to the single-accelerator semantics
    of :class:`MCSSimulator` — same rng contract, same event order, so
    identical metrics (pinned by ``tests/test_platform.py::
    TestMultiAccelSimulator::test_single_instance_matches_single_simulator``).
    """

    def __init__(self, tasks: List[TaskParams], programs: Dict[str, Program],
                 policy: Policy, *, n_instances: int = 2,
                 heuristic: str = "crit_aware",
                 duration: float = 2e7, seed: int = 0,
                 overrun_prob: float = 0.3, cf: float = 2.0,
                 dma_contention: bool = True,
                 migration=None, demand_profile: str = "sampled",
                 scenario=None):
        from repro.core.platform import AcceleratorPool, MigrationPolicy
        self.params = {t.tid: t for t in tasks}
        self.programs = programs
        self.policy = policy
        self.duration = duration
        self.rng = np.random.default_rng(seed)
        self.overrun_prob = overrun_prob
        self.cf = cf
        self.sampler = DemandSampler(
            self.rng, tasks, seed=seed, overrun_prob=overrun_prob, cf=cf,
            demand_profile=demand_profile, scenario=scenario)
        self.dma_contention = dma_contention
        self.pool = AcceleratorPool(
            n_instances, use_remapper=policy.use_banks, heuristic=heuristic,
            migration=migration or MigrationPolicy())
        self.assignment = self.pool.assign(tasks)
        from repro.core.scheduler import ModeCoordinator
        self.coordinator = ModeCoordinator(n_instances)
        self.tcbs: Dict[int, TCB] = {t.tid: TCB(params=t) for t in tasks}
        self.insts = [_InstState() for _ in range(n_instances)]
        self.multi = MultiRunMetrics(
            per_instance=[s.metrics for s in self.insts])
        self.now = 0.0
        self.demand: Dict[int, float] = {}
        self._events: List = []      # (time, seq, kind, tid-or-inst)
        self._seq = 0
        self._last_migration: Dict[int, float] = {}
        self._migration_retry_at: Optional[float] = None
        self._progs: Dict[int, Program] = {
            t.tid: programs[t.workload] for t in tasks}

    # ------------------------------------------------------------------
    def _push(self, t: float, kind: int, key: int = -1):
        self._seq += 1
        heapq.heappush(self._events, (t, self._seq, kind, key))

    def _program(self, tid: int) -> Program:
        return self._progs[tid]

    def _next_tick(self, t: float) -> float:
        return (int(t // self.policy.t_sr) + 1) * self.policy.t_sr

    def _inst_of(self, tid: int) -> int:
        return self.assignment.instance_of(tid)

    def _inst_tcbs(self, inst: int) -> Dict[int, TCB]:
        return {tid: tcb for tid, tcb in self.tcbs.items()
                if self._inst_of(tid) == inst}

    # ------------------------------------------------------------------
    def _advance_running(self, inst: int):
        st = self.insts[inst]
        if st.running is None:
            return
        tcb = self.tcbs[st.running]
        elapsed = self.now - st.run_started
        if elapsed <= 0:
            return
        tcb.exec_cycles += elapsed
        st.metrics.exec_cycles += elapsed
        self.pool.instances[inst].note_execution(
            tcb.tid, elapsed, self._program(tcb.tid))
        st.run_started = self.now

    def _set_mode(self, inst: int, mode: Mode):
        st = self.insts[inst]
        cur = self.coordinator.mode_of(inst)
        if mode is not cur:
            st.metrics.mode_cycles[cur.value] += \
                self.now - st.last_mode_stamp
            st.last_mode_stamp = self.now
            self.coordinator.set_mode(inst, mode)

    def _mode_tick(self, inst: int) -> Dict[int, TCB]:
        """Run the instance's SS IV progression; returns the instance's
        TCB view so the caller's scheduling pass can reuse it."""
        tcbs = self._inst_tcbs(inst)
        if self.coordinator.mode_of(inst) is Mode.LO:
            return tcbs              # LO only leaves via an overrun event
        accel = self.pool.instances[inst]
        resident_lo = [t for t in accel.remapper.resident_tasks()
                       if self.params.get(t) is not None
                       and self.params[t].crit == Crit.LO]
        any_active = any(t.status in ACTIVE for t in tcbs.values())
        # one shared copy of the SS IV progression (scheduler.update_mode)
        self._set_mode(inst, update_mode(self.coordinator.mode_of(inst),
                                         tcbs, resident_lo, any_active))
        return tcbs

    # ------------------------------------------------------------------
    def _finish_job(self, inst: int, tcb: TCB):
        st = self.insts[inst]
        tcb.status = Status.PENDING
        crit = tcb.params.crit.value
        st.metrics.done[crit] += 1
        if tcb.job_release >= 0 and self.now > tcb.job_deadline:
            st.metrics.misses[crit] += 1
            st.metrics.misses_by_mode[
                self.coordinator.mode_of(inst).value] += 1
        if tcb.released_in_hi and self.now <= tcb.job_deadline:
            st.metrics.lo_done_in_hi += 1
        st.metrics.overhead_cycles += self.pool.instances[inst].evict(tcb.tid)
        tcb.data_in_accel = False
        self.demand.pop(tcb.tid, None)
        # job-scoped migration: the context is discarded with the job,
        # so the task snaps back to its static partition for free
        if self.assignment.instance_of(tcb.tid) \
                != self.assignment.home_of(tcb.tid):
            self.assignment.return_home(tcb.tid)

    def _record_unblock(self, inst: int, tcb: TCB,
                        at: Optional[float] = None):
        st = self.insts[inst]
        if tcb.blocked_since is not None:
            dt = (at if at is not None else self.now) - tcb.blocked_since
            cause = tcb.blocking_cause
            if cause == "ci?" and self.coordinator.mode_of(inst) != Mode.LO:
                cause = "ci"
            if dt > 0:
                (st.metrics.ci_blocking if cause == "ci"
                 else st.metrics.pi_blocking).append(dt)
            tcb.blocked_since = None
            tcb.blocking_cause = None

    def _mark_blocked(self, inst: int, tcb: TCB):
        st = self.insts[inst]
        if tcb.blocked_since is None:
            tcb.blocked_since = self.now
            run = self.tcbs.get(st.running) if st.running is not None else None
            if (tcb.params.crit == Crit.HI and run is not None
                    and run.params.crit == Crit.LO):
                cause = "ci" if self.coordinator.mode_of(inst) != Mode.LO \
                    else "ci?"
                tcb.blocking_cause = cause
            else:
                tcb.blocking_cause = "pi"

    # ------------------------------------------------------------------
    def _concurrent_switches(self, inst: int) -> int:
        """Instances other than ``inst`` mid-context-switch right now —
        they hold a share of the single DMA path."""
        return sum(1 for i, st in enumerate(self.insts)
                   if i != inst and st.accel_free_at > self.now)

    def _dispatch(self, inst: int, nxt: TCB, extra_cost: float = 0.0):
        """Context switch on one instance (Alg. 1) with shared-DMA
        contention stretching and optional migration cycles."""
        st = self.insts[inst]
        accel = self.pool.instances[inst]
        cur = self.tcbs.get(st.running) if st.running is not None else None
        switch_cost = extra_cost
        if cur is not None and cur.tid != nxt.tid:
            prog = self._program(cur.tid)
            if self.policy.preemption == "instruction":
                boundary = prog.next_instruction_boundary(cur.exec_cycles)
            else:
                boundary = prog.next_operator_boundary(cur.exec_cycles)
            drain = max(0.0, min(boundary, self.demand[cur.tid])
                        - cur.exec_cycles)
            cur.exec_cycles += drain
            next_eta = nxt.params.eta if self.policy.use_banks else None
            br = accel.context_save(cur, int(drain), next_eta=next_eta)
            if (self.coordinator.mode_of(inst) == Mode.HI
                    and cur.params.crit == Crit.LO
                    and nxt.params.crit == Crit.LO):
                accel.remapper.release(cur.tid)
                cur.data_in_accel = False
            cur.status = Status.INTERRUPTED
            switch_cost += br.total
            st.metrics.save_cycles.append(br.total)
            st.metrics.cs_count += 1
        if nxt.pc > 0 or nxt.status == Status.INTERRUPTED:
            br = accel.context_restore(nxt)
            switch_cost += br.total
            st.metrics.restore_cycles.append(br.total)
        if self.dma_contention and switch_cost > 0:
            stretch = switch_cost * self._concurrent_switches(inst)
            switch_cost += stretch
            self.multi.dma_contention_cycles += stretch
        st.metrics.overhead_cycles += switch_cost
        st.running = nxt.tid
        nxt.status = Status.RUNNING
        nxt.pc = 1
        self._record_unblock(inst, nxt, at=self.now + switch_cost)
        st.run_started = self.now + switch_cost
        st.accel_free_at = self.now + switch_cost
        rem = self.demand[nxt.tid] - nxt.exec_cycles
        self._push(st.run_started + rem, _FINISH, nxt.tid)
        p = nxt.params
        if (p.crit == Crit.HI and not nxt.budget_overrun
                and nxt.exec_cycles < p.c_lo):
            self._push(st.run_started + (p.c_lo - nxt.exec_cycles),
                       _OVERRUN, nxt.tid)

    def _try_migrate_to(self, inst: int):
        """Pull the highest-priority waiting LO-task from a busy
        instance onto idle instance ``inst`` (migration-on-idle).
        Returns ``(tcb, ship_cycles)`` or ``None``; a candidate
        rejected only on timing grounds (min_wait / cooldown) leaves a
        retry time in ``self._migration_retry_at`` so the idle
        instance re-checks instead of sleeping past the window."""
        self._migration_retry_at = None
        mig = self.pool.migration
        if not mig.enabled:
            return None
        if mig.lo_mode_only \
                and self.coordinator.mode_of(inst) != Mode.LO:
            return None
        candidates = []
        retry_at = None
        for tid, tcb in self.tcbs.items():
            home = self._inst_of(tid)
            if home == inst or tcb.params.crit != Crit.LO:
                continue
            if tcb.status not in (Status.READY, Status.INTERRUPTED):
                continue
            if self.insts[home].running == tid:
                continue
            if self.insts[home].running is None:
                continue        # home instance is idle: it will run it
            eligible_at = max(
                tcb.job_release + mig.min_wait,
                self._last_migration.get(tid, -1e18) + mig.cooldown)
            if self.now < eligible_at:
                retry_at = eligible_at if retry_at is None \
                    else min(retry_at, eligible_at)
                continue        # home may pick it up sooner; re-check
            candidates.append(tcb)
        if mig.hi_slack_guard and candidates:
            from repro.core.isa import (ACCUM_BYTES, BANK_BYTES,
                                        DMA_BYTES_PER_CYCLE)
            stretch = self.pool.n_instances if self.dma_contention else 1
            hi_params = [t.params for t in self._inst_tcbs(inst).values()
                         if t.params.crit == Crit.HI]

            def preempt_cost(c: TCB) -> float:
                # worst case to get the migrant out of a HI-task's way:
                # the HI release can land mid-restore (ship + mvin, the
                # switch is atomic), then drain one instruction and
                # save the full working set (eta banks + accumulator)
                # back out — 4 full-working-set DMA passes, every cycle
                # stretched by full cross-instance contention
                bytes_wc = c.params.eta * BANK_BYTES + ACCUM_BYTES
                return (self._program(c.tid).max_instruction_cycles
                        + stretch * 4.0 * bytes_wc / DMA_BYTES_PER_CYCLE)

            candidates = [
                c for c in candidates
                if all(h.deadline - h.c_hi
                       > mig.slack_margin * preempt_cost(c)
                       for h in hi_params)]
        if not candidates:
            # timing-rejected tasks may become eligible later even when
            # the slack guard emptied the list — keep the retry time
            self._migration_retry_at = retry_at
            return None
        best = min(candidates, key=lambda t: t.params.priority)
        self._last_migration[best.tid] = self.now
        cycles = self.pool.migrate(best.tid, inst)
        self.multi.migrations = self.pool.migrations
        self.multi.migration_cycles += cycles
        return best, cycles

    def _schedule(self, inst: int):
        st = self.insts[inst]
        if self.now < st.accel_free_at:       # CS in progress
            self._push(self._next_tick(st.accel_free_at), _TICK, inst)
            return
        self._advance_running(inst)
        tcbs = self._mode_tick(inst)
        accel = self.pool.instances[inst]
        mode = self.coordinator.mode_of(inst)
        resident = accel.remapper.resident_tasks() \
            if mode is Mode.TRANS else ()
        nxt = pick_next(tcbs, mode, resident, self.policy)
        cur = self.tcbs.get(st.running) if st.running is not None else None
        if cur is not None and cur.status != Status.RUNNING:
            cur = None
            st.running = None
        if nxt is None and cur is None:
            migrated = self._try_migrate_to(inst)
            if migrated is not None:
                tcb, ship_cycles = migrated
                self._dispatch(inst, tcb, extra_cost=ship_cycles)
            elif self._migration_retry_at is not None:
                # a candidate becomes timing-eligible later: re-check
                # then instead of sleeping until this instance's next
                # own release
                self._push(self._next_tick(self._migration_retry_at),
                           _TICK, inst)
            return
        if nxt is None:
            return
        if cur is not None and nxt.tid == cur.tid:
            return
        if cur is not None and self.policy.preemption == "none":
            self._mark_blocked(inst, nxt)
            return
        if cur is not None:
            self._mark_blocked(inst, nxt)
        self._dispatch(inst, nxt)

    # ------------------------------------------------------------------
    def run(self) -> MultiRunMetrics:
        for tid, p in self.params.items():
            phase = self.rng.uniform(0, p.period)
            self._push(self.sampler.shift_phase(tid, phase, p.period),
                       _RELEASE, tid)
        while self._events:
            t, _, kind, key = heapq.heappop(self._events)
            if t > self.duration:
                break
            self.now = t
            if kind == _RELEASE:
                tid = key
                inst = self._inst_of(tid)
                st = self.insts[inst]
                tcb = self.tcbs[tid]
                p = tcb.params
                rel_n = self.sampler.count_release(tid)
                self._push(t + p.period, _RELEASE, tid)
                if tcb.status != Status.PENDING:
                    if tcb.job_deadline != float("inf"):
                        st.metrics.misses[p.crit.value] += 1
                        st.metrics.misses_by_mode[
                            self.coordinator.mode_of(inst).value] += 1
                        tcb.job_deadline = float("inf")
                    continue
                mode = self.coordinator.mode_of(inst)
                if self.policy.drop_lo_in_hi and p.crit == Crit.LO \
                        and mode != Mode.LO:
                    continue
                tcb.release(t)
                self.demand[tid] = self.sampler.sample(p, rel_n, t)
                st.metrics.jobs[p.crit.value] += 1
                tcb.released_in_hi = (p.crit == Crit.LO and mode != Mode.LO)
                if tcb.released_in_hi:
                    st.metrics.lo_released_in_hi += 1
                self._push(self._next_tick(t), _TICK, inst)
                # wake idle instances: their scheduler pass may pull
                # this (or another waiting) LO-task via migration-on-
                # idle — without this an instance whose own partition
                # is quiet never re-checks
                for other, ost in enumerate(self.insts):
                    if other != inst and ost.running is None:
                        self._push(self._next_tick(t), _TICK, other)
            elif kind == _FINISH:
                tid = key
                inst = self._inst_of(tid)
                st = self.insts[inst]
                tcb = self.tcbs[tid]
                if st.running == tid and tcb.status == Status.RUNNING:
                    self._advance_running(inst)
                    if tcb.exec_cycles >= self.demand.get(
                            tid, float("inf")) - 1e-6:
                        self._finish_job(inst, tcb)
                        st.running = None
                        self._schedule(inst)
            elif kind == _OVERRUN:
                tid = key
                inst = self._inst_of(tid)
                st = self.insts[inst]
                tcb = self.tcbs[tid]
                if st.running == tid and tcb.status == Status.RUNNING:
                    self._advance_running(inst)
                    if tcb.exec_cycles >= tcb.params.c_lo - 1e-6 \
                            and not tcb.budget_overrun:
                        tcb.budget_overrun = True
                        if self.coordinator.mode_of(inst) == Mode.LO:
                            self._set_mode(inst, Mode.TRANS)
                        self._schedule(inst)
            elif kind == _TICK:
                self._schedule(key)
        # tail accounting
        for inst, st in enumerate(self.insts):
            st.metrics.mode_cycles[
                self.coordinator.mode_of(inst).value] += \
                self.duration - st.last_mode_stamp
        for tcb in self.tcbs.values():
            if tcb.status != Status.PENDING \
                    and self.duration > tcb.job_deadline:
                inst = self._inst_of(tcb.tid)
                self.insts[inst].metrics.misses[tcb.params.crit.value] += 1
        return self.multi


def simulate_multi(tasks, programs, policy, **kw) -> MultiRunMetrics:
    """One partitioned multi-accelerator run (platform layer)."""
    return MultiAccelSimulator(tasks, programs, policy, **kw).run()


def simulate_batch(tasksets, programs, policy, *, seeds,
                   **kw) -> List[RunMetrics]:
    """Batch entry point: one independent simulator per (taskset, seed).

    ``seeds`` must align with ``tasksets``; pair this with
    ``taskgen.generate_taskset_batch`` so taskset ``s`` and its run share
    ``point_seed(seed0, s)`` — the engine's per-point seeding contract.
    """
    if len(tasksets) != len(seeds):
        raise ValueError(f"{len(tasksets)} tasksets vs {len(seeds)} seeds")
    return [MCSSimulator(tasks, programs, policy, seed=s, **kw).run()
            for tasks, s in zip(tasksets, seeds)]
