"""MESC core: instruction-level preemption for streaming accelerators.

The paper's contribution as a composable library:
  isa/program   — Gemmini^RT ISA + workload->instruction-stream compiler
  remapper      — scratchpad bank allocation (address remapper)
  executor      — virtual accelerator w/ config-copy buffer + context switch
  scheduler     — Alg. 1 + LO/transition/HI mode rules (+ NP/LP/AMC baselines)
  simulator     — cycle-level DES for the paper's experiments
  taskgen       — UUnifast task sets (SS VIII)
  wcrt          — response-time analysis (Eqs. 1-11) + partitioned variant
  monitor       — TCB registry + LO-WCET timers (real-executor path)
  platform      — N-instance accelerator pool, partition heuristics,
                  LO migration-on-idle (multi-accelerator scale-out)
"""
from repro.core.isa import Instruction, Op
from repro.core.program import Program, build_program, workload_library
from repro.core.remapper import AddressRemapper
from repro.core.executor import GemminiRT
from repro.core.scheduler import (Mode, ModeCoordinator, Policy, pick_next,
                                  update_mode)
from repro.core.simulator import (MCSSimulator, MultiAccelSimulator,
                                  MultiRunMetrics, RunMetrics, simulate,
                                  simulate_batch, simulate_multi)
from repro.core.task import Crit, Status, TCB, TaskParams
from repro.core.taskgen import (generate_taskset, generate_taskset_batch,
                                point_seed, uunifast)
from repro.core.wcrt import (AnalysisConstants, PartitionedSchedulability,
                             analyze, analyze_partitioned,
                             longest_instruction)
from repro.core.platform import (AcceleratorPool, Assignment,
                                 MigrationPolicy, partition, utilization)
from repro.core.monitor import TaskMonitor
