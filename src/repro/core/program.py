"""Workload -> instruction-stream compiler + cycle cost model (the
SS V.A instruction streams the SS VIII workloads execute).

A :class:`Program` is a sequence of :class:`Segment`s; each segment is a
repeating instruction pattern (the tiled-GEMM inner loop), so cycle
prefix-sums and instruction boundaries are O(1) analytic queries — the
discrete-event simulator preempts mid-stream without materializing millions
of Instruction objects.  ``instructions()`` still yields the full stream for
the real executor and Fig. 2(c) histograms.

The workload library covers the paper's benchmarks (AlexNet, MobileNet,
ResNet-50, Transformer — conv layers as im2col GEMMs) plus layer GEMMs of
the assigned architectures (reduced widths), tying the MCS half of the
system to the model half.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.isa import (DMA_SETUP_CYCLES, DMA_BYTES_PER_CYCLE, TILE_DIM,
                            CONFIG_CYCLES, Instruction, Op, instruction_cost)


@dataclasses.dataclass(frozen=True)
class Segment:
    """``repeats`` x ``pattern`` instructions, all in one operator."""
    pattern_ops: Tuple[Op, ...]
    pattern_costs: Tuple[int, ...]
    repeats: int
    operator: int

    @property
    def pattern_cycles(self) -> int:
        return sum(self.pattern_costs)

    @property
    def cycles(self) -> int:
        return self.pattern_cycles * self.repeats

    @property
    def n_instructions(self) -> int:
        return len(self.pattern_costs) * self.repeats


@dataclasses.dataclass
class Program:
    name: str
    segments: List[Segment]
    working_set_bytes: int        # peak input/weight tile residency

    def __post_init__(self):
        ends = np.cumsum([s.cycles for s in self.segments])
        self._seg_ends = ends
        self._total = int(ends[-1]) if len(ends) else 0
        op_ids = sorted({s.operator for s in self.segments})
        op_end: Dict[int, int] = {}
        for s, e in zip(self.segments, ends):
            op_end[s.operator] = int(e)
        self._operator_ends = np.asarray([op_end[o] for o in op_ids])
        # per-segment scalars precomputed once — Segment.cycles /
        # pattern_cycles are properties that re-sum on every access,
        # which dominates the boundary queries in the simulator hot loop
        self._seg_cycles = [s.cycles for s in self.segments]
        self._seg_pattern_cycles = [s.pattern_cycles for s in self.segments]

    @property
    def total_cycles(self) -> int:
        return self._total

    @property
    def n_instructions(self) -> int:
        return sum(s.n_instructions for s in self.segments)

    @property
    def max_instruction_cycles(self) -> int:
        return max(max(s.pattern_costs) for s in self.segments)

    @property
    def n_operators(self) -> int:
        return len(self._operator_ends)

    def operator_cycle_sizes(self) -> np.ndarray:
        e = self._operator_ends
        return np.diff(np.concatenate([[0], e]))

    def next_instruction_boundary(self, offset: float) -> int:
        """Smallest instruction-end cycle > offset (instruction-level
        preemption point).  O(log #segments).  Offsets beyond the program
        end wrap (overrunning jobs re-stream the workload)."""
        base = 0.0
        if offset >= self._total:
            base = (offset // self._total) * self._total
            offset = offset - base
        offset = min(max(offset, 0.0), self._total - 1e-9)
        i = int(np.searchsorted(self._seg_ends, offset, side="right"))
        seg = self.segments[i]
        seg_start = self._seg_ends[i] - self._seg_cycles[i]
        within = offset - seg_start
        pat = self._seg_pattern_cycles[i]
        rep = int(within // pat)
        rem = within - rep * pat
        acc = 0
        for c in seg.pattern_costs:
            acc += c
            if acc > rem:
                return int(base + seg_start + rep * pat + acc)
        return int(base + seg_start + (rep + 1) * pat)

    def next_operator_boundary(self, offset: float) -> int:
        """Smallest operator-end cycle > offset (limited preemption)."""
        base = 0.0
        if offset >= self._total:
            base = (offset // self._total) * self._total
            offset -= base
        e = self._operator_ends
        i = int(np.searchsorted(e, offset, side="right"))
        return int(base + e[min(i, len(e) - 1)])

    def instruction_cost_histogram(self) -> Dict[Op, np.ndarray]:
        """op -> array of (cost, count) pairs — Fig. 2(c) data."""
        acc: Dict[Op, Dict[int, int]] = {}
        for s in self.segments:
            for op, c in zip(s.pattern_ops, s.pattern_costs):
                acc.setdefault(op, {})
                acc[op][c] = acc[op].get(c, 0) + s.repeats
        return {op: np.array(sorted(d.items())) for op, d in acc.items()}

    def instructions(self, max_n: int = 10_000_000) -> Iterator[Instruction]:
        n = 0
        for s in self.segments:
            last_idx = len(s.pattern_ops) - 1
            for r in range(s.repeats):
                for j, (op, c) in enumerate(zip(s.pattern_ops,
                                                s.pattern_costs)):
                    yield Instruction(op=op, bytes=_bytes_from_cost(op, c),
                                      k=_k_from_cost(op, c),
                                      operator=s.operator,
                                      last_in_operator=(
                                          r == s.repeats - 1 and j == last_idx))
                    n += 1
                    if n >= max_n:
                        return


def _bytes_from_cost(op: Op, cost: int) -> int:
    if op in (Op.MVIN, Op.MVOUT, Op.STEP_WISE_MVIN, Op.STEP_WISE_MVOUT):
        return max(cost - DMA_SETUP_CYCLES, 1) * DMA_BYTES_PER_CYCLE
    return 0


def _k_from_cost(op: Op, cost: int) -> int:
    if op == Op.COMPUTE:
        return max(cost - 2 * TILE_DIM, 1)
    return 0


# ---------------------------------------------------------------------------
# GEMM -> tiled instruction segments
# ---------------------------------------------------------------------------

def gemm_segments(M: int, K: int, N: int, operator: int,
                  dtype_bytes: int = 1) -> List[Segment]:
    """im2col GEMM on the 16x16 systolic array, Gemmini dataflow:
    per output tile: loop_k {mvin A, mvin B, preload, compute}; mvout C."""
    tm, tk, tn = (max(1, -(-d // TILE_DIM)) for d in (M, K, N))
    tile_bytes = TILE_DIM * TILE_DIM * dtype_bytes
    mv = DMA_SETUP_CYCLES + -(-tile_bytes // DMA_BYTES_PER_CYCLE)
    comp = min(K, TILE_DIM) + 2 * TILE_DIM
    inner = Segment(
        pattern_ops=(Op.MVIN, Op.MVIN, Op.PRELOAD, Op.COMPUTE),
        pattern_costs=(mv, mv, TILE_DIM, comp),
        repeats=tm * tn * tk,
        operator=operator)
    out = Segment(
        pattern_ops=(Op.MVOUT,),
        pattern_costs=(DMA_SETUP_CYCLES
                       + -(-TILE_DIM * TILE_DIM * 4 // DMA_BYTES_PER_CYCLE),),
        repeats=tm * tn,
        operator=operator)
    return [inner, out]


def activation_segments(n_elems: int, operator: int) -> List[Segment]:
    """Non-GEMM operator (ReLU/Softmax/pooling): streamed moves."""
    n_tiles = max(1, n_elems // (TILE_DIM * TILE_DIM))
    mv = DMA_SETUP_CYCLES + TILE_DIM * TILE_DIM // DMA_BYTES_PER_CYCLE
    return [Segment(pattern_ops=(Op.MVIN, Op.MVOUT),
                    pattern_costs=(mv, mv), repeats=n_tiles,
                    operator=operator)]


def build_program(name: str, gemms: Sequence[Tuple[int, int, int]],
                  act_after: bool = True) -> Program:
    """One operator per GEMM (+ its activation), config insts up front."""
    segs: List[Segment] = [Segment(
        pattern_ops=(Op.CONFIG_LD, Op.CONFIG_ST, Op.CONFIG_EX, Op.CONFIG_NORM),
        pattern_costs=(CONFIG_CYCLES,) * 4, repeats=1, operator=0)]
    ws = 0
    for i, (M, K, N) in enumerate(gemms):
        segs += gemm_segments(M, K, N, operator=i)
        if act_after:
            segs += activation_segments(M * N, operator=i)
        ws = max(ws, (min(M, 256) * min(K, 1024)
                      + min(K, 1024) * min(N, 256)))
    return Program(name=name, segments=segs, working_set_bytes=ws)


# ---------------------------------------------------------------------------
# Workload library (paper SS III: AlexNet / MobileNet / ResNet-50 /
# Transformer) — conv layers as im2col GEMMs (M = out_h*out_w, K =
# k*k*c_in, N = c_out), batch 1, int8.
# ---------------------------------------------------------------------------

ALEXNET = [(3025, 363, 96), (729, 2400, 256), (169, 2304, 384),
           (169, 3456, 384), (169, 3456, 256), (1, 9216, 4096),
           (1, 4096, 4096), (1, 4096, 1000)]

MOBILENET = ([(12544, 27, 32)] +
             [(12544 // (4 ** (i // 2)), 9 * c, c)
              for i, c in enumerate([32, 64, 128, 128, 256, 256])] +
             [(196, 9 * 512, 512)] * 5 + [(49, 9 * 1024, 1024),
                                          (1, 1024, 1000)])

RESNET50 = ([(12544, 147, 64)] +
            [(3136, 576, 64), (3136, 64, 256)] * 3 +
            [(784, 1152, 128), (784, 128, 512)] * 4 +
            [(196, 2304, 256), (196, 256, 1024)] * 6 +
            [(49, 4608, 512), (49, 512, 2048)] * 3 + [(1, 2048, 1000)])

TRANSFORMER = [(512, 512, 512)] * 4 + [(512, 512, 2048), (512, 2048, 512)] \
    + [(512, 512, 512)] * 4 + [(512, 512, 2048), (512, 2048, 512)]

# small single-operator probes (paper's "small workloads" bucket)
SMALL_GEMM = [(128, 128, 128)]
MEDIUM_GEMM = [(512, 1024, 512)] * 3


def arch_layer_gemms(cfg: ArchConfig, seq: int = 128) -> List[Tuple[int, int, int]]:
    """One block's GEMMs for an assigned architecture (reduced seq)."""
    d, dh = cfg.d_model, cfg.dh
    g = [(seq, d, cfg.n_heads * dh), (seq, d, 2 * cfg.n_kv_heads * dh),
         (seq, cfg.n_heads * dh, d)]
    f = cfg.moe.d_expert if cfg.moe else (cfg.d_ff or d)
    g += [(seq, d, f), (seq, d, f), (seq, f, d)]
    return g


def scaled(gemms, f: float):
    return [(max(1, int(M * f)), max(1, int(K * f)), max(1, int(N * f)))
            for (M, K, N) in gemms]


def workload_library(include_archs: bool = True) -> Dict[str, Program]:
    """Paper workloads + scaled variants spanning the paper's Fig. 2(a)
    buckets: small [0,1M], medium (1M,10M], large (10M,1G] cycles."""
    lib = {
        "small_gemm": build_program("small_gemm", SMALL_GEMM),
        "medium_gemm": build_program("medium_gemm", MEDIUM_GEMM),
        "alexnet": build_program("alexnet", ALEXNET),
        "mobilenet": build_program("mobilenet", MOBILENET),
        "resnet50": build_program("resnet50", RESNET50),
        "transformer": build_program("transformer", TRANSFORMER),
        "alexnet_s": build_program("alexnet_s", scaled(ALEXNET, 0.25)),
        "resnet50_s": build_program("resnet50_s", scaled(RESNET50, 0.25)),
        "transformer_s": build_program("transformer_s",
                                       scaled(TRANSFORMER, 0.33)),
        "mobilenet_s": build_program("mobilenet_s", scaled(MOBILENET, 0.2)),
        "alexnet_xs": build_program("alexnet_xs", scaled(ALEXNET, 0.08)),
        "transformer_xs": build_program("transformer_xs",
                                        scaled(TRANSFORMER, 0.12)),
    }
    if include_archs:
        from repro.configs import ARCHS
        for name, cfg in ARCHS.items():
            lib[f"arch:{name}"] = build_program(
                f"arch:{name}", arch_layer_gemms(cfg, seq=128))
    return lib
