"""The address remapper (paper SS V.C).

Transitions Gemmini's explicit scratchpad addressing to a *semi-explicit*
form: DMA streams into the scratchpad are intercepted and redirected (via a
dynamic offset) into banks that are either partially filled and locked by
the task, or currently unlocked.  A 4 KB remapping block records
logical->physical ranges; banklock semaphores mark banks holding valid data.

The OS-visible contract: the scheduler only tracks *how many* banks a task
holds (eta_i) — which banks and at what offsets is resolved in hardware.
When local memory suffices, a context switch needs **zero scratchpad data
movement** (the next task simply locks other banks) — that is the paper's
20-30 % context-switch acceleration (Obs. 1).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.isa import BANK_BYTES, REMAP_BLOCK_BYTES, SCRATCHPAD_BANKS


@dataclasses.dataclass
class Bank:
    idx: int
    owner: Optional[int] = None      # task id holding the banklock
    used_bytes: int = 0

    @property
    def locked(self) -> bool:
        return self.owner is not None


class AddressRemapper:
    """Bank allocation + logical->physical mapping."""

    def __init__(self, n_banks: int = SCRATCHPAD_BANKS,
                 bank_bytes: int = BANK_BYTES):
        self.banks = [Bank(i) for i in range(n_banks)]
        self.bank_bytes = bank_bytes
        # remapping block: logical (tid, laddr_range) -> (bank, offset)
        self.remap_block: Dict[Tuple[int, int], Tuple[int, int]] = {}

    # -- queries ------------------------------------------------------------
    def locked_banks(self, exclude_tid: Optional[int] = None) -> int:
        return sum(1 for b in self.banks
                   if b.locked and b.owner != exclude_tid)

    def free_banks(self) -> int:
        return sum(1 for b in self.banks if not b.locked)

    def banks_of(self, tid: int) -> List[int]:
        return [b.idx for b in self.banks if b.owner == tid]

    def resident_bytes(self, tid: int) -> int:
        return sum(b.used_bytes for b in self.banks if b.owner == tid)

    def resident_tasks(self) -> List[int]:
        return sorted({b.owner for b in self.banks if b.locked})

    def fits(self, eta: int, exclude_tid: Optional[int] = None) -> bool:
        """Paper Alg.1 line 35: next->banks + locked <= total."""
        return eta + self.locked_banks(exclude_tid) <= len(self.banks)

    # -- DMA write interception (Fig. 5.b/e) ---------------------------------
    def write(self, tid: int, laddr: int, nbytes: int,
              strict: bool = False) -> int:
        """Route a DMA write; returns the physical bank.  Fills a partially
        used locked bank of this task first, else locks a free bank.  When
        the scratchpad is contended the write saturates (data stays in
        DRAM) unless ``strict``."""
        remaining = nbytes
        last_bank = -1
        while remaining > 0:
            bank = next((b for b in self.banks
                         if b.owner == tid and b.used_bytes < self.bank_bytes),
                        None)
            if bank is None:
                bank = next((b for b in self.banks if not b.locked), None)
                if bank is None:
                    if strict:
                        raise MemoryError(
                            f"scratchpad exhausted for task {tid}")
                    return last_bank
                bank.owner = tid
                bank.used_bytes = 0
            take = min(remaining, self.bank_bytes - bank.used_bytes)
            self.remap_block[(tid, laddr)] = (bank.idx, bank.used_bytes)
            bank.used_bytes += take
            remaining -= take
            laddr += take
            last_bank = bank.idx
        return last_bank

    def read(self, tid: int, laddr: int) -> Optional[Tuple[int, int]]:
        """Consult the remapping block (Fig. 5.c/d)."""
        return self.remap_block.get((tid, laddr))

    # -- context-switch support ----------------------------------------------
    def release(self, tid: int):
        """Deactivate banklocks + flush the task's ranges (task end/evict)."""
        for b in self.banks:
            if b.owner == tid:
                b.owner = None
                b.used_bytes = 0
        self.remap_block = {k: v for k, v in self.remap_block.items()
                            if k[0] != tid}

    def snapshot(self, tid: int) -> dict:
        """Remap-block content shipped to DRAM on context save."""
        return {k: v for k, v in self.remap_block.items() if k[0] == tid}

    def restore(self, tid: int, snap: dict, nbytes: int):
        """Re-load data on context restore into freshly allocated banks;
        the remapping block is updated for the new physical placement."""
        for (t, laddr) in list(snap):
            pass  # logical ranges re-established by the writes below
        if nbytes > 0:
            self.write(tid, 0, nbytes)

    @property
    def remap_block_bytes(self) -> int:
        return REMAP_BLOCK_BYTES
