"""The address remapper (paper SS V.C).

Transitions Gemmini's explicit scratchpad addressing to a *semi-explicit*
form: DMA streams into the scratchpad are intercepted and redirected (via a
dynamic offset) into banks that are either partially filled and locked by
the task, or currently unlocked.  A 4 KB remapping block records
logical->physical ranges; banklock semaphores mark banks holding valid data.

The OS-visible contract: the scheduler only tracks *how many* banks a task
holds (eta_i) — which banks and at what offsets is resolved in hardware.
When local memory suffices, a context switch needs **zero scratchpad data
movement** (the next task simply locks other banks) — that is the paper's
20-30 % context-switch acceleration (Obs. 1).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from repro.core.isa import BANK_BYTES, REMAP_BLOCK_BYTES, SCRATCHPAD_BANKS


@dataclasses.dataclass
class Bank:
    idx: int
    owner: Optional[int] = None      # task id holding the banklock
    used_bytes: int = 0

    @property
    def locked(self) -> bool:
        return self.owner is not None


class AddressRemapper:
    """Bank allocation + logical->physical mapping."""

    def __init__(self, n_banks: int = SCRATCHPAD_BANKS,
                 bank_bytes: int = BANK_BYTES):
        self.banks = [Bank(i) for i in range(n_banks)]
        self.bank_bytes = bank_bytes
        # remapping block: logical (tid, laddr_range) -> (bank, offset)
        self.remap_block: Dict[Tuple[int, int], Tuple[int, int]] = {}
        # incremental per-owner aggregates (the scheduler's hot queries);
        # write()/release() are the only mutators, so these stay exact
        self._owner_banks: Dict[int, int] = {}
        self._owner_bytes: Dict[int, int] = {}
        # free bank indices as a min-heap (lowest-index-first, matching
        # the original first-free scan) + each task's single partial bank
        self._free_heap: List[int] = list(range(n_banks))
        self._partial: Dict[int, Bank] = {}
        self._keys_by_tid: Dict[int, List[Tuple[int, int]]] = {}

    # -- queries ------------------------------------------------------------
    def locked_banks(self, exclude_tid: Optional[int] = None) -> int:
        n = len(self.banks) - len(self._free_heap)
        if exclude_tid is not None:
            n -= self._owner_banks.get(exclude_tid, 0)
        return n

    def free_banks(self) -> int:
        return len(self._free_heap)

    def banks_of(self, tid: int) -> List[int]:
        return [b.idx for b in self.banks if b.owner == tid]

    def resident_bytes(self, tid: int) -> int:
        return self._owner_bytes.get(tid, 0)

    def resident_tasks(self) -> List[int]:
        return sorted(self._owner_banks)

    def fits(self, eta: int, exclude_tid: Optional[int] = None) -> bool:
        """Paper Alg.1 line 35: next->banks + locked <= total."""
        return eta + self.locked_banks(exclude_tid) <= len(self.banks)

    # -- DMA write interception (Fig. 5.b/e) ---------------------------------
    def write(self, tid: int, laddr: int, nbytes: int,
              strict: bool = False) -> int:
        """Route a DMA write; returns the physical bank.  Fills a partially
        used locked bank of this task first, else locks a free bank.  When
        the scratchpad is contended the write saturates (data stays in
        DRAM) unless ``strict``.

        The remapping block records one logical->physical entry per
        written range (keyed by the range's starting ``laddr``); the
        per-bank spill points are hardware-internal and not observable
        through :meth:`read`.
        """
        remaining = nbytes
        last_bank = -1
        bb = self.bank_bytes
        bank = self._partial.get(tid)     # a task has <=1 partial bank
        entry = None
        while remaining > 0:
            if bank is None:
                if not self._free_heap:
                    self._partial.pop(tid, None)
                    break
                bank = self.banks[heapq.heappop(self._free_heap)]
                bank.owner = tid
                bank.used_bytes = 0
                self._owner_banks[tid] = self._owner_banks.get(tid, 0) + 1
            take = min(remaining, bb - bank.used_bytes)
            if entry is None:
                entry = (bank.idx, bank.used_bytes)
            bank.used_bytes += take
            remaining -= take
            last_bank = bank.idx
            if bank.used_bytes >= bb:
                bank = None               # full: next round grabs a free one
        else:
            if bank is not None:
                self._partial[tid] = bank
            else:
                self._partial.pop(tid, None)
        if entry is not None:
            self._owner_bytes[tid] = self._owner_bytes.get(tid, 0) \
                + (nbytes - remaining)
            key = (tid, laddr)
            if key not in self.remap_block:
                self._keys_by_tid.setdefault(tid, []).append(key)
            self.remap_block[key] = entry
        if remaining > 0 and strict:
            raise MemoryError(f"scratchpad exhausted for task {tid}")
        return last_bank

    def read(self, tid: int, laddr: int) -> Optional[Tuple[int, int]]:
        """Consult the remapping block (Fig. 5.c/d)."""
        return self.remap_block.get((tid, laddr))

    # -- context-switch support ----------------------------------------------
    def release(self, tid: int):
        """Deactivate banklocks + flush the task's ranges (task end/evict)."""
        if tid not in self._owner_banks:
            return
        for b in self.banks:
            if b.owner == tid:
                b.owner = None
                b.used_bytes = 0
                heapq.heappush(self._free_heap, b.idx)
        self._owner_banks.pop(tid)
        self._owner_bytes.pop(tid, None)
        self._partial.pop(tid, None)
        rb = self.remap_block
        for k in self._keys_by_tid.pop(tid, ()):
            rb.pop(k, None)

    def snapshot(self, tid: int) -> dict:
        """Remap-block content shipped to DRAM on context save."""
        rb = self.remap_block
        return {k: rb[k] for k in self._keys_by_tid.get(tid, ())}

    def restore(self, tid: int, snap: dict, nbytes: int):
        """Re-load data on context restore into freshly allocated banks;
        the remapping block entry is re-established by the write (the
        saved ``snap`` records the old physical placement, which the
        new allocation supersedes)."""
        del snap
        if nbytes > 0:
            self.write(tid, 0, nbytes)

    @property
    def remap_block_bytes(self) -> int:
        return REMAP_BLOCK_BYTES
