"""Fig. 11 (extension) — partitioned MESC across N virtual accelerators.

Beyond the paper: the instruction-level context-switch mechanism scaled
out to an accelerator *pool* (docs/scheduling.md).  One engine
`FuncSweep` over instances x total-utilisation x partition heuristic x
{MESC, non-preemptive}, each point a full multi-instance DES run with
shared-DMA contention and LO migration-on-idle
(``repro.experiments.multiacc:simulate_multiacc_point``).

Report: per (policy, N, heuristic, U) success ratios, mean/max blocking,
and the headline — on N=4 instances MESC keeps worst-case inversions
bounded by one instruction (+CS) while the non-preemptive pool still
exposes whole-workload blocking, which no amount of extra instances
resolves.
"""
from __future__ import annotations

from repro.core.simulator import (MULTI_SIM_SEMANTICS_VERSION,
                                  SIM_SEMANTICS_VERSION)
from repro.experiments import Campaign, FuncSweep, frac, group_rows
from benchmarks.common import DEFAULT_SETS, Timer, emit

SYSTEMS = ("mesc", "np")
HEURISTICS = ("first_fit", "worst_fit", "crit_aware")
INSTANCES = (1, 2, 4)
UTILS_PER_INST = (0.6, 0.8)          # total U = u_per_inst * N


def sweep(full: bool = False) -> FuncSweep:
    n_sets = 1000 if full else DEFAULT_SETS
    items = []
    for policy in SYSTEMS:
        for n in INSTANCES:
            for heur in HEURISTICS:
                for u_norm in UTILS_PER_INST:
                    for s in range(n_sets):
                        items.append(dict(
                            policy=policy, u=round(u_norm * n, 4),
                            n_instances=n, heuristic=heur, set_index=s,
                            # both salts: the multi path reuses the
                            # shared executor/scheduler/taskgen code
                            # tracked by SIM_SEMANTICS_VERSION
                            sim_v=[SIM_SEMANTICS_VERSION,
                                   MULTI_SIM_SEMANTICS_VERSION]))
    return FuncSweep.over(
        "fig11_multiacc",
        "repro.experiments.multiacc:simulate_multiacc_point", items)


def main(full: bool = False, engine: str = "event", devices=None,
         **campaign_kw):
    # engine/devices: accepted for run.py uniformity; this figure has no
    # single-accelerator DES sweep for the vec backend to run
    del engine
    sw = sweep(full)
    with Timer() as t:
        rows = Campaign(sw, **campaign_kw).collect()
    cells = group_rows(rows, "policy", "n_instances", "heuristic", "u")
    print("policy,n_instances,heuristic,u_total,success_all,success_hi,"
          "block_mean,block_max,migrations,dma_cycles")
    res = {}
    for key, cell in sorted(cells.items()):
        pol, n, heur, u = key
        bsum = sum(r["pi_sum"] + r["ci_sum"] for r in cell)
        bn = sum(r["pi_n"] + r["ci_n"] for r in cell)
        stats = dict(
            success_all=frac(cell, "success_all"),
            success_hi=frac(cell, "success_hi"),
            block_mean=bsum / bn if bn else 0.0,
            block_max=max(r["block_max"] for r in cell),
            migrations=sum(r["migrations"] for r in cell),
            dma=sum(r["dma_contention_cycles"] for r in cell),
        )
        res[key] = stats
        print(f"{pol},{n},{heur},{u},{stats['success_all']:.3f},"
              f"{stats['success_hi']:.3f},{stats['block_mean']:.0f},"
              f"{stats['block_max']:.0f},{stats['migrations']},"
              f"{stats['dma']:.0f}")
    # headline: inversion resolution at N=4 (crit_aware, u/inst=0.6;
    # the 0.8/inst column is the saturation stress point)
    key4 = ("mesc", 4, "crit_aware", round(0.6 * 4, 4))
    np4 = ("np", 4, "crit_aware", round(0.6 * 4, 4))
    speedup = res[np4]["block_max"] / max(res[key4]["block_max"], 1.0)
    emit("fig11_multiacc",
         t.seconds * 1e6 / max(len(rows), 1),
         f"N4_maxblock_np/mesc={speedup:.0f}x;"
         f"N4_mesc_hi={res[key4]['success_hi']:.2f};"
         f"N4_np_hi={res[np4]['success_hi']:.2f}")
    return res


if __name__ == "__main__":
    main()
