"""Fig. 7 — context-switch cycles and inversion durations vs utilisation.

Columns per U: C-Save / C-Restore (MESC), the same without the bank model
(Obs. 1: +4000-6000 cycles), Pi-I / Ci-I under MESC, and Pi-I / Ci-I with
the context-switch mechanism removed (non-preemptive) — from which the
paper's ~250x / ~300x accelerations follow (Obs. 2).

Declared as one campaign-engine sweep (3 policies x 6 utilisations);
aggregation uses pooled sums, matching the legacy concatenated-list
means exactly.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import Policy
from repro.experiments import Campaign, Sweep, group_rows, pooled_mean
from benchmarks.common import DEFAULT_SETS, Timer, UTILS, emit

POLICIES = (Policy.mesc(),
            dataclasses.replace(Policy.mesc(use_banks=False),
                                name="mesc-noB"),
            Policy.non_preemptive())


def sweep(full: bool = False, engine: str = "event",
          devices=None) -> Sweep:
    n_sets = max((1000 if full else DEFAULT_SETS) // 5, 20)
    return Sweep(name="fig7_blocking", policies=POLICIES, utils=UTILS,
                 n_sets=n_sets, engine=engine, devices=devices)


def _pm(rows, name):
    """pooled_mean with this figure's legacy empty-cell convention:
    a cell with zero events reads 0 cycles here (the table's columns
    are cycle counts and the speedup guard divides by max(x, 1.0),
    which NaN would poison — max(NaN, 1.0) is NaN in Python)."""
    v = pooled_mean(rows, name)
    return 0.0 if math.isnan(v) else v


def main(full: bool = False, engine: str = "event", devices=None,
         **campaign_kw):
    with Timer() as t:
        rows = Campaign(sweep(full, engine, devices),
                        **campaign_kw).collect()
    cells = group_rows(rows, "policy", "u")
    print("u,c_save,c_restore,c_save_noB,c_restore_noB,"
          "pi_mesc,ci_mesc,pi_noCS,ci_noCS,pi_speedup,ci_speedup")
    ratios = []
    for u in UTILS:
        ms = cells[("mesc", u)]
        mb = cells[("mesc-noB", u)]
        mn = cells[("np", u)]
        row = {
            "c_save": _pm(ms, "save"),
            "c_restore": _pm(ms, "restore"),
            "c_save_noB": _pm(mb, "save"),
            "c_restore_noB": _pm(mb, "restore"),
            "pi_mesc": _pm(ms, "pi"),
            "ci_mesc": _pm(ms, "ci"),
            "pi_noCS": _pm(mn, "pi"),
            "ci_noCS": _pm(mn, "ci"),
        }
        pi_sp = row["pi_noCS"] / max(row["pi_mesc"], 1.0)
        ci_sp = row["ci_noCS"] / max(row["ci_mesc"], 1.0)
        ratios.append((pi_sp, ci_sp, row["c_save_noB"] - row["c_save"]))
        print(f"{u}," + ",".join(f"{row[k]:.0f}" for k in
                                 ("c_save", "c_restore", "c_save_noB",
                                  "c_restore_noB", "pi_mesc", "ci_mesc",
                                  "pi_noCS", "ci_noCS"))
              + f",{pi_sp:.0f},{ci_sp:.0f}")
    pi_all = np.mean([r[0] for r in ratios])
    ci_all = np.mean([r[1] for r in ratios])
    dbank = np.mean([r[2] for r in ratios])
    emit("fig7_blocking", t.seconds * 1e6 / (len(UTILS) * 3),
         f"pi_speedup={pi_all:.0f}x;ci_speedup={ci_all:.0f}x;"
         f"bank_saving={dbank:.0f}cyc")
    return {"pi_speedup": pi_all, "ci_speedup": ci_all,
            "bank_saving_cycles": dbank}


if __name__ == "__main__":
    main()
