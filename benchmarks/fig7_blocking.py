"""Fig. 7 — context-switch cycles and inversion durations vs utilisation.

Columns per U: C-Save / C-Restore (MESC), the same without the bank model
(Obs. 1: +4000-6000 cycles), Pi-I / Ci-I under MESC, and Pi-I / Ci-I with
the context-switch mechanism removed (non-preemptive) — from which the
paper's ~250x / ~300x accelerations follow (Obs. 2).
"""
from __future__ import annotations

import numpy as np

from repro.core import Policy
from benchmarks.common import DEFAULT_SETS, Timer, UTILS, emit, mean, run_many


def main(full: bool = False):
    n_sets = 1000 if full else DEFAULT_SETS
    n_sets_blocking = max(n_sets // 5, 20)
    print("u,c_save,c_restore,c_save_noB,c_restore_noB,"
          "pi_mesc,ci_mesc,pi_noCS,ci_noCS,pi_speedup,ci_speedup")
    ratios = []
    with Timer() as t:
        for u in UTILS:
            ms = run_many(Policy.mesc(), n_sets=n_sets_blocking, u=u)
            mb = run_many(Policy.mesc(use_banks=False),
                          n_sets=n_sets_blocking, u=u)
            mn = run_many(Policy.non_preemptive(), n_sets=n_sets_blocking,
                          u=u)
            row = {
                "c_save": mean(sum((m.save_cycles for m in ms), [])),
                "c_restore": mean(sum((m.restore_cycles for m in ms), [])),
                "c_save_noB": mean(sum((m.save_cycles for m in mb), [])),
                "c_restore_noB": mean(sum((m.restore_cycles for m in mb), [])),
                "pi_mesc": mean(sum((m.pi_blocking for m in ms), [])),
                "ci_mesc": mean(sum((m.ci_blocking for m in ms), [])),
                "pi_noCS": mean(sum((m.pi_blocking for m in mn), [])),
                "ci_noCS": mean(sum((m.ci_blocking for m in mn), [])),
            }
            pi_sp = row["pi_noCS"] / max(row["pi_mesc"], 1.0)
            ci_sp = row["ci_noCS"] / max(row["ci_mesc"], 1.0)
            ratios.append((pi_sp, ci_sp,
                           row["c_save_noB"] - row["c_save"]))
            print(f"{u}," + ",".join(f"{row[k]:.0f}" for k in
                                     ("c_save", "c_restore", "c_save_noB",
                                      "c_restore_noB", "pi_mesc", "ci_mesc",
                                      "pi_noCS", "ci_noCS"))
                  + f",{pi_sp:.0f},{ci_sp:.0f}")
    pi_all = np.mean([r[0] for r in ratios])
    ci_all = np.mean([r[1] for r in ratios])
    dbank = np.mean([r[2] for r in ratios])
    emit("fig7_blocking", t.seconds * 1e6 / (len(UTILS) * 3),
         f"pi_speedup={pi_all:.0f}x;ci_speedup={ci_all:.0f}x;"
         f"bank_saving={dbank:.0f}cyc")
    return {"pi_speedup": pi_all, "ci_speedup": ci_all,
            "bank_saving_cycles": dbank}


if __name__ == "__main__":
    main()
