"""Fig. 12 (extension) — MESC as a serving-SLO result under traffic.

The paper's 250x/300x inversion-resolution claim, restated as what it
is in production terms: with LO traffic saturating the accelerator
pool (open-loop offered load ``lo_load`` x capacity), MESC's
instruction-level preemption keeps HI-request tail latency (p99/p999)
and deadline-miss rate bounded near the no-contention floor, while the
non-preemptive baseline's HI tail collapses to O(one whole LO request)
— no amount of queueing discipline above a non-preemptive accelerator
fixes that.

One engine ``FuncSweep`` over {mesc, np} x LO arrival process
{poisson, heavy_tail} x offered load {0.7, 1.2} x set index, each
point one deterministic virtual-clock serving run
(``repro.serving.fig12:simulate_fig12_point``) — common random
numbers across policies, so every row pair is a pure policy effect.
Campaign-cached and byte-identical on replay: CI's serving-smoke job
runs the smoke corpus twice (second pass uncached) and diffs the
``--out`` JSON byte-for-byte.

    PYTHONPATH=src python -m benchmarks.fig12_serving_slo [--full]
        [--smoke] [--gate] [--out slo.json] [--no-cache]
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.serving.fig12 import SERVING_SEMANTICS_VERSION
from repro.serving.slo import nearest_rank
from repro.experiments import Campaign, FuncSweep, group_rows
from benchmarks.common import Timer, emit

SYSTEMS = ("mesc", "np")
ARRIVALS = ("poisson", "heavy_tail")
LO_LOADS = (0.7, 1.2)                 # below / beyond pool capacity
LANES = 2
DEFAULT_SETS = 25                     # --full: 100; --smoke: 2
HI_DEADLINE_S = 0.5


def sweep(n_sets: int, *, n_lo: int = 64, n_hi: int = 24) -> FuncSweep:
    items = []
    for policy in SYSTEMS:
        for arrivals in ARRIVALS:
            for lo_load in LO_LOADS:
                for s in range(n_sets):
                    items.append(dict(
                        policy=policy, arrivals=arrivals,
                        lo_load=lo_load, lanes=LANES, set_index=s,
                        n_lo=n_lo, n_hi=n_hi,
                        hi_deadline_s=HI_DEADLINE_S,
                        serving_v=SERVING_SEMANTICS_VERSION))
    return FuncSweep.over(
        "fig12_serving_slo",
        "repro.serving.fig12:simulate_fig12_point", items)


def _cell_stats(cell):
    """Pool the per-point SLO rows of one (policy, arrivals, load)
    cell: true pooled HI tails from the per-request latencies, pooled
    miss rate / goodput from the counts."""
    lat = sorted(v for r in cell for v in r["hi_latencies_s"])
    n_hi = sum(r["hi_n"] for r in cell)
    missed = sum(round(r["hi_miss_rate"] * r["hi_n"]) for r in cell)
    return dict(
        hi_p50=nearest_rank(lat, 0.50),
        hi_p99=nearest_rank(lat, 0.99),
        hi_p999=nearest_rank(lat, 0.999),
        hi_miss=missed / n_hi if n_hi else None,
        lo_p50=(sorted(r["lo_p50_latency_s"] for r in cell)
                [len(cell) // 2]),
        goodput=sum(r["goodput_rps"] for r in cell) / len(cell),
        preempts=sum(r["hi_preemptions"] + r["lo_preemptions"]
                     for r in cell),
    )


def main(full: bool = False, engine: str = "event", devices=None,
         smoke: bool = False, out: str = None, gate: bool = False,
         **campaign_kw):
    # engine/devices: accepted for run.py uniformity; serving runs on
    # the virtual clock, not a DES backend
    del engine, devices
    if smoke:
        sw = sweep(2, n_lo=24, n_hi=8)
    else:
        sw = sweep(100 if full else DEFAULT_SETS)
    with Timer() as t:
        rows = Campaign(sw, **campaign_kw).collect()
    if out:                           # canonical byte-stable dump (CI)
        with open(out, "w") as f:
            json.dump(rows, f, sort_keys=True, separators=(",", ":"))
        print(f"# wrote {len(rows)} rows to {out}", file=sys.stderr)
    cells = group_rows(rows, "policy", "arrivals", "lo_load")
    print("policy,arrivals,lo_load,hi_p50,hi_p99,hi_p999,hi_miss,"
          "lo_p50,goodput_rps")
    res = {}
    for key, cell in sorted(cells.items()):
        pol, arr, load = key
        s = _cell_stats(cell)
        res[key] = s
        print(f"{pol},{arr},{load},{s['hi_p50']:.4f},{s['hi_p99']:.4f},"
              f"{s['hi_p999']:.4f},{s['hi_miss']:.3f},{s['lo_p50']:.2f},"
              f"{s['goodput']:.2f}")
    # headline: HI tail at saturation (poisson, max offered load)
    sat = max(LO_LOADS)
    mesc = res[("mesc", "poisson", sat)]
    np_ = res[("np", "poisson", sat)]
    ratio = np_["hi_p99"] / max(mesc["hi_p99"], 1e-9)
    emit("fig12_serving_slo",
         t.seconds * 1e6 / max(len(rows), 1),
         f"sat_hi_p99_np/mesc={ratio:.1f}x;"
         f"mesc_hi_miss={mesc['hi_miss']:.3f};"
         f"np_hi_miss={np_['hi_miss']:.3f}")
    if gate:
        ok = (mesc["hi_p99"] < np_["hi_p99"]
              and mesc["hi_p999"] < np_["hi_p999"]
              and mesc["hi_miss"] <= np_["hi_miss"])
        if not ok:
            raise SystemExit(
                f"fig12 gate FAILED: mesc hi_p99={mesc['hi_p99']:.4f} "
                f"p999={mesc['hi_p999']:.4f} miss={mesc['hi_miss']:.3f} "
                f"vs np hi_p99={np_['hi_p99']:.4f} "
                f"p999={np_['hi_p999']:.4f} miss={np_['hi_miss']:.3f}")
        print("# fig12 gate OK: MESC bounds the HI tail under "
              "LO saturation", file=sys.stderr)
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale set count (100 per cell)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny 2-set corpus (CI serving-smoke job)")
    ap.add_argument("--gate", action="store_true",
                    help="exit non-zero unless MESC bounds the HI tail "
                         "below the non-preemptive baseline")
    ap.add_argument("--out", default=None,
                    help="write the raw SLO rows as canonical JSON "
                         "(byte-identical across deterministic reruns)")
    ap.add_argument("--no-cache", action="store_true",
                    help="always re-simulate; write nothing to disk")
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--cache-dir", default=None)
    args = ap.parse_args()
    main(full=args.full, smoke=args.smoke, out=args.out, gate=args.gate,
         workers=args.workers, cache_dir=args.cache_dir,
         use_cache=not args.no_cache)
