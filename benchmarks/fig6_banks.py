"""Fig. 6 analogue — accelerator local-memory allocation method (SS VII.C).

The paper measures the minimal scratchpad capacity that preserves each
workload's optimal execution time (eta_i banks for task tau_i).  Here:
per workload, the measured working set, the derived eta, and the resulting
context-switch cost when the next task fits alongside (zero-copy) vs when
the scratchpad must be evacuated — the quantitative basis for the bank
allocator's Obs. 1 speedup.
"""
from __future__ import annotations

from repro.core import GemminiRT, Crit, TaskParams, TCB
from repro.core.isa import BANK_BYTES, SCRATCHPAD_BANKS
from repro.core.program import workload_library
from repro.core.taskgen import eta_for
from benchmarks.common import Timer, emit


def main(full: bool = False):
    lib = workload_library(include_archs=True)
    print("workload,working_set_KB,eta_banks,save_fit_cycles,"
          "save_evict_cycles,zero_copy")
    n_zero = 0
    rows = 0
    with Timer() as t:
        for name, prog in sorted(lib.items()):
            eta = eta_for(prog)
            # context save when the next task fits alongside
            acc = GemminiRT()
            p = TaskParams(0, 0, 1e9, 1e9, prog.total_cycles,
                           2 * prog.total_cycles, Crit.LO, eta,
                           workload=name)
            tcb = TCB(params=p)
            acc.note_execution(0, prog.total_cycles, prog)
            fit_eta = max(SCRATCHPAD_BANKS - eta, 0)
            br_fit = acc.context_save(tcb, drain_cycles=0, next_eta=fit_eta)
            # and when it does not (full evacuation)
            acc2 = GemminiRT()
            tcb2 = TCB(params=p)
            acc2.note_execution(0, prog.total_cycles, prog)
            br_evict = acc2.context_save(tcb2, drain_cycles=0,
                                         next_eta=SCRATCHPAD_BANKS)
            zero = br_fit.scratchpad == 0
            n_zero += zero
            rows += 1
            print(f"{name},{prog.working_set_bytes // 1024},{eta},"
                  f"{br_fit.total},{br_evict.total},{zero}")
    emit("fig6_banks", t.seconds * 1e6 / max(rows, 1),
         f"zero_copy_possible={n_zero}/{rows};bank={BANK_BYTES // 1024}KB")


if __name__ == "__main__":
    main()
