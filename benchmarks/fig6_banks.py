"""Fig. 6 analogue — accelerator local-memory allocation method (SS VII.C).

The paper measures the minimal scratchpad capacity that preserves each
workload's optimal execution time (eta_i banks for task tau_i).  Here:
per workload, the measured working set, the derived eta, and the resulting
context-switch cost when the next task fits alongside (zero-copy) vs when
the scratchpad must be evacuated — the quantitative basis for the bank
allocator's Obs. 1 speedup.

Declared as a campaign-engine FuncSweep: one cached point per workload.
"""
from __future__ import annotations

from repro.core import GemminiRT, Crit, TaskParams, TCB
from repro.core.isa import BANK_BYTES, SCRATCHPAD_BANKS
from repro.core.taskgen import eta_for
from repro.experiments import Campaign, FuncSweep
from repro.experiments.runner import cached_library
from benchmarks.common import Timer, emit

COLUMNS = ("workload", "working_set_KB", "eta_banks", "save_fit_cycles",
           "save_evict_cycles", "zero_copy")


def bank_row(workload: str) -> dict:
    """Engine point: save cost with/without room for the next task."""
    prog = cached_library("all")[workload]
    eta = eta_for(prog)
    p = TaskParams(0, 0, 1e9, 1e9, prog.total_cycles,
                   2 * prog.total_cycles, Crit.LO, eta, workload=workload)
    # context save when the next task fits alongside
    acc = GemminiRT()
    tcb = TCB(params=p)
    acc.note_execution(0, prog.total_cycles, prog)
    br_fit = acc.context_save(tcb, drain_cycles=0,
                              next_eta=max(SCRATCHPAD_BANKS - eta, 0))
    # and when it does not (full evacuation)
    acc2 = GemminiRT()
    tcb2 = TCB(params=p)
    acc2.note_execution(0, prog.total_cycles, prog)
    br_evict = acc2.context_save(tcb2, drain_cycles=0,
                                 next_eta=SCRATCHPAD_BANKS)
    return {"workload": workload,
            "working_set_KB": prog.working_set_bytes // 1024,
            "eta_banks": eta,
            "save_fit_cycles": br_fit.total,
            "save_evict_cycles": br_evict.total,
            "zero_copy": bool(br_fit.scratchpad == 0)}


def sweep(full: bool = False) -> FuncSweep:
    names = sorted(cached_library("all"))
    return FuncSweep.over("fig6_banks", "benchmarks.fig6_banks:bank_row",
                          [{"workload": n} for n in names])


def main(full: bool = False, engine: str = "event", devices=None,
         **campaign_kw):
    # engine/devices: accepted for run.py uniformity; this figure has no
    # single-accelerator DES sweep for the vec backend to run
    del engine
    with Timer() as t:
        rows = Campaign(sweep(full), **campaign_kw).collect()
    print(",".join(COLUMNS))
    for r in rows:
        print(",".join(str(r[c]) for c in COLUMNS))
    n_zero = sum(r["zero_copy"] for r in rows)
    emit("fig6_banks", t.seconds * 1e6 / max(len(rows), 1),
         f"zero_copy_possible={n_zero}/{len(rows)};"
         f"bank={BANK_BYTES // 1024}KB")
    return rows


if __name__ == "__main__":
    main()
