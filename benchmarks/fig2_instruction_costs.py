"""Fig. 2 — execution cycles of workloads / operators / instructions.

Reproduces the three panels: (a) whole-workload cycles per size bucket,
(b) operator-level cycles, (c) per-instruction cycles by type — the
quantitative motivation for instruction-level preemption.

Declared as a campaign-engine FuncSweep: one cached point per workload.
"""
from __future__ import annotations

import numpy as np

from repro.experiments import Campaign, FuncSweep
from repro.experiments.runner import cached_library
from benchmarks.common import Timer, emit

COLUMNS = ("workload", "bucket", "total_cycles", "op_max", "op_mean",
           "inst_max", "inst_mean")


def workload_row(workload: str) -> dict:
    """Engine point: instruction-cost statistics of one workload."""
    prog = cached_library("all")[workload]
    ops = prog.operator_cycle_sizes()
    hist = prog.instruction_cost_histogram()
    inst_mean = (sum(c * n for arr in hist.values() for c, n in arr)
                 / max(prog.n_instructions, 1))
    bucket = ("small" if prog.total_cycles <= 1e6 else
              "medium" if prog.total_cycles <= 1e7 else "large")
    return {"workload": workload, "bucket": bucket,
            "total_cycles": int(prog.total_cycles),
            "op_max": int(ops.max()), "op_mean": int(ops.mean()),
            "inst_max": int(prog.max_instruction_cycles),
            "inst_mean": round(inst_mean, 1)}


def sweep(full: bool = False) -> FuncSweep:
    names = sorted(cached_library("all"))
    return FuncSweep.over("fig2_instruction_costs",
                          "benchmarks.fig2_instruction_costs:workload_row",
                          [{"workload": n} for n in names])


def main(full: bool = False, engine: str = "event", devices=None,
         **campaign_kw):
    # engine/devices: accepted for run.py uniformity; this figure has no
    # single-accelerator DES sweep for the vec backend to run
    del engine
    with Timer() as t:
        rows = Campaign(sweep(full), **campaign_kw).collect()
    print(",".join(COLUMNS))
    for r in rows:
        print(",".join(str(r[c]) for c in COLUMNS))
    tot = np.array([r["total_cycles"] for r in rows], float)
    opm = np.array([r["op_max"] for r in rows], float)
    im = np.array([r["inst_max"] for r in rows], float)
    ratio_wo = np.median(tot / opm)
    ratio_oi = np.median(opm / im)
    emit("fig2_instruction_costs", t.seconds * 1e6 / max(len(rows), 1),
         f"workload/op={ratio_wo:.0f}x;op/inst={ratio_oi:.0f}x")
    return {"ratio_workload_op": ratio_wo, "ratio_op_inst": ratio_oi}


if __name__ == "__main__":
    main()
