"""Fig. 2 — execution cycles of workloads / operators / instructions.

Reproduces the three panels: (a) whole-workload cycles per size bucket,
(b) operator-level cycles, (c) per-instruction cycles by type — the
quantitative motivation for instruction-level preemption.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import LIB, Timer, emit


def main(full: bool = False):
    rows = []
    with Timer() as t:
        for name, prog in sorted(LIB.items()):
            ops = prog.operator_cycle_sizes()
            hist = prog.instruction_cost_histogram()
            inst_max = prog.max_instruction_cycles
            inst_mean = (sum(c * n for arr in hist.values() for c, n in arr)
                         / max(prog.n_instructions, 1))
            bucket = ("small" if prog.total_cycles <= 1e6 else
                      "medium" if prog.total_cycles <= 1e7 else "large")
            rows.append((name, bucket, prog.total_cycles, int(ops.max()),
                         int(ops.mean()), inst_max, round(inst_mean, 1)))
    print("workload,bucket,total_cycles,op_max,op_mean,inst_max,inst_mean")
    for r in rows:
        print(",".join(str(x) for x in r))
    tot = np.array([r[2] for r in rows], float)
    opm = np.array([r[3] for r in rows], float)
    im = np.array([r[5] for r in rows], float)
    ratio_wo = np.median(tot / opm)
    ratio_oi = np.median(opm / im)
    emit("fig2_instruction_costs", t.seconds * 1e6 / max(len(rows), 1),
         f"workload/op={ratio_wo:.0f}x;op/inst={ratio_oi:.0f}x")
    return {"ratio_workload_op": ratio_wo, "ratio_op_inst": ratio_oi}


if __name__ == "__main__":
    main()
