"""Fig. 13 (extension) — survivability under injected environmental
faults.

The ``faults@<intensity>`` scenario family (``repro.scenarios``) sweeps
a combined correlated-contention-burst + DMA-stretch + thermal-throttle
environment from off (intensity 0 — the neutral multiplier, results
bit-identical to ``scenario=None``) to a heavily degraded MPSoC.  The
scenario realization is CRN-keyed per (seed, task, release), identical
under every policy and engine, so each {mesc, np} pair is a pure policy
effect.

Two survivability axes per cell:

  * ``hi_success`` — fraction of runs where every HI deadline held.
    This is the axis faults actually discriminate on: fault stretch
    lands on top of overrunning HI demand, and the non-preemptive
    baseline's blocking turns each stretched LO job into a missed HI
    deadline, while MESC's instruction-level preemption degrades
    gracefully with intensity.
  * ``lo_surv`` — fig10's LO survivability (completed / released LO
    jobs during HI mode).  Reported, not policy-gated: non-preemption
    trivially finishes any LO job it has started (that blocking is
    exactly what kills its HI axis), so raw LO survivability does not
    separate the policies.

``--gate`` enforces the figure's claim: MESC HI-success >= the
non-preemptive baseline at *every* fault intensity, and MESC LO
survivability stays above the paper's Obs. 5 floor (>20%) even at
maximum fault intensity.

    PYTHONPATH=src python -m benchmarks.fig13_fault_survivability
        [--full] [--smoke] [--gate] [--out rows.json] [--no-cache]
        [--engine event|vec|jit]
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core import Policy
from repro.experiments import (Campaign, Sweep, frac, group_rows,
                               ratio_of_sums)
from benchmarks.common import DEFAULT_SETS, Timer, emit

INTENSITIES = (0.0, 0.25, 0.5, 0.75, 1.0)
SMOKE_INTENSITIES = (0.0, 0.5, 1.0)
U = 0.8
OVERRUN = 0.5                         # fig10's HI-mode-heavy regime
LO_SURV_FLOOR = 0.2                   # paper Obs. 5: >20% survivability


def sweeps(full: bool = False, engine: str = "event", devices=None,
           smoke: bool = False):
    """One two-policy sweep per fault intensity (scenario is a sweep-
    level axis: it salts every point's cache key)."""
    if smoke:
        # short horizon but enough sets that every cell accumulates
        # HI-mode LO releases (the lo_surv denominator)
        n_sets, duration = 10, 4e7
        intensities = SMOKE_INTENSITIES
    else:
        n_sets = 400 if full else max(DEFAULT_SETS // 2, 30)
        duration = 2e8
        intensities = INTENSITIES
    return [Sweep(name=f"fig13_faults_{x:g}",
                  policies=(Policy.mesc(), Policy.non_preemptive()),
                  utils=(U,), n_sets=n_sets, duration=duration,
                  overrun_prob=OVERRUN, engine=engine, devices=devices,
                  scenario=f"faults@{x:g}")
            for x in intensities], intensities


def _cell_stats(cell):
    return dict(hi_success=frac(cell, "success_hi"),
                lo_surv=ratio_of_sums(cell, "lo_done_in_hi",
                                      "lo_released_in_hi"))


def main(full: bool = False, engine: str = "event", devices=None,
         smoke: bool = False, out: str = None, gate: bool = False,
         **campaign_kw):
    sws, intensities = sweeps(full, engine, devices, smoke)
    rows = []
    res = {}
    with Timer() as t:
        for x, sw in zip(intensities, sws):
            sw_rows = Campaign(sw, **campaign_kw).collect()
            for r in sw_rows:
                r = dict(r)
                r["fault_intensity"] = x
                rows.append(r)
            for (pol,), cell in group_rows(sw_rows, "policy").items():
                res[(pol, x)] = _cell_stats(cell)
    if out:                           # canonical byte-stable dump (CI)
        with open(out, "w") as f:
            json.dump(rows, f, sort_keys=True, separators=(",", ":"))
        print(f"# wrote {len(rows)} rows to {out}", file=sys.stderr)
    print("intensity,mesc_hi_success,np_hi_success,"
          "mesc_lo_surv,np_lo_surv")
    for x in intensities:
        m, n = res[("mesc", x)], res[("np", x)]
        print(f"{x},{m['hi_success']:.3f},{n['hi_success']:.3f},"
              f"{m['lo_surv']:.3f},{n['lo_surv']:.3f}")
    worst_gap = min(res[("mesc", x)]["hi_success"]
                    - res[("np", x)]["hi_success"] for x in intensities)
    at_max = res[("mesc", intensities[-1])]
    emit("fig13_fault_survivability",
         t.seconds * 1e6 / max(len(rows), 1),
         f"mesc_hi_at_max_fault={at_max['hi_success']:.2f};"
         f"worst_hi_gap_vs_np={worst_gap:.3f};"
         f"mesc_lo_surv_at_max_fault={at_max['lo_surv']:.2f}")
    if gate:
        # "not >=" (rather than "<") so a NaN cell — an empty
        # denominator — fails loudly instead of passing by comparison
        bad = [x for x in intensities
               if not (res[("mesc", x)]["hi_success"]
                       >= res[("np", x)]["hi_success"])]
        if bad:
            raise SystemExit(
                "fig13 gate FAILED: MESC HI-success below the "
                "non-preemptive baseline at intensities "
                + ", ".join(
                    f"{x:g} (mesc={res[('mesc', x)]['hi_success']:.3f}"
                    f" < np={res[('np', x)]['hi_success']:.3f})"
                    for x in bad))
        if not at_max["lo_surv"] >= LO_SURV_FLOOR:
            raise SystemExit(
                f"fig13 gate FAILED: MESC LO survivability "
                f"{at_max['lo_surv']:.3f} at max fault intensity is "
                f"below the Obs. 5 floor {LO_SURV_FLOOR}")
        print("# fig13 gate OK: MESC survives every fault intensity "
              "at or above the non-preemptive baseline", file=sys.stderr)
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale set count (400 per cell)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny short-horizon corpus (CI scenario-smoke "
                         "job)")
    ap.add_argument("--gate", action="store_true",
                    help="exit non-zero unless MESC HI-success >= "
                         "non-preemptive at every fault intensity and "
                         "LO survivability holds the Obs. 5 floor")
    ap.add_argument("--out", default=None,
                    help="write the raw rows as canonical JSON "
                         "(byte-identical across deterministic reruns)")
    ap.add_argument("--no-cache", action="store_true",
                    help="always re-simulate; write nothing to disk")
    ap.add_argument("--engine", default="event",
                    choices=("event", "vec", "jit"))
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--cache-dir", default=None)
    args = ap.parse_args()
    main(full=args.full, engine=args.engine, devices=args.devices,
         smoke=args.smoke, out=args.out, gate=args.gate,
         workers=args.workers, cache_dir=args.cache_dir,
         use_cache=not args.no_cache)
