"""Tbl. II/III analogue — overhead of the preemption machinery.

The FPGA table reports LUT/register/power cost of Gemmini^RT vs Gemmini;
the software system's equivalent is (i) runtime overhead: context-switch +
scheduler cycles as a fraction of useful execution (< 5%, paper abstract),
and (ii) the per-component context-switch cycle decomposition (drain /
accumulator / config buffer / remap block / scratchpad), mirroring the
per-component hardware breakdown.

Two engine sweeps: a FuncSweep for the per-workload decomposition and a
simulation Sweep (u in {0.5, 0.7, 0.9}) for the overhead fraction.
"""
from __future__ import annotations

import numpy as np

from repro.core import GemminiRT, Policy, TaskParams, TCB, Crit
from repro.experiments import Campaign, FuncSweep, Sweep, group_rows
from repro.experiments.runner import cached_library
from benchmarks.common import DEFAULT_SETS, Timer, emit

UTILS = (0.5, 0.7, 0.9)
COLUMNS = ("workload", "drain", "accumulator", "config_buf", "remap_blk",
           "scratchpad", "save_total", "restore_total")


def cs_row(workload: str) -> dict:
    """Engine point: per-component cycles of one save+restore."""
    prog = cached_library("sim")[workload]
    acc = GemminiRT()
    p = TaskParams(tid=0, priority=0, period=1e9, deadline=1e9,
                   c_lo=prog.total_cycles, c_hi=2 * prog.total_cycles,
                   crit=Crit.LO, eta=1, workload=workload)
    tcb = TCB(params=p)
    acc.note_execution(0, prog.total_cycles * 0.5, prog)
    br = acc.context_save(tcb, drain_cycles=prog.max_instruction_cycles,
                          next_eta=8)
    rr = acc.context_restore(tcb)
    return {"workload": workload, "drain": br.drain,
            "accumulator": br.accumulator, "config_buf": br.config_buffer,
            "remap_blk": br.remap_block, "scratchpad": br.scratchpad,
            "save_total": br.total, "restore_total": rr.total}


def sweeps(full: bool = False, engine: str = "event", devices=None):
    n_sets = max((1000 if full else DEFAULT_SETS) // 2, 30)
    names = sorted(cached_library("sim"))
    return (FuncSweep.over("tbl_overhead_cs",
                           "benchmarks.tbl_overhead:cs_row",
                           [{"workload": n} for n in names]),
            Sweep(name="tbl_overhead", policies=(Policy.mesc(),),
                  utils=UTILS, n_sets=n_sets, engine=engine,
                  devices=devices))


def main(full: bool = False, engine: str = "event", devices=None,
         **campaign_kw):
    cs_sweep, sim_sweep = sweeps(full, engine, devices)
    n_sets = sim_sweep.n_sets
    with Timer() as t:
        cs_rows = Campaign(cs_sweep, **campaign_kw).collect()
        print(",".join(COLUMNS))
        for r in cs_rows:
            print(",".join(str(r[c]) for c in COLUMNS))
        cells = group_rows(Campaign(sim_sweep, **campaign_kw).collect(), "u")
        fracs = []
        for u in UTILS:
            fr = [r["overhead_cycles"] / max(r["exec_cycles"], 1)
                  for r in cells[(u,)]]
            fracs.append(np.mean(fr))
            print(f"overhead_fraction,u={u},{np.mean(fr):.4f}")
    worst = max(fracs)
    emit("tbl_overhead", t.seconds * 1e6 / (len(UTILS) * n_sets),
         f"overhead={worst * 100:.2f}%;claim=<5%;ok={worst < 0.05}")
    return {"overhead_fraction": worst}


if __name__ == "__main__":
    main()
