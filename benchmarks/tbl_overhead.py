"""Tbl. II/III analogue — overhead of the preemption machinery.

The FPGA table reports LUT/register/power cost of Gemmini^RT vs Gemmini;
the software system's equivalent is (i) runtime overhead: context-switch +
scheduler cycles as a fraction of useful execution (< 5%, paper abstract),
and (ii) the per-component context-switch cycle decomposition (drain /
accumulator / config buffer / remap block / scratchpad), mirroring the
per-component hardware breakdown.
"""
from __future__ import annotations

import numpy as np

from repro.core import GemminiRT, Policy, TaskParams, TCB, Crit
from repro.core.program import workload_library
from benchmarks.common import DEFAULT_SETS, Timer, emit, run_many

LIB = workload_library(include_archs=False)


def cs_decomposition():
    """Per-component cycles of one save+restore for each workload."""
    rows = []
    for name, prog in sorted(LIB.items()):
        acc = GemminiRT()
        p = TaskParams(tid=0, priority=0, period=1e9, deadline=1e9,
                       c_lo=prog.total_cycles, c_hi=2 * prog.total_cycles,
                       crit=Crit.LO, eta=1, workload=name)
        tcb = TCB(params=p)
        acc.note_execution(0, prog.total_cycles * 0.5, prog)
        br = acc.context_save(tcb, drain_cycles=prog.max_instruction_cycles,
                              next_eta=8)
        rr = acc.context_restore(tcb)
        rows.append((name, br.drain, br.accumulator, br.config_buffer,
                     br.remap_block, br.scratchpad, br.total, rr.total))
    return rows


def main(full: bool = False):
    n_sets = max((1000 if full else DEFAULT_SETS) // 2, 30)
    with Timer() as t:
        print("workload,drain,accumulator,config_buf,remap_blk,scratchpad,"
              "save_total,restore_total")
        for r in cs_decomposition():
            print(",".join(str(x) for x in r))
        fracs = []
        for u in (0.5, 0.7, 0.9):
            ms = run_many(Policy.mesc(), n_sets=n_sets, u=u)
            fr = [m.overhead_cycles / max(m.exec_cycles, 1) for m in ms]
            fracs.append(np.mean(fr))
            print(f"overhead_fraction,u={u},{np.mean(fr):.4f}")
    worst = max(fracs)
    emit("tbl_overhead", t.seconds * 1e6 / (3 * n_sets),
         f"overhead={worst * 100:.2f}%;claim=<5%;ok={worst < 0.05}")
    return {"overhead_fraction": worst}


if __name__ == "__main__":
    main()
