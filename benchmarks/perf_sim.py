"""Simulation-engine performance harness: points/sec for the
event-driven and vectorized backends on a fixed fig8-style corpus.

The corpus is MESC over the fig8 utilisation band (fig8's task-set
recipe: 10-task UUnifast sets, CF=2, duration 2e8 cycles), 512
``(taskset, seed)`` points — the unit every paper figure is built from.
Both engines simulate the *identical* corpus single-process, so the
ratio is an engine-vs-engine number, not a parallelism artefact; the
harness also asserts the two engines' per-point metrics agree
(the vectorized backend's exactness contract).

Results are written to ``BENCH_sim.json`` at the repo root — the
committed copy is the perf baseline every future PR is compared
against (CI job ``perf-smoke`` prints the delta).

    PYTHONPATH=src python -m benchmarks.perf_sim [--smoke]
        [--out BENCH_sim.json] [--baseline BENCH_sim.json]

``--smoke`` runs a reduced corpus (32 points, shorter horizon) sized
for CI; it updates only the ``smoke`` section of the JSON so the
committed ``full`` numbers survive.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path

SCHEMA_VERSION = 1
REPO_ROOT = Path(__file__).resolve().parent.parent
DEFAULT_OUT = REPO_ROOT / "BENCH_sim.json"

FULL = dict(utils=(0.6, 0.7, 0.8, 0.9), n_sets=128, duration=2e8,
            n_tasks=10)
SMOKE = dict(utils=(0.7, 0.9), n_sets=16, duration=2e7, n_tasks=10)


def build_corpus(spec):
    from repro.core import Policy, generate_taskset
    from repro.experiments.runner import cached_library
    lib = cached_library("sim")
    tasksets, seeds = [], []
    for u in spec["utils"]:
        for s in range(spec["n_sets"]):
            tasksets.append(generate_taskset(
                u, seed=s, n_tasks=spec["n_tasks"], programs=lib))
            seeds.append(s)
    return lib, Policy.mesc(), tasksets, seeds


def measure(spec):
    from repro.core.simulator import simulate
    from repro.core.simulator_vec import simulate_vbatch
    from repro.experiments.metrics import metrics_row
    lib, policy, tasksets, seeds = build_corpus(spec)
    n = len(tasksets)

    t0 = time.perf_counter()
    ev = [simulate(ts, lib, policy, duration=spec["duration"], seed=s)
          for ts, s in zip(tasksets, seeds)]
    t_event = time.perf_counter() - t0

    t0 = time.perf_counter()
    vc = simulate_vbatch(tasksets, lib, policy, seeds=seeds,
                         duration=spec["duration"], batch_size=512)
    t_vec = time.perf_counter() - t0

    mismatches = sum(metrics_row(a) != metrics_row(b)
                     for a, b in zip(ev, vc))
    return {
        "corpus": {"style": "fig8", "policy": policy.name,
                   "utils": list(spec["utils"]), "n_sets": spec["n_sets"],
                   "n_tasks": spec["n_tasks"], "duration": spec["duration"],
                   "points": n},
        "engines": {
            "event": {"points": n, "seconds": round(t_event, 3),
                      "points_per_sec": round(n / t_event, 2)},
            "vec": {"points": n, "seconds": round(t_vec, 3),
                    "points_per_sec": round(n / t_vec, 2)},
        },
        "speedup_vec_vs_event": round(t_event / t_vec, 2),
        "exact_match_points": n - mismatches,
        "mismatched_points": mismatches,
    }


def load(path: Path) -> dict:
    try:
        return json.loads(path.read_text())
    except (FileNotFoundError, json.JSONDecodeError):
        return {"schema_version": SCHEMA_VERSION, "sections": {}}


def print_delta(section: str, new: dict, baseline: dict) -> None:
    base = baseline.get("sections", {}).get(section)
    if not base:
        print(f"# no committed baseline for section {section!r}")
        return
    for eng in ("event", "vec"):
        old_pps = base["engines"][eng]["points_per_sec"]
        new_pps = new["engines"][eng]["points_per_sec"]
        delta = 100.0 * (new_pps - old_pps) / old_pps if old_pps else 0.0
        print(f"perf_delta,{section},{eng},{old_pps},{new_pps},"
              f"{delta:+.1f}%")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI-sized corpus (updates 'smoke' only)")
    ap.add_argument("--out", default=str(DEFAULT_OUT),
                    help="where to write the updated BENCH_sim.json")
    ap.add_argument("--baseline", default=str(DEFAULT_OUT),
                    help="committed baseline to diff against")
    args = ap.parse_args()

    section = "smoke" if args.smoke else "full"
    spec = SMOKE if args.smoke else FULL
    baseline = load(Path(args.baseline))
    result = measure(spec)

    doc = load(Path(args.out))
    doc["schema_version"] = SCHEMA_VERSION
    doc.setdefault("sections", {})
    # keep the other section's committed numbers intact
    for k, v in baseline.get("sections", {}).items():
        doc["sections"].setdefault(k, v)
    doc["sections"][section] = result
    doc["host"] = {"cpus": os.cpu_count()}

    Path(args.out).write_text(json.dumps(doc, indent=1, sort_keys=True)
                              + "\n")
    eng = result["engines"]
    print(f"corpus,{section},points={result['corpus']['points']}")
    print(f"event,{eng['event']['seconds']}s,"
          f"{eng['event']['points_per_sec']}pts/s")
    print(f"vec,{eng['vec']['seconds']}s,"
          f"{eng['vec']['points_per_sec']}pts/s")
    print(f"speedup,vec_vs_event,{result['speedup_vec_vs_event']}x")
    print(f"equivalence,{result['exact_match_points']}/"
          f"{result['corpus']['points']}")
    print_delta(section, result, baseline)
    if result["mismatched_points"]:
        raise SystemExit(
            f"{result['mismatched_points']} corpus points diverged "
            "between engines — vec exactness contract violated")


if __name__ == "__main__":
    main()
