"""Simulation-engine performance harness: points/sec for the
event-driven, vectorized and fully-compiled (jit) backends on a fixed
fig8-style corpus.

The corpus is MESC over the fig8 utilisation band (fig8's task-set
recipe: 10-task UUnifast sets, CF=2, duration 2e8 cycles), 512
``(taskset, seed)`` points — the unit every paper figure is built from.
All engines simulate the *identical* corpus from one process, so the
ratios are engine-vs-engine numbers, not parallelism artefacts.  The
jit engine is additionally timed at logical device counts 1/2/4
(``--devices``, ``REPRO_DEVICES``, see repro.runtime.device_config) —
the ``device_scaling`` rows — and every sharded run is asserted
bit-identical to the single-device run in the same process, so a
scaling number can never come from semantically divergent work.

Because container timing is noisy run-to-run, every engine is measured
**median-of-3 after a warmup run** (the warmup also absorbs the jit
engine's XLA compilation); the per-repeat samples and their spread are
recorded so baseline deltas can be read against the measured noise.

The harness also verifies the engine-equivalence contracts on the
corpus (see docs/performance.md):

  * ``vec`` is bit-exact against ``event`` on every point;
  * ``jit`` matches ``vec`` bit-exactly on the zero-jitter
    (``demand_profile="nominal"``) corpus, where no in-loop RNG draws
    exist;
  * ``jit`` matches ``vec`` statistically on the sampled corpus
    (success rates within binomial sampling error; counter-based RNG,
    see core/simulator_jit.py);
  * sharded ``jit`` (``--devices N > 1``) is bit-exact against the
    single-device jit run on the sampled corpus (the CI device-matrix
    gate).

An empty corpus or comparison set is a hard error naming the section —
a vacuous equivalence pass must never gate green.

Results are written to ``BENCH_sim.json`` at the repo root — the
committed copy is the perf baseline every future PR is compared
against (CI job ``perf-smoke`` prints the delta and *gates* on the
equivalence checks).

    PYTHONPATH=src python -m benchmarks.perf_sim [--smoke]
        [--check-equivalence] [--devices N] [--out BENCH_sim.json]
        [--baseline BENCH_sim.json]

``--smoke`` runs a reduced corpus (32 points, shorter horizon) sized
for CI; it updates only the ``smoke`` section of the JSON so the
committed ``full`` numbers survive.  ``--check-equivalence`` runs only
the (gating) equivalence checks, no timing repeats.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time
from pathlib import Path

SCHEMA_VERSION = 3
REPEATS = 3
DEVICE_COUNTS = (1, 2, 4)
REPO_ROOT = Path(__file__).resolve().parent.parent
DEFAULT_OUT = REPO_ROOT / "BENCH_sim.json"

FULL = dict(utils=(0.6, 0.7, 0.8, 0.9), n_sets=128, duration=2e8,
            n_tasks=10)
SMOKE = dict(utils=(0.7, 0.9), n_sets=16, duration=2e7, n_tasks=10)

ENGINES = ("event", "vec", "jit")


def build_corpus(spec):
    from repro.core import Policy, generate_taskset
    from repro.experiments.runner import cached_library
    lib = cached_library("sim")
    tasksets, seeds = [], []
    for u in spec["utils"]:
        for s in range(spec["n_sets"]):
            tasksets.append(generate_taskset(
                u, seed=s, n_tasks=spec["n_tasks"], programs=lib))
            seeds.append(s)
    return lib, Policy.mesc(), tasksets, seeds


def _engine_fn(engine, lib, policy, tasksets, seeds, duration,
               devices=None):
    from repro.core.simulator import simulate
    from repro.core.simulator_vec import simulate_vbatch
    if engine == "event":
        return lambda: [simulate(ts, lib, policy, duration=duration,
                                 seed=s)
                        for ts, s in zip(tasksets, seeds)]
    backend = "numpy" if engine == "vec" else "jit"
    return lambda: simulate_vbatch(tasksets, lib, policy, seeds=seeds,
                                   duration=duration, batch_size=512,
                                   select_backend=backend,
                                   devices=devices)


def _timed(fn):
    """Warmup + median-of-REPEATS timing; returns (result, samples)."""
    result = fn()                       # warmup (jit: compilation)
    samples = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        result = fn()
        samples.append(time.perf_counter() - t0)
    return result, samples


def _stats(samples, n):
    med = sorted(samples)[len(samples) // 2]
    spread = 100.0 * (max(samples) - min(samples)) / med if med else 0.0
    return {"points": n, "seconds": round(med, 3),
            "points_per_sec": round(n / med, 2),
            "samples": [round(s, 3) for s in samples],
            "spread_pct": round(spread, 1)}


def binomial_bound(pbar: float, n: int) -> float:
    """4-sigma bound on the difference of two success proportions over
    n points each — the jit-vs-vec statistical gate (shared with
    tests/test_simulator_jit.py)."""
    return 4.0 * math.sqrt(max(pbar * (1 - pbar), 1e-12) * 2 / n) \
        + 2.0 / n


def check_equivalence(spec, results=None, devices=None,
                      section="full") -> dict:
    """The cross-engine contracts on the corpus (see module
    docstring).  Returns the equivalence report; raises SystemExit on
    any violation — including an *empty* corpus or comparison set,
    which would otherwise vacuously pass every gate.  ``results`` may
    carry already-simulated ``{engine: [RunMetrics]}`` sampled-corpus
    outputs (measure() hands its timed runs over; the jit entry must
    have run at ``devices``) — only the missing pieces are simulated.
    ``devices > 1`` additionally gates sharded-vs-single-device jit
    bit-exactness."""
    from repro.core.simulator import simulate
    from repro.core.simulator_vec import simulate_vbatch
    from repro.experiments.metrics import metrics_row
    from repro.runtime.device_config import default_device_count
    lib, policy, tasksets, seeds = build_corpus(spec)
    n = len(tasksets)
    if n == 0:
        raise SystemExit(
            f"check-equivalence: corpus section {section!r} is empty "
            f"(utils={spec.get('utils')!r}, "
            f"n_sets={spec.get('n_sets')!r}) — an empty comparison set "
            "would vacuously pass every gate; refusing to report "
            "success")

    def _require(name, lst):
        """Comparison sets must cover the corpus, 1:1 — an empty or
        truncated set silently weakens every zip()-based gate below."""
        if len(lst) != n:
            raise SystemExit(
                f"check-equivalence: section {section!r} comparison "
                f"set {name!r} has {len(lst)} results for {n} corpus "
                "points — refusing to gate on a partial comparison")
        return lst

    duration = spec["duration"]
    devices = default_device_count() if devices is None else devices
    results = results or {}

    ev = _require("event", results.get("event") or [
        simulate(ts, lib, policy, duration=duration, seed=s)
        for ts, s in zip(tasksets, seeds)])
    vc = _require("vec", results.get("vec") or simulate_vbatch(
        tasksets, lib, policy, seeds=seeds, duration=duration,
        batch_size=512))
    vec_mismatch = sum(metrics_row(a) != metrics_row(b)
                       for a, b in zip(ev, vc))

    # zero-jitter corpus: no in-loop draws exist, jit must equal vec
    # bit-for-bit
    vc_nom = _require("vec_nominal", simulate_vbatch(
        tasksets, lib, policy, seeds=seeds, duration=duration,
        batch_size=512, demand_profile="nominal"))
    jt_nom = _require("jit_nominal", simulate_vbatch(
        tasksets, lib, policy, seeds=seeds, duration=duration,
        batch_size=512, demand_profile="nominal",
        select_backend="jit", devices=devices))
    nom_mismatch = sum(metrics_row(a) != metrics_row(b)
                       for a, b in zip(vc_nom, jt_nom))

    # sampled corpus: jit draws from counter-based streams — success
    # rates must agree within binomial sampling error
    jt = _require("jit", results.get("jit") or simulate_vbatch(
        tasksets, lib, policy, seeds=seeds, duration=duration,
        batch_size=512, select_backend="jit", devices=devices))

    # sharded vs single-device jit: per-point keyed RNG draws make the
    # device count pure execution placement — bit-exact, not just
    # statistically equivalent (the CI device-matrix gate)
    sharded_mismatch = None
    if devices > 1:
        jt_1 = _require("jit_devices1", simulate_vbatch(
            tasksets, lib, policy, seeds=seeds, duration=duration,
            batch_size=512, select_backend="jit", devices=1))
        sharded_mismatch = sum(metrics_row(a) != metrics_row(b)
                               for a, b in zip(jt, jt_1))

    rows_v = [metrics_row(m) for m in vc]
    rows_j = [metrics_row(m) for m in jt]
    statistical = {}
    stat_ok = True
    for field in ("success_all", "success_hi"):
        pv = sum(r[field] for r in rows_v) / n
        pj = sum(r[field] for r in rows_j) / n
        bound = binomial_bound(0.5 * (pv + pj), n)
        ok = abs(pv - pj) <= bound
        stat_ok = stat_ok and ok
        statistical[field] = {"vec": round(pv, 4), "jit": round(pj, 4),
                              "bound": round(bound, 4), "ok": ok}

    report = {
        "vec_exact_match_points": n - vec_mismatch,
        "vec_mismatched_points": vec_mismatch,
        "jit_nominal_exact_match_points": n - nom_mismatch,
        "jit_nominal_mismatched_points": nom_mismatch,
        "jit_statistical": statistical,
        "jit_statistical_ok": stat_ok,
        "jit_devices": devices,
        "sharded_exact_match_points":
            None if sharded_mismatch is None else n - sharded_mismatch,
        "sharded_mismatched_points": sharded_mismatch,
    }
    if vec_mismatch:
        raise SystemExit(f"{vec_mismatch}/{n} corpus points diverged "
                         "between event and vec — exactness contract "
                         "violated")
    if nom_mismatch:
        raise SystemExit(f"{nom_mismatch}/{n} zero-jitter corpus points "
                         "diverged between vec and jit — nominal "
                         "exact-equivalence contract violated")
    if sharded_mismatch:
        raise SystemExit(
            f"{sharded_mismatch}/{n} corpus points diverged between "
            f"jit at devices={devices} and devices=1 — sharded "
            "bit-exactness contract violated")
    if not stat_ok:
        raise SystemExit("jit-vs-vec statistical equivalence violated: "
                         f"{statistical}")
    return report


def measure(spec, skip_equivalence: bool = False, devices=None,
            section="full"):
    from repro.experiments.metrics import metrics_row
    from repro.runtime.device_config import default_device_count
    lib, policy, tasksets, seeds = build_corpus(spec)
    n = len(tasksets)
    devices = default_device_count() if devices is None else devices
    engines = {}
    results = {}
    for engine in ENGINES:
        fn = _engine_fn(engine, lib, policy, tasksets, seeds,
                        spec["duration"],
                        devices=devices if engine == "jit" else None)
        results[engine], samples = _timed(fn)
        engines[engine] = _stats(samples, n)
    # per-step XLA kernel count of the compiled lockstep body — the
    # grouped-carry refactor's tracked metric.  Sourced from the
    # graph-lint budget manifest (tools/graphlint/budgets.json), which
    # pins it at the canonical corpus shape and re-verifies the pin
    # against a live compile here: the perf log and the ir-budget-drift
    # gate quote one number by construction.  kernel_budget() also
    # enforces that the neutral scenario (faults@0 — every component
    # statically off) compiled to the identical body as the
    # scenario-free graph, so the timed rows above (scenario=None) and
    # the print_delta rows against the committed baseline measure the
    # scenario-off throughput cost the scenario layer is gated on.
    from tools.graphlint import kernel_budget
    engines["jit"].update(kernel_budget())

    # jit pts/s per logical device count, every sharded run asserted
    # bit-identical to the devices=1 rows *from the same process* — a
    # scaling number can never come from semantically divergent work
    import jax
    have = jax.local_device_count()
    scaling = {}
    rows_1 = None
    for d in DEVICE_COUNTS:
        if d > have:
            scaling[str(d)] = {"skipped":
                               f"only {have} logical devices in pool"}
            continue
        fn = _engine_fn("jit", lib, policy, tasksets, seeds,
                        spec["duration"], devices=d)
        res, samples = _timed(fn)
        st = _stats(samples, n)
        rows = [metrics_row(m) for m in res]
        if rows_1 is None:            # DEVICE_COUNTS starts at 1
            rows_1 = rows
        st["bit_exact_vs_devices1"] = rows == rows_1
        if not st["bit_exact_vs_devices1"]:
            raise SystemExit(
                f"sharded jit (devices={d}) diverged from devices=1 "
                f"on the {section!r} corpus — bit-exactness contract "
                "violated")
        scaling[str(d)] = st
    engines["jit"]["device_scaling"] = scaling

    # reuse the timed sampled-corpus runs; only the two nominal-profile
    # runs inside the check are freshly simulated
    equivalence = None if skip_equivalence \
        else check_equivalence(spec, results, devices=devices,
                               section=section)
    sec = {e: engines[e]["seconds"] for e in ENGINES}
    return {
        "corpus": {"style": "fig8", "policy": policy.name,
                   "utils": list(spec["utils"]), "n_sets": spec["n_sets"],
                   "n_tasks": spec["n_tasks"], "duration": spec["duration"],
                   "points": n},
        "engines": engines,
        "speedup_vec_vs_event": round(sec["event"] / sec["vec"], 2),
        "speedup_jit_vs_vec": round(sec["vec"] / sec["jit"], 2),
        "speedup_jit_vs_event": round(sec["event"] / sec["jit"], 2),
        "equivalence": equivalence,
    }


def load(path: Path) -> dict:
    try:
        return json.loads(path.read_text())
    except (FileNotFoundError, json.JSONDecodeError):
        return {"schema_version": SCHEMA_VERSION, "sections": {}}


def print_delta(section: str, new: dict, baseline: dict) -> None:
    base_schema = baseline.get("schema_version")
    if base_schema != SCHEMA_VERSION:
        # an old-schema baseline (e.g. the v1 layout without samples/
        # spread) must not KeyError the delta report — warn and skip
        print(f"# baseline schema v{base_schema} != v{SCHEMA_VERSION} "
              "— skipping perf delta (refresh the baseline by "
              "committing this run's BENCH_sim.json)")
        return
    base = baseline.get("sections", {}).get(section)
    if not base:
        print(f"# no committed baseline for section {section!r}")
        return
    for eng in ENGINES:
        old = base.get("engines", {}).get(eng)
        if not old:                       # e.g. schema-v1 baseline
            print(f"# no baseline for engine {eng!r}")
            continue
        old_pps = old["points_per_sec"]
        new_pps = new["engines"][eng]["points_per_sec"]
        delta = 100.0 * (new_pps - old_pps) / old_pps if old_pps else 0.0
        spread = new["engines"][eng].get("spread_pct", 0.0)
        print(f"perf_delta,{section},{eng},{old_pps},{new_pps},"
              f"{delta:+.1f}%,spread={spread}%")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI-sized corpus (updates 'smoke' only)")
    ap.add_argument("--check-equivalence", action="store_true",
                    help="run only the cross-engine equivalence checks "
                         "(the CI gate); no timing, no JSON update")
    ap.add_argument("--skip-equivalence", action="store_true",
                    help="measure timings only (CI's measure step — its "
                         "gating sibling already ran the checks)")
    ap.add_argument("--devices", type=int, default=None,
                    help="logical host devices for the jit engine "
                         "(default: REPRO_DEVICES or 1); the "
                         "device_scaling rows always cover "
                         f"{DEVICE_COUNTS}")
    ap.add_argument("--out", default=str(DEFAULT_OUT),
                    help="where to write the updated BENCH_sim.json")
    ap.add_argument("--baseline", default=str(DEFAULT_OUT),
                    help="committed baseline to diff against")
    args = ap.parse_args()

    section = "smoke" if args.smoke else "full"
    spec = SMOKE if args.smoke else FULL

    # the logical-device pool must be forced before the first jax
    # computation (XLA reads the flag once); cover the scaling rows too
    from repro.runtime.device_config import (configure_host_devices,
                                             default_device_count)
    devices = default_device_count() if args.devices is None \
        else args.devices
    configure_host_devices(max(devices, max(DEVICE_COUNTS)))

    if args.check_equivalence:
        report = check_equivalence(spec, devices=devices,
                                   section=section)
        sharded = report["sharded_exact_match_points"]
        print(f"equivalence,{section},"
              f"vec_exact={report['vec_exact_match_points']},"
              f"jit_nominal_exact="
              f"{report['jit_nominal_exact_match_points']},"
              f"jit_statistical_ok={report['jit_statistical_ok']},"
              f"devices={report['jit_devices']},"
              f"sharded_exact="
              f"{'n/a' if sharded is None else sharded}")
        return

    baseline = load(Path(args.baseline))
    result = measure(spec, skip_equivalence=args.skip_equivalence,
                     devices=devices, section=section)
    if result["equivalence"] is None:
        # timings-only run: carry the baseline's last verified block
        result["equivalence"] = baseline.get("sections", {}).get(
            section, {}).get("equivalence")

    import jax
    doc = load(Path(args.out))
    doc["schema_version"] = SCHEMA_VERSION
    doc.setdefault("sections", {})
    # keep the other section's committed numbers intact
    for k, v in baseline.get("sections", {}).items():
        doc["sections"].setdefault(k, v)
    doc["sections"][section] = result
    doc["host"] = {"cpus": os.cpu_count(), "devices": devices,
                   "logical_devices": jax.local_device_count()}

    Path(args.out).write_text(json.dumps(doc, indent=1, sort_keys=True)
                              + "\n")
    print(f"corpus,{section},points={result['corpus']['points']}")
    for eng in ENGINES:
        e = result["engines"][eng]
        print(f"{eng},{e['seconds']}s,{e['points_per_sec']}pts/s,"
              f"spread={e['spread_pct']}%")
    print(f"jit_kernels,{section},"
          f"{result['engines']['jit']['xla_kernels']},"
          f"neutral_scenario="
          f"{result['engines']['jit']['xla_kernels_neutral_scenario']}")
    for d, st in result["engines"]["jit"]["device_scaling"].items():
        if "points_per_sec" in st:
            print(f"jit_devices,{d},{st['points_per_sec']}pts/s,"
                  f"bit_exact={st['bit_exact_vs_devices1']}")
        else:
            print(f"jit_devices,{d},{st['skipped']}")
    print(f"speedup,vec_vs_event,{result['speedup_vec_vs_event']}x")
    print(f"speedup,jit_vs_vec,{result['speedup_jit_vs_vec']}x")
    eq = result["equivalence"]
    if eq is not None and not args.skip_equivalence:
        print(f"equivalence,vec_exact={eq['vec_exact_match_points']}/"
              f"{result['corpus']['points']},"
              f"jit_nominal_exact={eq['jit_nominal_exact_match_points']}/"
              f"{result['corpus']['points']},"
              f"jit_statistical_ok={eq['jit_statistical_ok']}")
    print_delta(section, result, baseline)


if __name__ == "__main__":
    main()
