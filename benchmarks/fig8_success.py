"""Fig. 8 — successful ratio vs total utilisation.

Systems: MESC (with CS), MESC without CS (non-preemptive), AMC with CS,
AMC without CS.  Success = no task misses a deadline during the run
(HI-scope success also reported)."""
from __future__ import annotations

from repro.core import Policy
from benchmarks.common import DEFAULT_SETS, Timer, UTILS, emit, run_many

SYSTEMS = (("mesc", Policy.mesc()),
           ("mesc_noCS", Policy.non_preemptive()),
           ("amc_CS", Policy.amc()),
           ("amc_noCS", Policy(preemption="none", drop_lo_in_hi=True,
                               name="amc-np")))


def main(full: bool = False):
    n_sets = 1000 if full else DEFAULT_SETS
    print("u," + ",".join(n for n, _ in SYSTEMS)
          + "," + ",".join(n + "_hi" for n, _ in SYSTEMS))
    res = {}
    with Timer() as t:
        for u in UTILS:
            row_all, row_hi = [], []
            for name, pol in SYSTEMS:
                ms = run_many(pol, n_sets=n_sets, u=u)
                row_all.append(sum(m.success() for m in ms) / len(ms))
                row_hi.append(sum(m.success("HI") for m in ms) / len(ms))
                res[(name, u)] = (row_all[-1], row_hi[-1])
            print(f"{u}," + ",".join(f"{x:.3f}" for x in row_all + row_hi))
    mesc95 = res[("mesc", 0.95)][1]
    nocs85 = res[("mesc_noCS", 0.9)][1]
    emit("fig8_success", t.seconds * 1e6 / (len(UTILS) * len(SYSTEMS) * n_sets),
         f"mesc_hi@0.95={mesc95:.2f};noCS_hi@0.9={nocs85:.2f}")
    return res


if __name__ == "__main__":
    main()
