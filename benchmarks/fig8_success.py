"""Fig. 8 — successful ratio vs total utilisation.

Systems: MESC (with CS), MESC without CS (non-preemptive), AMC with CS,
AMC without CS.  Success = no task misses a deadline during the run
(HI-scope success also reported).

One engine sweep over 4 policies x 6 utilisations; policy names stay
canonical ('mesc', 'np', ...) so cache points are shared with other
figures sweeping the same systems.
"""
from __future__ import annotations

from repro.core import Policy
from repro.experiments import Campaign, Sweep, frac, group_rows
from benchmarks.common import DEFAULT_SETS, Timer, UTILS, emit

# display label -> canonical policy
SYSTEMS = (("mesc", Policy.mesc()),
           ("mesc_noCS", Policy.non_preemptive()),
           ("amc_CS", Policy.amc()),
           ("amc_noCS", Policy(preemption="none", drop_lo_in_hi=True,
                               name="amc-np")))


def sweep(full: bool = False, engine: str = "event",
          devices=None, scenario=None) -> Sweep:
    n_sets = 1000 if full else DEFAULT_SETS
    return Sweep(name="fig8_success",
                 policies=tuple(p for _, p in SYSTEMS),
                 utils=UTILS, n_sets=n_sets, engine=engine,
                 devices=devices, scenario=scenario)


def main(full: bool = False, engine: str = "event", devices=None,
         scenario=None, **campaign_kw):
    sw = sweep(full, engine, devices, scenario)
    with Timer() as t:
        rows = Campaign(sw, **campaign_kw).collect()
    n_sets = sw.n_sets
    cells = group_rows(rows, "policy", "u")
    print("u," + ",".join(n for n, _ in SYSTEMS)
          + "," + ",".join(n + "_hi" for n, _ in SYSTEMS))
    res = {}
    for u in UTILS:
        row_all, row_hi = [], []
        for label, pol in SYSTEMS:
            cell = cells[(pol.name, u)]
            row_all.append(frac(cell, "success_all"))
            row_hi.append(frac(cell, "success_hi"))
            res[(label, u)] = (row_all[-1], row_hi[-1])
        print(f"{u}," + ",".join(f"{x:.3f}" for x in row_all + row_hi))
    mesc95 = res[("mesc", 0.95)][1]
    nocs85 = res[("mesc_noCS", 0.9)][1]
    emit("fig8_success",
         t.seconds * 1e6 / (len(UTILS) * len(SYSTEMS) * n_sets),
         f"mesc_hi@0.95={mesc95:.2f};noCS_hi@0.9={nocs85:.2f}")
    return res


if __name__ == "__main__":
    main()
