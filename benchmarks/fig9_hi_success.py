"""Fig. 9 — HI-mode successful ratio under varying gamma (HI share) and
beta (tasks per set).

Two engine sweeps (one per varied axis) at u = 0.8; each point is one
taskset + one MESC run.
"""
from __future__ import annotations

from repro.core import Policy
from repro.experiments import Campaign, Sweep, frac, group_rows
from benchmarks.common import DEFAULT_SETS, Timer, emit

GAMMAS = (0.2, 0.4, 0.5, 0.6, 0.8)
BETAS = (4, 8, 10, 14, 20)
U = 0.8


def sweeps(full: bool = False, engine: str = "event", devices=None,
           scenario=None):
    n_sets = 400 if full else max(DEFAULT_SETS // 2, 30)
    return (Sweep(name="fig9_gamma", policies=(Policy.mesc(),),
                  utils=(U,), gammas=GAMMAS, n_sets=n_sets, engine=engine,
                  devices=devices, scenario=scenario),
            Sweep(name="fig9_beta", policies=(Policy.mesc(),),
                  utils=(U,), n_tasks=BETAS, n_sets=n_sets, engine=engine,
                  devices=devices, scenario=scenario))


def main(full: bool = False, engine: str = "event", devices=None,
         scenario=None, **campaign_kw):
    gamma_sweep, beta_sweep = sweeps(full, engine, devices, scenario)
    n_sets = gamma_sweep.n_sets
    out = {}
    with Timer() as t:
        g_cells = group_rows(Campaign(gamma_sweep, **campaign_kw).collect(),
                             "gamma")
        b_cells = group_rows(Campaign(beta_sweep, **campaign_kw).collect(),
                             "n_tasks")
        print("gamma,hi_success")
        for g in GAMMAS:
            out[("gamma", g)] = frac(g_cells[(g,)], "success_hi")
            print(f"{g},{out[('gamma', g)]:.3f}")
        print("beta,hi_success")
        for b in BETAS:
            out[("beta", b)] = frac(b_cells[(b,)], "success_hi")
            print(f"{b},{out[('beta', b)]:.3f}")
    drop_g = out[("gamma", 0.2)] - out[("gamma", 0.8)]
    spread_b = max(out[(k, b)] for k, b in out if k == "beta") - \
        min(out[(k, b)] for k, b in out if k == "beta")
    emit("fig9_hi_success",
         t.seconds * 1e6 / ((len(GAMMAS) + len(BETAS)) * n_sets),
         f"gamma_drop={drop_g:.2f};beta_spread={spread_b:.2f}")
    return out


if __name__ == "__main__":
    main()
