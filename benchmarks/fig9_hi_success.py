"""Fig. 9 — HI-mode successful ratio under varying gamma (HI share) and
beta (tasks per set)."""
from __future__ import annotations

from repro.core import Policy
from benchmarks.common import DEFAULT_SETS, Timer, emit, run_many

GAMMAS = (0.2, 0.4, 0.5, 0.6, 0.8)
BETAS = (4, 8, 10, 14, 20)


def main(full: bool = False):
    n_sets = 400 if full else max(DEFAULT_SETS // 2, 30)
    u = 0.8
    out = {}
    with Timer() as t:
        print("gamma,hi_success")
        for g in GAMMAS:
            ms = run_many(Policy.mesc(), n_sets=n_sets, u=u, gamma=g)
            r = sum(m.success("HI") for m in ms) / len(ms)
            out[("gamma", g)] = r
            print(f"{g},{r:.3f}")
        print("beta,hi_success")
        for b in BETAS:
            ms = run_many(Policy.mesc(), n_sets=n_sets, u=u, n_tasks=b)
            r = sum(m.success("HI") for m in ms) / len(ms)
            out[("beta", b)] = r
            print(f"{b},{r:.3f}")
    drop_g = out[("gamma", 0.2)] - out[("gamma", 0.8)]
    spread_b = max(out[(k, b)] for k, b in out if k == "beta") - \
        min(out[(k, b)] for k, b in out if k == "beta")
    emit("fig9_hi_success", t.seconds * 1e6 / ((len(GAMMAS) + len(BETAS)) * n_sets),
         f"gamma_drop={drop_g:.2f};beta_spread={spread_b:.2f}")
    return out


if __name__ == "__main__":
    main()
