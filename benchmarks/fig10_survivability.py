"""Fig. 10 — survivability of LO-tasks in HI-mode vs gamma / beta.

Survivability = completed / released LO jobs while the system is degraded
(paper SS VIII.D; Obs. 5: >20% even at extreme gamma).

Same sweep shape as Fig. 9 but with overrun_prob = 0.5 (more HI-mode
residency); the cell metric is a ratio of sums across runs.
"""
from __future__ import annotations

import numpy as np

from repro.core import Policy
from repro.experiments import Campaign, Sweep, group_rows, ratio_of_sums
from benchmarks.common import DEFAULT_SETS, Timer, emit

GAMMAS = (0.2, 0.4, 0.5, 0.6, 0.8)
BETAS = (4, 8, 10, 14, 20)
U = 0.8
OVERRUN = 0.5


def sweeps(full: bool = False, engine: str = "event", devices=None,
           scenario=None):
    n_sets = 400 if full else max(DEFAULT_SETS // 2, 30)
    return (Sweep(name="fig10_gamma", policies=(Policy.mesc(),),
                  utils=(U,), gammas=GAMMAS, n_sets=n_sets,
                  overrun_prob=OVERRUN, engine=engine,
                  devices=devices, scenario=scenario),
            Sweep(name="fig10_beta", policies=(Policy.mesc(),),
                  utils=(U,), n_tasks=BETAS, n_sets=n_sets,
                  overrun_prob=OVERRUN, engine=engine,
                  devices=devices, scenario=scenario))


def _surv(cell) -> float:
    return ratio_of_sums(cell, "lo_done_in_hi", "lo_released_in_hi")


def main(full: bool = False, engine: str = "event", devices=None,
         scenario=None, **campaign_kw):
    gamma_sweep, beta_sweep = sweeps(full, engine, devices, scenario)
    n_sets = gamma_sweep.n_sets
    out = {}
    with Timer() as t:
        g_cells = group_rows(Campaign(gamma_sweep, **campaign_kw).collect(),
                             "gamma")
        b_cells = group_rows(Campaign(beta_sweep, **campaign_kw).collect(),
                             "n_tasks")
        print("gamma,survivability")
        for g in GAMMAS:
            out[("gamma", g)] = _surv(g_cells[(g,)])
            print(f"{g},{out[('gamma', g)]:.3f}")
        print("beta,survivability")
        for b in BETAS:
            out[("beta", b)] = _surv(b_cells[(b,)])
            print(f"{b},{out[('beta', b)]:.3f}")
    worst = np.nanmin([v for v in out.values()])
    emit("fig10_survivability",
         t.seconds * 1e6 / ((len(GAMMAS) + len(BETAS)) * n_sets),
         f"worst_survivability={worst:.2f}")
    return out


if __name__ == "__main__":
    main()
