"""Fig. 10 — survivability of LO-tasks in HI-mode vs gamma / beta.

Survivability = completed / released LO jobs while the system is degraded
(paper SS VIII.D; Obs. 5: >20% even at extreme gamma)."""
from __future__ import annotations

import numpy as np

from repro.core import Policy
from benchmarks.common import DEFAULT_SETS, Timer, emit, mean, run_many

GAMMAS = (0.2, 0.4, 0.5, 0.6, 0.8)
BETAS = (4, 8, 10, 14, 20)


def _surv(ms):
    rel = sum(m.lo_released_in_hi for m in ms)
    done = sum(m.lo_done_in_hi for m in ms)
    return done / rel if rel else float("nan")


def main(full: bool = False):
    n_sets = 400 if full else max(DEFAULT_SETS // 2, 30)
    u = 0.8
    out = {}
    with Timer() as t:
        print("gamma,survivability")
        for g in GAMMAS:
            ms = run_many(Policy.mesc(), n_sets=n_sets, u=u, gamma=g,
                          overrun_prob=0.5)
            out[("gamma", g)] = _surv(ms)
            print(f"{g},{out[('gamma', g)]:.3f}")
        print("beta,survivability")
        for b in BETAS:
            ms = run_many(Policy.mesc(), n_sets=n_sets, u=u, n_tasks=b,
                          overrun_prob=0.5)
            out[("beta", b)] = _surv(ms)
            print(f"{b},{out[('beta', b)]:.3f}")
    worst = np.nanmin([v for v in out.values()])
    emit("fig10_survivability",
         t.seconds * 1e6 / ((len(GAMMAS) + len(BETAS)) * n_sets),
         f"worst_survivability={worst:.2f}")
    return out


if __name__ == "__main__":
    main()
