"""Roofline analysis (deliverable g) from the dry-run artifacts.

Per (arch x shape) cell on the 16x16 mesh:
  compute    = FLOPs/device   / 197 TFLOP/s   (bf16, TPU v5e)
  memory     = bytes/device   / 819 GB/s      (HBM)
  collective = link-bytes/dev / 50 GB/s       (per-link ICI)

FLOPs/bytes per device come from the trip-count-corrected HLO text
analysis (cross-validated against the unrolled single-device cost probe —
agreement within ~1%; see runtime/hlo_analysis.py).  The memory term is an
upper bound at CPU-XLA fusion granularity.

MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill/decode), N = active params —
the ratio against compiled FLOPs exposes remat/redundancy waste.

Declared as a campaign-engine FuncSweep with ``cache=False``: cells read
mutable dry-run artifacts from disk, so they always re-analyze.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCHS, SHAPES_BY_NAME, supports_shape
from repro.experiments import Campaign, FuncSweep

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / ICI link

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"
OUT_MD = Path(__file__).resolve().parents[1] / "results" / "roofline.md"

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def model_flops(arch: str, shape_name: str) -> float:
    cfg = ARCHS[arch]
    shape = SHAPES_BY_NAME[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch          # decode: one token / seq


def suggestion(dom: str, arch: str, shape: str) -> str:
    if dom == "collective":
        return ("reduce weight re-gathers (remat policy / int8 FSDP gathers)"
                if "train" in shape else
                "co-locate cache shards with attention (less resharding)")
    if dom == "memory":
        return ("fuse attention/softmax chains (Pallas flash kernel)"
                if "train" in shape or "prefill" in shape else
                "fused decode-attention kernel: read cache once")
    return "MXU-align tile shapes; skip masked causal blocks"


def cell_row(arch: str, shape: str, pod: str = "pod1") -> dict:
    """Engine point: roofline analysis of one (arch, shape) cell."""
    if not supports_shape(ARCHS[arch], SHAPES_BY_NAME[shape]):
        return {"arch": arch, "shape": shape, "status": "skip"}
    p = RESULTS / f"{arch}__{shape}__{pod}.json"
    if not p.exists():
        return {"arch": arch, "shape": shape, "status": "missing"}
    d = json.loads(p.read_text())
    if d.get("status") != "ok":
        return {"arch": arch, "shape": shape, "status": "error"}
    n_dev = d.get("n_devices", 256)
    fl = d.get("hlo_text_flops_per_device", 0.0)
    by = d.get("hlo_text_bytes_no_copies",
               d.get("hlo_text_bytes_per_device", 0.0))
    cl = d.get("collective_link_bytes", 0.0)
    t_c = fl / PEAK_FLOPS
    t_m = by / HBM_BW
    t_l = cl / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_l),
              key=lambda kv: kv[1])[0]
    mf = model_flops(arch, shape)
    hlo_global = fl * n_dev
    ratio = mf / hlo_global if hlo_global else 0.0
    bound = max(t_c, t_m, t_l)
    frac = t_c / bound if bound else 0.0     # roofline fraction (compute)
    return {"arch": arch, "shape": shape, "status": "ok",
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_l,
            "dominant": dom, "model_flops": mf, "useful_ratio": ratio,
            "roofline_fraction": frac,
            "hbm_gib": d.get("per_device_hbm_bytes", 0) / 2 ** 30,
            "fits": bool(d.get("per_device_hbm_bytes", 0) < 16 * 2 ** 30)}


def sweep(full: bool = False) -> FuncSweep:
    items = [{"arch": arch, "shape": shape}
             for arch in sorted(ARCHS) for shape in SHAPE_ORDER]
    return FuncSweep.over("roofline", "benchmarks.roofline:cell_row",
                          items, cache=False)


def main(full: bool = False, engine: str = "event", devices=None,
         **campaign_kw):
    # engine/devices: accepted for run.py uniformity; this figure has no
    # single-accelerator DES sweep for the vec backend to run
    del engine
    cells = Campaign(sweep(full), **campaign_kw).collect()
    rows = []
    print("arch,shape,compute_ms,memory_ms,collective_ms,dominant,"
          "useful_ratio,roofline_frac,hbm_gib,fits")
    md = ["| arch | shape | compute | memory | collective | dominant | "
          "useful | roofline | HBM | fix |",
          "|---|---|---|---|---|---|---|---|---|---|"]
    for r in cells:
        arch, shape = r["arch"], r["shape"]
        if r["status"] in ("skip", "missing"):
            sk = ("SKIP(sub-quadratic-only)" if r["status"] == "skip"
                  else "MISSING")
            print(f"{arch},{shape},{sk},,,,,,,")
            md.append(f"| {arch} | {shape} | {sk} | | | | | | | |")
            continue
        if r["status"] != "ok":
            print(f"{arch},{shape},ERROR,,,,,,,")
            md.append(f"| {arch} | {shape} | ERROR | | | | | | | |")
            continue
        rows.append(r)
        print(f"{arch},{shape},{r['compute_s']*1e3:.1f},"
              f"{r['memory_s']*1e3:.1f},{r['collective_s']*1e3:.1f},"
              f"{r['dominant']},{r['useful_ratio']:.3f},"
              f"{r['roofline_fraction']:.3f},{r['hbm_gib']:.2f},{r['fits']}")
        md.append(
            f"| {arch} | {shape} | {r['compute_s']*1e3:.1f} ms "
            f"| {r['memory_s']*1e3:.1f} ms | {r['collective_s']*1e3:.1f} ms "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} | {r['hbm_gib']:.1f} GiB "
            f"| {suggestion(r['dominant'], arch, shape)} |")
    OUT_MD.parent.mkdir(exist_ok=True)
    OUT_MD.write_text("\n".join(md) + "\n")
    n_fit = sum(r["fits"] for r in rows)
    doms = {d: sum(1 for r in rows if r["dominant"] == d)
            for d in ("compute", "memory", "collective")}
    from benchmarks.common import emit
    emit("roofline", 0.0,
         f"cells={len(rows)};fit16GB={n_fit};dominant={doms}")
    return rows


if __name__ == "__main__":
    main()
