"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV summary lines (plus the per-figure
CSV blocks above them).  ``--full`` uses the paper's 1000 task sets per
point (slow); default is a statistically-meaningful reduction.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale experiment sizes (1000 task sets)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset (fig2,fig7,fig8,fig9,"
                         "fig10,overhead,roofline)")
    args = ap.parse_args()

    from benchmarks import (fig2_instruction_costs, fig6_banks,
                            fig7_blocking, fig8_success, fig9_hi_success,
                            fig10_survivability, tbl_overhead, roofline)
    table = {
        "fig2": fig2_instruction_costs.main,
        "fig6": fig6_banks.main,
        "fig7": fig7_blocking.main,
        "fig8": fig8_success.main,
        "fig9": fig9_hi_success.main,
        "fig10": fig10_survivability.main,
        "overhead": tbl_overhead.main,
        "roofline": roofline.main,
    }
    only = args.only.split(",") if args.only else list(table)
    print("name,us_per_call,derived")
    for name in only:
        print(f"# === {name} ===", file=sys.stderr)
        try:
            table[name](full=args.full)
        except Exception as e:  # keep the harness going
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
