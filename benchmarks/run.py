"""Benchmark entry point: one function per paper table/figure.

Every figure is a declaration over the campaign engine
(``repro.experiments``): points fan out across worker processes and are
cached on disk by content hash, so a re-run of an unchanged figure is
pure cache replay.  Prints ``name,us_per_call,derived`` CSV summary
lines (plus the per-figure CSV blocks above them).

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig7,fig8]
        [--engine event|vec|jit] [--devices N] [--scenario NAME]
        [--workers N] [--cache-dir DIR] [--no-cache] [--smoke]

``--full`` uses the paper's 1000 task sets per point (slow); default is
a statistically-meaningful reduction.  ``--engine vec`` routes the
single-accelerator simulation sweeps through the vectorized batch
backend (``core.simulator_vec``); ``--engine jit`` through the fully-
compiled ``jax.lax.while_loop`` backend (``core.simulator_jit``,
statistically equivalent RNG contract).  ``--devices N`` shards the
jit engine's point axis over N logical host devices (bit-identical
results and shared cache entries at any count — a pure throughput
knob; see docs/performance.md).  Each engine has its own cache
namespace, see docs/performance.md.  ``--scenario NAME`` runs the
scenario-capable sim figures (fig8/fig9/fig10) under a declarative
fault/demand scenario (``repro.scenarios``, e.g. ``heavy_tail`` or
``faults@0.5``; see docs/scenarios.md) — fig13 sweeps the whole
``faults@<x>`` family itself.  ``--smoke`` runs a 2-point sweep
end-to-end (used by CI).
"""
from __future__ import annotations

import argparse
import sys


def smoke(engine: str = "event", devices=None, scenario=None,
          **campaign_kw) -> None:
    """Tiny end-to-end campaign: 2 points through the full engine path."""
    from repro.core import Policy
    from repro.experiments import Campaign, Sweep
    sweep = Sweep(name="smoke", policies=(Policy.mesc(),), utils=(0.7,),
                  n_sets=2, duration=2e6, engine=engine,
                  devices=devices, scenario=scenario)
    camp = Campaign(sweep, **campaign_kw)
    rows = camp.collect()
    print("point,policy,u,seed,jobs,success_all")
    for r in rows:
        print(f"{r['set_index']},{r['policy']},{r['u']},{r['seed']},"
              f"{r['jobs_lo'] + r['jobs_hi']},{r['success_all']}")
    print(f"smoke,0.0,points={len(rows)};hits={camp.stats['hits']};"
          f"misses={camp.stats['misses']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale experiment sizes (1000 task sets)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset (fig2,fig6,fig7,fig8,"
                         "fig9,fig10,fig11,fig12,fig13,overhead,"
                         "roofline)")
    ap.add_argument("--workers", type=int, default=None,
                    help="worker processes per campaign "
                         "(default: CPU count / $REPRO_WORKERS)")
    ap.add_argument("--cache-dir", default=None,
                    help="result-cache root (default: results/campaigns "
                         "/ $REPRO_CACHE_DIR)")
    ap.add_argument("--no-cache", action="store_true",
                    help="always re-simulate; write nothing to disk")
    ap.add_argument("--smoke", action="store_true",
                    help="run a tiny 2-point campaign and exit (CI)")
    ap.add_argument("--engine", default="event",
                    choices=("event", "vec", "jit"),
                    help="simulation backend for the sim sweeps "
                         "(vec = vectorized batch engine, jit = fully-"
                         "compiled jax.lax.while_loop backend)")
    ap.add_argument("--devices", type=int, default=None,
                    help="logical host devices the jit engine shards "
                         "points over (requires --engine jit; results "
                         "and cache entries are identical at any "
                         "count)")
    ap.add_argument("--scenario", default=None,
                    help="declarative fault/demand scenario for the "
                         "scenario-capable sim figures (fig8/fig9/"
                         "fig10) and --smoke; a registry name like "
                         "'heavy_tail' or 'faults@<intensity>' — "
                         "unknown names fail loudly")
    args = ap.parse_args()
    if args.devices is not None and args.engine != "jit":
        ap.error("--devices requires --engine jit")
    if args.scenario is not None:      # fail loudly before any campaign
        from repro.scenarios import get_scenario
        try:
            get_scenario(args.scenario)
        except ValueError as e:
            ap.error(str(e))
    campaign_kw = dict(workers=args.workers, cache_dir=args.cache_dir,
                       use_cache=not args.no_cache)

    if args.smoke:
        smoke(engine=args.engine, devices=args.devices,
              scenario=args.scenario, **campaign_kw)
        return

    from benchmarks import (fig2_instruction_costs, fig6_banks,
                            fig7_blocking, fig8_success, fig9_hi_success,
                            fig10_survivability, fig11_multiacc,
                            fig12_serving_slo, fig13_fault_survivability,
                            tbl_overhead, roofline)
    table = {
        "fig2": fig2_instruction_costs.main,
        "fig6": fig6_banks.main,
        "fig7": fig7_blocking.main,
        "fig8": fig8_success.main,
        "fig9": fig9_hi_success.main,
        "fig10": fig10_survivability.main,
        "fig11": fig11_multiacc.main,
        "fig12": fig12_serving_slo.main,
        "fig13": fig13_fault_survivability.main,
        "overhead": tbl_overhead.main,
        "roofline": roofline.main,
    }
    # sim figures that take a scenario axis (the rest are scenario-free
    # analyses; --scenario leaves them untouched)
    scenario_figs = {"fig8", "fig9", "fig10"}
    only = args.only.split(",") if args.only else list(table)
    print("name,us_per_call,derived")
    for name in only:
        print(f"# === {name} ===", file=sys.stderr)
        kw = dict(campaign_kw)
        if args.scenario is not None and name in scenario_figs:
            kw["scenario"] = args.scenario
        try:
            table[name](full=args.full, engine=args.engine,
                        devices=args.devices, **kw)
        except Exception as e:  # repro-lint: disable=except-breadth (CLI boundary: one broken figure must not kill the sweep; the error lands in the CSV row)
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
