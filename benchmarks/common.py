"""Shared helpers for the paper-figure benchmarks.

The figures themselves are thin declarations over the campaign engine
(``repro.experiments``): each defines a ``Sweep``/``FuncSweep`` plus a
report function that aggregates the engine's tidy rows.  This module
keeps the cross-figure constants (set counts, utilisation grid), the
CSV summary emitter, and ``run_many`` — the original serial loop, kept
as the reference implementation the engine is tested against
(tests/test_experiments.py asserts bit-identical metrics).
"""
from __future__ import annotations

import time
from typing import List

from repro.core import Policy, generate_taskset, simulate
from repro.experiments.runner import cached_library

DEFAULT_SETS = 100          # paper: 1000 (use --full)
UTILS = (0.5, 0.6, 0.7, 0.8, 0.9, 0.95)


def run_many(policy: Policy, *, n_sets: int, u: float, gamma: float = 0.5,
             n_tasks: int = 10, duration: float = 2e8, cf: float = 2.0,
             overrun_prob: float = 0.3, seed0: int = 0) -> List:
    """Legacy serial reference: the engine's per-point seeding contract
    (``point_seed(seed0, s) == seed0 + s`` for taskset AND simulator)
    reproduces this loop exactly."""
    lib = cached_library("sim")
    out = []
    for s in range(n_sets):
        tasks = generate_taskset(u, gamma=gamma, n_tasks=n_tasks, cf=cf,
                                 seed=seed0 + s, programs=lib)
        out.append(simulate(tasks, lib, policy, duration=duration,
                            seed=seed0 + s, overrun_prob=overrun_prob,
                            cf=cf))
    return out


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
