"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import Policy, generate_taskset, simulate, workload_library

LIB = workload_library(include_archs=True)
SIM_LIB = {k: v for k, v in LIB.items() if not k.startswith("arch:")}

DEFAULT_SETS = 100          # paper: 1000 (use --full)
UTILS = (0.5, 0.6, 0.7, 0.8, 0.9, 0.95)


def run_many(policy: Policy, *, n_sets: int, u: float, gamma: float = 0.5,
             n_tasks: int = 10, duration: float = 2e8, cf: float = 2.0,
             overrun_prob: float = 0.3, seed0: int = 0) -> List:
    out = []
    for s in range(n_sets):
        tasks = generate_taskset(u, gamma=gamma, n_tasks=n_tasks, cf=cf,
                                 seed=seed0 + s, programs=SIM_LIB)
        out.append(simulate(tasks, SIM_LIB, policy, duration=duration,
                            seed=seed0 + s, overrun_prob=overrun_prob,
                            cf=cf))
    return out


def mean(xs) -> float:
    return float(np.mean(xs)) if len(xs) else 0.0


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
