"""Jit (fully-compiled) engine contract suite.

Pins the two halves of the jit backend's RNG-equivalence contract
(core/simulator_jit.py): bit-exact equality with the NumPy vec engine
on the zero-jitter ``demand_profile="nominal"`` corpus (no in-loop
draws exist there), and statistical equality on the sampled corpus
(counter-based splitmix64 draws, same distributions, different
realizations).  Also covers the overflow-retry ladder's bookkeeping,
batch-composition independence, the deprecated ``"jax"`` alias, and
the JAX-absent import guard.

Compilation note: each (policy-config, corpus-shape) pair compiles the
whole lockstep while_loop once per process (~tens of seconds), so the
tests below deliberately share two corpora — keep it that way when
adding cases.
"""
import dataclasses
import importlib
import sys

import numpy as np
import pytest

from repro.core import Policy, generate_taskset, simulate
from repro.core import simulator_jit as sj
from repro.core.simulator import AggSamples
from repro.core.simulator_vec import simulate_vbatch
from repro.experiments.metrics import metrics_row
from repro.experiments.runner import cached_library

LIB = cached_library("sim")

# shared corpora (see module docstring): one homogeneous fig8-style
# batch for the mesc tests, one mixed-size batch for the policy sweep
SIZES = [3, 10, 6, 13]
MIXED_TS = [generate_taskset(0.9, seed=s, n_tasks=n, programs=LIB)
            for s, n in enumerate(SIZES)]
MIXED_SEEDS = list(range(len(SIZES)))

FIG8_TS, FIG8_SEEDS = [], []
for u in (0.7, 0.9):
    for s in range(16):
        FIG8_TS.append(generate_taskset(u, seed=s, n_tasks=10,
                                        programs=LIB))
        FIG8_SEEDS.append(s)


def rows(ms):
    return [metrics_row(m) for m in ms]


class TestZeroJitterExactEquivalence:
    """No in-loop draws on the nominal profile -> jit == vec exactly."""

    def test_mesc_fig8_corpus_exact(self):
        a = simulate_vbatch(FIG8_TS, LIB, Policy.mesc(), seeds=FIG8_SEEDS,
                            duration=2e6, demand_profile="nominal")
        b = simulate_vbatch(FIG8_TS, LIB, Policy.mesc(), seeds=FIG8_SEEDS,
                            duration=2e6, demand_profile="nominal",
                            select_backend="jit")
        assert rows(a) == rows(b)

    @pytest.mark.parametrize("policy", [
        dataclasses.replace(Policy.mesc(use_banks=False), name="mesc-noB"),
        Policy(preemption="none", drop_lo_in_hi=True, name="amc-np"),
        Policy(preemption="operator", name="lp"),
    ], ids=lambda p: p.name)
    def test_policy_variants_mixed_sizes_exact(self, policy):
        """Bank-less save path, AMC drop + non-preemptive, operator
        boundaries — on one padded mixed-n_tasks batch."""
        a = simulate_vbatch(MIXED_TS, LIB, policy, seeds=MIXED_SEEDS,
                            duration=4e6, demand_profile="nominal")
        b = simulate_vbatch(MIXED_TS, LIB, policy, seeds=MIXED_SEEDS,
                            duration=4e6, demand_profile="nominal",
                            select_backend="jit")
        assert rows(a) == rows(b)

    def test_nominal_vec_matches_event_nominal_semantics(self):
        """The nominal profile itself is engine-consistent: the NumPy
        vec engine with nominal demand is still a valid simulation
        (sanity for the gate's reference side)."""
        ms = simulate_vbatch(FIG8_TS[:4], LIB, Policy.mesc(),
                             seeds=FIG8_SEEDS[:4], duration=2e6,
                             demand_profile="nominal")
        for m in ms:
            assert m.jobs["LO"] + m.jobs["HI"] > 0
            assert m.exec_cycles > 0


class TestStatisticalEquivalence:
    """Sampled profile: distributions equal, realizations differ."""

    def test_fig8_success_rates_within_ci(self):
        from benchmarks.perf_sim import binomial_bound
        v = simulate_vbatch(FIG8_TS, LIB, Policy.mesc(), seeds=FIG8_SEEDS,
                            duration=2e7)
        j = simulate_vbatch(FIG8_TS, LIB, Policy.mesc(), seeds=FIG8_SEEDS,
                            duration=2e7, select_backend="jit")
        rv, rj = rows(v), rows(j)
        n = len(rv)
        for field in ("success_all", "success_hi"):
            pv = sum(r[field] for r in rv) / n
            pj = sum(r[field] for r in rj) / n
            bound = binomial_bound(0.5 * (pv + pj), n)
            assert abs(pv - pj) <= bound, (field, pv, pj, bound)
        # volume metrics agree to a few percent on the pooled corpus
        for field in ("jobs_lo", "jobs_hi", "exec_cycles"):
            sv = sum(r[field] for r in rv)
            sj_ = sum(r[field] for r in rj)
            assert sv > 0
            assert abs(sv - sj_) / sv < 0.06, (field, sv, sj_)

    def test_deterministic_and_composition_independent(self):
        """Counter-based RNG: same point -> same result, regardless of
        run repetition or batch order."""
        a = simulate_vbatch(FIG8_TS, LIB, Policy.mesc(),
                            seeds=FIG8_SEEDS, duration=2e7,
                            select_backend="jit")
        b = simulate_vbatch(FIG8_TS, LIB, Policy.mesc(),
                            seeds=FIG8_SEEDS, duration=2e7,
                            select_backend="jit")
        assert rows(a) == rows(b)
        rev = simulate_vbatch(FIG8_TS[::-1], LIB, Policy.mesc(),
                              seeds=FIG8_SEEDS[::-1], duration=2e7,
                              select_backend="jit")
        assert rows(rev)[::-1] == rows(a)


class TestAggSamples:
    def test_metrics_row_consumes_aggregates(self):
        from repro.core.simulator import RunMetrics
        m = RunMetrics(pi_blocking=AggSamples(12.5, 3),
                       ci_blocking=AggSamples(0.0, 0))
        row = metrics_row(m)
        assert row["pi_sum"] == 12.5 and row["pi_n"] == 3
        assert row["ci_sum"] == 0.0 and row["ci_n"] == 0

    def test_jit_returns_aggregates(self):
        m = simulate_vbatch(FIG8_TS[:1], LIB, Policy.mesc(),
                            seeds=FIG8_SEEDS[:1], duration=2e6,
                            demand_profile="nominal",
                            select_backend="jit")[0]
        assert isinstance(m.pi_blocking, AggSamples)
        assert isinstance(m.save_cycles, AggSamples)
        assert len(m.save_cycles) == m.cs_count


class TestOverflowRetryLadder:
    """_run_chunk bookkeeping, with _run_once stubbed (no compiles)."""

    def test_selective_retry_merges_and_widens(self, monkeypatch):
        calls = []

        def run_once(b, policy, seeds, duration, op, cf, nominal, K):
            # odd-seed points overflow the primary table width only
            calls.append((list(seeds), K))
            return {"overflow": np.array([K <= sj._K0 and s % 2 == 1
                                          for s in seeds]),
                    "seeds": list(seeds)}

        monkeypatch.setattr(sj, "_run_once", run_once)
        monkeypatch.setattr(
            sj, "_assemble",
            lambda b, final, duration: [f"m{s}" for s in final["seeds"]])
        monkeypatch.setattr(sj, "_RETRY_BUCKET", 4)
        out = sj._run_chunk(MIXED_TS, LIB, Policy.mesc(), [0, 1, 2, 3],
                            4e6, 0.3, 2.0, "sampled")
        # odd seeds overflowed at K0 and were re-run once, wider
        assert out == ["m0", "m1", "m2", "m3"]
        assert len(calls) == 2
        assert calls[0] == ([0, 1, 2, 3], sj._K0)
        retry_seeds, retry_k = calls[1]
        assert retry_k == 2 * sj._K0
        # padded to the retry bucket with copies of the last point
        assert retry_seeds == [1, 3, 3, 3]

    def test_ladder_gives_up_past_kmax(self, monkeypatch):
        monkeypatch.setattr(
            sj, "_run_once",
            lambda b, policy, seeds, duration, op, cf, nominal, K:
            {"overflow": np.ones(b.P, bool), "seeds": list(seeds)})
        monkeypatch.setattr(
            sj, "_assemble", lambda b, final, duration: [None] * b.P)
        with pytest.raises(RuntimeError, match="exceeded"):
            sj._run_chunk(MIXED_TS[:1], LIB, Policy.mesc(), [0],
                          1e6, 0.3, 2.0, "sampled")


class TestBackendSelection:
    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError, match="unknown select_backend"):
            simulate_vbatch(MIXED_TS[:1], LIB, Policy.mesc(), seeds=[0],
                            duration=1e5, select_backend="cuda")

    def test_unknown_demand_profile_raises(self):
        with pytest.raises(ValueError, match="unknown demand_profile"):
            simulate_vbatch(MIXED_TS[:1], LIB, Policy.mesc(), seeds=[0],
                            duration=1e5, demand_profile="worst")

    def test_jax_alias_routes_to_jit(self):
        a = simulate_vbatch(FIG8_TS[:2], LIB, Policy.mesc(),
                            seeds=FIG8_SEEDS[:2], duration=2e6,
                            demand_profile="nominal",
                            select_backend="jit")
        b = simulate_vbatch(FIG8_TS[:2], LIB, Policy.mesc(),
                            seeds=FIG8_SEEDS[:2], duration=2e6,
                            demand_profile="nominal",
                            select_backend="jax")
        assert rows(a) == rows(b)

    def test_mismatched_seed_count_raises(self):
        with pytest.raises(ValueError, match="tasksets vs"):
            simulate_vbatch(MIXED_TS, LIB, Policy.mesc(), seeds=[0],
                            duration=1e5, select_backend="jit")


class TestPerfHarnessEquivalenceGate:
    """benchmarks.perf_sim's gating check on a micro corpus (reuses
    the shapes compiled above)."""

    def test_check_equivalence_micro(self):
        from benchmarks.perf_sim import check_equivalence
        spec = dict(utils=(0.7, 0.9), n_sets=16, duration=2e6,
                    n_tasks=10)
        report = check_equivalence(spec)
        assert report["vec_mismatched_points"] == 0
        assert report["jit_nominal_mismatched_points"] == 0
        assert report["jit_statistical_ok"]


# keep last: reloads simulator_jit, which clears its compilation cache
class TestJaxAbsentGuard:
    def test_module_imports_and_fails_actionably_without_jax(self):
        class _Block:
            def find_spec(self, name, path=None, target=None):
                if name == "jax" or name.startswith("jax."):
                    raise ImportError("jax blocked by test")
                return None

        saved = {k: sys.modules.pop(k) for k in list(sys.modules)
                 if k == "jax" or k.startswith("jax.")}
        blocker = _Block()
        sys.meta_path.insert(0, blocker)
        try:
            mod = importlib.reload(sj)
            assert mod.jax is None          # import still succeeded
            with pytest.raises(RuntimeError, match="install jax"):
                mod.require_jax("jit")
            # the public entry point surfaces the same actionable error
            with pytest.raises(RuntimeError, match="select_backend='jit'"):
                simulate_vbatch(MIXED_TS[:1], LIB, Policy.mesc(),
                                seeds=[0], duration=1e5,
                                select_backend="jit")
        finally:
            sys.meta_path.remove(blocker)
            sys.modules.update(saved)
            importlib.reload(sj)
        assert sj.jax is not None