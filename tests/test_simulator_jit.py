"""Jit (fully-compiled) engine contract suite.

Pins the two halves of the jit backend's RNG-equivalence contract
(core/simulator_jit.py): bit-exact equality with the NumPy vec engine
on the zero-jitter ``demand_profile="nominal"`` corpus (no in-loop
draws exist there), and statistical equality on the sampled corpus
(counter-based splitmix64 draws, same distributions, different
realizations).  Also covers the overflow-retry ladder's bookkeeping,
batch-composition independence, the deprecated ``"jax"`` alias, and
the JAX-absent import guard.  All cross-engine gates go through the
shared :mod:`harness` EngineCase family, so the sharded variants in
``tests/test_device_sharding.py`` are the same fixtures at another
device count.

Compilation note: each (policy-config, corpus-shape, device-count)
tuple compiles the whole lockstep while_loop once per process (seconds
each), so the tests below deliberately share the two harness corpora —
keep it that way when adding cases.
"""
import dataclasses
import importlib
import math
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from harness import (EngineCase, LIB, assert_bit_exact,
                     assert_deterministic, assert_statistical_close,
                     fig8_corpus, mixed_corpus, rows, run_case)
from repro.core import Policy, generate_taskset
from repro.core import simulator_jit as sj
from repro.core.simulator import AggSamples
from repro.core.simulator_vec import simulate_vbatch
from repro.experiments.metrics import metrics_row

# shared corpora (see module docstring): one homogeneous fig8-style
# batch for the mesc tests, one mixed-size batch for the policy sweep
MIXED_TS, MIXED_SEEDS = mixed_corpus()
FIG8_TS, FIG8_SEEDS = fig8_corpus()

VEC_NOM = EngineCase("vec-nominal", engine="vec",
                     demand_profile="nominal")
JIT_NOM = EngineCase("jit-nominal", demand_profile="nominal")
JIT = EngineCase("jit")


class TestZeroJitterExactEquivalence:
    """No in-loop draws on the nominal profile -> jit == vec exactly,
    at any device count (the fixture family's sharded leg)."""

    @pytest.mark.parametrize("case", [
        JIT_NOM,
        EngineCase("jit-nominal-d2", demand_profile="nominal",
                   devices=2),
    ], ids=str)
    def test_mesc_fig8_corpus_exact(self, case):
        a = run_case(VEC_NOM, FIG8_TS, FIG8_SEEDS, Policy.mesc(),
                     duration=2e6)
        b = run_case(case, FIG8_TS, FIG8_SEEDS, Policy.mesc(),
                     duration=2e6)
        assert_bit_exact(a, b, case.name)

    @pytest.mark.parametrize("policy", [
        dataclasses.replace(Policy.mesc(use_banks=False), name="mesc-noB"),
        Policy(preemption="none", drop_lo_in_hi=True, name="amc-np"),
        Policy(preemption="operator", name="lp"),
    ], ids=lambda p: p.name)
    def test_policy_variants_mixed_sizes_exact(self, policy):
        """Bank-less save path, AMC drop + non-preemptive, operator
        boundaries — on one padded mixed-n_tasks batch."""
        a = run_case(VEC_NOM, MIXED_TS, MIXED_SEEDS, policy,
                     duration=4e6)
        b = run_case(JIT_NOM, MIXED_TS, MIXED_SEEDS, policy,
                     duration=4e6)
        assert_bit_exact(a, b, policy.name)

    def test_nominal_vec_matches_event_nominal_semantics(self):
        """The nominal profile itself is engine-consistent: the NumPy
        vec engine with nominal demand is still a valid simulation
        (sanity for the gate's reference side)."""
        ms = simulate_vbatch(FIG8_TS[:4], LIB, Policy.mesc(),
                             seeds=FIG8_SEEDS[:4], duration=2e6,
                             demand_profile="nominal")
        for m in ms:
            assert m.jobs["LO"] + m.jobs["HI"] > 0
            assert m.exec_cycles > 0


class TestStatisticalEquivalence:
    """Sampled profile: distributions equal, realizations differ."""

    def test_fig8_success_rates_within_ci(self):
        v = run_case(EngineCase("vec", engine="vec"), FIG8_TS,
                     FIG8_SEEDS, Policy.mesc(), duration=2e7)
        j = run_case(JIT, FIG8_TS, FIG8_SEEDS, Policy.mesc(),
                     duration=2e7)
        assert_statistical_close(v, j)

    def test_deterministic_and_composition_independent(self):
        """Counter-based RNG: same point -> same result, regardless of
        run repetition or batch order."""
        assert_deterministic(JIT, FIG8_TS, FIG8_SEEDS, Policy.mesc(),
                             duration=2e7)


class TestAggSamples:
    def test_metrics_row_consumes_aggregates(self):
        from repro.core.simulator import RunMetrics
        m = RunMetrics(pi_blocking=AggSamples(12.5, 3),
                       ci_blocking=AggSamples(0.0, 0))
        row = metrics_row(m)
        assert row["pi_sum"] == 12.5 and row["pi_n"] == 3
        assert row["ci_sum"] == 0.0 and row["ci_n"] == 0

    def test_empty_aggregate_mean_is_nan_not_crash(self):
        """A run with zero blocking/save events is normal: the mean
        must come back NaN (AggSamples.mean) / None (the row's JSON-
        safe spelling), never ZeroDivisionError."""
        import json
        from repro.core.simulator import RunMetrics
        assert math.isnan(AggSamples(0.0, 0).mean)
        assert AggSamples(9.0, 3).mean == 3.0
        m = RunMetrics(pi_blocking=AggSamples(12.0, 4),
                       ci_blocking=AggSamples(0.0, 0),
                       save_cycles=[], restore_cycles=[])
        row = metrics_row(m)                  # must not raise
        assert row["pi_mean"] == 3.0
        assert row["ci_mean"] is None         # empty aggregate
        assert row["restore_mean"] is None    # empty list form
        # the tidy-row collector's storage format round-trips it
        assert json.loads(json.dumps(row)) == row
        # and row equality (the cross-engine gates) still works
        assert row == metrics_row(m)

    def test_jit_returns_aggregates(self):
        m = simulate_vbatch(FIG8_TS[:1], LIB, Policy.mesc(),
                            seeds=FIG8_SEEDS[:1], duration=2e6,
                            demand_profile="nominal",
                            select_backend="jit")[0]
        assert isinstance(m.pi_blocking, AggSamples)
        assert isinstance(m.save_cycles, AggSamples)
        assert len(m.save_cycles) == m.cs_count


class TestOverflowRetryLadder:
    """_run_chunk bookkeeping, with _run_once stubbed (no compiles).
    The sharded handoff (first dispatch sharded, retries single-
    device) is pinned in tests/test_device_sharding.py."""

    def test_selective_retry_merges_and_widens(self, monkeypatch):
        calls = []

        def run_once(b, policy, seeds, duration, op, cf, nominal, K,
                     devices=1, scenario=None):
            # odd-seed points overflow the primary table width only
            calls.append((list(seeds), K))
            return {"overflow": np.array([K <= sj._K0 and s % 2 == 1
                                          for s in seeds]),
                    "seeds": list(seeds)}

        monkeypatch.setattr(sj, "_run_once", run_once)
        monkeypatch.setattr(
            sj, "_assemble",
            lambda b, final, duration: [f"m{s}" for s in final["seeds"]])
        monkeypatch.setattr(sj, "_RETRY_BUCKET", 4)
        out = sj._run_chunk(MIXED_TS, LIB, Policy.mesc(), [0, 1, 2, 3],
                            4e6, 0.3, 2.0, "sampled")
        # odd seeds overflowed at K0 and were re-run once, wider
        assert out == ["m0", "m1", "m2", "m3"]
        assert len(calls) == 2
        assert calls[0] == ([0, 1, 2, 3], sj._K0)
        retry_seeds, retry_k = calls[1]
        assert retry_k == 2 * sj._K0
        # padded to the retry bucket with copies of the last point
        assert retry_seeds == [1, 3, 3, 3]

    def test_ladder_gives_up_past_kmax(self, monkeypatch):
        """Exhaustion is a loud, point-identified error — never metrics
        from a saturated table."""
        monkeypatch.setattr(
            sj, "_run_once",
            lambda b, policy, seeds, duration, op, cf, nominal, K,
            devices=1, scenario=None:
            {"overflow": np.ones(b.P, bool), "seeds": list(seeds)})
        monkeypatch.setattr(
            sj, "_assemble", lambda b, final, duration: [None] * b.P)
        with pytest.raises(RuntimeError) as ei:
            sj._run_chunk(MIXED_TS[:2], LIB, Policy.mesc(), [7, 9],
                          1e6, 0.3, 2.0, "sampled", point_ids=[40, 41])
        msg = str(ei.value)
        assert "overflowed at the maximum width" in msg
        # both points named with their global taskset index + seed
        assert "(taskset 40, seed 7)" in msg
        assert "(taskset 41, seed 9)" in msg
        assert "REPRO_JIT_TABLE_MAX" in msg

    def test_real_exhaustion_with_tiny_starting_width(self, monkeypatch):
        """Regression for the saturated-table bug: a real run whose
        table can never fit (width ladder capped at 1) must raise the
        point-identified error instead of returning metrics."""
        monkeypatch.setenv("REPRO_JIT_TABLE_WIDTH", "1")
        monkeypatch.setenv("REPRO_JIT_TABLE_MAX", "1")
        with pytest.raises(RuntimeError) as ei:
            simulate_vbatch(FIG8_TS[:1], LIB, Policy.mesc(),
                            seeds=FIG8_SEEDS[:1], duration=2e6,
                            demand_profile="nominal",
                            select_backend="jit")
        msg = str(ei.value)
        assert "overflowed at the maximum width 1" in msg
        assert f"seed {FIG8_SEEDS[0]}" in msg


class TestEnvKnobs:
    """REPRO_* env overrides reject junk loudly (a bad value must not
    crash with a bare int() traceback or silently misconfigure the
    device pool / retry ladder)."""

    @pytest.mark.parametrize("bad", ["abc", "1.5", "0", "-2", "2x"])
    def test_entry_point_rejects_junk_devices(self, monkeypatch, bad):
        """The engine entry validates REPRO_DEVICES before any
        dispatch (the knob's own suite is tests/test_device_config.py)
        — a junk pool size must never start a campaign."""
        monkeypatch.setenv("REPRO_DEVICES", bad)
        with pytest.raises(ValueError, match="REPRO_DEVICES"):
            simulate_vbatch(MIXED_TS[:1], LIB, Policy.mesc(), seeds=[0],
                            duration=1e5, select_backend="jit")

    def test_explicit_single_device_skips_env_default(self, monkeypatch):
        """devices=1 is the no-sharding fast path: it must not consult
        (or trip over) the env default at all."""
        monkeypatch.setenv("REPRO_DEVICES", "junk")
        out = simulate_vbatch(FIG8_TS[:1], LIB, Policy.mesc(),
                              seeds=FIG8_SEEDS[:1], duration=2e6,
                              demand_profile="nominal",
                              select_backend="jit", devices=1)
        assert len(out) == 1

    @pytest.mark.parametrize("var,fn", [
        ("REPRO_JIT_TABLE_WIDTH", sj._table_width),
        ("REPRO_JIT_TABLE_MAX", lambda: sj._table_max(1)),
    ])
    def test_table_knobs_reject_junk(self, monkeypatch, var, fn):
        monkeypatch.setenv(var, "many")
        with pytest.raises(ValueError, match=var):
            fn()
        monkeypatch.setenv(var, "0")
        with pytest.raises(ValueError, match=var):
            fn()


class TestStaleInterruptPruning:
    """The pruning pass (proof in core/simulator_jit.py's docstring)
    must be invisible in results: pruned entries are exactly the
    no-op pops, so the pruned jit engine stays bit-exact vs the
    unpruned NumPy vec engine on nominal points — across policies and
    forced-high table occupancies — and bit-identical to its own
    unpruned graph."""

    PRUNE_POLICIES = [Policy.mesc(),
                      Policy(preemption="none", drop_lo_in_hi=True,
                             name="amc-np")]
    PROP_TS = [generate_taskset(0.9, seed=100 + s, n_tasks=6,
                                programs=LIB) for s in range(4)]

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2 ** 20), u=st.floats(0.6, 0.95),
           pol=st.integers(0, 1), k0=st.integers(1, 2))
    def test_pruned_jit_bit_exact_vs_unpruned_vec(self, seed, u, pol,
                                                  k0):
        """Property: random point content, random policy, and a tiny
        starting table width (forcing high relative occupancy + the
        retry ladder) — rows must equal the vec engine's exactly."""
        policy = self.PRUNE_POLICIES[pol]
        ts = list(self.PROP_TS)
        ts[0] = generate_taskset(u, seed=seed, n_tasks=6, programs=LIB)
        seeds = [seed, 1, 2, 3]
        ref = run_case(VEC_NOM, ts, seeds, policy, duration=3e5)
        old_bucket = sj._RETRY_BUCKET
        sj._RETRY_BUCKET = 4
        try:
            out = run_case(
                EngineCase("jit-nominal-narrow",
                           demand_profile="nominal",
                           table_width=2 ** k0),
                ts, seeds, policy, duration=3e5)
        finally:
            sj._RETRY_BUCKET = old_bucket
        assert_bit_exact(ref, out, "pruned jit vs unpruned vec")

    def test_prune_toggle_bit_identical(self):
        """Pruning removes only dead pops: the unpruned compiled graph
        produces bit-identical metrics (sampled profile, so demand
        draws and the full event mix are exercised)."""
        a = run_case(JIT, FIG8_TS[:16], FIG8_SEEDS[:16], Policy.mesc(),
                     duration=2e6)
        assert sj._PRUNE_STALE is True
        sj._PRUNE_STALE = False
        try:
            b = run_case(JIT, FIG8_TS[:16], FIG8_SEEDS[:16],
                         Policy.mesc(), duration=2e6)
        finally:
            sj._PRUNE_STALE = True
        assert_bit_exact(a, b, "prune toggle")

    def test_kernel_count_reported(self):
        """The grouped-carry step's per-step kernel count is queryable
        (perf_sim logs it into BENCH_sim.json); the pre-refactor
        engine compiled to ~143 body kernels at this shape — the
        grouped carry must stay well under that."""
        n = sj.lockstep_kernel_count(FIG8_TS[:8], LIB, Policy.mesc(),
                                     seeds=FIG8_SEEDS[:8],
                                     duration=2e6)
        assert 0 < n < 140


class TestPerfDeltaSchemaGuard:
    """print_delta vs an old-schema baseline: warn + skip, no KeyError
    (regression: v1 entries lack the per-engine layout)."""

    def test_v1_baseline_skipped_with_warning(self, capsys):
        import json
        from pathlib import Path
        from benchmarks.perf_sim import print_delta
        stub = json.loads(
            (Path(__file__).parent / "data"
             / "BENCH_sim_v1_stub.json").read_text())
        new = {"engines": {e: {"points_per_sec": 100.0,
                               "spread_pct": 1.0}
                           for e in ("event", "vec", "jit")}}
        print_delta("full", new, stub)          # must not raise
        out = capsys.readouterr().out
        assert "schema v1" in out and "skipping perf delta" in out
        assert "perf_delta" not in out

    def test_current_schema_still_diffs(self, capsys):
        from benchmarks.perf_sim import SCHEMA_VERSION, print_delta
        base = {"schema_version": SCHEMA_VERSION,
                "sections": {"full": {"engines": {
                    "event": {"points_per_sec": 50.0},
                    "vec": {"points_per_sec": 100.0},
                    "jit": {"points_per_sec": 200.0}}}}}
        new = {"engines": {e: {"points_per_sec": 110.0,
                               "spread_pct": 2.0}
                           for e in ("event", "vec", "jit")}}
        print_delta("full", new, base)
        out = capsys.readouterr().out
        assert out.count("perf_delta,full") == 3


class TestBackendSelection:
    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError, match="unknown select_backend"):
            simulate_vbatch(MIXED_TS[:1], LIB, Policy.mesc(), seeds=[0],
                            duration=1e5, select_backend="cuda")

    def test_unknown_demand_profile_raises(self):
        with pytest.raises(ValueError, match="unknown demand_profile"):
            simulate_vbatch(MIXED_TS[:1], LIB, Policy.mesc(), seeds=[0],
                            duration=1e5, demand_profile="worst")

    def test_devices_require_jit_backend(self):
        with pytest.raises(ValueError, match="select_backend='jit'"):
            simulate_vbatch(MIXED_TS[:1], LIB, Policy.mesc(), seeds=[0],
                            duration=1e5, devices=2)

    def test_jax_alias_routes_to_jit(self):
        a = simulate_vbatch(FIG8_TS[:2], LIB, Policy.mesc(),
                            seeds=FIG8_SEEDS[:2], duration=2e6,
                            demand_profile="nominal",
                            select_backend="jit")
        b = simulate_vbatch(FIG8_TS[:2], LIB, Policy.mesc(),
                            seeds=FIG8_SEEDS[:2], duration=2e6,
                            demand_profile="nominal",
                            select_backend="jax")
        assert_bit_exact(rows(a), rows(b), "jax alias")

    def test_mismatched_seed_count_raises(self):
        with pytest.raises(ValueError, match="tasksets vs"):
            simulate_vbatch(MIXED_TS, LIB, Policy.mesc(), seeds=[0],
                            duration=1e5, select_backend="jit")


class TestPerfHarnessEquivalenceGate:
    """benchmarks.perf_sim's gating check on a micro corpus (reuses
    the shapes compiled above)."""

    SPEC = dict(utils=(0.7, 0.9), n_sets=16, duration=2e6, n_tasks=10)

    def test_check_equivalence_micro(self):
        from benchmarks.perf_sim import check_equivalence
        report = check_equivalence(dict(self.SPEC))
        assert report["vec_mismatched_points"] == 0
        assert report["jit_nominal_mismatched_points"] == 0
        assert report["jit_statistical_ok"]
        # devices defaulted to 1: the sharded gate reports skipped,
        # never a vacuous pass
        assert report["jit_devices"] == 1
        assert report["sharded_exact_match_points"] is None

    def test_check_equivalence_gates_sharded(self):
        from benchmarks.perf_sim import check_equivalence
        report = check_equivalence(dict(self.SPEC), devices=2)
        assert report["jit_devices"] == 2
        assert report["sharded_mismatched_points"] == 0
        assert report["sharded_exact_match_points"] == 32

    @pytest.mark.parametrize("empty", [dict(utils=(), n_sets=16),
                                       dict(utils=(0.7,), n_sets=0)])
    def test_empty_corpus_is_a_hard_error(self, empty):
        """An empty comparison set would vacuously pass every gate —
        the harness must die loudly, naming the section."""
        from benchmarks.perf_sim import check_equivalence
        spec = dict(self.SPEC, **empty)
        with pytest.raises(SystemExit,
                           match=r"corpus section 'smoke' is empty"):
            check_equivalence(spec, section="smoke")

    def test_partial_comparison_set_is_a_hard_error(self):
        """A truncated engine result list silently weakens every
        zip()-based gate — refuse it, naming set and section."""
        from benchmarks.perf_sim import check_equivalence
        with pytest.raises(SystemExit,
                           match=r"set 'event' has 1 results"):
            check_equivalence(dict(self.SPEC), section="full",
                              results={"event": [object()]})


# keep last: reloads simulator_jit, which clears its compilation cache
class TestJaxAbsentGuard:
    def test_module_imports_and_fails_actionably_without_jax(self):
        class _Block:
            def find_spec(self, name, path=None, target=None):
                if name == "jax" or name.startswith("jax."):
                    raise ImportError("jax blocked by test")
                return None

        saved = {k: sys.modules.pop(k) for k in list(sys.modules)
                 if k == "jax" or k.startswith("jax.")}
        blocker = _Block()
        sys.meta_path.insert(0, blocker)
        try:
            mod = importlib.reload(sj)
            assert mod.jax is None          # import still succeeded
            with pytest.raises(RuntimeError, match="install jax"):
                mod.require_jax("jit")
            # the public entry point surfaces the same actionable error
            with pytest.raises(RuntimeError, match="select_backend='jit'"):
                simulate_vbatch(MIXED_TS[:1], LIB, Policy.mesc(),
                                seeds=[0], duration=1e5,
                                select_backend="jit")
        finally:
            sys.meta_path.remove(blocker)
            sys.modules.update(saved)
            importlib.reload(sj)
        assert sj.jax is not None
