"""MESC scheduler/executor unit + property tests.

Covers: mode rules, AMC dropping, bank-allocation zero-copy fast path,
instruction/operator preemption bounds, and the simulator invariant that
MESC blocking is bounded by I(G) + T_sr + context-switch time.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (GemminiRT, Mode, Policy, TaskParams, TCB, Crit,
                        generate_taskset, simulate, workload_library)
from repro.core.isa import BANK_BYTES
from repro.core.program import build_program
from repro.core.scheduler import eligible_set, pick_next
from repro.core.task import Status

LIB = workload_library(include_archs=False)


def _tcb(tid, prio, crit, status=Status.READY, resident=False):
    p = TaskParams(tid=tid, priority=prio, period=1e6, deadline=1e6,
                   c_lo=1e4, c_hi=2e4, crit=crit, eta=1, workload="small_gemm")
    t = TCB(params=p, status=status)
    t.data_in_accel = resident
    return t


class TestModeRules:
    def test_lo_mode_priority_order(self):
        tcbs = {0: _tcb(0, 5, Crit.LO), 1: _tcb(1, 2, Crit.HI),
                2: _tcb(2, 1, Crit.LO)}
        nxt = pick_next(tcbs, Mode.LO, [], Policy.mesc())
        assert nxt.tid == 2  # highest priority wins regardless of crit

    def test_hi_mode_prefers_hi(self):
        tcbs = {0: _tcb(0, 1, Crit.LO), 1: _tcb(1, 9, Crit.HI)}
        nxt = pick_next(tcbs, Mode.HI, [], Policy.mesc())
        assert nxt.tid == 1  # HI beats higher-priority LO outside LO-mode

    def test_lo_runs_in_hi_mode_when_no_hi_active(self):
        """The imprecise-MCS stance: LO is never dropped (SS II.A)."""
        tcbs = {0: _tcb(0, 1, Crit.LO)}
        nxt = pick_next(tcbs, Mode.HI, [], Policy.mesc())
        assert nxt is not None and nxt.tid == 0

    def test_amc_drops_lo_outside_lo_mode(self):
        tcbs = {0: _tcb(0, 1, Crit.LO)}
        assert pick_next(tcbs, Mode.HI, [], Policy.amc()) is None
        assert pick_next(tcbs, Mode.LO, [], Policy.amc()).tid == 0

    def test_transition_only_resident_lo(self):
        tcbs = {0: _tcb(0, 1, Crit.LO, resident=False),
                1: _tcb(1, 2, Crit.LO, resident=True)}
        nxt = pick_next(tcbs, Mode.TRANS, [], Policy.mesc())
        assert nxt.tid == 1  # only not-yet-saved LO data may run

    def test_transition_two_resident_lo_stays_transition(self):
        """With two LO-tasks' data still in the accelerator the system
        must NOT advance to HI-mode (the <=1-resident-LO invariant)."""
        from repro.core.scheduler import update_mode
        tcbs = {0: _tcb(0, 1, Crit.LO, resident=True),
                1: _tcb(1, 2, Crit.LO, resident=True)}
        mode = update_mode(Mode.TRANS, tcbs, resident_lo=[0, 1],
                           any_active=True)
        assert mode == Mode.TRANS
        # both resident LO-tasks stay eligible (highest priority first)
        elig = eligible_set(tcbs, Mode.TRANS, [0, 1], Policy.mesc())
        assert {t.tid for t in elig} == {0, 1}
        # one save later the countdown completes -> HI-mode
        assert update_mode(Mode.TRANS, tcbs, resident_lo=[1],
                           any_active=True) == Mode.HI

    def test_idle_reverts_to_lo(self):
        """Idle system -> revert to LO-mode (HI directly; transition
        first completes its countdown to HI, then reverts)."""
        from repro.core.scheduler import update_mode
        assert update_mode(Mode.HI, {}, resident_lo=[],
                           any_active=False) == Mode.LO
        # transition: <=1 resident LO always advances to HI first...
        mid = update_mode(Mode.TRANS, {}, resident_lo=[], any_active=False)
        assert mid == Mode.HI
        # ...and the next scheduler invocation reverts the idle system
        assert update_mode(mid, {}, resident_lo=[],
                           any_active=False) == Mode.LO
        # never revert while work remains
        assert update_mode(Mode.HI, {}, resident_lo=[],
                           any_active=True) == Mode.HI


class TestModeCoordinator:
    """Per-instance mode machines + platform aggregation (platform layer)."""

    def test_platform_mode_is_most_severe(self):
        from repro.core.scheduler import ModeCoordinator
        co = ModeCoordinator(3)
        assert co.platform_mode() == Mode.LO
        co.set_mode(1, Mode.TRANS)
        assert co.platform_mode() == Mode.TRANS
        co.set_mode(2, Mode.HI)
        assert co.platform_mode() == Mode.HI
        assert co.degraded() == [1, 2]
        assert co.instances_in(Mode.LO) == [0]

    def test_per_instance_progression_is_independent(self):
        """An overrun on one instance must not degrade the others."""
        from repro.core.scheduler import ModeCoordinator
        co = ModeCoordinator(2)
        co.set_mode(0, Mode.TRANS)
        # instance 0: two resident LO -> stays in transition
        assert co.update_instance(0, {}, resident_lo=[7, 8],
                                  any_active=True) == Mode.TRANS
        # instance 1 stays untouched in LO
        assert co.mode_of(1) == Mode.LO
        # instance 0 completes its countdown -> HI; 1 still LO
        assert co.update_instance(0, {}, resident_lo=[8],
                                  any_active=True) == Mode.HI
        assert co.mode_of(1) == Mode.LO
        # idle -> both revert
        co.update_instance(0, {}, resident_lo=[], any_active=False)
        assert co.platform_mode() == Mode.LO


class TestBankAllocation:
    def test_zero_copy_when_banks_fit(self):
        acc = GemminiRT(use_remapper=True)
        prog = build_program("p", [(64, 64, 64)])
        t = _tcb(0, 1, Crit.LO)
        acc.note_execution(0, 1e5, prog)
        br_fit = acc.context_save(t, drain_cycles=10, next_eta=2)
        assert br_fit.scratchpad == 0          # zero-copy fast path
        assert t.data_in_accel                 # banks stay locked
        # without room, the scratchpad must be evacuated
        acc2 = GemminiRT(use_remapper=True)
        acc2.note_execution(0, 1e7, LIB["resnet50"])
        t2 = _tcb(0, 1, Crit.LO)
        br_full = acc2.context_save(t2, drain_cycles=10, next_eta=8)
        assert br_full.scratchpad > 0
        assert br_full.total > br_fit.total

    def test_save_restore_roundtrip(self):
        acc = GemminiRT()
        t = _tcb(3, 1, Crit.LO)
        acc.note_execution(3, 5e4, LIB["small_gemm"])
        acc.context_save(t, drain_cycles=0, next_eta=8)
        br = acc.context_restore(t)
        assert t.data_in_accel
        assert br.total >= 0

    def test_remapper_write_read_release(self):
        from repro.core.remapper import AddressRemapper
        r = AddressRemapper()
        r.write(1, 0, BANK_BYTES // 2)
        assert r.locked_banks() == 1
        assert r.resident_bytes(1) == BANK_BYTES // 2
        assert r.read(1, 0) is not None
        r.write(2, 0, 2 * BANK_BYTES)
        assert r.locked_banks() == 3
        r.release(1)
        assert r.locked_banks() == 2
        assert r.resident_bytes(1) == 0


class TestPrograms:
    def test_boundaries_monotone_and_bounded(self):
        prog = LIB["alexnet"]
        for off in (0.0, 1.0, 1234.5, prog.total_cycles * 0.7):
            nb = prog.next_instruction_boundary(off)
            assert nb > off
            assert nb - off <= prog.max_instruction_cycles
            ob = prog.next_operator_boundary(off)
            assert ob >= nb or ob >= prog.total_cycles * 0.99

    def test_fig2_hierarchy(self):
        """workload >> operator >> instruction cycles (paper Fig. 2)."""
        for name in ("alexnet", "resnet50", "transformer"):
            p = LIB[name]
            ops_sizes = p.operator_cycle_sizes()
            assert p.total_cycles > ops_sizes.max() > p.max_instruction_cycles
            assert p.total_cycles / p.max_instruction_cycles > 1e4

    def test_instruction_stream_consistent(self):
        p = LIB["small_gemm"]
        insts = list(p.instructions())
        assert len(insts) == p.n_instructions
        assert sum(i.cost for i in insts) == p.total_cycles


class TestSimulatorInvariants:
    def test_blocking_hierarchy(self):
        """MESC << limited << non-preemptive blocking (Fig. 1/2)."""
        tasks = generate_taskset(0.7, seed=3, programs=LIB)
        res = {}
        for pol in (Policy.mesc(), Policy.limited(), Policy.non_preemptive()):
            m = simulate(tasks, LIB, pol, duration=3e8, seed=2)
            blocks = m.pi_blocking + m.ci_blocking
            res[pol.name] = np.mean(blocks) if blocks else 0.0
        assert res["mesc"] < res["lp"] < res["np"]

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10 ** 6), u=st.floats(0.3, 0.8))
    def test_mesc_blocking_bounded(self, seed, u):
        """Under MESC, any single blocking interval is bounded by
        I(G) + T_sr + save + restore (the paper's Eq. 1 structure)."""
        tasks = generate_taskset(u, seed=seed, programs=LIB)
        m = simulate(tasks, LIB, Policy.mesc(), duration=1e8, seed=seed)
        max_inst = max(LIB[t.workload].max_instruction_cycles for t in tasks)
        save = max(m.save_cycles) if m.save_cycles else 0
        rest = max(m.restore_cycles) if m.restore_cycles else 0
        bound = max_inst + 5000 + save + rest + 5000
        for b in m.pi_blocking + m.ci_blocking:
            assert b <= bound + 1

    def test_overhead_below_5pct(self):
        """Paper abstract: < 5% overhead."""
        tasks = generate_taskset(0.6, seed=11, programs=LIB)
        m = simulate(tasks, LIB, Policy.mesc(), duration=3e8, seed=4)
        assert m.exec_cycles > 0
        assert m.overhead_cycles / m.exec_cycles < 0.05

    def test_amc_never_runs_lo_in_hi(self):
        tasks = generate_taskset(0.8, seed=5, programs=LIB)
        m = simulate(tasks, LIB, Policy.amc(), duration=2e8, seed=6)
        assert m.lo_released_in_hi == 0
