"""Vectorized-engine equivalence suite + perf-baseline regression.

The vectorized SoA backend (`core.simulator_vec`) claims bit-exact
per-run metrics against the event-driven engine — not "close", equal.
These tests pin that contract across policies, taskset shapes, seeds
and horizons (hypothesis-driven) through the shared :mod:`harness`
EngineCase family, pin the RNG identity the vectorized release path
relies on, the cache-key contract that keeps the three engines'
(event / vec / jit) campaign caches disjoint — including a committed
byte-stability fixture — and the committed ``BENCH_sim.json`` schema
that CI's perf-smoke job diffs against.  The jit backend's own
equivalence contract lives in ``tests/test_simulator_jit.py``, the
sharded-dispatch one in ``tests/test_device_sharding.py``.
"""
import dataclasses
import json
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from harness import (EngineCase, LIB, assert_bit_exact, mixed_corpus,
                     run_case)
from repro.core import Policy, generate_taskset, simulate
from repro.core.simulator import simulate_batch
from repro.core.simulator_vec import (VEC_SIM_SEMANTICS_VERSION, _VecBatch,
                                      simulate_vbatch)
from repro.experiments.metrics import metrics_row
from repro.experiments.spec import SimPoint, Sweep

REPO_ROOT = Path(__file__).resolve().parent.parent

EVENT = EngineCase("event", engine="event")
VEC = EngineCase("vec", engine="vec")

POLICIES = [Policy.mesc(), Policy.non_preemptive(), Policy.amc(),
            dataclasses.replace(Policy.mesc(use_banks=False),
                                name="mesc-noB"),
            Policy(preemption="operator", name="lp"),
            Policy(preemption="none", drop_lo_in_hi=True, name="amc-np")]


def both_engines(tasksets, seeds, policy, **kw):
    """Event- and vec-engine rows for one corpus (the exactness gate's
    two sides, as harness cases)."""
    return (run_case(EVENT, tasksets, seeds, policy, **kw),
            run_case(VEC, tasksets, seeds, policy, **kw))


class TestGoldenCorpusEquivalence:
    """Vec metrics == event metrics on every corpus point, exactly."""

    @pytest.mark.parametrize("policy", POLICIES, ids=lambda p: p.name)
    def test_policy_corpus_exact(self, policy):
        tasksets, seeds = [], []
        for u in (0.6, 0.95):
            for s in range(3):
                tasksets.append(generate_taskset(
                    u, seed=s, n_tasks=6, programs=LIB))
                seeds.append(s)
        ev, vc = both_engines(tasksets, seeds, policy, duration=6e6)
        assert_bit_exact(ev, vc, policy.name)

    def test_per_event_lists_exact(self):
        """Not just aggregates: the raw per-event metric lists (blocking
        intervals, save/restore breakdowns) match element for element."""
        tasksets = [generate_taskset(0.9, seed=s, n_tasks=8, programs=LIB)
                    for s in range(3)]
        ev = [simulate(ts, LIB, Policy.mesc(), seed=s, duration=2e7)
              for ts, s in zip(tasksets, [0, 1, 2])]
        vc = simulate_vbatch(tasksets, LIB, Policy.mesc(),
                             seeds=[0, 1, 2], duration=2e7)
        for a, b in zip(ev, vc):
            assert a.pi_blocking == b.pi_blocking
            assert a.ci_blocking == b.ci_blocking
            assert a.save_cycles == b.save_cycles
            assert a.restore_cycles == b.restore_cycles
            assert a.mode_cycles == b.mode_cycles
            assert a.exec_cycles == b.exec_cycles
            assert a.overhead_cycles == b.overhead_cycles

    def test_mixed_taskset_sizes_one_batch(self):
        """Padding: one lockstep batch with heterogeneous n_tasks."""
        tasksets, seeds = mixed_corpus(u=0.8)
        ev, vc = both_engines(tasksets, seeds, Policy.mesc(),
                              duration=8e6)
        assert_bit_exact(ev, vc, "mixed sizes")

    def test_matches_simulate_batch(self):
        """Drop-in for the serial batch entry point."""
        tasksets = [generate_taskset(0.7, seed=s, programs=LIB)
                    for s in range(2)]
        serial = simulate_batch(tasksets, LIB, Policy.mesc(),
                                seeds=[0, 1], duration=4e6)
        vec = simulate_vbatch(tasksets, LIB, Policy.mesc(),
                              seeds=[0, 1], duration=4e6)
        for a, b in zip(serial, vec):
            assert metrics_row(a) == metrics_row(b)

    @settings(max_examples=12, deadline=None)
    @given(u=st.floats(0.3, 1.1), gamma=st.floats(0.1, 0.9),
           n_tasks=st.integers(2, 12), seed=st.integers(0, 10_000),
           pol_idx=st.integers(0, len(POLICIES) - 1),
           overrun=st.floats(0.0, 0.9), cf=st.floats(1.1, 3.0))
    def test_random_point_exact(self, u, gamma, n_tasks, seed, pol_idx,
                                overrun, cf):
        policy = POLICIES[pol_idx]
        tasks = generate_taskset(u, gamma=gamma, n_tasks=n_tasks, cf=cf,
                                 seed=seed, programs=LIB)
        ev, vc = both_engines([tasks], [seed], policy, duration=4e6,
                              overrun_prob=overrun, cf=cf)
        assert_bit_exact(ev, vc, f"random point seed={seed}")


class TestEngineInternals:
    def test_uniform_decomposition_identity(self):
        """The vectorized release path draws demands as
        ``a + (b - a) * rng.random()``; pin that this is bit-identical
        to ``rng.uniform(a, b)`` for numpy's Generator."""
        for seed in range(50):
            r1, r2 = (np.random.default_rng(seed) for _ in range(2))
            for a, b in ((0.7, 1.0), (1.0, 2.0), (1.0, 1.8)):
                assert r1.uniform(a, b) == a + (b - a) * r2.random()

    def test_incremental_aggregates_consistent(self):
        """The engine's O(1) scheduler aggregates (locked banks, active
        counts, min-priority keys, resident-LO count) must equal a from-
        scratch recomputation of the final state."""
        tasksets = [generate_taskset(0.9, seed=s, n_tasks=8, programs=LIB)
                    for s in range(4)]
        batch = _VecBatch(tasksets, LIB, Policy.mesc(),
                          seeds=[0, 1, 2, 3], duration=1e7,
                          overrun_prob=0.3, cf=2.0)
        batch.run()
        bb = 32 * 1024
        locked = ((batch.r_bytes + bb - 1) // bb).sum(axis=1)
        np.testing.assert_array_equal(batch.locked, locked)
        active = (batch.status != 0) & batch.valid
        np.testing.assert_array_equal(batch.act_cnt, active.sum(axis=1))
        np.testing.assert_array_equal(
            batch.hi_cnt, (active & batch.is_hi).sum(axis=1))
        res_lo = ((batch.r_bytes > 0) & ~batch.is_hi
                  & batch.valid).sum(axis=1)
        np.testing.assert_array_equal(batch.res_lo_cnt, res_lo)

    def test_nominal_profile_draws_nothing(self):
        """The zero-jitter profile consumes no demand draws: after a
        run, each point's RNG stream sits exactly where the phase
        draws left it."""
        tasks = generate_taskset(0.7, seed=1, n_tasks=4, programs=LIB)
        batch = _VecBatch([tasks], LIB, Policy.mesc(), seeds=[1],
                          duration=1e6, overrun_prob=0.3, cf=2.0,
                          demand_profile="nominal")
        ref = np.random.default_rng(1)
        for tp in tasks:
            ref.uniform(0, tp.period)
        batch.run()
        assert batch.rngs[0].random() == ref.random()

    def test_nominal_demand_is_c_lo(self):
        """Zero-jitter profile: every accepted job's demand is exactly
        its C_LO budget."""
        tasks = generate_taskset(0.8, seed=2, n_tasks=6, programs=LIB)
        batch = _VecBatch([tasks], LIB, Policy.mesc(), seeds=[2],
                          duration=5e5, overrun_prob=0.3, cf=2.0,
                          demand_profile="nominal")
        batch.run()
        live = np.isfinite(batch.demand) & batch.valid
        assert live.any()
        np.testing.assert_array_equal(batch.demand[live],
                                      batch.c_lo[live])


class TestCacheContract:
    """Vec/jit points are salted; event points keep pre-change keys.
    The devices knob's cache-neutrality (bit-identical results share
    entries) is pinned in tests/test_device_sharding.py."""

    def _point(self, engine):
        sweep = Sweep(name="t", policies=(Policy.mesc(),), n_sets=1,
                      duration=1e6, engine=engine)
        return sweep.points()[0]

    def test_event_point_dict_has_no_engine_key(self):
        d = self._point("event").to_dict()
        assert "engine" not in d
        assert "vec_sim_v" not in d
        assert "jit_sim_v" not in d

    def test_vec_point_salted(self):
        d = self._point("vec").to_dict()
        assert d["engine"] == "vec"
        assert d["vec_sim_v"] == VEC_SIM_SEMANTICS_VERSION
        assert "jit_sim_v" not in d

    def test_jit_point_salted(self):
        from repro.core.simulator_jit import JIT_SIM_SEMANTICS_VERSION
        d = self._point("jit").to_dict()
        assert d["engine"] == "jit"
        assert d["jit_sim_v"] == JIT_SIM_SEMANTICS_VERSION
        assert "vec_sim_v" not in d

    def test_keys_disjoint_across_engines(self):
        keys = {e: self._point(e).key() for e in ("event", "vec", "jit")}
        assert len(set(keys.values())) == 3

    def test_unknown_engine_rejected(self):
        with pytest.raises(ValueError, match="unknown engine"):
            Sweep(name="t", policies=(Policy.mesc(),), n_sets=1,
                  duration=1e6, engine="cuda")

    def test_committed_hash_fixture_byte_stable(self):
        """Pre-PR event/vec point keys (and the new jit keys) pinned
        against a committed fixture: cache entries must never silently
        migrate."""
        fixture = json.loads(
            (REPO_ROOT / "tests" / "data"
             / "engine_point_hashes.json").read_text())
        for engine, expected in fixture.items():
            sweep = Sweep(name="fixture",
                          policies=(Policy.mesc(), Policy.amc()),
                          utils=(0.7, 0.9), n_sets=2, duration=2e7,
                          engine=engine)
            pts = sweep.points()
            for i in range(4):
                assert pts[i].key() == expected[f"point_{i}"], \
                    f"{engine} point {i} hash moved"
            assert sweep.spec_hash() == expected["spec_hash"]

    def test_event_spec_hash_unchanged_by_engine_field(self):
        """Sweep spec hashes for event sweeps must not move (manifests
        keep resolving), and SimPoint round-trips the engine field."""
        sweep = Sweep(name="t", policies=(Policy.mesc(),), n_sets=1,
                      duration=1e6)
        assert "engine" not in sweep.to_dict()
        p = self._point("vec")
        assert SimPoint.from_dict(p.to_dict()) == p

    def test_vec_campaign_caches_per_point(self, tmp_path):
        from repro.experiments import Campaign
        sweep = Sweep(name="t", policies=(Policy.mesc(),), n_sets=3,
                      duration=1e6, engine="vec")
        c1 = Campaign(sweep, cache_dir=tmp_path, workers=1)
        rows1 = c1.collect()
        assert c1.stats == {"hits": 0, "misses": 3}
        c2 = Campaign(sweep, cache_dir=tmp_path, workers=1)
        rows2 = c2.collect()
        assert c2.stats == {"hits": 3, "misses": 0}
        assert rows1 == rows2
        # same sweep on the event engine: different namespace -> misses,
        # but identical simulated metrics (the exactness contract)
        ev = Campaign(dataclasses.replace(sweep, engine="event"),
                      cache_dir=tmp_path, workers=1)
        rows_ev = ev.collect()
        assert ev.stats == {"hits": 0, "misses": 3}
        assert rows_ev == rows1


class TestBenchBaseline:
    """BENCH_sim.json is the committed perf trajectory: schema-stable
    and in sync with the harness."""

    def test_committed_baseline_schema(self):
        doc = json.loads((REPO_ROOT / "BENCH_sim.json").read_text())
        assert doc["schema_version"] == 3
        full = doc["sections"]["full"]
        assert full["corpus"]["points"] == 512
        assert full["corpus"]["style"] == "fig8"
        for eng in ("event", "vec", "jit"):
            block = full["engines"][eng]
            assert block["points_per_sec"] > 0
            assert block["seconds"] > 0
            # schema v2: per-repeat samples + spread, so CI deltas can
            # be read against measured run-to-run noise
            assert len(block["samples"]) >= 3
            assert block["spread_pct"] >= 0
        assert full["speedup_vec_vs_event"] > 1.0
        eq = full["equivalence"]
        assert eq["vec_mismatched_points"] == 0
        assert eq["jit_nominal_mismatched_points"] == 0
        assert eq["jit_statistical_ok"] is True

    def test_committed_baseline_device_scaling(self):
        """Schema v3: the jit engine carries per-device-count scaling
        rows, and every non-skipped row was asserted bit-exact against
        devices=1 in the recording process — a committed throughput
        number can never come from divergent work."""
        from benchmarks.perf_sim import DEVICE_COUNTS
        doc = json.loads((REPO_ROOT / "BENCH_sim.json").read_text())
        scaling = doc["sections"]["full"]["engines"]["jit"][
            "device_scaling"]
        assert set(scaling) == {str(d) for d in DEVICE_COUNTS}
        assert "1" in scaling                 # the reference leg
        for d, row in scaling.items():
            if "skipped" in row:
                assert "logical devices" in row["skipped"]
                continue
            assert row["points_per_sec"] > 0
            assert row["bit_exact_vs_devices1"] is True

    def test_perf_harness_stats_and_delta(self, capsys):
        """Harness internals: median-of-N stats, same-schema deltas,
        and the old-schema guard (a v1 baseline is skipped with a
        warning rather than diffed against a different layout —
        tests/test_simulator_jit.py pins the committed v1 stub)."""
        from benchmarks.perf_sim import SCHEMA_VERSION, _stats, print_delta
        s = _stats([2.0, 1.0, 3.0], 10)
        assert s["seconds"] == 2.0            # median, not first sample
        assert s["points_per_sec"] == 5.0
        assert s["samples"] == [2.0, 1.0, 3.0]
        assert s["spread_pct"] == 100.0
        new = {"engines": {e: _stats([1.0, 1.0, 1.0], 10)
                           for e in ("event", "vec", "jit")}}
        base = {"schema_version": SCHEMA_VERSION,
                "sections": {"smoke": {"engines": {
                    "event": {"points_per_sec": 20.0},
                    "vec": {"points_per_sec": 5.0}}}}}
        print_delta("smoke", new, base)
        out = capsys.readouterr().out
        assert "perf_delta,smoke,event,20.0,10.0,-50.0%" in out
        assert "# no baseline for engine 'jit'" in out
        old_v1 = {"sections": {"smoke": {"engines": {
            "event": {"points_per_sec": 20.0}}}}}
        print_delta("smoke", new, old_v1)     # no schema_version = pre-v2
        out = capsys.readouterr().out
        assert "skipping perf delta" in out
        assert "perf_delta" not in out
