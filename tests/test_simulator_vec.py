"""Vectorized-engine equivalence suite + perf-baseline regression.

The vectorized SoA backend (`core.simulator_vec`) claims bit-exact
per-run metrics against the event-driven engine — not "close", equal.
These tests pin that contract across policies, taskset shapes, seeds
and horizons (hypothesis-driven), pin the RNG identity the vectorized
release path relies on, the cache-key contract that keeps the two
engines' campaign caches disjoint, and the committed ``BENCH_sim.json``
schema that CI's perf-smoke job diffs against.
"""
import dataclasses
import json
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Policy, generate_taskset, simulate
from repro.core.simulator import simulate_batch
from repro.core.simulator_vec import (VEC_SIM_SEMANTICS_VERSION, _VecBatch,
                                      simulate_vbatch)
from repro.experiments.metrics import metrics_row
from repro.experiments.runner import cached_library
from repro.experiments.spec import SimPoint, Sweep

REPO_ROOT = Path(__file__).resolve().parent.parent

LIB = cached_library("sim")

POLICIES = [Policy.mesc(), Policy.non_preemptive(), Policy.amc(),
            dataclasses.replace(Policy.mesc(use_banks=False),
                                name="mesc-noB"),
            Policy(preemption="operator", name="lp"),
            Policy(preemption="none", drop_lo_in_hi=True, name="amc-np")]


def both_engines(tasksets, seeds, policy, **kw):
    ev = [simulate(ts, LIB, policy, seed=s, **kw)
          for ts, s in zip(tasksets, seeds)]
    vc = simulate_vbatch(tasksets, LIB, policy, seeds=seeds, **kw)
    return ev, vc


class TestGoldenCorpusEquivalence:
    """Vec metrics == event metrics on every corpus point, exactly."""

    @pytest.mark.parametrize("policy", POLICIES, ids=lambda p: p.name)
    def test_policy_corpus_exact(self, policy):
        tasksets, seeds = [], []
        for u in (0.6, 0.95):
            for s in range(3):
                tasksets.append(generate_taskset(
                    u, seed=s, n_tasks=6, programs=LIB))
                seeds.append(s)
        ev, vc = both_engines(tasksets, seeds, policy, duration=6e6)
        for i, (a, b) in enumerate(zip(ev, vc)):
            assert metrics_row(a) == metrics_row(b), \
                f"{policy.name} point {i} diverged"

    def test_per_event_lists_exact(self):
        """Not just aggregates: the raw per-event metric lists (blocking
        intervals, save/restore breakdowns) match element for element."""
        tasksets = [generate_taskset(0.9, seed=s, n_tasks=8, programs=LIB)
                    for s in range(3)]
        ev, vc = both_engines(tasksets, [0, 1, 2], Policy.mesc(),
                              duration=2e7)
        for a, b in zip(ev, vc):
            assert a.pi_blocking == b.pi_blocking
            assert a.ci_blocking == b.ci_blocking
            assert a.save_cycles == b.save_cycles
            assert a.restore_cycles == b.restore_cycles
            assert a.mode_cycles == b.mode_cycles
            assert a.exec_cycles == b.exec_cycles
            assert a.overhead_cycles == b.overhead_cycles

    def test_mixed_taskset_sizes_one_batch(self):
        """Padding: one lockstep batch with heterogeneous n_tasks."""
        sizes = [3, 10, 6, 13]
        tasksets = [generate_taskset(0.8, seed=s, n_tasks=n, programs=LIB)
                    for s, n in enumerate(sizes)]
        ev, vc = both_engines(tasksets, list(range(len(sizes))),
                              Policy.mesc(), duration=8e6)
        for a, b in zip(ev, vc):
            assert metrics_row(a) == metrics_row(b)

    def test_matches_simulate_batch(self):
        """Drop-in for the serial batch entry point."""
        tasksets = [generate_taskset(0.7, seed=s, programs=LIB)
                    for s in range(2)]
        serial = simulate_batch(tasksets, LIB, Policy.mesc(),
                                seeds=[0, 1], duration=4e6)
        vec = simulate_vbatch(tasksets, LIB, Policy.mesc(),
                              seeds=[0, 1], duration=4e6)
        for a, b in zip(serial, vec):
            assert metrics_row(a) == metrics_row(b)

    @settings(max_examples=12, deadline=None)
    @given(u=st.floats(0.3, 1.1), gamma=st.floats(0.1, 0.9),
           n_tasks=st.integers(2, 12), seed=st.integers(0, 10_000),
           pol_idx=st.integers(0, len(POLICIES) - 1),
           overrun=st.floats(0.0, 0.9), cf=st.floats(1.1, 3.0))
    def test_random_point_exact(self, u, gamma, n_tasks, seed, pol_idx,
                                overrun, cf):
        policy = POLICIES[pol_idx]
        tasks = generate_taskset(u, gamma=gamma, n_tasks=n_tasks, cf=cf,
                                 seed=seed, programs=LIB)
        ev = simulate(tasks, LIB, policy, duration=4e6, seed=seed,
                      overrun_prob=overrun, cf=cf)
        vc = simulate_vbatch([tasks], LIB, policy, seeds=[seed],
                             duration=4e6, overrun_prob=overrun, cf=cf)[0]
        assert metrics_row(ev) == metrics_row(vc)


class TestEngineInternals:
    def test_uniform_decomposition_identity(self):
        """The vectorized release path draws demands as
        ``a + (b - a) * rng.random()``; pin that this is bit-identical
        to ``rng.uniform(a, b)`` for numpy's Generator."""
        for seed in range(50):
            r1, r2 = (np.random.default_rng(seed) for _ in range(2))
            for a, b in ((0.7, 1.0), (1.0, 2.0), (1.0, 1.8)):
                assert r1.uniform(a, b) == a + (b - a) * r2.random()

    def test_incremental_aggregates_consistent(self):
        """The engine's O(1) scheduler aggregates (locked banks, active
        counts, min-priority keys, resident-LO count) must equal a from-
        scratch recomputation of the final state."""
        tasksets = [generate_taskset(0.9, seed=s, n_tasks=8, programs=LIB)
                    for s in range(4)]
        batch = _VecBatch(tasksets, LIB, Policy.mesc(),
                          seeds=[0, 1, 2, 3], duration=1e7,
                          overrun_prob=0.3, cf=2.0)
        batch.run()
        bb = 32 * 1024
        locked = ((batch.r_bytes + bb - 1) // bb).sum(axis=1)
        np.testing.assert_array_equal(batch.locked, locked)
        active = (batch.status != 0) & batch.valid
        np.testing.assert_array_equal(batch.act_cnt, active.sum(axis=1))
        np.testing.assert_array_equal(
            batch.hi_cnt, (active & batch.is_hi).sum(axis=1))
        res_lo = ((batch.r_bytes > 0) & ~batch.is_hi
                  & batch.valid).sum(axis=1)
        np.testing.assert_array_equal(batch.res_lo_cnt, res_lo)

    def test_jax_select_matches_numpy(self):
        """The optional jax.vmap candidate-reduction step (the fixed-
        shape inner step) selects identical events."""
        jax = pytest.importorskip("jax")
        del jax
        from repro.core.simulator_vec import _jax_select
        select = _jax_select()
        rng = np.random.default_rng(0)
        cand = rng.uniform(0, 1e8, size=(32, 4))
        cand[rng.random(cand.shape) < 0.3] = np.inf
        j, t = (np.asarray(x) for x in select(cand))
        np.testing.assert_array_equal(j, np.argmin(cand, axis=1))
        np.testing.assert_array_equal(
            t, cand[np.arange(len(cand)), np.argmin(cand, axis=1)])

    def test_jax_backend_end_to_end(self):
        tasks = generate_taskset(0.7, seed=1, n_tasks=4, programs=LIB)
        a = simulate_vbatch([tasks], LIB, Policy.mesc(), seeds=[1],
                            duration=1e6)[0]
        b = simulate_vbatch([tasks], LIB, Policy.mesc(), seeds=[1],
                            duration=1e6, select_backend="jax")[0]
        assert metrics_row(a) == metrics_row(b)


class TestCacheContract:
    """Vec points are salted; event points keep their pre-change keys."""

    def _point(self, engine):
        sweep = Sweep(name="t", policies=(Policy.mesc(),), n_sets=1,
                      duration=1e6, engine=engine)
        return sweep.points()[0]

    def test_event_point_dict_has_no_engine_key(self):
        d = self._point("event").to_dict()
        assert "engine" not in d
        assert "vec_sim_v" not in d

    def test_vec_point_salted(self):
        d = self._point("vec").to_dict()
        assert d["engine"] == "vec"
        assert d["vec_sim_v"] == VEC_SIM_SEMANTICS_VERSION

    def test_keys_disjoint_across_engines(self):
        assert self._point("event").key() != self._point("vec").key()

    def test_event_spec_hash_unchanged_by_engine_field(self):
        """Sweep spec hashes for event sweeps must not move (manifests
        keep resolving), and SimPoint round-trips the engine field."""
        sweep = Sweep(name="t", policies=(Policy.mesc(),), n_sets=1,
                      duration=1e6)
        assert "engine" not in sweep.to_dict()
        p = self._point("vec")
        assert SimPoint.from_dict(p.to_dict()) == p

    def test_vec_campaign_caches_per_point(self, tmp_path):
        from repro.experiments import Campaign
        sweep = Sweep(name="t", policies=(Policy.mesc(),), n_sets=3,
                      duration=1e6, engine="vec")
        c1 = Campaign(sweep, cache_dir=tmp_path, workers=1)
        rows1 = c1.collect()
        assert c1.stats == {"hits": 0, "misses": 3}
        c2 = Campaign(sweep, cache_dir=tmp_path, workers=1)
        rows2 = c2.collect()
        assert c2.stats == {"hits": 3, "misses": 0}
        assert rows1 == rows2
        # same sweep on the event engine: different namespace -> misses,
        # but identical simulated metrics (the exactness contract)
        ev = Campaign(dataclasses.replace(sweep, engine="event"),
                      cache_dir=tmp_path, workers=1)
        rows_ev = ev.collect()
        assert ev.stats == {"hits": 0, "misses": 3}
        assert rows_ev == rows1


class TestBenchBaseline:
    """BENCH_sim.json is the committed perf trajectory: schema-stable
    and in sync with the harness."""

    def test_committed_baseline_schema(self):
        doc = json.loads((REPO_ROOT / "BENCH_sim.json").read_text())
        assert doc["schema_version"] == 1
        full = doc["sections"]["full"]
        assert full["corpus"]["points"] == 512
        assert full["corpus"]["style"] == "fig8"
        for eng in ("event", "vec"):
            block = full["engines"][eng]
            assert block["points_per_sec"] > 0
            assert block["seconds"] > 0
        assert full["speedup_vec_vs_event"] > 1.0
        assert full["mismatched_points"] == 0

    def test_perf_sim_smoke_runs_in_budget(self):
        """The CI perf-smoke measurement completes quickly and the two
        engines agree on every smoke-corpus point."""
        import time
        from benchmarks.perf_sim import SMOKE, measure
        t0 = time.time()
        result = measure(SMOKE)
        assert time.time() - t0 < 120          # CI time budget
        assert result["mismatched_points"] == 0
        assert set(result["engines"]) == {"event", "vec"}
        for eng in result["engines"].values():
            assert eng["points_per_sec"] > 0
