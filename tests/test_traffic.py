"""Statistical + determinism gates for the traffic layer
(repro/serving/traffic.py).

Each generator gets two kinds of gate: *distributional* (the process
is what it claims — rate, dispersion, tail shape — checked against
analytic confidence bounds, no scipy) and *mechanical* (CRN
determinism, stream decorrelation, prefix stability, exact trace
round-trip — the properties fig12's common-random-numbers comparison
and the CI byte-identical gate stand on)."""
import json

import numpy as np
import pytest

from repro.serving.traffic import (PROCESS_KINDS, Diurnal, HeavyTail,
                                   Poisson, Trace, build_workload,
                                   crn_bits, crn_u01, load_trace,
                                   make_process, save_trace)
from repro.core.task import Crit

N = 20_000          # gap-sample size for the distributional gates
RATE = 3.0
SEED = 11


def _counts(times, width=1.0):
    """Arrivals per consecutive window of ``width`` seconds."""
    return np.bincount((np.asarray(times) / width).astype(int))


class TestDistributions:
    def test_poisson_mean_rate_within_ci(self):
        """Sample mean gap within 5 standard errors of 1/rate (for
        exponential gaps the SE is exactly mean/sqrt(n))."""
        gaps = Poisson(RATE).inter_arrivals(SEED, "lo_arrivals", N)
        mean = gaps.mean()
        se = (1.0 / RATE) / np.sqrt(N)
        assert abs(mean - 1.0 / RATE) < 5 * se, (mean, se)

    def test_poisson_counts_are_equidispersed(self):
        """Index of dispersion (var/mean of per-window counts) ~ 1 for
        a Poisson process; the bound is +-6 standard errors of the
        dispersion statistic (~sqrt(2/n_windows))."""
        t = Poisson(RATE).arrival_times(SEED, "lo_arrivals", N)
        c = _counts(t)
        d = c.var() / c.mean()
        tol = 6 * np.sqrt(2.0 / len(c))
        assert abs(d - 1.0) < tol, (d, tol)

    def test_heavy_tail_matches_mean_but_overdisperses(self):
        """Lomax gaps are calibrated to the same mean rate as Poisson
        (CRN load-matching) yet visibly burstier: window-count
        dispersion well above the Poisson band."""
        ht = HeavyTail(RATE, alpha=2.2)
        gaps = ht.inter_arrivals(SEED, "lo_arrivals", N)
        # Lomax(x_m, a) mean x_m/(a-1) = 1/rate; SE via sample std
        se = gaps.std() / np.sqrt(N)
        assert abs(gaps.mean() - 1.0 / RATE) < 5 * se
        d = _counts(ht.arrival_times(SEED, "lo_arrivals", N))
        dp = _counts(Poisson(RATE).arrival_times(SEED, "lo_arrivals", N))
        assert d.var() / d.mean() > 1.3 > dp.var() / dp.mean()

    def test_heavy_tail_dominates_exponential_tail(self):
        """The burst gate itself: heavy-tail gap quantiles dominate the
        rate-matched exponential's at and beyond p99."""
        ht = HeavyTail(RATE).inter_arrivals(SEED, "lo_arrivals", N)
        ex = Poisson(RATE).inter_arrivals(SEED, "lo_arrivals", N)
        for q in (0.99, 0.999):
            assert np.quantile(ht, q) > np.quantile(ex, q), q
        assert ht.max() > 3 * ex.max()

    def test_diurnal_peak_beats_trough(self):
        """The sinusoidal envelope shows up in the realization: arrival
        density around the rate peak (phase pi/2) exceeds the trough
        (phase 3pi/2) by at least the half-amplitude ratio."""
        proc = Diurnal(RATE, amplitude=0.8, period_s=40.0)
        t = proc.arrival_times(SEED, "lo_arrivals", N)
        phase = (t % proc.period_s) / proc.period_s     # [0, 1)
        peak = np.sum((phase > 0.10) & (phase < 0.40))  # around 0.25
        trough = np.sum((phase > 0.60) & (phase < 0.90))
        assert peak > 1.5 * trough, (peak, trough)


class TestDeterminism:
    def test_same_key_is_bit_identical(self):
        idx = np.arange(4096)
        a = crn_bits(SEED, "lo_arrivals", idx)
        b = crn_bits(SEED, "lo_arrivals", idx)
        assert np.array_equal(a, b)
        # scalar and vectorized spellings agree
        assert crn_bits(SEED, "lo_arrivals", 7) == a[7]

    def test_streams_and_seeds_decorrelate(self):
        """Distinct stream names / seeds give unrelated sequences:
        no collisions and ~zero correlation between the u01 draws."""
        idx = np.arange(8192)
        a = crn_u01(SEED, "lo_arrivals", idx)
        b = crn_u01(SEED, "hi_arrivals", idx)
        c = crn_u01(SEED + 1, "lo_arrivals", idx)
        for other in (b, c):
            assert not np.any(a == other)
            r = np.corrcoef(a, other)[0, 1]
            assert abs(r) < 5.0 / np.sqrt(len(idx)), r

    @pytest.mark.parametrize("kind", ("poisson", "heavy_tail", "diurnal"))
    def test_prefix_stable(self, kind):
        """arrival_times(n) is exactly the prefix of arrival_times(m>n)
        — the counter-keyed property that makes workload size a free
        knob (no resampling when a sweep grows)."""
        proc = make_process(kind, RATE)
        short = proc.arrival_times(SEED, "lo_arrivals", 100)
        long = proc.arrival_times(SEED, "lo_arrivals", 1000)
        assert np.array_equal(short, long[:100])

    def test_trace_round_trip_exact(self, tmp_path):
        times = list(Poisson(RATE).arrival_times(SEED, "lo_arrivals",
                                                 500))
        p = save_trace(times, tmp_path / "t.json")
        got = load_trace(p)
        assert list(got.times) == [float(t) for t in times]  # bit-exact
        assert np.array_equal(got.arrival_times(0, "x", 500),
                              np.asarray(times))

    def test_trace_validation(self, tmp_path):
        with pytest.raises(ValueError, match="ascending"):
            Trace(times=(2.0, 1.0))
        with pytest.raises(ValueError, match="holds"):
            Trace(times=(0.5, 1.0)).arrival_times(0, "x", 3)
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps({"version": 99, "times": [0.1]}))
        with pytest.raises(ValueError, match="version"):
            load_trace(bad)


class TestWorkload:
    def test_make_process_validation(self):
        with pytest.raises(ValueError, match="unknown arrival"):
            make_process("uniform", RATE)
        with pytest.raises(ValueError, match="trace_path"):
            make_process("trace", RATE)
        with pytest.raises(ValueError, match="rate"):
            Poisson(0.0)
        with pytest.raises(ValueError, match="alpha"):
            HeavyTail(RATE, alpha=1.0)
        with pytest.raises(ValueError, match="amplitude"):
            Diurnal(RATE, amplitude=1.5)
        assert set(PROCESS_KINDS) == {"poisson", "heavy_tail",
                                      "diurnal", "trace"}

    def test_build_workload_invariants(self):
        wl = build_workload(seed=SEED, lo_process=Poisson(RATE),
                            hi_process=Poisson(0.5), n_lo=40, n_hi=10,
                            lo_tokens=64, hi_tokens=8)
        assert [s.rid for s in wl] == list(range(50))
        assert all(a.t <= b.t for a, b in zip(wl, wl[1:]))  # time-sorted
        his = [s for s in wl if s.crit == Crit.HI]
        los = [s for s in wl if s.crit == Crit.LO]
        assert len(his) == 10 and len(los) == 40
        # priority convention: every HI priority below every LO priority
        assert max(s.priority for s in his) < min(s.priority for s in los)
        assert all(s.max_new_tokens >= 1 for s in wl)
        # token budgets land in the documented uniform band
        assert all(32 <= s.max_new_tokens <= 96 for s in los)
        # same seed rebuild is identical (workload is pure CRN)
        again = build_workload(seed=SEED, lo_process=Poisson(RATE),
                               hi_process=Poisson(0.5), n_lo=40, n_hi=10,
                               lo_tokens=64, hi_tokens=8)
        assert wl == again
