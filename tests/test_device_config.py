"""Regression suite for ``repro.runtime.device_config``.

The device layer is pure env/flag plumbing with two failure modes that
must stay loud: junk configuration (a campaign silently running
unsharded is the worst outcome, so every knob rejects bad values with
the variable named) and ordering violations (XLA reads ``XLA_FLAGS``
once at backend init — reconfiguring after that must warn, not
pretend).  The suite process has a live JAX backend (conftest forces
the >=4-way pool before anything imports jax), so the post-init paths
here are exercised against the real initialized state, and the
pre-init flag-rewriting paths via a monkeypatched ``jax_initialized``.
"""
import os

import jax
import pytest

from repro.runtime import device_config as dc
from repro.runtime.device_config import (MAX_LOGICAL_DEVICES, _env_int,
                                         configure_host_devices,
                                         default_device_count,
                                         jax_initialized,
                                         resolve_device_count,
                                         set_platform)


def _ensure_backend() -> None:
    """Force backend init (first touch uses conftest's >=4-way pool).

    The post-init tests below pin behavior against a *live* backend;
    depending on which test file runs first, this module may be the
    first to touch jax, so initialize explicitly."""
    jax.local_device_count()
    assert jax_initialized()


class TestEnvValidation:
    """REPRO_DEVICES (and _env_int generally) rejects junk loudly."""

    @pytest.mark.parametrize("bad", ["abc", "1.5", "0", "-2", "2x",
                                     str(MAX_LOGICAL_DEVICES + 1)])
    def test_junk_zero_and_oversubscribed_named(self, monkeypatch, bad):
        monkeypatch.setenv("REPRO_DEVICES", bad)
        with pytest.raises(ValueError, match="REPRO_DEVICES"):
            default_device_count()

    def test_valid_default_and_empty(self, monkeypatch):
        monkeypatch.setenv("REPRO_DEVICES", "3")
        assert default_device_count() == 3
        monkeypatch.delenv("REPRO_DEVICES")
        assert default_device_count() == 1
        monkeypatch.setenv("REPRO_DEVICES", "  ")   # blank = unset
        assert default_device_count() == 1

    def test_env_int_bounds_name_the_variable(self, monkeypatch):
        monkeypatch.setenv("SOME_KNOB", "9")
        with pytest.raises(ValueError, match="SOME_KNOB"):
            _env_int("SOME_KNOB", 1, minimum=1, maximum=8)
        monkeypatch.setenv("SOME_KNOB", "2")
        assert _env_int("SOME_KNOB", 1, minimum=1, maximum=8) == 2


class TestConfigureHostDevices:
    def test_out_of_range_rejected(self):
        with pytest.raises(ValueError, match="out of range"):
            configure_host_devices(0)
        with pytest.raises(ValueError, match="out of range"):
            configure_host_devices(MAX_LOGICAL_DEVICES + 1)

    def test_post_init_warns_and_changes_nothing(self, monkeypatch):
        """With the backend live, a reconfiguration attempt must warn
        loudly and leave XLA_FLAGS untouched."""
        _ensure_backend()
        monkeypatch.setenv("XLA_FLAGS", "--sentinel=1")
        with pytest.warns(RuntimeWarning,
                          match="after JAX backend initialization"):
            got = configure_host_devices(8)
        assert got == 8                      # request echoed back
        assert os.environ["XLA_FLAGS"] == "--sentinel=1"

    def test_pre_init_replaces_only_the_device_flag(self, monkeypatch):
        """Flag rewrite (pre-init path, initialization stubbed out):
        an existing device-count flag is replaced in place, unrelated
        flags survive."""
        monkeypatch.setattr(dc, "jax_initialized", lambda: False)
        monkeypatch.setenv(
            "XLA_FLAGS",
            "--foo=1 --xla_force_host_platform_device_count=2 --bar=x")
        assert configure_host_devices(8) == 8
        flags = os.environ["XLA_FLAGS"].split()
        assert "--xla_force_host_platform_device_count=8" in flags
        assert "--xla_force_host_platform_device_count=2" not in flags
        assert "--foo=1" in flags and "--bar=x" in flags

    def test_reads_repro_devices_when_unspecified(self, monkeypatch):
        monkeypatch.setattr(dc, "jax_initialized", lambda: False)
        monkeypatch.setenv("REPRO_DEVICES", "6")
        monkeypatch.setenv("XLA_FLAGS", "")
        assert configure_host_devices() == 6
        assert ("--xla_force_host_platform_device_count=6"
                in os.environ["XLA_FLAGS"])


class TestSetPlatform:
    def test_unknown_platform_rejected(self):
        with pytest.raises(ValueError, match="not in"):
            set_platform("quantum")

    def test_gpu_sets_flags_without_a_gpu_present(self, monkeypatch):
        """The single-flag CPU->GPU route: selecting the gpu platform
        writes the dispatch-latency XLA flags and the platform env var
        even on a host with no GPU (JAX validates at backend init, not
        here).  Post-init it additionally warns — exercised that way
        here because flipping a live process's platform config would
        poison every later jax call in the suite."""
        _ensure_backend()
        monkeypatch.setenv("XLA_FLAGS", "--keep=me")
        monkeypatch.delenv("JAX_PLATFORM_NAME", raising=False)
        with pytest.warns(RuntimeWarning,
                          match="after JAX backend initialization"):
            set_platform("gpu")
        flags = os.environ["XLA_FLAGS"]
        assert "--keep=me" in flags
        for f in dc._GPU_XLA_FLAGS.split():
            assert f in flags
        assert os.environ["JAX_PLATFORM_NAME"] == "gpu"

    def test_gpu_flags_idempotent(self, monkeypatch):
        _ensure_backend()
        monkeypatch.setenv("XLA_FLAGS", "")
        monkeypatch.delenv("JAX_PLATFORM_NAME", raising=False)
        with pytest.warns(RuntimeWarning):
            set_platform("gpu")
        once = os.environ["XLA_FLAGS"]
        with pytest.warns(RuntimeWarning):
            set_platform("gpu")
        assert os.environ["XLA_FLAGS"] == once   # no duplicate flags


class TestResolveDeviceCount:
    def test_single_device_never_touches_jax(self, monkeypatch):
        # want == 1 short-circuits before any backend query
        monkeypatch.setattr(dc, "jax_initialized",
                            lambda: pytest.fail("queried backend"))
        assert resolve_device_count(1) == 1

    @pytest.mark.parametrize("bad", [0, -1, MAX_LOGICAL_DEVICES + 1])
    def test_out_of_range_rejected(self, bad):
        with pytest.raises(ValueError, match="out of range"):
            resolve_device_count(bad)

    def test_within_pool_resolves_exactly(self):
        # conftest forces a >=4-way pool before jax initializes
        _ensure_backend()
        assert jax.local_device_count() >= 4
        assert resolve_device_count(4) == 4
        assert resolve_device_count(2) == 2

    def test_oversized_request_clamps_with_loud_warning(self):
        _ensure_backend()
        have = jax.local_device_count()
        want = min(have + 1, MAX_LOGICAL_DEVICES)
        if want <= have:                      # pragma: no cover
            pytest.skip("pool already at the maximum")
        with pytest.warns(RuntimeWarning, match=f"running on {have}"):
            assert resolve_device_count(want) == have

    def test_none_reads_env_default(self, monkeypatch):
        # post-init on purpose: pre-init this would legitimately
        # re-force the pool, shrinking it for the rest of the suite
        _ensure_backend()
        monkeypatch.setenv("REPRO_DEVICES", "3")
        assert resolve_device_count(None) == 3
        monkeypatch.delenv("REPRO_DEVICES")
        assert resolve_device_count(None) == 1
