"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, SHAPES_BY_NAME, supports_shape
from repro.data import batch_for_arch
from repro.models import lm
from repro.models.common import CPU_RC
from repro.optim import OptConfig, init_opt_state
from repro.runtime.trainer import make_train_step

ALL_ARCHS = sorted(ARCHS)


def _smoke_batch(cfg, B=2, S=16, seed=0):
    return {k: jnp.asarray(v)
            for k, v in batch_for_arch(cfg, S, B, step=0, seed=seed).items()}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch + "-smoke")
    params = lm.init_params(cfg, jax.random.PRNGKey(0), CPU_RC)
    batch = _smoke_batch(cfg)
    logits, _ = lm.forward(cfg, params, batch, CPU_RC)
    S = 16
    if cfg.family == "audio":
        assert logits.shape == (2, S, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (2, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_runs_and_finite(arch):
    cfg = get_config(arch + "-smoke")
    opt_cfg = OptConfig(warmup_steps=2, decay_steps=10)
    params = lm.init_params(cfg, jax.random.PRNGKey(0), CPU_RC)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, CPU_RC, opt_cfg))
    params, opt, metrics = step(params, opt, _smoke_batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    leaves = jax.tree_util.tree_leaves(params)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_metadata(arch):
    cfg = ARCHS[arch]
    assert cfg.param_count() > 0
    assert cfg.active_param_count() <= cfg.param_count()
    for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        shape = SHAPES_BY_NAME[s]
        ok = supports_shape(cfg, shape)
        if s == "long_500k":
            assert ok == (cfg.family in ("hybrid", "xlstm"))
        else:
            assert ok
