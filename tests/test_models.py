"""Model-level consistency tests: prefill+decode == full forward for every
family; recurrent parallel/chunkwise/step forms agree; attention variants
against naive oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as attn_lib
from repro.models import lm
from repro.models import recurrent as rec_lib
from repro.models.common import CPU_RC

ARCHS = ["tinyllama-1.1b", "llama4-maverick-400b-a17b", "deepseek-v2-lite-16b",
         "olmo-1b", "phi4-mini-3.8b", "qwen1.5-110b", "recurrentgemma-2b",
         "llava-next-34b", "xlstm-125m", "musicgen-large"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch + "-smoke")
    key = jax.random.PRNGKey(1)
    B, S, S1 = 2, 12, 8
    params = lm.init_params(cfg, key, CPU_RC)
    if cfg.family == "audio":
        toks = jax.random.randint(key, (B, S, cfg.n_codebooks), 0, cfg.vocab)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch, pre = {"tokens": toks}, {"tokens": toks[:, :S1]}
    if cfg.family == "vlm":
        nf = cfg.n_frontend_tokens
        vis = jax.random.normal(key, (B, nf, cfg.d_model), jnp.float32)
        batch = {"tokens": toks[:, :S - nf], "vis_embeds": vis}
        pre = {"tokens": toks[:, :S1 - nf], "vis_embeds": vis}
    full, _ = lm.forward(cfg, params, batch, CPU_RC)
    last, cache = lm.prefill(cfg, params, pre, CPU_RC, max_len=S)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, S1 - 1]),
                               atol=2e-3, rtol=1e-3)
    for t in range(S1, S):
        tok = (batch["tokens"][:, t - (cfg.n_frontend_tokens
                                       if cfg.family == "vlm" else 0)]
               if cfg.family == "vlm" else toks[:, t])
        logits, cache = lm.decode_step(cfg, params, tok, cache, CPU_RC)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, t]),
                                   atol=2e-3, rtol=1e-3)


def test_flash_vs_naive_attention():
    key = jax.random.PRNGKey(0)
    B, Sq, Hq, Hkv, dh = 2, 64, 8, 2, 32
    q = jax.random.normal(key, (B, Sq, Hq, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Sq, Hkv, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Sq, Hkv, dh))
    out = attn_lib.flash_attention(q, k, v, causal=True, block_q=16,
                                   block_kv=16)
    # naive
    G = Hq // Hkv
    kr = jnp.repeat(k, G, axis=2)
    vr = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * (dh ** -0.5)
    s = jnp.where(jnp.tril(jnp.ones((Sq, Sq), bool))[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_local_attention_window_semantics():
    key = jax.random.PRNGKey(0)
    B, S, H, dh, W = 1, 64, 2, 16, 8
    q = jax.random.normal(key, (B, S, H, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, dh))
    out = attn_lib.local_attention(q, k, v, window=W, block_q=16)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * (dh ** -0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - W)
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_mlstm_forms_agree():
    """parallel == chunkwise == recurrent stepping (stabilized)."""
    key = jax.random.PRNGKey(3)
    B, H, S, dh = 2, 2, 32, 16
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, S, dh))
    k = jax.random.normal(ks[1], (B, H, S, dh))
    v = jax.random.normal(ks[2], (B, H, S, dh))
    log_i = jax.random.normal(ks[3], (B, H, S)) * 2.0
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, S)) + 2.0)

    h_par = rec_lib.mlstm_parallel(q, k, v, log_i, log_f)
    h_chk, state_chk = rec_lib.mlstm_chunkwise(q, k, v, log_i, log_f, chunk=8)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_chk),
                               atol=1e-4, rtol=1e-4)
    # recurrent stepping
    st = rec_lib._empty_mlstm_state(B, H, dh, dh)
    outs = []
    for t in range(S):
        o, st = rec_lib.mlstm_step(q[:, :, t], k[:, :, t], v[:, :, t],
                                   log_i[:, :, t], log_f[:, :, t], st)
        outs.append(o)
    h_seq = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_seq),
                               atol=1e-4, rtol=1e-4)
    # chunkwise final state == sequential final state
    for a, b in zip(state_chk, st):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-3)


def test_rglru_scan_vs_step():
    cfg = get_config("recurrentgemma-2b-smoke")
    p = lm._rglru_block_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.rglru.d_rnn))
    y_par, h_last = rec_lib.rglru_scan(x, p, cfg.n_heads)
    h = jnp.zeros((B, cfg.rglru.d_rnn))
    ys = []
    for t in range(S):
        y, h = rec_lib.rglru_step(x[:, t], p, cfg.n_heads, h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_par),
                               np.asarray(jnp.stack(ys, 1)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h), atol=1e-5)


def test_chunked_xent_matches_full():
    cfg = get_config("tinyllama-1.1b-smoke")
    params = lm.init_params(cfg, jax.random.PRNGKey(0), CPU_RC)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    from repro.models.common import apply_norm, softmax_xent
    h, _ = lm.forward(cfg, params, {"tokens": toks}, CPU_RC,
                      return_hidden=True)
    hn = apply_norm(cfg.norm, h, params["out_norm"])
    l1, _ = lm.chunked_xent(cfg, params, hn, toks, CPU_RC)
    logits, _ = lm.forward(cfg, params, {"tokens": toks}, CPU_RC)
    l2, _ = softmax_xent(logits, toks, z_loss_coef=CPU_RC.z_loss)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
