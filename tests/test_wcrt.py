"""WCRT analysis (Eqs. 1-11): structure checks + the soundness property —
if the analysis declares a task set schedulable, the simulator must observe
zero HI deadline misses (and zero LO misses in LO-mode)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (AnalysisConstants, Crit, Policy, TaskParams, analyze,
                        generate_taskset, longest_instruction, simulate,
                        workload_library)
from repro.core.wcrt import (response_time_hi, response_time_lo,
                             response_time_trans)

LIB = workload_library(include_archs=False)
K = AnalysisConstants()


def _tasks(u, seed):
    return generate_taskset(u, seed=seed, programs=LIB)


def test_longest_instruction_positive():
    tasks = _tasks(0.5, 0)
    i = longest_instruction(tasks, LIB)
    assert 0 < i < 5000  # instructions are tiny vs T_sr


def test_response_time_monotone_in_priority():
    """Lower-priority tasks can only see more interference."""
    tasks = _tasks(0.5, 1)
    rs = {}
    for t in tasks:
        r = response_time_lo(t, tasks, LIB, K)
        if r is not None:
            rs[t.priority] = r - t.c_lo  # interference share
    prios = sorted(rs)
    # not strictly monotone (different C), but the top-priority task's
    # interference must be minimal
    assert rs[prios[0]] == min(rs[prios[0]] for _ in [0])


def test_hi_ge_lo_response():
    tasks = _tasks(0.4, 2)
    for t in tasks:
        if t.crit != Crit.HI:
            continue
        r_lo = response_time_lo(t, tasks, LIB, K)
        r_hi = response_time_hi(t, tasks, LIB, K)
        if r_lo is not None and r_hi is not None:
            assert r_hi >= r_lo * 0.5  # HI uses C_HI; sanity relation


def test_unschedulable_at_extreme_utilisation():
    tasks = _tasks(3.0, 3)  # U >> 1 cannot be schedulable
    assert not analyze(tasks, LIB, K).schedulable


@settings(max_examples=12, deadline=None)
@given(u=st.floats(0.2, 0.6), seed=st.integers(0, 10 ** 6))
def test_analysis_soundness(u, seed):
    """Analysis-schedulable  =>  no HI misses in simulation.

    The simulator's demands never exceed the modeled WCETs (LO <= C_LO,
    HI <= C_HI), so a sound analysis must imply zero HI-task misses.
    """
    tasks = _tasks(u, seed)
    res = analyze(tasks, LIB, K)
    if not res.schedulable:
        return  # nothing to check; analysis may be conservative
    m = simulate(tasks, LIB, Policy.mesc(), duration=2e8, seed=seed,
                 overrun_prob=0.3)
    assert m.misses["HI"] == 0, (
        f"analysis said schedulable but HI missed: u={u} seed={seed}")


def test_blocking_terms_match_eq1():
    """PB_i^LO = I(F(lp)) + T_sr (Eq. 1) — verify the implementation's
    blocking term for the highest-priority task."""
    tasks = _tasks(0.4, 5)
    hi_prio = min(tasks, key=lambda t: t.priority)
    lp = [t for t in tasks if t.priority > hi_prio.priority]
    expect = longest_instruction(lp, LIB) + K.t_sr
    r = response_time_lo(hi_prio, tasks, LIB, K)
    # response >= blocking + C + CS overhead
    assert r is None or r >= expect + hi_prio.c_lo
