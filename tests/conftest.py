import os

# tests must see the real (single) CPU device — the 512-device flag is only
# for the dry-run (see src/repro/launch/dryrun.py)
os.environ.pop("XLA_FLAGS", None)

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
