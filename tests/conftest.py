import os
import sys
from pathlib import Path

# tests must see the real (single) CPU device — the 512-device flag is only
# for the dry-run (see src/repro/launch/dryrun.py)
os.environ.pop("XLA_FLAGS", None)

# make `repro` (src/) and `benchmarks` (repo root) importable regardless of
# how pytest was invoked; mirrors pyproject's tool.pytest.ini_options
_ROOT = Path(__file__).resolve().parents[1]
for p in (str(_ROOT / "src"), str(_ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)

# gate the optional `hypothesis` dependency: on bare images fall back to the
# deterministic shim so the property tests still collect and run
try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - depends on image
    from repro._compat import hypothesis_fallback
    hypothesis_fallback.install()

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
