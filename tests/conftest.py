import os
import sys
from pathlib import Path

# make `repro` (src/) and `benchmarks` (repo root) importable regardless of
# how pytest was invoked; mirrors pyproject's tool.pytest.ini_options
_ROOT = Path(__file__).resolve().parents[1]
for p in (str(_ROOT / "src"), str(_ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)

# the suite runs on a >= 4-way logical CPU device pool so the sharded jit
# gates (tests/test_device_sharding.py, tests/harness.py cases) execute
# in-process; any inherited flag — e.g. the dry-run's 512-device one (see
# src/repro/launch/dryrun.py) — is dropped first, then the pool is forced
# before jax initializes.  REPRO_DEVICES (the CI device matrix) can only
# widen the pool; it is deliberately NOT defaulted here, so the engine's
# device *default* stays 1 and sharding in tests is always explicit.
os.environ.pop("XLA_FLAGS", None)
from repro.runtime.device_config import (configure_host_devices,  # noqa: E402
                                         default_device_count)

configure_host_devices(max(4, default_device_count()))

# gate the optional `hypothesis` dependency: on bare images fall back to the
# deterministic shim so the property tests still collect and run
try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - depends on image
    from repro._compat import hypothesis_fallback
    hypothesis_fallback.install()

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
