"""Data pipeline, optimizer, compression, checkpointing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.data import SyntheticLM, batch_for_arch
from repro.optim import (OptConfig, adamw_update, compress_int8,
                         decompress_int8, init_opt_state, lr_schedule)
from repro.checkpointing import (CheckpointManager, latest_step,
                                 load_checkpoint, save_checkpoint)


class TestData:
    def test_deterministic(self):
        ds = SyntheticLM(vocab=101, seq_len=32, global_batch=8, seed=3)
        b1, b2 = ds.batch(5), ds.batch(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = ds.batch(6)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_sharding_partitions_batch(self):
        ds = SyntheticLM(vocab=50, seq_len=16, global_batch=8, seed=0)
        shards = [ds.batch(2, shard=i, n_shards=4) for i in range(4)]
        assert all(s["tokens"].shape[0] == 2 for s in shards)
        # shards are distinct slices (resumable DP)
        assert not np.array_equal(shards[0]["tokens"], shards[1]["tokens"])

    def test_labels_are_next_tokens(self):
        ds = SyntheticLM(vocab=50, seq_len=16, global_batch=2, seed=0,
                         noise_frac=0.0)
        b = ds.batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_family_batches(self):
        for name in ("musicgen-large", "llava-next-34b"):
            cfg = get_config(name + "-smoke")
            b = batch_for_arch(cfg, 16, 2, step=0)
            if cfg.family == "audio":
                assert b["tokens"].shape == (2, 16, cfg.n_codebooks)
            else:
                nf = min(cfg.n_frontend_tokens, 8)
                assert b["vis_embeds"].shape[1] == nf
                assert b["tokens"].shape[1] + nf == 16


class TestOptimizer:
    def test_adamw_converges_quadratic(self):
        cfg = OptConfig(lr=0.1, warmup_steps=5, decay_steps=200,
                        weight_decay=0.0, clip_norm=0)
        params = {"w": jnp.array([3.0, -2.0])}
        state = init_opt_state(params, cfg)
        for _ in range(150):
            g = {"w": 2 * params["w"]}
            params, state, _ = adamw_update(params, g, state, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_lr_schedule_shape(self):
        cfg = OptConfig(lr=1.0, warmup_steps=10, decay_steps=100)
        assert float(lr_schedule(cfg, jnp.asarray(0))) < 0.2
        assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0, rel=0.1)
        assert float(lr_schedule(cfg, jnp.asarray(1000))) == pytest.approx(0.1, rel=0.01)

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.floats(-1e3, 1e3), min_size=1, max_size=64))
    def test_int8_compression_bounded_error(self, xs):
        x = jnp.asarray(xs, jnp.float32)
        q, s = compress_int8(x)
        back = decompress_int8(q, s)
        amax = float(jnp.max(jnp.abs(x)))
        assert float(jnp.max(jnp.abs(back - x))) <= max(amax / 127.0, 1e-6) * 1.01


class TestCheckpoint:
    def test_roundtrip_and_retention(self, tmp_path):
        state = {"params": {"a": jnp.arange(6.0).reshape(2, 3),
                            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}},
                 "opt": {"step": jnp.asarray(7, jnp.int32)}}
        for step in (10, 20, 30, 40):
            save_checkpoint(tmp_path, step, state, extra={"data_step": step},
                            keep=2)
        assert latest_step(tmp_path) == 40
        # retention keeps only 2
        kept = [p.name for p in tmp_path.iterdir()]
        assert sorted(kept) == ["step_00000030", "step_00000040"]
        restored, manifest = load_checkpoint(tmp_path, state)
        np.testing.assert_array_equal(np.asarray(restored["params"]["a"]),
                                      np.asarray(state["params"]["a"]))
        assert restored["params"]["nested"]["b"].dtype == jnp.bfloat16
        assert manifest["extra"]["data_step"] == 40

    def test_manager_resume(self, tmp_path):
        mgr = CheckpointManager(tmp_path, interval=5)
        state = {"params": {"w": jnp.zeros((3,))}}
        assert mgr.maybe_save(3, state) is None
        assert mgr.maybe_save(5, state) is not None
        restored, step, extra = mgr.restore_or_init(
            state, init_fn=lambda: (_ for _ in ()).throw(AssertionError()))
        assert step == 5

    def test_elastic_restore_with_shardings(self, tmp_path):
        """Restore places arrays with caller-provided (new-mesh) shardings."""
        state = {"params": {"w": jnp.arange(8.0)}}
        save_checkpoint(tmp_path, 1, state)
        sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
        restored, _ = load_checkpoint(
            tmp_path, state, shardings={"params": {"w": sharding}})
        assert restored["params"]["w"].sharding == sharding
