"""The declarative scenario layer (``repro.scenarios``), gated.

Four contract families:

* **CRN** — scenario draws are counter-based pure functions of
  ``(seed, component, task, release_index)``: order-free (the same key
  gives the same draw no matter what was drawn before), policy-free
  (the absolute release counter makes realizations identical under any
  policy), and decorrelated from the engines' own demand RNG streams
  (enabling a scenario never perturbs a base draw).
* **Equivalence** — every scenario preserves the engine contracts:
  event == vec bit-exact on the sampled profile, vec == jit bit-exact
  on the nominal profile, and the neutral scenario (``None`` /
  ``faults@0``) is bit-identical to the scenario-free code paths.
* **Loud validation** — unknown scenario / demand-profile names raise
  ``ValueError`` naming the argument at every entry layer (Sweep, the
  engines, the serving driver).
* **Serving instance loss** — outage windows stall lanes without ever
  losing a request: the FrontDoor conservation invariant holds at
  every driver iteration (property-tested over seeds and loss knobs)
  and every request still completes.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from harness import (EngineCase, LIB, ServingCase, assert_bit_exact,
                     assert_serving_deterministic, mixed_corpus,
                     run_case, run_serving_case, serving_corpus)
from repro.core import Policy, generate_taskset
from repro.core.simulator import DemandSampler
from repro.scenarios import (SCENARIOS, Scenario, get_scenario, faults,
                             keyed_u01, mix64, stream_salt)

DURATION = 3e5
# every registry scenario plus a mid-intensity faults family member
ALL_SCENARIOS = sorted(SCENARIOS) + ["faults@0.7"]
# the demand-affecting subset the jit engine compiles a graph for
# (phase_shift/instance_loss don't touch the release arithmetic)
JIT_SCENARIOS = ["heavy_tail", "burst", "thermal_throttle", "faults@0.7"]


class TestCRN:
    """Counter-based draws: keyed, uniform-range, order-free."""

    def test_mix64_scrambles_and_is_deterministic(self):
        xs = np.arange(16, dtype=np.uint64)
        a, b = mix64(xs), mix64(xs)
        assert np.array_equal(a, b)
        assert len(set(a.tolist())) == 16        # injective on the probe
        assert not np.array_equal(a, xs)

    def test_keyed_u01_in_unit_interval(self):
        seed = np.uint64(123)
        salt = stream_salt("probe")
        us = [float(keyed_u01(seed, salt, np.uint64(e), np.uint64(i)))
              for e in range(8) for i in range(64)]
        assert all(0.0 <= u < 1.0 for u in us)
        assert 0.3 < float(np.mean(us)) < 0.7    # roughly uniform

    def test_stream_salts_distinct(self):
        names = ["heavy_tail", "burst", "phase_shift", "dma", "thermal",
                 "instance_loss"]
        salts = {int(stream_salt(n)) for n in names}
        assert len(salts) == len(names)

    def test_draws_are_order_free(self):
        """The same (task, release, time) key gives the same sampled
        demand regardless of sampling order or history — the CRN
        property that makes realizations policy-independent (policies
        only reorder/skip draws, they can't perturb them)."""
        tasks = generate_taskset(0.8, seed=0, programs=LIB)
        keys = [(i, n, 1e4 * (n + 1))
                for i in range(len(tasks)) for n in range(5)]

        def draws(order):
            s = DemandSampler(np.random.default_rng(0), tasks, seed=7,
                              overrun_prob=0.3, cf=2.0,
                              demand_profile="nominal",
                              scenario="faults@0.9")
            return {k: s.sample(tasks[k[0]], k[1], k[2]) for k in order}

        assert draws(keys) == draws(keys[::-1])

    def test_scenario_draws_decorrelated_from_demand_stream(self):
        """A scenario whose components draw but (almost surely) never
        fire leaves the event engine bit-identical: scenario draws
        come from their own keyed streams, never the demand RNG."""
        ghost = Scenario(name="ghost", dma_prob=1e-12, dma_factor=2.0)
        assert ghost.affects_demand
        ts, seeds = mixed_corpus()
        base = run_case(EngineCase("ev", engine="event"), ts, seeds,
                        Policy.mesc(), duration=DURATION)
        got = run_case(EngineCase("ev-ghost", engine="event",
                                  scenario=ghost), ts, seeds,
                       Policy.mesc(), duration=DURATION)
        assert_bit_exact(base, got, "ghost scenario vs none")


class TestLoudValidation:
    """Unknown names raise ValueError naming the argument, everywhere."""

    def test_get_scenario_unknown_name(self):
        with pytest.raises(ValueError, match="unknown scenario 'bogus'"):
            get_scenario("bogus")

    def test_faults_family_bad_intensity(self):
        with pytest.raises(ValueError, match="faults@<intensity>"):
            get_scenario("faults@nope")
        with pytest.raises(ValueError, match="intensity"):
            get_scenario("faults@1.5")

    def test_sweep_validates_scenario_and_profile(self):
        from repro.experiments.spec import Sweep
        with pytest.raises(ValueError, match="unknown scenario"):
            Sweep(name="t", policies=(Policy.mesc(),), n_sets=1,
                  duration=1e6, scenario="bogus")
        with pytest.raises(ValueError, match="unknown demand_profile"):
            Sweep(name="t", policies=(Policy.mesc(),), n_sets=1,
                  duration=1e6, demand_profile="bogus")

    def test_engines_validate_scenario(self):
        from repro.core.simulator import simulate
        from repro.core.simulator_vec import simulate_vbatch
        ts = generate_taskset(0.8, seed=0, programs=LIB)
        with pytest.raises(ValueError, match="unknown scenario"):
            simulate(ts, LIB, Policy.mesc(), duration=1e5,
                     scenario="bogus")
        with pytest.raises(ValueError, match="unknown scenario"):
            simulate_vbatch([ts], LIB, Policy.mesc(), seeds=[0],
                            duration=1e5, scenario="bogus")
        with pytest.raises(ValueError, match="unknown demand_profile"):
            simulate(ts, LIB, Policy.mesc(), duration=1e5,
                     demand_profile="bogus")


class TestEngineEquivalence:
    """Every scenario preserves the cross-engine contracts."""

    @pytest.mark.parametrize("scenario", ALL_SCENARIOS)
    def test_event_vec_bit_exact(self, scenario):
        ts, seeds = mixed_corpus()
        ev = run_case(EngineCase(f"ev-{scenario}", engine="event",
                                 scenario=scenario),
                      ts, seeds, Policy.mesc(), duration=DURATION)
        vec = run_case(EngineCase(f"vec-{scenario}", engine="vec",
                                  scenario=scenario),
                       ts, seeds, Policy.mesc(), duration=DURATION)
        assert_bit_exact(ev, vec, f"event vs vec under {scenario}")

    @pytest.mark.parametrize("scenario", JIT_SCENARIOS)
    def test_vec_jit_bit_exact_nominal(self, scenario):
        ts, seeds = mixed_corpus()
        kw = dict(duration=DURATION)
        vec = run_case(EngineCase(f"vec-{scenario}", engine="vec",
                                  demand_profile="nominal",
                                  scenario=scenario),
                       ts, seeds, Policy.mesc(), **kw)
        jit = run_case(EngineCase(f"jit-{scenario}", engine="jit",
                                  demand_profile="nominal",
                                  scenario=scenario),
                       ts, seeds, Policy.mesc(), **kw)
        assert_bit_exact(vec, jit, f"vec vs jit under {scenario}")

    @pytest.mark.parametrize("engine", ["event", "vec", "jit"])
    def test_neutral_scenario_bit_identical(self, engine):
        """``faults@0`` (every component statically off) must equal
        ``scenario=None`` bit for bit in every engine — the neutral
        scenario is the pre-scenario code path."""
        ts, seeds = mixed_corpus()
        profile = "nominal" if engine == "jit" else "sampled"
        kw = {} if engine == "event" else {"demand_profile": profile}
        base = run_case(EngineCase(f"{engine}-none", engine=engine,
                                   **kw),
                        ts, seeds, Policy.mesc(), duration=DURATION)
        zero = run_case(EngineCase(f"{engine}-f0", engine=engine,
                                   scenario="faults@0", **kw),
                        ts, seeds, Policy.mesc(), duration=DURATION)
        assert_bit_exact(base, zero, f"{engine}: faults@0 vs None")

    def test_realization_policy_independent(self):
        """The scenario realization is common-random-numbered across
        policies: under the *nominal* profile (no base-demand noise)
        per-policy differences under a fault scenario come only from
        scheduling, so per-task release counts stay within the bounds
        the same policies show scenario-free.  Spot check: the faulted
        mesc/np job-count delta matches the unfaulted delta direction
        and the faulted runs still released the same job totals per
        policy pair as a re-run (determinism across the pairing)."""
        ts, seeds = mixed_corpus((6, 9))
        rows = {}
        for pol in (Policy.mesc(), Policy.non_preemptive()):
            rows[pol.name] = run_case(
                EngineCase(f"vec-{pol.name}", engine="vec",
                           demand_profile="nominal",
                           scenario="faults@0.8"),
                ts, seeds, pol, duration=DURATION)
            again = run_case(
                EngineCase(f"vec-{pol.name}-2", engine="vec",
                           demand_profile="nominal",
                           scenario="faults@0.8"),
                ts, seeds, pol, duration=DURATION)
            assert_bit_exact(rows[pol.name], again,
                             f"{pol.name} faulted repeat")
        # same workload realization: released job totals agree across
        # policies (releases are time-driven; policies change only
        # completion, not the release schedule or the fault draws)
        for a, b in zip(rows["mesc"], rows["np"]):
            assert a["jobs_lo"] + a["jobs_hi"] \
                == b["jobs_lo"] + b["jobs_hi"]


class TestServingLoss:
    """Instance loss: lanes stall, requests conserve and complete."""

    CASE = ServingCase("loss", scenario="instance_loss", n_lo=10,
                       n_hi=4)

    def test_loss_case_deterministic(self):
        assert_serving_deterministic(self.CASE)

    def test_loss_neutral_scenario_identical(self):
        import dataclasses
        base = run_serving_case(dataclasses.replace(self.CASE,
                                                    scenario=None))
        zero = run_serving_case(dataclasses.replace(self.CASE,
                                                    scenario="faults@0"))
        assert_bit_exact(base, zero, "serving faults@0 vs None")

    def test_loss_stretches_latency(self):
        import dataclasses
        base = run_serving_case(dataclasses.replace(self.CASE,
                                                    scenario=None))
        lossy = run_serving_case(self.CASE)
        lat = lambda rows: sum(r["finished_at"] - r["submitted_at"]
                               for r in rows if "rid" in r)
        assert lat(lossy) > lat(base)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000),
           loss_prob=st.floats(0.05, 0.9),
           window=st.floats(0.05, 0.6))
    def test_loss_never_violates_conservation(self, seed, loss_prob,
                                              window):
        """The FrontDoor invariant (finished + live + queued ==
        submitted) holds at every driver iteration under any outage
        realization, and every request still completes."""
        from repro.serving.frontend import run_virtual_serving
        scen = Scenario(name="loss", loss_prob=loss_prob,
                        loss_window_s=window)
        wl = serving_corpus("poisson", seed % 4, 8, 3, 1.2, 2)
        reqs = run_virtual_serving(
            wl, lanes=2, seed=seed, scenario=scen,
            on_step=lambda front, server: front.check_conservation())
        assert all(r.done for r in reqs.values())

    def test_blocked_lanes_steer_assignment(self):
        """The partitioner never places work on a blocked lane while a
        healthy one exists (and falls back to all lanes when every
        lane is blocked)."""
        from repro.serving.fig12 import POLICIES
        from repro.serving.frontend import (VirtualModel,
                                            make_request)
        from repro.serving.clock import VirtualClock
        from repro.core.serving import MultiLaneServer
        clocks = [VirtualClock() for _ in range(3)]
        models = [VirtualModel(c, seed=0) for c in clocks]
        server = MultiLaneServer(
            None, None, n_lanes=3, policy=POLICIES["mesc"](),
            max_len=16, total_slots=6,
            jit_fns=[m.jit_fns for m in models], clocks=clocks)
        wl = serving_corpus("poisson", 0, 6, 2, 1.2, 3)
        server.blocked_lanes = {0, 2}
        for spec in wl[:4]:
            assert server.submit(make_request(spec)) == 1
        server.blocked_lanes = {0, 1, 2}     # all lost: fall back
        assert server.submit(make_request(wl[4])) in (0, 1, 2)
