"""Multi-accelerator platform layer: partition heuristics, the
accelerator pool + LO migration-on-idle, the multi-instance simulator
(per-instance/global mode coordination, shared-DMA contention), the
partitioned WCRT analysis, and the KV-slot arena bookkeeping."""
import numpy as np
import pytest

from repro.core import (Crit, MCSSimulator, Policy, TaskParams,
                        analyze_partitioned, generate_taskset, partition,
                        simulate, simulate_multi, utilization,
                        workload_library)
from repro.core.platform import (AcceleratorPool, HEURISTICS,
                                 MigrationPolicy)
from repro.core.serving import KVSlotArena
from repro.core.taskgen import uunifast_discard

LIB = workload_library(include_archs=False)


def _task(tid, prio, u, crit=Crit.LO, c_lo=1e5):
    return TaskParams(tid=tid, priority=prio, period=c_lo / u,
                      deadline=c_lo / u, c_lo=c_lo, c_hi=2 * c_lo,
                      crit=crit, eta=1, workload="small_gemm")


class TestPartition:
    def test_every_task_assigned_every_heuristic(self):
        tasks = generate_taskset(1.6, n_tasks=12, seed=0, programs=LIB)
        for h in HEURISTICS:
            a = partition(tasks, 4, h)
            assert sorted(a.task_to_instance) == sorted(t.tid for t in tasks)
            assert set(a.task_to_instance.values()) <= set(range(4))

    def test_single_instance_degenerates(self):
        tasks = generate_taskset(0.8, n_tasks=8, seed=1, programs=LIB)
        for h in HEURISTICS:
            a = partition(tasks, 1, h)
            assert all(i == 0 for i in a.task_to_instance.values())

    def test_worst_fit_balances_load(self):
        tasks = [_task(i, i, 0.2) for i in range(8)]
        a = partition(tasks, 4, "worst_fit")
        loads = [utilization(a.tasks_on(i, tasks)) for i in range(4)]
        assert max(loads) - min(loads) < 0.21   # within one task's share

    def test_first_fit_packs(self):
        tasks = [_task(i, i, 0.2) for i in range(8)]
        a = partition(tasks, 4, "first_fit")
        loads = [utilization(a.tasks_on(i, tasks)) for i in range(4)]
        assert loads[0] > 0.79                  # 5 x 0.2 fit on instance 0
        assert loads[2] == loads[3] == 0

    def test_crit_aware_spreads_hi_tasks(self):
        tasks = [_task(i, i, 0.1, Crit.HI) for i in range(4)] + \
                [_task(i + 4, i + 4, 0.1, Crit.LO) for i in range(4)]
        a = partition(tasks, 4, "crit_aware")
        hi_per_inst = [sum(1 for t in tasks
                           if t.crit == Crit.HI
                           and a.instance_of(t.tid) == i)
                       for i in range(4)]
        assert hi_per_inst == [1, 1, 1, 1]

    def test_bad_args_raise(self):
        tasks = [_task(0, 0, 0.1)]
        with pytest.raises(ValueError):
            partition(tasks, 0)
        with pytest.raises(ValueError):
            partition(tasks, 2, "best_fit")


class TestUUnifastDiscard:
    def test_respects_cap_and_total(self):
        rng = np.random.default_rng(7)
        u = uunifast_discard(12, 2.4, rng, max_u=0.5)
        assert u.max() <= 0.5
        assert abs(u.sum() - 2.4) < 1e-9

    def test_infeasible_cap_raises(self):
        rng = np.random.default_rng(0)
        with pytest.raises(ValueError):
            uunifast_discard(2, 2.0, rng, max_u=0.5, max_tries=10)


class TestAcceleratorPool:
    def test_migrate_moves_saved_context(self):
        tasks = [_task(0, 0, 0.3), _task(1, 1, 0.3)]
        pool = AcceleratorPool(2, heuristic="worst_fit")
        a = pool.assign(tasks)
        src = a.instance_of(0)
        dst = 1 - src
        pool.instances[src].dram[0] = {
            "accumulator": 1024, "scratchpad": 4096,
            "kept_resident": False, "config": (None,) * 4, "remap": {}}
        cycles = pool.migrate(0, dst)
        assert a.instance_of(0) == dst
        assert 0 in pool.instances[dst].dram
        assert 0 not in pool.instances[src].dram
        assert cycles > 0                       # shipping is not free
        assert pool.migrations == 1

    def test_migrate_to_same_instance_is_free(self):
        tasks = [_task(0, 0, 0.3)]
        pool = AcceleratorPool(2)
        a = pool.assign(tasks)
        assert pool.migrate(0, a.instance_of(0)) == 0.0
        assert pool.migrations == 0


class TestMultiAccelSimulator:
    def test_single_instance_matches_single_simulator(self):
        """N=1 with migration disabled reproduces MCSSimulator exactly
        (same rng contract, same event semantics)."""
        for seed in (0, 2):
            tasks = generate_taskset(0.6, n_tasks=10, seed=seed,
                                     programs=LIB)
            m1 = simulate(tasks, LIB, Policy.mesc(), duration=1e8,
                          seed=seed)
            m2 = simulate_multi(
                tasks, LIB, Policy.mesc(), n_instances=1, duration=1e8,
                seed=seed,
                migration=MigrationPolicy(enabled=False)).merged()
            assert m1.jobs == m2.jobs
            assert m1.misses == m2.misses
            assert m1.cs_count == m2.cs_count
            assert m1.pi_blocking == m2.pi_blocking
            assert m1.ci_blocking == m2.ci_blocking

    def test_partitioned_mesc_bounds_blocking_vs_np(self):
        """On N=4 instances, MESC keeps inversions at instruction scale
        while the non-preemptive pool exposes whole-workload blocking —
        extra instances alone cannot resolve inversions."""
        tasks = generate_taskset(2.4, n_tasks=12, seed=1, programs=LIB,
                                 max_task_u=0.5)
        mesc = simulate_multi(tasks, LIB, Policy.mesc(), n_instances=4,
                              duration=2e8, seed=1).merged()
        np_ = simulate_multi(tasks, LIB, Policy.non_preemptive(),
                             n_instances=4, duration=2e8, seed=1).merged()
        b_mesc = mesc.pi_blocking + mesc.ci_blocking
        b_np = np_.pi_blocking + np_.ci_blocking
        assert b_mesc and b_np
        assert max(b_mesc) * 10 < max(b_np)
        assert np.mean(b_mesc) * 10 < np.mean(b_np)

    def test_migration_on_idle_fires_and_is_charged(self):
        tasks = generate_taskset(1.6, n_tasks=12, seed=1, programs=LIB,
                                 max_task_u=0.5)
        multi = simulate_multi(tasks, LIB, Policy.mesc(), n_instances=4,
                               duration=2e8, seed=1)
        assert multi.migrations > 0
        assert multi.migration_cycles > 0
        off = simulate_multi(tasks, LIB, Policy.mesc(), n_instances=4,
                             duration=2e8, seed=1,
                             migration=MigrationPolicy(enabled=False))
        assert off.migrations == 0
        assert off.migration_cycles == 0

    def test_dma_contention_accounted_only_when_enabled(self):
        tasks = generate_taskset(2.4, n_tasks=12, seed=0, programs=LIB,
                                 max_task_u=0.5)
        on = simulate_multi(tasks, LIB, Policy.mesc(), n_instances=4,
                            duration=1e8, seed=0)
        offm = simulate_multi(tasks, LIB, Policy.mesc(), n_instances=4,
                              duration=1e8, seed=0, dma_contention=False)
        assert on.dma_contention_cycles > 0
        assert offm.dma_contention_cycles == 0

    def test_merged_metrics_sum_per_instance(self):
        tasks = generate_taskset(1.2, n_tasks=10, seed=3, programs=LIB,
                                 max_task_u=0.5)
        multi = simulate_multi(tasks, LIB, Policy.mesc(), n_instances=2,
                               duration=1e8, seed=3)
        merged = multi.merged()
        assert merged.jobs["LO"] == sum(m.jobs["LO"]
                                        for m in multi.per_instance)
        assert merged.jobs["HI"] == sum(m.jobs["HI"]
                                        for m in multi.per_instance)
        assert merged.cs_count == sum(m.cs_count
                                      for m in multi.per_instance)
        assert merged.jobs["LO"] + merged.jobs["HI"] > 0


class TestPartitionedWCRT:
    def test_more_instances_admit_higher_total_utilisation(self):
        tasks = generate_taskset(1.2, n_tasks=12, seed=3, programs=LIB)
        verdicts = [analyze_partitioned(tasks, LIB, n_instances=n)
                    .schedulable for n in (1, 2, 4)]
        assert verdicts == [False, True, True]

    def test_dma_contention_stretch_can_break_schedulability(self):
        """The shared-DMA model inflates Upsilon^S/R by N; with it off,
        analysis can only get more optimistic."""
        tasks = generate_taskset(1.6, n_tasks=12, seed=3, programs=LIB)
        with_dma = analyze_partitioned(tasks, LIB, n_instances=4,
                                       dma_contention=True)
        without = analyze_partitioned(tasks, LIB, n_instances=4,
                                      dma_contention=False)
        assert without.schedulable or not with_dma.schedulable

    def test_empty_instances_are_schedulable(self):
        tasks = [_task(0, 0, 0.2, Crit.HI)]
        r = analyze_partitioned(tasks, LIB, n_instances=4)
        assert sum(1 for res in r.per_instance.values()
                   if not res.lo and not res.hi) >= 3


class TestKVSlotArena:
    def test_quotas_partition_total(self):
        a = KVSlotArena(5, 2)
        assert a.quotas == [3, 2]
        with pytest.raises(ValueError):
            KVSlotArena(4, 2, quotas=[3, 3])
        with pytest.raises(ValueError):
            KVSlotArena(1, 2)                 # a lane would get 0 slots

    def test_acquire_release_enforce_quota(self):
        a = KVSlotArena(2, 2)
        a.acquire(0, 10)
        a.acquire(0, 10)                      # idempotent re-acquire
        assert a.held(0) == 1
        with pytest.raises(RuntimeError):
            a.acquire(0, 11)                  # lane 0 quota = 1
        a.acquire(1, 12)                      # lane 1 unaffected
        a.release(0, 10)
        a.acquire(0, 11)
        assert (a.held(0), a.held(1)) == (1, 1)
