"""Per-kernel correctness: shape/dtype sweeps, interpret=True vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.systolic_gemm import gemm_partial, systolic_gemm

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 512, 128, 128, 128, 128),
    (512, 256, 384, 128, 128, 128),
    (128, 1024, 256, 64, 128, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_systolic_gemm_sweep(M, K, N, bm, bn, bk, dtype):
    a = jax.random.normal(KEY, (M, K), jnp.float32).astype(dtype)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (K, N),
                          jnp.float32).astype(dtype)
    out = systolic_gemm(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.gemm_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol * K ** 0.5, rtol=tol)


@pytest.mark.parametrize("split", [1, 2, 3])
def test_gemm_preempt_resume(split):
    """Preempting a GEMM mid-K and resuming from the saved accumulator is
    exact — the step_wise_mvout analogue (paper SS V.A)."""
    M = K = N = 512
    bk = 128
    a = jax.random.normal(KEY, (M, K), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(KEY, 2), (K, N), jnp.float32)
    nk = K // bk
    acc = jnp.zeros((M, N), jnp.float32)
    acc = gemm_partial(a, b, acc, 0, split, bk=bk, interpret=True)
    acc = gemm_partial(a, b, acc, split, nk, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-2)


@pytest.mark.parametrize("B,Hq,Hkv,S,dh,bq,bkv", [
    (1, 4, 4, 128, 64, 64, 64),      # MHA
    (2, 8, 2, 256, 64, 64, 128),     # GQA
    (1, 8, 1, 128, 128, 32, 32),     # MQA
])
def test_flash_attention_sweep(B, Hq, Hkv, S, dh, bq, bkv):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, dh), jnp.float32)
    from repro.kernels.flash_attention import flash_attention_tpu
    out = flash_attention_tpu(q, k, v, block_q=bq, block_kv=bkv,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=5e-5)


@pytest.mark.parametrize("pos", [0, 17, 255])
@pytest.mark.parametrize("B,Hq,Hkv,S,dh", [(2, 8, 2, 256, 64),
                                           (1, 4, 4, 512, 32)])
def test_decode_attention_sweep(B, Hq, Hkv, S, dh, pos):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, dh), jnp.float32)
    from repro.kernels.decode_attention import decode_attention_tpu
    out = decode_attention_tpu(q, k, v, pos, block_s=64, interpret=True)
    want = ref.decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=5e-5)


@pytest.mark.parametrize("B,S,D,bs,bd", [(2, 128, 256, 32, 128),
                                         (1, 64, 512, 64, 256)])
def test_rglru_kernel_sweep(B, S, D, bs, bd):
    ks = jax.random.split(KEY, 3)
    a = jax.random.uniform(ks[0], (B, S, D), jnp.float32, 0.4, 0.999)
    b = jax.random.normal(ks[1], (B, S, D), jnp.float32)
    h0 = jax.random.normal(ks[2], (B, D), jnp.float32)
    from repro.kernels.rglru_scan import rglru_scan_tpu
    out = rglru_scan_tpu(a, b, h0, block_s=bs, block_d=bd, interpret=True)
    want = ref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)
