"""Property tests for the serving admission layer.

Three invariants the front end promises (docs/serving.md), exercised
over randomized inputs via hypothesis — or the deterministic fallback
sampler (``repro._compat.hypothesis_fallback``) on images without it;
both paths run the same properties:

  * **arena quota** — no interleaving of acquire/release ever leaves a
    lane holding more KV slots than its static quota, and an
    over-acquire raises instead of silently oversubscribing;
  * **conservation** — ``finished + live + queued == submitted`` at
    every observable step of an open-loop serving run (no request is
    ever dropped or double-counted by the front door);
  * **HI-never-behind-LO** — in any front-door drain order and in any
    lane's eligible order, a HI-criticality request is never queued
    behind a LO one.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import Policy
from repro.core.serving import KVSlotArena, MESCServer, Request
from repro.core.task import Crit

from harness import ServingCase, run_serving_case


class TestArenaQuota:
    @settings(max_examples=25, deadline=None)
    @given(total=st.integers(2, 12), n_lanes=st.integers(1, 4),
           ops=st.lists(st.tuples(st.integers(0, 3),    # lane (mod)
                                  st.integers(0, 15),   # rid
                                  st.booleans()),       # acquire?
                        min_size=1, max_size=60))
    def test_no_interleaving_exceeds_quota(self, total, n_lanes, ops):
        n_lanes = min(n_lanes, total)       # every lane needs >= 1 slot
        arena = KVSlotArena(total, n_lanes)
        assert sum(arena.quotas) == total   # quotas partition the pool
        for lane_raw, rid, acquire in ops:
            lane = lane_raw % n_lanes
            if acquire:
                if arena.can_admit(lane) or rid in arena._held[lane]:
                    arena.acquire(lane, rid)
                else:
                    with pytest.raises(RuntimeError, match="over quota"):
                        arena.acquire(lane, rid)
            else:
                arena.release(lane, rid)
            assert all(arena.held(i) <= arena.quotas[i]
                       for i in range(n_lanes))

    def test_quota_validation(self):
        with pytest.raises(ValueError, match="partition"):
            KVSlotArena(4, 2, quotas=[3, 3])
        with pytest.raises(ValueError, match=">= 1 slot"):
            KVSlotArena(2, 2, quotas=[2, 0])


class TestConservation:
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 10 ** 6),
           policy=st.sampled_from(["mesc", "np"]),
           cap=st.sampled_from([None, 1, 2]))
    def test_every_step_conserves_requests(self, seed, policy, cap):
        """finished + live + queued == submitted after every scheduler
        step of a full open-loop run (the hook also re-checks the
        front door's own accounting)."""
        case = ServingCase(f"prop-{policy}-{seed}-{cap}", policy=policy,
                           seed=seed, n_lo=8, n_hi=3, max_live_lo=cap)
        checks = []

        def watch(front, server):
            front.check_conservation()      # raises on violation
            checks.append(front.submitted)

        rows = run_serving_case(case, on_step=watch)
        assert checks, "driver never stepped"
        assert checks[-1] == case.n_lo + case.n_hi  # all arrived
        summary = rows[-1]
        assert summary["hi_finished"] + summary["lo_finished"] \
            == case.n_lo + case.n_hi                 # all finished


class TestHiNeverBehindLo:
    @settings(max_examples=15, deadline=None)
    @given(n_hi=st.integers(1, 5), n_lo=st.integers(1, 8),
           seed=st.integers(0, 10 ** 6))
    def test_eligible_order(self, n_hi, n_lo, seed):
        """In a lane's eligible order every HI request precedes every
        LO request, whatever the submission interleaving."""
        rng = np.random.default_rng(seed)
        srv = MESCServer(None, None, policy=Policy.mesc(), max_len=16,
                         jit_fns=(lambda *a: None, lambda *a: None))
        reqs = ([Request(rid=i, priority=i,
                         prompt=np.asarray([i], np.int32),
                         max_new_tokens=2, crit=Crit.HI)
                 for i in range(n_hi)]
                + [Request(rid=100 + i, priority=1_000_000 + i,
                           prompt=np.asarray([i], np.int32),
                           max_new_tokens=2, crit=Crit.LO)
                   for i in range(n_lo)])
        rng.shuffle(reqs)
        for r in reqs:
            srv.submit(r)
        order = [r.crit for r in srv.eligible_order()]
        assert order == sorted(order,
                               key=lambda c: 0 if c == Crit.HI else 1)
        assert order.count(Crit.HI) == n_hi

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10 ** 6), cap=st.sampled_from([None, 1]))
    def test_front_door_admission_order(self, seed, cap):
        """pump() admits every queued HI request before any LO request
        — even when the LO throttle is wide open."""
        from repro.serving import FrontDoor
        from repro.serving.traffic import ArrivalSpec

        class Sink:                        # records admission order
            def __init__(self):
                self.requests = {}

            def submit(self, r):
                r.submitted_at = r.submitted_at or 0.0
                self.requests[r.rid] = r

        rng = np.random.default_rng(seed)
        front = FrontDoor(Sink(), max_live_lo=cap)
        specs = ([ArrivalSpec(t=0.0, rid=i, crit=Crit.HI, priority=i,
                              max_new_tokens=1) for i in range(3)]
                 + [ArrivalSpec(t=0.0, rid=10 + i, crit=Crit.LO,
                                priority=1_000_000 + i,
                                max_new_tokens=1) for i in range(4)])
        rng.shuffle(specs)
        for s in specs:
            front.arrive(s)
        admitted = front.pump()
        crits = [front.server.requests[rid].crit for rid in admitted]
        hi_tail = crits.index(Crit.LO) if Crit.LO in crits else len(crits)
        assert all(c == Crit.HI for c in crits[:hi_tail])
        assert crits.count(Crit.HI) == 3   # HI is never throttled
        if cap == 1:
            assert crits.count(Crit.LO) == 1
        front.check_conservation()
