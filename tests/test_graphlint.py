"""Tests for graph-lint (tools/graphlint/): the jaxpr walker units,
the budget-manifest pin/tamper/repin workflow on throwaway trees (one
shared set of tiny-corpus compiles behind ``live_report``'s memo), the
committed manifest's own contracts (19 dtype-homogeneous carry
tensors, neutral-scenario equality, donation), the ``kernel_budget``
bridge perf_sim logs through, and the CLI's exit-code contract.
"""
import json
import os
import subprocess
import sys
import types
from pathlib import Path

import pytest

from tools.graphlint import (CANONICAL_CASE, IR_RULES, NEUTRAL_CASE,
                             budgets, kernel_budget, update_budgets)
from tools.graphlint import trace
from tools.lint.core import RULES, run_lint
import tools.lint.rules  # noqa: F401  (registers the rule families)

REPO = Path(__file__).resolve().parents[1]

#: a deliberately tiny corpus so the workflow tests compile toy graphs
#: (seconds, shared through the live_report memo), while exercising
#: the exact same trace/compare/repin path as the canonical manifest
TINY_SPEC = {"utils": [0.7], "n_seeds": 2, "n_tasks": 4,
             "duration": 2.0e5, "overrun_prob": 0.3, "cf": 2.0,
             "table_width": 16, "chunk": 64}

TINY_CASES = {
    CANONICAL_CASE: {
        "config": {"policy": "mesc", "demand_profile": "sampled",
                   "scenario": None, "devices": 1}},
    NEUTRAL_CASE: {
        "config": {"policy": "mesc", "demand_profile": "sampled",
                   "scenario": "faults@0", "devices": 1},
        "equals": CANONICAL_CASE},
}


def make_tree(tmp_path, cases=TINY_CASES):
    """A throwaway repo root with a freshly pinned tiny manifest."""
    path = tmp_path / "tools" / "graphlint" / "budgets.json"
    path.parent.mkdir(parents=True)
    path.write_text(json.dumps({
        "version": budgets.BUDGETS_VERSION, "spec": dict(TINY_SPEC),
        "cases": json.loads(json.dumps(cases))}))
    update_budgets(tmp_path)
    return path


def tamper(path: Path, fn):
    data = json.loads(path.read_text())
    fn(data)
    path.write_text(json.dumps(data))


def ir_lint(root, rules=IR_RULES):
    report, _ = run_lint(root, ["tools/graphlint/budgets.json"],
                         rule_names=list(rules), use_baseline=False)
    return report


def rules_fired(report):
    return {f.rule for f in report.findings}


class TestRegistry:
    def test_ir_rules_registered_and_nondefault(self):
        for name in IR_RULES:
            assert name in RULES
            assert RULES[name].default is False
            assert len(RULES[name].contract) > 20

    def test_default_lint_run_excludes_ir_family(self, tmp_path):
        f = tmp_path / "clean.py"
        f.write_text('"""doc."""\n')
        report, _ = run_lint(REPO, [str(f)], use_baseline=False)
        assert not set(report.rules_run) & set(IR_RULES)


class TestJaxprWalker:
    """Toy traced functions — no engine, milliseconds."""

    def _closed(self, fn, *args):
        import jax
        return jax.make_jaxpr(jax.jit(fn))(*args)

    def test_find_while_through_pjit_wrapper(self):
        import jax.numpy as jnp
        from jax import lax

        def f(c):
            return lax.while_loop(lambda c: c[0] < 10,
                                  lambda c: (c[0] + 1, c[1] * 2.0), c)
        closed = self._closed(f, (jnp.int32(0), jnp.float32(1.0)))
        assert trace.find_while(closed.jaxpr).primitive.name == "while"

    def test_histogram_recurses_and_skips_wrappers(self):
        import jax.numpy as jnp
        from jax import lax

        def f(c):
            return lax.while_loop(lambda c: c[0] < 10,
                                  lambda c: (c[0] + 1, c[1] * 2.0), c)
        hist = trace.primitive_histogram(
            self._closed(f, (jnp.int32(0), jnp.float32(1.0))).jaxpr)
        assert hist.get("while") == 1
        assert "pjit" not in hist
        assert hist.get("mul", 0) >= 1      # inside the body sub-jaxpr

    def test_find_while_raises_on_whileless_graph(self):
        import jax.numpy as jnp
        closed = self._closed(lambda x: x * 2, jnp.float32(3.0))
        with pytest.raises(ValueError, match="no while eqn"):
            trace.find_while(closed.jaxpr)

    def test_banned_detects_traced_rng(self):
        import jax

        def f(key):
            return jax.random.uniform(key)
        banned = trace.banned_primitives(
            self._closed(f, jax.random.PRNGKey(0)).jaxpr)
        assert banned, "threefry/random_* primitives not flagged"
        assert all(p.startswith(("threefry", "random_"))
                   for p in banned)

    def test_banned_clean_on_pure_arithmetic(self):
        import jax.numpy as jnp
        closed = self._closed(lambda x: jnp.sin(x) + 1, jnp.float32(0.))
        assert trace.banned_primitives(closed.jaxpr) == {}

    def test_dtype_summary_counts_float32_ops(self):
        import jax.numpy as jnp
        closed = self._closed(lambda x: x + 1, jnp.float32(0.0))
        assert trace.dtype_summary(closed.jaxpr)["float32_ops"] >= 1

    def test_dtype_summary_counts_f64_demotions(self):
        import jax.numpy as jnp
        from jax.experimental import enable_x64
        with enable_x64():
            closed = self._closed(lambda x: x.astype(jnp.float32),
                                  jnp.float64(0.0))
            summary = trace.dtype_summary(closed.jaxpr)
        assert summary["f64_to_f32_demotions"] == 1

    def test_donation_summary_parses_alias_header(self):
        hlo = ("HloModule jit__run, input_output_alias={ {0}: "
               "(2, {}, may-alias), {1}: (3, {}, may-alias) }\n"
               "ENTRY main { ... }\n")
        assert trace.donation_summary(hlo, []) == \
            {"donated": 2, "dropped": 0}

    def test_donation_summary_counts_dropped_warnings(self):
        w = types.SimpleNamespace(
            message="Some donated buffers were not usable")
        assert trace.donation_summary("HloModule x\n", [w]) == \
            {"donated": 0, "dropped": 1}

    def test_retrace_surface_is_o1_in_corpus_size(self):
        surface = trace.retrace_surface(TINY_SPEC)
        for corpus, row in surface.items():
            assert row["signatures"] == 1, (corpus, row)


class TestBudgetDiff:
    def test_flatten_dotted_paths(self):
        flat = budgets.flatten("", {"carry": {"dtypes":
                                              {"ev_time": "float64"}},
                               "k": 1})
        assert flat == {"carry.dtypes.ev_time": "float64", "k": 1}

    def test_diff_reports_changed_and_missing_leaves(self):
        rows = budgets.diff_budget({"a": 1, "b": {"c": 2}},
                                   {"a": 1, "b": {"c": 3, "d": 4}})
        assert ("b.c", 2, 3) in rows
        assert ("b.d", None, 4) in rows
        assert not any(p == "a" for p, _, _ in rows)

    def test_diff_respects_field_slice_and_unpinned(self):
        pinned = {"while_body_kernels": 5}
        live = {"while_body_kernels": 6, "banned_primitives": {"x": 1},
                "donation": {"donated": 0}}
        rows = budgets.diff_budget(pinned, live,
                                   ("while_body_kernels",))
        assert [p for p, _, _ in rows] == ["while_body_kernels"]
        # banned_primitives is live-only diagnostics, never drift
        assert not any("banned" in p for p, _, _
                       in budgets.diff_budget(pinned, live))


class TestBudgetManifest:
    """Pin / tamper / repin on throwaway trees (tiny corpus)."""

    def test_update_budgets_pins_clean_tree(self, tmp_path):
        make_tree(tmp_path)
        report = ir_lint(tmp_path)
        assert report.findings == [], [f.message
                                       for f in report.findings]

    def test_kernel_count_tamper_names_engine_and_field(self, tmp_path):
        path = make_tree(tmp_path)
        tamper(path, lambda d: d["cases"][CANONICAL_CASE]["budget"]
               .__setitem__("while_body_kernels", 1))
        report = ir_lint(tmp_path)
        assert "ir-budget-drift" in rules_fired(report)
        msg = "\n".join(f.message for f in report.findings)
        assert CANONICAL_CASE in msg and "while_body_kernels" in msg
        assert "--update-budgets" in msg

    def test_histogram_tamper_fires_budget_drift(self, tmp_path):
        path = make_tree(tmp_path)

        def bump(d):
            h = d["cases"][CANONICAL_CASE]["budget"][
                "primitive_histogram"]
            h["add"] = h.get("add", 0) + 7
        tamper(path, bump)
        report = ir_lint(tmp_path, rules=("ir-budget-drift",))
        assert rules_fired(report) == {"ir-budget-drift"}
        assert any("primitive_histogram.add" in f.message
                   for f in report.findings)

    def test_total_bytes_is_budget_not_dtype(self, tmp_path):
        path = make_tree(tmp_path)
        tamper(path, lambda d: d["cases"][CANONICAL_CASE]["budget"]
               ["carry"].__setitem__("total_bytes", 1))
        report = ir_lint(tmp_path)
        assert rules_fired(report) == {"ir-budget-drift"}

    def test_carry_dtype_tamper_fires_dtype_rule(self, tmp_path):
        path = make_tree(tmp_path)
        tamper(path, lambda d: d["cases"][CANONICAL_CASE]["budget"]
               ["carry"]["dtypes"].__setitem__("ev_time", "float32"))
        report = ir_lint(tmp_path, rules=("ir-dtype-discipline",))
        assert rules_fired(report) == {"ir-dtype-discipline"}
        assert any("carry.dtypes.ev_time" in f.message
                   for f in report.findings)

    def test_carry_tensor_count_tamper_fires_dtype_rule(self, tmp_path):
        path = make_tree(tmp_path)
        tamper(path, lambda d: d["cases"][CANONICAL_CASE]["budget"]
               ["carry"].__setitem__("tensors", 16))
        report = ir_lint(tmp_path, rules=("ir-dtype-discipline",))
        assert any("carry.tensors" in f.message
                   for f in report.findings)

    def test_donation_tamper_fires_donation_rule(self, tmp_path):
        path = make_tree(tmp_path)
        tamper(path, lambda d: d["cases"][CANONICAL_CASE]["budget"]
               ["donation"].__setitem__("donated", 0))
        report = ir_lint(tmp_path, rules=("ir-donation",))
        assert rules_fired(report) == {"ir-donation"}
        assert any("donation.donated" in f.message
                   for f in report.findings)

    def test_equals_divergence_fires_neutrality_finding(self, tmp_path):
        path = make_tree(tmp_path)
        tamper(path, lambda d: d["cases"][NEUTRAL_CASE]["budget"]
               .__setitem__("while_body_kernels", 999))
        report = ir_lint(tmp_path, rules=("ir-budget-drift",))
        msgs = [f.message for f in report.findings]
        assert any("graph-equal" in m and NEUTRAL_CASE in m
                   for m in msgs), msgs

    def test_retrace_pin_tamper_fires_retrace_rule(self, tmp_path):
        path = make_tree(tmp_path)
        tamper(path, lambda d: d["retrace"]["fig8-d1"]
               .__setitem__("signatures", 64))
        report = ir_lint(tmp_path, rules=("ir-retrace-surface",))
        assert rules_fired(report) == {"ir-retrace-surface"}

    def test_per_point_retrace_is_flagged(self, tmp_path, monkeypatch):
        path = make_tree(tmp_path, cases={})
        per_point = {"toy-d1": {"n_points": 8, "signatures": 8}}
        tamper(path, lambda d: d.__setitem__("retrace", per_point))
        monkeypatch.setattr(
            budgets, "live_report",
            lambda manifest, only=None: {"cases": {},
                                         "retrace": per_point})
        report = ir_lint(tmp_path, rules=("ir-retrace-surface",))
        assert any("retraces per point" in f.message
                   for f in report.findings)

    def test_update_budgets_repins_to_clean(self, tmp_path):
        path = make_tree(tmp_path)
        tamper(path, lambda d: d["cases"][CANONICAL_CASE]["budget"]
               .__setitem__("while_body_kernels", 1))
        changed = update_budgets(tmp_path)
        assert f"{CANONICAL_CASE}.while_body_kernels" in changed
        assert ir_lint(tmp_path).findings == []

    def test_unmeasurable_serving_probe_is_skipped(self, tmp_path):
        # in-process the engine compiles above already initialized a
        # backend, so the serving probe reports None -> no findings
        cases = dict(TINY_CASES)
        cases["serving-virtual"] = {"config": {"engine": "serving"},
                                    "budget": {"xla_compilations": 2}}
        make_tree(tmp_path, cases=cases)
        assert ir_lint(tmp_path).findings == []


class TestKernelBudget:
    """The manifest numbers perf_sim logs (BENCH_sim.json schema)."""

    def test_roundtrip_matches_pins(self, tmp_path):
        path = make_tree(tmp_path)
        data = json.loads(path.read_text())
        out = kernel_budget(tmp_path)
        assert set(out) == {"xla_kernels",
                            "xla_kernels_neutral_scenario"}
        assert out["xla_kernels"] == \
            data["cases"][CANONICAL_CASE]["budget"]["while_body_kernels"]
        assert out["xla_kernels"] == \
            out["xla_kernels_neutral_scenario"]

    def test_drift_exits_naming_the_repin_step(self, tmp_path):
        path = make_tree(tmp_path)
        tamper(path, lambda d: d["cases"][CANONICAL_CASE]["budget"]
               .__setitem__("while_body_kernels", 1))
        with pytest.raises(SystemExit, match="--update-budgets"):
            kernel_budget(tmp_path)

    def test_missing_manifest_exits_with_recipe(self, tmp_path):
        with pytest.raises(SystemExit, match="--update-budgets"):
            kernel_budget(tmp_path)


class TestCommittedManifest:
    """The real tools/graphlint/budgets.json: the acceptance-surface
    contracts, checked without tracing (pure JSON reads)."""

    @pytest.fixture(scope="class")
    def manifest(self):
        data = budgets.load_budgets(REPO)
        assert data is not None, "committed budgets.json missing"
        return data

    def test_canonical_cases_present(self, manifest):
        for name in (CANONICAL_CASE, NEUTRAL_CASE,
                     "jit-mesc-sampled-d2", "jit-np-sampled",
                     "serving-virtual"):
            assert name in manifest["cases"], name

    def test_neutral_scenario_pins_identical_budget(self, manifest):
        assert manifest["cases"][NEUTRAL_CASE]["equals"] == \
            CANONICAL_CASE
        assert manifest["cases"][NEUTRAL_CASE]["budget"] == \
            manifest["cases"][CANONICAL_CASE]["budget"]

    def test_carry_contract_19_homogeneous_tensors(self, manifest):
        # PR 5's 16 grouped tensors + the PR 8 scenario tensors
        # (sn/sw/sm) + the step counter; each a single dtype
        from repro.core.simulator_jit import _CARRY_KEYS
        for name, case in manifest["cases"].items():
            carry = case["budget"].get("carry")
            if carry is None:        # serving case
                continue
            assert carry["tensors"] == len(_CARRY_KEYS) == 19, name
            assert set(carry["dtypes"]) == set(_CARRY_KEYS), name
            for tensor, dtype in carry["dtypes"].items():
                assert dtype in ("float64", "int32", "int64",
                                 "uint64"), (name, tensor, dtype)

    def test_every_jit_case_donates_its_whole_carry(self, manifest):
        for name, case in manifest["cases"].items():
            donation = case["budget"].get("donation")
            if donation is None:
                continue
            assert donation == {"donated": 19, "dropped": 0}, name

    def test_dtype_counters_pinned_at_zero(self, manifest):
        for name, case in manifest["cases"].items():
            b = case["budget"]
            if "float32_ops" not in b:
                continue
            assert b["float32_ops"] == 0, name
            assert b["f64_to_f32_demotions"] == 0, name

    def test_retrace_surface_pinned_o1(self, manifest):
        for corpus, row in manifest["retrace"].items():
            assert row["signatures"] < row["n_points"] \
                or row["n_points"] <= 1, (corpus, row)


class TestCli:
    """Exit-code contract via subprocess (fresh jax-free processes)."""

    def gl(self, *args, cwd=REPO):
        # hermetic env: earlier tests (device_config) leave platform
        # overrides in os.environ that must not steer the subprocess
        env = {k: v for k, v in os.environ.items()
               if k not in ("XLA_FLAGS", "JAX_PLATFORM_NAME")
               and not k.startswith("REPRO_")}
        return subprocess.run(
            [sys.executable, "-m", "tools.graphlint", *args],
            cwd=cwd, capture_output=True, text=True, env=env)

    def test_list_rules(self):
        p = self.gl("--list-rules")
        assert p.returncode == 0
        for name in IR_RULES:
            assert name in p.stdout

    def test_missing_manifest_is_invocation_error(self, tmp_path):
        p = self.gl("--root", str(tmp_path))
        assert p.returncode == 2
        assert "no manifest" in p.stderr

    def test_unknown_case_is_invocation_error(self):
        p = self.gl("--cases", "no-such-case")
        assert p.returncode == 2
        assert "unknown budget case" in p.stderr

    def test_unknown_rule_is_invocation_error(self):
        p = self.gl("--rules", "ir-nope")
        assert p.returncode == 2
        assert "unknown ir rule" in p.stderr

    def test_serving_probe_authoritative_in_fresh_process(self):
        # a fresh process measures the serving compilation ceiling for
        # real (no engine compile pollutes the eager-kernel cache) —
        # and json format round-trips the report
        p = self.gl("--cases", "serving-virtual", "--format", "json")
        assert p.returncode == 0, p.stdout + p.stderr
        data = json.loads(p.stdout)
        assert data["exit_code"] == 0 and data["findings"] == []
