"""Every ``*_SEMANTICS_VERSION`` salt reaches the cache keys it
protects, and the lint pin registry knows about all of them.

Three layers of guarantee:

  * discovery — AST-scan ``core/`` and ``serving/`` for salt
    constants; a newly added salt that is not registered in
    ``tools/lint/salts.json`` (and therefore not drift-pinned) fails
    here before it can silently serve stale cache entries;
  * emission — ``SimPoint.to_dict`` carries the right engine salt per
    engine (event: ``sim_v`` only; vec/jit: plus their own), fig11's
    FuncSweep items carry BOTH shared-path salts, and fig12's items
    carry ``serving_v`` (the SERVING salt's only route into keys);
  * sensitivity — the serialized dicts embed the salts by value, so
    any bump changes every affected content hash.
"""
import ast
import json
from pathlib import Path

import pytest

from repro.core.simulator import (MULTI_SIM_SEMANTICS_VERSION,
                                  SIM_SEMANTICS_VERSION)
from repro.core.simulator_vec import (JIT_SIM_SEMANTICS_VERSION,
                                      VEC_SIM_SEMANTICS_VERSION)
from repro.experiments.spec import Policy, Sweep
from repro.serving.fig12 import SERVING_SEMANTICS_VERSION

REPO = Path(__file__).resolve().parents[1]


def declared_salts():
    """name -> (module rel-path, int value) for every module-level
    ``*_SEMANTICS_VERSION`` constant under core/ and serving/."""
    out = {}
    for pkg in ("core", "serving"):
        for path in sorted((REPO / "src" / "repro" / pkg).glob("*.py")):
            tree = ast.parse(path.read_text(encoding="utf-8"))
            for node in tree.body:
                if not isinstance(node, ast.Assign):
                    continue
                for t in node.targets:
                    if isinstance(t, ast.Name) and \
                            t.id.endswith("_SEMANTICS_VERSION") and \
                            isinstance(node.value, ast.Constant) and \
                            isinstance(node.value.value, int):
                        rel = path.relative_to(REPO).as_posix()
                        # simulator_jit re-exports the vec-defined
                        # salt; only true definitions count
                        out.setdefault(t.id, (rel, node.value.value))
    return out


class TestSaltRegistry:
    def test_every_declared_salt_is_drift_pinned(self):
        pins = json.loads(
            (REPO / "tools/lint/salts.json").read_text())["salts"]
        declared = declared_salts()
        assert set(declared) == set(pins), (
            "salt constants and tools/lint/salts.json disagree — a new "
            "*_SEMANTICS_VERSION must be registered (with its semantic "
            "surface) so salt-drift can pin it")
        for name, (rel, value) in declared.items():
            assert pins[name]["defined_in"] == rel, name
            assert pins[name]["value"] == value, name

    def test_expected_salt_population(self):
        assert set(declared_salts()) == {
            "SIM_SEMANTICS_VERSION", "MULTI_SIM_SEMANTICS_VERSION",
            "VEC_SIM_SEMANTICS_VERSION", "JIT_SIM_SEMANTICS_VERSION",
            "SERVING_SEMANTICS_VERSION"}


def _point(engine):
    return Sweep(name="t", policies=(Policy.mesc(),), n_sets=1,
                 duration=1e6, engine=engine).points()[0]


class TestSimPointEmission:
    def test_event_points_carry_sim_salt_only(self):
        d = _point("event").to_dict()
        assert d["sim_v"] == SIM_SEMANTICS_VERSION
        assert "engine" not in d          # legacy-key compatibility
        assert "vec_sim_v" not in d and "jit_sim_v" not in d

    def test_vec_points_add_the_vec_salt(self):
        d = _point("vec").to_dict()
        assert d["sim_v"] == SIM_SEMANTICS_VERSION
        assert d["vec_sim_v"] == VEC_SIM_SEMANTICS_VERSION
        assert d["engine"] == "vec" and "jit_sim_v" not in d

    def test_jit_points_add_the_jit_salt(self):
        d = _point("jit").to_dict()
        assert d["sim_v"] == SIM_SEMANTICS_VERSION
        assert d["jit_sim_v"] == JIT_SIM_SEMANTICS_VERSION
        assert d["engine"] == "jit" and "vec_sim_v" not in d

    @pytest.mark.parametrize("engine", ["event", "vec", "jit"])
    def test_keys_differ_across_engines(self, engine):
        assert len({_point(e).key()
                    for e in ("event", "vec", "jit")}) == 3


class TestFuncSweepEmission:
    def test_fig11_items_carry_both_shared_path_salts(self):
        from benchmarks.fig11_multiacc import sweep
        pts = sweep(full=False).points()
        assert pts, "fig11 sweep is empty"
        for p in pts:
            kw = dict(p.kwargs)
            assert kw["sim_v"] == [SIM_SEMANTICS_VERSION,
                                   MULTI_SIM_SEMANTICS_VERSION]
            assert kw["sim_v"] == p.to_dict()["kwargs"]["sim_v"]

    def test_fig12_items_carry_the_serving_salt(self):
        from benchmarks.fig12_serving_slo import sweep
        pts = sweep(2).points()
        assert pts, "fig12 sweep is empty"
        for p in pts:
            kw = dict(p.kwargs)
            assert kw["serving_v"] == SERVING_SEMANTICS_VERSION
            assert p.to_dict()["kwargs"]["serving_v"] == \
                SERVING_SEMANTICS_VERSION
