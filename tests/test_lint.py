"""Tests for the repro-lint static-analysis framework (tools/lint/).

Covers, per rule, a golden violating fixture (the rule fires, and only
it) and a clean fixture (zero findings); plus the framework mechanics:
pragma suppression, baseline round-trip with stale-entry detection,
the salt-drift pin/mutate/bump/re-pin workflow on a throwaway tree,
and the CLI's exit-code contract (0 clean / 1 findings / 2 bad
invocation) including ``--format json``.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from tools.lint import RULES, run_lint
from tools.lint.core import (Context, pragma_disabled,
                             pragma_justification, write_baseline)
from tools.lint.rules.salt_drift import (normalized_fingerprint,
                                         update_salts)

REPO = Path(__file__).resolve().parents[1]
TESTDATA = REPO / "tools" / "lint" / "testdata"
BAD = TESTDATA / "bad"
GOOD = TESTDATA / "good"
TREES = TESTDATA / "trees"

#: the default (stdlib-only, AST/text) family
EXPECTED_RULES = {
    "doc-link", "env-validation", "except-breadth", "jit-purity",
    "module-docstring", "no-host-rng", "no-wall-clock", "salt-drift",
    "xp-generic",
}

#: the non-default jax-costing family (tools/graphlint); registered in
#: the same registry, excluded from no---rules runs
IR_RULES = {
    "ir-budget-drift", "ir-donation", "ir-dtype-discipline",
    "ir-graph-purity", "ir-retrace-surface",
}


def lint(paths, root=REPO, rules=None, baseline=None):
    report, _ = run_lint(root, [str(p) for p in paths],
                         rule_names=rules, baseline_path=baseline,
                         use_baseline=baseline is not None)
    return report


def cli(*args, cwd=REPO):
    return subprocess.run([sys.executable, "-m", "tools.lint", *args],
                          cwd=cwd, capture_output=True, text=True)


class TestRegistry:
    def test_all_rules_registered(self):
        assert set(RULES) == EXPECTED_RULES | IR_RULES

    def test_every_rule_states_its_contract(self):
        for rule in RULES.values():
            assert len(rule.contract) > 20, rule.name

    def test_default_family_is_exactly_the_ast_rules(self):
        assert {n for n, r in RULES.items() if r.default} == \
            EXPECTED_RULES


class TestViolatingFixtures:
    """Each bad fixture fires exactly its rule, nothing else."""

    CASES = [
        ("except_breadth_bad.py", "except-breadth", 3),
        ("host_rng_bad.py", "no-host-rng", 3),
        ("jit_purity_bad.py", "jit-purity", 4),
        ("xp_generic_bad.py", "xp-generic", 2),
        ("env_validation_bad.py", "env-validation", 4),
        ("doc_link_bad.md", "doc-link", 2),
    ]

    @pytest.mark.parametrize("fname,rule,count", CASES)
    def test_fixture_fires_its_rule(self, fname, rule, count):
        report = lint([BAD / fname])
        assert {f.rule for f in report.findings} == {rule}
        assert len(report.findings) == count
        assert report.exit_code == 1

    def test_jit_purity_names_every_host_construct(self):
        msgs = "\n".join(f.message for f in
                         lint([BAD / "jit_purity_bad.py"]).findings)
        for expect in ("python if", "float()", ".item()",
                       "numpy.maximum"):
            assert expect in msgs

    def test_env_validation_checks_real_registry(self):
        msgs = [f.message for f in
                lint([BAD / "env_validation_bad.py"]).findings]
        enum = [m for m in msgs if "ENGINES" in m]
        assert len(enum) == 1 and "'evnet'" in enum[0]


class TestCleanFixtures:
    @pytest.mark.parametrize("fname", sorted(
        p.name for p in GOOD.iterdir() if p.name != "pragma_good.py"))
    def test_clean_fixture_has_no_findings(self, fname):
        report = lint([GOOD / fname])
        assert report.findings == []
        assert report.exit_code == 0

    def test_pragmas_suppress_but_are_counted(self):
        report = lint([GOOD / "pragma_good.py"])
        assert report.findings == []
        assert {f.rule for f in report.suppressed} == \
            {"no-host-rng", "except-breadth"}

    def test_suppressed_findings_carry_justifications(self):
        report = lint([GOOD / "pragma_good.py"])
        assert report.suppressed_justifications == \
            ["fixture"] * len(report.suppressed)

    def test_suppressed_findings_in_json_output(self):
        p = cli("--no-baseline", "--format", "json",
                str(GOOD / "pragma_good.py"))
        assert p.returncode == 0
        data = json.loads(p.stdout)
        assert data["findings"] == []
        assert data["suppressed"] == 2
        rows = data["suppressed_findings"]
        assert {r["rule"] for r in rows} == \
            {"no-host-rng", "except-breadth"}
        for r in rows:
            assert r["justification"] == "fixture"
            assert r["path"].endswith("pragma_good.py") and r["line"]


class TestZoneTrees:
    """Zone-scoped rules keyed off --root-relative paths."""

    CASES = [
        ("crn_zone_bad", "no-host-rng"),
        ("wall_clock_bad", "no-wall-clock"),
        ("docstring_bad", "module-docstring"),
        ("salt_bad", "salt-drift"),
    ]

    @pytest.mark.parametrize("tree,rule", CASES)
    def test_tree_fires_its_zone_rule(self, tree, rule):
        report = lint(["src"], root=TREES / tree)
        assert {f.rule for f in report.findings} == {rule}

    def test_clean_tree(self):
        report = lint(["src"], root=TREES / "wall_clock_good")
        assert report.findings == []

    def test_zone_rules_inert_outside_their_zone(self):
        # the same wall-clock-calling file, linted as a path under the
        # real repo root (tools/...), is outside the pure zones
        report = lint([TREES / "wall_clock_bad/src/repro/core/stamp.py"])
        assert report.findings == []


class TestPragmaParsing:
    def test_single_and_multi_rule(self):
        assert pragma_disabled("x  # repro-lint: disable=a") == {"a"}
        assert pragma_disabled("x  # repro-lint: disable=a, b") == \
            {"a", "b"}

    def test_trailing_justification_in_parens(self):
        line = "x  # repro-lint: disable=no-host-rng (why: boundary)"
        assert pragma_disabled(line) == {"no-host-rng"}

    def test_all_sentinel_and_absence(self):
        assert "all" in pragma_disabled("# repro-lint: disable=all")
        assert pragma_disabled("plain line # comment") == frozenset()

    def test_justification_extracted_from_parens(self):
        line = "x  # repro-lint: disable=no-host-rng (why: boundary)"
        assert pragma_justification(line) == "why: boundary"

    def test_justification_empty_when_absent(self):
        assert pragma_justification(
            "x  # repro-lint: disable=no-host-rng") == ""
        assert pragma_justification("plain line") == ""


class TestBaseline:
    def test_roundtrip_then_new_finding_then_stale(self, tmp_path):
        target = tmp_path / "legacy.py"
        target.write_text(textwrap.dedent("""\
            def f():
                try:
                    return 1
                except Exception:
                    return None
        """))
        bpath = tmp_path / "baseline.json"

        fresh = lint([target])
        assert len(fresh.findings) == 1

        ctx = Context(REPO, [])
        assert write_baseline(bpath, fresh.findings, ctx) == 1

        grandfathered = lint([target], baseline=bpath)
        assert grandfathered.findings == []
        assert len(grandfathered.baselined) == 1
        assert grandfathered.stale_baseline == []

        # a NEW broad handler is not covered by the old baseline
        target.write_text(target.read_text() + textwrap.dedent("""\

            def g():
                try:
                    return 2
                except BaseException:
                    return None
        """))
        drifted = lint([target], baseline=bpath)
        assert len(drifted.findings) == 1
        assert "BaseException" in drifted.findings[0].message
        assert len(drifted.baselined) == 1

        # fixing the original finding leaves a stale entry behind
        target.write_text("def f():\n    return 1\n")
        healed = lint([target], baseline=bpath)
        assert healed.findings == []
        assert len(healed.stale_baseline) == 1


def make_salt_tree(tmp_path):
    """A throwaway repo root with one salted engine module."""
    eng = tmp_path / "src" / "repro" / "core" / "engine.py"
    eng.parent.mkdir(parents=True)
    eng.write_text(textwrap.dedent('''\
        """Tiny salted engine for salt-drift workflow tests."""

        ENGINE_SEMANTICS_VERSION = 1


        def step(state):
            return state + 1
    '''))
    salts = tmp_path / "tools" / "lint" / "salts.json"
    salts.parent.mkdir(parents=True)
    salts.write_text(json.dumps({
        "version": 1,
        "salts": {"ENGINE_SEMANTICS_VERSION": {
            "defined_in": "src/repro/core/engine.py",
            "surface": ["src/repro/core/engine.py"],
            "surface_hash": "bootstrap", "value": 0}}}))
    update_salts(tmp_path)
    return eng


class TestSaltDrift:
    def test_pinned_tree_is_clean(self, tmp_path):
        make_salt_tree(tmp_path)
        assert lint(["src"], root=tmp_path).findings == []

    def test_comment_and_docstring_edits_stay_clean(self, tmp_path):
        eng = make_salt_tree(tmp_path)
        text = eng.read_text().replace(
            "Tiny salted engine", "Rewritten docstring, same tokens")
        eng.write_text(text + "\n# trailing comment\n\n")
        assert lint(["src"], root=tmp_path).findings == []

    def test_semantic_edit_without_bump_fires(self, tmp_path):
        eng = make_salt_tree(tmp_path)
        eng.write_text(eng.read_text().replace("state + 1", "state + 2"))
        found = lint(["src"], root=tmp_path).findings
        assert [f.rule for f in found] == ["salt-drift"]
        assert "without a salt bump" in found[0].message

    def test_bump_without_repin_names_the_regen_step(self, tmp_path):
        eng = make_salt_tree(tmp_path)
        eng.write_text(eng.read_text().replace(
            "ENGINE_SEMANTICS_VERSION = 1",
            "ENGINE_SEMANTICS_VERSION = 2"))
        found = lint(["src"], root=tmp_path).findings
        assert [f.rule for f in found] == ["salt-drift"]
        assert "engine_point_hashes.json" in found[0].message

    def test_update_salts_repins_to_clean(self, tmp_path):
        eng = make_salt_tree(tmp_path)
        eng.write_text(eng.read_text().replace("state + 1", "state + 3"))
        assert update_salts(tmp_path) == ["ENGINE_SEMANTICS_VERSION"]
        assert lint(["src"], root=tmp_path).findings == []

    def test_normalized_fingerprint_ignores_formatting_only(self):
        base = normalized_fingerprint("x = 1\ny = x + 2\n")
        same = normalized_fingerprint(
            '"""doc"""\n# comment\nx = 1\n\ny = x + 2\n')
        assert base != normalized_fingerprint("x = 1\ny = x + 3\n")
        # docstring/comment/blank-line edits hash identically apart
        # from the docstring-free vs docstring'd module header
        assert same == normalized_fingerprint(
            '"""other doc"""\nx = 1\ny = x + 2   # note\n')


def make_git_tree(tmp_path):
    """A committed throwaway git repo with one clean lintable file."""
    def git(*args):
        subprocess.run(
            ["git", "-c", "user.email=t@t", "-c", "user.name=t",
             *args], cwd=tmp_path, check=True, capture_output=True)
    (tmp_path / "mod.py").write_text('"""Clean module."""\n')
    git("init", "-q")
    git("add", "-A")
    git("commit", "-q", "-m", "seed")
    return tmp_path


class TestChangedMode:
    """``--changed``: lint only files touched since HEAD."""

    def test_clean_worktree_lints_nothing(self, tmp_path):
        make_git_tree(tmp_path)
        p = cli("--changed", "--no-baseline", "--root", str(tmp_path))
        assert p.returncode == 0, p.stdout + p.stderr
        assert "no changed lintable files" in p.stdout

    def test_modified_file_is_linted(self, tmp_path):
        root = make_git_tree(tmp_path)
        (root / "mod.py").write_text(textwrap.dedent('''\
            """Module with a broad handler."""
            try:
                pass
            except Exception:
                pass
        '''))
        p = cli("--changed", "--no-baseline", "--root", str(root))
        assert p.returncode == 1
        assert "except-breadth" in p.stdout

    def test_untracked_file_is_linted(self, tmp_path):
        root = make_git_tree(tmp_path)
        (root / "new.py").write_text(textwrap.dedent('''\
            """Untracked module with a broad handler."""
            try:
                pass
            except BaseException:
                pass
        '''))
        p = cli("--changed", "--no-baseline", "--root", str(root))
        assert p.returncode == 1
        assert "new.py" in p.stdout

    def test_changed_with_explicit_paths_is_an_error(self, tmp_path):
        root = make_git_tree(tmp_path)
        p = cli("--changed", "src", "--root", str(root))
        assert p.returncode == 2
        assert "--changed" in p.stderr

    def test_outside_a_git_repo_is_invocation_error(self, tmp_path):
        p = cli("--changed", "--root", str(tmp_path))
        assert p.returncode == 2


class TestCheckDocsShim:
    def test_main_warns_deprecation_and_delegates(self):
        import tools.check_docs as cd
        with pytest.warns(DeprecationWarning, match="tools.lint"):
            rc = cd.main([])
        assert rc == 0

    def test_warning_is_fatal_under_w_error(self):
        p = subprocess.run(
            [sys.executable, "-W", "error::DeprecationWarning",
             "tools/check_docs.py"],
            cwd=REPO, capture_output=True, text=True)
        assert p.returncode != 0
        assert "DeprecationWarning" in p.stderr


class TestCli:
    def test_merged_tree_is_clean(self):
        p = cli("src", "tools", "benchmarks")
        assert p.returncode == 0, p.stdout + p.stderr

    def test_violating_fixture_exits_nonzero(self):
        p = cli("--no-baseline",
                str(BAD / "except_breadth_bad.py"))
        assert p.returncode == 1
        assert "except-breadth" in p.stdout

    def test_unknown_rule_is_invocation_error(self):
        p = cli("--rules", "no-such-rule", "tools/lint/core.py")
        assert p.returncode == 2
        assert "unknown rule" in p.stderr

    def test_json_format(self):
        p = cli("--no-baseline", "--format", "json",
                str(BAD / "host_rng_bad.py"))
        data = json.loads(p.stdout)
        assert data["exit_code"] == p.returncode == 1
        assert {f["rule"] for f in data["findings"]} == {"no-host-rng"}

    def test_salt_tree_via_root_flag(self):
        p = cli("--root", str(TREES / "salt_bad"), "--no-baseline",
                "src")
        assert p.returncode == 1
        assert "salt-drift" in p.stdout

    def test_check_docs_shim_still_passes(self):
        p = subprocess.run([sys.executable, "tools/check_docs.py"],
                           cwd=REPO, capture_output=True, text=True)
        assert p.returncode == 0, p.stdout + p.stderr
