"""Campaign engine: spec hashing, caching, parallelism, seeding.

Tiny sweeps (short duration, few sets) keep each test fast while still
exercising the full plan -> fan-out -> cache -> collect path.
"""
import dataclasses
import math

import pytest

from repro.core import (Policy, generate_taskset, generate_taskset_batch,
                        point_seed, simulate, simulate_batch)
from repro.core.program import workload_library
from repro.experiments import (Campaign, FuncSweep, Sweep, frac, group_rows,
                               metrics_row, pooled_mean)

TINY = dict(utils=(0.7,), n_sets=3, duration=2e6)


def tiny_sweep(**kw):
    merged = {**TINY, **kw}
    return Sweep(name=merged.pop("name", "tiny"),
                 policies=merged.pop("policies", (Policy.mesc(),)),
                 **merged)


class TestSpecHash:
    def test_stable_across_instances(self):
        assert tiny_sweep().spec_hash() == tiny_sweep().spec_hash()

    def test_sensitive_to_every_axis(self):
        base = tiny_sweep()
        variants = [
            tiny_sweep(utils=(0.8,)),
            tiny_sweep(n_sets=4),
            tiny_sweep(duration=3e6),
            tiny_sweep(seed0=1),
            tiny_sweep(overrun_prob=0.5),
            tiny_sweep(policies=(Policy.non_preemptive(),)),
        ]
        hashes = {s.spec_hash() for s in [base] + variants}
        assert len(hashes) == len(variants) + 1

    def test_point_keys_content_addressed(self):
        """Same point content -> same key, even from different sweeps."""
        a = tiny_sweep(name="a").points()
        b = tiny_sweep(name="b").points()
        assert [p.key() for p in a] == [p.key() for p in b]
        keys = {p.key() for p in a}
        assert len(keys) == len(a)

    def test_duplicate_policy_names_rejected(self):
        with pytest.raises(ValueError):
            tiny_sweep(policies=(Policy.mesc(),
                                 Policy.mesc(use_banks=False)))


class TestCache:
    def test_hit_miss_and_row_identity(self, tmp_path):
        sweep = tiny_sweep()
        c1 = Campaign(sweep, cache_dir=tmp_path, workers=1)
        rows1 = c1.collect()
        assert c1.stats == {"hits": 0, "misses": 3}
        c2 = Campaign(sweep, cache_dir=tmp_path, workers=1)
        rows2 = c2.collect()
        assert c2.stats == {"hits": 3, "misses": 0}
        assert rows1 == rows2

    def test_partial_overlap_is_incremental(self, tmp_path):
        Campaign(tiny_sweep(), cache_dir=tmp_path, workers=1).run()
        grown = tiny_sweep(n_sets=5)        # supersets the first 3 points
        c = Campaign(grown, cache_dir=tmp_path, workers=1)
        c.run()
        assert c.stats == {"hits": 3, "misses": 2}

    def test_manifest_written(self, tmp_path):
        sweep = tiny_sweep()
        c = Campaign(sweep, cache_dir=tmp_path, workers=1)
        c.run()
        m = c.cache.read_manifest(sweep.spec_hash())
        assert m is not None
        assert m["name"] == "tiny"
        assert m["n_points"] == 3
        assert len(m["point_keys"]) == 3

    def test_no_cache_mode_writes_nothing(self, tmp_path):
        c = Campaign(tiny_sweep(), cache_dir=tmp_path, use_cache=False,
                     workers=1)
        c.collect()
        assert not any(tmp_path.iterdir())


class TestParallel:
    def test_parallel_equals_serial(self, tmp_path):
        sweep = tiny_sweep(n_sets=4)
        ser = Campaign(sweep, use_cache=False, workers=1).collect()
        par = Campaign(sweep, use_cache=False, workers=2).collect()
        assert ser == par

    def test_func_sweep_fans_out(self, tmp_path):
        fs = FuncSweep.over("echo", "repro.experiments.runner:_echo_point",
                            [{"i": i} for i in range(4)])
        rows = Campaign(fs, cache_dir=tmp_path, workers=2).collect()
        assert [r["i"] for r in rows] == [0, 1, 2, 3]
        assert all(r["echo"] for r in rows)


class TestSeeding:
    def test_point_seed_contract(self):
        assert point_seed(7, 5) == 12
        sweep = tiny_sweep(seed0=7)
        assert [p.seed for p in sweep.points()] == [7, 8, 9]

    def test_taskset_batch_matches_singles(self):
        lib = {k: v for k, v in workload_library().items()}
        batch = generate_taskset_batch(0.6, 3, seed0=4, programs=lib)
        singles = [generate_taskset(0.6, seed=4 + s, programs=lib)
                   for s in range(3)]
        assert batch == singles

    def test_simulate_batch_matches_singles(self):
        lib = workload_library()
        sets = generate_taskset_batch(0.6, 2, seed0=0, programs=lib)
        batch = simulate_batch(sets, lib, Policy.mesc(), seeds=[0, 1],
                               duration=2e6)
        singles = [simulate(ts, lib, Policy.mesc(), seed=s, duration=2e6)
                   for ts, s in zip(sets, [0, 1])]
        assert batch == singles

    def test_simulate_batch_length_mismatch(self):
        with pytest.raises(ValueError):
            simulate_batch([], {}, Policy.mesc(), seeds=[1])

    def test_engine_matches_legacy_serial_loop(self, tmp_path):
        """The acceptance property: engine rows == benchmarks.common
        run_many (the pre-engine serial reference), policy by policy."""
        from benchmarks.common import run_many
        for policy in (Policy.mesc(), Policy.non_preemptive()):
            sweep = tiny_sweep(policies=(policy,), duration=5e6)
            rows = Campaign(sweep, use_cache=False, workers=2).collect()
            legacy = run_many(policy, n_sets=3, u=0.7, duration=5e6)
            expected = [metrics_row(m, policy=policy.name, u=0.7, gamma=0.5,
                                    n_tasks=10, set_index=s, seed=s)
                        for s, m in enumerate(legacy)]
            assert rows == expected


class TestAggregation:
    def test_pre_mean_cached_rows_upgraded_on_read(self, tmp_path):
        """Rows cached before the {name}_mean columns existed must be
        backfilled on cache read — mixing schemas in one collect()
        would KeyError consumers of the new columns."""
        from repro.experiments.metrics import ensure_row_means
        from repro.experiments.runner import Campaign
        sweep = Sweep(name="t", policies=(Policy.mesc(),), n_sets=2,
                      duration=1e6)
        c1 = Campaign(sweep, cache_dir=tmp_path, workers=1)
        fresh = c1.collect()
        assert all("pi_mean" in r for r in fresh)
        # simulate a pre-upgrade cache: strip the mean columns in situ
        for key in [p.key() for p in sweep.points()]:
            row = c1.cache.get(key)
            for name in ("pi", "ci", "save", "restore"):
                row.pop(f"{name}_mean", None)
            c1.cache.put(key, row)
        replay = Campaign(sweep, cache_dir=tmp_path,
                          workers=1).collect()
        assert replay == fresh
        # non-sim rows (no sum/count keys) pass through untouched
        assert ensure_row_means({"x": 1}) == {"x": 1}
        assert ensure_row_means({"pi_sum": 0.0, "pi_n": 0})[
            "pi_mean"] is None

    def test_pooled_mean_matches_concatenated_lists(self):
        rows = [{"pi_sum": 10.0, "pi_n": 2}, {"pi_sum": 5.0, "pi_n": 3}]
        assert pooled_mean(rows, "pi") == pytest.approx(15.0 / 5)
        # zero events pools to NaN ("no data"), never ZeroDivisionError
        assert math.isnan(pooled_mean([{"pi_sum": 0.0, "pi_n": 0}], "pi"))

    def test_group_and_frac(self):
        rows = [{"u": 0.5, "success_all": 1}, {"u": 0.5, "success_all": 0},
                {"u": 0.9, "success_all": 0}]
        cells = group_rows(rows, "u")
        assert frac(cells[(0.5,)], "success_all") == 0.5
        assert frac(cells[(0.9,)], "success_all") == 0.0
