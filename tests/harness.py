"""Shared equivalence-test harness for the three simulation engines.

One fixture family covers every cross-engine gate in the suite: an
:class:`EngineCase` names a concrete backend configuration — engine,
logical device count, dispatch chunk shape, interrupt-table width,
demand profile — and :func:`run_case` executes any corpus under it,
returning tidy metric rows.  The assertion helpers then express the
three contracts the engines promise:

* :func:`assert_bit_exact` — metrics equal row for row (the vec-vs-
  event contract, the jit nominal-profile contract, and the sharded-
  vs-single-device contract at *any* device count);
* :func:`assert_statistical_close` — equal distributions, different
  realizations (the jit sampled-profile contract vs event/vec);
* :func:`assert_deterministic` — same case, same corpus, any batch
  order: identical rows (the counter-based-RNG composition-
  independence contract).

``tests/test_simulator_vec.py``, ``tests/test_simulator_jit.py`` and
``tests/test_device_sharding.py`` all parametrize over EngineCases
instead of hand-rolling per-file runners, so a new backend knob (such
as ``devices``) lands in every gate by adding one case.

The serving stack gets the same treatment: a :class:`ServingCase`
names one deterministic virtual-clock serving configuration (lanes,
policy, arrival process, offered load, admission cap),
:func:`run_serving_case` executes it over a cached CRN workload corpus
and returns tidy rows (one per request, plus the SLO summary row), and
:func:`assert_serving_deterministic` is the serving spelling of the
determinism contract — two runs of the same case are bit-exact.
``tests/test_serving.py`` and CI's serving-smoke job ride on it.

Compilation note: the jit engine compiles one lockstep ``while_loop``
per (policy-config, batch-shape, table-width, device-count) tuple
(seconds each); corpora here are deliberately shared — reuse
:func:`fig8_corpus` / :func:`mixed_corpus` rather than inventing new
shapes.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import Policy, generate_taskset, simulate
from repro.core.simulator_vec import simulate_vbatch
from repro.experiments.metrics import metrics_row
from repro.experiments.runner import cached_library

LIB = cached_library("sim")

# the two shared corpus shapes (see module docstring)
MIXED_SIZES = (3, 10, 6, 13)


def rows(ms) -> List[Dict[str, Any]]:
    """Tidy metric rows — the comparable unit of every gate."""
    return [metrics_row(m) for m in ms]


@functools.lru_cache(maxsize=None)
def mixed_corpus(sizes: Tuple[int, ...] = MIXED_SIZES, u: float = 0.9):
    """Heterogeneous-``n_tasks`` batch (exercises taskset padding)."""
    tasksets = [generate_taskset(u, seed=s, n_tasks=n, programs=LIB)
                for s, n in enumerate(sizes)]
    return tasksets, list(range(len(sizes)))


@functools.lru_cache(maxsize=None)
def fig8_corpus(utils: Tuple[float, ...] = (0.7, 0.9),
                n_seeds: int = 16, n_tasks: int = 10):
    """Homogeneous fig8-style batch (the perf-corpus shape)."""
    tasksets, seeds = [], []
    for u in utils:
        for s in range(n_seeds):
            tasksets.append(generate_taskset(u, seed=s, n_tasks=n_tasks,
                                             programs=LIB))
            seeds.append(s)
    return tasksets, seeds


@dataclasses.dataclass(frozen=True)
class EngineCase:
    """One backend configuration under test.

    ``devices`` is the logical-device shard count (jit only; ``None``
    leaves the engine's own default).  ``chunk`` bounds the per-device
    dispatch chunk via ``batch_size`` (small values force multi-span
    dispatch and rectangle padding).  ``table_width`` pins the starting
    interrupt-table width via ``REPRO_JIT_TABLE_WIDTH`` (small values
    force the overflow-retry ladder).
    """
    name: str
    engine: str = "jit"                  # "event" | "vec" | "jit"
    devices: Optional[int] = None
    chunk: Optional[int] = None
    table_width: Optional[int] = None
    demand_profile: str = "sampled"
    scenario: Optional[str] = None       # scenarios.get_scenario name

    def __str__(self) -> str:            # pytest id
        return self.name


def run_case(case: EngineCase, tasksets, seeds, policy, *,
             duration: float, overrun_prob: float = 0.3,
             cf: float = 2.0) -> List[Dict[str, Any]]:
    """Execute the corpus under ``case`` and return metric rows."""
    if case.engine == "event":
        if case.demand_profile != "sampled":
            raise ValueError("event engine has no demand_profile knob")
        return rows(simulate(ts, LIB, policy, seed=s, duration=duration,
                             overrun_prob=overrun_prob, cf=cf,
                             scenario=case.scenario)
                    for ts, s in zip(tasksets, seeds))
    kw: Dict[str, Any] = dict(seeds=seeds, duration=duration,
                              overrun_prob=overrun_prob, cf=cf,
                              demand_profile=case.demand_profile,
                              scenario=case.scenario)
    if case.engine == "jit":
        kw["select_backend"] = "jit"
        kw["devices"] = case.devices
        if case.chunk is not None:
            kw["batch_size"] = case.chunk
    elif case.engine != "vec":
        raise ValueError(f"unknown EngineCase engine {case.engine!r}")
    saved = os.environ.get("REPRO_JIT_TABLE_WIDTH")
    try:
        if case.table_width is not None:
            os.environ["REPRO_JIT_TABLE_WIDTH"] = str(case.table_width)
        return rows(simulate_vbatch(tasksets, LIB, policy, **kw))
    finally:
        if case.table_width is not None:
            if saved is None:
                os.environ.pop("REPRO_JIT_TABLE_WIDTH", None)
            else:
                os.environ["REPRO_JIT_TABLE_WIDTH"] = saved


# ----------------------------------------------------------------------
# The three contracts
# ----------------------------------------------------------------------

def assert_bit_exact(ref_rows, got_rows, context: str = "") -> None:
    """Rows equal, exactly — reporting the first diverging point."""
    assert len(ref_rows) == len(got_rows), \
        f"{context}: {len(ref_rows)} vs {len(got_rows)} rows"
    for i, (a, b) in enumerate(zip(ref_rows, got_rows)):
        if a != b:
            diff = sorted(k for k in set(a) | set(b)
                          if a.get(k) != b.get(k))
            raise AssertionError(
                f"{context}: point {i} diverged in fields {diff}: "
                f"{[(k, a.get(k), b.get(k)) for k in diff[:4]]}")


def assert_statistical_close(ref_rows, got_rows, *,
                             volume_tol: float = 0.06) -> None:
    """Equal distributions: pooled success rates within the two-sided
    binomial bound, volume metrics within ``volume_tol`` relative."""
    from benchmarks.perf_sim import binomial_bound
    n = len(ref_rows)
    assert n == len(got_rows) and n > 0
    for field in ("success_all", "success_hi"):
        pa = sum(r[field] for r in ref_rows) / n
        pb = sum(r[field] for r in got_rows) / n
        bound = binomial_bound(0.5 * (pa + pb), n)
        assert abs(pa - pb) <= bound, (field, pa, pb, bound)
    for field in ("jobs_lo", "jobs_hi", "exec_cycles"):
        sa = sum(r[field] for r in ref_rows)
        sb = sum(r[field] for r in got_rows)
        assert sa > 0
        assert abs(sa - sb) / sa < volume_tol, (field, sa, sb)


def assert_deterministic(case: EngineCase, tasksets, seeds, policy, *,
                         duration: float, **kw) -> List[Dict[str, Any]]:
    """Same case run twice, then in reversed batch order: identical
    rows (per-point keyed RNG = batch-composition independence).
    Returns the rows for further comparisons."""
    a = run_case(case, tasksets, seeds, policy, duration=duration, **kw)
    b = run_case(case, tasksets, seeds, policy, duration=duration, **kw)
    assert_bit_exact(a, b, f"{case.name}: repeat run")
    rev = run_case(case, list(tasksets)[::-1], list(seeds)[::-1], policy,
                   duration=duration, **kw)
    assert_bit_exact(a, rev[::-1], f"{case.name}: reversed batch")
    return a


# ----------------------------------------------------------------------
# The serving fixture family (virtual clock, fig12 stack)
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServingCase:
    """One deterministic virtual-clock serving configuration.

    ``policy`` names a ``repro.serving.fig12.POLICIES`` entry;
    ``arrivals`` one of ``traffic.PROCESS_KINDS``; ``lo_load`` is the
    LO offered load as a multiple of pool capacity (>= 1 saturates).
    Frozen + hashable so the workload corpus behind it can be
    ``lru_cache``'d across tests the way ``fig8_corpus`` is.
    """
    name: str
    lanes: int = 2
    policy: str = "mesc"
    arrivals: str = "poisson"
    seed: int = 0
    n_lo: int = 16
    n_hi: int = 6
    lo_load: float = 1.2
    heuristic: str = "crit_aware"
    max_live_lo: Optional[int] = None
    hi_deadline_s: float = 0.5
    scenario: Optional[str] = None       # instance-loss component only

    def __str__(self) -> str:            # pytest id
        return self.name


@functools.lru_cache(maxsize=None)
def serving_corpus(arrivals: str = "poisson", seed: int = 0,
                   n_lo: int = 16, n_hi: int = 6, lo_load: float = 1.2,
                   lanes: int = 2, lo_tokens: int = 48,
                   hi_tokens: int = 6):
    """CRN arrival realization shared by every case with the same
    traffic knobs (policies differ, workload does not — common random
    numbers is the whole comparison contract)."""
    from repro.serving import build_workload, make_process
    from repro.serving.frontend import ServiceModelSpec
    svc = ServiceModelSpec()
    capacity = lanes * svc.lane_capacity_rps(float(lo_tokens))
    workload = build_workload(
        seed=seed, lo_process=make_process(arrivals, lo_load * capacity),
        hi_process=make_process("poisson", 0.25 * lanes),
        n_lo=n_lo, n_hi=n_hi, lo_tokens=lo_tokens, hi_tokens=hi_tokens)
    return tuple(workload)


def run_serving_case(case: ServingCase,
                     on_step=None) -> List[Dict[str, Any]]:
    """Execute ``case`` on the virtual clock; tidy rows out.

    One row per request (rid, class, timing, preemption counters,
    generated-token digest) followed by the SLO summary row — a flat
    ``assert_bit_exact``-able list, like :func:`run_case`'s."""
    from repro.serving import run_virtual_serving, slo_summary
    from repro.serving.fig12 import POLICIES
    workload = serving_corpus(case.arrivals, case.seed, case.n_lo,
                              case.n_hi, case.lo_load, case.lanes)
    reqs = run_virtual_serving(
        workload, lanes=case.lanes, policy=POLICIES[case.policy](),
        seed=case.seed, heuristic=case.heuristic,
        max_live_lo=case.max_live_lo, scenario=case.scenario,
        on_step=on_step)
    out: List[Dict[str, Any]] = []
    for rid in sorted(reqs):
        r = reqs[rid]
        out.append(dict(
            rid=rid, crit=r.crit.value, done=r.done,
            submitted_at=r.submitted_at, first_token_at=r.first_token_at,
            finished_at=r.finished_at, preemptions=r.preemptions,
            saves=r.saves, tokens=tuple(r.generated)))
    out.append(slo_summary(reqs.values(),
                           hi_deadline_s=case.hi_deadline_s))
    return out


def assert_serving_deterministic(case: ServingCase) -> List[Dict[str, Any]]:
    """The determinism contract, serving spelling: the same case run
    twice produces bit-exact request timelines and SLO rows (this is
    what lets CI gate fig12 output byte-identically)."""
    a = run_serving_case(case)
    b = run_serving_case(case)
    assert_bit_exact(a, b, f"{case.name}: repeat serving run")
    return a
