"""MESC model-serving integration (core/serving.py) + int8 Adam +
the deterministic virtual-clock serving harness (ServingCase)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.scheduler import Mode, Policy
from repro.core.serving import MESCServer, Request
from repro.core.task import Crit
from repro.models import lm
from repro.models.common import CPU_RC
from repro.optim import OptConfig, adamw_update, init_opt_state

from harness import (ServingCase, assert_serving_deterministic,
                     run_serving_case)

CFG = get_config("tinyllama-1.1b-smoke")
PARAMS = lm.init_params(CFG, jax.random.PRNGKey(0), CPU_RC)


def _req(rid, crit, prio, n=6):
    rng = np.random.default_rng(rid)
    return Request(rid=rid, priority=prio,
                   prompt=rng.integers(0, CFG.vocab, 8, dtype=np.int32),
                   max_new_tokens=n, crit=crit)


class TestMESCServing:
    def test_hi_preempts_lo_at_instruction_boundary(self):
        srv = MESCServer(CFG, PARAMS, policy=Policy.mesc(), max_len=32)
        lo = _req(0, Crit.LO, 10, n=12)
        srv.submit(lo)
        for _ in range(2):
            srv.step()
        hi = _req(1, Crit.HI, 0, n=3)
        srv.submit(hi)
        order = [srv.step() for _ in range(4)]
        assert order[0] == 1, order        # HI runs at the very next step
        srv.run()
        assert srv.requests[1].done and srv.requests[0].done  # LO not dropped

    def test_non_preemptive_runs_to_completion(self):
        srv = MESCServer(CFG, PARAMS, policy=Policy.non_preemptive(),
                         max_len=32)
        lo = _req(0, Crit.LO, 10, n=8)
        srv.submit(lo)
        srv.step()
        srv.submit(_req(1, Crit.HI, 0, n=2))
        order = [srv.step() for _ in range(7)]
        assert all(r == 0 for r in order), order  # LO holds the accelerator

    def test_bank_pool_eviction_and_restore(self):
        """Cache save/restore across the bank pool is output-preserving."""
        # reference: uninterrupted generation
        srv = MESCServer(CFG, PARAMS, policy=Policy.mesc(), max_len=32,
                         resident_slots=1)
        a, b = _req(0, Crit.LO, 1, n=6), _req(1, Crit.LO, 2, n=6)
        srv.submit(a)
        [srv.step() for _ in range(3)]
        srv.submit(b)                      # same priority class; pool size 1
        srv.run()
        saves = a.saves + b.saves
        ref = MESCServer(CFG, PARAMS, policy=Policy.mesc(), max_len=32,
                         resident_slots=4)
        a2, b2 = _req(0, Crit.LO, 1, n=6), _req(1, Crit.LO, 2, n=6)
        ref.submit(a2)
        [ref.step() for _ in range(3)]
        ref.submit(b2)
        ref.run()
        assert a.generated == a2.generated
        assert b.generated == b2.generated


class TestMultiLaneServing:
    def test_lanes_partition_and_preserve_output(self):
        """Two dispatch lanes over a shared KV arena generate the same
        tokens as one lane, with HI requests spread across lanes."""
        from repro.core.serving import MultiLaneServer
        msrv = MultiLaneServer(CFG, PARAMS, n_lanes=2, max_len=32,
                               total_slots=2, heuristic="crit_aware")
        reqs = [_req(0, Crit.HI, 0), _req(1, Crit.HI, 1),
                _req(2, Crit.LO, 10), _req(3, Crit.LO, 11)]
        lanes = [msrv.submit(r) for r in reqs]
        assert sorted(lanes[:2]) == [0, 1]     # HI spread one per lane
        msrv.run()
        assert all(r.done for r in msrv.requests.values())
        # reference: single-lane serving of the same requests
        ref = MESCServer(CFG, PARAMS, max_len=32, resident_slots=4)
        ref_reqs = [_req(0, Crit.HI, 0), _req(1, Crit.HI, 1),
                    _req(2, Crit.LO, 10), _req(3, Crit.LO, 11)]
        for r in ref_reqs:
            ref.submit(r)
        ref.run()
        for r, rr in zip(reqs, ref_reqs):
            assert r.generated == rr.generated
        # the shared arena never exceeded per-lane quotas
        assert all(msrv.arena.held(i) == 0 for i in range(2))

    def test_non_preemptive_lane_isolation(self):
        """A LO request holding one lane cannot block a HI request
        partitioned onto the other lane (the fig11 story end-to-end)."""
        from repro.core.serving import MultiLaneServer
        msrv = MultiLaneServer(CFG, PARAMS, n_lanes=2, max_len=32,
                               policy=Policy.non_preemptive())
        lo = _req(0, Crit.LO, 10, n=10)
        msrv.submit(lo)
        msrv.step()                            # LO owns its lane
        hi = _req(1, Crit.HI, 0, n=2)
        hi_lane = msrv.submit(hi)
        assert hi_lane != msrv.lane_of[0]
        ran = msrv.step()
        assert ran[hi_lane] == 1               # HI runs immediately


SERVING_CASES = [
    ServingCase("mesc-poisson-sat", policy="mesc", arrivals="poisson"),
    ServingCase("np-poisson-sat", policy="np", arrivals="poisson"),
    ServingCase("mesc-heavytail-capped", policy="mesc",
                arrivals="heavy_tail", max_live_lo=2),
]


class TestVirtualServing:
    """The deterministic serving harness over the fig12 stack: virtual
    clocks, CRN traffic, admission front door, SLO summary."""

    @pytest.mark.parametrize("case", SERVING_CASES, ids=str)
    def test_serving_case_deterministic(self, case):
        rows = assert_serving_deterministic(case)
        summary = rows[-1]
        assert summary["hi_finished"] == case.n_hi     # nothing dropped
        assert summary["lo_finished"] == case.n_lo

    def test_crn_workload_shared_across_policies(self):
        """Common random numbers: both policies see byte-identical
        arrivals, so any SLO delta is a pure policy effect."""
        a = run_serving_case(SERVING_CASES[0])[:-1]
        b = run_serving_case(SERVING_CASES[1])[:-1]
        assert [(r["rid"], r["crit"], r["submitted_at"]) for r in a] \
            == [(r["rid"], r["crit"], r["submitted_at"]) for r in b]

    def test_mesc_bounds_hi_tail_under_saturation(self):
        """The fig12 headline as a gate: with LO offered load 1.2x
        capacity, MESC preemption keeps the HI p99 and miss rate below
        the non-preemptive baseline on the same workload."""
        mesc = run_serving_case(SERVING_CASES[0])[-1]
        base = run_serving_case(SERVING_CASES[1])[-1]
        assert mesc["hi_p99_latency_s"] < base["hi_p99_latency_s"]
        assert mesc["hi_miss_rate"] <= base["hi_miss_rate"]
        assert mesc["hi_preemptions"] + mesc["lo_preemptions"] > 0
        assert base["hi_preemptions"] + base["lo_preemptions"] == 0

    def test_front_door_lo_cap_holds_at_every_step(self):
        """max_live_lo bounds concurrently-live LO admissions at every
        observable instant; HI requests are never throttled."""
        case = SERVING_CASES[2]
        seen = []

        def watch(front, server):
            live_lo = sum(1 for r in server.requests.values()
                          if not r.done and r.crit == Crit.LO)
            seen.append(live_lo)
            assert live_lo <= case.max_live_lo
            front.check_conservation()

        rows = run_serving_case(case, on_step=watch)
        assert max(seen) == case.max_live_lo      # the cap actually binds
        assert rows[-1]["hi_finished"] == case.n_hi

    def test_lo_budget_mode_switch_at_virtual_time(self):
        """Regression (clock injection): a LO request overrunning its
        lo_budget_s trips the LO->HI mode switch at a *deterministic
        virtual* time — byte-identical across runs, no wall clock."""
        from repro.serving import VirtualClock, VirtualModel

        def run_once():
            clk = VirtualClock()
            model = VirtualModel(clk, seed=3, decode_mean_s=0.010,
                                 jitter=0.0)
            srv = MESCServer(None, None, policy=Policy.mesc(),
                             max_len=64, jit_fns=model.jit_fns,
                             clock=clk)
            lo = Request(rid=0, priority=10,
                         prompt=np.asarray([0], np.int32),
                         max_new_tokens=32, crit=Crit.LO,
                         lo_budget_s=0.035)     # < 4 decode steps
            srv.submit(lo)
            assert srv.mode == Mode.LO
            steps = 0
            while srv.mode == Mode.LO:
                srv.step()
                steps += 1
                assert steps < 64, "mode never switched"
            return steps, clk(), srv.requests[0].exec_s

        a, b = run_once(), run_once()
        assert a == b                          # deterministic switch
        steps, t_switch, exec_s = a
        assert exec_s > 0.035                  # budget actually exceeded
        # jitter=0: exec_s crosses 0.035 after decode step 4 (0.040);
        # the monitor trips at the NEXT step's tick, so the loop exits
        # after step 5 with the clock at prefill 0.020 + 5 * 0.010
        assert steps == 5
        assert abs(t_switch - 0.070) < 1e-9
        assert abs(exec_s - 0.050) < 1e-9

    def test_wall_clock_is_the_default(self):
        """Production default unchanged: no clock injected means
        time.monotonic, and submit() stamps arrivals with it."""
        srv = MESCServer(CFG, PARAMS, policy=Policy.mesc(), max_len=32)
        assert srv.clock is time.monotonic
        t0 = time.monotonic()
        r = _req(7, Crit.LO, 5, n=2)
        srv.submit(r)
        assert t0 <= r.submitted_at <= time.monotonic()
        # a pre-stamped arrival time (front-door contract) is respected
        r2 = _req(8, Crit.LO, 6, n=2)
        r2.submitted_at = 123.0
        srv.submit(r2)
        assert r2.submitted_at == 123.0


class TestInt8Adam:
    def test_int8_moments_converge(self):
        cfg = OptConfig(lr=0.1, warmup_steps=5, decay_steps=200,
                        weight_decay=0.0, clip_norm=0, moments_int8=True)
        params = {"w": jnp.array([3.0, -2.0, 1.5])}
        state = init_opt_state(params, cfg)
        assert state["m"]["w"].dtype == jnp.int8
        for _ in range(150):
            g = {"w": 2 * params["w"]}
            params, state, _ = adamw_update(params, g, state, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.2

    def test_int8_state_is_quarter_size(self):
        params = {"w": jnp.zeros((128, 128))}
        s8 = init_opt_state(params, OptConfig(moments_int8=True))
        s16 = init_opt_state(params, OptConfig())
        b8 = sum(a.size * a.dtype.itemsize
                 for a in jax.tree_util.tree_leaves(s8))
        b16 = sum(a.size * a.dtype.itemsize
                  for a in jax.tree_util.tree_leaves(s16))
        assert b8 < b16 * 0.6
