"""Sharded-dispatch equivalence: ``sharded(N) == unsharded``, always.

The sharded jit dispatcher (``core.simulator_jit``) promises that the
logical-device count is a pure throughput knob: per-point keyed RNG
draws make every point's result independent of which device, span, or
rectangle padding executed it.  This suite pins that promise three
ways:

* property tests over the span planner (``_plan_spans``) — every
  point covered exactly once, in order, padding only ever duplicates
  a span's own last point;
* hypothesis-driven (fallback-compatible) bit-exactness of the full
  engine at device counts 1–4 on a mixed-``n_tasks`` corpus whose
  size is coprime with every ``devices * chunk`` rectangle, so both
  multi-span dispatch and pad points are always in play;
* the cache contract — ``devices`` never reaches a point's content
  hash (bit-identical results must share cache entries) and never
  changes committed spec hashes.

Plus the suite-floor meta check for the harness refactor: moving
``test_simulator_vec.py`` / ``test_simulator_jit.py`` onto
``tests/harness.py`` must never quietly drop tests.

Compilation note: corpus shapes here are chosen so the whole file
compiles ~6 distinct lockstep graphs (see EngineCase comments); keep
new cases on the same ``(sizes, chunk)`` geometry.
"""
import functools

import pytest
from hypothesis import given, settings, strategies as st

from harness import (EngineCase, LIB, assert_bit_exact,
                     assert_deterministic, mixed_corpus, run_case)
from repro.core import Policy
from repro.core import simulator_jit as sj
from repro.core.simulator_jit import _plan_spans

# 7 mixed-size points with chunk=4: uneven at every device count
# (7 % 4, 7 % 8, 7 % 9, 7 % 8 rectangles all ragged), one shared
# max-n_tasks so spans containing point 3 reuse one padded shape
SIZES = (3, 10, 6, 13, 4, 8, 5)
CHUNK = 4
DURATION = 3e5


def corpus(n=len(SIZES)):
    tasksets, seeds = mixed_corpus(SIZES[:n])
    return tasksets, seeds


@functools.lru_cache(maxsize=None)
def reference_rows(n=len(SIZES)):
    """Unsharded (devices=1) jit rows — the bit-exactness baseline."""
    ts, seeds = corpus(n)
    return run_case(EngineCase("jit-d1", devices=1, chunk=CHUNK),
                    ts, seeds, Policy.mesc(), duration=DURATION)


class TestPlanSpans:
    """The span planner, as pure properties (no compilation)."""

    @settings(max_examples=60, deadline=None)
    @given(n=st.integers(1, 400), chunk=st.integers(1, 64),
           devices=st.integers(1, 8))
    def test_cover_order_and_padding(self, n, chunk, devices):
        spans = _plan_spans(n, chunk, devices)
        covered = []
        for idxs, real, d in spans:
            assert 1 <= d <= devices
            assert len(idxs) % d == 0          # equal shards
            assert 1 <= real <= len(idxs)
            covered.extend(idxs[:real])
            # padding duplicates the span's own last real point only
            assert idxs[real:] == [idxs[real - 1]] * (len(idxs) - real)
        assert covered == list(range(n))       # exact cover, in order

    @settings(max_examples=30, deadline=None)
    @given(n=st.integers(1, 300), chunk=st.integers(1, 64))
    def test_single_device_reproduces_legacy_plan(self, n, chunk):
        """devices=1 must be the pre-sharding chunking exactly: full
        chunks then one ragged tail padded up to the chunk size."""
        spans = _plan_spans(n, chunk, 1)
        assert all(d == 1 for _, _, d in spans)
        assert [real for _, real, _ in spans] == \
            [min(chunk, n - lo) for lo in range(0, n, chunk)]
        # only the tail of a multi-span plan pads; the first span of a
        # small batch shrinks to the batch instead
        assert len(spans[0][0]) == min(chunk, n)
        for idxs, real, _ in spans[1:]:
            assert len(idxs) == chunk

    def test_later_tails_keep_the_superchunk_shape(self):
        # lo > 0 tails pad to the full devices x chunk rectangle so
        # they reuse the superchunk compilation
        spans = _plan_spans(17, 2, 3)          # 6 + 6 + 5
        assert [(len(i), r, d) for i, r, d in spans] == \
            [(6, 6, 3), (6, 6, 3), (6, 5, 3)]


class TestShardedBitExactness:
    """sharded(N) == sharded(1), bit for bit, sampled profile."""

    @settings(max_examples=6, deadline=None)
    @given(devices=st.integers(1, 4))
    def test_any_device_count_matches_unsharded(self, devices):
        ts, seeds = corpus()
        got = run_case(EngineCase(f"jit-d{devices}", devices=devices,
                                  chunk=CHUNK),
                       ts, seeds, Policy.mesc(), duration=DURATION)
        assert_bit_exact(reference_rows(), got,
                         f"devices={devices} vs devices=1")

    def test_pad_points_never_leak(self):
        """n=5 on a devices=4 x chunk=2 rectangle: 3 of 8 simulated
        lanes are padding — exactly 5 rows come back, each equal to
        its unsharded self (a leaked pad row would misalign or
        duplicate the tail)."""
        ts, seeds = corpus(5)
        got = run_case(EngineCase("jit-d4-pad", devices=4, chunk=2),
                       ts, seeds, Policy.mesc(), duration=DURATION)
        assert len(got) == 5
        assert_bit_exact(reference_rows()[:5], got, "padded rectangle")

    def test_sharded_composition_independence(self):
        """Repeat and reversed-batch runs at devices=3: identical rows
        (the keyed-RNG contract survives sharding)."""
        ts, seeds = corpus()
        a = assert_deterministic(
            EngineCase("jit-d3", devices=3, chunk=CHUNK),
            ts, seeds, Policy.mesc(), duration=DURATION)
        assert_bit_exact(reference_rows(), a, "devices=3 vs devices=1")

    def test_retry_ladder_stays_sharded_exact(self, monkeypatch):
        """A tiny starting interrupt table forces overflow retries,
        which deliberately run single-device — the merged result must
        still equal the unsharded run's bit for bit."""
        monkeypatch.setattr(sj, "_RETRY_BUCKET", 4)
        ts, seeds = corpus()
        kw = dict(duration=DURATION)
        narrow1 = run_case(EngineCase("jit-d1-k2", devices=1,
                                      chunk=CHUNK, table_width=2),
                           ts, seeds, Policy.mesc(), **kw)
        assert_bit_exact(reference_rows(), narrow1, "width ladder d=1")
        narrow3 = run_case(EngineCase("jit-d3-k2", devices=3,
                                      chunk=CHUNK, table_width=2),
                           ts, seeds, Policy.mesc(), **kw)
        assert_bit_exact(narrow1, narrow3, "width ladder d=3")

    def test_retries_dispatch_single_device(self, monkeypatch):
        """The ladder's devices handoff, pinned without compiles:
        the first dispatch carries the span's device count, every
        retry runs devices=1 (retry sub-batches are bucket-padded,
        not rectangle-padded)."""
        calls = []

        def run_once(b, policy, seeds, duration, op, cf, nominal, K,
                     devices=1, scenario=None):
            calls.append((K, devices))
            return {"overflow": [K <= sj._K0] * len(seeds),
                    "seeds": list(seeds)}

        monkeypatch.setattr(sj, "_run_once", run_once)
        monkeypatch.setattr(
            sj, "_assemble",
            lambda b, final, duration: [None] * len(final["seeds"]))
        monkeypatch.setattr(sj, "_RETRY_BUCKET", 4)
        ts, seeds = corpus(6)
        sj._run_chunk(ts, LIB, Policy.mesc(), seeds, DURATION, 0.3,
                      2.0, "sampled", devices=3)
        assert [d for _, d in calls] == [3, 1]
        assert calls[1][0] == 2 * sj._K0


class TestDevicesCacheNeutral:
    """devices never reaches content hashes (results are identical)."""

    def _point(self, devices):
        from repro.experiments.spec import Sweep
        return Sweep(name="t", policies=(Policy.mesc(),), n_sets=1,
                     duration=1e6, engine="jit",
                     devices=devices).points()[0]

    def test_key_identical_across_device_counts(self):
        keys = {self._point(d).key() for d in (None, 1, 4)}
        assert len(keys) == 1

    def test_to_dict_carries_devices_only_when_set(self):
        assert "devices" not in self._point(None).to_dict()
        d = self._point(4).to_dict()
        assert d["devices"] == 4
        from repro.experiments.spec import SimPoint
        assert SimPoint.from_dict(d).devices == 4    # worker payload

    def test_sweep_spec_hash_unchanged_when_unset(self):
        from repro.experiments.spec import Sweep
        plain = Sweep(name="t", policies=(Policy.mesc(),), n_sets=1,
                      duration=1e6, engine="jit")
        assert "devices" not in plain.to_dict()

    def test_devices_requires_jit_engine(self):
        from repro.experiments.spec import Sweep
        with pytest.raises(ValueError, match="devices"):
            Sweep(name="t", policies=(Policy.mesc(),), n_sets=1,
                  duration=1e6, engine="vec", devices=2)
        with pytest.raises(ValueError, match="devices"):
            Sweep(name="t", policies=(Policy.mesc(),), n_sets=1,
                  duration=1e6, engine="jit", devices=0)


class TestSuiteFloor:
    """The harness refactor must never quietly drop tests."""

    # pre-refactor test-function counts of the migrated modules
    # (test_serving pinned post-ServingCase refactor: the 7 real-model
    # tests plus the 6 virtual-clock harness tests; test_scenarios
    # pinned at its PR-8 landing size)
    # test_lint / test_graphlint pinned at the graph-lint PR landing
    # sizes (pragma-justification, --changed and ir-* coverage)
    FLOORS = {"test_simulator_jit": 23, "test_simulator_vec": 19,
              "test_serving": 13, "test_scenarios": 18,
              "test_lint": 38, "test_graphlint": 41}

    @pytest.mark.parametrize("module,floor", sorted(FLOORS.items()))
    def test_migrated_module_keeps_its_tests(self, module, floor):
        mod = __import__(module)
        n = sum(1 for cls in vars(mod).values()
                if isinstance(cls, type)
                and cls.__name__.startswith("Test")
                for name in vars(cls) if name.startswith("test_"))
        assert n >= floor, \
            f"{module} has {n} test functions, refactor floor {floor}"

    def test_lint_rule_registry_never_shrinks(self):
        # dropping a lint rule silently un-guards a repo contract;
        # removal must be a conscious, test-visible decision.  9 AST
        # rules plus the 5 non-default ir-* graph rules.
        import tools.lint.rules  # noqa: F401
        from tools.lint import RULES
        assert len(RULES) >= 14, sorted(RULES)
