"""End-to-end behaviour tests: the paper's headline claims, a real training
run that learns, and the serving path under MESC scheduling."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Policy, generate_taskset, simulate, workload_library
from repro.data import batch_for_arch
from repro.models import lm
from repro.models.common import CPU_RC
from repro.optim import OptConfig, init_opt_state
from repro.runtime.trainer import make_train_step

LIB = workload_library(include_archs=False)


def _mean(xs):
    return float(np.mean(xs)) if xs else 0.0


class TestPaperClaims:
    """Quantitative reproduction of the paper's headline observations."""

    def _run(self, policy, seeds=(0, 1, 2), u=0.7):
        pis, cis, saves = [], [], []
        for s in seeds:
            tasks = generate_taskset(u, seed=s, programs=LIB)
            m = simulate(tasks, LIB, policy, duration=3e8, seed=s + 100)
            pis += m.pi_blocking
            cis += m.ci_blocking
            saves += m.save_cycles
        return _mean(pis), _mean(cis), _mean(saves)

    def test_inversion_speedup_two_orders_of_magnitude(self):
        """Abstract: ~250x pi / ~300x ci reduction vs non-preemptive.
        We require >= 2 orders of magnitude via >=50x on the mean (the
        exact ratio depends on the workload mix; see benchmarks/fig7)."""
        pi_m, ci_m, _ = self._run(Policy.mesc())
        pi_n, ci_n, _ = self._run(Policy.non_preemptive())
        assert pi_n / max(pi_m, 1) > 50
        assert ci_n / max(ci_m, 1) > 50

    def test_bank_allocation_speeds_up_context_switch(self):
        """Obs. 1: removing the bank model slows CS by thousands of cycles."""
        _, _, s_banks = self._run(Policy.mesc())
        _, _, s_nobank = self._run(Policy.mesc(use_banks=False))
        assert s_nobank > s_banks
        assert 1000 < s_nobank - s_banks < 50000

    def test_success_ordering_matches_fig8(self):
        """MESC-with-CS must dominate MESC-without-CS (non-preemptive)."""
        ok_mesc = ok_np = 0
        n = 12
        for s in range(n):
            tasks = generate_taskset(0.85, seed=s, programs=LIB)
            m1 = simulate(tasks, LIB, Policy.mesc(), duration=2e8, seed=s)
            m2 = simulate(tasks, LIB, Policy.non_preemptive(), duration=2e8,
                          seed=s)
            ok_mesc += m1.success("HI")
            ok_np += m2.success("HI")
        assert ok_mesc >= ok_np

    def test_survivability_positive_under_pressure(self):
        """Obs. 5: LO-tasks retain >20% survivability even at high gamma."""
        rates = []
        for s in range(6):
            tasks = generate_taskset(0.8, gamma=0.8, seed=s, programs=LIB)
            m = simulate(tasks, LIB, Policy.mesc(), duration=2e8, seed=s,
                         overrun_prob=0.5)
            if m.lo_released_in_hi:
                rates.append(m.survivability())
        if rates:  # only assert when degraded-mode LO releases occurred
            assert np.mean(rates) > 0.2


class TestTraining:
    def test_tiny_model_learns(self):
        cfg = get_config("tinyllama-1.1b-smoke")
        opt_cfg = OptConfig(lr=3e-3, warmup_steps=5, decay_steps=60,
                            weight_decay=0.01)
        params = lm.init_params(cfg, jax.random.PRNGKey(0), CPU_RC)
        opt = init_opt_state(params, opt_cfg)
        step_fn = jax.jit(make_train_step(cfg, CPU_RC, opt_cfg))
        losses = []
        for step in range(60):
            batch = {k: jnp.asarray(v) for k, v in
                     batch_for_arch(cfg, 32, 8, step).items()}
            params, opt, m = step_fn(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.5, losses[::10]

    def test_microbatched_grads_match(self):
        cfg = get_config("olmo-1b-smoke")
        opt_cfg = OptConfig()
        params = lm.init_params(cfg, jax.random.PRNGKey(0), CPU_RC)
        opt = init_opt_state(params, opt_cfg)
        batch = {k: jnp.asarray(v) for k, v in
                 batch_for_arch(cfg, 16, 4, 0).items()}
        s1 = jax.jit(make_train_step(cfg, CPU_RC, opt_cfg, microbatches=1))
        s2 = jax.jit(make_train_step(cfg, CPU_RC, opt_cfg, microbatches=2))
        p1, _, m1 = s1(params, opt, batch)
        p2, _, m2 = s2(params, opt, batch)
        # losses may differ (per-microbatch mean), params must be close
        d = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
        assert max(jax.tree_util.tree_leaves(d)) < 5e-2


class TestServing:
    def test_greedy_decode_deterministic(self):
        cfg = get_config("phi4-mini-3.8b-smoke")
        params = lm.init_params(cfg, jax.random.PRNGKey(0), CPU_RC)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
        outs = []
        for _ in range(2):
            _, cache = lm.prefill(cfg, params, {"tokens": toks}, CPU_RC,
                                  max_len=16)
            cur = toks[:, -1]
            seq = []
            for _ in range(8):
                logits, cache = lm.decode_step(cfg, params, cur, cache,
                                               CPU_RC)
                cur = jnp.argmax(logits, -1).astype(jnp.int32)
                seq.append(np.asarray(cur))
            outs.append(np.stack(seq))
        np.testing.assert_array_equal(outs[0], outs[1])
