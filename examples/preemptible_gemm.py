"""Instruction-level preemption INSIDE a single GEMM — the Pallas analogue
of Gemmini^RT's step_wise_mvout of the accumulator (paper SS V.A).

A high-criticality request arrives while a large GEMM streams through the
"systolic array".  Instead of waiting for the full product (non-preemptive)
or restarting it later (kill-based), MESC saves the partial fp32
accumulator at a K-block boundary, runs the HI work, and resumes exactly
where it stopped.

    PYTHONPATH=src python examples/preemptible_gemm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.systolic_gemm import gemm_partial
from repro.core import Instruction, Op
from repro.core.executor import GemminiRT
from repro.core.task import Crit, TaskParams, TCB


def main():
    M = K = N = 1024
    bk = 128
    nk = K // bk
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (M, K), jnp.float32)
    B = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    want = np.asarray(A @ B)

    # LO task starts the big GEMM; after 3 of 8 K-blocks a HI task arrives
    acc = jnp.zeros((M, N), jnp.float32)
    t0 = time.time()
    acc = gemm_partial(A, B, acc, 0, 3, bk=bk, interpret=True)

    # --- preemption: freeze, save accumulator ("step_wise_mvout") ---
    hw = GemminiRT()
    lo = TCB(params=TaskParams(0, 5, 1e9, 1e9, 1e6, 2e6, Crit.LO, 2,
                               workload="big_gemm"))
    hw.accum_bytes_used[0] = acc.size * 4 % (64 * 1024)
    saved = np.asarray(acc)                   # accumulator -> DRAM
    br = hw.context_save(lo, drain_cycles=bk + 32, next_eta=2)
    print(f"context save: {br.total} cycles "
          f"(drain={br.drain}, acc={br.accumulator}, cfg={br.config_buffer})")

    # --- HI work runs immediately (here: a small urgent GEMM) ---
    hi_out = jax.random.normal(key, (128, 128)) @ jax.random.normal(
        jax.random.fold_in(key, 2), (128, 128))
    hi_out.block_until_ready()
    print("HI task served while LO GEMM is suspended")

    # --- resume LO from the saved accumulator ---
    rr = hw.context_restore(lo)
    acc = gemm_partial(A, B, jnp.asarray(saved), 3, nk, bk=bk,
                       interpret=True)
    err = float(np.max(np.abs(np.asarray(acc) - want)))
    print(f"context restore: {rr.total} cycles;  resumed GEMM max|err| "
          f"vs uninterrupted = {err:.2e}")
    assert err < 1e-2
    print("preempt/resume exact — the GEMM never restarted from scratch")


if __name__ == "__main__":
    main()
