"""Train a small (~10M param) model for a few hundred steps with
fault-tolerant checkpointing, then simulate a crash and resume —
demonstrating the training substrate end to end on CPU.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import subprocess
import sys
import tempfile
from pathlib import Path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="tinyllama-1.1b-smoke")
    args = ap.parse_args()
    ckpt = Path(tempfile.mkdtemp(prefix="repro_ckpt_"))
    env = {"PYTHONPATH": "src"}
    common = [sys.executable, "-m", "repro.launch.train",
              "--arch", args.arch, "--seq", "64", "--batch", "8",
              "--ckpt-dir", str(ckpt), "--ckpt-every", "50"]
    half = max(args.steps // 2, 60)
    print(f"=== phase 1: train to step {half} (then 'crash') ===")
    subprocess.run(common + ["--steps", str(half)], check=True,
                   env={**env, **dict(__import__('os').environ)})
    print(f"=== phase 2: restart from the checkpoint, continue to "
          f"{args.steps} ===")
    subprocess.run(common + ["--steps", str(args.steps)], check=True,
                   env={**env, **dict(__import__('os').environ)})
    print(f"checkpoints kept in {ckpt}")


if __name__ == "__main__":
    main()
