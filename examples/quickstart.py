"""Quickstart: build an assigned architecture at smoke scale, train a few
steps on the synthetic pipeline, then serve greedily from it.

    PYTHONPATH=src python examples/quickstart.py [--arch tinyllama-1.1b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.data import batch_for_arch
from repro.models import lm
from repro.models.common import CPU_RC
from repro.optim import OptConfig, init_opt_state
from repro.runtime.trainer import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=list_archs())
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    cfg = get_config(args.arch + "-smoke")   # reduced same-family config
    print(f"family={cfg.family}  d_model={cfg.d_model}  L={cfg.n_layers}")

    params = lm.init_params(cfg, jax.random.PRNGKey(0), CPU_RC)
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=5, decay_steps=args.steps)
    opt = init_opt_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, CPU_RC, opt_cfg))

    for step in range(args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in batch_for_arch(cfg, 32, 8, step).items()}
        params, opt, m = step_fn(params, opt, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:3d}  loss={float(m['loss']):.3f}")

    if cfg.family == "audio":
        print("decode demo skipped for multi-codebook audio quickstart")
        return
    prompt = jnp.asarray([[1, 2, 3, 4, 5, 6, 7, 8]], jnp.int32)
    _, cache = lm.prefill(cfg, params, {"tokens": prompt}, CPU_RC, max_len=24)
    cur = prompt[:, -1]
    out = []
    for _ in range(12):
        logits, cache = lm.decode_step(cfg, params, cur, cache, CPU_RC)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(cur[0]))
    print("greedy continuation:", out)


if __name__ == "__main__":
    main()
