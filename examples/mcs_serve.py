"""End-to-end driver (the paper's system): mixed-criticality serving of a
small model with batched requests under the MESC scheduler, versus a
non-preemptive accelerator baseline.

HI-criticality requests preempt LO requests at instruction (decode-step)
boundaries; request KV caches live in a bounded "bank pool" of device
slots managed like the Gemmini^RT scratchpad (context save = cache to host
DRAM).  Reported: time-to-first-token and completion latency per
criticality — the serving analogue of the paper's Fig. 7 blocking numbers.

    PYTHONPATH=src python examples/mcs_serve.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
