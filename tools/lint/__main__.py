"""CLI for repro-lint: ``python -m tools.lint [paths ...]``.

Exit codes: 0 clean, 1 findings, 2 bad invocation/configuration.
See docs/linting.md for the rule catalog and workflows.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

from tools.lint.core import (DEFAULT_BASELINE, DEFAULT_PATHS,
                             LINT_SUFFIXES, RULES, LintConfigError,
                             run_lint, write_baseline)
from tools.lint.rules.salt_drift import update_salts


def default_root() -> Path:
    """The repo root: this file lives at <root>/tools/lint/."""
    return Path(__file__).resolve().parents[2]


def changed_files(root: Path) -> list:
    """Root-relative lintable files changed vs git HEAD, plus
    untracked ones — the fast pre-commit surface for ``--changed``."""
    out = []
    for cmd in (["git", "diff", "--name-only", "HEAD"],
                ["git", "ls-files", "--others", "--exclude-standard"]):
        try:
            proc = subprocess.run(cmd, cwd=root, capture_output=True,
                                  text=True, check=True)
        except (OSError, subprocess.CalledProcessError) as e:
            raise LintConfigError(
                f"--changed needs a git checkout at {root}: {e}")
        out.extend(line.strip() for line in proc.stdout.splitlines()
                   if line.strip())
    return sorted({f for f in out
                   if Path(f).suffix in LINT_SUFFIXES
                   and (root / f).is_file()})


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description="AST-based contract checker for the repo's "
                    "determinism, CRN and cache-salt invariants")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: "
                         f"{' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: auto-detected)")
    ap.add_argument("--format", choices=("human", "json"),
                    default="human")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset")
    ap.add_argument("--baseline", type=Path, default=None,
                    help=f"baseline file (default: {DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report grandfathered findings too")
    ap.add_argument("--write-baseline", action="store_true",
                    help="grandfather the current findings and exit 0")
    ap.add_argument("--update-salts", action="store_true",
                    help="re-pin tools/lint/salts.json surface hashes")
    ap.add_argument("--changed", action="store_true",
                    help="lint only files changed vs git HEAD (plus "
                         "untracked) — the fast pre-commit mode")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    root = (args.root or default_root()).resolve()
    try:
        if args.changed:
            if args.paths:
                raise LintConfigError(
                    "--changed selects its own files; drop the "
                    "explicit path arguments")
            args.paths = changed_files(root)
            if not args.paths:
                print("repro-lint: no changed lintable files")
                return 0

        if args.list_rules:
            import tools.lint.rules  # noqa: F401
            for name in sorted(RULES):
                print(f"{name:18s} {RULES[name].contract}")
            return 0

        if args.update_salts:
            changed = update_salts(root)
            print(f"salts re-pinned: {len(changed)} changed "
                  f"({', '.join(changed) or 'none'})")
            return 0

        rule_names = (args.rules.split(",") if args.rules else None)
        report, ctx = run_lint(
            root, args.paths, rule_names=rule_names,
            baseline_path=args.baseline,
            use_baseline=not (args.no_baseline or args.write_baseline))

        if args.write_baseline:
            bpath = args.baseline or (root / DEFAULT_BASELINE)
            n = write_baseline(bpath, report.findings, ctx)
            print(f"baseline written: {bpath} "
                  f"({n} entries, {len(report.findings)} findings)")
            return 0
    except LintConfigError as e:
        print(f"repro-lint: error: {e}", file=sys.stderr)
        return 2

    if args.format == "json":
        print(json.dumps(report.to_json(), indent=1, sort_keys=True))
        return report.exit_code

    for f in report.findings:
        print(f"{f.location()}: {f.rule}: {f.message}")
    for e in report.stale_baseline:
        print(f"note: stale baseline entry {e['fp']} "
              f"({e['rule']} @ {e['path']}, {e['count']} unmatched) — "
              "regenerate with --write-baseline")
    n = len(report.findings)
    print(f"repro-lint: {report.checked_files} files, "
          f"{len(report.rules_run)} rules: "
          f"{n} finding(s), {len(report.baselined)} baselined, "
          f"{len(report.suppressed)} pragma-suppressed")
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
