"""Violating fixture tree: this module's semantics drifted from the
pinned surface hash in the tree's salts.json (salt-drift)."""

ENGINE_SEMANTICS_VERSION = 1


def step(state):
    return state + 2
