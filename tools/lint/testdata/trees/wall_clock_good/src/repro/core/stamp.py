"""Clean fixture tree: the injection contract — referencing
``time.monotonic`` as a default is legal; only inline calls are not."""
import time


def stamp(row, clock=time.monotonic):
    row["t"] = clock()
    return row
