"""Violating fixture tree: inline wall-clock call in a pure zone —
timestamps must flow through an injected clock callable."""
import time


def stamp(row):
    row["t"] = time.monotonic()
    return row
