"""Violating fixture tree: even a *seeded* host stream is banned in a
CRN zone — only keyed splitmix64 draws are sanctioned here."""
import numpy as np


def jitter(seed, n):
    rng = np.random.default_rng(seed)
    return rng.random(n)
