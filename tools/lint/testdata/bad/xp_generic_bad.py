"""Violating fixture: direct np/jax array ops in xp-generic code."""
import jax.numpy as jnp
import numpy as np


def mix(xp, a):
    b = np.asarray(a)           # array op must go through xp
    c = jnp.cumsum(b)           # direct jax forks the engines
    return xp.sum(c)
