"""Violating fixture: raw env reads and a non-member registry
literal (env-validation)."""
import os


def configure():
    workers = os.environ.get("REPRO_WORKERS", "4")     # raw read
    cache = os.environ["REPRO_CACHE_DIR"]              # raw subscript
    plat = os.getenv("REPRO_PLATFORM")                 # raw getenv
    return workers, cache, plat


def sweep(run):
    return run(engine="evnet")       # typo: not a member of ENGINES
