"""Violating fixture: process-global RNG outside CRN zones."""
import random

import numpy as np


def draw_stdlib():
    return random.random()


def draw_np_global():
    np.random.seed(0)
    return np.random.random()
