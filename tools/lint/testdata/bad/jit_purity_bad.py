"""Violating fixture: host constructs inside a lax.while_loop body."""
import functools

import jax
import numpy as np


def _body(bonus, carry):
    t, acc = carry
    if t > 3:                       # python branch on traced value
        acc = acc + bonus
    host = float(acc)               # host coercion of traced value
    probe = acc.item()              # host round-trip
    extra = np.maximum(acc, t)      # host numpy on traced values
    del host, probe
    return (t + 1, acc + extra)


def run():
    return jax.lax.while_loop(lambda c: c[0] < 10,
                              functools.partial(_body, 2), (0, 0))
