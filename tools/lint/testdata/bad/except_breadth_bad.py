"""Violating fixture: broad handlers that swallow (except-breadth)."""


def swallow_exception():
    try:
        return 1 / 0
    except Exception:
        return None


def swallow_bare():
    try:
        return open("nope")
    except:  # noqa: E722
        return None


def swallow_tuple():
    try:
        return int("x")
    except (ValueError, Exception):
        return None
