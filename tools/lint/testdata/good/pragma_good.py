"""Clean-by-pragma fixture: real violations suppressed by same-line
``# repro-lint: disable=...`` pragmas (the framework counts them as
suppressed, not findings)."""
import random


def boundary():
    try:
        return random.random()  # repro-lint: disable=no-host-rng (fixture)
    except Exception:  # repro-lint: disable=except-breadth (fixture)
        return None
