"""Clean fixture: narrow handlers and the re-raise idiom."""


def narrow():
    try:
        return int("x")
    except (ValueError, TypeError):
        return None


def cleanup_then_propagate(path):
    try:
        return open(path).read()
    except BaseException:
        print("cleanup")
        raise
