"""Clean fixture: xp-generic function using only xp plus neutral
dtype constructors and np.errstate."""
import numpy as np


def mix(xp, a):
    with np.errstate(over="ignore"):
        b = xp.asarray(a, dtype=np.uint64)
        return xp.sum(b * np.uint64(3))
