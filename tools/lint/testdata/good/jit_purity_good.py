"""Clean fixture: pure traced while_loop body (jnp ops, lax.cond
staging on closure statics only)."""
import jax
import jax.numpy as jnp
import numpy as np


def build(use_bonus):
    def body(carry):
        t, acc = carry
        if use_bonus:               # closure static: legal staging
            acc = acc + 1
        acc = jnp.where(t > 3, acc + 2, acc)
        width = np.uint64(33)       # literal-arg dtype scalar: legal
        return (t + 1, acc + jnp.uint64(width))

    return jax.lax.while_loop(lambda c: c[0] < 10, body, (0, 0))
