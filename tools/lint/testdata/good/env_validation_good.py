"""Clean fixture: env reads inside a validating _env_* helper,
registry literals that are members, and writes (configuration)."""
import os


def _env_int(name, default):
    raw = os.environ.get(name)
    if raw is None:
        return default
    try:
        return int(raw)
    except ValueError:
        raise ValueError(f"{name}={raw!r} is not an integer") from None


def configure():
    os.environ["JAX_PLATFORM_NAME"] = "cpu"    # writes stay legal
    flags = os.environ.get("XLA_FLAGS", "")    # free-form passthrough
    return _env_int("REPRO_WORKERS", 4), flags


def sweep(run):
    return run(engine="jit", scenario="faults@0.05")
