"""Clean fixture: explicitly seeded per-point streams (legal outside
CRN zones) and keyed jax.random."""
import numpy as np


def draw_seeded(seed):
    rng = np.random.default_rng(seed)
    return rng.random()


def draw_keyed(key):
    import jax.random as jr
    return jr.uniform(key)
