"""repro-lint: AST-based static analysis mechanizing the repo's
reproducibility contracts (determinism, CRN draws, cache salts,
injected clocks, xp-genericity, loud env validation).

Entry points:

  * CLI — ``python -m tools.lint [paths]`` (see docs/linting.md);
  * API — :func:`tools.lint.core.run_lint` plus the registry
    :data:`tools.lint.core.RULES` (populated by importing
    ``tools.lint.rules``).
"""
from tools.lint.core import (RULES, Context, Finding, Report, Rule,  # noqa: F401
                             run_lint)
